"""Edge-analytics demo (the paper's deployment story, §1):

an IoT gateway keeps only the GreedyGD-compressed stream + a PairwiseHist
synopsis; dashboards query the synopsis at sub-ms latency; new sensor
batches append incrementally; the synopsis serializes to a few kB for
shipping to other edge nodes (storage codec round-trip).

    PYTHONPATH=src python examples/aqp_edge_demo.py
"""
import numpy as np

from repro.aqp import AQPFramework, ExactEngine
from repro.aqp.datasets import load
from repro.core import storage
from repro.core.query import QueryEngine
from repro.core.types import BuildParams


def main():
    table = load("iot_temp", n=300_000)
    fw = AQPFramework(BuildParams(n_samples=60_000)).ingest(table)
    rep = fw.storage_report()
    print(f"edge node storage: raw {rep['raw_data_bytes']/1e6:.1f} MB -> "
          f"compressed {rep['compressed_data_bytes']/1e6:.1f} MB + "
          f"synopsis {rep['synopsis']['total']/1e3:.1f} kB "
          f"(total {rep['total_storage_reduction']:.2f}x smaller)")

    exact = ExactEngine(table)
    for sql in ("SELECT AVG(temp) FROM t WHERE device = 'dev3'",
                "SELECT MAX(humidity) FROM t WHERE temp > 24",
                "SELECT COUNT(*) FROM t WHERE battery < 50 AND temp > 22"):
        res = fw.query(sql)
        truth = exact.query(sql)
        print(f"{sql}\n  ~ {res.estimate:.2f} [{res.lower:.2f},"
              f" {res.upper:.2f}] exact {truth:.2f} "
              f"[{res.latency_s*1e3:.2f} ms]")

    # Ship the synopsis to another node: serialize -> deserialize -> query.
    blob = storage.encode(fw.synopsis)
    print(f"\nserialized synopsis: {len(blob)/1e3:.1f} kB")
    remote = QueryEngine(storage.decode(blob))
    res = remote.query("SELECT AVG(temp) FROM t WHERE device = 'dev3'")
    print(f"remote node answers: {res.estimate:.2f}")

    # Incremental ingestion: a new sensor batch arrives.
    batch = load("iot_temp", n=50_000, seed=99)
    fw.append_rows(batch)
    try:
        fw.query("SELECT AVG(temp) FROM t")
    except RuntimeError as exc:
        print(f"\nafter append: {exc}")
    fw.rebuild(table)
    res = fw.query("SELECT AVG(temp) FROM t")
    print(f"rebuilt synopsis answers: {res.estimate:.2f}")


if __name__ == "__main__":
    main()
