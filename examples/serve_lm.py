"""Batched serving demo: prefill + decode with continuous slot refill.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=4, max_len=256)

    rng = np.random.default_rng(7)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new_tokens=16)
        for n in (24, 18, 24, 30, 12, 24, 20)
    ]
    t0 = time.perf_counter()
    engine.generate(requests)
    wall = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in requests)
    print(f"{len(requests)} requests over {engine.slots} slots: "
          f"{total_new} tokens in {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s on 1 CPU core)")
    print(f"stats: {engine.last_stats}")
    for i, req in enumerate(requests):
        print(f"req{i}: prompt[{len(req.prompt)}] -> {req.out_tokens}")


if __name__ == "__main__":
    main()
