"""End-to-end training driver: a ~30M-param qwen3-family model for a few
hundred steps on CPU, with fault-tolerant checkpointing, the straggler
watchdog, and PairwiseHist telemetry analytics over the run.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 256]

(The full-size configs train identically under the production mesh via
src/repro/launch/train.py; this example is sized for the CPU container. At
~100M params (--d-model 512 --layers 8) a few hundred steps take hours on
1 CPU core — the default here keeps the demo minutes-scale.)
"""
import argparse
import tempfile

from repro.models.model import ModelConfig
from repro.train.loop import train
from repro.train.optimizer import Hyper
from repro.train.telemetry import TelemetryStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=192)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compress", action="store_true",
                    help="GD-inspired int8 gradient compression + EF")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-demo", vocab=2048, d_model=args.d_model,
        n_layers=args.layers, n_heads=4, n_kv=2,
        head_dim=args.d_model // 4, d_ff=args.d_model * 3,
        qk_norm=True, dtype="float32", attn_chunk=64)
    hyper = Hyper(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")

    compressor = None
    if args.grad_compress:
        from repro.train.grad_compress import GDQuantizer
        compressor = GDQuantizer(bits=8)

    telemetry = TelemetryStore()
    state, hist = train(cfg, hyper, steps=args.steps, batch=args.batch,
                        seq=args.seq, ckpt_dir=ckpt_dir, ckpt_every=50,
                        compressor=compressor, telemetry=telemetry,
                        log_every=20)
    print(f"\nfinal step {int(state.step)}; loss "
          f"{hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"checkpoints in {ckpt_dir}")

    # AQP over the training telemetry (the paper's technique, §DESIGN.md 4).
    telemetry.build()
    half = args.steps // 2
    for sql in (f"SELECT AVG(loss) FROM t WHERE step > {half}",
                "SELECT MAX(step_time) FROM t WHERE step > 10",
                "SELECT AVG(grad_norm) FROM t WHERE loss < 8"):
        res = telemetry.query(sql)
        if res.estimate is None:
            print(f"telemetry  {sql} ~ (no matching rows)")
        else:
            print(f"telemetry  {sql} ~ {res.estimate:.4f} "
                  f"[{res.lower:.4f}, {res.upper:.4f}]")


if __name__ == "__main__":
    main()
