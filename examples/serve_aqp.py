"""Multi-table AQP serving demo: streaming admission + batched execution.

The single-table ``AQPFramework`` answers one query at a time; the serving
subsystem (``repro.serve.aqp``, reference: docs/serving.md) turns it into
a multi-tenant query server:

  * **TableCatalog** — registers many named tables, so ``FROM <table>``
    actually resolves (unknown tables raise ``PlanError``);
  * **streaming admission** — ``submit`` enqueues and returns a
    ``QueryFuture`` immediately; an admission worker drains the queue into
    waves under a latency/batch-size policy and resolves futures as waves
    complete (``query_batch`` is the synchronous submit+flush+wait
    wrapper);
  * **BatchScheduler** — groups in-flight queries by plan shape
    (table, agg column, predicate column set) and runs every group as ONE
    fused query-batched kernel launch (``kernels.weightings
    .batched_weightings``); GROUP BY queries expand into per-category leaf
    plans at planning time and their leaves ride the same fused launches
    (OR-trees fall back per query);
  * **backpressure** — the admission queue is bounded (``max_queue_depth``)
    and a full queue sheds per ``shed_policy`` (``reject`` /
    ``shed_oldest`` / ``block``), resolving the losing futures with a
    typed ``AdmissionRejected`` result instead of growing without limit
    (synchronous ``query_batch`` drains-and-retries instead);
  * **LRU plan + result caches** — keyed on normalized SQL (plus
    plan-canonical per-leaf keys for GROUP BY) and the owning table's
    staleness epoch, so ``append_rows`` invalidates rather than serves
    stale results;
  * **Metrics** — per-table p50/p99 latency, throughput, cache hit rates,
    GROUP BY expansion counters, admission queue/wait/drain/shed
    telemetry;
  * **tracing** (docs/observability.md) — the demo runs with tracing on:
    each query gets an EXPLAIN stage breakdown (printed for one below)
    and the span ring is exported to ``trace.json`` — open it at
    https://ui.perfetto.dev (or chrome://tracing) to see the admission /
    worker / per-query swimlanes.

Run:

    PYTHONPATH=src python examples/serve_aqp.py

Benchmark (throughput vs batch size, cache-hit sweep, streaming p50/p99
under Poisson arrivals, GROUP BY batching; acceptance targets: >= 5x
queries/sec at batch 64 and > 2x for GROUP BY at batch 16 vs one-at-a-time
AQPFramework.query):

    PYTHONPATH=src python -m benchmarks.bench_serving          # quick
    PYTHONPATH=src python -m benchmarks.run --only serving     # full
"""
from __future__ import annotations

import json

import numpy as np

from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.core.query import PlanError
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer


def main():
    params = BuildParams(n_samples=20_000, seed=0)
    # Auto mode: fused Pallas launches on TPU; per-query NumPy on CPU (where
    # JAX dispatch is the overhead, not the savings — batched_fraction will
    # read 0.0 here). Pass mode="ref" to watch the fused path off-TPU.
    srv = AQPServer(trace_enabled=True)

    print("== registering tables ==")
    for name in ("power", "flights"):
        table = load(name, n=50_000)
        srv.register_table(name, table, params=params, use_compression=False)
        print(f"  {name}: {len(next(iter(table.values()))):,} rows, "
              f"{len(table)} columns")

    print("\n== one wave, two tables, mixed shapes ==")
    wave = [
        "SELECT COUNT(*) FROM power WHERE global_active_power > 2.0",
        "SELECT COUNT(*) FROM power WHERE global_active_power > 4.0",
        "SELECT AVG(arr_delay) FROM flights WHERE distance > 800",
        "SELECT SUM(arr_delay) FROM flights WHERE distance > 800 "
        "AND dep_delay > 10",
        # OR-tree: executes on the per-query reference path
        "SELECT COUNT(*) FROM flights WHERE dep_delay > 30 OR arr_delay > 30",
    ]
    for sql, res in zip(wave, srv.query_batch(wave)):
        est, lo, hi = res.as_tuple()
        print(f"  {sql}\n    -> {est:,.1f}  [{lo:,.1f}, {hi:,.1f}]")

    print("\n== EXPLAIN: where one traced query's wall-clock went ==")
    res = srv.query("SELECT AVG(arr_delay) FROM flights WHERE distance > 650")
    exp = res.explain
    for stage in ("plan", "admit", "queue", "assemble", "execute", "resolve"):
        print(f"  {stage:>9}: {exp[f'{stage}_ms']:8.3f} ms")
    print(f"  {'total':>9}: {exp['total_ms']:8.3f} ms  "
          f"(kernel share {exp['kernel_share_ms']:.3f} ms, "
          f"plan_cache_hit={exp['plan_cache_hit']}, "
          f"batched={exp['batched']}, wave={exp['wave_size']})")

    print("\n== GROUP BY rides the batched path (per-category leaf plans) ==")
    res = srv.query("SELECT AVG(arr_delay) FROM flights "
                    "WHERE distance > 500 GROUP BY airline")
    for value, (est, lo, hi) in sorted(res.groups.items())[:5]:
        print(f"  {value}: {est:,.1f}  [{lo:,.1f}, {hi:,.1f}]")
    print(f"  ... {len(res.groups)} groups; group_by telemetry: "
          f"{srv.stats()['tables']['flights']['group_by']}")

    print("\n== streaming: submit returns futures, waves resolve them ==")
    futures = [srv.submit(sql) for sql in wave * 2]   # dupes dedupe in-flight
    srv.flush()
    results = [fut.result() for fut in futures]
    print(f"  {len(futures)} submitted, "
          f"{sum(r.estimate is not None for r in results)} resolved; "
          f"admission: "
          f"{json.dumps(srv.stats()['totals']['admission'], default=float)}")

    print("\n== repeated query: served from the result cache ==")
    srv.query(wave[0])
    print(json.dumps(srv.stats()["totals"], indent=2, default=float))

    print("\n== staleness: append_rows invalidates, rebuild restores ==")
    fw: AQPFramework = srv.catalog.resolve("power")
    base = load("power", n=50_000)
    extra = {k: np.asarray(v)[:5_000] for k, v in base.items()}
    fw.append_rows(extra)
    try:
        srv.query(wave[0])
    except RuntimeError as exc:
        print(f"  stale as expected: {exc}")
    fw.rebuild(base)
    print(f"  after rebuild: {srv.query(wave[0]).estimate:,.1f}")

    print("\n== backpressure: a bounded queue sheds typed, never grows ==")
    tiny = AQPServer(catalog=srv.catalog, max_wait_ms=10_000.0,
                     max_queue_depth=1, shed_policy="reject")
    queued = tiny.submit(wave[1])             # occupies the whole queue
    turned = tiny.submit(wave[2])             # full -> AdmissionRejected
    res = turned.result()
    print(f"  rejected: rejected={res.rejected} reason={res.reason!r} "
          f"queue_depth={res.queue_depth} estimate={res.estimate}")
    tiny.flush()
    print(f"  queued one answered: {queued.result().estimate:,.1f}")
    print(f"  sync query_batch drains-and-retries instead: "
          f"{len(tiny.query_batch([wave[1], wave[2], wave[3]]))} answered")
    adm = tiny.stats()["totals"]["admission"]
    print(f"  ledger: rejected={adm['rejected']} shed={adm['shed']} "
          f"high_water={adm['queue_high_water']}")
    tiny.close()

    print("\n== unknown table ==")
    try:
        srv.query("SELECT COUNT(*) FROM nope WHERE x > 1")
    except PlanError as exc:
        print(f"  PlanError: {exc}")

    print("\n== per-table telemetry ==")
    print(json.dumps(srv.stats()["tables"], indent=2, default=float))

    print("\n== trace export ==")
    path = srv.export_trace("trace.json")
    tr = srv.stats()["tracing"]
    print(f"  {tr['spans_recorded']} spans ({tr['spans_dropped']} dropped) "
          f"-> {path}")
    print("  open it at https://ui.perfetto.dev to see the admission/worker/"
          "per-query swimlanes")


if __name__ == "__main__":
    main()
