"""Quickstart: build a PairwiseHist synopsis and run approximate SQL.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.aqp import AQPFramework, ExactEngine
from repro.aqp.datasets import load
from repro.core.types import BuildParams


def main():
    # 1. A flights-like table (mixed numeric/categorical, missing values).
    table = load("flights", n=200_000)
    print(f"table: {len(table)} columns x {len(table['distance'])} rows")

    # 2. Ingest: GD pre-processing -> GreedyGD compression -> PairwiseHist.
    fw = AQPFramework(BuildParams(n_samples=100_000)).ingest(table)
    rep = fw.storage_report()
    print(f"synopsis: {rep['synopsis']['total']/1e3:.1f} kB | "
          f"compressed data: {rep['compressed_data_bytes']/1e6:.1f} MB "
          f"(raw {rep['raw_data_bytes']/1e6:.1f} MB, "
          f"{rep['compression_ratio']:.2f}x)")
    print(f"build: {fw.timings['build_synopsis_s']:.1f}s\n")

    # 3. Approximate SQL with bounds — vs exact ground truth.
    exact = ExactEngine(table)
    queries = [
        "SELECT COUNT(*) FROM flights WHERE dep_delay > 30",
        "SELECT AVG(arr_delay) FROM flights WHERE distance > 1000 "
        "AND airline = 'AA'",
        "SELECT SUM(air_time) FROM flights WHERE origin = 'A001' "
        "OR dest = 'A001'",
        "SELECT MEDIAN(distance) FROM flights WHERE air_time > 120",
        "SELECT MAX(dep_delay) FROM flights WHERE month = 7",
        "SELECT AVG(dep_delay) FROM flights WHERE cancelled = 0 "
        "GROUP BY airline",
    ]
    for sql in queries:
        res = fw.query(sql)
        if res.groups is not None:
            print(f"{sql}")
            truth = exact.query(sql)
            for key in list(res.groups)[:4]:
                est, lo, hi = res.groups[key]
                print(f"   {key:4s}: {est:10.2f}  in [{lo:.2f}, {hi:.2f}] "
                      f"(exact {truth.get(key, float('nan')):.2f})")
            continue
        truth = exact.query(sql)
        err = abs(res.estimate - truth) / max(abs(truth), 1e-9) * 100
        print(f"{sql}\n   ~ {res.estimate:12.2f} in [{res.lower:.2f}, "
              f"{res.upper:.2f}]  exact {truth:12.2f}  err {err:5.2f}%  "
              f"[{res.latency_s*1e3:.2f} ms]")


if __name__ == "__main__":
    main()
