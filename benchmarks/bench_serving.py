"""Serving-layer throughput: batched multi-table AQPServer vs one-at-a-time.

Workload model: serving traffic is a Zipf-weighted stream over a pool of
*templated* queries against two registered tables — a handful of query
templates (fixed aggregate + predicate column set), many literal variants,
with popular queries repeated. That is the shape of dashboard / public-
endpoint traffic, and exactly what the plan-shape batching exploits: every
variant of a template lands in the same fused launch group. We compare:

  * baseline  — the same stream issued one-at-a-time through
    ``AQPFramework.query`` (parse + plan + NumPy weightings per call, no
    caching: the pre-serving execution model);
  * server    — ``AQPServer.query_batch`` at batch sizes 1/8/64: normalized
    plan + result caches and one fused batched kernel launch per plan-shape
    group per wave.

Reported: queries/sec per batch size, speedup at batch 64 (acceptance:
>= 5x), plan/result cache hit rates, and a cold sweep (every query
distinct, caches can only help within the wave) isolating the pure
batching win from the caching win. The scheduler's auto mode picks the
fused Pallas launch on TPU and NumPy execution on CPU (where per-launch
JAX dispatch is the overhead, not the savings); the fused path's
engagement is additionally reported as explicit ``fused_ref`` rows so the
batched kernel is exercised on every backend.

Two further modes (PR 3):

  * streaming — the same Zipf stream submitted through ``AQPServer.submit``
    under **Poisson arrivals** at ~70% of the measured batch-64 capacity;
    reports client-observed p50/p99 latency (submit -> future resolution,
    admission wait included) and sustained qps, plus the admission drain
    telemetry. This is the traffic-shaped serving model the synchronous
    sweeps approximate from above.
  * groupby — a GROUP BY template pool over ``flights.airline`` (14
    categories / leaves per query), per-query ``AQPFramework.query`` vs
    ``query_batch`` at batch 16/64 (acceptance: > 2x qps at batch >= 16 —
    the planning-time leaf expansion + per-leaf result cache + fused leaf
    launches vs the sequential per-category loop).

Planning mode (PR 7): per-plan cold ``plan_sql`` latency vs the zero-parse
template-bind path (scalar and wave-vectorized ``bind_batch``), plus the
overload harness rerun with plan templating on vs off over a repeat-shape
all-distinct-literal workload — acceptance: templated ``submit_qps`` >=
1.5x the plain (PR 4 parity) run. The split / single_lock overload rows
keep templating OFF so they remain comparable with their pre-templating
history.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.core.sql import fingerprint_sql, parse_sql
from repro.core.types import BuildParams
from repro.obs.export import validate_trace_events, write_trace
from repro.obs.trace import Tracer
from repro.serve.aqp import AQPServer, faults


def _template_pool(table: dict, name: str, rng, n_templates: int,
                   variants: int) -> list[str]:
    """Templated queries: per template fix (agg func, agg col, predicate
    columns + ops); vary only the literals across ``variants`` instances."""
    numeric = [c for c in table
               if np.asarray(table[c]).dtype.kind not in ("U", "S", "O")]
    pool = []
    for _ in range(n_templates):
        func = rng.choice(("COUNT", "SUM", "AVG"))
        agg_col = rng.choice(numeric)
        others = [c for c in numeric if c != agg_col]
        k = int(rng.integers(1, min(3, len(others)) + 1))
        pred_cols = list(rng.choice(others, size=k, replace=False))
        ops = [rng.choice(("<", "<=", ">", ">=")) for _ in pred_cols]
        for _ in range(variants):
            conds = []
            for col, op in zip(pred_cols, ops):
                x = np.asarray(table[col], float)
                x = x[np.isfinite(x)]
                lit = float(np.quantile(x, rng.uniform(0.1, 0.9)))
                conds.append(f"{col} {op} {lit:.4f}")
            pool.append(f"SELECT {func}({agg_col}) FROM {name} "
                        f"WHERE {' AND '.join(conds)}")
    return pool


def _zipf_stream(rng, items, n, s: float = 1.5):
    p = 1.0 / np.arange(1, len(items) + 1) ** s
    p /= p.sum()
    idx = rng.choice(len(items), size=n, p=p)
    return [items[i] for i in idx]


def _serve_qps(frameworks, workload, batch_size, mode):
    """Steady-state serving throughput at one batch size.

    Runs the sweep twice on *fresh servers* and times the second: the first
    pass warms the process-wide XLA compile cache (a one-time deployment
    cost, not a per-query cost), while plan/result caches start cold in the
    timed pass because the server is new.
    """
    stats = None
    for attempt in range(2):
        srv = AQPServer(mode=mode)
        for name, fw in frameworks.items():
            srv.register(name, fw)
        t0 = time.perf_counter()
        for lo in range(0, len(workload), batch_size):
            srv.query_batch([sql for sql, _ in workload[lo:lo + batch_size]])
        wall = time.perf_counter() - t0
        stats = srv.stats()
        srv.close()   # detach framework callbacks: servers here are throwaway
    return len(workload) / wall, stats


def _groupby_pool(table: dict, name: str, group_col: str, rng,
                  n_templates: int, variants: int) -> list[str]:
    """GROUP BY templates: fixed (func, agg col, predicate col, group col);
    literals vary across ``variants`` instances."""
    numeric = [c for c in table
               if np.asarray(table[c]).dtype.kind not in ("U", "S", "O")]
    pool = []
    for _ in range(n_templates):
        func = rng.choice(("COUNT", "SUM", "AVG"))
        agg_col = rng.choice(numeric)
        pred_col = rng.choice([c for c in numeric if c != agg_col])
        op = rng.choice(("<", "<=", ">", ">="))
        for _ in range(variants):
            x = np.asarray(table[pred_col], float)
            x = x[np.isfinite(x)]
            lit = float(np.quantile(x, rng.uniform(0.1, 0.9)))
            pool.append(f"SELECT {func}({agg_col}) FROM {name} "
                        f"WHERE {pred_col} {op} {lit:.4f} "
                        f"GROUP BY {group_col}")
    return pool


def _noop_guard_cost_us(n: int = 200_000) -> float:
    """Measured cost of the disabled-tracing guard branches one submitted
    query pays. With tracing off, the serving path creates NO span or trace
    objects — it only reads ``tracer.enabled`` (or an equivalent
    ``trace is not None``) at roughly a dozen sites across submit, drain,
    scheduler and resolution. This times those dozen attribute-read
    branches per iteration, so the reported per-query cost is the honest
    ceiling of what the instrumentation costs when disabled."""
    tr = Tracer(enabled=False)
    t0 = time.perf_counter()
    for _ in range(n):
        for _site in range(12):
            if tr.enabled:
                pass
    return (time.perf_counter() - t0) / n * 1e6


def _fault_hook_cost_us(n: int = 200_000) -> float:
    """Measured cost of the disabled fault-injection hooks one query pays.

    With no FaultPlan installed, ``faults.hook(site)`` is one module-global
    read plus an ``is None`` branch. A query crosses at most 6 sites
    (planner, wave_execute, worker, kernel_launch, blob_read, cold_decode
    — the cold sites only on a cold table's first access), so timing 6
    real hook calls per iteration is the honest per-query ceiling of the
    harness when disabled."""
    assert faults.active() is None
    t0 = time.perf_counter()
    for _ in range(n):
        for site in ("planner", "wave_execute", "worker", "kernel_launch",
                     "blob_read", "cold_decode"):
            faults.hook(site)
    return (time.perf_counter() - t0) / n * 1e6


def _tracing_overhead(frameworks, workload, reps: int = 3,
                      trace_path: str | None = None) -> dict:
    """Traced vs untraced serving latency, paired-chunk interleaved A/B.

    Shared benchmark boxes drift by double-digit percentages at the
    100ms timescale, so pass-level medians cannot resolve a few-percent
    effect. Each ~10-query chunk of the workload is instead timed
    back-to-back on an untraced and a traced server (order alternating
    chunk to chunk) and the reported overhead is the median of the
    per-chunk traced/untraced ratios — drift cancels within a pair, a
    real regression shifts every pair. The final traced server's span
    ring is exported to ``trace_path`` (validated).
    """
    def mk(trace_enabled: bool):
        srv = AQPServer(mode=None, trace_enabled=trace_enabled)
        for name, fw in frameworks.items():
            srv.register(name, fw)
        return srv

    def chunk_ms(srv, sqls):
        t0 = time.perf_counter()
        srv.query_batch(sqls)
        return (time.perf_counter() - t0) / len(sqls) * 1e3

    chunks = [[sql for sql, _ in workload[lo:lo + 16]]
              for lo in range(0, len(workload), 16)]
    warm = mk(False)                             # compile/cache warm-up
    for chunk in chunks:
        chunk_ms(warm, chunk)
    warm.close()

    ratios, off_ms, on_ms = [], [], []
    events = None
    for _ in range(reps):
        off_srv, on_srv = mk(False), mk(True)
        for i, chunk in enumerate(chunks):
            if i % 2 == 0:
                off = chunk_ms(off_srv, chunk)
                on = chunk_ms(on_srv, chunk)
            else:
                on = chunk_ms(on_srv, chunk)
                off = chunk_ms(off_srv, chunk)
            ratios.append(on / off)
            off_ms.append(off)
            on_ms.append(on)
        events = on_srv.trace_events()
        off_srv.close()
        on_srv.close()
    p50_off = float(np.median(off_ms))
    guard_us = _noop_guard_cost_us()
    out = {
        "p50_ms_untraced": p50_off,
        "p50_ms_traced": float(np.median(on_ms)),
        "enabled_overhead_pct": (float(np.median(ratios)) - 1.0) * 100.0,
        # Disabled cost: the measured guard-branch cost per query as a
        # fraction of the untraced median latency (no spans/objects are
        # created when disabled, so the branches ARE the entire cost).
        "disabled_guard_us_per_query": guard_us,
        "disabled_overhead_pct": guard_us / (p50_off * 1e3) * 100.0,
        "spans_exported": len(events or []),
    }
    if trace_path is not None and events:
        problems = validate_trace_events(events)
        out["trace_valid"] = not problems
        out["trace_path"] = write_trace(trace_path, events)
    return out


def _streaming_run(frameworks, workload, rate_qps: float, rng):
    """Submit ``workload`` through the async path under Poisson arrivals.

    Client-observed latency = submit -> future resolution (admission wait +
    queueing + execution share). Returns qps/p50/p99 + admission telemetry.
    """
    srv = AQPServer()
    for name, fw in frameworks.items():
        srv.register(name, fw)
    done_at: dict[int, float] = {}
    submitted_at: list[float] = []
    futs = []
    t0 = time.perf_counter()
    t_next = t0
    for sql, _name in workload:
        now = time.perf_counter()
        if t_next > now:
            time.sleep(t_next - now)
        submitted_at.append(time.perf_counter())
        fut = srv.submit(sql)
        idx = len(futs)
        fut.add_done_callback(
            lambda f, i=idx: done_at.__setitem__(i, time.perf_counter()))
        futs.append(fut)
        t_next += rng.exponential(1.0 / rate_qps)
    srv.flush()
    for fut in futs:
        fut.result()
    wall = time.perf_counter() - t0
    lat_ms = 1e3 * (np.array([done_at[i] for i in range(len(futs))])
                    - np.array(submitted_at))
    stats = srv.stats()
    srv.close()
    return {
        "offered_qps": rate_qps,
        "qps": len(futs) / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "admission": stats["totals"]["admission"],
    }


def _overload_run(frameworks, workloads, single_lock: bool,
                  max_queue_depth: int = 128,
                  plan_templates: bool = False):
    """Fixed-work overload: N submitter threads blast the bounded queue as
    fast as they can (no pacing). ``shed_policy="block"`` paces producers
    to the consumer, so every query is answered and no work is shed — the
    measured wall time is therefore the end-to-end submit-path + drain
    throughput under contention, comparable across modes (a metric that
    counted raw submissions/sec would *reward* starving the worker, which
    is exactly the single-lock failure mode).

    ``single_lock=True`` runs the pre-split critical section (parse + plan
    + leaf expansion under the one server lock) as the contention baseline
    for the lock-split submit path. NOTE the honest caveat recorded in
    docs/benchmarks.md: on a GIL-bound CPython host the split's gain is
    bounded (planning is Python, so submitters serialize on the GIL
    whether or not they serialize on a lock); the structural win shows up
    where execution is device-side (TPU) or planning runs without the GIL.

    Plan templating defaults OFF here so the split / single_lock rows stay
    directly comparable with their pre-templating baselines; the planning
    mode flips it on explicitly for the templated-vs-plain comparison.
    """
    n_threads = len(workloads)
    srv = AQPServer(max_wait_ms=1.0, max_batch=64,
                    max_queue_depth=max_queue_depth,
                    shed_policy="block", single_lock=single_lock,
                    plan_templates=plan_templates)
    for name, fw in frameworks.items():
        srv.register(name, fw)
    futs = [[] for _ in range(n_threads)]
    lat: dict[int, float] = {}
    barrier = threading.Barrier(n_threads + 1)

    def submitter(ti):
        barrier.wait()
        for sql, _name in workloads[ti]:
            t_sub = time.perf_counter()
            fut = srv.submit(sql)
            key = id(fut)
            fut.add_done_callback(
                lambda f, k=key, t=t_sub: lat.__setitem__(
                    k, time.perf_counter() - t))
            futs[ti].append(fut)

    threads = [threading.Thread(target=submitter, args=(ti,))
               for ti in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    submit_wall = time.perf_counter() - t0
    srv.flush()
    flat = [f for per in futs for f in per]
    for fut in flat:
        fut.result()
    wall = time.perf_counter() - t0
    adm = srv.stats()["totals"]["admission"]
    srv.close()
    lat_ms = 1e3 * np.array([lat[id(f)] for f in flat])
    return {
        "qps": len(flat) / wall,
        "submit_qps": len(flat) / submit_wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "queue_high_water": adm["queue_high_water"],
        "rejected": adm["rejected"],
        "shed": adm["shed"],
    }


def _planning_micro(framework, sqls: list[str], reps: int = 3) -> dict:
    """Per-plan planning latency: cold ``plan_sql`` (parse + plan) vs the
    zero-parse template path (fingerprint + ``bind``) vs the wave-vectorized
    ``bind_batch`` over the whole set, all producing bit-for-bit equal
    plans. Median of ``reps`` sweeps over ``sqls`` (distinct literals, one
    shape)."""
    engine = framework.engine
    template = engine.plan_template(parse_sql(sqls[0]))
    cold_us, bind_us, batch_us = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        for sql in sqls:
            engine.plan_sql(sql)
        cold_us.append((time.perf_counter() - t0) / len(sqls) * 1e6)
        t0 = time.perf_counter()
        for sql in sqls:
            template.bind(fingerprint_sql(sql).literals)
        bind_us.append((time.perf_counter() - t0) / len(sqls) * 1e6)
        t0 = time.perf_counter()
        template.bind_batch([fingerprint_sql(s).literals for s in sqls])
        batch_us.append((time.perf_counter() - t0) / len(sqls) * 1e6)
    out = {
        "plans": len(sqls),
        "cold_plan_us": float(np.median(cold_us)),
        "template_bind_us": float(np.median(bind_us)),
        "template_bind_batch_us": float(np.median(batch_us)),
    }
    out["bind_speedup"] = out["cold_plan_us"] / out["template_bind_us"]
    out["bind_batch_speedup"] = (out["cold_plan_us"]
                                 / out["template_bind_batch_us"])
    return out


def run(rows: list, quick: bool = False, trace: bool = False):
    rng = np.random.default_rng(0)
    n = 60_000 if quick else 120_000
    n_templates = 4 if quick else 6
    variants = 12 if quick else 16
    n_requests = 512 if quick else 1024
    params = BuildParams(n_samples=min(n, 30_000), seed=0)

    frameworks, pool = {}, []
    for name, ds in (("power", "power"), ("flights", "flights")):
        table = load(ds, n=n)
        frameworks[name] = AQPFramework(
            params=params, use_compression=False).ingest(table)
        for sql in _template_pool(table, name, rng, n_templates, variants):
            pool.append((sql, name))
    workload = _zipf_stream(rng, pool, n_requests)

    # Baseline: one-at-a-time through the single-table framework.
    t0 = time.perf_counter()
    for sql, name in workload:
        frameworks[name].query(sql)
    qps_base = len(workload) / (time.perf_counter() - t0)

    out = {"n_rows": n, "pool": len(pool), "requests": n_requests,
           "qps_baseline": qps_base}
    emit(rows, "serving/qps_baseline", 1e6 / qps_base, f"{qps_base:.0f} qps")

    stats = None
    for bs in (1, 8, 64):
        qps, stats = _serve_qps(frameworks, workload, bs, mode=None)
        out[f"qps_b{bs}"] = qps
        emit(rows, f"serving/qps_b{bs}", 1e6 / qps,
             f"{qps:.0f} qps ({qps / qps_base:.1f}x)")
    speedup = out["qps_b64"] / qps_base
    out["speedup_b64"] = speedup
    out["plan_cache_hit_rate"] = stats["totals"]["plan_cache"]["hit_rate"]
    out["result_cache_hit_rate"] = stats["totals"]["result_cache"]["hit_rate"]
    out["batched_fraction"] = stats["totals"]["batched_fraction"]
    emit(rows, "serving/speedup_b64", None, f"{speedup:.1f}x")
    emit(rows, "serving/plan_cache_hit_rate", None,
         f"{out['plan_cache_hit_rate']:.2f}")
    emit(rows, "serving/result_cache_hit_rate", None,
         f"{out['result_cache_hit_rate']:.2f}")

    # Cold sweep: all-distinct workload (each pool query once) at batch 64 —
    # isolates grouping gains from repeat-traffic cache gains.
    t0 = time.perf_counter()
    for sql, name in pool:
        frameworks[name].query(sql)
    qps_base_cold = len(pool) / (time.perf_counter() - t0)
    qps_cold, _ = _serve_qps(frameworks, pool, 64, mode=None)
    out["qps_baseline_cold"] = qps_base_cold
    out["qps_b64_cold"] = qps_cold
    out["speedup_b64_cold"] = qps_cold / qps_base_cold
    emit(rows, "serving/speedup_b64_cold", None,
         f"{qps_cold / qps_base_cold:.1f}x")

    # Fused-kernel path (jnp oracle of the Pallas kernel) at batch 64: on
    # TPU this IS the auto mode; on CPU it is exercised for the record.
    qps_fused, fstats = _serve_qps(frameworks, workload, 64, mode="ref")
    out["qps_b64_fused_ref"] = qps_fused
    out["fused_batched_fraction"] = fstats["totals"]["batched_fraction"]
    emit(rows, "serving/qps_b64_fused_ref", 1e6 / qps_fused,
         f"{qps_fused:.0f} qps ({qps_fused / qps_base:.1f}x, "
         f"batched={out['fused_batched_fraction']:.2f})")

    # Streaming admission under Poisson arrivals at ~70% of batch capacity:
    # client-observed latency percentiles + sustained throughput.
    n_stream = 256 if quick else 512
    rate = max(min(0.7 * out["qps_b64"], 5_000.0), 50.0)
    stream_wl = _zipf_stream(rng, pool, n_stream)
    out["streaming"] = _streaming_run(frameworks, stream_wl, rate, rng)
    emit(rows, "serving/streaming_qps", 1e6 / out["streaming"]["qps"],
         f"{out['streaming']['qps']:.0f} qps "
         f"(offered {out['streaming']['offered_qps']:.0f})")
    emit(rows, "serving/streaming_p50_ms", None,
         f"{out['streaming']['p50_ms']:.2f} ms")
    emit(rows, "serving/streaming_p99_ms", None,
         f"{out['streaming']['p99_ms']:.2f} ms")

    # GROUP BY batching: per-category leaf expansion through the batched
    # path + per-leaf result cache, vs the sequential per-category loop.
    gb_templates = 3 if quick else 5
    gb_variants = 8 if quick else 12
    gb_requests = 192 if quick else 384
    fl_table = load("flights", n=n)
    gb_pool = [(sql, "flights") for sql in _groupby_pool(
        fl_table, "flights", "airline", rng, gb_templates, gb_variants)]
    gb_wl = _zipf_stream(rng, gb_pool, gb_requests)

    t0 = time.perf_counter()
    for sql, name in gb_wl:
        frameworks[name].query(sql)
    qps_gb_base = len(gb_wl) / (time.perf_counter() - t0)
    out["groupby"] = {"pool": len(gb_pool), "requests": gb_requests,
                      "qps_baseline": qps_gb_base}
    emit(rows, "serving/groupby_qps_baseline", 1e6 / qps_gb_base,
         f"{qps_gb_base:.0f} qps")
    gstats = None
    for bs in (16, 64):
        qps_gb, gstats = _serve_qps(frameworks, gb_wl, bs, mode=None)
        out["groupby"][f"qps_b{bs}"] = qps_gb
        out["groupby"][f"speedup_b{bs}"] = qps_gb / qps_gb_base
        emit(rows, f"serving/groupby_qps_b{bs}", 1e6 / qps_gb,
             f"{qps_gb:.0f} qps ({qps_gb / qps_gb_base:.1f}x)")
    gb_tm = gstats["tables"]["flights"]["group_by"]
    out["groupby"]["leaves_executed"] = gb_tm["leaves_executed"]
    out["groupby"]["leaf_cache_hits"] = gb_tm["leaf_cache_hits"]
    # Fused leaf launches (jnp oracle of the batched kernel) for the record.
    qps_gb_fused, _ = _serve_qps(frameworks, gb_wl, 64, mode="ref")
    out["groupby"]["qps_b64_fused_ref"] = qps_gb_fused
    emit(rows, "serving/groupby_speedup_b16", None,
         f"{out['groupby']['speedup_b16']:.1f}x")

    # Overload: 8 concurrent submitters blasting a bounded (block-policy)
    # queue with a plan-heavy mixed pool — the lock-split submit path vs
    # the pre-split single-lock baseline (acceptance: >= 2x; p99 bounded by
    # the queue bound, not by queue growth). Split runs FIRST so any
    # process-warmth advantage accrues to the baseline.
    ov_threads = 8
    ov_per_thread = 24 if quick else 48
    ov_pool = pool + gb_pool
    workloads = [_zipf_stream(rng, ov_pool, ov_per_thread)
                 for _ in range(ov_threads)]
    out["overload"] = {"threads": ov_threads,
                       "queries": ov_threads * ov_per_thread,
                       "max_queue_depth": 128}
    _overload_run(frameworks, workloads, single_lock=False)      # warm-up
    reps = 3                                # cheap enough even in --quick
    runs = {"split": [], "single_lock": []}
    for _ in range(reps):                   # interleave: box drift is real
        for label, single in (("split", False), ("single_lock", True)):
            runs[label].append(
                _overload_run(frameworks, workloads, single_lock=single))
    for label in ("split", "single_lock"):
        med = sorted(runs[label],
                     key=lambda r: r["qps"])[(len(runs[label]) - 1) // 2]
        out["overload"][label] = med
        emit(rows, f"serving/overload_qps_{label}", 1e6 / med["qps"],
             f"{med['qps']:.0f} qps (p99 {med['p99_ms']:.1f} ms, "
             f"high water {med['queue_high_water']})")
    speedup = (out["overload"]["split"]["qps"]
               / out["overload"]["single_lock"]["qps"])
    out["overload"]["speedup"] = speedup
    emit(rows, "serving/overload_speedup", None, f"{speedup:.1f}x")

    # Planning fast path (PR 7). Two measurements:
    #   micro — cold plan_sql (parse + plan) vs zero-parse template bind vs
    #   wave-vectorized bind_batch, per plan, same shape / distinct literals;
    #   overload — the submit-path throughput with templating on vs off
    #   (off = the PR 4 parity baseline above) on a repeat-shape,
    #   all-distinct-literal workload: every query misses the text-keyed
    #   plan cache, so only the template path can skip the parse. The queue
    #   bound is raised so producers never block on the drain — submit_qps
    #   isolates the submit path, which is what templating changes.
    pl_var = 128 if quick else 256
    pl_sqls = _template_pool(fl_table, "flights", rng, 1, pl_var)
    out["planning"] = {"micro": _planning_micro(frameworks["flights"],
                                                pl_sqls)}
    mic = out["planning"]["micro"]
    emit(rows, "serving/planning_cold_plan", mic["cold_plan_us"],
         f"{mic['cold_plan_us']:.0f} us/plan")
    emit(rows, "serving/planning_template_bind", mic["template_bind_us"],
         f"{mic['template_bind_us']:.0f} us/plan "
         f"({mic['bind_speedup']:.1f}x vs cold)")
    emit(rows, "serving/planning_bind_batch", mic["template_bind_batch_us"],
         f"{mic['template_bind_batch_us']:.0f} us/plan "
         f"({mic['bind_batch_speedup']:.1f}x vs cold)")

    tp_pool = [(sql, "flights") for sql in _template_pool(
        fl_table, "flights", rng, 6, ov_threads * ov_per_thread // 6 + 1)]
    tp_wls = [[tp_pool[i] for i in range(ti, len(tp_pool), ov_threads)]
              for ti in range(ov_threads)]
    _overload_run(frameworks, tp_wls, single_lock=False,
                  max_queue_depth=4096, plan_templates=True)     # warm-up
    tp_runs = {"plain": [], "templated": []}
    for _ in range(reps):                   # interleave: box drift is real
        for label, templ in (("plain", False), ("templated", True)):
            tp_runs[label].append(_overload_run(
                frameworks, tp_wls, single_lock=False,
                max_queue_depth=4096, plan_templates=templ))
    for label in ("plain", "templated"):
        med = sorted(tp_runs[label], key=lambda r: r["submit_qps"])[
            (len(tp_runs[label]) - 1) // 2]
        out["planning"][label] = med
        emit(rows, f"serving/planning_submit_qps_{label}",
             1e6 / med["submit_qps"], f"{med['submit_qps']:.0f} submit qps")
    t_speedup = (out["planning"]["templated"]["submit_qps"]
                 / out["planning"]["plain"]["submit_qps"])
    out["planning"]["templating_speedup"] = t_speedup
    out["planning"]["queries"] = len(tp_pool)
    emit(rows, "serving/planning_templating_speedup", None,
         f"{t_speedup:.1f}x")

    # Tracing overhead (PR 6 acceptance): enabled-vs-disabled median latency
    # on the repeat-traffic workload, plus the measured disabled-guard cost
    # (< 2% of median latency). With --trace the last traced pass's span
    # ring lands in results/serving_trace.json (trace_event schema valid).
    trace_path = (os.path.join(RESULTS_DIR, "serving_trace.json")
                  if trace else None)
    out["tracing"] = _tracing_overhead(frameworks, workload,
                                       trace_path=trace_path)
    tr = out["tracing"]
    emit(rows, "serving/tracing_enabled_overhead", None,
         f"{tr['enabled_overhead_pct']:+.1f}% "
         f"({tr['p50_ms_untraced']:.3f} -> {tr['p50_ms_traced']:.3f} ms p50)")
    emit(rows, "serving/tracing_disabled_overhead", tr["disabled_guard_us_per_query"],
         f"{tr['disabled_overhead_pct']:.3f}% of p50 "
         f"({tr['disabled_guard_us_per_query']:.2f} us/query)")
    if trace:
        emit(rows, "serving/trace_artifact", None,
             f"{tr['spans_exported']} events, "
             f"valid={tr.get('trace_valid')} -> {tr.get('trace_path')}")

    # Fault-injection harness (robustness PR acceptance): the permanently
    # compiled-in hooks, measured with NO plan installed, must cost < 2%
    # of serving p50 — same gate method as the disabled-tracing guard.
    hook_us = _fault_hook_cost_us()
    out["faults"] = {
        "disabled_hook_us_per_query": hook_us,
        "disabled_overhead_pct":
            hook_us / (tr["p50_ms_untraced"] * 1e3) * 100.0,
        "sites_per_query": 6,
    }
    emit(rows, "serving/fault_hooks_disabled_overhead", hook_us,
         f"{out['faults']['disabled_overhead_pct']:.3f}% of p50 "
         f"({hook_us:.2f} us/query)")

    save_json("serving", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--trace", action="store_true",
                    help="export a validated Perfetto trace artifact to "
                         "benchmarks/results/serving_trace.json")
    ap.add_argument("--full", action="store_true",
                    help="full-size run (default is the quick sweep)")
    args = ap.parse_args()
    rows: list = []
    res = run(rows, quick=not args.full, trace=args.trace)
    print("\n".join(rows))
    print(res)
