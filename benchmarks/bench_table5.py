"""Table 5: median relative error by aggregation function on the scaled-up
power & flights datasets (IDEBench-style scale-up; all seven aggregations).

Paper claims to validate: per-function sub-2% medians for COUNT/SUM/AVG/VAR,
0–5%-ish for MIN/MAX/MEDIAN; overall medians ~0.2–0.5%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.aqp.datasets import load, scale_up
from repro.aqp.engine import AQPFramework
from repro.aqp.exact import ExactEngine
from repro.aqp.queries import AGGS_FULL, generate_queries, relative_error
from repro.core.sql import parse_sql
from repro.core.types import BuildParams

SCALE_FACTOR = 8  # 150k -> 1.2M rows (container-scale stand-in for 1e9)


def run(rows: list, quick: bool = False):
    out = {}
    for name in ("power", "flights"):
        base = load(name, n=75_000 if quick else 150_000)
        table = scale_up(base, 2 if quick else SCALE_FACTOR, seed=5)
        exact = ExactEngine(table)
        queries = generate_queries(table, 60 if quick else 140, seed=23,
                                   aggs=AGGS_FULL, max_preds=5,
                                   min_selectivity=1e-5)
        fw = AQPFramework(BuildParams(n_samples=100_000)).ingest(table)
        by_func: dict[str, list] = {}
        for sql in queries:
            func = parse_sql(sql).func
            res = fw.query(sql)
            ex = exact.query(sql)
            by_func.setdefault(func, []).append(
                relative_error(res.estimate, ex))
        table_out = {}
        all_errs = []
        for func, errs in sorted(by_func.items()):
            med = float(np.median(errs))
            table_out[func] = {"median_err": med, "n": len(errs)}
            all_errs.extend(errs)
            emit(rows, f"table5/{name}/{func}", None, f"{med:.3f}%")
        table_out["overall"] = {"median_err": float(np.median(all_errs)),
                                "n": len(all_errs)}
        emit(rows, f"table5/{name}/overall", None,
             f"{table_out['overall']['median_err']:.3f}%")
        out[name] = table_out
    save_json("table5", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
