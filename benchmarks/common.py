"""Shared benchmark utilities: engine sweeps, metric collection, reporting."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_json(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as fh:
        json.dump(payload, fh, indent=1, default=float)


def emit(rows: list, name: str, us_per_call, derived):
    """Append one CSV row in the harness's required format."""
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    rows.append(f"{name},{us},{derived}")


def eval_engine(query_fn, queries, exact_engine):
    """Run queries through an engine; returns error list, latency list,
    bounds-correctness list, bound widths."""
    from repro.aqp.queries import relative_error
    errs, lats, bok, widths = [], [], [], []
    for sql in queries:
        exact = exact_engine.query(sql)
        t0 = time.perf_counter()
        out = query_fn(sql)
        lats.append(time.perf_counter() - t0)
        if isinstance(out, tuple):
            est, lo, hi = out
        else:
            est, lo, hi = out.estimate, out.lower, out.upper
        errs.append(relative_error(est, exact))
        if lo is not None and hi is not None and exact is not None:
            bok.append(lo - 1e-9 <= exact <= hi + 1e-9)
            if exact != 0:
                widths.append(abs(hi - lo) / abs(exact) * 100.0)
    return {
        "median_err": float(np.median(errs)) if errs else None,
        "mean_err": float(np.mean(errs)) if errs else None,
        "p90_err": float(np.percentile(errs, 90)) if errs else None,
        "errs": errs,
        "median_latency_ms": float(np.median(lats) * 1e3),
        "bounds_correct_pct": (float(np.mean(bok) * 100.0) if bok else None),
        "median_bound_width_pct": (float(np.median(widths)) if widths else None),
        "n_queries": len(queries),
    }
