"""§4.3 storage encoding: encoded size vs the Eq. 12 bound + codec
round-trip integrity + Golomb sparse-vs-dense selection stats."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.core import storage
from repro.core.types import BuildParams


def run(rows: list, quick: bool = False):
    out = {}
    for name in ("power", "taxi") if not quick else ("power",):
        table = load(name, n=100_000)
        fw = AQPFramework(BuildParams(n_samples=50_000)).ingest(table)
        rep = storage.synopsis_size_report(fw.synopsis)
        t0 = time.perf_counter()
        blob = storage.encode(fw.synopsis)
        encode_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        ph2 = storage.decode(blob)
        decode_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        ph_oracle = storage.decode(blob, vectorized=False)
        decode_oracle_ms = (time.perf_counter() - t0) * 1e3
        roundtrip = all(
            np.allclose(h1.h, h2.h) and np.allclose(h1.edges, h2.edges)
            for h1, h2 in zip(fw.synopsis.hists, ph2.hists))
        vectorized_ok = all(
            np.array_equal(h1.h, h2.h) and np.array_equal(h1.edges, h2.edges)
            for h1, h2 in zip(ph_oracle.hists, ph2.hists))
        rep["roundtrip_ok"] = roundtrip
        rep["vectorized_matches_oracle"] = vectorized_ok
        rep["ratio_vs_eq12"] = rep["total"] / max(rep["eq12_bound"], 1)
        rep["encode_ms"] = encode_ms
        rep["decode_ms"] = decode_ms
        rep["decode_oracle_ms"] = decode_oracle_ms
        rep["decode_speedup"] = decode_oracle_ms / max(decode_ms, 1e-9)
        out[name] = rep
        emit(rows, f"storage/{name}/encoded", None, f"{rep['total']}B")
        emit(rows, f"storage/{name}/vs_eq12_bound", None,
             f"{rep['ratio_vs_eq12']:.2f}x")
        emit(rows, f"storage/{name}/roundtrip", None, str(roundtrip))
        emit(rows, f"storage/{name}/codec", None,
             f"encode {encode_ms:.1f} ms / decode {decode_ms:.1f} ms")
        emit(rows, f"storage/{name}/decode_vectorized", None,
             f"{decode_ms:.1f} ms vs oracle {decode_oracle_ms:.1f} ms "
             f"({rep['decode_speedup']:.1f}x, match={vectorized_ok})")
    save_json("storage", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
