"""Benchmark driver: one module per paper table/figure + the roofline reader.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and writes
JSON artifacts to benchmarks/results/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("construction", "kernels", "storage", "serving", "fig8", "fig9",
          "table5", "table6", "fig11", "roofline")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-fast)")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {SUITES}")
    args = ap.parse_args(argv)
    suites = args.only.split(",") if args.only else list(SUITES)

    rows: list[str] = []
    failures = []
    print("name,us_per_call,derived")
    for name in suites:
        mod_name = f"benchmarks.roofline" if name == "roofline" \
            else f"benchmarks.bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            before = len(rows)
            mod.run(rows, quick=args.quick)
            for row in rows[before:]:
                print(row)
            print(f"# {name}: {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
