"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:

    compute term    = HLO_FLOPs / (chips * 197e12)      bf16 peak, v5e
    memory term     = HLO_bytes / (chips * 819e9)       HBM BW
    collective term = wire_bytes / (chips * 50e9)       ICI per-link

``cost_analysis``/HLO text report *per-partition* numbers, so per-device
values divide by the per-chip rates directly (equivalent to the global
formula). Costs come from the *unrolled* pass (XLA counts while bodies once
— measured; see dryrun.py); memory comes from the scan pass (the deployable
program). MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference) with N_active
for MoE.
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "dryrun_results")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def _params_of(arch: str):
    """(N_total, N_active) parameter counts from the config, analytically."""
    from repro.configs import get_config
    cfg = get_config(arch)
    d = cfg.d_model
    emb = cfg.vocab * d
    total = emb + d  # embed + final norm
    active = total
    groups = cfg.layer_groups()
    for pat, n_rep in groups:
        for kind in pat:
            if kind.startswith("attn") or kind.startswith("moe"):
                attn = d * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
                    + cfg.n_heads * cfg.head_dim * d
                total += n_rep * (attn + 2 * d)
                active += n_rep * (attn + 2 * d)
                if kind.startswith("moe"):
                    router = d * cfg.n_experts
                    expert = 3 * d * cfg.d_ff_expert
                    shared = 3 * d * cfg.d_ff_expert * cfg.n_shared
                    total += n_rep * (router + cfg.n_experts * expert + shared)
                    active += n_rep * (router + cfg.top_k * expert + shared)
                else:
                    total += n_rep * 3 * d * cfg.d_ff
                    active += n_rep * 3 * d * cfg.d_ff
            elif kind == "ssm":
                din = cfg.ssm_expand * d
                nh = din // cfg.ssm_head_dim
                n_p = d * (2 * din + 2 * cfg.ssm_state + nh) + din * d + d
                total += n_rep * n_p
                active += n_rep * n_p
            elif kind == "rec":
                w = cfg.rnn_width
                n_p = 2 * d * w + 2 * w * w + w * d + 3 * d * cfg.d_ff + 2 * d
                total += n_rep * n_p
                active += n_rep * n_p
    return total, active


def load_cell(arch: str, shape: str, mesh: str) -> dict | None:
    names = (arch,
             arch.replace("-", "_").replace("0.6", "0_6").replace("1.3", "1_3"),
             arch.replace("_", "-"))
    for name in names:
        path = os.path.join(RESULTS_DIR, f"{name}__{shape}__{mesh}.json")
        if os.path.exists(path):
            with open(path) as fh:
                return json.load(fh)
    return None


def analyze(arch: str, shape: str) -> dict | None:
    scan = load_cell(arch, shape, "single_pod")
    cost_rec = load_cell(arch, shape, "single_pod_cost")
    if scan is None or scan.get("skipped"):
        return {"arch": arch, "shape": shape,
                "skipped": scan.get("reason") if scan else "missing"}
    cost_src = cost_rec if cost_rec and cost_rec.get("ok") else scan
    cost = cost_src.get("cost_analysis", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll = cost_src.get("collectives", {})
    wire_dev = sum(v.get("wire_bytes_per_device", 0.0) for v in coll.values())

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t_compute / bound if bound > 0 else 0.0

    n_total, n_active = _params_of(arch)
    toks = SHAPE_TOKENS[shape]
    mult = 6 if shape == "train_4k" else 2
    model_flops = mult * n_active * toks
    n_dev = scan.get("n_devices", 256)
    hlo_total = flops_dev * n_dev
    useful = model_flops / hlo_total if hlo_total else 0.0

    mem = scan.get("memory_analysis", {})
    return {
        "arch": arch, "shape": shape, "n_devices": n_dev,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire_dev,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,     # compute / dominant (1.0 = compute-bound)
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "n_params_total": n_total, "n_params_active": n_active,
        "temp_bytes_per_device": mem.get("temp_size_in_bytes"),
        "arg_bytes_per_device": mem.get("argument_size_in_bytes"),
        "collectives": coll,
        "cost_source": ("u1u2-extrapolated" if cost_src is cost_rec
                        else "scan(body-once)"),
    }


_SUGGEST = {
    "compute": "compute-bound: raise MXU utilization (fuse elementwise into "
               "matmuls, bf16 everywhere, drop redundant remat recompute)",
    "memory": "HBM-bound: cut activation traffic (wider fusion, smaller "
              "remat residuals, bf16 logits / chunked cross-entropy)",
    "collective": "ICI-bound: reshard to remove all-gathers (bf16-cast "
                  "before FSDP gather, sequence-shard boundary, larger "
                  "per-device batch)",
}


def markdown_table(shapes=None, archs=None) -> str:
    from repro.configs import ARCHS
    from repro.launch import specs as S
    shapes = shapes or list(S.SHAPES)
    archs = archs or list(ARCHS)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | useful-FLOP ratio | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in shapes:
            r = analyze(arch.replace("_", "-").replace("-0-6b", "-0.6b")
                        .replace("-1-3b", "-1.3b"), shape)
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {r['arch']} | {shape} | — | — | — | skipped |"
                             f" — | — | {r['skipped'][:48]} |")
                continue
            lines.append(
                f"| {r['arch']} | {shape} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{_SUGGEST[r['dominant']][:64]} |")
    return "\n".join(lines)


def run(rows: list, quick: bool = False):
    from benchmarks.common import emit, save_json
    from repro.configs import ARCHS
    from repro.launch import specs as S
    out = {}
    for arch_us in ARCHS:
        arch = arch_us.replace("_", "-").replace("-0-6b", "-0.6b") \
            .replace("-1-3b", "-1.3b")
        for shape in S.SHAPES:
            r = analyze(arch, shape)
            if r is None:
                continue
            out[f"{arch}/{shape}"] = r
            if "skipped" in r:
                emit(rows, f"roofline/{arch}/{shape}", None, "skipped")
            else:
                emit(rows, f"roofline/{arch}/{shape}", None,
                     f"dom={r['dominant']}/frac={r['roofline_fraction']:.2f}"
                     f"/useful={r['useful_flops_ratio']:.2f}")
    save_json("roofline", out)
    return out


if __name__ == "__main__":
    print(markdown_table())
