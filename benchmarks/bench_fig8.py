"""Fig. 8: median query error + synopsis size across datasets.

PairwiseHist (10k / 50k samples) vs the sampling baseline and the
histogram-product (attribute-independence) baseline, over the synthetic
dataset suite. Paper claims to validate: PairwiseHist sub-1% median error on
most datasets with sub-MB synopses, 1–2 orders of magnitude smaller than
competitors at comparable accuracy.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, eval_engine, save_json
from repro.aqp.baselines import HistProductAQP, SamplingAQP
from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.aqp.exact import ExactEngine
from repro.aqp.queries import AGGS_INITIAL, generate_queries
from repro.core.types import BuildParams

DATASETS = ("power", "flights", "iot_temp", "aqua", "taxi", "gas")
N_ROWS = 150_000
N_QUERIES = 50


def run(rows: list, quick: bool = False):
    datasets = DATASETS[:3] if quick else DATASETS
    out = {}
    for name in datasets:
        table = load(name, n=N_ROWS)
        exact = ExactEngine(table)
        queries = generate_queries(table, N_QUERIES, seed=17,
                                   aggs=AGGS_INITIAL, max_preds=3,
                                   min_selectivity=1e-4)
        per = {}
        for n_s in (10_000, 50_000):
            fw = AQPFramework(BuildParams(n_samples=n_s)).ingest(table)
            res = eval_engine(fw.query, queries, exact)
            res["size_bytes"] = fw.size_bytes()
            res.pop("errs")
            per[f"pairwisehist_{n_s//1000}k"] = res
            emit(rows, f"fig8/{name}/pairwisehist_{n_s//1000}k_err",
                 res["median_latency_ms"] * 1e3, f"{res['median_err']:.3f}%")
            emit(rows, f"fig8/{name}/pairwisehist_{n_s//1000}k_size",
                 None, f"{res['size_bytes']}B")
        samp = SamplingAQP(table, n_sample=50_000)
        res = eval_engine(samp.query, queries, exact)
        res["size_bytes"] = samp.size_bytes()
        res.pop("errs")
        per["sampling_50k"] = res
        emit(rows, f"fig8/{name}/sampling_50k_err",
             res["median_latency_ms"] * 1e3,
             f"{res['median_err']:.3f}%/{res['size_bytes']}B")
        hp = HistProductAQP(table, n_sample=50_000)
        res = eval_engine(hp.query, queries, exact)
        res["size_bytes"] = hp.size_bytes()
        res.pop("errs")
        per["histproduct_50k"] = res
        emit(rows, f"fig8/{name}/histproduct_50k_err",
             res["median_latency_ms"] * 1e3,
             f"{res['median_err']:.3f}%/{res['size_bytes']}B")
        out[name] = per
    save_json("fig8", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
