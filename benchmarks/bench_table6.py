"""Table 6: bounds correct-rate (%) and median bound width (% of exact).

Paper reference points: PairwiseHist 70–80% correct with ~3–9% widths
(DeepDB narrower but less correct). Faithful Eq. 29 widening is used, plus
the corrected variant for comparison (DESIGN.md §7.3).
"""
from __future__ import annotations

from benchmarks.common import emit, eval_engine, save_json
from repro.aqp.datasets import load, scale_up
from repro.aqp.engine import AQPFramework
from repro.aqp.exact import ExactEngine
from repro.aqp.queries import AGGS_FULL, generate_queries
from repro.core.query import QueryEngine
from repro.core.types import BuildParams


def run(rows: list, quick: bool = False):
    out = {}
    for name in ("power", "flights"):
        base = load(name, n=75_000 if quick else 150_000)
        table = scale_up(base, 2 if quick else 8, seed=7)
        exact = ExactEngine(table)
        queries = generate_queries(table, 40 if quick else 100, seed=29,
                                   aggs=AGGS_FULL, max_preds=4,
                                   min_selectivity=1e-5)
        fw = AQPFramework(BuildParams(n_samples=100_000)).ingest(table)
        res_faithful = eval_engine(fw.query, queries, exact)
        res_faithful.pop("errs")
        eng_corr = QueryEngine(fw.synopsis, corrected_sampling_bounds=True)
        res_corr = eval_engine(eng_corr.query, queries, exact)
        res_corr.pop("errs")
        out[name] = {"faithful_eq29": res_faithful,
                     "corrected": res_corr}
        emit(rows, f"table6/{name}/correct_rate", None,
             f"{res_faithful['bounds_correct_pct']:.1f}%")
        emit(rows, f"table6/{name}/width", None,
             f"{res_faithful['median_bound_width_pct']:.2f}%")
        emit(rows, f"table6/{name}/correct_rate_corrected", None,
             f"{res_corr['bounds_correct_pct']:.1f}%")
    save_json("table6", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
