"""Fig. 11: synopsis storage, total storage with compression, query latency,
and construction time on the scaled-up datasets.

Paper claims to validate: sub-MB synopses; total storage reduction 3.2–4.3x
with GD; sub-ms median query latency; construction in seconds–minutes and
1.2–4x faster when seeded with GD bases.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.aqp.datasets import load, scale_up
from repro.aqp.engine import AQPFramework
from repro.aqp.queries import AGGS_FULL, generate_queries
from repro.core.types import BuildParams


def run(rows: list, quick: bool = False):
    out = {}
    for name in ("power", "flights"):
        base = load(name, n=75_000 if quick else 150_000)
        table = scale_up(base, 2 if quick else 8, seed=9)
        queries = generate_queries(table, 30 if quick else 80, seed=31,
                                   aggs=AGGS_FULL, max_preds=5,
                                   min_selectivity=1e-5)
        # With compression (bases seed bin edges) vs without.
        fw = AQPFramework(BuildParams(n_samples=100_000),
                          use_compression=True).ingest(table)
        fw_nc = AQPFramework(BuildParams(n_samples=100_000),
                             use_compression=False).ingest(table)
        lats = []
        for sql in queries:
            t0 = time.perf_counter()
            fw.query(sql)
            lats.append(time.perf_counter() - t0)
        rep = fw.storage_report()
        entry = {
            "synopsis_bytes": rep["synopsis"]["total"],
            "compressed_data_bytes": rep["compressed_data_bytes"],
            "raw_data_bytes": rep["raw_data_bytes"],
            "total_storage_reduction": rep["total_storage_reduction"],
            "median_latency_ms": float(np.median(lats) * 1e3),
            "p99_latency_ms": float(np.percentile(lats, 99) * 1e3),
            "build_with_gd_s": fw.timings["build_synopsis_s"],
            "compress_s": fw.timings["compress_s"],
            "build_without_gd_s": fw_nc.timings["build_synopsis_s"],
        }
        out[name] = entry
        emit(rows, f"fig11/{name}/latency",
             entry["median_latency_ms"] * 1e3, "median query")
        emit(rows, f"fig11/{name}/synopsis_size", None,
             f"{entry['synopsis_bytes']}B")
        emit(rows, f"fig11/{name}/total_storage_reduction", None,
             f"{entry['total_storage_reduction']:.2f}x")
        emit(rows, f"fig11/{name}/build_time", None,
             f"{entry['build_with_gd_s']:.1f}s(gd)/"
             f"{entry['build_without_gd_s']:.1f}s(raw)")
    save_json("fig11", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
