"""Fig. 9: parameter sensitivity — N_s, M (via m_frac) and alpha.

Paper claims to validate: N_s dominates accuracy/size/build-time; alpha has
near-zero impact; lower M -> more bins -> better accuracy, bigger synopsis.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, eval_engine, save_json
from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.aqp.exact import ExactEngine
from repro.aqp.queries import AGGS_INITIAL, generate_queries
from repro.core.types import BuildParams

GRID = {
    "n_samples": (10_000, 50_000, 100_000),
    "m_frac": (0.005, 0.01, 0.02),
    "alpha": (0.01, 0.001, 0.0001),
}
BASE = dict(n_samples=50_000, m_frac=0.01, alpha=0.001)


def run(rows: list, quick: bool = False):
    table = load("flights", n=150_000)
    exact = ExactEngine(table)
    queries = generate_queries(table, 25 if quick else 50, seed=41,
                               aggs=AGGS_INITIAL, max_preds=3,
                               min_selectivity=1e-4)
    out = {}
    for knob, values in GRID.items():
        if quick and knob != "n_samples":
            continue
        for val in values:
            kw = dict(BASE)
            kw[knob] = val
            t0 = time.perf_counter()
            fw = AQPFramework(BuildParams(**kw)).ingest(table)
            build_s = time.perf_counter() - t0
            res = eval_engine(fw.query, queries, exact)
            res.pop("errs")
            res["build_s"] = build_s
            res["size_bytes"] = fw.size_bytes()
            out[f"{knob}={val}"] = res
            emit(rows, f"fig9/{knob}={val}", None,
                 f"err={res['median_err']:.3f}%/size={res['size_bytes']}B"
                 f"/build={build_s:.1f}s")
    save_json("fig9", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
