# One benchmark per paper table/figure (see DESIGN.md §6 for the index).
