"""Kernel micro-benchmarks + the fused-query-path latency comparison.

On this CPU container, Pallas runs in interpret mode (correctness only), so
wall-times compare the *paper-faithful per-predicate path* against the
*fused single-launch path* executed via the jnp reference of the same fused
kernel — the structural win (ops per query) that the Pallas kernel locks in
on TPU.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.aqp.datasets import load
from repro.aqp.engine import AQPFramework
from repro.core.fastpath import make_fastpath
from repro.core.query import QueryEngine
from repro.core.types import BuildParams
from repro.kernels.hist2d import hist2d
from repro.kernels.hist2d.ref import hist2d_ref
from repro.kernels.weightings import fused_weightings
from repro.kernels.weightings.ref import fused_weightings_ref


def _time(fn, n=5):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
    return (time.perf_counter() - t0) / n


def run(rows: list, quick: bool = False):
    rng = np.random.default_rng(0)
    out = {}

    # hist2d: jnp scatter-add ref timing (compiled) at construction scale.
    n, ki, kj = 100_000, 256, 256
    bi = rng.integers(0, ki, n).astype(np.int32)
    bj = rng.integers(0, kj, n).astype(np.int32)
    w = np.ones(n, np.float32)
    import jax.numpy as jnp
    import jax
    ref = jax.jit(lambda a, b, c: hist2d_ref(a, b, c, ki, kj))
    t_ref = _time(lambda: ref(jnp.asarray(bi), jnp.asarray(bj), jnp.asarray(w)))
    ok = bool(jnp.allclose(hist2d(bi, bj, w, ki, kj),
                           ref(jnp.asarray(bi), jnp.asarray(bj),
                               jnp.asarray(w))))
    out["hist2d"] = {"n": n, "ref_us": t_ref * 1e6, "pallas_matches_ref": ok}
    emit(rows, "kernels/hist2d_ref", t_ref * 1e6, f"match={ok}")

    # fused weightings kernel vs ref.
    el, k2, k1 = 5, 256, 256
    H = rng.random((el, k2, k2)).astype(np.float32)
    beta = rng.random((el, k2)).astype(np.float32)
    hx = H.sum(2) + 1.0
    fold = np.zeros((el, k1, k2), np.float32)
    fold[:, np.arange(k1), np.sort(rng.integers(0, k2, k1))] = 1
    refw = jax.jit(fused_weightings_ref)
    t_refw = _time(lambda: refw(jnp.asarray(H), jnp.asarray(beta),
                                jnp.asarray(fold), jnp.asarray(hx)))
    okw = bool(jnp.allclose(
        fused_weightings(H, beta, fold, hx),
        refw(jnp.asarray(H), jnp.asarray(beta), jnp.asarray(fold),
             jnp.asarray(hx)), rtol=1e-5, atol=1e-5))
    out["fused_weightings"] = {"ref_us": t_refw * 1e6,
                               "pallas_matches_ref": okw}
    emit(rows, "kernels/fused_weightings_ref", t_refw * 1e6, f"match={okw}")

    # End-to-end query latency: per-predicate NumPy path vs fused path.
    table = load("power", n=100_000)
    fw = AQPFramework(BuildParams(n_samples=50_000)).ingest(table)
    sql = ("SELECT AVG(global_active_power) FROM t WHERE voltage > 238 AND "
           "global_intensity < 9 AND sub_metering_3 >= 1")
    eng_ref = QueryEngine(fw.synopsis)
    eng_fast = QueryEngine(fw.synopsis,
                           fastpath=make_fastpath(use_pallas=False))
    t_per_pred = _time(lambda: eng_ref.query(sql), n=20)
    t_fused = _time(lambda: eng_fast.query(sql), n=20)
    agree = np.allclose(eng_ref.query(sql).as_tuple(),
                        eng_fast.query(sql).as_tuple(), rtol=1e-5)
    out["query_path"] = {"per_predicate_us": t_per_pred * 1e6,
                         "fused_us": t_fused * 1e6, "agree": bool(agree)}
    emit(rows, "kernels/query_per_predicate", t_per_pred * 1e6, "baseline")
    emit(rows, "kernels/query_fused", t_fused * 1e6,
         f"{t_per_pred / t_fused:.2f}x vs baseline, agree={agree}")
    save_json("kernels", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
