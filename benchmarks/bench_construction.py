"""§Perf (AQP side): construction benchmarks.

Two comparisons:

  1. paper-faithful sequential (Algorithm 1/2, recursive NumPy) vs the
     level-synchronous vectorized JAX construction (full build);
  2. the 2-D *pair phase* in isolation: legacy per-pair host loop (one
     compiled launch + blocking device->host sync per pair,
     ``build.build_pairs_sequential``) vs the pair-batched path
     (``build.build_pairs_batched``: chunked (P, N) tensors, one while_loop
     per chunk, one grouped transfer, adaptive capacity ladder) — measured
     at d >= 8 with a pairs-per-second metric, bit-for-bit equality
     asserted in oracle mode. Both paths are timed via the synopsis's
     ``build_stats`` telemetry on repeated warm builds; the reported
     number is the median of ``repeats`` runs (2-core CI boxes are noisy).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import chi2 as chi2lib
from repro.core import ref_sequential
from repro.core.build import build_pairwise_hist
from repro.core.types import BuildParams, ColumnInfo


def _pair_phase_data(n: int, d: int, rng):
    """d >= 8 mixed workload: independent + correlated + heavy-tail columns
    so the 2-D refinement actually splits (the all-independent case is the
    degenerate no-split fast path)."""
    base = np.abs(rng.normal(300, 90, n))
    cols = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
            for i in range(d - 2)]
    cols.append(np.round(base))
    cols.append(np.round(base * 2 + rng.normal(0, 20, n)))
    return np.stack(cols, 1)


def _timed_pair_phase(data, cols, params, repeats: int):
    syn = build_pairwise_hist(data, cols, params)    # warm jit caches
    times = []
    for _ in range(repeats):
        syn = build_pairwise_hist(data, cols, params)
        times.append(syn.build_stats["pair_phase_s"])
    return float(np.median(times)), syn.build_stats


def _assert_pairs_equal(a, b):
    assert set(a.pairs) == set(b.pairs)
    for key in a.pairs:
        for f, x, y in zip(a.pairs[key]._fields, a.pairs[key], b.pairs[key]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"pair {key} field {f}")


def run(rows: list, quick: bool = False):
    rng = np.random.default_rng(3)
    out = {}

    # --- 1. paper-faithful sequential recursion vs level-sync JAX ----------
    n = 50_000 if quick else 100_000
    d = 4 if quick else 6
    cols_data = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
                 for i in range(d)]
    data = np.stack(cols_data, 1)
    crit = chi2lib.build_crit_table(0.001, 128)
    m_pts = n // 100

    t0 = time.perf_counter()
    edges_1d = {}
    for i in range(d):
        x = data[:, i]
        init = np.array([x.min(), x.max()])
        edges_1d[i], _, _, _, _ = ref_sequential.build_1d_sequential(
            x, init, m_pts, crit)
    for i in range(d):
        for j in range(i):
            ref_sequential.build_2d_sequential(
                data[:, j], data[:, i], edges_1d[j], edges_1d[i], m_pts, crit,
                s_max=32)
    t_seq = time.perf_counter() - t0

    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    params = BuildParams(n_samples=n)
    build_pairwise_hist(data, cols, params)  # warm the jit caches
    t0 = time.perf_counter()
    build_pairwise_hist(data, cols, params)
    t_vec = time.perf_counter() - t0

    out["full_build"] = {"n": n, "d": d, "sequential_s": t_seq,
                         "vectorized_s": t_vec, "speedup": t_seq / t_vec}
    emit(rows, "construction/sequential_alg1", t_seq * 1e6, "paper-faithful")
    emit(rows, "construction/levelsync_jax", t_vec * 1e6,
         f"{t_seq / t_vec:.2f}x vs sequential")

    # --- 2. pair phase: legacy per-pair loop vs pair-batched ---------------
    n2 = 20_000 if quick else 60_000
    d2 = 8
    repeats = 2 if quick else 3
    data2 = _pair_phase_data(n2, d2, rng)
    cols2 = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d2)]
    n_pairs = d2 * (d2 - 1) // 2
    p_loop = BuildParams(n_samples=n2, pair_batched=False)
    p_batched = dataclasses.replace(p_loop, pair_batched=True)

    t_loop, _ = _timed_pair_phase(data2, cols2, p_loop, repeats)
    t_batched, bstats = _timed_pair_phase(data2, cols2, p_batched, repeats)
    launches = bstats["pair_launches"]

    # bit-for-bit equality of the two paths in oracle mode (the acceptance
    # bar for the batched rewrite) — checked on the benchmark workload.
    _assert_pairs_equal(build_pairwise_hist(data2, cols2, p_loop),
                        build_pairwise_hist(data2, cols2, p_batched))

    speedup = t_loop / t_batched
    out["pair_phase"] = {
        "n": n2, "d": d2, "n_pairs": n_pairs,
        "per_pair_loop_s": t_loop, "batched_s": t_batched,
        "speedup": speedup,
        "pairs_per_s_loop": n_pairs / t_loop,
        "pairs_per_s_batched": n_pairs / t_batched,
        "batched_launches": [list(l) for l in launches],
        "bitforbit_equal": True,
    }
    emit(rows, "construction/pair_loop", t_loop * 1e6,
         f"{n_pairs / t_loop:.1f} pairs/s")
    emit(rows, "construction/pair_batched", t_batched * 1e6,
         f"{n_pairs / t_batched:.1f} pairs/s; {speedup:.2f}x vs loop")
    save_json("construction", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
