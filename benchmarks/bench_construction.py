"""§Perf (AQP side): paper-faithful sequential construction (Algorithm 1/2,
recursive NumPy) vs the level-synchronous vectorized JAX construction —
measured wall-clock on CPU, identical 1-D outputs asserted.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import chi2 as chi2lib
from repro.core import ref_sequential
from repro.core.build import build_pairwise_hist
from repro.core.types import BuildParams, ColumnInfo


def run(rows: list, quick: bool = False):
    rng = np.random.default_rng(3)
    n = 50_000 if quick else 100_000
    d = 4 if quick else 6
    cols_data = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
                 for i in range(d)]
    data = np.stack(cols_data, 1)
    crit = chi2lib.build_crit_table(0.001, 128)
    m_pts = n // 100

    # paper-faithful sequential (1-D + 2-D)
    t0 = time.perf_counter()
    for i in range(d):
        x = data[:, i]
        init = np.array([x.min(), x.max()])
        e_i, _, _, _, _ = ref_sequential.build_1d_sequential(x, init, m_pts, crit)
    edges_1d = {}
    for i in range(d):
        x = data[:, i]
        init = np.array([x.min(), x.max()])
        edges_1d[i], _, _, _, _ = ref_sequential.build_1d_sequential(
            x, init, m_pts, crit)
    for i in range(d):
        for j in range(i):
            ref_sequential.build_2d_sequential(
                data[:, j], data[:, i], edges_1d[j], edges_1d[i], m_pts, crit,
                s_max=32)
    t_seq = time.perf_counter() - t0

    # level-synchronous vectorized
    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    params = BuildParams(n_samples=n)
    build_pairwise_hist(data, cols, params)  # warm the jit caches
    t0 = time.perf_counter()
    build_pairwise_hist(data, cols, params)
    t_vec = time.perf_counter() - t0

    out = {"n": n, "d": d, "sequential_s": t_seq, "vectorized_s": t_vec,
           "speedup": t_seq / t_vec}
    emit(rows, "construction/sequential_alg1", t_seq * 1e6, "paper-faithful")
    emit(rows, "construction/levelsync_jax", t_vec * 1e6,
         f"{t_seq / t_vec:.2f}x vs sequential")
    save_json("construction", out)
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    print("\n".join(rows))
