"""§Perf (AQP side): construction benchmarks.

Three comparisons:

  1. paper-faithful sequential (Algorithm 1/2, recursive NumPy) vs the
     level-synchronous vectorized JAX construction (full build);
  2. the 2-D *pair phase* in isolation on the mixed (mostly independent)
     workload: legacy per-pair host loop (one compiled launch + blocking
     device->host sync per pair, ``build.build_pairs_sequential``) vs the
     default batched path (since the compaction rewrite:
     ``build.build_pairs_compact``) — measured at d >= 8 with a
     pairs-per-second metric, bit-for-bit equality asserted in oracle mode;
  3. the *correlated-pair* scenario (``--correlated`` runs it alone):
     sequential vs the fixed-chunk scheduler
     (``compact_drain=False``, which lockstep-drags on deep pairs) vs the
     convergence-compacting scheduler, with the occupancy ledger.

All paths are timed via the synopsis's ``build_stats`` telemetry on
repeated warm builds; the reported number is the median of ``repeats``
runs (2-core CI boxes are noisy).
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro.core import chi2 as chi2lib
from repro.core import ref_sequential
from repro.core import storage
from repro.core.build import build_pairwise_hist
from repro.core.types import BuildParams, ColumnInfo
from repro.gd.greedygd import GreedyGD
from repro.obs.export import (timeline_to_events, validate_trace_events,
                              write_trace)
from repro.serve.aqp.catalog import ColdTable


def _pair_phase_data(n: int, d: int, rng):
    """d >= 8 mixed workload: independent + correlated + heavy-tail columns
    so the 2-D refinement actually splits (the all-independent case is the
    degenerate no-split fast path)."""
    base = np.abs(rng.normal(300, 90, n))
    cols = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
            for i in range(d - 2)]
    cols.append(np.round(base))
    cols.append(np.round(base * 2 + rng.normal(0, 20, n)))
    return np.stack(cols, 1)


def _correlated_data(n: int, d: int, rng):
    """Pairwise-dependent workload: half the columns derive from one shared
    base, so every pair among them refines deep while the independent half
    converges in a round or two — the exact mix where fixed-chunk
    refinement lockstep-drags (deep pairs hold their whole chunk hostage)
    and convergence compaction should not."""
    base = np.abs(rng.normal(300, 90, n))
    cols = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
            for i in range(d // 2)]
    cols += [np.round(base * (1 + 0.5 * i) + rng.normal(0, 15, n))
             for i in range(d - d // 2)]
    return np.stack(cols, 1)


def _timed_pair_phase(data, cols, params, repeats: int):
    syn = build_pairwise_hist(data, cols, params)    # warm jit caches
    times = []
    for _ in range(repeats):
        syn = build_pairwise_hist(data, cols, params)
        times.append(syn.build_stats["pair_phase_s"])
    return float(np.median(times)), syn.build_stats


def _assert_pairs_equal(a, b):
    assert set(a.pairs) == set(b.pairs)
    for key in a.pairs:
        for f, x, y in zip(a.pairs[key]._fields, a.pairs[key], b.pairs[key]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"pair {key} field {f}")


def _run_correlated(rows: list, out: dict, quick: bool, rng):
    """Correlated-pair scenario: sequential vs fixed-chunk vs compacting.

    The tracked numbers are the two speedups over the sequential per-pair
    loop: the fixed-chunk scheduler historically lost most of its batching
    win here (~1.5-1.7x; deep pairs lockstep-drag their chunk), the
    convergence-compacting scheduler must hold >= 3x (acceptance), with the
    occupancy ledger (pair-rounds refined vs slot-rounds paid) explaining
    where the recovered time comes from.
    """
    n = 20_000 if quick else 60_000
    d = 8
    repeats = 2 if quick else 3
    data = _correlated_data(n, d, rng)
    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    n_pairs = d * (d - 1) // 2
    p_loop = BuildParams(n_samples=n, pair_batched=False)
    p_fixed = dataclasses.replace(p_loop, pair_batched=True,
                                  compact_drain=False)
    p_compact = dataclasses.replace(p_loop, pair_batched=True,
                                    compact_drain=True)

    t_loop, _ = _timed_pair_phase(data, cols, p_loop, repeats)
    t_fixed, _ = _timed_pair_phase(data, cols, p_fixed, repeats)
    t_compact, cstats = _timed_pair_phase(data, cols, p_compact, repeats)

    _assert_pairs_equal(build_pairwise_hist(data, cols, p_loop),
                        build_pairwise_hist(data, cols, p_compact))
    comp = cstats["compaction"]
    out["correlated"] = {
        "n": n, "d": d, "n_pairs": n_pairs,
        "per_pair_loop_s": t_loop,
        "fixed_chunk_s": t_fixed,
        "compact_s": t_compact,
        "speedup_fixed": t_loop / t_fixed,
        "speedup_compact": t_loop / t_compact,
        "pairs_per_s_compact": n_pairs / t_compact,
        "occupancy": (comp["pair_rounds"] / comp["slot_rounds"]
                      if comp["slot_rounds"] else None),
        "compaction": comp,
        "bitforbit_equal": True,
    }
    emit(rows, "construction/correlated_fixed_chunk", t_fixed * 1e6,
         f"{t_loop / t_fixed:.2f}x vs loop (lockstep drag)")
    emit(rows, "construction/correlated_compact", t_compact * 1e6,
         f"{t_loop / t_compact:.2f}x vs loop; "
         f"occupancy {out['correlated']['occupancy']:.2f}")


def _trace_build(rows: list, out: dict, quick: bool, rng):
    """Build-phase timeline export: one instrumented build's per-phase /
    per-round event stream (``build_stats["timeline"]``) rendered to a
    validated Perfetto trace_event artifact, with the phase-seconds summary
    recorded so the JSON tells the same story as the trace."""
    n = 20_000 if quick else 60_000
    d = 8
    data = _correlated_data(n, d, rng)
    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    syn = build_pairwise_hist(data, cols, BuildParams(n_samples=n))
    stats = syn.build_stats
    events = timeline_to_events(stats["timeline"])
    problems = validate_trace_events(events)
    path = write_trace(os.path.join(RESULTS_DIR, "construction_trace.json"),
                       events)
    out["trace"] = {
        "n": n, "d": d,
        "phase_s": dict(stats.get("phase_s", {})),
        "events": len(events),
        "valid": not problems,
        "path": path,
    }
    emit(rows, "construction/trace_artifact", None,
         f"{len(events)} events, valid={not problems} -> {path}")
    for phase, secs in sorted(out["trace"]["phase_s"].items(),
                              key=lambda kv: -kv[1]):
        emit(rows, f"construction/phase_{phase}", secs * 1e6,
             f"{secs * 1e3:.1f} ms")


def _run_gd(rows: list, out: dict, quick: bool, rng):
    """GD-native compressed construction + storage cold start: compress a
    redundant table, build the synopsis directly from the
    ``CompressedTable`` (only the N_s sampled rows decode) vs the raw build
    with the same base-seeded edges, then encode the synopsis and time the
    cold-start decode a ``ColdTable`` pays on its first query."""
    n = 30_000 if quick else 100_000
    d = 6
    # Few distinct high-order patterns per column -> real base dedup.
    data = np.stack(
        [rng.integers(0, 40 + 10 * i, n).astype(float) * 64
         + rng.integers(0, 8, n) for i in range(d)], 1)
    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    # N_s < n so rows_decoded reflects a sample-only decode, not a full pass.
    params = BuildParams(n_samples=min(n // 2, 50_000))

    ct = GreedyGD().compress(data)
    ratio = ct.raw_size_bytes() / ct.size_bytes()

    build_pairwise_hist(ct, cols, params)            # warm jit caches
    t0 = time.perf_counter()
    syn = build_pairwise_hist(ct, cols, params)
    t_ct = time.perf_counter() - t0
    t0 = time.perf_counter()
    build_pairwise_hist(data, cols, params,
                        seed_edges=GreedyGD.seed_edges(ct))
    t_raw = time.perf_counter() - t0

    blob = storage.encode(syn)
    cold = ColdTable(blob, compressed=ct)
    cold.published                                   # first access: decode
    decode_ms = cold.timings["cold_decode_s"] * 1e3

    out["gd"] = {
        "n": n, "d": d,
        "synopsis_bytes": len(blob),
        "compression_ratio": ratio,
        "cold_start_decode_ms": decode_ms,
        "table_bytes_raw": ct.raw_size_bytes(),
        "table_bytes_compressed": ct.size_bytes(),
        "rows_decoded": syn.build_stats["rows_decoded"],
        "build_from_compressed_s": t_ct,
        "build_raw_s": t_raw,
    }
    emit(rows, "construction/gd_compression", None,
         f"{ratio:.2f}x ({ct.raw_size_bytes()} -> {ct.size_bytes()}B)")
    emit(rows, "construction/gd_build", t_ct * 1e6,
         f"{syn.build_stats['rows_decoded']}/{n} rows decoded; "
         f"raw build {t_raw * 1e3:.0f} ms")
    emit(rows, "construction/gd_cold_start", decode_ms * 1e3,
         f"{len(blob)}B synopsis, {decode_ms:.1f} ms decode")


def run(rows: list, quick: bool = False, correlated_only: bool = False,
        trace: bool = False):
    rng = np.random.default_rng(3)
    out: dict = {}
    if correlated_only:
        _run_correlated(rows, out, quick, rng)
        if trace:
            _trace_build(rows, out, quick, rng)
        _run_gd(rows, out, quick, rng)
        save_json("construction", out)
        return out

    # --- 1. paper-faithful sequential recursion vs level-sync JAX ----------
    n = 50_000 if quick else 100_000
    d = 4 if quick else 6
    cols_data = [np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i, n)))
                 for i in range(d)]
    data = np.stack(cols_data, 1)
    crit = chi2lib.build_crit_table(0.001, 128)
    m_pts = n // 100

    t0 = time.perf_counter()
    edges_1d = {}
    for i in range(d):
        x = data[:, i]
        init = np.array([x.min(), x.max()])
        edges_1d[i], _, _, _, _ = ref_sequential.build_1d_sequential(
            x, init, m_pts, crit)
    for i in range(d):
        for j in range(i):
            ref_sequential.build_2d_sequential(
                data[:, j], data[:, i], edges_1d[j], edges_1d[i], m_pts, crit,
                s_max=32)
    t_seq = time.perf_counter() - t0

    cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]
    params = BuildParams(n_samples=n)
    build_pairwise_hist(data, cols, params)  # warm the jit caches
    t0 = time.perf_counter()
    build_pairwise_hist(data, cols, params)
    t_vec = time.perf_counter() - t0

    out["full_build"] = {"n": n, "d": d, "sequential_s": t_seq,
                         "vectorized_s": t_vec, "speedup": t_seq / t_vec}
    emit(rows, "construction/sequential_alg1", t_seq * 1e6, "paper-faithful")
    emit(rows, "construction/levelsync_jax", t_vec * 1e6,
         f"{t_seq / t_vec:.2f}x vs sequential")

    # --- 2. pair phase: legacy per-pair loop vs pair-batched ---------------
    n2 = 20_000 if quick else 60_000
    d2 = 8
    repeats = 2 if quick else 3
    data2 = _pair_phase_data(n2, d2, rng)
    cols2 = [ColumnInfo(name=f"c{i}", kind="int") for i in range(d2)]
    n_pairs = d2 * (d2 - 1) // 2
    p_loop = BuildParams(n_samples=n2, pair_batched=False)
    p_batched = dataclasses.replace(p_loop, pair_batched=True)

    t_loop, _ = _timed_pair_phase(data2, cols2, p_loop, repeats)
    t_batched, bstats = _timed_pair_phase(data2, cols2, p_batched, repeats)
    launches = bstats["pair_launches"]

    # bit-for-bit equality of the two paths in oracle mode (the acceptance
    # bar for the batched rewrite) — checked on the benchmark workload.
    _assert_pairs_equal(build_pairwise_hist(data2, cols2, p_loop),
                        build_pairwise_hist(data2, cols2, p_batched))

    speedup = t_loop / t_batched
    out["pair_phase"] = {
        "n": n2, "d": d2, "n_pairs": n_pairs,
        "per_pair_loop_s": t_loop, "batched_s": t_batched,
        "speedup": speedup,
        "pairs_per_s_loop": n_pairs / t_loop,
        "pairs_per_s_batched": n_pairs / t_batched,
        "batched_launches": [list(l) for l in launches],
        "bitforbit_equal": True,
    }
    emit(rows, "construction/pair_loop", t_loop * 1e6,
         f"{n_pairs / t_loop:.1f} pairs/s")
    emit(rows, "construction/pair_batched", t_batched * 1e6,
         f"{n_pairs / t_batched:.1f} pairs/s; {speedup:.2f}x vs loop")

    # --- 3. correlated pairs: lockstep drag vs convergence compaction ------
    _run_correlated(rows, out, quick, rng)
    if trace:
        _trace_build(rows, out, quick, rng)

    # --- 4. GD-native compressed build + storage cold start ----------------
    _run_gd(rows, out, quick, rng)
    save_json("construction", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--correlated", action="store_true",
                    help="run only the correlated-pair scenario")
    ap.add_argument("--trace", action="store_true",
                    help="export a validated build-timeline trace artifact "
                         "to benchmarks/results/construction_trace.json")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, correlated_only=args.correlated,
        trace=args.trace)
    print("\n".join(rows))
