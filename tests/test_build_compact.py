"""Convergence-compacting 2-D construction vs the sequential oracle.

The compacted path (refine.refine_2d_compact driven by
build.build_pairs_compact — drain/backfill active set, shared per-column
presorts, per-pair capacity rungs) must be *bit-for-bit* equal to the
legacy host loop (build.build_pairs_sequential) on every workload mix:
each pair's refinement is the same deterministic fixed-point iteration
whatever the slot count, queue order, drain timing or occupancy_min
re-bucketing. Covers correlated, independent, constant, NaN-heavy and
K2-capped mixes plus drain/backfill schedule invariants (every pair
refined exactly once, deterministic outputs, exact occupancy ledger).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.build import (_column_ranks, _pad_edges, _presort_pairs_host,
                              build_pairwise_hist)
from repro.core.types import BuildParams, ColumnInfo


def _cols(d):
    return [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]


def _mixed_table(n=5000, seed=7):
    """Deep (correlated) + shallow (independent) + constant + NaN-heavy."""
    rng = np.random.default_rng(seed)
    base = np.abs(rng.normal(300, 80, n))
    c0 = rng.integers(0, 500, n).astype(float)       # independent
    c1 = np.round(base)                              # correlated cluster
    c2 = np.round(base * 2 + rng.normal(0, 25, n))
    c3 = rng.zipf(1.7, n).clip(1, 40).astype(float)  # heavy tail + NULLs
    c3[rng.random(n) < 0.05] = np.nan
    c4 = np.full(n, 7.0)                             # constant
    return np.stack([c0, c1, c2, c3, c4], 1)


def _independent_table(n=4000, seed=11, d=4):
    rng = np.random.default_rng(seed)
    return np.stack([np.round(np.abs(rng.normal(100 * (i + 1), 20 + 10 * i,
                                                n))) for i in range(d)], 1)


def _assert_same_synopsis(a, b):
    for h1, h2 in zip(a.hists, b.hists):
        for f, x, y in zip(h1._fields, h1, h2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"hist field {f}")
    assert set(a.pairs) == set(b.pairs)
    for key in a.pairs:
        for f, x, y in zip(a.pairs[key]._fields, a.pairs[key], b.pairs[key]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"pair {key} field {f}")


@pytest.fixture(scope="module")
def mixed():
    return _mixed_table()


@pytest.fixture(scope="module")
def seq_mixed(mixed):
    params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=False)
    return build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)


def test_compact_equals_sequential_bitforbit(mixed, seq_mixed):
    params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=True, compact_drain=True, pair_chunk=4)
    compact = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
    assert compact.build_stats["mode"] == "compact"
    _assert_same_synopsis(seq_mixed, compact)


def test_slot_count_invariance(mixed, seq_mixed):
    """Slot count (and with it queue order / drain timing) never changes
    bits — the schedule-independence core of the compaction claim."""
    for chunk in (1, 2, 8):
        params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                             pair_batched=True, pair_chunk=chunk)
        compact = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
        _assert_same_synopsis(seq_mixed, compact)


def test_occupancy_rebucket_invariance(mixed, seq_mixed):
    """occupancy_min early-exit + smaller relaunches resume mid-refinement
    pairs exactly; occupancy_min=1.0 re-buckets after every drain."""
    for occ in (0.5, 1.0):
        params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                             pair_batched=True, pair_chunk=4,
                             occupancy_min=occ)
        compact = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
        _assert_same_synopsis(seq_mixed, compact)
        if occ == 1.0:
            assert compact.build_stats["compaction"]["relaunches"] > 0


def test_fixed_chunk_path_still_equal(mixed, seq_mixed):
    """compact_drain=False keeps the PR 2 fixed-chunk scheduler (benchmark
    baseline / escape hatch) — and it must still match the oracle."""
    params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=True, compact_drain=False, pair_chunk=4)
    fixed = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
    assert fixed.build_stats["mode"] == "batched"
    _assert_same_synopsis(seq_mixed, fixed)


def test_independent_columns(seq_mixed):
    data = _independent_table()
    p_seq = BuildParams(n_samples=data.shape[0], k2_cap=64, s2_max=16,
                        pair_batched=False)
    p_cmp = dataclasses.replace(p_seq, pair_batched=True, pair_chunk=4)
    _assert_same_synopsis(build_pairwise_hist(data, _cols(4), p_seq),
                          build_pairwise_hist(data, _cols(4), p_cmp))


def test_k2_capacity_guard(mixed):
    """At a tiny k2_cap the guard binds; the final rung must NOT early-drain
    capped pairs (their capped result is the real one) and must reproduce
    the sequential capped bins."""
    p_seq = BuildParams(n_samples=mixed.shape[0], k2_cap=8, s2_max=16,
                        pair_batched=False)
    p_cmp = dataclasses.replace(p_seq, pair_batched=True, pair_chunk=4)
    seq = build_pairwise_hist(mixed, _cols(mixed.shape[1]), p_seq)
    cmp_ = build_pairwise_hist(mixed, _cols(mixed.shape[1]), p_cmp)
    _assert_same_synopsis(seq, cmp_)
    for pr in cmp_.pairs.values():
        assert int(pr.kx) <= 8 and int(pr.ky) <= 8


def test_capacity_ladder_escalation_per_pair(mixed):
    """A tiny first rung forces guards to bind; only the capped pairs
    re-queue one rung up (per-pair escalation) and the result still matches
    the sequential loop at full capacity."""
    p_seq = BuildParams(n_samples=mixed.shape[0], k2_cap=128, s2_max=16,
                        pair_batched=False)
    p_esc = dataclasses.replace(p_seq, pair_batched=True, pair_chunk=4,
                                k2_start=4)
    seq = build_pairwise_hist(mixed, _cols(mixed.shape[1]), p_seq)
    esc = build_pairwise_hist(mixed, _cols(mixed.shape[1]), p_esc)
    _assert_same_synopsis(seq, esc)
    comp = esc.build_stats["compaction"]
    assert comp["escalated_pairs"] > 0
    # escalation is per pair: strictly fewer pair-slots re-ran than a
    # whole-chunk re-run would have paid
    assert comp["escalated_pairs"] < len(esc.pairs)


def test_schedule_ledger_and_determinism(mixed):
    """Every pair drains exactly once (n_pairs results, occupancy ledger
    exact: pair_rounds <= slot_rounds, both positive) and repeated builds
    are identical."""
    params = BuildParams(n_samples=mixed.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=True, pair_chunk=4)
    a = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
    b = build_pairwise_hist(mixed, _cols(mixed.shape[1]), params)
    _assert_same_synopsis(a, b)
    d = mixed.shape[1]
    assert len(a.pairs) == d * (d - 1) // 2
    comp = a.build_stats["compaction"]
    assert 0 < comp["pair_rounds"] <= comp["slot_rounds"]
    assert comp["loop_rounds"] > 0
    assert a.build_stats["pair_launches"]


def test_rank_presort_matches_lexsort_presort():
    """The shared-rank composite-key presort is permutation-identical to
    the two-key float lexsort (stable sorts, order-isomorphic keys)."""
    rng = np.random.default_rng(2)
    p, n = 4, 500
    x = rng.integers(0, 25, (p, n)).astype(float)    # many ties
    y = rng.integers(0, 25, (p, n)).astype(float)
    valid = rng.random((p, n)) < 0.85
    sample = np.stack([x[0], y[0], x[1], y[1]], 1)   # rank source columns
    ranks = _column_ranks(sample)
    lex = _presort_pairs_host(x[:2], y[:2], valid[:2])
    rk = _presort_pairs_host(x[:2], y[:2], valid[:2],
                             np.stack([ranks[0], ranks[2]]),
                             np.stack([ranks[1], ranks[3]]))
    for name, h, r in zip("xo1 yo1 vo1 new1 xo2 yo2 vo2 new2".split(),
                          lex, rk):
        np.testing.assert_array_equal(h, r, err_msg=name)


def test_refine_2d_compact_direct_invariants():
    """Drive refine_2d_compact directly: every pair drains exactly once
    with the same (ex, ey, kx, ky) as the single-pair refine_2d oracle,
    and the occupancy ledger is exact (sum of per-pair rounds ==
    active_rounds <= loop_rounds * slots)."""
    import jax.numpy as jnp

    from repro.core import chi2 as chi2lib
    from repro.core import refine

    rng = np.random.default_rng(5)
    n, n_pairs, k2 = 1500, 4, 32
    crit = jnp.asarray(chi2lib.build_crit_table(0.001, 16))
    base = np.abs(rng.normal(100, 30, n))
    xs = np.stack([np.round(base), np.round(base),
                   np.round(rng.uniform(0, 50, n)),
                   np.round(rng.uniform(0, 9, n))])
    ys = np.stack([np.round(base * 2 + rng.normal(0, 5, n)),
                   np.round(rng.uniform(0, 200, n)),
                   np.round(rng.uniform(0, 50, n) * 3 + base),
                   np.round(rng.uniform(0, 9, n))])
    valid = np.ones((n_pairs, n), bool)
    valid[1, rng.random(n) < 0.1] = False
    pres = _presort_pairs_host(xs, ys, valid)
    ex0 = np.stack([_pad_edges(np.array([x.min(), x.max()]), k2)
                    for x in xs])
    ey0 = np.stack([_pad_edges(np.array([y.min(), y.max()]), k2)
                    for y in ys])
    ones = np.ones(n_pairs, np.int32)
    m_pts = 25.0

    out = refine.refine_2d_compact(
        *(jnp.asarray(a) for a in pres), jnp.asarray(ex0), jnp.asarray(ey0),
        jnp.asarray(ones), jnp.asarray(ones),
        jnp.zeros(n_pairs, jnp.int32), jnp.zeros(n_pairs, bool),
        jnp.int32(n_pairs), jnp.float64(m_pts), crit, jnp.float64(0.0),
        n_slots=2, k2=k2, s_max=16, max_rounds=16)
    (oex, oey, okx, oky, _ocap, ornd, odone, _sp, sact,
     *_rest, loop_rounds, active_rounds) = [np.asarray(v) for v in out]
    assert odone.all() and not sact.any()
    assert int(active_rounds) == int(ornd.sum())
    assert int(active_rounds) <= int(loop_rounds) * 2

    for p in range(n_pairs):
        ex, ey, kx, ky = refine.refine_2d(
            jnp.asarray(xs[p]), jnp.asarray(ys[p]), jnp.asarray(valid[p]),
            jnp.asarray(ex0[p]), jnp.asarray(ey0[p]),
            jnp.int32(1), jnp.int32(1), jnp.float64(m_pts), crit,
            k2=k2, s_max=16, max_rounds=16)
        np.testing.assert_array_equal(oex[p], np.asarray(ex))
        np.testing.assert_array_equal(oey[p], np.asarray(ey))
        assert okx[p] == int(kx) and oky[p] == int(ky)


def test_all_nan_pair_column():
    """A column that is NULL on every row yields empty pair histograms
    through the compacted path too."""
    rng = np.random.default_rng(0)
    n = 2000
    data = np.stack([rng.integers(0, 100, n).astype(float),
                     np.full(n, np.nan),
                     np.abs(rng.normal(50, 10, n)).round()], 1)
    p_seq = BuildParams(n_samples=n, k2_cap=32, s2_max=16,
                        pair_batched=False)
    p_cmp = dataclasses.replace(p_seq, pair_batched=True)
    seq = build_pairwise_hist(data, _cols(3), p_seq)
    cmp_ = build_pairwise_hist(data, _cols(3), p_cmp)
    _assert_same_synopsis(seq, cmp_)
    assert float(cmp_.pairs[(0, 1)].H.sum()) == 0.0
