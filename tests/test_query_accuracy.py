"""End-to-end query accuracy vs exact ground truth + Table 3 semantics."""
import numpy as np
import pytest


CASES = [
    ("SELECT COUNT(c0) FROM t WHERE c1 > 300", 3.0),
    ("SELECT SUM(c1) FROM t WHERE c2 <= 900 AND c0 < 500", 3.0),
    ("SELECT AVG(c2) FROM t WHERE c1 >= 250 AND c1 < 350", 1.5),
    ("SELECT AVG(c1) FROM t WHERE c0 < 100 OR c3 = 2", 2.0),
    ("SELECT MEDIAN(c1) FROM t WHERE c2 > 600", 2.0),
    ("SELECT VAR(c1) FROM t WHERE c0 >= 200", 5.0),
    ("SELECT COUNT(c0) FROM t WHERE c3 = 1", 2.0),
    ("SELECT COUNT(*) FROM t WHERE c1 > 250 AND c1 < 350 AND c2 > 900", 5.0),
]


@pytest.mark.parametrize("sql,tol_pct", CASES)
def test_query_error_within_tolerance(engine, exact, sql, tol_pct):
    res = engine.query(sql)
    truth = exact.query(sql)
    assert res.estimate is not None
    err = abs(res.estimate - truth) / max(abs(truth), 1e-9) * 100
    assert err < tol_pct, (sql, res.estimate, truth)


def test_bounds_are_ordered(engine, exact):
    for sql, _ in CASES:
        res = engine.query(sql)
        assert res.lower - 1e-9 <= res.estimate <= res.upper + 1e-9, sql


def test_min_max_same_column_clipping(engine, exact):
    for sql in ("SELECT MIN(c1) FROM t WHERE c1 > 100",
                "SELECT MIN(c2) FROM t WHERE c2 >= 777",
                "SELECT MAX(c1) FROM t WHERE c1 <= 444"):
        res = engine.query(sql)
        truth = exact.query(sql)
        assert res.estimate == pytest.approx(truth, abs=1.0), sql


def test_count_star_no_where(engine, small_table):
    res = engine.query("SELECT COUNT(*) FROM t")
    assert res.estimate == len(small_table["c0"])
    assert res.lower == res.upper == res.estimate


def test_null_semantics(engine, exact):
    # c3 has NaNs: COUNT(c3) must exclude them, predicates on c3 are false.
    res = engine.query("SELECT COUNT(c3) FROM t WHERE c3 >= 1")
    truth = exact.query("SELECT COUNT(c3) FROM t WHERE c3 >= 1")
    err = abs(res.estimate - truth) / truth * 100
    assert err < 3.0


def test_empty_result(engine):
    res = engine.query("SELECT AVG(c1) FROM t WHERE c1 > 999999")
    assert res.estimate is None


def test_delayed_transformation_same_column(engine, exact):
    # Two conditions on one column must be consolidated, not multiplied
    # under independence (which would square the selectivity).
    sql = "SELECT COUNT(c1) FROM t WHERE c1 > 200 AND c1 < 400"
    res = engine.query(sql)
    truth = exact.query(sql)
    err = abs(res.estimate - truth) / truth * 100
    assert err < 3.0


def test_or_of_same_column(engine, exact):
    sql = "SELECT COUNT(c1) FROM t WHERE c1 < 150 OR c1 > 450"
    res = engine.query(sql)
    truth = exact.query(sql)
    err = abs(res.estimate - truth) / max(truth, 1) * 100
    assert err < 6.0


def test_group_by(small_table):
    import copy
    from repro.aqp.engine import AQPFramework
    from repro.core.types import BuildParams
    table = copy.deepcopy(small_table)
    table["cat"] = np.where(table["c0"] < 500, "low", "high")
    fw = AQPFramework(BuildParams(n_samples=30_000)).ingest(table)
    res = fw.query("SELECT AVG(c1) FROM t WHERE c2 > 600 GROUP BY cat")
    assert set(res.groups) == {"low", "high"}
    mask = table["c2"] > 600
    for name in ("low", "high"):
        sel = mask & (table["cat"] == name)
        truth = np.nanmean(table["c1"][sel])
        est = res.groups[name][0]
        assert abs(est - truth) / truth < 0.03
