"""Chi-squared machinery vs scipy oracle."""
import numpy as np
import pytest

scipy_stats = pytest.importorskip("scipy.stats")

from repro.core import chi2 as chi2lib  # noqa: E402


def test_critical_values_match_scipy():
    table = chi2lib.build_crit_table(alpha=0.001, s_max=128)
    for s in (2, 3, 5, 10, 32, 64, 128):
        expected = scipy_stats.chi2.isf(0.001, df=s - 1)
        assert abs(table[s] - expected) < 1e-6 * max(expected, 1), s


@pytest.mark.parametrize("alpha", [0.05, 0.01, 0.001, 1e-5])
def test_isf_round_trip(alpha):
    import jax.numpy as jnp
    df = jnp.asarray([1.0, 4.0, 17.0, 99.0])
    x = chi2lib.chi2_isf(alpha, df)
    back = np.asarray(chi2lib.chi2_sf(x, df))
    np.testing.assert_allclose(back, alpha, rtol=1e-6)


def test_degenerate_entries_are_inf():
    table = chi2lib.build_crit_table(alpha=0.001, s_max=8)
    assert np.isinf(table[0]) and np.isinf(table[1])


def test_num_subbins_terrell_scott():
    import jax.numpy as jnp
    u = jnp.asarray([1.0, 4.0, 100.0, 1e6])
    s = np.asarray(chi2lib.num_subbins(u, 128))
    # s = ceil((2u)^(1/3))
    np.testing.assert_array_equal(s, [2, 2, 6, 126])
