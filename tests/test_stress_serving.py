"""Concurrency stress harness for backpressure-bounded streaming admission.

The acceptance gate for the lock-split submit path (see docs/serving.md):
seeded multi-threaded workloads (N submitter threads x mixed GROUP BY /
point queries, optional mid-flight ``append_rows``/``rebuild``) drive a
live ``AQPServer`` and assert the serving invariants directly:

  * **no future is lost** — every submitted ``QueryFuture`` resolves
    (answered, ``AdmissionRejected``, or failed with the staleness/plan
    error) exactly once;
  * **the queue bound holds** — observed admission-queue depth never
    exceeds ``max_queue_depth`` (submit-time high-water AND drain-time
    depth);
  * **no stale epoch is served** — every answered ``COUNT(*)`` equals the
    row count of some synopsis version that actually existed;
  * **the ledger matches** — shed/reject counters equal the number of
    rejected submissions when the workload has no in-flight duplicates.

Small-N variants run in the default lane; the full-N variants are marked
``stress`` (``scripts/tier1.sh --stress``). Hypothesis property tests for
the admission state machine live in ``test_property_admission.py``.
"""
import concurrent.futures
import threading
import time
from collections import Counter

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core.query import PlanError
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer, StreamingAdmission

TIMEOUT = 60  # generous future-resolution bound; loaded CI boxes are slow


def _make_table(n=6_000, seed=13):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "cat": np.array(["r", "g", "b", "c"])[rng.integers(0, 4, n)],
    }


@pytest.fixture(scope="module")
def framework():
    return AQPFramework(BuildParams(n_samples=3_000, seed=4),
                        use_compression=False).ingest(_make_table())


def _workload(rng, n, unique_tag=None):
    """Seeded mixed stream: dup-heavy point + GROUP BY queries, literal
    variants, full-table counts. ``unique_tag`` makes every query textually
    distinct (one future == one submission, for ledger-exact tests)."""
    out = []
    for i in range(n):
        u = "" if unique_tag is None else f" AND a >= 0.{unique_tag}{i}"
        r = rng.random()
        if r < 0.12:
            out.append("SELECT COUNT(*) FROM t" if unique_tag is None else
                       f"SELECT SUM(b) FROM t WHERE b >= 0{u}")
        elif r < 0.25:
            out.append(f"SELECT COUNT(b) FROM t WHERE a < 250{u} "
                       "GROUP BY cat")
        elif r < 0.35:
            out.append(f"SELECT AVG(b) FROM t "
                       f"WHERE a > {int(rng.integers(0, 400))}{u} "
                       "GROUP BY cat")
        elif r < 0.55:
            out.append(f"SELECT COUNT(a) FROM t WHERE b > 100{u}")
        else:
            out.append(f"SELECT SUM(b) FROM t "
                       f"WHERE a > {int(rng.integers(0, 450))}{u}")
    return out


def _classify(futs):
    """-> (answered, rejected, failed); asserts every future resolved and
    every failure is the documented staleness/plan error."""
    answered = rejected = failed = 0
    for fut in futs:
        assert fut.done(), f"lost future: {fut.sql!r}"
        exc = fut.exception()
        if exc is not None:
            assert isinstance(exc, (RuntimeError, PlanError)), exc
            failed += 1
        elif getattr(fut.result(), "rejected", False):
            rejected += 1
        else:
            answered += 1
    return answered, rejected, failed


def _run_stress(fw, *, n_threads, n_per_thread, shed_policy, max_queue_depth,
                seed=0, unique=False, mutator=None, **server_kwargs):
    """Drive one seeded multi-threaded stress run; returns
    (futures, admission-stats snapshot, answered/rejected/failed counts)."""
    server_kwargs.setdefault("mode", "numpy")
    server_kwargs.setdefault("max_wait_ms", 1.0)
    server_kwargs.setdefault("max_batch", 16)
    srv = AQPServer(max_queue_depth=max_queue_depth, shed_policy=shed_policy,
                    **server_kwargs)
    srv.register("t", fw)
    ledgers = [[] for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads + (1 if mutator else 0))

    def submitter(ti):
        rng = np.random.default_rng(seed * 1_000 + ti)
        wl = _workload(rng, n_per_thread,
                       unique_tag=f"{seed}{ti}" if unique else None)
        barrier.wait()
        for sql in wl:
            ledgers[ti].append(srv.submit(sql))

    threads = [threading.Thread(target=submitter, args=(ti,))
               for ti in range(n_threads)]
    if mutator:
        threads.append(threading.Thread(target=mutator, args=(barrier,)))
    for t in threads:
        t.start()
    for t in threads:
        t.join(TIMEOUT)
        assert not t.is_alive(), "stress thread wedged"
    srv.flush()
    futs = [f for ledger in ledgers for f in ledger]
    done, not_done = concurrent.futures.wait(futs, timeout=TIMEOUT)
    assert not not_done, f"{len(not_done)} futures never resolved"
    counts = _classify(futs)
    stats = srv.stats()["totals"]["admission"]
    srv.close()
    # The bound is a hard invariant: depth observed right after every admit
    # (high water) and at every drain must respect it.
    if max_queue_depth > 0:
        assert stats["queue_high_water"] <= max_queue_depth
        assert stats["max_queue_depth"] <= max_queue_depth
    assert stats["submitted"] == len(futs)
    return futs, stats, counts


# ------------------------------------------------------- default (small-N)


def test_stress_small_reject(framework):
    futs, stats, (answered, rejected, failed) = _run_stress(
        framework, n_threads=4, n_per_thread=24,
        shed_policy="reject", max_queue_depth=8, seed=1)
    assert answered + rejected + failed == len(futs)
    assert failed == 0                    # no mutation: nothing may error
    assert answered > 0


def test_stress_small_shed_oldest(framework):
    futs, stats, (answered, rejected, failed) = _run_stress(
        framework, n_threads=4, n_per_thread=24,
        shed_policy="shed_oldest", max_queue_depth=4, seed=2)
    assert answered + rejected + failed == len(futs)
    assert failed == 0
    assert answered > 0
    assert stats["rejected"] == 0         # shed_oldest never rejects the new


def test_stress_small_block(framework):
    """block policy: producers are paced, nothing is ever shed — every
    future must come back answered."""
    futs, stats, (answered, rejected, failed) = _run_stress(
        framework, n_threads=4, n_per_thread=16,
        shed_policy="block", max_queue_depth=4, seed=3)
    assert (answered, rejected, failed) == (len(futs), 0, 0)
    assert stats["rejected"] == 0 and stats["shed"] == 0


def test_stress_counters_match_ledger(framework):
    """Unique-text workload (no in-flight dedupe): the shed/reject counters
    must equal the number of AdmissionRejected futures exactly."""
    futs, stats, (answered, rejected, failed) = _run_stress(
        framework, n_threads=4, n_per_thread=24, unique=True,
        shed_policy="reject", max_queue_depth=2, seed=4)
    assert failed == 0
    assert stats["rejected"] + stats["shed"] == rejected
    reasons = Counter(f.result().reason for f in futs
                      if f.exception() is None
                      and getattr(f.result(), "rejected", False))
    assert reasons.get("reject", 0) == stats["rejected"]
    assert reasons.get("shed_oldest", 0) == stats["shed"]


def test_stress_append_rows_mid_flight():
    """Mid-flight append_rows/rebuild cycles: answered COUNT(*) values must
    all equal a row count some synopsis version actually had — a stale
    epoch served would produce a count outside the valid set."""
    base = _make_table(4_000, seed=17)
    extra = {k: np.asarray(v)[:200] for k, v in base.items()}
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=6),
                      use_compression=False).ingest(base)
    valid = {4_000.0, 4_200.0}            # base, base + one append cycle

    def mutator(barrier):
        barrier.wait()
        time.sleep(0.2)                   # let early waves answer fresh
        for _ in range(3):
            fw.append_rows(extra)         # stale window: queries must fail
            time.sleep(0.005)
            fw.rebuild(base)              # merges pending: back to 4_200

    futs, _stats, (answered, rejected, failed) = _run_stress(
        fw, n_threads=4, n_per_thread=24,
        shed_policy="reject", max_queue_depth=16, seed=5, mutator=mutator)
    assert answered > 0
    for fut in futs:
        if fut.exception() is None and not getattr(fut.result(), "rejected",
                                                   False):
            res = fut.result()
            if fut.sql == "SELECT COUNT(*) FROM t":
                assert res.estimate in valid, \
                    f"stale count served: {res.estimate}"
    for fut in futs:                      # failures are staleness, only
        exc = fut.exception()
        if exc is not None:
            assert "stale" in str(exc)


def test_admission_interleavings_exactly_once():
    """Seeded interleavings of submit/flush/sleep/close against a bounded
    StreamingAdmission: every item lands in exactly one executed wave or
    exactly one shed callback — never both, never twice, never dropped.
    (The hypothesis generalization lives in test_property_admission.py.)"""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        executed, shed = [], []
        delay = 0.002 if seed % 2 else 0.0

        def execute(batch, stats, _d=delay, _e=executed):
            if _d:
                time.sleep(_d)            # slow consumer: forces full queues
            _e.extend(batch)

        adm = StreamingAdmission(
            execute,
            max_wait_ms=float(rng.choice([0.2, 2.0])),
            max_batch=int(rng.integers(1, 5)),
            max_queue_depth=int(rng.integers(1, 5)),
            shed_policy=str(rng.choice(["reject", "shed_oldest"])),
            shed_cb=lambda item, reason, depth, _s=shed: _s.append(item))
        submitted = []
        for i in range(int(rng.integers(10, 40))):
            op = rng.random()
            if op < 0.7:
                item = (seed, i)
                submitted.append(item)
                adm.submit(item)
            elif op < 0.85:
                adm.flush()
            else:
                time.sleep(float(rng.random()) * 0.003)
        adm.close()
        assert Counter(executed) + Counter(shed) == Counter(submitted), \
            f"seed {seed}: exactly-once violated"
        assert adm.high_water <= adm.max_queue_depth


def test_admission_block_policy_paces_producer():
    """block: a submit against a full queue waits for the drain instead of
    shedding; everything executes exactly once. The long max_wait keeps the
    worker idle until flush, so the full-queue window is deterministic."""
    executed = []
    adm = StreamingAdmission(lambda batch, stats: executed.extend(batch),
                             max_wait_ms=10_000.0, max_batch=8,
                             max_queue_depth=2, shed_policy="block")
    adm.submit(0)
    adm.submit(1)                         # queue at the bound; worker idle
    done = threading.Event()
    threading.Thread(target=lambda: (adm.submit(2), done.set()),
                     daemon=True).start()
    assert not done.wait(0.15)            # queue full: submit is blocked
    adm.flush()                           # drain frees space -> admit
    assert done.wait(TIMEOUT)
    adm.close()
    assert sorted(executed) == [0, 1, 2]
    assert adm.high_water <= 2


# --------------------------------------------------------- full-N (stress)


@pytest.mark.stress
@pytest.mark.parametrize("shed_policy,depth", [
    ("reject", 8), ("shed_oldest", 8), ("block", 4),
])
def test_stress_full(framework, shed_policy, depth):
    """Full-N lane (scripts/tier1.sh --stress): 8 submitters, larger
    seeded workloads, every shed policy."""
    futs, stats, (answered, rejected, failed) = _run_stress(
        framework, n_threads=8, n_per_thread=120,
        shed_policy=shed_policy, max_queue_depth=depth, seed=7)
    assert answered + rejected + failed == len(futs)
    assert failed == 0
    if shed_policy == "block":
        assert rejected == 0
    assert answered > 0


@pytest.mark.stress
def test_stress_full_mid_flight_mutation():
    base = _make_table(6_000, seed=19)
    extra = {k: np.asarray(v)[:300] for k, v in base.items()}
    fw = AQPFramework(BuildParams(n_samples=3_000, seed=8),
                      use_compression=False).ingest(base)
    valid = {6_000.0, 6_300.0}

    def mutator(barrier):
        barrier.wait()
        time.sleep(0.25)                  # let early waves answer fresh
        for _ in range(3):
            fw.append_rows(extra)
            time.sleep(0.005)
            fw.rebuild(base)              # takes long: broad stale window

    futs, _stats, (answered, _rejected, _failed) = _run_stress(
        fw, n_threads=8, n_per_thread=80,
        shed_policy="shed_oldest", max_queue_depth=16, seed=9,
        mutator=mutator)
    assert answered > 0
    for fut in futs:
        if (fut.exception() is None and fut.sql == "SELECT COUNT(*) FROM t"
                and not getattr(fut.result(), "rejected", False)):
            assert fut.result().estimate in valid
