"""Vectorized storage codec vs the scalar BitReader oracle.

``storage.decode`` now routes through ``FastBitReader`` (unpacked-bit
numpy gathers) by default; ``BitReader`` remains the per-bit oracle. These
tests hold the two bit-for-bit equal — on raw primitive runs, on the
packed-numpy ``BitWriter.write_run`` path, and on full synopsis blobs
covering the adversarial shapes (dense/sparse count flips, all-zero
pair counts, single-bin histograms) — without requiring hypothesis.
"""
import numpy as np
import pytest

from repro.core.storage import BitReader, BitWriter, FastBitReader, decode, encode
from repro.core.types import (BuildParams, ColumnInfo, Hist1D, PairHist,
                              PairwiseHist)


# ------------------------------------------------------------- primitive runs

def _write_stream(rng, n_ops=24):
    """A random interleaving of all write primitives; returns (blob, ops)."""
    w = BitWriter()
    ops = []
    for _ in range(n_ops):
        kind = int(rng.integers(0, 6))
        if kind == 0:
            nb = int(rng.integers(1, 64))
            v = int(rng.integers(0, 1 << min(nb, 62)))
            w.write(v, nb)
            ops.append(("bits", v, nb))
        elif kind == 1:
            n, nb = int(rng.integers(0, 200)), int(rng.integers(1, 62))
            vals = rng.integers(0, 1 << min(nb, 62), n)
            w.write_run(vals, nb)
            ops.append(("uint_run", vals, nb))
        elif kind == 2:
            vals = [int(rng.integers(0, 2 ** int(rng.integers(1, 62))))
                    for _ in range(int(rng.integers(0, 80)))]
            for v in vals:
                w.write_varint(v)
            ops.append(("varint_run", vals))
        elif kind == 3:
            vals = [int(rng.integers(-2**40, 2**40))
                    for _ in range(int(rng.integers(0, 80)))]
            for v in vals:
                w.write_svarint(v)
            ops.append(("svarint_run", vals))
        elif kind == 4:
            b = int(rng.integers(0, 9))
            vals = [int(rng.integers(0, 4000))
                    for _ in range(int(rng.integers(0, 150)))]
            for v in vals:
                w.write_rice(v, b)
            ops.append(("rice_run", vals, b))
        else:
            data = bytes(rng.integers(0, 256, int(rng.integers(0, 12)),
                                      dtype=np.uint8))
            for byte in data:
                w.write(byte, 8)
            ops.append(("bytes", data))
    return w.getvalue(), ops


def _read_stream(r, ops):
    out = []
    for op in ops:
        if op[0] == "bits":
            out.append(r.read(op[2]))
        elif op[0] == "uint_run":
            out.append(r.read_uint_run(len(op[1]), op[2]).tolist())
        elif op[0] == "varint_run":
            out.append(r.read_varint_run(len(op[1])).tolist())
        elif op[0] == "svarint_run":
            out.append(r.read_svarint_run(len(op[1])).tolist())
        elif op[0] == "rice_run":
            out.append(r.read_rice_run(len(op[1]), op[2]).tolist())
        else:
            out.append(r.read_bytes(len(op[1])))
    return out


@pytest.mark.parametrize("seed", range(40))
def test_bulk_readers_match_oracle(seed):
    """Every bulk read method returns identical values (and leaves the
    cursor at the identical bit position) on both reader classes."""
    rng = np.random.default_rng(seed)
    blob, ops = _write_stream(rng)
    oracle, fast = BitReader(blob), FastBitReader(blob)
    got_o = _read_stream(oracle, ops)
    got_f = _read_stream(fast, ops)
    assert got_o == got_f
    assert oracle.pos == fast.pos


def test_write_run_matches_looped_writes():
    """BitWriter.write_run emits the exact bits of the equivalent write
    loop at any alignment, width, and run length (incl. the short-run
    scalar path and the >= 512-bit packed-numpy path)."""
    rng = np.random.default_rng(7)
    for misalign in (0, 1, 3, 7):
        for nbits in (1, 5, 8, 13, 31, 62):
            for n in (0, 1, 17, 600):
                vals = rng.integers(0, 1 << min(nbits, 62), n)
                w1, w2 = BitWriter(), BitWriter()
                for w in (w1, w2):
                    w.write(0b1011011 & ((1 << misalign) - 1) if misalign
                            else 0, max(misalign, 1))
                w1.write_run(vals, nbits)
                for v in vals:
                    w2.write(int(v), nbits)
                assert w1.getvalue() == w2.getvalue(), (misalign, nbits, n)


def test_varint_run_int64_boundary():
    """The vectorized path is exact through the full int64 range (9 LEB
    chunks); values past it raise OverflowError from both readers instead
    of silently truncating (run reads carry int64 arrays by contract —
    scalar read_varint still handles arbitrary magnitude)."""
    vals = [0, 1, 2**62, 2**63 - 1, 5]
    w = BitWriter()
    for v in vals:
        w.write_varint(v)
    assert FastBitReader(w.getvalue()).read_varint_run(len(vals)).tolist() \
        == BitReader(w.getvalue()).read_varint_run(len(vals)).tolist() == vals

    w = BitWriter()
    for v in (1, 2**63, 2):                    # 2**63 needs a 10th chunk
        w.write_varint(v)
    for reader in (BitReader, FastBitReader):
        with pytest.raises(OverflowError):
            reader(w.getvalue()).read_varint_run(3)
    assert BitReader(w.getvalue()).read_varint() == 1  # scalar path is fine


def test_rice_run_window_growth():
    """Rice runs whose unary parts overflow the initial scan window (huge
    quotients) still decode exactly via the window-doubling path."""
    vals = [50_000, 0, 123_456, 7, 99_999]
    for b in (0, 2, 7):
        w = BitWriter()
        for v in vals:
            w.write_rice(v, b)
        got = FastBitReader(w.getvalue()).read_rice_run(len(vals), b)
        assert got.tolist() == vals


def test_truncated_run_raises():
    """Asking for more varints than the stream holds raises instead of
    fabricating values."""
    w = BitWriter()
    w.write_varint(5)
    with pytest.raises(ValueError):
        FastBitReader(w.getvalue()).read_varint_run(3)


# --------------------------------------------------- full synopsis equivalence

def _mk_hist(rng, k):
    edges = np.unique(rng.choice(200, k + 1, replace=False)).astype(float)
    k = edges.size - 1
    h = rng.integers(0, 500, k).astype(float)
    u = np.minimum(rng.integers(0, 50, k), h).astype(float)
    vmin = edges[:-1].copy()
    vmax = np.minimum(edges[1:], vmin + rng.integers(0, 3, k))
    c = 0.5 * (vmin + vmax)
    return Hist1D(edges=edges, k=np.int32(k), h=h, u=u, vmin=vmin, vmax=vmax,
                  c=c, cminus=c, cplus=c)


def _mk_pair(rng, hx_hist, hy_hist, all_zero):
    kx, ky = int(hx_hist.k), int(hy_hist.k)
    H = (np.zeros((kx, ky)) if all_zero
         else rng.integers(0, 100, (kx, ky)).astype(float))
    if not all_zero:                       # force sparse/dense boundary mix
        H[rng.random((kx, ky)) < 0.6] = 0.0
    return PairHist(
        ex=hx_hist.edges.copy(), ey=hy_hist.edges.copy(),
        kx=np.int32(kx), ky=np.int32(ky), H=H,
        hx=H.sum(1), ux=hx_hist.u[:kx].copy(),
        vminx=hx_hist.vmin.copy(), vmaxx=hx_hist.vmax.copy(),
        hy=H.sum(0), uy=hy_hist.u[:ky].copy(),
        vminy=hy_hist.vmin.copy(), vmaxy=hy_hist.vmax.copy(),
        fold_x=np.zeros(kx, np.int32), fold_y=np.zeros(ky, np.int32))


def _mk_synopsis(seed, d, zero_pairs, single_bin):
    rng = np.random.default_rng(seed)
    kinds = ["int", "float", "categorical"]
    columns = [
        ColumnInfo(name=f"c{i}", kind=kinds[i % 3],
                   offset=float(rng.integers(0, 100)),
                   scale=float(10 ** rng.integers(0, 3)),
                   categories=(("a", "b")[: rng.integers(1, 3)]
                               if kinds[i % 3] == "categorical" else ()),
                   n_null=int(rng.integers(0, 10)),
                   mu=float(rng.integers(1, 5)))
        for i in range(d)
    ]
    hists = [_mk_hist(rng, 1 if single_bin else int(rng.integers(1, 12)))
             for _ in range(d)]
    pairs = {(i, j): _mk_pair(rng, hists[i], hists[j], zero_pairs)
             for i in range(d) for j in range(i + 1, d)}
    params = BuildParams(n_samples=1000, m_frac=0.01, alpha=0.001,
                         s1_max=16, s2_max=8)
    return PairwiseHist(params=params, n_rows=5000, n_sampled=1000,
                        columns=columns, hists=hists, pairs=pairs,
                        chi2_table=np.zeros(17))


def _assert_decodes_equal(a, b):
    assert (a.n_rows, a.n_sampled, a.d) == (b.n_rows, b.n_sampled, b.d)
    for c1, c2 in zip(a.columns, b.columns):
        assert (c1.name, c1.kind, c1.offset, c1.scale, c1.categories,
                c1.n_null, c1.mu) == (c2.name, c2.kind, c2.offset, c2.scale,
                                      c2.categories, c2.n_null, c2.mu)
    for h1, h2 in zip(a.hists, b.hists):
        for f in ("edges", "h", "u", "vmin", "vmax", "c", "cminus", "cplus"):
            v1, v2 = getattr(h1, f), getattr(h2, f)
            assert np.asarray(v1).tobytes() == np.asarray(v2).tobytes(), f
    assert set(a.pairs) == set(b.pairs)
    for key, p1 in a.pairs.items():
        p2 = b.pairs[key]
        for f in ("ex", "ey", "H", "hx", "hy", "ux", "uy",
                  "vminx", "vmaxx", "vminy", "vmaxy", "fold_x", "fold_y"):
            v1, v2 = getattr(p1, f), getattr(p2, f)
            assert np.asarray(v1).tobytes() == np.asarray(v2).tobytes(), f
    assert a.chi2_table.tobytes() == b.chi2_table.tobytes()


@pytest.mark.parametrize("seed,d,zero_pairs,single_bin", [
    (0, 1, False, False), (1, 3, False, False), (2, 4, False, False),
    (3, 3, True, False), (4, 2, False, True), (5, 4, True, True),
    (6, 2, True, False), (7, 1, False, True),
])
def test_full_decode_bit_for_bit(seed, d, zero_pairs, single_bin):
    """decode(blob) [FastBitReader] == decode(blob, vectorized=False)
    [BitReader oracle] with every stored field byte-identical, across the
    adversarial corpus: dense/sparse count flips, all-zero pair counts,
    single-bin histograms, mixed column kinds."""
    blob = encode(_mk_synopsis(seed, d, zero_pairs, single_bin))
    _assert_decodes_equal(decode(blob, vectorized=False), decode(blob))
