"""Deterministic fault injection: FaultPlan scheduling semantics, worker
supervision, per-query deadlines, execution retry/quarantine containment,
cold-tier decode resilience, and a seeded mini-chaos run asserting the
serving invariants (every future resolves — typed error or correct answer,
never a hang; exactly-once; bit-identical retried-through answers)."""
import time

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core import storage
from repro.core.types import BuildParams
from repro.serve.aqp import (AQPServer, DeadlineExceeded, QueryError,
                             TableQuarantinedError, faults)
from repro.serve.aqp.faults import FaultPlan, InjectedFault

TIMEOUT = 30


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """A test that dies mid-``installed`` must not poison its neighbours."""
    yield
    faults.clear()


def _make_table(n=6_000, seed=11):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
    }


@pytest.fixture(scope="module")
def framework():
    return AQPFramework(BuildParams(n_samples=3_000, seed=5),
                        use_compression=False).ingest(_make_table())


@pytest.fixture(scope="module")
def blob(framework):
    return storage.encode(framework.engine.ph)


def _server(framework, **kwargs):
    kwargs.setdefault("mode", "numpy")
    return AQPServer(**kwargs).register("t", framework)


# ------------------------------------------------------------ FaultPlan unit


def test_plan_at_schedule_fires_exact_indices():
    plan = FaultPlan().fail("s", at=[1, 3])
    fired = []
    for i in range(5):
        try:
            plan.fire("s")
        except InjectedFault as exc:
            fired.append(exc.index)
            assert exc.site == "s"
    assert fired == [1, 3]
    assert plan.count("s") == 5
    assert plan.injected("s") == 2


def test_plan_first_and_every_schedules():
    plan = FaultPlan().fail("f", first=2).fail("e", every=3)
    f = [i for i in range(6) if _fires(plan, "f")]
    e = [i for i in range(9) if _fires(plan, "e")]
    assert f == [0, 1]
    assert e == [2, 5, 8]          # every=3 -> indices 2, 5, 8 (1-based 3rd)


def _fires(plan, site):
    try:
        plan.fire(site)
    except InjectedFault:
        return True
    return False


def test_plan_rate_is_deterministic_under_seed():
    a = FaultPlan(seed=7).fail("k", rate=0.3)
    b = FaultPlan(seed=7).fail("k", rate=0.3)
    sched_a = [_fires(a, "k") for _ in range(200)]
    sched_b = [_fires(b, "k") for _ in range(200)]
    assert sched_a == sched_b
    assert 20 < sum(sched_a) < 120  # actually probabilistic, not degenerate
    c = FaultPlan(seed=8).fail("k", rate=0.3)
    assert [_fires(c, "k") for _ in range(200)] != sched_a


def test_plan_action_injects_without_raising():
    stalls = []
    plan = FaultPlan().fail("w", at=[0], action=lambda: stalls.append(1))
    plan.fire("w")
    plan.fire("w")
    assert stalls == [1]
    assert plan.injected("w") == 1


def test_plan_custom_exception_factory():
    plan = FaultPlan().fail("d", at=[0],
                            exc=lambda site, i: OSError(f"{site}@{i}"))
    with pytest.raises(OSError, match="d@0"):
        plan.fire("d")


def test_installed_restores_previous_plan():
    assert faults.active() is None
    outer = FaultPlan()
    with faults.installed(outer):
        assert faults.active() is outer
        with faults.installed(FaultPlan()) as inner:
            assert faults.active() is inner
        assert faults.active() is outer
    assert faults.active() is None
    faults.hook("anything")        # no plan: must be a silent no-op


def test_snapshot_reports_counts_and_injections():
    plan = FaultPlan().fail("x", at=[0])
    _fires(plan, "x")
    _fires(plan, "x")
    snap = plan.snapshot()
    assert snap["counts"] == {"x": 2}
    assert snap["injected"] == {"x": 1}


# ------------------------------------------------- wave retry and quarantine


def test_wave_fault_retries_to_bit_identical_answer(framework):
    sql = "SELECT COUNT(a) FROM t WHERE b > 95"
    control = _server(framework)
    want = control.query(sql).as_tuple()
    control.close()

    srv = _server(framework)
    with faults.installed(FaultPlan().fail("wave_execute", at=[0])):
        res = srv.query(sql)
    assert res.failed is False
    assert res.as_tuple() == want
    flt = srv.stats()["totals"]["faults"]
    assert flt["exec_retries"] == 1
    assert flt["query_errors"] == 0
    srv.close()


def test_poison_query_quarantines_then_recovers(framework):
    sql = "SELECT COUNT(a) FROM t WHERE b > 96"
    srv = _server(framework)
    with faults.installed(FaultPlan().fail("wave_execute", at=[0, 1])):
        res = srv.query(sql)
    assert isinstance(res, QueryError)
    assert res.failed and res.kind == "execution" and res.retries == 2
    assert "injected fault" in res.error
    # Re-submission is refused from quarantine without touching the wave
    # path (no fault plan installed any more, yet it still fails typed).
    res2 = srv.query(sql)
    assert isinstance(res2, QueryError) and res2.kind == "quarantined"
    q = srv.quarantined()
    assert len(q) == 1 and next(iter(q.values()))["table"] == "t"
    flt = srv.stats()["totals"]["faults"]
    assert flt["quarantined"] >= 1 and flt["query_errors"] >= 2
    # clear_quarantine gives the statement a fresh chance; it now answers.
    srv.clear_quarantine(sql)
    assert srv.quarantined() == {}
    assert srv.query(sql).failed is False
    srv.close()


def test_wave_fault_does_not_poison_neighbours(framework):
    """One wave-level crash retries EVERY submission of the wave and all of
    them answer; exactly-once holds (no duplicate or lost resolution)."""
    srv = _server(framework, max_wait_ms=10_000.0)
    control = _server(framework)
    sqls = [f"SELECT COUNT(a) FROM t WHERE b > {90 + i}" for i in range(4)]
    want = [control.query(s).as_tuple() for s in sqls]
    control.close()
    with faults.installed(FaultPlan().fail("wave_execute", at=[0])):
        futs = [srv.submit(s) for s in sqls]
        srv.flush()
        got = [f.result(timeout=TIMEOUT) for f in futs]
    assert [r.as_tuple() for r in got] == want
    srv.close()


def test_kernel_fault_isolates_to_per_item_fallback(framework):
    """A fused-launch fault must not fail the wave: the scheduler's
    isolation path re-runs items one by one (below min_group, so no second
    fused launch) and every answer is still correct — bit-identical to the
    numpy control, because the fallback IS the numpy path."""
    srv = _server(framework, mode="ref", max_wait_ms=10_000.0)
    control = _server(framework)
    sqls = [f"SELECT COUNT(a) FROM t WHERE b > {80 + i}" for i in range(3)]
    want = [control.query(s).as_tuple() for s in sqls]
    control.close()
    with faults.installed(FaultPlan().fail("kernel_launch", every=1)) as plan:
        futs = [srv.submit(s) for s in sqls]
        srv.flush()
        got = [f.result(timeout=TIMEOUT) for f in futs]
        assert plan.injected("kernel_launch") >= 1
    assert [r.as_tuple() for r in got] == want
    flt = srv.stats()["totals"]["faults"]
    assert flt["query_errors"] == 0    # isolation, not failure
    srv.close()


def test_planner_fault_raises_typed_on_future(framework):
    srv = _server(framework)
    with faults.installed(FaultPlan().fail("planner", at=[0])):
        fut = srv.submit("SELECT COUNT(a) FROM t WHERE b > 97")
        srv.flush()
        with pytest.raises(InjectedFault):
            fut.result(timeout=TIMEOUT)
    # The plan error resolved the future immediately; nothing leaked into
    # the quarantine (plan errors keep exception semantics).
    assert srv.quarantined() == {}
    srv.close()


# ------------------------------------------------------- worker supervision


def test_worker_crash_restarts_and_answers(framework):
    sql = "SELECT COUNT(a) FROM t WHERE b > 98"
    control = _server(framework)
    want = control.query(sql).as_tuple()
    control.close()
    srv = _server(framework)
    with faults.installed(FaultPlan().fail("worker", at=[0])) as plan:
        fut = srv.submit(sql)
        srv.flush()
        res = fut.result(timeout=TIMEOUT)
        assert plan.injected("worker") == 1
    assert res.as_tuple() == want      # exactly-once: re-queued, not lost
    assert srv.admission.restarts == 1
    assert srv.stats()["totals"]["faults"]["worker_restarts"] == 1
    srv.close()


# ---------------------------------------------------------------- deadlines


def test_deadline_expired_resolves_typed_within_bound(framework):
    """A submission whose deadline passes while the wave ahead of it stalls
    resolves with DeadlineExceeded — within 2x the deadline, never a hang —
    and skips the fused launch entirely."""
    srv = _server(framework, max_wait_ms=10_000.0)
    stall = 0.12
    plan = FaultPlan().fail("wave_execute", at=[0],
                            action=lambda: time.sleep(stall))
    with faults.installed(plan):
        t0 = time.perf_counter()
        slow = srv.submit("SELECT COUNT(a) FROM t WHERE b > 99")
        doomed = srv.submit("SELECT COUNT(a) FROM t WHERE b > 100",
                            deadline_ms=100.0)
        srv.flush()
        res = doomed.result(timeout=TIMEOUT)
        waited = time.perf_counter() - t0
    assert isinstance(res, DeadlineExceeded)
    assert res.expired and res.failed is False
    assert res.deadline_ms == pytest.approx(100.0)
    assert res.elapsed_ms >= 100.0
    assert waited < 2 * 0.1 + 0.05     # 2x deadline (+sched slack)
    assert slow.result(timeout=TIMEOUT).estimate is not None
    assert srv.stats()["totals"]["faults"]["deadline_expired"] == 1
    srv.close()


def test_deadline_wakes_drain_before_max_wait(framework):
    """With a huge max_wait the drain must still wake for an imminent
    deadline: the query answers (not expires) long before max_wait."""
    srv = _server(framework, max_wait_ms=30_000.0)
    t0 = time.perf_counter()
    fut = srv.submit("SELECT COUNT(a) FROM t WHERE b > 101",
                     deadline_ms=200.0)
    res = fut.result(timeout=TIMEOUT)   # NO flush: the deadline wakes it
    waited = time.perf_counter() - t0
    assert res.expired is False and res.estimate is not None
    assert waited < 5.0
    adm = srv.stats()["totals"]["admission"]
    assert adm["drain_causes"].get("deadline", 0) >= 1
    srv.close()


def test_deadline_queries_skip_dedupe(framework):
    """Deadline-carrying submissions never share a dedupe entry: the same
    text without a deadline keeps its own contract."""
    srv = _server(framework, max_wait_ms=10_000.0)
    sql = "SELECT COUNT(a) FROM t WHERE b > 102"
    a = srv.submit(sql, deadline_ms=60_000.0)
    b = srv.submit(sql)
    srv.flush()
    ra = a.result(timeout=TIMEOUT)
    rb = b.result(timeout=TIMEOUT)
    assert ra.as_tuple() == rb.as_tuple()
    srv.close()


# ------------------------------------------------------- cold-tier resilience


def test_cold_decode_retry_recovers(framework, blob):
    srv = AQPServer(mode="numpy")
    srv.register_cold("c", blob, decode_retries=1, decode_backoff_s=0.001)
    with faults.installed(FaultPlan().fail("cold_decode", at=[0])) as plan:
        res = srv.query("SELECT COUNT(a) FROM c WHERE b > 95")
        assert plan.count("cold_decode") == 2
    assert res.failed is False and res.estimate is not None
    flt = srv.stats()["totals"]["faults"]
    assert flt["decode_retries"] == 1 and flt["quarantined"] == 0
    srv.close()


def test_cold_decode_exhaustion_quarantines_table(framework, blob):
    srv = AQPServer(mode="numpy")
    srv.register_cold("c", blob, decode_retries=1, decode_backoff_s=0.001)
    with faults.installed(FaultPlan().fail("cold_decode", first=2)) as plan:
        fut = srv.submit("SELECT COUNT(a) FROM c WHERE b > 96")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut.result(timeout=TIMEOUT)
        n = plan.count("cold_decode")
        # Circuit breaker: the next query fails fast with NO fresh decode
        # attempt (typed, immediate — never a hang).
        fut2 = srv.submit("SELECT COUNT(a) FROM c WHERE b > 97")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut2.result(timeout=TIMEOUT)
        assert plan.count("cold_decode") == n
    ct = srv.catalog.resolve("c")
    assert ct.quarantined and ct.decode_failures == 2
    assert srv.stats()["totals"]["faults"]["quarantined"] >= 1
    # Re-registering the blob clears the breaker; the table serves again.
    srv.register_cold("c", blob)
    assert srv.query("SELECT COUNT(a) FROM c WHERE b > 96").failed is False
    srv.close()


def test_cold_breaker_half_opens_after_reset(framework, blob):
    srv = AQPServer(mode="numpy")
    srv.register_cold("c", blob, decode_retries=0, decode_backoff_s=0.001,
                      breaker_reset_s=0.05)
    with faults.installed(FaultPlan().fail("cold_decode", at=[0])):
        fut = srv.submit("SELECT COUNT(a) FROM c WHERE b > 98")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut.result(timeout=TIMEOUT)
        assert srv.catalog.resolve("c").quarantined
        time.sleep(0.06)               # breaker half-opens; index 1 passes
        res = srv.query("SELECT COUNT(a) FROM c WHERE b > 99")
    assert res.failed is False and res.estimate is not None
    assert not srv.catalog.resolve("c").quarantined
    srv.close()


def test_cold_reset_faults_reopens_without_reregister(framework, blob):
    srv = AQPServer(mode="numpy")
    srv.register_cold("c", blob, decode_retries=0, decode_backoff_s=0.001)
    with faults.installed(FaultPlan().fail("blob_read", at=[0])):
        fut = srv.submit("SELECT COUNT(a) FROM c WHERE b > 100")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut.result(timeout=TIMEOUT)
        srv.catalog.resolve("c").reset_faults()
        res = srv.query("SELECT COUNT(a) FROM c WHERE b > 101")
    assert res.failed is False
    srv.close()


def test_demoted_table_quarantine_is_typed_not_hang(framework, blob):
    """Decode failure at execution time (table demoted, plan cached) goes
    through exec containment: typed QueryError(kind='quarantined'), no
    wasted retry against the open breaker."""
    srv = AQPServer(mode="numpy")
    srv.register_cold("c", blob, decode_retries=0, decode_backoff_s=0.001)
    assert srv.query("SELECT COUNT(a) FROM c WHERE b > 95").failed is False
    assert srv.demote("c")
    # New text: the cached result for the first query must not satisfy it.
    with faults.installed(FaultPlan().fail("cold_decode", first=8)):
        res = srv.query("SELECT COUNT(a) FROM c WHERE b > 94")
    assert isinstance(res, QueryError) and res.kind == "quarantined"
    srv.close()


# ------------------------------------------------------------ seeded chaos


def test_mini_chaos_every_future_resolves(framework):
    """Seeded multi-site chaos: every future resolves (correct answer or
    typed result, never a hang), retried-through answers are bit-identical
    to an undisturbed control, and the admission queue stays bounded."""
    sqls = [f"SELECT COUNT(a) FROM t WHERE b > {60 + i}" for i in range(24)]
    control = _server(framework)
    want = {s: control.query(s).as_tuple() for s in sqls}
    control.close()

    srv = _server(framework, max_wait_ms=20.0, max_batch=8)
    plan = (FaultPlan(seed=3)
            .fail("wave_execute", rate=0.15)
            .fail("kernel_launch", rate=0.15)
            .fail("worker", at=[2]))
    with faults.installed(plan):
        futs = [srv.submit(s) for s in sqls]
        srv.flush()
        got = [f.result(timeout=TIMEOUT) for f in futs]
    ok = failed = 0
    for sql, res in zip(sqls, got):
        if isinstance(res, QueryError):
            failed += 1
            assert res.kind in ("execution", "quarantined")
        else:
            ok += 1
            assert res.as_tuple() == want[sql]
    assert ok + failed == len(sqls)    # exactly-once: all resolved
    assert ok > 0
    flt = srv.stats()["totals"]["faults"]
    assert flt["query_errors"] == failed
    adm = srv.stats()["totals"]["admission"]
    # Bounded depth: requeues/retries never balloon the queue past the
    # original submission count.
    assert adm["max_queue_depth"] <= len(sqls)
    srv.close()
