"""Training loop: resume determinism, corruption recovery, compression,
telemetry."""
import dataclasses
import os
import shutil

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.train.loop import InjectedFailure, train
from repro.train.optimizer import Hyper


def _cfg():
    return dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                               dtype="float32")


HYPER = Hyper(lr=1e-3, warmup_steps=5, total_steps=40)


def test_crash_resume_bitwise_identical(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    s1, h1 = train(_cfg(), HYPER, steps=12, batch=4, seq=64, ckpt_dir=d1,
                   ckpt_every=4, verbose=False)
    with pytest.raises(InjectedFailure):
        train(_cfg(), HYPER, steps=12, batch=4, seq=64, ckpt_dir=d2,
              ckpt_every=4, fail_at_step=7, verbose=False)
    s2, h2 = train(_cfg(), HYPER, steps=12, batch=4, seq=64, ckpt_dir=d2,
                   ckpt_every=4, verbose=False)
    assert int(s1.step) == int(s2.step) == 12
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_checkpoint_skip_back(tmp_path):
    d = str(tmp_path / "c")
    train(_cfg(), HYPER, steps=8, batch=4, seq=64, ckpt_dir=d, ckpt_every=3,
          verbose=False)
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(d)
    steps = mgr.all_steps()
    assert len(steps) >= 2
    # Corrupt the newest checkpoint's first array file.
    newest = os.path.join(d, f"step_{steps[-1]:010d}")
    victim = next(f for f in os.listdir(newest) if f.endswith(".npy"))
    with open(os.path.join(newest, victim), "r+b") as fh:
        fh.seek(100)
        fh.write(b"\xde\xad\xbe\xef")
    from repro.train.step import init_train_state
    like = init_train_state(_cfg(), jax.random.PRNGKey(0))
    step, state = mgr.restore(like)
    assert step == steps[-2]  # skipped back past the corrupt one


def test_loss_decreases(tmp_path):
    _, hist = train(_cfg(), HYPER, steps=30, batch=8, seq=64,
                    ckpt_dir=str(tmp_path / "d"), ckpt_every=100,
                    verbose=False)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.2


def test_grad_compression_error_feedback_converges(tmp_path):
    from repro.train.grad_compress import GDQuantizer
    _, hist = train(_cfg(), HYPER, steps=30, batch=8, seq=64,
                    ckpt_dir=str(tmp_path / "e"), ckpt_every=100,
                    compressor=GDQuantizer(bits=8), verbose=False)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.2  # compression must not break convergence


def test_microbatch_accumulation_matches_full_batch():
    import jax.numpy as jnp
    from repro.train.step import init_train_state, make_train_step
    from repro.data.pipeline import TokenPipeline
    cfg = _cfg()
    pipe = TokenPipeline(cfg.vocab, 8, 64, seed=1)
    batch = pipe.host_slice(0)
    s0 = init_train_state(cfg, jax.random.PRNGKey(0))
    full = jax.jit(make_train_step(cfg, HYPER, microbatches=1))
    micro = jax.jit(make_train_step(cfg, HYPER, microbatches=4))
    s1, m1 = full(s0, batch)
    s2, m2 = micro(s0, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_telemetry_aqp_queries():
    from repro.train.telemetry import TelemetryStore
    from repro.core.types import BuildParams
    rng = np.random.default_rng(0)
    tel = TelemetryStore(BuildParams(n_samples=5000))
    for step in range(5000):
        host = f"host{step % 4}"
        base = 0.1 if host != "host3" else 0.25   # host3 is a straggler
        tel.record(step=step, loss=3.0 - step * 1e-4,
                   grad_norm=float(rng.random()),
                   step_time=base + rng.random() * 0.01, host=host)
    res = tel.query("SELECT AVG(step_time) FROM t WHERE host = 'host3'")
    assert abs(res.estimate - 0.255) < 0.01
    # loss is a *deterministic uniform* function of step: both marginals are
    # uniform, so the paper's per-dimension uniformity test never splits the
    # pair — a structural blind spot of RefineBin2D (DESIGN.md §7.6). The
    # estimate degrades gracefully to ~8% instead of <1%.
    res2 = tel.query("SELECT AVG(loss) FROM t WHERE step > 4000")
    exact2 = 3.0 - 4500 * 1e-4
    assert abs(res2.estimate - exact2) / exact2 < 0.12
    stragglers = tel.straggler_report()
    assert "host3" in stragglers
