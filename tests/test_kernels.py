"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hist2d import batched_hist2d, hist2d
from repro.kernels.hist2d.ref import batched_hist2d_ref, hist2d_ref
from repro.kernels.subbin import batched_subbin_hist
from repro.kernels.subbin.ref import batched_subbin_hist_ref
from repro.kernels.weightings import batched_weightings, fused_weightings
from repro.kernels.weightings.ref import (batched_weightings_ref,
                                          fused_weightings_ref)


@pytest.mark.parametrize("n,ki,kj", [
    (100, 8, 8), (1000, 37, 53), (4096, 128, 256), (2048, 300, 17),
    (1024, 512, 512),
])
def test_hist2d_matches_ref(n, ki, kj):
    rng = np.random.default_rng(n + ki)
    bi = rng.integers(0, ki, n).astype(np.int32)
    bj = rng.integers(0, kj, n).astype(np.int32)
    w = rng.random(n).astype(np.float32)
    out = hist2d(bi, bj, w, ki, kj)
    ref = hist2d_ref(jnp.asarray(bi), jnp.asarray(bj), jnp.asarray(w), ki, kj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("wdtype", [np.float32, np.float64, np.int32])
def test_hist2d_weight_dtypes(wdtype):
    rng = np.random.default_rng(0)
    n, ki, kj = 500, 16, 16
    bi = rng.integers(0, ki, n).astype(np.int32)
    bj = rng.integers(0, kj, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(wdtype)
    out = hist2d(bi, bj, w, ki, kj)
    ref = hist2d_ref(jnp.asarray(bi), jnp.asarray(bj),
                     jnp.asarray(w, jnp.float32), ki, kj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    assert float(out.sum()) == pytest.approx(float(w.sum()))


@pytest.mark.parametrize("p,n,ki,kj", [
    (1, 100, 8, 8), (3, 500, 37, 53), (2, 2048, 128, 256), (4, 1000, 300, 17),
])
def test_batched_hist2d_matches_ref(p, n, ki, kj):
    """Pair-batched Pallas kernel == oracle == per-pair single kernel."""
    rng = np.random.default_rng(p * n + ki)
    bi = rng.integers(0, ki, (p, n)).astype(np.int32)
    bj = rng.integers(0, kj, (p, n)).astype(np.int32)
    w = rng.random((p, n)).astype(np.float32)
    out = batched_hist2d(bi, bj, w, ki, kj, use_pallas=True)
    ref = batched_hist2d_ref(jnp.asarray(bi), jnp.asarray(bj),
                             jnp.asarray(w), ki, kj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    for pi in range(p):
        single = hist2d_ref(jnp.asarray(bi[pi]), jnp.asarray(bj[pi]),
                            jnp.asarray(w[pi]), ki, kj)
        np.testing.assert_allclose(np.asarray(out)[pi], np.asarray(single),
                                   rtol=1e-5, atol=1e-5)


def test_batched_hist2d_integer_counts_exact():
    """Construction feeds f64 ones/flags: counts must be exact integers and
    identical between the Pallas path (f32 accumulate) and the f64 oracle."""
    import repro.core  # noqa: F401  (enables jax x64 for the f64 oracle)
    rng = np.random.default_rng(1)
    p, n, k = 3, 4000, 24
    bi = rng.integers(0, k, (p, n)).astype(np.int32)
    bj = rng.integers(0, k, (p, n)).astype(np.int32)
    w = (rng.random((p, n)) < 0.9).astype(np.float64)  # 0/1 validity weights
    pal = np.asarray(batched_hist2d(bi, bj, w, k, k, use_pallas=True))
    ora = np.asarray(batched_hist2d(bi, bj, w, k, k, use_pallas=False))
    np.testing.assert_array_equal(pal, ora)
    assert ora.dtype == np.float64
    np.testing.assert_array_equal(ora, np.round(ora))
    assert float(ora.sum()) == float(w.sum())


@pytest.mark.parametrize("p,n,ncell,s_max", [
    (1, 100, 9, 8), (3, 500, 64, 16), (2, 2048, 256, 32), (4, 1000, 100, 5),
])
def test_batched_subbin_hist_matches_ref(p, n, ncell, s_max):
    """Sub-bin Pallas kernel (base-128 flat-id one-hot matmul) == oracle."""
    rng = np.random.default_rng(p * n + ncell)
    cell = rng.integers(0, ncell, (p, n)).astype(np.int32)
    sub = rng.integers(0, s_max, (p, n)).astype(np.int32)
    w = rng.random((p, n)).astype(np.float32)
    out = batched_subbin_hist(cell, sub, w, ncell, s_max, use_pallas=True)
    ref = batched_subbin_hist_ref(jnp.asarray(cell), jnp.asarray(sub),
                                  jnp.asarray(w), ncell, s_max)
    assert out.shape == (p, ncell, s_max)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_batched_subbin_hist_integer_counts_exact():
    """Refinement feeds f64 validity weights: counts must be exact integers
    and identical between the Pallas path (f32 accumulate) and the
    dtype-preserving segment-sum oracle; masked rows contribute nothing."""
    import repro.core  # noqa: F401  (enables jax x64 for the f64 oracle)
    rng = np.random.default_rng(1)
    p, n, ncell, s_max = 3, 4000, 64, 16
    cell = rng.integers(0, ncell, (p, n)).astype(np.int32)
    sub = rng.integers(0, s_max, (p, n)).astype(np.int32)
    w = (rng.random((p, n)) < 0.9).astype(np.float64)  # 0/1 validity weights
    pal = np.asarray(batched_subbin_hist(cell, sub, w, ncell, s_max,
                                         use_pallas=True))
    ora = np.asarray(batched_subbin_hist(cell, sub, w, ncell, s_max,
                                         use_pallas=False))
    np.testing.assert_array_equal(pal, ora)
    assert ora.dtype == np.float64
    np.testing.assert_array_equal(ora, np.round(ora))
    assert float(ora.sum()) == float(w.sum())
    # last-axis sum reproduces per-cell totals (the h_cell contract the
    # refinement loop relies on)
    totals = np.zeros((p, ncell))
    for pi in range(p):
        np.add.at(totals[pi], cell[pi], w[pi])
    np.testing.assert_array_equal(ora.sum(axis=2), totals)


def test_subbin_counts_matches_inline_scatter():
    """chi2.subbin_counts (kernel-backed) == the legacy in-loop masked
    segment_sum formulation, bit for bit, including null rows and
    zero-width (constant) cells."""
    import repro.core  # noqa: F401
    from repro.core import chi2 as chi2lib
    import jax
    rng = np.random.default_rng(4)
    p, n, k2, s_max = 2, 3000, 8, 16
    ncell = k2 * k2
    vals = jnp.asarray(rng.uniform(0, 100, (p, n)))
    lo = jnp.asarray(np.floor(rng.uniform(0, 50, (p, n))))
    width = jnp.asarray(rng.choice([0.0, 25.0, 50.0], (p, n)))
    cell = jnp.asarray(rng.integers(0, ncell, (p, n)), jnp.int32)
    u = jnp.asarray(rng.integers(0, 40, (p, ncell)).astype(np.float64))
    s = chi2lib.num_subbins(u, s_max)
    valid = jnp.asarray(rng.random((p, n)) < 0.9)

    got = chi2lib.subbin_counts(vals, lo, width, cell, s, valid,
                                ncell=ncell, s_max=s_max, use_pallas=False)

    s_pt = jnp.take_along_axis(s, cell, axis=1)
    frac = jnp.where(width > 0, (vals - lo) / width, 0.0)
    r = jnp.clip((frac * s_pt).astype(jnp.int32), 0, s_pt - 1)
    flat = jnp.where(valid, cell * s_max + r, ncell * s_max)
    ones = jnp.ones_like(vals)
    hbar = jax.vmap(lambda f, o: jax.ops.segment_sum(
        o, f, num_segments=ncell * s_max + 1))(flat, ones)
    want = hbar[:, :-1].reshape(p, ncell, s_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("el,k2,k1", [
    (1, 16, 16), (3, 64, 80), (5, 200, 260), (2, 128, 128), (4, 384, 400),
])
def test_fused_weightings_matches_ref(el, k2, k1):
    rng = np.random.default_rng(el * k2)
    H = (rng.random((el, k2, k2)) * 10).astype(np.float32)
    beta = rng.random((el, k2)).astype(np.float32)
    hx = H.sum(2) + 1.0
    fold = np.zeros((el, k1, k2), np.float32)
    idx = np.sort(rng.integers(0, k2, k1))   # 1-D bin -> containing row
    for li in range(el):
        fold[li, np.arange(k1), idx] = 1
    out = fused_weightings(H, beta, fold, hx)
    ref = fused_weightings_ref(jnp.asarray(H), jnp.asarray(beta),
                               jnp.asarray(fold), jnp.asarray(hx))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,el,k2,k1", [
    (1, 1, 16, 16), (5, 3, 70, 90), (17, 2, 200, 260), (64, 4, 128, 128),
])
def test_batched_weightings_matches_per_query(q, el, k2, k1):
    """Query-batched kernel == per-query oracle, row by row, for both the
    Pallas path and the jitted-jnp path."""
    rng = np.random.default_rng(q * k2 + el)
    H = (rng.random((el, k2, k2)) * 10).astype(np.float32)
    hx = H.sum(2) + 1.0
    fold = np.zeros((el, k1, k2), np.float32)
    idx = np.sort(rng.integers(0, k2, k1))
    for li in range(el):
        fold[li, np.arange(k1), idx] = 1
    beta = rng.random((q, el, k2)).astype(np.float32)
    seq = np.stack([np.asarray(fused_weightings_ref(
        jnp.asarray(H), jnp.asarray(beta[qi]), jnp.asarray(fold),
        jnp.asarray(hx))) for qi in range(q)])
    for use_pallas in (True, False):
        out = np.asarray(batched_weightings(H, beta, fold, hx,
                                            use_pallas=use_pallas))
        assert out.shape == (q, k1)
        np.testing.assert_allclose(out, seq, rtol=1e-5, atol=1e-6)


def test_batched_weightings_ref_reduces_to_single():
    """Q=1 batched ref == single-query ref exactly (same einsum graph)."""
    rng = np.random.default_rng(11)
    el, k2, k1 = 2, 32, 40
    H = rng.random((el, k2, k2)).astype(np.float32)
    hx = H.sum(2) + 1.0
    fold = np.zeros((el, k1, k2), np.float32)
    fold[:, np.arange(k1), np.sort(rng.integers(0, k2, k1))] = 1
    beta = rng.random((1, el, k2)).astype(np.float32)
    one = batched_weightings_ref(jnp.asarray(H), jnp.asarray(beta),
                                 jnp.asarray(fold), jnp.asarray(hx))
    single = fused_weightings_ref(jnp.asarray(H), jnp.asarray(beta[0]),
                                  jnp.asarray(fold), jnp.asarray(hx))
    np.testing.assert_allclose(np.asarray(one[0]), np.asarray(single),
                               rtol=1e-6, atol=1e-7)


def test_fused_weightings_identity_predicate():
    """A beta of all-ones gives probability 1 in every bin."""
    rng = np.random.default_rng(7)
    k2, k1 = 32, 32
    H = rng.integers(0, 5, (1, k2, k2)).astype(np.float32)
    hx = H.sum(2)
    fold = np.zeros((1, k1, k2), np.float32)
    fold[0, np.arange(k1), np.arange(k2)] = 1
    beta = np.ones((1, k2), np.float32)
    out = np.asarray(fused_weightings(H, beta, fold, hx))
    mask = hx[0] > 0
    np.testing.assert_allclose(out[mask], 1.0, rtol=1e-6)


def test_pair_betas_batch_bit_for_bit(synopsis):
    """Vectorized per-leaf beta assembly (_pair_betas_batch) is bit-for-bit
    equal to stacking the per-query _pair_betas calls, across operators,
    out-of-range literals and consolidated interval leaves."""
    from repro.core import weightings as wlib
    from repro.core.fastpath import FastPath
    fp = FastPath(use_pallas=False)
    rng = np.random.default_rng(5)
    agg = 0
    leaf_lists = []
    for qi in range(9):
        lo = float(rng.uniform(100, 500))
        leaves = [
            wlib.Leaf(1, rng.choice(["<", "<=", ">", ">=", "=", "!="]),
                      float(rng.uniform(-50, 700))),
            (wlib.Consolidated(2, [(lo, lo + 200.0)]) if qi % 3 == 0
             else wlib.Leaf(2, str(rng.choice(["<", ">"])),
                            float(rng.uniform(0, 1200)))),
        ]
        leaf_lists.append(leaves)
    k2max = 512
    batched = fp._pair_betas_batch(synopsis, agg, leaf_lists, k2max)
    seq = np.stack([fp._pair_betas(synopsis, agg, pls, k2max)
                    for pls in leaf_lists])
    np.testing.assert_array_equal(batched, seq)


def test_fastpath_batch_equals_single(synopsis):
    """FastPath.batch (one fused launch + vectorized betas) matches the
    per-query FastPath.__call__ triples."""
    from repro.core.fastpath import FastPath
    from repro.core.query import QueryEngine
    fp = FastPath(use_pallas=False)
    eng = QueryEngine(synopsis)
    trees = [eng.plan_sql(f"SELECT COUNT(c0) FROM t WHERE c1 > {200 + 10 * i}"
                          f" AND c2 < {900 - 15 * i}").tree
             for i in range(6)]
    batch = fp.batch(synopsis, 0, trees, corrected=False)
    assert batch is not None
    for tree, triple in zip(trees, batch):
        single = fp(synopsis, 0, tree, corrected=False)
        for got, want in zip(triple, single):
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_fastpath_equals_reference_engine(synopsis):
    from repro.core.fastpath import make_fastpath
    from repro.core.query import QueryEngine
    e_ref = QueryEngine(synopsis)
    e_fast = QueryEngine(synopsis, fastpath=make_fastpath(use_pallas=True))
    for sql in ("SELECT COUNT(c0) FROM t WHERE c1 > 300 AND c2 < 900",
                "SELECT AVG(c2) FROM t WHERE c1 >= 250 AND c1 < 350",
                "SELECT SUM(c1) FROM t WHERE c2 <= 900 AND c0 < 500",
                "SELECT MIN(c1) FROM t WHERE c1 > 100",
                # OR falls back to the reference path inside the engine
                "SELECT AVG(c1) FROM t WHERE c0 < 100 OR c3 = 2"):
        r1, r2 = e_ref.query(sql), e_fast.query(sql)
        np.testing.assert_allclose(r1.as_tuple(), r2.as_tuple(),
                                   rtol=1e-5, atol=1e-6)
