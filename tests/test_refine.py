"""Level-synchronous refinement vs the paper-faithful sequential oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chi2 as chi2lib
from repro.core import ref_sequential, refine


def _bfs_edges(x, init, m_pts, crit):
    K = 384
    xs = np.sort(x)
    up = np.concatenate([[0], np.cumsum(np.concatenate([[True],
                                                        xs[1:] != xs[:-1]]))])
    e0 = np.full(K + 1, np.inf)
    e0[: len(init)] = init
    edges, k = refine.refine_1d(jnp.asarray(xs), jnp.asarray(up),
                                jnp.asarray(e0), jnp.int32(len(init) - 1),
                                jnp.float64(m_pts), jnp.asarray(crit))
    return np.asarray(edges)[: int(k) + 1]


@pytest.mark.parametrize("dist", ["bimodal", "uniform", "zipf", "steps"])
def test_bfs_equals_sequential_recursion(dist):
    rng = np.random.default_rng(11)
    n = 4000
    x = {
        "bimodal": np.where(rng.random(n) < 0.4, rng.normal(50, 3, n),
                            rng.normal(200, 30, n)).round(),
        "uniform": rng.integers(0, 50, n).astype(float),
        "zipf": rng.zipf(1.6, n).clip(1, 500).astype(float),
        "steps": np.repeat(np.arange(8.0) * 100, n // 8)
        + rng.integers(0, 30, n),
    }[dist]
    crit = chi2lib.build_crit_table(0.001, 128)
    m_pts = 40
    init = np.array([x.min(), x.max()], float)
    e_seq, h, u, vmin, vmax = ref_sequential.build_1d_sequential(
        x, init, m_pts, crit)
    e_bfs = _bfs_edges(x, init, m_pts, crit)
    assert e_seq.size == e_bfs.size
    np.testing.assert_allclose(e_seq, e_bfs)


def test_refinement_invariants(synopsis):
    for hist in synopsis.hists:
        k = int(hist.k)
        edges = hist.edges[: k + 1]
        assert np.all(np.diff(edges) >= 0)
        assert np.all(hist.h >= 0)
        assert np.all(hist.u <= np.maximum(hist.h, 1))
        assert np.all(hist.vmin <= hist.vmax + 1e-12)
        assert np.all(hist.vmin >= edges[:-1] - 1e-9)
        assert np.all(hist.vmax <= edges[1:] + 1e-9)
        assert np.all(hist.cminus <= hist.cplus + 1e-12)
        assert np.all(hist.cminus >= hist.vmin - 1e-9)
        assert np.all(hist.cplus <= hist.vmax + 1e-9)


def test_pair_invariants(synopsis):
    for (i, j), pr in synopsis.pairs.items():
        np.testing.assert_allclose(pr.H.sum(1), pr.hx)
        np.testing.assert_allclose(pr.H.sum(0), pr.hy)
        # pair edges are a subset of the union-refined 1-D edges
        e1 = synopsis.hists[i].edges
        assert np.all(np.isin(np.round(pr.ex, 9), np.round(e1, 9)))
        e1j = synopsis.hists[j].edges
        assert np.all(np.isin(np.round(pr.ey, 9), np.round(e1j, 9)))
        # fold maps (1-D bin -> pair row) are monotone and in range
        assert np.all(np.diff(pr.fold_x) >= 0)
        assert np.all(np.diff(pr.fold_y) >= 0)
        assert pr.fold_x.shape[0] == int(synopsis.hists[i].k)
        assert pr.fold_y.shape[0] == int(synopsis.hists[j].k)
        assert pr.fold_x.max() < int(pr.kx)
        assert pr.fold_y.max() < int(pr.ky)


def test_uniform_data_is_not_split():
    rng = np.random.default_rng(5)
    x = rng.integers(0, 1000, 20000).astype(float)
    crit = chi2lib.build_crit_table(0.001, 128)
    e = _bfs_edges(x, np.array([x.min(), x.max()]), 200, crit)
    assert e.size - 1 <= 2  # uniform: essentially no refinement
