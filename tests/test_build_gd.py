"""Differential tests: GD-native construction vs the raw-matrix build.

``build_pairwise_hist`` accepts a ``CompressedTable`` directly: it samples
row *indices* from ``params.seed``, decodes only those rows (bit-exact),
and seeds the 1-D edges from the deduplicated bases. The build is therefore
bit-for-bit identical to the raw build with ``GreedyGD.seed_edges`` passed
in — asserted here field by field — and answers the accuracy corpus of
``test_query_accuracy.py`` within the exact same tolerances. A spy on the
decode path proves the full raw matrix is never materialized.
"""
import numpy as np
import pytest

from repro.core import storage
from repro.core.build import build_pairwise_hist
from repro.core.query import QueryEngine
from repro.core.types import BuildParams
from repro.gd.greedygd import GreedyGD
from repro.gd.preprocess import preprocess_table

from test_query_accuracy import CASES


@pytest.fixture(scope="module")
def gd_setup(small_table):
    pp = preprocess_table(small_table)
    ct = GreedyGD().compress(pp.data)
    return pp, ct


@pytest.fixture(scope="module")
def gd_synopsis(gd_setup):
    pp, ct = gd_setup
    return build_pairwise_hist(ct, pp.columns,
                               BuildParams(n_samples=30_000, seed=3))


def _assert_synopses_identical(a, b):
    assert a.n_rows == b.n_rows and a.n_sampled == b.n_sampled
    for ha, hb in zip(a.hists, b.hists):
        assert int(ha.k) == int(hb.k)
        for field in ("edges", "h", "u", "vmin", "vmax", "c",
                      "cminus", "cplus"):
            assert np.array_equal(getattr(ha, field), getattr(hb, field)), field
    assert set(a.pairs) == set(b.pairs)
    for key, pa in a.pairs.items():
        pb = b.pairs[key]
        for field in ("ex", "ey", "H", "hx", "hy", "ux", "uy", "vminx",
                      "vmaxx", "vminy", "vmaxy", "fold_x", "fold_y"):
            assert np.array_equal(getattr(pa, field), getattr(pb, field)), \
                (key, field)


def test_gd_build_bit_identical_to_raw_seeded(gd_setup, gd_synopsis):
    """Same seed, same sample indices, lossless row decode: the compressed
    build must equal the raw+seed_edges build bit for bit."""
    pp, ct = gd_setup
    raw = build_pairwise_hist(pp.data, pp.columns,
                              BuildParams(n_samples=30_000, seed=3),
                              seed_edges=GreedyGD.seed_edges(ct))
    _assert_synopses_identical(gd_synopsis, raw)
    assert gd_synopsis.build_stats["from_compressed"] is True
    assert raw.build_stats["from_compressed"] is False


@pytest.mark.parametrize("sql,tol_pct", CASES)
def test_gd_build_accuracy_on_corpus(gd_synopsis, exact, sql, tol_pct):
    """The GD-built synopsis answers the accuracy corpus within the same
    tolerances the raw build is held to in test_query_accuracy.py."""
    res = QueryEngine(gd_synopsis).query(sql)
    truth = exact.query(sql)
    assert res.estimate is not None
    err = abs(res.estimate - truth) / max(abs(truth), 1e-9) * 100
    assert err < tol_pct, (sql, res.estimate, truth)


def test_gd_build_decodes_only_the_sample(gd_setup, monkeypatch):
    """Building from a CompressedTable touches exactly the N_s sampled rows
    — never the full matrix, never the full-decode API."""
    pp, ct = gd_setup
    import repro.core.build as buildmod
    calls = []
    real = buildmod.decompress_rows

    def spy(ct_, rows=None):
        calls.append(None if rows is None else len(rows))
        return real(ct_, rows)

    monkeypatch.setattr(buildmod, "decompress_rows", spy)

    def forbid(self, ct_):
        raise AssertionError("full decompress() called during GD-native build")

    monkeypatch.setattr(GreedyGD, "decompress", forbid)
    ph = build_pairwise_hist(ct, pp.columns,
                             BuildParams(n_samples=5000, seed=1))
    assert calls == [5000]
    assert ph.build_stats["rows_decoded"] == 5000 < ct.n_rows
    assert ph.build_stats["from_compressed"] is True


def test_gd_build_storage_roundtrip_bit_exact(gd_synopsis):
    """encode/decode of a GD-built synopsis reproduces every stored field
    (and the re-derived fold maps) exactly."""
    blob = storage.encode(gd_synopsis)
    info = storage.blob_info(blob)
    assert info["bytes"] == len(blob)
    assert info["n_rows"] == gd_synopsis.n_rows
    assert info["d"] == gd_synopsis.d
    ph2 = storage.decode(blob)
    assert ph2.n_rows == gd_synopsis.n_rows
    for h1, h2 in zip(gd_synopsis.hists, ph2.hists):
        for field in ("edges", "h", "u", "vmin", "vmax"):
            assert np.array_equal(getattr(h1, field), getattr(h2, field)), field
    for key, p1 in gd_synopsis.pairs.items():
        p2 = ph2.pairs[key]
        for field in ("ex", "ey", "H", "hx", "hy", "ux", "uy", "vminx",
                      "vmaxx", "vminy", "vmaxy", "fold_x", "fold_y"):
            assert np.array_equal(getattr(p1, field), getattr(p2, field)), \
                (key, field)


def test_ingest_compressed_builds_without_raw(gd_setup):
    """AQPFramework.ingest_compressed: synopsis straight from an
    already-compressed table (the cold catalog's rebuild path)."""
    from repro.aqp.engine import AQPFramework
    pp, ct = gd_setup
    fw = AQPFramework(BuildParams(n_samples=10_000, seed=3))
    fw.ingest_compressed(ct, pp.columns)
    assert fw.preprocessed is None
    assert fw.timings["build_from_compressed"] is True
    res = fw.query("SELECT COUNT(*) FROM t WHERE c1 > 300")
    assert res.estimate is not None and res.estimate > 0


def test_seed_from_bases_off_still_correct(gd_setup):
    """seed_from_bases=False builds from min/max edges only — different
    binning, still a valid synopsis (sanity for the knob)."""
    pp, ct = gd_setup
    ph = build_pairwise_hist(ct, pp.columns,
                             BuildParams(n_samples=10_000, seed=3,
                                         seed_from_bases=False))
    assert ph.build_stats["from_compressed"] is True
    res = QueryEngine(ph).query("SELECT COUNT(*) FROM t WHERE c1 > 300")
    assert res.estimate is not None and res.estimate > 0
