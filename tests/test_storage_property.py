"""Hypothesis property tests for the bit-level storage codecs.

Complements tests/test_storage.py (structural/query-identity roundtrips on
real synopses) with adversarial fuzzing of the codec layer itself: random
bit-IO interleavings, dyadic-exponent boundaries, dense-vs-sparse count
flips, and full encode/decode of synthetic PairwiseHist shapes the builder
would rarely emit (all-zero counts, single-bin histograms).

Exactness caveat: ``_encode_values``'s dyadic path snaps values within 1e-6
of a dyadic grid onto it, so exact-roundtrip assertions use either genuinely
dyadic values (ints / 2**p) or values far from any dyadic grid of exponent
<= 40 (which take the bit-exact f64 fallback).
"""
import math
import struct

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.storage import (BitReader, BitWriter,  # noqa: E402
                                IntegrityError, _decode_counts,
                                _decode_values, _encode_counts,
                                _encode_values, blob_info, decode, encode)
from repro.core.types import (BuildParams, ColumnInfo, Hist1D,  # noqa: E402
                              PairHist, PairwiseHist)


# ------------------------------------------------------------ bit IO fuzzing

_OPS = st.one_of(
    st.tuples(st.just("bits"), st.integers(0, 2**63 - 1), st.integers(1, 64)),
    st.tuples(st.just("varint"), st.integers(0, 2**62)),
    # Crosses the 2**63 boundary where the old C-idiom zig-zag
    # ((v << 1) ^ (v >> 63)) silently corrupted Python's unbounded ints.
    st.tuples(st.just("svarint"), st.integers(-2**70, 2**70)),
    st.tuples(st.just("rice"), st.integers(0, 20000), st.integers(0, 10)),
    st.tuples(st.just("f64"), st.floats(allow_nan=True, allow_infinity=True)),
)


@given(st.lists(_OPS, min_size=1, max_size=300))
@settings(max_examples=200, deadline=None)
def test_bitio_interleaved_roundtrip(ops):
    """Any interleaving of the five write primitives reads back exactly
    (f64 compared at the bit level so NaN payloads count)."""
    w = BitWriter()
    for op in ops:
        if op[0] == "bits":
            w.write(op[1] & ((1 << op[2]) - 1), op[2])
        elif op[0] == "varint":
            w.write_varint(op[1])
        elif op[0] == "svarint":
            w.write_svarint(op[1])
        elif op[0] == "rice":
            w.write_rice(op[1], op[2])
        else:
            w.write_f64(op[1])
    r = BitReader(w.getvalue())
    for op in ops:
        if op[0] == "bits":
            assert r.read(op[2]) == op[1] & ((1 << op[2]) - 1)
        elif op[0] == "varint":
            assert r.read_varint() == op[1]
        elif op[0] == "svarint":
            assert r.read_svarint() == op[1]
        elif op[0] == "rice":
            assert r.read_rice(op[2]) == op[1]
        else:
            assert struct.pack("<d", r.read_f64()) == struct.pack("<d", op[1])


def test_svarint_boundary_roundtrip():
    """|v| at and past 2**63 roundtrips exactly.

    Regression: the zig-zag used the C idiom ``(v << 1) ^ (v >> 63)``,
    which on arbitrary-precision ints maps every v >= 2**63 to the wrong
    codeword (the ``>> 63`` no longer isolates a sign bit), so the
    roundtrip silently returned a different number instead of raising."""
    boundary = [2**63 - 1, 2**63, 2**63 + 1, -(2**63) + 1, -(2**63),
                -(2**63) - 1, 2**64 + 17, -(2**70) - 3]
    w = BitWriter()
    for v in boundary:
        w.write_svarint(v)
    r = BitReader(w.getvalue())
    assert [r.read_svarint() for _ in boundary] == boundary


@given(st.lists(st.integers(1, 64), min_size=1, max_size=64),
       st.integers(0, 2**31))
@settings(max_examples=100, deadline=None)
def test_bitio_random_widths(widths, seed):
    """Width-1..64 fields packed back to back roundtrip at any alignment."""
    rng = np.random.default_rng(seed)
    vals = [int(rng.integers(0, 1 << min(nb, 62))) for nb in widths]
    w = BitWriter()
    for v, nb in zip(vals, widths):
        w.write(v, nb)
    r = BitReader(w.getvalue())
    assert [r.read(nb) for nb in widths] == vals


# --------------------------------------------------------- value-array codec

@given(st.integers(0, 19),
       st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_values_dyadic_exact(p, ints):
    """(2k+1) / 2**p roundtrips bit-exactly through the dyadic delta path.

    Odd numerators keep every value at least 2**-p away from any coarser
    dyadic grid, and p <= 19 keeps 2**-p above the encoder's 1e-6 snap
    tolerance — so the chosen exponent is exactly p and the roundtrip is
    lossless. (Tiny even-numerator values like 3/2**40 legitimately snap
    to a coarser grid; that lossy-by-design case is covered by
    ``test_values_any_floats_roundtrip_exact``.)"""
    arr = (2.0 * np.array(ints, np.float64) + 1.0) / (1 << p)
    w = BitWriter()
    _encode_values(w, arr)
    out = _decode_values(BitReader(w.getvalue()), len(arr))
    assert np.array_equal(out, arr)


@given(st.lists(st.floats(min_value=-1e300, max_value=1e300,
                          allow_nan=False), min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_values_any_floats_roundtrip_exact(values):
    """Arbitrary finite floats roundtrip bit-exactly UNLESS they sit within
    the 1e-6 dyadic-snap tolerance of a p<=40 grid (then they land on it) —
    either way the decoded array is within 1e-6 * 2**-p of the input."""
    arr = np.array(values, np.float64)
    w = BitWriter()
    _encode_values(w, arr)
    out = _decode_values(BitReader(w.getvalue()), len(arr))
    assert np.allclose(out, arr, rtol=0, atol=2e-6) or np.array_equal(out, arr)


def test_values_dyadic_cap_falls_back_to_f64():
    """Values past the dyadic caps take the bit-exact f64 fallback.

    Two cap edges: an alternating-bit numerator over 2**41 (0.0101...01 in
    binary) is exactly dyadic only at p=41 — one past the p<=40 cap — and
    its fractional part stays >= 0.25 at every p<=40, so no coarser grid
    can snap it; and a magnitude past the 2**62 guard rejects every
    exponent outright. (A *small* numerator over 2**41 like 1/2**41 instead
    snaps to a coarse grid within the 1e-6 tolerance — lossy by design.)"""
    alt_bits = (4**21 - 1) // 3                # 0b0101...01, 41 bits, odd
    arr = np.array([alt_bits / (1 << 41), 2.0**63], np.float64)
    w = BitWriter()
    _encode_values(w, arr)
    r = BitReader(w.getvalue())
    assert r.read(1) == 1                      # f64 fallback flag
    out = _decode_values(BitReader(w.getvalue()), len(arr))
    assert np.array_equal(out, arr)


def test_values_f64_fallback_bit_exact():
    """Values far from every dyadic grid (1/3, pi) take the fallback and
    roundtrip to the exact same bit patterns."""
    arr = np.array([1.0 / 3.0, math.pi, -math.e * 1e17], np.float64)
    w = BitWriter()
    _encode_values(w, arr)
    out = _decode_values(BitReader(w.getvalue()), len(arr))
    assert arr.tobytes() == out.tobytes()


# --------------------------------------------------------------- count codec

@given(st.integers(0, 2**31), st.integers(1, 400), st.floats(0.0, 1.0),
       st.integers(0, 20))
@settings(max_examples=150, deadline=None)
def test_counts_roundtrip_any_density(seed, n, density, log_scale):
    """Count vectors from all-zero through dense roundtrip exactly; the
    dense-vs-sparse flag picks whichever encoding is smaller, and both
    decode identically across the flip boundary."""
    rng = np.random.default_rng(seed)
    flat = np.where(rng.random(n) < density,
                    rng.integers(0, (1 << log_scale) + 1, n), 0)
    H = flat.astype(np.float64)
    w = BitWriter()
    _encode_counts(w, H)
    out = _decode_counts(BitReader(w.getvalue()), (n,))
    assert np.array_equal(out, H)


@given(st.integers(1, 24), st.integers(1, 24), st.integers(0, 2**31))
@settings(max_examples=100, deadline=None)
def test_counts_roundtrip_2d(kx, ky, seed):
    rng = np.random.default_rng(seed)
    H = rng.integers(0, 1000, (kx, ky)).astype(np.float64)
    H[rng.random((kx, ky)) < 0.7] = 0.0        # mostly sparse
    w = BitWriter()
    _encode_counts(w, H)
    out = _decode_counts(BitReader(w.getvalue()), (kx, ky))
    assert np.array_equal(out, H)


def test_counts_all_zero_and_single_nonzero():
    for H in (np.zeros(17), np.zeros((5, 5)),
              np.eye(1) * 7, np.array([0.0, 0, 0, 12345.0, 0])):
        w = BitWriter()
        _encode_counts(w, H)
        out = _decode_counts(BitReader(w.getvalue()), H.shape)
        assert np.array_equal(out, H)


# ----------------------------------------------- adversarial synopsis shapes

def _mk_hist(rng, k, lo=0.0):
    """A structurally valid Hist1D on an integer grid with k bins."""
    edges = lo + np.unique(rng.choice(200, k + 1, replace=False)).astype(float)
    k = edges.size - 1
    h = rng.integers(0, 500, k).astype(float)
    u = np.minimum(rng.integers(0, 50, k), h).astype(float)
    vmin = edges[:-1].copy()
    vmax = np.minimum(edges[1:], vmin + rng.integers(0, 3, k))
    c = 0.5 * (vmin + vmax)
    return Hist1D(edges=edges, k=np.int32(k), h=h, u=u, vmin=vmin, vmax=vmax,
                  c=c, cminus=c, cplus=c)


def _mk_pair(rng, hx_hist, hy_hist, all_zero=False):
    """A structurally valid PairHist consistent with its slice metadata
    (decode re-derives hx/hy as H.sum, so the fixture must agree)."""
    kx, ky = int(hx_hist.k), int(hy_hist.k)
    H = (np.zeros((kx, ky)) if all_zero
         else rng.integers(0, 100, (kx, ky)).astype(float))
    return PairHist(
        ex=hx_hist.edges.copy(), ey=hy_hist.edges.copy(),
        kx=np.int32(kx), ky=np.int32(ky), H=H,
        hx=H.sum(1), ux=hx_hist.u[:kx].copy(),
        vminx=hx_hist.vmin.copy(), vmaxx=hx_hist.vmax.copy(),
        hy=H.sum(0), uy=hy_hist.u[:ky].copy(),
        vminy=hy_hist.vmin.copy(), vmaxy=hy_hist.vmax.copy(),
        fold_x=np.zeros(kx, np.int32), fold_y=np.zeros(ky, np.int32))


@given(st.integers(0, 2**31), st.integers(1, 4), st.booleans(),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_encode_decode_adversarial_shapes(seed, d, zero_pairs, single_bin):
    """Synthetic synopses — single-bin histograms, all-zero pair counts,
    mixed column kinds — encode/decode with every stored field bit-exact."""
    rng = np.random.default_rng(seed)
    kinds = ["int", "float", "categorical"]
    columns = [
        ColumnInfo(name=f"c{i}", kind=kinds[i % 3],
                   offset=float(rng.integers(0, 100)),
                   scale=float(10 ** rng.integers(0, 3)),
                   categories=(("a", "b", "zz")[: rng.integers(1, 4)]
                               if kinds[i % 3] == "categorical" else ()),
                   n_null=int(rng.integers(0, 10)),
                   mu=float(rng.integers(1, 5)))
        for i in range(d)
    ]
    hists = [_mk_hist(rng, 1 if single_bin else int(rng.integers(1, 12)))
             for _ in range(d)]
    pairs = {}
    for i in range(d):
        for j in range(i + 1, d):
            pairs[(i, j)] = _mk_pair(rng, hists[i], hists[j],
                                     all_zero=zero_pairs)
    params = BuildParams(n_samples=1000, m_frac=0.01, alpha=0.001,
                         s1_max=16, s2_max=8)
    ph = PairwiseHist(params=params, n_rows=5000, n_sampled=1000,
                      columns=columns, hists=hists, pairs=pairs,
                      chi2_table=np.zeros(17))
    blob = encode(ph)

    info = blob_info(blob)
    assert info == {"bytes": len(blob), "n_rows": 5000, "n_sampled": 1000,
                    "d": d, "framed": True}

    ph2 = decode(blob)
    assert ph2.n_rows == ph.n_rows and ph2.n_sampled == ph.n_sampled
    assert ph2.params.min_points == ph.params.min_points
    assert ph2.params.alpha == ph.params.alpha
    for c1, c2 in zip(ph.columns, ph2.columns):
        assert (c1.name, c1.kind, c1.offset, c1.scale, c1.n_null, c1.mu) == \
               (c2.name, c2.kind, c2.offset, c2.scale, c2.n_null, c2.mu)
        assert tuple(str(x) for x in c1.categories) == c2.categories
    for h1, h2 in zip(ph.hists, ph2.hists):
        for field in ("edges", "h", "u", "vmin", "vmax"):
            assert np.array_equal(getattr(h1, field), getattr(h2, field)), field
    assert set(ph2.pairs) == set(ph.pairs)
    for key, p1 in ph.pairs.items():
        p2 = ph2.pairs[key]
        for field in ("ex", "ey", "H", "hx", "hy", "ux", "uy",
                      "vminx", "vmaxx", "vminy", "vmaxy"):
            assert np.array_equal(getattr(p1, field), getattr(p2, field)), field


def test_blob_info_rejects_bad_magic():
    with pytest.raises(ValueError):
        blob_info(b"NOPE" + b"\x00" * 16)


# --------------------------------------------------------- corruption corpus

def _small_ph(seed=123, d=3):
    """A small but real synopsis for corruption fuzzing."""
    rng = np.random.default_rng(seed)
    columns = [ColumnInfo(name=f"c{i}", kind="float", offset=0.0, scale=1.0,
                          categories=(), n_null=0, mu=1.0) for i in range(d)]
    hists = [_mk_hist(rng, int(rng.integers(3, 10))) for _ in range(d)]
    pairs = {(i, j): _mk_pair(rng, hists[i], hists[j])
             for i in range(d) for j in range(i + 1, d)}
    return PairwiseHist(params=BuildParams(n_samples=1000), n_rows=4000,
                        n_sampled=1000, columns=columns, hists=hists,
                        pairs=pairs, chi2_table=np.zeros(17))


@pytest.fixture(scope="module")
def framed_blob():
    return encode(_small_ph())


def _assert_rejected(data):
    """Every reader surface rejects ``data`` with the typed IntegrityError —
    wrong answers and hangs are the failure modes being excluded."""
    for vectorized in (True, False):
        with pytest.raises(IntegrityError):
            decode(data, vectorized=vectorized)
    with pytest.raises(IntegrityError):
        blob_info(data)


@given(st.integers(0, 2**31))
@settings(max_examples=150, deadline=None)
def test_corruption_single_bit_flip_rejected(framed_blob, seed):
    """ANY single-bit flip — header or payload — is caught by the frame
    (CRC over the payload, explicit length, 3-bit magic distance), in both
    the vectorized and the oracle decoder."""
    rng = np.random.default_rng(seed)
    pos = int(rng.integers(0, len(framed_blob)))
    bit = int(rng.integers(0, 8))
    bad = bytearray(framed_blob)
    bad[pos] ^= 1 << bit
    _assert_rejected(bytes(bad))


@given(st.integers(0, 2**31))
@settings(max_examples=100, deadline=None)
def test_corruption_truncation_rejected(framed_blob, seed):
    """Truncation at any point — inside the 12-byte frame header or the
    payload — raises IntegrityError, never decodes garbage."""
    rng = np.random.default_rng(seed)
    cut = int(rng.integers(0, len(framed_blob)))
    _assert_rejected(framed_blob[:cut])


@given(st.integers(0, 2**31), st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_corruption_garbage_tail_rejected(framed_blob, seed, n_tail):
    """Appended garbage breaks the frame's length check even when the
    payload itself is intact."""
    rng = np.random.default_rng(seed)
    tail = rng.integers(0, 256, n_tail, dtype=np.uint8).tobytes()
    _assert_rejected(framed_blob + tail)


@given(st.binary(min_size=0, max_size=64))
@settings(max_examples=100, deadline=None)
def test_corruption_arbitrary_garbage_rejected(garbage):
    """Arbitrary non-synopsis bytes are rejected typed (bad magic / short
    frame), not crashed on or misread."""
    _assert_rejected(garbage)


@given(st.integers(0, 2**31))
@settings(max_examples=60, deadline=None)
def test_corruption_legacy_truncation_rejected(framed_blob, seed):
    """Legacy UNframed streams have no CRC, but truncation still surfaces
    as IntegrityError via the bit-reader overrun guards (both readers) —
    never a hang or a silently short synopsis."""
    ph = _small_ph()
    raw = encode(ph, framed=False)
    assert decode(raw).n_rows == ph.n_rows     # sanity: legacy passthrough
    rng = np.random.default_rng(seed)
    cut = int(rng.integers(4, len(raw) - 1))   # keep the PWH1 magic
    for vectorized in (True, False):
        with pytest.raises(IntegrityError):
            decode(raw[:cut], vectorized=vectorized)


def test_framed_roundtrip_and_info(framed_blob):
    """The frame is transparent: decode returns the same synopsis, and
    blob_info reports framed=True with payload-level fields intact."""
    ph = decode(framed_blob)
    assert ph.n_rows == 4000 and len(ph.hists) == 3
    info = blob_info(framed_blob)
    assert info["framed"] is True and info["d"] == 3
