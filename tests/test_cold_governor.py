"""Cold-tier memory governor + catalog/rebuild race regressions.

Covers the demote/re-promote lifecycle (epoch stability, bit-identical
answers, cache validity, in-flight waves racing a demote, rebuild-then-
demote freshness), the byte-budget stress (high-water telemetry proves
resident engine bytes stay within ``max_engine_bytes``), and two threaded
regressions that fail on the pre-fix code: the unlocked ``TableCatalog``
registry dict and ``ColdTable.rebuild``'s last-write-wins publication.
"""
import threading
import time

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core import storage
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer, TableCatalog
from repro.serve.aqp import catalog as catalogmod


@pytest.fixture(scope="module")
def cold_fixture():
    """A small GD-compressed table, its synopsis blob, and the live fw."""
    rng = np.random.default_rng(3)
    n = 6_000
    table = {
        "a": rng.integers(0, 400, n).astype(float),
        "b": np.abs(rng.normal(80, 25, n)).round(),
        "c": rng.integers(0, 40, n).astype(float),
    }
    fw = AQPFramework(params=BuildParams(n_samples=2_500, seed=5),
                      use_compression=True).ingest(table)
    return storage.encode(fw.synopsis), fw.compressed, fw


QUERIES = [
    "SELECT COUNT(a) FROM {t} WHERE b > 70",
    "SELECT AVG(b) FROM {t} WHERE a < 250",
    "SELECT SUM(b) FROM {t} WHERE c >= 10",
]


# ----------------------------------------------------- demote / re-promote


def test_demote_repromote_lifecycle(cold_fixture):
    """Epoch stable across demote; answers before/after re-promotion are
    bit-identical; telemetry counts every transition."""
    blob, compressed, _ = cold_fixture
    srv = AQPServer(mode="numpy", result_cache_size=0)
    srv.register_cold("t", blob, compressed=compressed)
    cold = srv.catalog.resolve("t")
    sqls = [q.format(t="t") for q in QUERIES]
    before = [srv.query(s).as_tuple() for s in sqls]
    e0 = cold.epoch
    assert cold.decode_count == 1 and cold.resident_bytes > 0

    assert srv.demote("t") is True
    assert cold.epoch == e0                 # representation, not state
    assert cold.engine is None and cold.resident_bytes == 0
    assert srv.demote("t") is False         # already cold: no-op

    after = [srv.query(s).as_tuple() for s in sqls]
    assert after == before                  # bit-identical, not just close
    assert cold.decode_count == 2 and cold.demote_count == 1
    tm = srv.stats()["tables"]["t"]["cold"]
    assert tm["decodes"] == 2 and tm["demotes"] == 1
    info = cold.cold_info()
    assert info["demote_count"] == 1 and info["decoded"] is True
    srv.close()


def test_result_cache_survives_demote(cold_fixture):
    """Demote is epoch-stable, so result-cache entries stay valid: a repeat
    query after the demote is a cache hit and never re-decodes."""
    blob, compressed, _ = cold_fixture
    srv = AQPServer(mode="numpy")
    srv.register_cold("t", blob, compressed=compressed)
    cold = srv.catalog.resolve("t")
    sql = "SELECT COUNT(a) FROM t WHERE b > 70"
    first = srv.query(sql)
    assert len(srv.result_cache) == 1 and cold.decode_count == 1
    assert srv.demote("t")
    assert len(srv.result_cache) == 1       # no spurious purge
    hit = srv.query(sql)
    assert hit.as_tuple() == first.as_tuple()
    assert cold.decode_count == 1           # served cold, straight from cache
    assert srv.stats()["tables"]["t"]["result_cache_hits"] == 1
    srv.close()


def test_inflight_engine_survives_demote(cold_fixture):
    """A wave holding the pre-demote (engine, epoch) snapshot finishes
    safely: demote swaps the published tuple, never touches the engine."""
    blob, compressed, _ = cold_fixture
    cat = TableCatalog()
    cat.register_cold("t", blob, compressed=compressed)
    cold = cat.resolve("t")
    engine, epoch = cat.snapshot("t")       # the wave's held reference
    assert cold.demote() is True
    assert cold.engine is None
    # The held engine still answers — and identically to a re-decode.
    from repro.core.sql import parse_sql
    plan = engine.plan_query(parse_sql("SELECT AVG(b) FROM t WHERE a < 250"))
    held = engine.execute_plan(plan).as_tuple()
    engine2, epoch2 = cat.snapshot("t")     # transparent re-decode
    assert epoch2 == epoch and cold.decode_count == 2
    assert engine2.execute_plan(plan).as_tuple() == held


def test_queries_racing_demote_storm(cold_fixture):
    """Queries submitted while another thread demotes in a tight loop all
    come back bit-identical to an undisturbed server's answers."""
    blob, compressed, _ = cold_fixture
    ref = AQPServer(mode="numpy")
    ref.register_cold("t", blob, compressed=compressed)
    sqls = [q.format(t="t") for q in QUERIES] * 4
    expected = [ref.query(s).as_tuple() for s in sqls]
    ref.close()

    srv = AQPServer(mode="numpy", result_cache_size=0)
    srv.register_cold("t", blob, compressed=compressed)
    stop = threading.Event()

    def demoter():
        while not stop.is_set():
            srv.demote("t")

    th = threading.Thread(target=demoter)
    th.start()
    try:
        got = [srv.query(s).as_tuple() for s in sqls]
    finally:
        stop.set()
        th.join()
    assert got == expected
    assert srv.catalog.resolve("t").demote_count >= 1
    srv.close()


def test_rebuild_then_demote_serves_fresh_state(cold_fixture):
    """Demote after a rebuild re-promotes to the *rebuilt* synopsis, never
    the registration-time blob; and if the blob ever lags the published
    epoch, demote re-encodes before dropping the engine."""
    blob, compressed, _ = cold_fixture
    srv = AQPServer(mode="numpy", result_cache_size=0)
    srv.register_cold("t", blob, compressed=compressed,
                      params=BuildParams(n_samples=2_500, seed=5))
    cold = srv.catalog.resolve("t")
    srv.query("SELECT COUNT(a) FROM t WHERE b > 70")
    cold.rebuild(BuildParams(n_samples=1_800, seed=9))
    rebuilt = [srv.query(q.format(t="t")).as_tuple() for q in QUERIES]
    assert cold.engine.ph.n_sampled == 1_800
    assert srv.demote("t")
    again = [srv.query(q.format(t="t")).as_tuple() for q in QUERIES]
    assert again == rebuilt
    assert cold.engine.ph.n_sampled == 1_800    # not the 2_500-sample seed

    # Defensive branch: force blob/engine divergence (as if the encode had
    # been deferred) and check demote re-encodes rather than losing state.
    stale_blob = cold.blob
    cold._blob_epoch = cold.epoch - 1
    assert srv.demote("t")
    assert cold.blob != stale_blob or storage.decode(cold.blob).n_sampled == 1_800
    assert cold._blob_epoch == cold.epoch
    final = [srv.query(q.format(t="t")).as_tuple() for q in QUERIES]
    assert final == rebuilt
    srv.close()


# ------------------------------------------------------------ byte budget


def test_budget_stress_high_water(cold_fixture):
    """Many cold tables under ``max_engine_bytes``: resident engine bytes
    never exceed the budget (post-enforcement high-water proves it), the
    governor actually demotes, and every answer is bit-identical to an
    unbudgeted server's."""
    blob, compressed, _ = cold_fixture
    engine_bytes = storage.decode(blob).nbytes
    names = [f"t{i:02d}" for i in range(12)]

    ref = AQPServer(mode="numpy", result_cache_size=0)
    srv = AQPServer(mode="numpy", result_cache_size=0,
                    max_engine_bytes=3 * engine_bytes)
    for s in (ref, srv):
        for name in names:
            s.register_cold(name, blob, compressed=compressed)

    sqls = [QUERIES[i % len(QUERIES)].format(t=name)
            for i in range(2) for name in names]
    expected = [ref.query(s).as_tuple() for s in sqls]
    ref.close()
    got = [srv.query(s).as_tuple() for s in sqls]
    assert got == expected

    st = srv.stats()["cold"]
    assert st["max_engine_bytes"] == 3 * engine_bytes
    assert st["demotes"] > 0
    assert 0 < st["resident_high_water"] <= 3 * engine_bytes
    assert st["resident_bytes"] <= 3 * engine_bytes
    total = sum(t.resident_bytes for _, t in srv.catalog.cold_tables())
    assert total <= 3 * engine_bytes
    srv.close()


def test_idle_demotion_between_waves(cold_fixture):
    """``demote_idle_s``: a table idle past the window demotes on the next
    between-waves sweep; an active table does not."""
    blob, compressed, _ = cold_fixture
    srv = AQPServer(mode="numpy", demote_idle_s=0.15, result_cache_size=0)
    srv.register_cold("idle", blob, compressed=compressed)
    srv.register_cold("hot", blob, compressed=compressed)
    srv.query("SELECT COUNT(a) FROM idle WHERE b > 70")
    time.sleep(0.3)
    # A wave against the hot table triggers the sweep; "hot" was active in
    # this very wave, "idle" was not.
    srv.query("SELECT COUNT(a) FROM hot WHERE b > 70")
    deadline = time.time() + 2.0
    idle = srv.catalog.resolve("idle")
    while idle.engine is not None and time.time() < deadline:
        time.sleep(0.01)
    assert idle.engine is None and idle.demote_count == 1
    assert srv.catalog.resolve("hot").engine is not None
    res = srv.query("SELECT COUNT(a) FROM idle WHERE b > 70")  # re-promotes
    assert res.estimate is not None and idle.decode_count == 2
    srv.close()


# --------------------------------------------------- regression: catalog race


def test_catalog_register_unregister_race():
    """Registration churn racing ``tables()``/``resolve``/``epoch`` must
    never raise (pre-fix: plain-dict mutation mid-``sorted()`` raised
    ``RuntimeError: dictionary changed size during iteration``)."""

    class _Dummy:
        epoch = 1

    cat = TableCatalog()
    for i in range(300):
        cat.register(f"seed{i:03d}", _Dummy())
    stop = threading.Event()
    errors = []

    def churn(tag):
        i = 0
        while not stop.is_set():
            name = f"{tag}{i % 200:03d}"
            try:
                cat.register(name, _Dummy())
                cat.unregister(name)
            except Exception as exc:    # pragma: no cover - pre-fix only
                errors.append(exc)
                return
            i += 1

    def reader():
        while not stop.is_set():
            try:
                cat.tables()
                # Python-level .items() iteration: without the registry
                # lock this is the line that raises "dictionary changed
                # size during iteration" under churn.
                cat.cold_tables()
                cat.epoch("seed000")
                "seed001" in cat
                len(cat)
            except Exception as exc:    # pragma: no cover - pre-fix only
                errors.append(exc)
                return

    threads = ([threading.Thread(target=churn, args=(t,)) for t in "ab"]
               + [threading.Thread(target=reader) for _ in range(2)])
    for t in threads:
        t.start()
    time.sleep(0.6)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors


# --------------------------------------------- regression: rebuild last-write


def test_concurrent_rebuild_newer_wins(cold_fixture, monkeypatch):
    """A slow rebuild that started first must not clobber a faster one that
    published after it (pre-fix: builds ran outside the lock and the last
    writer won, so the *older* build's engine and blob overwrote the newer
    publication after its callbacks had already fired)."""
    blob, compressed, _ = cold_fixture
    cat = TableCatalog()
    cat.register_cold("t", blob, compressed=compressed,
                      params=BuildParams(n_samples=2_500, seed=5))
    cold = cat.resolve("t")
    cold.published                           # decode so rebuild has columns

    real_build = catalogmod.build_pairwise_hist
    slow_entered = threading.Event()
    release_slow = threading.Event()

    def instrumented(compressed_tbl, columns, params):
        if params.n_samples == 1_000:        # the slow, older rebuild
            slow_entered.set()
            release_slow.wait(timeout=10)
        return real_build(compressed_tbl, columns, params)

    monkeypatch.setattr(catalogmod, "build_pairwise_hist", instrumented)

    published_epochs = []
    cold.on_invalidate(lambda c: published_epochs.append(c.epoch))

    slow = threading.Thread(
        target=cold.rebuild, args=(BuildParams(n_samples=1_000, seed=5),))
    slow.start()
    assert slow_entered.wait(timeout=10)
    # The fast rebuild arrives while the slow one is mid-build.
    fast = threading.Thread(
        target=cold.rebuild, args=(BuildParams(n_samples=2_000, seed=5),))
    fast.start()
    time.sleep(0.1)
    release_slow.set()
    slow.join(timeout=30)
    fast.join(timeout=30)

    # The later-arriving build's state must be what remains published.
    assert cold.engine.ph.n_sampled == 2_000
    assert storage.decode(cold.blob).n_sampled == 2_000
    # Publications observed in strictly increasing epoch order.
    assert published_epochs == sorted(published_epochs)
    assert len(set(published_epochs)) == len(published_epochs)
