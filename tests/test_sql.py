"""SQL subset parser."""
import pytest

from repro.core.sql import SQLError, parse_sql


def test_basic():
    q = parse_sql("SELECT AVG(price) FROM t WHERE qty > 3 AND region = 'EU'")
    assert q.func == "AVG" and q.agg_col == "price" and q.table == "t"
    assert q.where.kind == "and"
    assert q.where.children[1].value == "EU"


def test_precedence_and_parens():
    q = parse_sql("SELECT COUNT(x) FROM t WHERE a < 1 OR b > 2 AND c = 3")
    assert q.where.kind == "or"          # AND binds tighter
    assert q.where.children[1].kind == "and"
    q2 = parse_sql("SELECT COUNT(x) FROM t WHERE (a < 1 OR b > 2) AND c = 3")
    assert q2.where.kind == "and"


def test_group_by_and_star():
    q = parse_sql("SELECT COUNT(*) FROM flights GROUP BY airline;")
    assert q.agg_col == "*" and q.group_by == "airline"


def test_operators():
    for op in ("=", "!=", "<>", "<", "<=", ">", ">="):
        q = parse_sql(f"SELECT MIN(v) FROM t WHERE v {op} 1.5e3")
        want = "!=" if op == "<>" else op
        assert q.where.op == want
        assert q.where.value == 1500.0


def test_errors():
    with pytest.raises(SQLError):
        parse_sql("SELECT FOO(x) FROM t")
    with pytest.raises(SQLError):
        parse_sql("SELECT AVG(*) FROM t")
    with pytest.raises(SQLError):
        parse_sql("SELECT AVG(x) FROM t WHERE x >")
    with pytest.raises(SQLError):
        parse_sql("AVG(x) FROM t")
