"""SQL subset parser + template fingerprints."""
import pytest

from repro.core.sql import SQLError, fingerprint_sql, parse_calls, parse_sql


def test_basic():
    q = parse_sql("SELECT AVG(price) FROM t WHERE qty > 3 AND region = 'EU'")
    assert q.func == "AVG" and q.agg_col == "price" and q.table == "t"
    assert q.where.kind == "and"
    assert q.where.children[1].value == "EU"


def test_precedence_and_parens():
    q = parse_sql("SELECT COUNT(x) FROM t WHERE a < 1 OR b > 2 AND c = 3")
    assert q.where.kind == "or"          # AND binds tighter
    assert q.where.children[1].kind == "and"
    q2 = parse_sql("SELECT COUNT(x) FROM t WHERE (a < 1 OR b > 2) AND c = 3")
    assert q2.where.kind == "and"


def test_group_by_and_star():
    q = parse_sql("SELECT COUNT(*) FROM flights GROUP BY airline;")
    assert q.agg_col == "*" and q.group_by == "airline"


def test_operators():
    for op in ("=", "!=", "<>", "<", "<=", ">", ">="):
        q = parse_sql(f"SELECT MIN(v) FROM t WHERE v {op} 1.5e3")
        want = "!=" if op == "<>" else op
        assert q.where.op == want
        assert q.where.value == 1500.0


def test_errors():
    with pytest.raises(SQLError):
        parse_sql("SELECT FOO(x) FROM t")
    with pytest.raises(SQLError):
        parse_sql("SELECT AVG(*) FROM t")
    with pytest.raises(SQLError):
        parse_sql("SELECT AVG(x) FROM t WHERE x >")
    with pytest.raises(SQLError):
        parse_sql("AVG(x) FROM t")


# ------------------------------------------------------------- fingerprints


def test_fingerprint_strips_literals():
    fp = fingerprint_sql(
        "SELECT COUNT(*) FROM t WHERE a > 5 AND b = 'EU' OR c <= 2.5")
    assert fp.literals == (5.0, "EU", 2.5)
    assert "?" in fp.shape and "5" not in fp.shape and "EU" not in fp.shape


def test_fingerprint_same_shape_different_literals():
    a = fingerprint_sql("SELECT SUM(x) FROM t WHERE a > 1 AND b < 2")
    b = fingerprint_sql("SELECT SUM(x) FROM t WHERE a > 9.75 AND b < -40")
    assert a.shape == b.shape
    assert a.literals != b.literals


def test_fingerprint_negative_and_scientific_literals():
    # Negative literals and scientific notation are single num tokens, so
    # they strip to the same placeholder as a plain integer.
    base = fingerprint_sql("SELECT MIN(v) FROM t WHERE v > 3")
    for lit in ("-7", "-7.25", "1.5e3", "2E-2", "-1e+4"):
        fp = fingerprint_sql(f"SELECT MIN(v) FROM t WHERE v > {lit}")
        assert fp.shape == base.shape, lit
        assert fp.literals == (float(lit),)


def test_fingerprint_quoted_strings_with_digits():
    # Digits inside quoted literals must strip with the string, never
    # tokenize as numbers: the shape stays literal-free.
    a = fingerprint_sql("SELECT COUNT(*) FROM t WHERE city = 'NY 10001'")
    b = fingerprint_sql('SELECT COUNT(*) FROM t WHERE city = "Area 51"')
    assert a.shape == b.shape
    assert a.literals == ("NY 10001",)
    assert b.literals == ("Area 51",)
    assert "10001" not in a.shape and "51" not in b.shape


def test_fingerprint_whitespace_and_semicolon_variants():
    a = fingerprint_sql("SELECT AVG(x) FROM t WHERE a > 1 AND b < 2")
    b = fingerprint_sql("  SELECT  AVG( x )\nFROM t\tWHERE a>3 AND b<4 ; ")
    assert a.shape == b.shape


def test_fingerprint_clause_order_variants():
    a = fingerprint_sql(
        "SELECT COUNT(*) FROM t WHERE a > 1 GROUP BY g")
    b = fingerprint_sql(
        "SELECT COUNT(*) FROM t GROUP BY g WHERE a > 2")
    assert a.shape == b.shape
    assert a.literals == (1.0,) and b.literals == (2.0,)


def test_fingerprint_distinct_shapes_stay_distinct():
    # Different columns, operators, or aggregation functions are different
    # shapes — only literal values may differ within one template.
    shapes = {fingerprint_sql(s).shape for s in (
        "SELECT COUNT(*) FROM t WHERE a > 1",
        "SELECT COUNT(*) FROM t WHERE b > 1",
        "SELECT COUNT(*) FROM t WHERE a >= 1",
        "SELECT SUM(a) FROM t WHERE a > 1",
        "SELECT COUNT(*) FROM u WHERE a > 1",
    )}
    assert len(shapes) == 5


def test_parse_calls_counter_is_monotonic():
    before = parse_calls()
    fingerprint_sql("SELECT COUNT(*) FROM t WHERE a > 1")   # no parse
    assert parse_calls() == before
    parse_sql("SELECT COUNT(*) FROM t WHERE a > 1")
    assert parse_calls() == before + 1
