import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_table():
    rng = np.random.default_rng(1)
    n = 60_000
    c0 = rng.integers(0, 1000, n).astype(float)
    c1 = np.abs(rng.normal(300, 80, n)).round()
    c2 = (c1 * 3 + rng.normal(0, 30, n)).round()
    c3 = rng.zipf(1.7, n).clip(1, 40).astype(float)
    c3[rng.random(n) < 0.04] = np.nan
    return {"c0": c0, "c1": c1, "c2": c2, "c3": c3}


@pytest.fixture(scope="session")
def synopsis(small_table):
    from repro.core.build import build_pairwise_hist
    from repro.core.types import BuildParams, ColumnInfo
    data = np.stack(list(small_table.values()), 1)
    cols = [ColumnInfo(name=k, kind="int") for k in small_table]
    return build_pairwise_hist(data, cols, BuildParams(n_samples=30_000,
                                                       seed=3))


@pytest.fixture(scope="session")
def engine(synopsis):
    from repro.core.query import QueryEngine
    return QueryEngine(synopsis)


@pytest.fixture(scope="session")
def exact(small_table):
    from repro.aqp.exact import ExactEngine
    return ExactEngine(small_table)
