"""Dry-run machinery in subprocesses (device-count manipulation) + the
elastic-restore path across different mesh sizes."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
       "REPRO_DRYRUN_DEVICES": "8"}


def _run(code: str, extra_env=None):
    env = dict(ENV)
    if extra_env:
        env.update(extra_env)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


@pytest.mark.slow
def test_debug_mesh_cell_compiles():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch.dryrun import run_cell
        res = run_cell("qwen3-0.6b", "decode_32k", multi_pod=True,
                       debug_mesh=True)
        assert res.get("ok"), res.get("error")
        assert res["collectives"], "expected collectives in partitioned HLO"
        print("OK", res["n_devices"])
    """)
    assert "OK 8" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_collective_parser_counts_bytes():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch.dryrun import run_cell
        res = run_cell("mistral-nemo-12b", "train_4k", multi_pod=False,
                       debug_mesh=True)
        assert res.get("ok"), res.get("error")
        wire = sum(v["wire_bytes_per_device"]
                   for v in res["collectives"].values())
        assert wire > 0, res["collectives"]
        print("WIRE_OK", int(wire))
    """)
    assert "WIRE_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_distributed_hist2d_row_sharded():
    """DESIGN §3.5: row-sharded bin counting reduces via psum to the same
    counts as the single-device oracle."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.kernels.hist2d.ops import hist2d_sharded
        from repro.kernels.hist2d.ref import hist2d_ref
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n, ki, kj = 64_000, 96, 64
        bi = rng.integers(0, ki, n).astype(np.int32)
        bj = rng.integers(0, kj, n).astype(np.int32)
        w = rng.random(n).astype(np.float32)
        out = hist2d_sharded(bi, bj, w, ki, kj, mesh)
        ref = hist2d_ref(jnp.asarray(bi), jnp.asarray(bj), jnp.asarray(w),
                         ki, kj)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)
        txt = jax.jit(lambda a,b,c: hist2d_ref(a,b,c,ki,kj),
                      out_shardings=jax.sharding.NamedSharding(
                          mesh, jax.sharding.PartitionSpec())).lower(
            jax.device_put(jnp.asarray(bi), jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data"))),
            jax.device_put(jnp.asarray(bj), jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data"))),
            jax.device_put(jnp.asarray(w), jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")))).compile().as_text()
        assert "all-reduce" in txt  # counts psum across the data axis
        print("DIST_HIST_OK")
    """)
    assert "DIST_HIST_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_elastic_restore_across_device_counts(tmp_path):
    """Save on a 4-device mesh, restore+reshard on 2 devices."""
    ckpt = str(tmp_path / "elastic")
    save_code = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.train.step import init_train_state
        from repro.ckpt.checkpoint import CheckpointManager
        cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                                  dtype="float32")
        mesh = jax.make_mesh((4,), ("data",))
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        sharded = jax.device_put(
            state, NamedSharding(mesh, P()))
        mgr = CheckpointManager({ckpt!r})
        mgr.save(0, sharded, blocking=True)
        print("SAVED")
    """
    out = _run(save_code)
    assert "SAVED" in out.stdout, out.stdout + out.stderr
    restore_code = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, dataclasses, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.train.step import init_train_state
        from repro.ckpt.checkpoint import CheckpointManager
        cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                                  dtype="float32")
        mesh = jax.make_mesh((2,), ("data",))
        like = init_train_state(cfg, jax.random.PRNGKey(1))
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), like)
        mgr = CheckpointManager({ckpt!r})
        step, state = mgr.restore(like, shardings=shardings)
        assert step == 0, step
        ref = init_train_state(cfg, jax.random.PRNGKey(0))
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("RESTORED_ELASTIC")
    """
    out = _run(restore_code)
    assert "RESTORED_ELASTIC" in out.stdout, out.stdout + out.stderr
