"""Streaming admission + GROUP BY batching: futures, admission policy edge
cases (empty drain, timeout with a partial group, epoch bumps mid-flight),
and GROUP BY leaf-path equivalence with the unbatched oracle."""
import threading
import time

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer, StreamingAdmission

TIMEOUT = 30  # generous future-resolution bound; loaded CI boxes are slow


def _make_table(n=8_000, seed=7):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "cat": np.array(["r", "g", "b", "c", "m", "y"])[
            rng.integers(0, 6, n)],
    }


@pytest.fixture(scope="module")
def framework():
    return AQPFramework(BuildParams(n_samples=4_000, seed=2),
                        use_compression=False).ingest(_make_table())


def _server(framework, **kwargs):
    kwargs.setdefault("mode", "numpy")
    return AQPServer(**kwargs).register("t", framework)


# -------------------------------------------------------- admission mechanics


def test_submit_returns_future_and_resolves(framework):
    srv = _server(framework)
    sql = "SELECT COUNT(a) FROM t WHERE b > 100"
    fut = srv.submit(sql)
    assert fut.sql == sql
    srv.flush()
    res = fut.result(timeout=TIMEOUT)
    assert res.as_tuple() == framework.engine.query(sql).as_tuple()
    srv.close()


def test_empty_queue_drain_is_noop(framework):
    """flush() with nothing queued must not hang, fire a wave, or poison
    the worker — and must not bank a drain for the next arrivals."""
    srv = _server(framework, max_wait_ms=200.0)
    srv.flush()                               # worker not even started
    fut = srv.submit("SELECT COUNT(a) FROM t WHERE b > 120")
    srv.flush()
    assert fut.result(timeout=TIMEOUT).estimate is not None
    srv.flush()                               # empty again, after a wave
    time.sleep(0.05)
    snap = srv.stats()["totals"]["admission"]
    assert snap["drains"] == 1 and snap["queue_depth"] == 0
    srv.close()


def test_streaming_admission_close_drains_pending():
    """Pending submissions are executed, not abandoned, on close()."""
    seen = []
    adm = StreamingAdmission(lambda batch, stats: seen.append(
        (len(batch), stats.cause)), max_wait_ms=10_000.0, max_batch=64)
    adm.submit("x")
    adm.submit("y")
    adm.close()
    assert seen == [(2, "flush")]
    with pytest.raises(RuntimeError, match="closed"):
        adm.submit("z")


def test_worker_survives_raising_execute_cb():
    """Regression: an exception escaping execute_cb must not kill the drain
    worker. Pre-fix the first raising wave ended the daemon thread and every
    later submission sat in the queue forever; now the guard routes the
    error to error_cb and the SAME worker keeps draining."""
    errors = []
    seen = []

    def execute(batch, stats):
        if "poison" in batch:
            raise RuntimeError("boom")
        seen.extend(batch)

    adm = StreamingAdmission(execute, max_wait_ms=5.0, max_batch=1,
                             error_cb=lambda batch, exc: errors.append(
                                 (list(batch), exc)))
    adm.submit("poison")
    adm.flush()
    deadline = time.perf_counter() + TIMEOUT
    while not errors and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert errors and errors[0][0] == ["poison"]
    assert isinstance(errors[0][1], RuntimeError)
    # The worker survived: later submissions still execute, with no restart.
    adm.submit("after")
    adm.flush()
    deadline = time.perf_counter() + TIMEOUT
    while "after" not in seen and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert seen == ["after"]
    assert adm.restarts == 0
    adm.close()


def test_raising_error_cb_does_not_kill_worker():
    """The supervision callback itself is untrusted: if error_cb raises,
    the worker still survives and keeps draining."""
    seen = []

    def execute(batch, stats):
        if "poison" in batch:
            raise RuntimeError("boom")
        seen.extend(batch)

    def bad_error_cb(batch, exc):
        raise ValueError("error_cb is broken too")

    adm = StreamingAdmission(execute, max_wait_ms=5.0, max_batch=1,
                             error_cb=bad_error_cb)
    adm.submit("poison")
    adm.submit("after")
    adm.flush()
    deadline = time.perf_counter() + TIMEOUT
    while "after" not in seen and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert seen == ["after"]
    assert adm.restarts == 0
    adm.close()


def test_watchdog_respawns_dead_worker():
    """If the worker thread dies outside the guarded paths, the next
    submit notices (is_alive() false), bumps ``restarts`` and respawns —
    queued items are never stranded."""
    seen = []
    adm = StreamingAdmission(lambda batch, stats: seen.extend(batch),
                             max_wait_ms=5.0, max_batch=1)
    adm.submit("first")
    adm.flush()
    deadline = time.perf_counter() + TIMEOUT
    while "first" not in seen and time.perf_counter() < deadline:
        time.sleep(0.005)
    # Simulate a hard worker death the guards never saw.
    with adm._cv:
        adm._stop = True
        adm._cv.notify_all()
    adm._thread.join(timeout=TIMEOUT)
    assert not adm._thread.is_alive()
    adm._stop = False
    adm.submit("second")                      # watchdog respawns here
    adm.flush()
    deadline = time.perf_counter() + TIMEOUT
    while "second" not in seen and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert seen == ["first", "second"]
    assert adm.restarts == 1
    adm.close()


def test_max_wait_timeout_fires_partial_group(framework):
    """A partial group (size < max_batch) executes once the oldest
    submission has waited max_wait_ms — no flush, no full batch."""
    srv = _server(framework, max_wait_ms=60.0, max_batch=64)
    futs = [srv.submit(f"SELECT COUNT(a) FROM t WHERE b > {thr}")
            for thr in (90, 110, 130)]
    t0 = time.perf_counter()
    for fut in futs:                          # resolve WITHOUT flush
        assert fut.result(timeout=TIMEOUT).estimate is not None
    waited = time.perf_counter() - t0
    assert waited < TIMEOUT
    adm = srv.stats()["totals"]["admission"]
    assert adm["drain_causes"]["timeout"] >= 1
    assert adm["drain_causes"]["full"] == 0
    assert 3 <= adm["max_queue_depth"] <= 3
    assert adm["wait_p99_ms"] >= 20.0         # the group actually waited
    srv.close()


def test_full_batch_fires_without_waiting(framework):
    srv = _server(framework, max_wait_ms=10_000.0, max_batch=4)
    futs = [srv.submit(f"SELECT COUNT(a) FROM t WHERE b > {thr}")
            for thr in (60, 70, 80, 90)]
    for fut in futs:                          # max_batch reached: no flush
        assert fut.result(timeout=TIMEOUT).estimate is not None
    assert srv.stats()["totals"]["admission"]["drain_causes"]["full"] >= 1
    srv.close()


def test_inflight_duplicates_execute_once(framework):
    srv = _server(framework, max_wait_ms=10_000.0)
    sql = "SELECT SUM(b) FROM t WHERE a > 250"
    futs = [srv.submit(sql) for _ in range(4)]
    srv.flush()
    got = {fut.result(timeout=TIMEOUT).as_tuple() for fut in futs}
    assert len(got) == 1
    st = srv.stats()
    assert st["totals"]["queries_executed"] == 1
    assert st["tables"]["t"]["result_cache_hits"] == 3
    srv.close()


def test_streaming_does_not_block_later_arrivals(framework):
    """A second wave completes while an earlier submission's results are
    still being consumed — admission is continuous, not call-scoped."""
    srv = _server(framework, max_wait_ms=5.0)
    first = srv.submit("SELECT COUNT(a) FROM t WHERE b > 100")
    done = threading.Event()
    first.add_done_callback(lambda f: done.set())
    assert done.wait(TIMEOUT)
    second = srv.submit("SELECT COUNT(a) FROM t WHERE b > 101")
    assert second.result(timeout=TIMEOUT).estimate is not None
    assert srv.stats()["totals"]["admission"]["drains"] >= 2
    srv.close()


# --------------------------------------------------- epoch bumps mid-flight


def test_append_rows_mid_flight_rejects_future():
    """append_rows lands after submit but before the wave executes: the
    future resolves with the staleness error and nothing stale is cached."""
    table = _make_table(4_000, seed=8)
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=3),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=10_000.0)
    sql = "SELECT COUNT(a) FROM t WHERE b > 100"
    fut = srv.submit(sql)                     # enqueued at the fresh epoch
    fw.append_rows({k: np.asarray(v)[:100] for k, v in table.items()})
    srv.flush()                               # wave executes against stale fw
    with pytest.raises(RuntimeError, match="stale"):
        fut.result(timeout=TIMEOUT)
    assert len(srv.result_cache) == 0
    fw.rebuild(table)
    assert srv.query(sql).estimate is not None
    srv.close()


def test_rebuild_mid_flight_replans_against_new_synopsis():
    """A rebuild that lands while a submission waits in the admission queue
    invalidates the plan's literal encodings: the wave must re-plan against
    the new synopsis, not execute the stale plan (silently wrong) or fail.
    The doubled table makes a stale answer numerically obvious."""
    table = _make_table(4_000, seed=9)
    bigger = {k: np.concatenate([np.asarray(v), np.asarray(v)])
              for k, v in table.items()}
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=4),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=10_000.0)
    sql = "SELECT COUNT(*) FROM t WHERE a >= 0"
    fut = srv.submit(sql)                     # planned+tagged at old epoch
    fw.append_rows({k: np.asarray(v)[:100] for k, v in table.items()})
    fw.rebuild(bigger)        # merges the 100 appended rows: 8100 total
    srv.flush()
    res = fut.result(timeout=TIMEOUT)
    np.testing.assert_allclose(res.estimate, 8_100, rtol=1e-6)
    # the replanned result was cached under the NEW epoch: repeats hit it
    executed = srv.stats()["totals"]["queries_executed"]
    assert round(srv.query(sql).estimate) == 8_100
    assert srv.stats()["totals"]["queries_executed"] == executed
    srv.close()


def test_rebuild_mid_wave_execution_requeues_and_replans():
    """Regression for the wave-execution epoch window: a rebuild landing
    AFTER the wave's epoch pre-check but DURING scheduler execution must
    not pair the old plan with the new synopsis. The scheduler's per-item
    epoch re-validation (inside ``BatchScheduler.execute``) marks the item
    stale, the server re-enqueues the submission, and the next wave
    re-plans against the rebuilt table — the doubled table makes a stale
    answer numerically obvious."""
    table = _make_table(4_000, seed=21)
    bigger = {k: np.concatenate([np.asarray(v), np.asarray(v)])
              for k, v in table.items()}
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=5),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=5.0)
    real_execute = srv.scheduler.execute
    fired = []

    def racing_execute(items):
        if not fired:                 # first wave only: simulate the race
            fired.append(True)
            fw.rebuild(bigger)        # lands inside the wave, post pre-check
        return real_execute(items)

    srv.scheduler.execute = racing_execute
    res = srv.query("SELECT COUNT(*) FROM t WHERE a >= 0")
    np.testing.assert_allclose(res.estimate, 8_000, rtol=1e-6)
    assert srv.stats()["totals"]["admission"]["stale_requeues"] >= 1
    srv.close()


def test_stale_requeue_bypasses_block_backpressure():
    """The stale re-enqueue runs ON the admission worker thread; with the
    bounded queue full under shed_policy="block" it must bypass the bound
    — blocking there would deadlock the worker on the condition only it
    can drain, hanging every queued future."""
    table = _make_table(2_000, seed=23)
    bigger = {k: np.concatenate([np.asarray(v), np.asarray(v)])
              for k, v in table.items()}
    fw = AQPFramework(BuildParams(n_samples=1_000, seed=7),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=5.0, max_queue_depth=1,
                  shed_policy="block")
    real_execute = srv.scheduler.execute
    fired, extra = [], []

    def racing(items):
        if not fired:
            fired.append(True)
            # fill the bounded queue to its limit, then move the epoch:
            # the wave item's requeue now meets a FULL queue
            extra.append(srv.submit("SELECT COUNT(*) FROM t WHERE a >= 1"))
            fw.rebuild(bigger)
        return real_execute(items)

    srv.scheduler.execute = racing
    fut = srv.submit("SELECT COUNT(*) FROM t WHERE a >= 0")
    srv.flush()
    res = fut.result(timeout=TIMEOUT)          # pre-fix: deadlocked here
    np.testing.assert_allclose(res.estimate, 4_000, rtol=1e-6)
    assert extra[0].result(timeout=TIMEOUT).estimate is not None
    srv.close()


def test_stale_retry_bound_fails_futures():
    """A table rebuilt inside EVERY wave exhausts MAX_STALE_RETRIES and
    fails the future instead of re-enqueueing forever."""
    table = _make_table(2_000, seed=22)
    fw = AQPFramework(BuildParams(n_samples=1_000, seed=6),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=1.0)
    real_execute = srv.scheduler.execute

    def always_racing(items):
        fw.rebuild(table)             # epoch moves inside every wave
        return real_execute(items)

    srv.scheduler.execute = always_racing
    fut = srv.submit("SELECT COUNT(*) FROM t WHERE a >= 0")
    srv.flush()
    with pytest.raises(RuntimeError, match="epoch kept moving"):
        fut.result(timeout=TIMEOUT)
    srv.close()


def test_submit_after_close_fails_cleanly(framework):
    """submit() on a closed server rejects the future AND leaves no orphaned
    in-flight entry for later submits of the same SQL to attach to."""
    srv = _server(framework)
    srv.close()
    sql = "SELECT COUNT(a) FROM t WHERE b > 115"
    for _ in range(2):                        # second submit must not hang
        fut = srv.submit(sql)
        with pytest.raises(RuntimeError, match="closed"):
            fut.result(timeout=TIMEOUT)
    assert not srv._inflight


# ----------------------------------------------------------- backpressure


def test_streaming_rejection_resolves_future_typed(framework):
    """A full queue under shed_policy="reject" resolves the overflowing
    future with a typed AdmissionRejected RESULT (never an exception)."""
    srv = _server(framework, max_wait_ms=10_000.0, max_batch=64,
                  max_queue_depth=1, shed_policy="reject")
    ok = srv.submit("SELECT COUNT(a) FROM t WHERE b > 103")
    turned = srv.submit("SELECT COUNT(a) FROM t WHERE b > 104")
    res = turned.result(timeout=TIMEOUT)
    assert res.rejected and res.reason == "reject"
    assert res.as_tuple() == (None, None, None)
    assert res.queue_depth == 1
    srv.flush()
    assert ok.result(timeout=TIMEOUT).estimate is not None
    adm = srv.stats()["totals"]["admission"]
    assert adm["rejected"] == 1 and adm["shed"] == 0
    assert adm["queue_high_water"] == 1
    srv.close()


def test_shed_oldest_evicts_queued_future(framework):
    """shed_policy="shed_oldest": the oldest queued submission (and every
    duplicate future attached to it) resolves AdmissionRejected; the new
    arrival takes its place and is answered."""
    srv = _server(framework, max_wait_ms=10_000.0, max_batch=64,
                  max_queue_depth=1, shed_policy="shed_oldest")
    first = srv.submit("SELECT COUNT(a) FROM t WHERE b > 105")
    dup = srv.submit("SELECT COUNT(a) FROM t WHERE b > 105")    # attaches
    second = srv.submit("SELECT COUNT(a) FROM t WHERE b > 106")
    res = first.result(timeout=TIMEOUT)
    assert res.rejected and res.reason == "shed_oldest"
    assert dup.result(timeout=TIMEOUT).rejected                 # rides along
    srv.flush()
    assert second.result(timeout=TIMEOUT).estimate is not None
    adm = srv.stats()["totals"]["admission"]
    assert adm["shed"] == 1 and adm["rejected"] == 0            # per-submission
    assert not srv._inflight
    srv.close()


def test_query_batch_at_capacity_drains_and_retries(framework):
    """Regression: query_batch on a server whose queue is at capacity had
    no defined behavior. Now it drains and retries rejected submissions —
    a synchronous caller never sees AdmissionRejected."""
    srv = _server(framework, max_wait_ms=10_000.0, max_batch=64,
                  max_queue_depth=2, shed_policy="reject")
    sqls = [f"SELECT COUNT(a) FROM t WHERE b > {100 + i}" for i in range(8)]
    results = srv.query_batch(sqls)
    assert len(results) == 8
    assert all(not r.rejected and r.estimate is not None for r in results)
    adm = srv.stats()["totals"]["admission"]
    assert adm["rejected"] >= 1           # the bound actually bound
    assert adm["queue_high_water"] <= 2
    srv.close()


def test_query_batch_retry_timeout(framework):
    """The drain-and-retry budget is enforced: a zero budget with a full
    queue raises TimeoutError instead of retrying forever."""
    srv = _server(framework, max_wait_ms=10_000.0, max_batch=64,
                  max_queue_depth=1, shed_policy="reject")
    sqls = [f"SELECT COUNT(a) FROM t WHERE b > {110 + i}" for i in range(3)]
    with pytest.raises(TimeoutError, match="drain-and-retry"):
        srv.query_batch(sqls, retry_timeout_s=0.0)
    srv.close()


def test_append_rows_mid_flight_with_shed_interaction():
    """Epoch bump while submissions sit in a BOUNDED queue: the shed loser
    resolves AdmissionRejected (it was never executed, so it must NOT get
    the staleness error), the queued survivor fails with the staleness
    error at wave time, and nothing stale is cached."""
    table = _make_table(4_000, seed=21)
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=9),
                      use_compression=False).ingest(table)
    srv = _server(fw, max_wait_ms=10_000.0, max_batch=64,
                  max_queue_depth=1, shed_policy="shed_oldest")
    victim = srv.submit("SELECT COUNT(b) FROM t WHERE a < 250 GROUP BY cat")
    survivor = srv.submit("SELECT COUNT(a) FROM t WHERE b > 100")  # evicts
    res = victim.result(timeout=TIMEOUT)
    assert res.rejected and res.reason == "shed_oldest"
    fw.append_rows({k: np.asarray(v)[:100] for k, v in table.items()})
    srv.flush()
    with pytest.raises(RuntimeError, match="stale"):
        survivor.result(timeout=TIMEOUT)
    assert len(srv.result_cache) == 0
    # a NEW submit against the stale table fails at planning, not admission
    fut = srv.submit("SELECT COUNT(a) FROM t WHERE b > 100")
    with pytest.raises(RuntimeError, match="stale"):
        fut.result(timeout=TIMEOUT)
    fw.rebuild(table)
    assert srv.query("SELECT COUNT(a) FROM t WHERE b > 100").estimate \
        is not None
    srv.close()


# ------------------------------------------------------- GROUP BY batching


GROUP_SQLS = [
    "SELECT COUNT(b) FROM t WHERE a < 300 GROUP BY cat",
    "SELECT AVG(b) FROM t WHERE a > 100 AND b < 160 GROUP BY cat",
    "SELECT SUM(b) FROM t GROUP BY cat",
    "SELECT COUNT(*) FROM t WHERE b > 90 GROUP BY cat",
]


def _oracle_groups(framework, sql):
    """The unbatched sequential GROUP BY path (engine.execute -> _group_by)."""
    plan = framework.engine.plan_sql(sql)
    return framework.engine.execute(plan.func, plan.agg_col, plan.tree,
                                    plan.group_by).groups


def test_group_by_leaves_bit_for_bit_numpy(framework):
    """numpy-mode serving (leaf expansion, no kernels) is bit-for-bit equal
    to the sequential per-category loop."""
    srv = _server(framework, mode="numpy")
    for sql, res in zip(GROUP_SQLS, srv.query_batch(GROUP_SQLS)):
        assert res.groups == _oracle_groups(framework, sql), sql
    tm = srv.stats()["tables"]["t"]
    assert tm["group_by"]["queries"] == len(GROUP_SQLS)
    assert tm["group_by"]["leaves_executed"] == 6 * len(GROUP_SQLS)
    srv.close()


def test_group_by_leaves_batched_kernel_close(framework):
    """ref-mode serving fuses all six category leaves of each GROUP BY into
    batched launches; estimates match the oracle to fp tolerance."""
    srv = _server(framework, mode="ref")
    for sql, res in zip(GROUP_SQLS, srv.query_batch(GROUP_SQLS)):
        oracle = _oracle_groups(framework, sql)
        assert set(res.groups) == set(oracle), sql
        for value, triple in oracle.items():
            np.testing.assert_allclose(res.groups[value], triple,
                                       rtol=1e-4, atol=1e-6,
                                       err_msg=f"{sql} [{value}]")
    tm = srv.stats()["tables"]["t"]
    assert tm["batched"] > 0                  # leaves actually fused
    assert tm["group_by"]["leaves_executed"] > 0
    srv.close()


def test_overlapping_group_by_share_leaf_cache(framework):
    """Textual variants of one GROUP BY (clause order differs, so the
    normalized-SQL keys differ) share per-leaf cache entries: the second
    query executes zero leaves."""
    srv = _server(framework, mode="numpy")
    a = "SELECT COUNT(b) FROM t WHERE a < 200 GROUP BY cat"
    b = "SELECT COUNT(b) FROM t GROUP BY cat WHERE a < 200"
    res_a = srv.query(a)
    executed = srv.stats()["totals"]["queries_executed"]
    res_b = srv.query(b)
    assert res_b.groups == res_a.groups
    assert srv.stats()["totals"]["queries_executed"] == executed
    gb = srv.stats()["tables"]["t"]["group_by"]
    assert gb["leaf_cache_hits"] == 6         # all of b's leaves were shared
    srv.close()


def test_group_by_epoch_invalidates_leaf_cache():
    table = _make_table(4_000, seed=11)
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=5),
                      use_compression=False).ingest(table)
    srv = _server(fw, mode="numpy")
    sql = "SELECT COUNT(b) FROM t WHERE a < 250 GROUP BY cat"
    srv.query(sql)
    fw.append_rows({k: np.asarray(v)[:500] for k, v in table.items()})
    fw.rebuild(table)
    executed = srv.stats()["totals"]["queries_executed"]
    srv.query(sql)                            # leaf entries must NOT validate
    assert srv.stats()["totals"]["queries_executed"] == executed + 1
    srv.close()
