"""Pair-batched 2-D construction vs the legacy sequential per-pair loop.

The batched path (refine.refine_2d_batch / pair_metadata_batch driven by
build.build_pairs_batched) must be *bit-for-bit* equal to the legacy host
loop (build.build_pairs_sequential) in oracle (numpy/jnp) mode: every count
is an exact integer and every float statistic is computed by the same ops on
the same values. Covers NaN-masked rows, constant columns, the K2-capacity
guard, chunk bucketing, and the adaptive capacity ladder.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.build import build_pairwise_hist
from repro.core.types import BuildParams, ColumnInfo


def _table(n=6000, seed=7):
    rng = np.random.default_rng(seed)
    c0 = rng.integers(0, 500, n).astype(float)
    c1 = np.abs(rng.normal(300, 80, n)).round()
    c2 = (c1 * 2 + rng.normal(0, 25, n)).round()   # correlated with c1
    c3 = rng.zipf(1.7, n).clip(1, 40).astype(float)
    c3[rng.random(n) < 0.05] = np.nan              # NULL-heavy column
    c4 = np.full(n, 7.0)                           # constant column
    return np.stack([c0, c1, c2, c3, c4], 1)


def _cols(d):
    return [ColumnInfo(name=f"c{i}", kind="int") for i in range(d)]


def _assert_same_synopsis(a, b):
    for h1, h2 in zip(a.hists, b.hists):
        for f, x, y in zip(h1._fields, h1, h2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"hist field {f}")
    assert set(a.pairs) == set(b.pairs)
    for key in a.pairs:
        for f, x, y in zip(a.pairs[key]._fields, a.pairs[key], b.pairs[key]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"pair {key} field {f}")


@pytest.fixture(scope="module")
def data():
    return _table()


@pytest.fixture(scope="module")
def seq_synopsis(data):
    params = BuildParams(n_samples=data.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=False)
    return build_pairwise_hist(data, _cols(data.shape[1]), params)


def test_batched_equals_sequential_bitforbit(data, seq_synopsis):
    params = BuildParams(n_samples=data.shape[0], k2_cap=64, s2_max=16,
                         pair_batched=True, pair_chunk=4)
    batched = build_pairwise_hist(data, _cols(data.shape[1]), params)
    _assert_same_synopsis(seq_synopsis, batched)


def test_chunk_bucketing_invariance(data, seq_synopsis):
    """Chunk size (incl. non-pow2 -> padded dummy lanes) never changes bits."""
    for chunk in (1, 2, 3, 16):
        params = BuildParams(n_samples=data.shape[0], k2_cap=64, s2_max=16,
                             pair_batched=True, pair_chunk=chunk)
        batched = build_pairwise_hist(data, _cols(data.shape[1]), params)
        _assert_same_synopsis(seq_synopsis, batched)


def test_capacity_ladder_escalation(data):
    """A tiny first rung forces the guard to bind and the chunk to re-run
    one rung up; the escalated result must still match the legacy loop run
    directly at full capacity."""
    p_seq = BuildParams(n_samples=data.shape[0], k2_cap=128, s2_max=16,
                        pair_batched=False)
    p_esc = dataclasses.replace(p_seq, pair_batched=True, pair_chunk=4,
                                k2_start=4)
    seq = build_pairwise_hist(data, _cols(data.shape[1]), p_seq)
    esc = build_pairwise_hist(data, _cols(data.shape[1]), p_esc)
    _assert_same_synopsis(seq, esc)


def test_k2_capacity_guard(data):
    """At a deliberately tiny k2_cap the guard binds in both paths; the
    batched ladder is pinned at K2 and must reproduce the capped bins."""
    p_seq = BuildParams(n_samples=data.shape[0], k2_cap=8, s2_max=16,
                        pair_batched=False)
    p_bat = dataclasses.replace(p_seq, pair_batched=True)
    seq = build_pairwise_hist(data, _cols(data.shape[1]), p_seq)
    bat = build_pairwise_hist(data, _cols(data.shape[1]), p_bat)
    _assert_same_synopsis(seq, bat)
    for pr in bat.pairs.values():
        assert int(pr.kx) <= 8 and int(pr.ky) <= 8


def test_all_nan_pair_column():
    """A column that is NULL on every row yields empty pair histograms
    without breaking either path."""
    rng = np.random.default_rng(0)
    n = 2000
    data = np.stack([rng.integers(0, 100, n).astype(float),
                     np.full(n, np.nan),
                     np.abs(rng.normal(50, 10, n)).round()], 1)
    p_seq = BuildParams(n_samples=n, k2_cap=32, s2_max=16,
                        pair_batched=False)
    p_bat = dataclasses.replace(p_seq, pair_batched=True)
    seq = build_pairwise_hist(data, _cols(3), p_seq)
    bat = build_pairwise_hist(data, _cols(3), p_bat)
    _assert_same_synopsis(seq, bat)
    assert bat.columns[1].n_null == n
    assert float(bat.pairs[(0, 1)].H.sum()) == 0.0


def test_build_does_not_mutate_caller_columns(data):
    cols = _cols(data.shape[1])
    params = BuildParams(n_samples=data.shape[0], k2_cap=32, s2_max=16)
    syn = build_pairwise_hist(data, cols, params)
    assert all(c.n_null == 0 for c in cols), \
        "build_pairwise_hist mutated the caller's ColumnInfo list"
    assert syn.columns is not cols
    assert syn.columns[3].n_null > 0          # NaN column counted on the copy
    assert all(a is not b for a, b in zip(cols, syn.columns))


def test_device_presort_matches_host_presort():
    """The jitted presort (device-resident callers) and the host np.lexsort
    used by build must produce identical layouts — both are stable sorts on
    the same (+inf-keyed) keys, so every array matches exactly."""
    from repro.core.build import _presort_pairs_host
    from repro.core.refine import presort_pairs
    rng = np.random.default_rng(2)
    p, n = 3, 400
    x = rng.integers(0, 30, (p, n)).astype(float)   # many ties
    y = rng.integers(0, 30, (p, n)).astype(float)
    valid = rng.random((p, n)) < 0.9
    host = _presort_pairs_host(x, y, valid)
    import jax.numpy as jnp
    dev = presort_pairs(jnp.asarray(x), jnp.asarray(y), jnp.asarray(valid))
    for name, h, d in zip("xo1 yo1 vo1 new1 xo2 yo2 vo2 new2".split(),
                          host, dev):
        np.testing.assert_array_equal(h, np.asarray(d), err_msg=name)


def test_prep_columns_matches_per_column_reference():
    """Vectorized all-column prep == the straightforward per-column loop."""
    from repro.core.build import _prep_columns
    rng = np.random.default_rng(5)
    n, d = 500, 4
    sample = rng.normal(0, 10, (n, d)).round()
    sample[rng.random((n, d)) < 0.1] = np.nan
    sample[:, 2] = 3.0                         # constant column
    xs_all, up_all, nv, vmin, vmax = _prep_columns(sample)
    for i in range(d):
        x = sample[:, i].copy()
        nan = np.isnan(x)
        x[nan] = np.inf
        xs = np.sort(x)
        n_valid = int(x.size - nan.sum())
        new = np.empty(x.size, bool)
        new[0] = True
        new[1:] = xs[1:] != xs[:-1]
        up = np.concatenate([[0], np.cumsum(new)]).astype(np.int64)
        np.testing.assert_array_equal(xs_all[i], xs)
        np.testing.assert_array_equal(up_all[i], up)
        assert nv[i] == n_valid
        if n_valid:
            assert vmin[i] == xs[0] and vmax[i] == xs[n_valid - 1]
