"""Per-architecture smoke + numerical-consistency tests (reduced configs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def _cfg(arch):
    return dataclasses.replace(get_config(arch, smoke=True), dtype="float32",
                               capacity_factor=8.0)


def _inputs(cfg):
    if cfg.embed_inputs:
        inp = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
        return inp, {"embeds": inp,
                     "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return toks, {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_grads(arch):
    cfg = _cfg(arch)
    params = init_params(cfg, KEY)
    inp, batch = _inputs(cfg)
    logits = forward(params, cfg, inp)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = _cfg(arch)
    params = init_params(cfg, KEY)
    inp, _ = _inputs(cfg)
    cache = init_cache(cfg, B, S)
    lg_full, _ = prefill(params, cfg, inp, cache)
    cache2 = init_cache(cfg, B, S)
    _, cache2 = prefill(params, cfg, inp[:, : S - 1], cache2)
    last = inp[:, S - 1] if not cfg.embed_inputs else inp[:, S - 1: S]
    lg_last, _ = decode_step(params, cfg, last, cache2)
    np.testing.assert_allclose(np.asarray(lg_full[:, -1]),
                               np.asarray(lg_last[:, 0]),
                               rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_matches_prefill_logits(arch):
    cfg = _cfg(arch)
    params = init_params(cfg, KEY)
    inp, _ = _inputs(cfg)
    logits = forward(params, cfg, inp)
    cache = init_cache(cfg, B, S)
    lg_full, _ = prefill(params, cfg, inp, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(lg_full),
                               rtol=1e-3, atol=2e-4)


def test_moe_routing_mass_conservation():
    from repro.models import layers as L
    cfg = _cfg("dbrx_132b")
    p = L.init_moe(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    out = L.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())
    aux = L.moe_aux_loss(p, x, cfg)
    assert float(aux) >= 1.0 - 1e-6  # >= 1 by Cauchy-Schwarz at balance


def test_ssm_chunked_equals_naive_recurrence():
    """SSD chunked algorithm vs the literal per-step recurrence."""
    from repro.models import layers as L
    cfg = _cfg("mamba2_1_3b")
    p = L.init_ssm(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.float32)
    y_chunk, (state, conv) = L.ssm_apply(p, x, cfg)
    # step-by-step decode over the same inputs must produce the same outputs
    cache = L.ssm_cache(cfg, 1, jnp.float32)
    st, cv = cache["state"], cache["conv"]
    ys = []
    for t in range(32):
        y_t, (st, cv) = L.ssm_apply(p, x[:, t: t + 1], cfg, st, cv)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st),
                               rtol=2e-3, atol=2e-4)


def test_rglru_assoc_scan_equals_sequential():
    from repro.models import layers as L
    cfg = _cfg("recurrentgemma_9b")
    p = L.init_rglru(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    y_scan, (state, conv) = L.rglru_apply(p, x, cfg)
    cache = L.rglru_cache(cfg, 1, jnp.float32)
    st, cv = cache["state"], cache["conv"]
    ys = []
    for t in range(16):
        y_t, (st, cv) = L.rglru_apply(p, x[:, t: t + 1], cfg, st, cv)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)


def test_local_window_masks_distant_tokens():
    """gemma2 local layers must ignore tokens beyond the window."""
    cfg = dataclasses.replace(_cfg("gemma2_2b"), n_layers=2, window=8)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 48), 0, cfg.vocab)
    base = forward(params, cfg, toks)
    # perturbing a token > window+pattern away must not change the local-only
    # receptive field... with the global layer present it will; so instead
    # check pure-local config:
    cfg_local = dataclasses.replace(cfg, block_pattern=("attn_local",))
    params_l = init_params(cfg_local, KEY)
    base_l = forward(params_l, cfg_local, toks)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    out2 = forward(params_l, cfg_local, toks2)
    # last position is 47; window 8 x 2 layers -> receptive field 16 << 47
    np.testing.assert_allclose(np.asarray(base_l[0, -1]),
                               np.asarray(out2[0, -1]), atol=1e-5)


def test_param_counts_near_nominal():
    """Full configs must land near their nominal parameter counts."""
    from benchmarks.roofline import _params_of
    nominal = {
        "minitron-4b": 4.2e9, "mistral-nemo-12b": 12.2e9,
        "gemma2-2b": 2.6e9, "qwen3-0.6b": 0.6e9, "dbrx-132b": 132e9,
        "deepseek-moe-16b": 16.4e9, "internvl2-76b": 70e9,
        "mamba2-1.3b": 1.3e9, "recurrentgemma-9b": 9e9,
        "musicgen-medium": 1.5e9,
    }
    for arch, want in nominal.items():
        total, active = _params_of(arch)
        assert 0.55 * want < total < 1.6 * want, (arch, total, want)
        assert active <= total
