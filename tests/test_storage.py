"""Storage codec: exact round-trip + size accounting."""
import numpy as np

from repro.core import storage
from repro.core.query import QueryEngine


def test_roundtrip_structural(synopsis):
    blob = storage.encode(synopsis)
    ph2 = storage.decode(blob)
    assert ph2.d == synopsis.d
    assert ph2.n_rows == synopsis.n_rows
    for h1, h2 in zip(synopsis.hists, ph2.hists):
        np.testing.assert_allclose(h1.edges, h2.edges)
        np.testing.assert_allclose(h1.h, h2.h)
        np.testing.assert_allclose(h1.u, h2.u)
        np.testing.assert_allclose(h1.vmin, h2.vmin)
        np.testing.assert_allclose(h1.vmax, h2.vmax)
        # re-derived quantities
        np.testing.assert_allclose(h1.c, h2.c)
        np.testing.assert_allclose(h1.cminus, h2.cminus, rtol=1e-9)
        np.testing.assert_allclose(h1.cplus, h2.cplus, rtol=1e-9)
    for key in synopsis.pairs:
        p1, p2 = synopsis.pairs[key], ph2.pairs[key]
        np.testing.assert_allclose(p1.H, p2.H)
        np.testing.assert_allclose(p1.hx, p2.hx)
        np.testing.assert_allclose(p1.fold_x, p2.fold_x)
        np.testing.assert_allclose(p1.fold_y, p2.fold_y)


def test_roundtrip_query_identity(synopsis, exact):
    ph2 = storage.decode(storage.encode(synopsis))
    e1, e2 = QueryEngine(synopsis), QueryEngine(ph2)
    for sql in ("SELECT COUNT(c0) FROM t WHERE c1 > 300",
                "SELECT AVG(c2) FROM t WHERE c1 >= 250 AND c1 < 350",
                "SELECT MEDIAN(c1) FROM t WHERE c2 > 600"):
        r1, r2 = e1.query(sql), e2.query(sql)
        np.testing.assert_allclose(r1.as_tuple(), r2.as_tuple(), rtol=1e-9)


def test_size_is_compact(synopsis):
    rep = storage.synopsis_size_report(synopsis)
    assert rep["total"] < 1_000_000          # sub-MB (paper claim band)
    assert rep["total"] < 0.05 * synopsis.n_sampled * synopsis.d * 8
    # within 1.5x of the paper's Eq. 12 bound on integer data
    assert rep["total"] <= 1.5 * rep["eq12_bound"]


def test_counts_sparse_vs_dense_selection():
    from repro.core.storage import BitWriter, _encode_counts, _decode_counts, BitReader
    dense = np.ones((40, 40))
    sparse = np.zeros((40, 40))
    sparse[3, 7] = 9
    for mat in (dense, sparse):
        w = BitWriter()
        _encode_counts(w, mat)
        out = _decode_counts(BitReader(w.getvalue()), mat.shape)
        np.testing.assert_allclose(out, mat)
