"""Storage codec: exact round-trip + size accounting."""
import numpy as np

from repro.core import storage
from repro.core.query import QueryEngine


def test_roundtrip_structural(synopsis):
    blob = storage.encode(synopsis)
    ph2 = storage.decode(blob)
    assert ph2.d == synopsis.d
    assert ph2.n_rows == synopsis.n_rows
    for h1, h2 in zip(synopsis.hists, ph2.hists):
        np.testing.assert_allclose(h1.edges, h2.edges)
        np.testing.assert_allclose(h1.h, h2.h)
        np.testing.assert_allclose(h1.u, h2.u)
        np.testing.assert_allclose(h1.vmin, h2.vmin)
        np.testing.assert_allclose(h1.vmax, h2.vmax)
        # re-derived quantities
        np.testing.assert_allclose(h1.c, h2.c)
        np.testing.assert_allclose(h1.cminus, h2.cminus, rtol=1e-9)
        np.testing.assert_allclose(h1.cplus, h2.cplus, rtol=1e-9)
    for key in synopsis.pairs:
        p1, p2 = synopsis.pairs[key], ph2.pairs[key]
        np.testing.assert_allclose(p1.H, p2.H)
        np.testing.assert_allclose(p1.hx, p2.hx)
        np.testing.assert_allclose(p1.fold_x, p2.fold_x)
        np.testing.assert_allclose(p1.fold_y, p2.fold_y)


def test_roundtrip_query_identity(synopsis, exact):
    ph2 = storage.decode(storage.encode(synopsis))
    e1, e2 = QueryEngine(synopsis), QueryEngine(ph2)
    for sql in ("SELECT COUNT(c0) FROM t WHERE c1 > 300",
                "SELECT AVG(c2) FROM t WHERE c1 >= 250 AND c1 < 350",
                "SELECT MEDIAN(c1) FROM t WHERE c2 > 600"):
        r1, r2 = e1.query(sql), e2.query(sql)
        np.testing.assert_allclose(r1.as_tuple(), r2.as_tuple(), rtol=1e-9)


def test_size_is_compact(synopsis):
    rep = storage.synopsis_size_report(synopsis)
    assert rep["total"] < 1_000_000          # sub-MB (paper claim band)
    assert rep["total"] < 0.05 * synopsis.n_sampled * synopsis.d * 8
    # within 1.5x of the paper's Eq. 12 bound on integer data
    assert rep["total"] <= 1.5 * rep["eq12_bound"]


def test_counts_sparse_vs_dense_selection():
    from repro.core.storage import BitWriter, _encode_counts, _decode_counts, BitReader
    dense = np.ones((40, 40))
    sparse = np.zeros((40, 40))
    sparse[3, 7] = 9
    for mat in (dense, sparse):
        w = BitWriter()
        _encode_counts(w, mat)
        out = _decode_counts(BitReader(w.getvalue()), mat.shape)
        np.testing.assert_allclose(out, mat)


# ---------------------------------------------------- corruption corpus
# Deterministic complement to the hypothesis corpus in
# tests/test_storage_property.py (which only runs where hypothesis is
# installed): every corruption must surface as the typed IntegrityError
# from BOTH decoders and blob_info — wrong answers and hangs are the
# failure modes being excluded.

def _assert_rejected(data):
    import pytest
    for vectorized in (True, False):
        with pytest.raises(storage.IntegrityError):
            storage.decode(data, vectorized=vectorized)
    with pytest.raises(storage.IntegrityError):
        storage.blob_info(data)


def test_corruption_bit_flips_rejected(synopsis):
    blob = storage.encode(synopsis)
    rng = np.random.default_rng(42)
    # Every header byte plus a seeded payload sample: ANY single-bit flip
    # is caught (CRC over the payload; explicit length; the PWF1/PWH1
    # magics are 3 bits apart so no flip aliases one into the other).
    positions = list(range(12)) + sorted(
        int(p) for p in rng.integers(12, len(blob), 48))
    for pos in positions:
        bad = bytearray(blob)
        bad[pos] ^= 1 << int(rng.integers(0, 8))
        _assert_rejected(bytes(bad))


def test_corruption_truncations_rejected(synopsis):
    blob = storage.encode(synopsis)
    rng = np.random.default_rng(43)
    cuts = list(range(13)) + sorted(
        int(c) for c in rng.integers(13, len(blob), 24))
    for cut in cuts:
        _assert_rejected(blob[:cut])


def test_corruption_garbage_tails_rejected(synopsis):
    blob = storage.encode(synopsis)
    rng = np.random.default_rng(44)
    for n_tail in (1, 7, 64, 4096):
        tail = rng.integers(0, 256, n_tail, dtype=np.uint8).tobytes()
        _assert_rejected(blob + tail)
    _assert_rejected(b"")
    _assert_rejected(b"NOPE" + bytes(16))


def test_corruption_legacy_truncation_rejected(synopsis):
    # Legacy unframed streams have no CRC, but truncation still hits the
    # bit-reader overrun guards instead of hanging or zero-padding.
    import pytest
    raw = storage.encode(synopsis, framed=False)
    assert storage.decode(raw).n_rows == synopsis.n_rows
    rng = np.random.default_rng(45)
    for cut in sorted(int(c) for c in rng.integers(4, len(raw) - 1, 16)):
        for vectorized in (True, False):
            with pytest.raises(storage.IntegrityError):
                storage.decode(raw[:cut], vectorized=vectorized)


def test_framed_blob_info_reports_frame(synopsis):
    framed = storage.encode(synopsis)
    raw = storage.encode(synopsis, framed=False)
    assert storage.blob_info(framed)["framed"] is True
    assert storage.blob_info(raw)["framed"] is False
    # The frame costs exactly 12 bytes; the payload is unchanged.
    assert len(framed) == len(raw) + 12
    assert framed[12:] == raw
