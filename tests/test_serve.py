"""Serving engine: batched generation correctness."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve.engine import Request, ServeEngine


def _setup():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    """Single-request greedy loop straight on the model API."""
    cache = init_cache(cfg, 1, 512)
    logits, cache = prefill(params, cfg, prompt[None, :], cache)
    tok = int(np.argmax(np.asarray(logits[0, -1])))
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = decode_step(
            params, cfg, np.asarray([tok], np.int32), cache)
        tok = int(np.argmax(np.asarray(logits[0, 0])))
        out.append(tok)
    return out


def test_batched_generation_matches_single():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (12, 12, 12)]  # equal lengths: no padding effects
    engine = ServeEngine(cfg, params, batch_slots=3, max_len=128)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    engine.generate(reqs)
    for req in reqs:
        ref = _greedy_reference(cfg, params, req.prompt, 6)
        assert req.out_tokens == ref


def test_continuous_refill_more_requests_than_slots():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(5)]
    engine = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    engine.generate(reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert engine.last_stats["prefills"] >= 3  # refilled at least twice
