"""Documentation stays linted under the plain tier-1 pytest command:
scripts/check_docs.sh fails on broken intra-repo links, missing docstrings
on public serve/aqp surfaces, and knobs documented zero or multiple times."""
import pathlib
import subprocess

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_check_docs_passes():
    proc = subprocess.run(
        ["bash", str(REPO / "scripts" / "check_docs.sh")],
        capture_output=True, text=True)
    assert proc.returncode == 0, f"\n{proc.stdout}\n{proc.stderr}"
    assert "check_docs: OK" in proc.stdout


def test_docs_tree_complete():
    for name in ("architecture.md", "serving.md", "construction.md",
                 "benchmarks.md", "observability.md"):
        assert (REPO / "docs" / name).is_file(), f"docs/{name} missing"
    assert (REPO / "README.md").is_file()
