"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import coverage as covlib
from repro.core import chi2 as chi2lib
from repro.core.storage import BitReader, BitWriter

CRIT = chi2lib.build_crit_table(0.001, 64)


# ------------------------------------------------------------------ bit IO

@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(1, 33)),
                min_size=1, max_size=200))
def test_bitio_roundtrip(pairs):
    w = BitWriter()
    for val, nbits in pairs:
        w.write(val & ((1 << nbits) - 1), nbits)
    r = BitReader(w.getvalue())
    for val, nbits in pairs:
        assert r.read(nbits) == val & ((1 << nbits) - 1)


@given(st.lists(st.integers(0, 2**62), min_size=1, max_size=100))
def test_varint_roundtrip(values):
    w = BitWriter()
    for v in values:
        w.write_varint(v)
    r = BitReader(w.getvalue())
    assert [r.read_varint() for _ in values] == values


@given(st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=100))
def test_svarint_roundtrip(values):
    w = BitWriter()
    for v in values:
        w.write_svarint(v)
    r = BitReader(w.getvalue())
    assert [r.read_svarint() for _ in values] == values


@given(st.lists(st.integers(0, 10000), min_size=1, max_size=100),
       st.integers(0, 8))
def test_golomb_rice_roundtrip(values, b):
    w = BitWriter()
    for v in values:
        w.write_rice(v, b)
    r = BitReader(w.getvalue())
    assert [r.read_rice(b) for _ in values] == values


# ------------------------------------------------------------ GD round-trip

@given(st.integers(0, 2**31), st.integers(1, 6), st.integers(20, 300),
       st.floats(0, 0.3))
@settings(max_examples=25, deadline=None)
def test_gd_lossless(seed, d, n, null_frac):
    from repro.gd.greedygd import GreedyGD
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 10000, (n, d)).astype(float)
    data[rng.random((n, d)) < null_frac] = np.nan
    gd = GreedyGD(search_rows=200)
    ct = gd.compress(data)
    rec = gd.decompress(ct)
    assert np.array_equal(np.isnan(rec), np.isnan(data))
    assert np.allclose(np.nan_to_num(rec), np.nan_to_num(data))


# ------------------------------------------------------- coverage invariants

@given(st.integers(0, 2**31), st.sampled_from(["<", "<=", ">", ">=", "=",
                                               "!="]))
@settings(max_examples=50, deadline=None)
def test_coverage_in_unit_interval_and_bounds_ordered(seed, op):
    rng = np.random.default_rng(seed)
    k = rng.integers(2, 30)
    edges = np.sort(rng.uniform(0, 1000, k + 1))
    vmin = edges[:-1] + rng.uniform(0, 1, k) * np.diff(edges) * 0.2
    vmax = vmin + rng.uniform(0, 1, k) * (edges[1:] - vmin)
    h = rng.integers(0, 500, k).astype(float)
    u = np.minimum(rng.integers(1, 100, k), np.maximum(h, 1)).astype(float)
    value = rng.uniform(-100, 1100)
    beta = covlib.coverage_single(op, value, h, u, vmin, vmax)
    assert np.all(beta >= 0) and np.all(beta <= 1)
    lo, hi = covlib.coverage_bounds(beta, h, u, 100, CRIT, 64)
    assert np.all(lo <= beta + 1e-12)
    assert np.all(beta <= hi + 1e-12)
    assert np.all(lo >= 0) and np.all(hi <= 1)


# ------------------------------------------------------- interval algebra

_intervals = st.lists(
    st.tuples(st.floats(-1e6, 1e6), st.floats(0, 1e5)).map(
        lambda t: (t[0], t[0] + t[1])),
    min_size=1, max_size=5)


@given(_intervals, _intervals, st.floats(-1e6, 1e6))
@settings(max_examples=100, deadline=None)
def test_interval_union_intersection_membership(a, b, x):
    def member(ivs, v):
        return any(lo <= v <= hi for lo, hi in ivs)

    union = covlib.union_intervals([a, b])
    inter = covlib.intersect_intervals([a, b])
    assert member(union, x) == (member(a, x) or member(b, x))
    assert member(inter, x) == (member(a, x) and member(b, x))
    # disjointness of the union
    for (l1, h1), (l2, h2) in zip(union, union[1:]):
        assert h1 < l2


# -------------------------------------------------------- weightings order

_SYNOPSIS_CACHE = {}


def _shared_synopsis():
    """Module-cached synopsis (hypothesis forbids fixtures inside @given)."""
    if "ph" not in _SYNOPSIS_CACHE:
        from repro.core.build import build_pairwise_hist
        from repro.core.types import BuildParams, ColumnInfo
        rng = np.random.default_rng(1)
        n = 20_000
        c0 = rng.integers(0, 1000, n).astype(float)
        c1 = np.abs(rng.normal(300, 80, n)).round()
        c2 = (c1 * 3 + rng.normal(0, 30, n)).round()
        data = np.stack([c0, c1, c2], 1)
        cols = [ColumnInfo(name=f"c{i}", kind="int") for i in range(3)]
        _SYNOPSIS_CACHE["ph"] = build_pairwise_hist(
            data, cols, BuildParams(n_samples=n, seed=3))
    return _SYNOPSIS_CACHE["ph"]


@given(st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_weightings_bounds_ordered(seed):
    from repro.core import weightings as wlib
    synopsis = _shared_synopsis()
    rng = np.random.default_rng(seed)
    cols = rng.choice(synopsis.d, 2, replace=False)
    agg = int(cols[0])
    pred = int(cols[1])
    hist = synopsis.hists[pred]
    val = float(rng.uniform(hist.vmin.min(), hist.vmax.max()))
    op = rng.choice(["<", "<=", ">", ">=", "="])
    tree = wlib.Leaf(pred, str(op), val)
    w, wlo, whi = wlib.weightings(synopsis, agg, tree)
    assert np.all(wlo <= w + 1e-9)
    assert np.all(w <= whi + 1e-9)
    assert np.all(wlo >= -1e-9)
    assert np.all(whi <= synopsis.hists[agg].h + 1e-9)
