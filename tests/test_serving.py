"""Multi-table AQP serving subsystem: catalog, batching oracle-equivalence,
plan/result caches, staleness lifecycle, metrics."""
import dataclasses

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core.query import PlanError
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer, TableCatalog, normalize_sql


def _make_tables():
    rng = np.random.default_rng(7)
    n = 12_000
    sensors = {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "c": rng.integers(0, 50, n).astype(float),
    }
    logs = {
        "x": rng.integers(0, 300, n).astype(float),
        "y": np.abs(rng.normal(10, 3, n)).round(),
    }
    return sensors, logs


@pytest.fixture(scope="module")
def tables():
    return _make_tables()


@pytest.fixture(scope="module")
def frameworks(tables):
    params = BuildParams(n_samples=6_000, seed=1)
    sensors, logs = tables
    fws = {}
    for name, tbl in (("sensors", sensors), ("logs", logs)):
        fws[name] = AQPFramework(params=params,
                                 use_compression=False).ingest(tbl)
    return fws


def _server(frameworks, mode, **kwargs):
    srv = AQPServer(mode=mode, **kwargs)
    for name, fw in frameworks.items():
        srv.register(name, fw)
    return srv


def _mixed_workload():
    """>= 32 queries across 2 tables: AND batches, same-col, OR fallbacks,
    GROUP-BY-free aggregates of every kind."""
    sqls = []
    for thr in (60, 80, 100, 120, 140, 160):
        sqls.append(f"SELECT COUNT(a) FROM sensors WHERE b > {thr} AND c < 25")
        sqls.append(f"SELECT AVG(b) FROM sensors WHERE a < {thr * 3} AND c >= 5")
        sqls.append(f"SELECT SUM(b) FROM sensors WHERE b <= {thr + 60}")
        sqls.append(f"SELECT SUM(y) FROM logs WHERE x > {thr}")
        sqls.append(f"SELECT COUNT(*) FROM logs WHERE x < {thr} OR y > 12")
    sqls += [
        "SELECT MIN(b) FROM sensors WHERE b > 90 AND a < 400",
        "SELECT MAX(b) FROM sensors WHERE b < 180 AND c > 2",
        "SELECT MEDIAN(y) FROM logs WHERE x >= 50 AND x < 250",
        "SELECT VAR(y) FROM logs WHERE x > 20",
        "SELECT COUNT(*) FROM sensors WHERE (a < 100 OR c > 40) AND b > 70",
        "SELECT AVG(y) FROM logs",
    ]
    return sqls


# ------------------------------------------------------------------- catalog


def test_unknown_table_raises_plan_error(frameworks):
    srv = _server(frameworks, mode="numpy")
    with pytest.raises(PlanError) as exc:
        srv.query("SELECT COUNT(*) FROM nope WHERE a > 1")
    msg = str(exc.value)
    assert "unknown table 'nope'" in msg
    assert "logs" in msg and "sensors" in msg


def test_catalog_resolve_and_epoch(frameworks):
    cat = TableCatalog()
    cat.register("sensors", frameworks["sensors"])
    assert "sensors" in cat and "nope" not in cat
    assert cat.epoch("sensors") == frameworks["sensors"].epoch
    assert cat.epoch("nope") == -1
    with pytest.raises(PlanError):
        cat.resolve("nope")


# ------------------------------------------------- batched oracle equivalence


def test_batched_numpy_mode_bit_for_bit(frameworks):
    """numpy scheduler mode routes through the exact sequential code path."""
    srv = _server(frameworks, mode="numpy")
    sqls = _mixed_workload()
    assert len(sqls) >= 32
    got = srv.query_batch(sqls)
    for sql, res in zip(sqls, got):
        table = "sensors" if "sensors" in sql else "logs"
        ref = frameworks[table].engine.query(sql)
        assert res.as_tuple() == ref.as_tuple(), sql


def test_batched_kernel_mode_matches_sequential(frameworks):
    """Fused batched launches (jnp oracle of the Pallas kernel, f32) match
    the sequential f64 reference to fp tolerance; OR trees fall back and
    match exactly."""
    srv = _server(frameworks, mode="ref")
    sqls = _mixed_workload()
    got = srv.query_batch(sqls)
    n_batched = sum(t["batched"] for t in srv.stats()["tables"].values())
    assert n_batched >= 20          # the AND templates actually fused
    for sql, res in zip(sqls, got):
        table = "sensors" if "sensors" in sql else "logs"
        ref = frameworks[table].engine.query(sql)
        np.testing.assert_allclose(res.as_tuple(), ref.as_tuple(),
                                   rtol=1e-4, atol=1e-6, err_msg=sql)
        if " OR " in sql:           # fallback path: identical code
            assert res.as_tuple() == ref.as_tuple(), sql


def test_batched_pallas_interpret_matches_sequential(frameworks):
    srv = AQPServer(mode="pallas", min_group=1)
    for name, fw in frameworks.items():
        srv.register(name, fw)
    sqls = ["SELECT COUNT(a) FROM sensors WHERE b > 100 AND c < 30",
            "SELECT COUNT(a) FROM sensors WHERE b > 80 AND c < 40",
            "SELECT AVG(b) FROM sensors WHERE a < 300 AND c < 40",
            "SELECT SUM(y) FROM logs WHERE x > 120 AND y < 16",
            "SELECT COUNT(x) FROM logs WHERE x <= 240 AND y >= 6"]
    got = srv.query_batch(sqls)
    for sql, res in zip(sqls, got):
        table = "sensors" if "sensors" in sql else "logs"
        ref = frameworks[table].engine.query(sql)
        np.testing.assert_allclose(res.as_tuple(), ref.as_tuple(),
                                   rtol=1e-4, atol=1e-6, err_msg=sql)


# ------------------------------------------------------------------- caching


def test_plan_and_result_cache_hits(frameworks):
    srv = _server(frameworks, mode="ref")
    sql = "SELECT COUNT(a) FROM sensors WHERE b > 110 AND c < 20"
    first = srv.query(sql)
    again = srv.query("  SELECT  COUNT(a)  FROM sensors "
                      "WHERE b > 110 AND c < 20 ; ")   # same after normalize
    assert again.as_tuple() == first.as_tuple()
    st = srv.stats()["totals"]
    assert st["result_cache"]["hits"] == 1
    assert st["queries_executed"] == 1      # second answer came from cache
    # duplicate within one wave executes once
    res = srv.query_batch(["SELECT SUM(y) FROM logs WHERE x > 99"] * 5)
    assert len({r.as_tuple() for r in res}) == 1
    assert srv.stats()["totals"]["queries_executed"] == 2


def test_result_cache_byte_budget():
    """The byte budget evicts from the LRU end until the estimated
    footprint fits, counts those evictions separately, and drops a value
    larger than the whole budget outright."""
    from repro.serve.aqp.cache import LRUCache, approx_nbytes
    payload = np.zeros(1000)                     # ~8 KB each
    per_entry = approx_nbytes(payload)
    assert per_entry >= payload.nbytes
    cache = LRUCache(capacity=100, max_bytes=3 * per_entry)
    for i in range(5):
        cache.put(f"q{i}", "t", 1, payload)
    assert len(cache) == 3                       # budget, not capacity, binds
    assert cache.nbytes <= cache.max_bytes
    assert cache.byte_evictions == 2
    assert cache.get("q0", lambda t: 1) is None  # LRU end evicted
    assert cache.get("q4", lambda t: 1) is not None
    # refreshing an existing key replaces its bytes, not double-counts
    before = cache.nbytes
    cache.put("q4", "t", 1, payload)
    assert cache.nbytes == before
    # an oversized single value never sticks AND never churns warm
    # entries out on its way through
    cache.put("big", "t", 1, np.zeros(10_000))
    assert cache.get("big", lambda t: 1) is None
    assert len(cache) == 3                       # q2/q3/q4 survived
    assert cache.get("q4", lambda t: 1) is not None
    assert cache.nbytes <= cache.max_bytes
    # purge/stale eviction keep the ledger consistent
    cache.purge_table("t")
    assert cache.nbytes == 0 and len(cache) == 0
    st = cache.stats()
    assert st["max_bytes"] == 3 * per_entry
    assert st["byte_evictions"] == cache.byte_evictions


def test_server_max_result_bytes_knob(frameworks):
    """max_result_bytes wires through to the result cache and surfaces in
    the telemetry snapshot; a tiny budget keeps the cache near-empty but
    answers stay correct."""
    srv = _server(frameworks, mode="numpy", max_result_bytes=1)
    sqls = [f"SELECT COUNT(a) FROM sensors WHERE b > {100 + i}"
            for i in range(4)]
    res = srv.query_batch(sqls)
    assert all(r.estimate is not None for r in res)
    st = srv.stats()["totals"]["result_cache"]
    assert st["max_bytes"] == 1
    assert st["size"] == 0                   # every result outgrew the budget
    assert st["byte_evictions"] >= len(sqls)
    assert st["bytes"] == 0
    srv.close()


def test_normalize_sql():
    assert normalize_sql("  SELECT COUNT(*)\n FROM t ; ") \
        == "SELECT COUNT(*) FROM t"
    # quoted literals survive verbatim: the server parses the normalized
    # text, so 'New  York' must keep its double space (and distinct
    # literals must not collide onto one cache key)
    a = normalize_sql("SELECT COUNT(*) FROM t WHERE city = 'New  York'")
    b = normalize_sql("SELECT COUNT(*) FROM t WHERE city = 'New York'")
    assert "'New  York'" in a and a != b


def test_reregister_detaches_old_framework(tables):
    """A replaced framework can no longer purge its successor's caches."""
    sensors, _ = tables
    params = BuildParams(n_samples=2_000, seed=4)
    fw1 = AQPFramework(params=params, use_compression=False).ingest(sensors)
    fw2 = AQPFramework(params=params, use_compression=False).ingest(sensors)
    srv = AQPServer(mode="numpy").register("t", fw1)
    srv.register("t", fw2)               # replace: fw1 wiring detached
    sql = "SELECT COUNT(*) FROM t WHERE a >= 0"
    srv.query(sql)
    assert len(srv.result_cache) == 1
    fw1.append_rows({k: np.asarray(v)[:10] for k, v in sensors.items()})
    assert len(srv.result_cache) == 1    # fw1's bump didn't purge fw2 entries
    fw2.append_rows({k: np.asarray(v)[:10] for k, v in sensors.items()})
    assert len(srv.result_cache) == 0    # fw2's bump did


# ------------------------------------------------------- staleness lifecycle


def test_staleness_lifecycle_and_cache_invalidation(tables):
    sensors, _ = tables
    params = BuildParams(n_samples=4_000, seed=2)
    fw = AQPFramework(params=params, use_compression=False).ingest(sensors)
    srv = AQPServer(mode="ref").register("sensors", fw)

    sql = "SELECT COUNT(*) FROM sensors WHERE a >= 0"
    before = srv.query(sql)
    assert srv.query(sql).as_tuple() == before.as_tuple()  # cached

    extra = {k: np.asarray(v)[:2_000] for k, v in sensors.items()}
    fw.append_rows(extra)
    assert fw.is_stale
    with pytest.raises(RuntimeError, match="stale"):
        srv.query(sql)                  # cache is NOT consulted when stale
    with pytest.raises(RuntimeError, match="stale"):
        fw.query(sql)                   # single-table contract unchanged

    fw.rebuild(sensors)
    after = srv.query(sql)
    assert after.estimate is not None
    # the rebuilt table has 2k more rows: a stale cached COUNT would be wrong
    assert after.estimate > before.estimate
    np.testing.assert_allclose(after.estimate, fw.synopsis.n_rows, rtol=1e-6)
    # batched path after rebuild uses the NEW synopsis's kernel stacks
    # (stack cache lives on the PairwiseHist, dies with it)
    batched_sql = "SELECT COUNT(a) FROM sensors WHERE b > 100 AND c < 25"
    got = srv.query_batch([batched_sql,
                           "SELECT COUNT(a) FROM sensors "
                           "WHERE b > 120 AND c < 25"])
    ref = fw.engine.query(batched_sql)
    np.testing.assert_allclose(got[0].as_tuple(), ref.as_tuple(),
                               rtol=1e-4, atol=1e-6)


def test_epoch_bumps(tables):
    sensors, _ = tables
    params = BuildParams(n_samples=2_000, seed=3)
    fw = AQPFramework(params=params, use_compression=False)
    seen = []
    fw.on_invalidate(lambda f: seen.append(f.epoch))
    fw.ingest(sensors)
    fw.append_rows({k: np.asarray(v)[:100] for k, v in sensors.items()})
    fw.rebuild(sensors)
    # epochs are strictly increasing and drawn from a process-global
    # sequence: no two frameworks can ever share an epoch value
    assert len(seen) == 3 and seen == sorted(set(seen))
    fw2 = AQPFramework(params=params, use_compression=False)
    fw2.ingest({k: np.asarray(v)[:500] for k, v in sensors.items()})
    assert fw2.epoch > fw.epoch


def test_replacing_table_via_catalog_cannot_serve_stale(tables):
    """Even bypassing AQPServer.register (raw catalog swap), globally
    unique epochs make the old table's cached results unservable."""
    sensors, _ = tables
    params = BuildParams(n_samples=2_000, seed=5)
    small = {k: np.asarray(v)[:4_000] for k, v in sensors.items()}
    big = {k: np.asarray(v)[:9_000] for k, v in sensors.items()}
    fw1 = AQPFramework(params=params, use_compression=False).ingest(small)
    fw2 = AQPFramework(params=params, use_compression=False).ingest(big)
    srv = AQPServer(mode="numpy").register("t", fw1)
    sql = "SELECT COUNT(*) FROM t WHERE a >= 0"
    assert round(srv.query(sql).estimate) == 4_000
    srv.catalog.register("t", fw2)       # raw swap, no server wiring
    assert round(srv.query(sql).estimate) == 9_000


def test_unregister_and_close_detach(tables):
    sensors, _ = tables
    params = BuildParams(n_samples=2_000, seed=6)
    fw = AQPFramework(params=params, use_compression=False).ingest(sensors)
    srv = AQPServer(mode="numpy").register("t", fw)
    srv.query("SELECT COUNT(*) FROM t WHERE a >= 0")
    srv.unregister("t")
    assert len(srv.result_cache) == 0 and not fw._invalidate_cbs
    with pytest.raises(PlanError):
        srv.query("SELECT COUNT(*) FROM t WHERE a >= 0")
    srv2 = AQPServer(mode="numpy").register("t", fw)
    srv2.close()
    assert not fw._invalidate_cbs       # discarded server is unreferenced


# ---------------------------------------------------------------- cold tier


@pytest.fixture(scope="module")
def cold_blob(tables):
    """A bit-packed synopsis blob + its CompressedTable, built GD-natively."""
    from repro.core import storage
    sensors, _ = tables
    fw = AQPFramework(params=BuildParams(n_samples=4_000, seed=11),
                      use_compression=True).ingest(sensors)
    return storage.encode(fw.synopsis), fw.compressed, fw


def test_cold_catalog_lazy_decode_once(cold_blob):
    blob, compressed, fw = cold_blob
    srv = AQPServer(mode="numpy")
    srv.register_cold("sensors", blob, compressed=compressed)
    cold = srv.catalog.resolve("sensors")
    # Registration and epoch reads never decode (submit-path safety).
    assert srv.catalog.epoch("sensors") == cold.epoch
    assert cold.cold_info()["decoded"] is False and cold.decode_count == 0
    sql = "SELECT COUNT(a) FROM sensors WHERE b > 100"
    res = srv.query(sql)
    assert cold.decode_count == 1
    # Decoded synopsis answers like the live framework it was encoded from.
    ref = fw.engine.query(sql)
    np.testing.assert_allclose(res.as_tuple(), ref.as_tuple(),
                               rtol=1e-9, atol=1e-9)
    # Subsequent queries reuse the decoded engine — decode-once.
    srv.query("SELECT AVG(b) FROM sensors WHERE a < 300")
    assert cold.decode_count == 1
    st = srv.stats()["tables"]["sensors"]["cold"]
    assert st["decodes"] == 1 and st["synopsis_bytes"] == len(blob)
    assert st["decode_ms"] is not None and st["decode_ms"] > 0
    srv.close()


def test_cold_epoch_stable_across_decode_bumps_on_rebuild(cold_blob):
    blob, compressed, _ = cold_blob
    srv = AQPServer(mode="numpy")
    srv.register_cold("sensors", blob, compressed=compressed)
    cold = srv.catalog.resolve("sensors")
    e0 = srv.catalog.epoch("sensors")
    srv.query("SELECT COUNT(*) FROM sensors WHERE a >= 0")
    # The first decode changes representation, not table state: epoch-keyed
    # cache entries written after it stay valid.
    assert srv.catalog.epoch("sensors") == e0
    assert len(srv.result_cache) == 1
    # GD-native rebuild: fresh epoch, invalidation purges the caches.
    cold.rebuild()
    assert srv.catalog.epoch("sensors") > e0
    assert len(srv.result_cache) == 0
    res = srv.query("SELECT COUNT(*) FROM sensors WHERE a >= 0")
    assert res.estimate is not None
    assert cold.decode_count == 1       # rebuild publishes directly, no decode
    assert cold.cold_info()["bytes"] > 0
    srv.close()


def test_register_cold_invalid_blob_leaves_no_phantom_metrics():
    """Regression: ``register_cold`` recorded cold telemetry *before* the
    blob's magic was validated, so a rejected registration left a phantom
    metrics entry (and a ``cold`` stats section) for a table that was
    never registered. Validation must come first."""
    srv = AQPServer(mode="numpy")
    with pytest.raises(ValueError):
        srv.register_cold("ghost", b"NOPE" + b"\x00" * 64)
    assert "ghost" not in srv.catalog
    assert "ghost" not in srv.stats()["tables"]
    assert "ghost" not in srv.metrics._tables
    srv.close()


def test_register_cold_corrupted_blob_rejected_at_registration(cold_blob):
    """A bit-flipped or truncated blob is refused AT registration (typed
    IntegrityError from the frame check), before any metrics/catalog entry
    exists — corruption is caught at the door, not at first query."""
    from repro.core.storage import IntegrityError
    blob, _, _ = cold_blob
    flipped = bytearray(blob)
    flipped[len(blob) // 2] ^= 0x10
    for bad in (bytes(flipped), blob[: len(blob) // 2]):
        srv = AQPServer(mode="numpy")
        with pytest.raises(IntegrityError):
            srv.register_cold("ghost", bad)
        assert "ghost" not in srv.catalog
        assert "ghost" not in srv.stats()["tables"]
        srv.close()


def test_cold_first_query_decode_failure_is_typed_with_telemetry(cold_blob):
    """Decode failing on FIRST access (blob fine at registration, fault at
    decode time) resolves typed and records retry/quarantine telemetry —
    queriers never hang on a sick cold table."""
    from repro.serve.aqp import TableQuarantinedError, faults
    blob, _, _ = cold_blob
    srv = AQPServer(mode="numpy")
    srv.register_cold("sensors", blob, decode_retries=1,
                      decode_backoff_s=0.001)
    plan = faults.FaultPlan().fail("cold_decode", first=2)
    with faults.installed(plan):
        fut = srv.submit("SELECT COUNT(a) FROM sensors WHERE b > 100")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut.result(timeout=30)
    flt = srv.stats()["totals"]["faults"]
    assert flt["decode_retries"] == 1 and flt["quarantined"] == 1
    cold = srv.catalog.resolve("sensors")
    assert cold.quarantined
    assert cold.cold_info()["quarantined"] is True
    assert cold.cold_info()["decode_failures"] == 2
    srv.close()


def test_cold_quarantine_reregister_recovers_cleanly(cold_blob):
    """Quarantine -> re-register lifecycle: the replacement table serves,
    the breaker state is gone, and no stale failure telemetry leaks into
    the fresh table's stats."""
    from repro.serve.aqp import TableQuarantinedError, faults
    blob, compressed, fw = cold_blob
    srv = AQPServer(mode="numpy")
    srv.register_cold("sensors", blob, decode_retries=0,
                      decode_backoff_s=0.001)
    with faults.installed(faults.FaultPlan().fail("cold_decode", at=[0])):
        fut = srv.submit("SELECT COUNT(a) FROM sensors WHERE b > 100")
        srv.flush()
        with pytest.raises(TableQuarantinedError):
            fut.result(timeout=30)
    srv.register_cold("sensors", blob, compressed=compressed)
    cold = srv.catalog.resolve("sensors")
    assert not cold.quarantined and cold.decode_failures == 0
    sql = "SELECT COUNT(a) FROM sensors WHERE b > 100"
    res = srv.query(sql)
    np.testing.assert_allclose(res.as_tuple(),
                               fw.engine.query(sql).as_tuple(),
                               rtol=1e-9, atol=1e-9)
    st = srv.stats()["tables"]["sensors"]["cold"]
    assert st["decodes"] == 1
    srv.close()


def test_cold_rebuild_without_compressed_table_refuses(cold_blob):
    blob, _, _ = cold_blob
    cat = TableCatalog()
    cold = cat.register_cold("t", blob)          # no CompressedTable attached
    with pytest.raises(RuntimeError, match="CompressedTable"):
        cold.rebuild()


def test_cold_concurrent_first_access_decodes_once(cold_blob):
    """No stale serve mid-decode: concurrent first readers block on the one
    decode and all observe the same atomic (engine, epoch) pair."""
    import threading
    blob, compressed, _ = cold_blob
    cat = TableCatalog()
    cat.register_cold("t", blob, compressed=compressed)
    cold = cat.resolve("t")
    seen = []
    barrier = threading.Barrier(8)

    def reader():
        barrier.wait()
        seen.append(cat.snapshot("t"))

    threads = [threading.Thread(target=reader) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cold.decode_count == 1
    engines = {id(eng) for eng, _ in seen}
    epochs = {ep for _, ep in seen}
    assert len(engines) == 1 and len(epochs) == 1
    assert epochs == {cold.epoch}


# ------------------------------------------------------------------- metrics


def test_metrics_snapshot(frameworks):
    srv = _server(frameworks, mode="ref")
    srv.query_batch(_mixed_workload())
    snap = srv.stats()
    for name in ("sensors", "logs"):
        tm = snap["tables"][name]
        assert tm["queries_executed"] > 0
        assert tm["p50_ms"] is not None and tm["p99_ms"] is not None
        assert tm["p50_ms"] <= tm["p99_ms"] + 1e-9
    assert 0.0 < snap["totals"]["batched_fraction"] <= 1.0
    assert "hit_rate" in snap["totals"]["plan_cache"]
