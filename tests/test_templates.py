"""Zero-parse plan templates: fingerprint -> PlanTemplate bind fidelity.

The contract under test is *bit-for-bit equality*: a template-hit plan must
be indistinguishable — ``canonical_key`` and executed results — from the
plan the cold ``parse_sql`` -> ``plan_query`` path produces for the same
text, across every template shape the engine supports (consolidation, OR
trees, GROUP BY expansion, categorical literals, COUNT(*)). On top of
that, the serving integration: the template-hit path performs ZERO
``parse_sql`` calls (counter-based), deferred wave binds group by template,
epoch bumps invalidate compiled templates, and the planner pool offload
returns identical answers.
"""
import threading

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core import sql as sqlmod
from repro.core.query import PlanError
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer

TIMEOUT = 30


def _make_table(n=8_000, seed=11):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "c": rng.integers(0, 50, n).astype(float),
        "cat": np.array(["r", "g", "b", "c", "m", "y"])[
            rng.integers(0, 6, n)],
    }


@pytest.fixture(scope="module")
def framework():
    return AQPFramework(BuildParams(n_samples=4_000, seed=2),
                        use_compression=False).ingest(_make_table())


def _server(framework, **kwargs):
    kwargs.setdefault("mode", "numpy")
    return AQPServer(**kwargs).register("t", framework)


# Shape corpus: (template, literal dicts). Covers plain AND, same-column
# consolidation, OR/nested trees, COUNT(*), MIN/MAX snapping, categorical
# string literals (seen and unseen), and GROUP BY expansion.
CORPUS = [
    ("SELECT COUNT(*) FROM t WHERE a > {p} AND b < {q}",
     [dict(p=100, q=130), dict(p=250.5, q=90), dict(p=-5, q=1.1e2)]),
    ("SELECT SUM(b) FROM t WHERE a >= {p} AND a <= {q}",
     [dict(p=50, q=400), dict(p=0, q=499)]),
    ("SELECT AVG(b) FROM t WHERE a < {p} OR c > {q}",
     [dict(p=100, q=40), dict(p=350, q=10)]),
    ("SELECT MIN(b) FROM t WHERE b > {p} AND b < {q} AND c > {r}",
     [dict(p=60, q=160, r=5), dict(p=90, q=140, r=20)]),
    ("SELECT MAX(b) FROM t WHERE (a < {p} OR c > {q}) AND b > {r}",
     [dict(p=100, q=40, r=70), dict(p=400, q=45, r=100)]),
    ("SELECT COUNT(*) FROM t WHERE cat = '{p}' AND a > {q}",
     [dict(p="r", q=100), dict(p="g", q=250), dict(p="zz", q=10)]),
    ("SELECT COUNT(b) FROM t WHERE a < {p} GROUP BY cat",
     [dict(p=300), dict(p=120)]),
    ("SELECT COUNT(*) FROM t GROUP BY cat WHERE b > {p}",
     [dict(p=90), dict(p=140)]),
    ("SELECT VAR(b) FROM t",
     [dict()]),
]


def _instances(shape, variants):
    return [shape.format(**v) for v in variants]


# ------------------------------------------------------ engine-level fidelity


def test_template_bind_bit_for_bit(framework):
    eng = framework.engine
    for shape, variants in CORPUS:
        texts = _instances(shape, variants)
        tmpl = eng.plan_template(sqlmod.parse_sql(texts[0]))
        fps = [sqlmod.fingerprint_sql(t) for t in texts]
        assert len({fp.shape for fp in fps}) == 1
        batch = tmpl.bind_batch([fp.literals for fp in fps])
        for text, fp, bplan in zip(texts, fps, batch):
            cold = eng.plan_sql(text)
            for hot in (tmpl.bind(fp.literals), bplan):
                assert hot.canonical_key() == cold.canonical_key(), text
                assert ([lf.canonical_key() for lf in hot.leaf_plans]
                        == [lf.canonical_key() for lf in cold.leaf_plans])
                rc, rh = eng.execute_plan(cold), eng.execute_plan(hot)
                assert rc.as_tuple() == rh.as_tuple(), text
                assert rc.groups == rh.groups, text


def test_template_slot_count_guard(framework):
    eng = framework.engine
    tmpl = eng.plan_template(
        sqlmod.parse_sql("SELECT COUNT(*) FROM t WHERE a > 1 AND b < 2"))
    assert tmpl.n_slots == 2
    with pytest.raises(PlanError):
        tmpl.bind((1.0,))
    with pytest.raises(PlanError):
        tmpl.bind_batch([(1.0, 2.0), (3.0,)])


def test_template_bad_literal_matches_cold_error(framework):
    # A quoted non-numeric literal on a numeric column fails identically on
    # the template path and the cold path (same encode, same exception).
    eng = framework.engine
    good = "SELECT COUNT(*) FROM t WHERE a = 5"
    bad = "SELECT COUNT(*) FROM t WHERE a = 'oops'"
    tmpl = eng.plan_template(sqlmod.parse_sql(good))
    fp = sqlmod.fingerprint_sql(bad)
    assert fp.shape == sqlmod.fingerprint_sql(good).shape
    with pytest.raises(ValueError):
        eng.plan_sql(bad)
    with pytest.raises(ValueError):
        tmpl.bind(fp.literals)
    # Batch fallback still binds the good rows.
    good_fp = sqlmod.fingerprint_sql(good)
    with pytest.raises(ValueError):
        tmpl.bind_batch([good_fp.literals, fp.literals])


def test_canonical_key_memoized(framework):
    plan = framework.engine.plan_sql("SELECT COUNT(*) FROM t WHERE a > 9")
    k1 = plan.canonical_key()
    assert plan._ckey == k1
    assert plan.canonical_key() is k1          # cached string, not rebuilt


def test_group_by_leaf_exec_col_invariant(framework):
    # Satellite: _expand_group_by computes exec_col once per plan; every
    # leaf must agree, and match the documented min-column rule.
    plan = framework.engine.plan_sql(
        "SELECT COUNT(*) FROM t WHERE b > 90 GROUP BY cat")
    exec_cols = {leaf.exec_col for leaf in plan.leaf_plans}
    assert len(exec_cols) == 1
    gcol = plan.group_by
    bcol = framework.engine.ph.col_index("b")
    assert exec_cols == {min(gcol, bcol)}


# ------------------------------------------------------- serving integration


def test_server_template_hits_skip_parse_entirely(framework):
    srv = _server(framework)
    shape = "SELECT COUNT(*) FROM t WHERE a > {p} AND b < {q}"
    # Cold: compiles the template (parses exactly this query).
    cold = srv.query(shape.format(p=42, q=150))
    # Hit phase: distinct literals (no plan/result-cache hits possible) —
    # the zero-parse guarantee, asserted by counting parse_sql calls.
    hits = [shape.format(p=p, q=q)
            for p in (10, 60, 110, 210, 310) for q in (80, 120, 160)]
    before = sqlmod.parse_calls()
    res = srv.query_batch(hits)
    assert sqlmod.parse_calls() == before
    assert cold.estimate is not None
    for sql, r in zip(hits, res):
        assert r.as_tuple() == framework.engine.query(sql).as_tuple()
    snap = srv.stats()
    tc = snap["totals"]["template_cache"]
    assert tc["hits"] >= len(hits)
    assert tc["hit_rate"] > 0
    srv.close()


def test_server_template_group_by_deferred_bind(framework):
    srv = _server(framework)
    shape = "SELECT COUNT(b) FROM t WHERE a < {p} GROUP BY cat"
    srv.query(shape.format(p=777))            # compile
    sqls = [shape.format(p=p) for p in (50, 150, 250)]
    want = [framework.engine.query(s) for s in sqls]   # parses; outside count
    before = sqlmod.parse_calls()
    got = [srv.query(s) for s in sqls]
    assert sqlmod.parse_calls() == before
    for g, w in zip(got, want):
        assert g.groups == w.groups
    srv.close()


def test_server_templates_off_still_serves(framework):
    srv = _server(framework, plan_templates=False)
    sql = "SELECT COUNT(*) FROM t WHERE a > 33 AND b < 170"
    assert (srv.query(sql).as_tuple()
            == framework.engine.query(sql).as_tuple())
    assert srv.stats()["totals"]["template_cache"]["hits"] == 0
    srv.close()


def test_template_cache_epoch_invalidation():
    table = _make_table(n=4_000, seed=21)
    fw = AQPFramework(BuildParams(n_samples=2_000, seed=3),
                      use_compression=False).ingest(table)
    srv = _server(fw)
    shape = "SELECT COUNT(*) FROM t WHERE a > {p}"
    srv.query(shape.format(p=10))
    assert srv.query(shape.format(p=20)).estimate is not None
    fw.append_rows({k: np.asarray(v)[:50] for k, v in table.items()})
    fw.rebuild(table)
    # Old-epoch template must not answer post-rebuild queries: the purge +
    # epoch-keyed get force a cold re-plan (which recompiles the template).
    sql = shape.format(p=30)
    got = srv.query(sql)
    assert got.as_tuple() == fw.engine.query(sql).as_tuple()
    tmpl_entry = srv.template_cache.get(
        sqlmod.fingerprint_sql(sql).shape, srv.catalog.epoch)
    assert tmpl_entry is not None and tmpl_entry.epoch == fw.epoch
    srv.close()


def test_server_bad_template_literal_fails_only_that_query(framework):
    srv = _server(framework)
    shape = "SELECT COUNT(*) FROM t WHERE a = {p}"
    srv.query(shape.format(p=5))              # compile the shape
    good = srv.submit(shape.format(p=7))
    bad = srv.submit("SELECT COUNT(*) FROM t WHERE a = 'oops'")
    srv.flush()
    assert good.result(timeout=TIMEOUT).estimate is not None
    with pytest.raises(ValueError):
        bad.result(timeout=TIMEOUT)
    srv.close()


def test_planner_pool_equivalence_and_errors(framework):
    srv = _server(framework, planner_workers=2)
    sqls = [f"SELECT COUNT(*) FROM t WHERE a > {p} AND c < {q}"
            for p in (10, 90, 170) for q in (20, 45)]
    res = srv.query_batch(sqls)
    for sql, r in zip(sqls, res):
        assert r.as_tuple() == framework.engine.query(sql).as_tuple()
    # Cold planning errors surface on the future, same as inline planning.
    fut = srv.submit("SELECT COUNT(*) FROM nope WHERE a > 1")
    with pytest.raises(PlanError):
        fut.result(timeout=TIMEOUT)
    srv.close()


def test_planner_pool_concurrent_submitters(framework):
    srv = _server(framework, planner_workers=2)
    shapes = ["SELECT COUNT(*) FROM t WHERE a > {} AND b < 150",
              "SELECT SUM(b) FROM t WHERE c > {}"]
    futs, lock = [], threading.Lock()

    def blast(seed):
        rng = np.random.default_rng(seed)
        mine = [srv.submit(shapes[i % 2].format(int(rng.integers(0, 400))))
                for i in range(20)]
        with lock:
            futs.extend(mine)

    threads = [threading.Thread(target=blast, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.flush()
    for fut in futs:
        r = fut.result(timeout=TIMEOUT)
        assert r.estimate is not None and not r.rejected
    srv.close()


def test_explain_and_metrics_label_plan_path(framework):
    srv = _server(framework, trace_enabled=True)
    shape = "SELECT AVG(b) FROM t WHERE a > {p}"
    cold = srv.query(shape.format(p=111))
    hot = srv.query(shape.format(p=222))
    assert cold.explain["plan_path"] == "full"
    assert hot.explain["plan_path"] == "template"
    # Exact-text repeat: plan-cache hit, then served from the result cache.
    again = srv.query(shape.format(p=222))
    assert again.explain["plan_path"] == "plan_cache"
    assert again.explain["result_cache_hit"]
    stages = srv.stats()["totals"]["stages"]
    assert stages["plan_full"]["p50_ms"] is not None
    assert stages["plan_template_hit"]["p50_ms"] is not None
    srv.close()
