"""Hypothesis property tests: the admission-policy state machine.

Generalizes the seeded interleaving checks in ``test_stress_serving.py``:
for ANY generated interleaving of submit / flush / timeout waits against a
bounded ``StreamingAdmission``, every submitted item is handed to exactly
one of the execute callback (inside exactly one wave) or the shed callback
— never both, never twice, never dropped — and the queue bound holds.
Skips cleanly when hypothesis is unavailable (same pattern as
``test_property.py``).
"""
import time
from collections import Counter

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.aqp import StreamingAdmission  # noqa: E402

_OPS = st.lists(
    st.one_of(
        st.just(("submit", 0)),
        st.just(("flush", 0)),
        st.integers(0, 3).map(lambda ms: ("sleep", ms)),
    ),
    min_size=1, max_size=50)


def _drive(ops, adm):
    """Apply one generated op sequence; returns the submitted items."""
    submitted = []
    for op, arg in ops:
        if op == "submit":
            item = len(submitted)
            submitted.append(item)
            adm.submit(item)
        elif op == "flush":
            adm.flush()
        else:
            time.sleep(arg / 1e3)
    return submitted


@given(ops=_OPS, max_batch=st.integers(1, 4), max_queue=st.integers(1, 4),
       policy=st.sampled_from(["reject", "shed_oldest"]),
       slow_us=st.sampled_from([0, 500]))
@settings(max_examples=40, deadline=None)
def test_every_item_resolves_exactly_once(ops, max_batch, max_queue, policy,
                                          slow_us):
    """submit/flush/timeout/shed interleavings: exactly-once hand-off."""
    executed, shed = [], []

    def execute(batch, stats):
        if slow_us:
            time.sleep(slow_us / 1e6)    # slow consumer: forces full queues
        executed.extend(batch)

    adm = StreamingAdmission(
        execute, max_wait_ms=0.5, max_batch=max_batch,
        max_queue_depth=max_queue, shed_policy=policy,
        shed_cb=lambda item, reason, depth: shed.append(item))
    submitted = _drive(ops, adm)
    adm.close()                          # drains the remainder; joins worker
    assert Counter(executed) + Counter(shed) == Counter(submitted)
    assert adm.high_water <= max_queue
    with pytest.raises(RuntimeError, match="closed"):
        adm.submit(object())


@given(ops=_OPS, max_batch=st.integers(1, 4), max_queue=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_block_policy_never_sheds(ops, max_batch, max_queue):
    """block: the producer is paced, so every item executes — the shed
    callback must never fire and the bound must still hold."""
    executed, shed = [], []
    adm = StreamingAdmission(
        lambda batch, stats: executed.extend(batch),
        max_wait_ms=0.5, max_batch=max_batch,
        max_queue_depth=max_queue, shed_policy="block",
        shed_cb=lambda item, reason, depth: shed.append(item))
    submitted = _drive(ops, adm)
    adm.close()
    assert shed == []
    assert Counter(executed) == Counter(submitted)
    assert adm.high_water <= max_queue
