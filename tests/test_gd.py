"""GreedyGD compression + preprocessing."""
import numpy as np

from repro.gd.greedygd import GreedyGD
from repro.gd.preprocess import preprocess_column, preprocess_table


def test_preprocess_float_to_int():
    codes, info = preprocess_column(np.array([10.22, 10.25, 9.99]), "x")
    assert info.scale == 100.0
    assert info.kind == "float"
    np.testing.assert_allclose(codes, [23.0, 26.0, 0.0])
    # literal encoding matches data encoding (§5.1)
    assert info.encode(10.22) == 23.0
    assert info.decode(23.0) == 10.22


def test_preprocess_categorical_frequency_ranked():
    codes, info = preprocess_column(
        np.array(["b", "a", "b", "b", "c", "a"]), "x")
    assert info.categories[0] == "b"       # most frequent -> code 0
    assert info.encode("b") == 0.0
    assert info.encode("zzz") != info.encode("zzz")  # NaN: unseen literal


def test_preprocess_missing():
    codes, info = preprocess_column(np.array([1.0, np.nan, 3.0]), "x")
    assert np.isnan(codes[1])
    assert codes[0] == 0.0 and codes[2] == 2.0


def test_compression_reduces_size_on_redundant_data():
    rng = np.random.default_rng(0)
    n = 50_000
    table = {
        "a": rng.integers(0, 8, n).astype(float) * 1000,  # 8 values
        "b": np.round(rng.normal(500, 3, n)),             # narrow
        "c": rng.integers(0, 4, n).astype(float),
    }
    pp = preprocess_table(table)
    gd = GreedyGD()
    ct = gd.compress(pp.data)
    assert ct.size_bytes() < ct.raw_size_bytes()
    rec = gd.decompress(ct)
    assert np.allclose(rec, pp.data)


def test_seed_edges_are_sorted_and_in_domain():
    rng = np.random.default_rng(1)
    data = np.stack([rng.integers(0, 1000, 10000).astype(float),
                     rng.integers(0, 50, 10000).astype(float)], 1)
    gd = GreedyGD()
    ct = gd.compress(data)
    for i, edges in enumerate(GreedyGD.seed_edges(ct)):
        assert np.all(np.diff(edges) > 0)
        assert edges.min() >= 0
        assert edges.max() <= data[:, i].max() + 1


def test_gd_seeding_changes_initial_edges_not_correctness(small_table):
    from repro.aqp.engine import AQPFramework
    from repro.aqp.exact import ExactEngine
    from repro.core.types import BuildParams
    exact = ExactEngine(small_table)
    fw_gd = AQPFramework(BuildParams(n_samples=20_000),
                         use_compression=True).ingest(small_table)
    fw_raw = AQPFramework(BuildParams(n_samples=20_000),
                          use_compression=False).ingest(small_table)
    sql = "SELECT AVG(c1) FROM t WHERE c2 > 600"
    truth = exact.query(sql)
    for fw in (fw_gd, fw_raw):
        est = fw.query(sql).estimate
        assert abs(est - truth) / truth < 0.02
