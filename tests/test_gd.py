"""GreedyGD compression + preprocessing."""
import numpy as np
import pytest

from repro.gd.greedygd import GreedyGD, decompress_rows
from repro.gd.preprocess import preprocess_column, preprocess_table


def _roundtrip_bit_exact(data):
    gd = GreedyGD(search_rows=500)
    ct = gd.compress(data)
    rec = gd.decompress(ct)
    assert rec.shape == data.shape
    assert np.array_equal(np.isnan(rec), np.isnan(data))
    ok = ~np.isnan(data)
    assert data[ok].tobytes() == rec[ok].tobytes()   # bit-exact, not approx
    return ct


def test_preprocess_float_to_int():
    codes, info = preprocess_column(np.array([10.22, 10.25, 9.99]), "x")
    assert info.scale == 100.0
    assert info.kind == "float"
    np.testing.assert_allclose(codes, [23.0, 26.0, 0.0])
    # literal encoding matches data encoding (§5.1)
    assert info.encode(10.22) == 23.0
    assert info.decode(23.0) == 10.22


def test_preprocess_categorical_frequency_ranked():
    codes, info = preprocess_column(
        np.array(["b", "a", "b", "b", "c", "a"]), "x")
    assert info.categories[0] == "b"       # most frequent -> code 0
    assert info.encode("b") == 0.0
    assert info.encode("zzz") != info.encode("zzz")  # NaN: unseen literal


def test_preprocess_missing():
    codes, info = preprocess_column(np.array([1.0, np.nan, 3.0]), "x")
    assert np.isnan(codes[1])
    assert codes[0] == 0.0 and codes[2] == 2.0


def test_compression_reduces_size_on_redundant_data():
    rng = np.random.default_rng(0)
    n = 50_000
    table = {
        "a": rng.integers(0, 8, n).astype(float) * 1000,  # 8 values
        "b": np.round(rng.normal(500, 3, n)),             # narrow
        "c": rng.integers(0, 4, n).astype(float),
    }
    pp = preprocess_table(table)
    gd = GreedyGD()
    ct = gd.compress(pp.data)
    assert ct.size_bytes() < ct.raw_size_bytes()
    rec = gd.decompress(ct)
    assert np.allclose(rec, pp.data)


def test_seed_edges_are_sorted_and_in_domain():
    rng = np.random.default_rng(1)
    data = np.stack([rng.integers(0, 1000, 10000).astype(float),
                     rng.integers(0, 50, 10000).astype(float)], 1)
    gd = GreedyGD()
    ct = gd.compress(data)
    for i, edges in enumerate(GreedyGD.seed_edges(ct)):
        assert np.all(np.diff(edges) > 0)
        assert edges.min() >= 0
        assert edges.max() <= data[:, i].max() + 1


@pytest.mark.parametrize("case", [
    "nan_pattern", "constant_cols", "single_row", "all_unique",
    "nan_only_col", "nibble_boundary",
])
def test_gd_lossless_edge_cases(case):
    """decompress(compress(x)) is bit-exact on the adversarial shapes the
    null bitmap / base split / nibble granularity each stress."""
    rng = np.random.default_rng(42)
    if case == "nan_pattern":
        data = rng.integers(0, 5000, (3000, 4)).astype(float)
        data[rng.random((3000, 4)) < 0.2] = np.nan
    elif case == "constant_cols":
        data = np.stack([np.full(500, 7.0), np.zeros(500),
                         rng.integers(0, 9, 500).astype(float)], 1)
    elif case == "single_row":
        data = np.array([[13.0, 0.0, 4095.0]])
    elif case == "all_unique":
        data = np.stack([np.arange(2000, dtype=float),
                         rng.permutation(2000).astype(float)], 1)
    elif case == "nan_only_col":
        data = rng.integers(0, 100, (200, 3)).astype(float)
        data[:, 1] = np.nan
    else:  # nibble_boundary: widths straddling 2**k - 1 / 2**k
        cols = [np.array([(1 << k) - 1, (1 << k), 0], float)
                for k in (4, 8, 12, 16)]
        data = np.stack(cols, 1)
    _roundtrip_bit_exact(data)


def test_decompress_rows_subset_matches_full():
    """Row-subset decode (any order, duplicates) slices the full decode —
    the invariant GD-native construction rests on."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 3000, (4000, 3)).astype(float) * 8 \
        + rng.integers(0, 8, (4000, 3))
    data[rng.random((4000, 3)) < 0.1] = np.nan
    ct = GreedyGD(search_rows=500).compress(data)
    full = GreedyGD().decompress(ct)
    rows = np.array([0, 3999, 17, 17, 2500, 1])       # dupes + unsorted
    sub = decompress_rows(ct, rows)
    assert full[rows].tobytes() == sub.tobytes()
    assert decompress_rows(ct, None).tobytes() == full.tobytes()


def test_seed_edges_invariants():
    """seed_edges: strictly increasing, within [0, column max], and
    invariant under row permutation (bases are a set, order-free)."""
    rng = np.random.default_rng(9)
    data = np.stack([rng.integers(0, 4000, 6000).astype(float),
                     rng.integers(0, 64, 6000).astype(float) * 64], 1)
    gd = GreedyGD(search_rows=6000)     # full-data plan: permutation-proof
    ct = gd.compress(data)
    edges = GreedyGD.seed_edges(ct)
    for i, e in enumerate(edges):
        assert np.all(np.diff(e) > 0)
        assert e.min() >= 0.0 and e.max() <= data[:, i].max()
    perm = rng.permutation(data.shape[0])
    edges_p = GreedyGD.seed_edges(gd.compress(data[perm]))
    for e1, e2 in zip(edges, edges_p):
        assert np.array_equal(e1, e2)


def test_gd_seeding_changes_initial_edges_not_correctness(small_table):
    from repro.aqp.engine import AQPFramework
    from repro.aqp.exact import ExactEngine
    from repro.core.types import BuildParams
    exact = ExactEngine(small_table)
    fw_gd = AQPFramework(BuildParams(n_samples=20_000),
                         use_compression=True).ingest(small_table)
    fw_raw = AQPFramework(BuildParams(n_samples=20_000),
                          use_compression=False).ingest(small_table)
    sql = "SELECT AVG(c1) FROM t WHERE c2 > 600"
    truth = exact.query(sql)
    for fw in (fw_gd, fw_raw):
        est = fw.query(sql).estimate
        assert abs(est - truth) / truth < 0.02
