"""Observability subsystem: span ring, EXPLAIN accounting, trace export,
build timeline, metrics concurrency, immutable build timings."""
import json
import random
import threading
import time

import numpy as np
import pytest

from repro.aqp.engine import AQPFramework
from repro.core.types import BuildParams
from repro.obs.export import (spans_to_events, timeline_to_events,
                              trace_json, validate_trace_events)
from repro.obs.trace import NOOP_SPAN, QueryTrace, Tracer
from repro.obs.timeline import BuildTimeline
from repro.serve.aqp import AQPServer
from repro.serve.aqp.metrics import Metrics, TableMetrics


@pytest.fixture(scope="module")
def framework():
    rng = np.random.default_rng(5)
    n = 8_000
    table = {
        "a": rng.integers(0, 400, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "c": rng.integers(0, 40, n).astype(float),
    }
    params = BuildParams(n_samples=4_000, seed=1)
    return AQPFramework(params=params, use_compression=False).ingest(table)


def _server(framework, **kwargs):
    srv = AQPServer(mode=None, **kwargs)
    srv.register("t", framework)
    return srv


# --------------------------------------------------------------- span ring


def test_ring_wraparound_drops_oldest():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.add(f"s{i}", float(i), float(i) + 0.5)
    assert tr.n_recorded == 20
    assert tr.n_dropped == 12
    window = tr.spans()
    assert len(window) == 8
    assert [s.name for s in window] == [f"s{i}" for i in range(12, 20)]
    assert [s.seq for s in window] == list(range(12, 20))
    tr.clear()
    assert tr.spans() == [] and tr.n_recorded == 0 and tr.n_dropped == 0


def test_disabled_tracer_records_nothing():
    tr = Tracer(capacity=8, enabled=False)
    assert tr.span("x") is NOOP_SPAN
    with tr.span("x"):
        pass
    tr.add("y", 0.0, 1.0)
    tr.instant("z")
    assert tr.spans() == [] and tr.n_recorded == 0


def test_concurrent_add_no_lost_spans():
    tr = Tracer(capacity=4096)
    n_threads, per = 8, 200

    def worker(tid):
        for i in range(per):
            tr.add(f"t{tid}-{i}", 0.0, 1.0, track=f"w{tid}")

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.n_recorded == n_threads * per
    assert tr.n_dropped == 0
    spans = tr.spans()
    assert len(spans) == n_threads * per
    # every committed span is present exactly once
    assert len({s.name for s in spans}) == n_threads * per


# ----------------------------------------------------------------- explain


def test_explain_tiles_interval_exactly():
    qt = QueryTrace(t_submit=10.0)
    qt.t_planned = 10.002
    qt.t_admitted = 10.003
    # t_drained missing (e.g. cache hit) -> zero-width queue stage
    qt.t_exec0 = 10.010
    qt.t_exec1 = 10.020
    qt.t_resolved = 10.021
    exp = qt.explain()
    stages = [exp[k] for k in ("plan_ms", "admit_ms", "queue_ms",
                               "assemble_ms", "execute_ms", "resolve_ms")]
    assert exp["queue_ms"] == 0.0
    assert sum(stages) == pytest.approx(exp["total_ms"])
    assert exp["total_ms"] == pytest.approx(21.0, rel=1e-6)


def test_explain_accounts_observed_wall_clock(framework):
    # Acceptance: the EXPLAIN breakdown of a traced query accounts for
    # >= 95% of the wall-clock the client observed. The admission wait
    # (max_wait_ms) is part of the traced interval, so the measured total
    # dwarfs the only unaccounted gaps (pre-submit entry + future wakeup).
    srv = _server(framework, trace_enabled=True, max_wait_ms=50.0)
    try:
        t0 = time.perf_counter()
        fut = srv.submit("SELECT AVG(b) FROM t WHERE a > 100")
        res = fut.result(timeout=30)
        wall_ms = (time.perf_counter() - t0) * 1e3
        exp = res.explain
        assert exp is not None
        assert exp["total_ms"] <= wall_ms + 1e-6
        assert exp["total_ms"] >= 0.95 * wall_ms, (exp, wall_ms)
        stages = [exp[k] for k in ("plan_ms", "admit_ms", "queue_ms",
                                   "assemble_ms", "execute_ms",
                                   "resolve_ms")]
        assert sum(stages) == pytest.approx(exp["total_ms"])
    finally:
        srv.close()


def test_cached_results_stay_explain_free(framework):
    srv = _server(framework, trace_enabled=True)
    try:
        sql = "SELECT COUNT(a) FROM t WHERE b > 90"
        first = srv.query(sql)
        assert first.explain is not None
        assert first.explain["result_cache_hit"] is False
        hit = srv.query(sql)
        assert hit.explain is not None           # per-query, not cached
        assert hit.explain["result_cache_hit"] is True
        assert hit.explain["execute_ms"] == 0.0
    finally:
        srv.close()


def test_untraced_server_attaches_no_explain(framework):
    srv = _server(framework)
    try:
        res = srv.query("SELECT SUM(b) FROM t WHERE c < 20")
        assert res.explain is None
        assert srv.stats()["tracing"]["enabled"] is False
        assert srv.trace_events() == []
    finally:
        srv.close()


def test_slow_query_log_bounded_and_thresholded(framework):
    srv = _server(framework, trace_enabled=True, slow_query_ms=0.0)
    try:
        for thr in (50, 60, 70):
            srv.query(f"SELECT COUNT(a) FROM t WHERE b > {thr}")
        log = srv.slow_queries()
        assert len(log) == 3
        assert all("sql" in e and e["total_ms"] >= 0.0 for e in log)
        assert len(log) <= AQPServer.SLOW_LOG_CAP
    finally:
        srv.close()


# ------------------------------------------------------------------ export


def test_trace_export_valid_trace_event_json(framework):
    srv = _server(framework, trace_enabled=True)
    try:
        srv.query_batch([
            "SELECT COUNT(a) FROM t WHERE b > 80",
            "SELECT AVG(b) FROM t WHERE a < 300",
            "SELECT SUM(b) FROM t WHERE c >= 5",
        ])
        parsed = json.loads(srv.trace_json())
        assert parsed, "no events exported"
        assert validate_trace_events(parsed) == []
        names = {ev["name"] for ev in parsed}
        assert {"plan", "execute", "resolve"} <= names
        # every query lane is named via M metadata
        meta = [ev for ev in parsed if ev["ph"] == "M"]
        assert {m["args"]["name"] for m in meta} >= {"admission"}
    finally:
        srv.close()


def test_validate_trace_events_catches_breakage():
    good = spans_to_events([])
    assert good == []
    bad = [{"ph": "X", "name": "x", "pid": 1, "tid": 0, "ts": -5.0,
            "dur": 1.0},
           {"ph": "i", "name": 3, "pid": 1, "tid": 1, "ts": 0.0, "s": "t"}]
    problems = validate_trace_events(bad)
    assert any("ts" in p for p in problems)
    assert any("name" in p for p in problems)
    assert any("thread_name" in p for p in problems)
    assert validate_trace_events("nope") == ["top level is not a JSON array"]


# ----------------------------------------------------------- build timeline


def test_build_timeline_and_phase_summary(framework):
    stats = framework.synopsis.build_stats
    events = stats["timeline"]
    assert events, "build recorded no timeline events"
    phase_names = {ev["name"] for ev in events if ev["kind"] == "phase"}
    assert {"sample", "refine_1d", "pair_phase", "folds"} <= phase_names
    summary = stats["phase_s"]
    assert {"sample", "refine_1d", "pair_phase"} <= set(summary)
    assert all(v >= 0.0 for v in summary.values())
    exported = timeline_to_events(events)
    assert validate_trace_events(json.loads(trace_json(exported))) == []


def test_compact_occupancy_hist_ledger(framework):
    comp = framework.synopsis.build_stats.get("compaction")
    if comp is None:
        pytest.skip("compact path not taken on this build")
    hist = comp["occupancy_hist"]
    assert hist and all(isinstance(v, int) and v > 0 for v in hist.values())
    # one histogram entry per device loop round ...
    assert sum(hist.values()) == comp["loop_rounds"]
    # ... and occupancy-weighted rounds are exactly the pair-rounds refined
    assert sum(n * v for n, v in hist.items()) == comp["pair_rounds"]


def test_timeline_disabled_records_nothing():
    tl = BuildTimeline(enabled=False)
    with tl.phase("sample"):
        pass
    tl.add("x", 0.0, 1.0)
    tl.event("y")
    assert tl.events == [] and tl.summary() == {}


# ----------------------------------------------------------------- metrics


def test_qps_reported_for_single_query():
    tm = TableMetrics()
    tm.record(0.002, batched=False)
    snap = tm.snapshot()
    assert snap["qps"] is not None and snap["qps"] > 0
    empty = TableMetrics().snapshot()
    assert empty["qps"] is None


def test_metrics_concurrent_record_ledger_exact():
    m = Metrics(reservoir=128)
    n_threads, per = 8, 250
    errors = []

    def worker(tid):
        rng = random.Random(tid)
        try:
            for i in range(per):
                tm = m.table(f"t{tid % 2}")
                tm.record(rng.random() * 1e-3, batched=(i % 2 == 0))
                if i % 5 == 0:
                    tm.record_result_hit()
                m.admission.record_wait(rng.random() * 1e-4)
                m.record_explain({"plan_ms": 0.1, "execute_ms": 0.5,
                                  "total_ms": 0.6})
                if i % 50 == 0:
                    m.snapshot()      # concurrent snapshots must not blow up
        except Exception as exc:      # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    snap = m.snapshot()
    total = n_threads * per
    assert snap["totals"]["queries_executed"] == total
    executed = sum(t["queries_executed"] for t in snap["tables"].values())
    batched = sum(t["batched"] for t in snap["tables"].values())
    fallback = sum(t["fallback"] for t in snap["tables"].values())
    assert executed == batched + fallback == total
    hits = sum(t["result_cache_hits"] for t in snap["tables"].values())
    assert hits == n_threads * len(range(0, per, 5))
    assert snap["totals"]["stages"]["explained"] == total
    assert snap["totals"]["stages"]["execute"]["p50_ms"] == pytest.approx(0.5)


# ------------------------------------------------------- immutable timings


def test_published_timings_immutable_and_atomic(framework):
    timings = framework.timings
    assert {"preprocess_s", "build_synopsis_s", "build_pairs_s",
            "build_phase_s"} <= set(timings)
    with pytest.raises(TypeError):
        timings["preprocess_s"] = 0.0
    engine, epoch = framework.published
    assert engine is framework.engine and epoch == framework.epoch


def test_stale_publish_carries_timings_forward(framework):
    rng = np.random.default_rng(6)
    n = 4_000
    table = {"a": rng.integers(0, 100, n).astype(float),
             "b": np.abs(rng.normal(50, 10, n)).round()}
    fw = AQPFramework(params=BuildParams(n_samples=2_000, seed=2),
                      use_compression=False).ingest(table)
    before = fw.timings
    fw.append_rows({k: v[:100] for k, v in table.items()})
    assert fw.is_stale
    assert fw.timings is before       # carried forward, still immutable
    fw.rebuild(table)
    assert not fw.is_stale
    assert fw.timings is not before   # fresh build published fresh telemetry
