"""Tier-1 fault-injection chaos lane (``scripts/tier1.sh --chaos``).

Drives an undisturbed CONTROL server and a CHAOS server through the same
workload. The chaos server runs under a seeded multi-site ``FaultPlan``
(wave crashes, kernel-launch faults, a scripted worker death, cold decode
failures, injected wave latency) and must uphold the serving invariants:

  1. EVERY submitted future resolves — a correct answer or a typed
     result (``QueryError`` / ``DeadlineExceeded``), never a hang;
  2. answers that retried through transient faults are **bit-identical**
     to the control server's (retries ride the normal wave path);
  3. the admission worker never stays dead — scripted crashes are
     absorbed by revive/watchdog and the final queue is fully drained;
  4. deadline-expired queries resolve within 2x their deadline;
  5. failure telemetry is consistent: typed failures on the wire match
     the ``query_errors`` counter, and the queue depth stayed bounded.

Deterministic under its seed; writes nothing; exits non-zero on failure.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.aqp.engine import AQPFramework
from repro.core import storage
from repro.core.types import BuildParams
from repro.serve.aqp import (AQPServer, DeadlineExceeded, QueryError,
                             faults)

TIMEOUT_S = 30.0


def _table(n=10_000):
    rng = np.random.default_rng(17)
    return {
        "a": rng.integers(0, 500, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
    }


def _sqls():
    return [f"SELECT COUNT(a) FROM t WHERE b > {50 + i}" for i in range(32)]


def _check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"chaos_smoke: [{status}] {name}" + (f" ({detail})" if detail else ""))
    return bool(ok)


def main() -> int:
    fw = AQPFramework(BuildParams(n_samples=5_000, seed=3),
                      use_compression=False).ingest(_table())
    blob = storage.encode(fw.engine.ph)
    sqls = _sqls()

    control = AQPServer(mode="numpy").register("t", fw)
    want = {s: control.query(s).as_tuple() for s in sqls}
    control.close()

    srv = AQPServer(mode="numpy", max_wait_ms=20.0,
                    max_batch=8).register("t", fw)
    srv.register_cold("c", blob, decode_retries=2, decode_backoff_s=0.005)

    # Rule order matters (first match wins): the wave-0 stall outlives the
    # doomed query's deadline deterministically, making the expiry path
    # exercised on every run, not just lucky schedules.
    plan = (faults.FaultPlan(seed=11)
            .fail("wave_execute", at=[0], action=lambda: time.sleep(0.12))
            .fail("wave_execute", rate=0.15)
            .fail("kernel_launch", rate=0.10)
            .fail("worker", at=[1])
            .fail("cold_decode", at=[0])
            .fail("wave_execute", every=7,
                  action=lambda: time.sleep(0.02)))

    ok = True
    with faults.installed(plan):
        futs = [srv.submit(s) for s in sqls]
        cold_fut = srv.submit("SELECT COUNT(a) FROM c WHERE b > 90")
        doomed = srv.submit("SELECT AVG(b) FROM t WHERE a < 9999",
                            deadline_ms=100.0)
        t_doomed = time.perf_counter()
        srv.flush()

        resolved = matched = failed = 0
        for sql, fut in zip(sqls, futs):
            try:
                res = fut.result(timeout=TIMEOUT_S)
            except Exception as exc:       # plan errors would raise typed
                ok = _check(f"future resolved: {sql}", False, repr(exc))
                continue
            resolved += 1
            if isinstance(res, QueryError):
                failed += 1
                if res.kind not in ("execution", "quarantined"):
                    ok = _check("typed failure kind", False, res.kind)
            elif res.as_tuple() == want[sql]:
                matched += 1
            else:
                ok = _check("bit-identical retried answer", False, sql)
        ok &= _check("every future resolves",
                     resolved == len(sqls), f"{resolved}/{len(sqls)}")
        ok &= _check("answers bit-identical to control",
                     matched + failed == resolved,
                     f"{matched} matched, {failed} typed failures")
        ok &= _check("chaos actually injected",
                     sum(plan.snapshot()["injected"].values()) > 0,
                     str(plan.snapshot()["injected"]))

        # Cold table: the decode retried through the injected fault.
        cold_res = cold_fut.result(timeout=TIMEOUT_S)
        ok &= _check("cold decode retried through fault",
                     cold_res.estimate is not None and
                     plan.count("cold_decode") >= 2)

        # Deadline: the wave-0 stall (120ms) outlives the 100ms deadline,
        # so the query expires while queued and must resolve — typed —
        # within 2x its deadline.
        dres = doomed.result(timeout=TIMEOUT_S)
        waited_ms = (time.perf_counter() - t_doomed) * 1e3
        ok &= _check("deadline resolves typed within 2x deadline",
                     isinstance(dres, DeadlineExceeded)
                     and waited_ms < 2 * 100.0,
                     f"{waited_ms:.1f}ms")

    # Worker supervision: scripted crash absorbed, worker alive at the end.
    post = srv.query("SELECT COUNT(a) FROM t WHERE b > 49")
    flt = srv.stats()["totals"]["faults"]
    ok &= _check("worker never stays dead",
                 post.as_tuple() is not None and post.failed is False
                 and flt["worker_restarts"] >= 1,
                 f"restarts={flt['worker_restarts']}")
    ok &= _check("telemetry consistent with typed failures",
                 flt["query_errors"] == failed,
                 f"counter={flt['query_errors']} wire={failed}")
    adm = srv.stats()["totals"]["admission"]
    ok &= _check("queue depth bounded",
                 adm["max_queue_depth"] <= len(sqls) + 2,
                 str(adm["max_queue_depth"]))
    srv.close()
    print("chaos_smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
