#!/usr/bin/env bash
# CI-style documentation lint. Fails (non-zero) on:
#   1. broken intra-repo links in docs/*.md or README.md;
#   2. public surfaces of src/repro/serve/aqp/ missing docstrings
#      (modules, public classes, public functions/methods);
#   3. a BuildParams / serving knob appearing in zero or in more than one
#      reference doc under docs/ (every knob must have exactly one home:
#      construction knobs in docs/construction.md, compression knobs in
#      docs/compression.md, serving knobs in docs/serving.md).
#
# Wired into scripts/tier1.sh and exercised by tests/test_docs.py, so the
# plain ROADMAP tier-1 command enforces it too.
set -euo pipefail
cd "$(dirname "$0")/.."
python - <<'EOF'
import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(".").resolve()
errors = []

# ---------------------------------------------------------------- 1. links
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
md_files = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]
for md in md_files:
    if not md.exists():
        errors.append(f"missing documentation file: {md.relative_to(ROOT)}")
        continue
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                              f"broken link -> {target}")

# ----------------------------------------------------- 2. serve/aqp docstrings
def check_docstrings(py: pathlib.Path):
    tree = ast.parse(py.read_text())
    rel = py.relative_to(ROOT)
    if ast.get_docstring(tree) is None:
        errors.append(f"{rel}: missing module docstring")
    def walk(node, prefix=""):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                public = not name.startswith("_") or name == "__init__"
                if isinstance(child, ast.ClassDef):
                    if public and ast.get_docstring(child) is None:
                        errors.append(f"{rel}:{child.lineno}: class "
                                      f"{prefix}{name} missing docstring")
                    if public:      # a private class is not public surface
                        walk(child, prefix=f"{name}.")
                elif public and name != "__init__" \
                        and ast.get_docstring(child) is None:
                    errors.append(f"{rel}:{child.lineno}: def "
                                  f"{prefix}{name} missing docstring")

    walk(tree)

for py in sorted((ROOT / "src/repro/serve/aqp").glob("*.py")):
    check_docstrings(py)

# ------------------------------------------------------- 3. knob uniqueness
def class_fields(path, cls):
    for node in ast.parse((ROOT / path).read_text()).body:
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [st.target.id for st in node.body
                    if isinstance(st, ast.AnnAssign)
                    and isinstance(st.target, ast.Name)]
    raise SystemExit(f"cannot find {cls} in {path}")

# Cold-tier governor knobs (AQPServer kwargs) live with the cold-catalog
# docs in compression.md, not serving.md.
compression_knobs = ["from_compressed", "seed_from_bases",
                     "max_engine_bytes", "demote_idle_s"]
build_knobs = [k for k in class_fields("src/repro/core/types.py",
                                       "BuildParams")
               if k not in compression_knobs]
serving_knobs = ["mode", "plan_cache_size", "result_cache_size",
                 "max_result_bytes", "max_group", "min_group",
                 "max_wait_ms", "max_batch", "max_queue_depth",
                 "shed_policy", "retry_timeout_s", "single_lock",
                 "plan_templates", "template_cache_size", "planner_workers"]
obs_knobs = ["trace_enabled", "trace_buffer", "slow_query_ms"]
# Fault-tolerance knobs (per-query deadline + cold decode resilience)
# live with the robustness reference in robustness.md.
robustness_knobs = ["deadline_ms", "decode_retries", "decode_backoff_s",
                    "breaker_reset_s"]
docs = {p: p.read_text() for p in sorted(ROOT.glob("docs/*.md"))}
for knob, home in ([(k, "construction") for k in build_knobs]
                   + [(k, "compression") for k in compression_knobs]
                   + [(k, "serving") for k in serving_knobs]
                   + [(k, "observability") for k in obs_knobs]
                   + [(k, "robustness") for k in robustness_knobs]):
    pat = re.compile(rf"`{re.escape(knob)}`")
    hits = [p.name for p, text in docs.items() if pat.search(text)]
    if hits != [f"{home}.md"]:
        errors.append(f"knob `{knob}` must appear in exactly docs/{home}.md; "
                      f"found in {hits or 'no docs'}")

if errors:
    print("check_docs: FAIL", file=sys.stderr)
    for err in errors:
        print(f"  {err}", file=sys.stderr)
    sys.exit(1)
print(f"check_docs: OK ({len(md_files)} md files, "
      f"{len(build_knobs) + len(serving_knobs) + len(obs_knobs) + len(robustness_knobs)} knobs)")
EOF
