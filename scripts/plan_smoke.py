"""Tier-1 planner smoke lane (``scripts/tier1.sh --plan-smoke``).

End-to-end check of the zero-parse planner fast path (PR 7):

  1. build one small synopsis and serve a repeat-shape / distinct-literal
     workload through an ``AQPServer`` with templating on (every hit-phase
     query misses the plan and result caches, so only the template path
     can avoid the parse);
  2. assert the hit phase performed **zero** ``parse_sql`` calls —
     counter-based (``repro.core.sql.parse_calls``), not timing-based;
  3. assert hit-path answers are bit-for-bit equal to the cold engine
     path (``QueryEngine.query`` re-planned from scratch) for every query,
     and hit-path plans canonical-key-equal to freshly planned ones;
  4. sanity-check the telemetry: template-cache hit rate > 0 and the
     ``plan_template_hit`` stage reservoir populated on a traced re-run.

Writes nothing; exits non-zero on any failure.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.aqp.engine import AQPFramework
from repro.core import sql as sqlmod
from repro.core.types import BuildParams
from repro.serve.aqp import AQPServer


def _framework():
    rng = np.random.default_rng(13)
    n = 8_000
    table = {
        "a": rng.integers(0, 400, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "c": rng.integers(0, 40, n).astype(float),
        "g": np.array([f"g{i}" for i in rng.integers(0, 8, n)]),
    }
    return AQPFramework(params=BuildParams(n_samples=4_000, seed=1),
                        use_compression=False).ingest(table)


SHAPES = [
    "SELECT COUNT(*) FROM t WHERE a > {p} AND b < {q}",
    "SELECT SUM(b) FROM t WHERE a >= {p} AND a <= {q}",
    "SELECT AVG(b) FROM t WHERE a < {p} OR c > {q}",
    "SELECT MIN(b) FROM t WHERE b > {p} AND b < {q}",
    "SELECT COUNT(b) FROM t WHERE a < {p} GROUP BY g",
]


def _workload(rng, n_per_shape=8):
    """Distinct-literal instances of each shape (no two texts equal, so the
    plan/result caches cannot answer them — only the template path can)."""
    out = []
    for shape in SHAPES:
        seen = set()
        while len(seen) < n_per_shape:
            p = int(rng.integers(0, 300))
            q = int(rng.integers(50, 400))
            if (p, q) not in seen:
                seen.add((p, q))
                out.append(shape.format(p=p, q=q))
    return out


def main() -> int:
    fw = _framework()
    rng = np.random.default_rng(29)

    srv = AQPServer(mode="numpy").register("t", fw)
    # Cold phase: one instance per shape compiles each template.
    for shape in SHAPES:
        srv.query(shape.format(p=999, q=1000))

    hits = _workload(rng)
    before = sqlmod.parse_calls()
    served = srv.query_batch(hits)
    parses = sqlmod.parse_calls() - before
    if parses != 0:
        print(f"FAIL: template-hit phase performed {parses} parse_sql "
              f"calls (expected 0 across {len(hits)} queries)")
        return 1
    print(f"zero-parse: OK ({len(hits)} template-hit queries, 0 parses)")

    # Bit-for-bit: hit-path answers vs the cold engine path, and hit-path
    # plans vs freshly planned ones (these comparisons parse — they run
    # after the counting window).
    eng = fw.engine
    for sql, got in zip(hits, served):
        want = eng.query(sql)
        if got.as_tuple() != want.as_tuple() or got.groups != want.groups:
            print(f"FAIL: hit-path result diverged for {sql!r}: "
                  f"{got.as_tuple()} vs {want.as_tuple()}")
            return 1
        fp = sqlmod.fingerprint_sql(sql)
        entry = srv.template_cache.get(fp.shape, srv.catalog.epoch)
        if entry is None:
            print(f"FAIL: no template cached for shape of {sql!r}")
            return 1
        hot = entry.value.bind(fp.literals)
        cold = eng.plan_sql(sql)
        if hot.canonical_key() != cold.canonical_key():
            print(f"FAIL: template plan differs from cold plan for {sql!r}:\n"
                  f"  hot : {hot.canonical_key()}\n"
                  f"  cold: {cold.canonical_key()}")
            return 1
    print(f"bit-for-bit: OK ({len(hits)} plans + results)")

    tc = srv.stats()["totals"]["template_cache"]
    if not tc["hits"] or tc["hit_rate"] <= 0:
        print(f"FAIL: template cache reports no hits: {tc}")
        return 1
    srv.close()

    # Traced re-run: the plan-stage split must label both paths.
    srv2 = AQPServer(mode="numpy", trace_enabled=True).register("t", fw)
    srv2.query(SHAPES[0].format(p=10, q=100))          # cold -> plan_full
    hot = srv2.query(SHAPES[0].format(p=20, q=200))    # hit  -> template
    stages = srv2.stats()["totals"]["stages"]
    if stages["plan_full"]["p50_ms"] is None or \
            stages["plan_template_hit"]["p50_ms"] is None:
        print(f"FAIL: plan-stage split not populated: "
              f"full={stages['plan_full']} "
              f"template={stages['plan_template_hit']}")
        return 1
    if hot.explain is None or hot.explain.get("plan_path") != "template":
        print(f"FAIL: EXPLAIN plan_path label missing/wrong: {hot.explain}")
        return 1
    srv2.close()
    print("telemetry: OK (plan_full / plan_template_hit split + "
          "EXPLAIN plan_path)")
    print("plan smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
