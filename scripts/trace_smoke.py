"""Tier-1 trace smoke lane (``scripts/tier1.sh --trace-smoke``).

Tiny end-to-end check of the PR-6 observability surface:

  1. build one small synopsis with the always-on build timeline and serve a
     small workload through a *traced* ``AQPServer``;
  2. export both the serving span ring and the construction timeline to
     trace_event JSON, JSON-round-trip them, and validate against the
     schema checker (``repro.obs.export.validate_trace_events``);
  3. replay the same workload through traced and untraced servers in
     back-to-back chunk pairs (order alternating, median of per-pair
     ratios — robust to the ±20% drift of shared CI boxes) and assert
     the traced overhead stays under ``TRACE_SMOKE_MAX_OVERHEAD_PCT``
     (default 5%);
  4. sanity-check one EXPLAIN breakdown: stages tile submit->resolve, and
     the accounted total covers the observed wall-clock.

Writes nothing outside a temp directory; exits non-zero on any failure.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.aqp.engine import AQPFramework
from repro.core.types import BuildParams
from repro.obs.export import (timeline_to_events, validate_trace_events,
                              write_trace)
from repro.serve.aqp import AQPServer

MAX_OVERHEAD_PCT = float(os.environ.get("TRACE_SMOKE_MAX_OVERHEAD_PCT", "5"))


def _framework():
    rng = np.random.default_rng(11)
    n = 8_000
    table = {
        "a": rng.integers(0, 400, n).astype(float),
        "b": np.abs(rng.normal(100, 30, n)).round(),
        "c": rng.integers(0, 40, n).astype(float),
        "g": np.array([f"g{i}" for i in rng.integers(0, 10, n)]),
    }
    params = BuildParams(n_samples=4_000, seed=1)
    return AQPFramework(params=params, use_compression=False).ingest(table)


def _workload():
    """All-distinct queries so every one executes (a result-cache hit's
    wall-clock is smaller than a single span, which would make a relative
    budget meaningless), with GROUP BY mixed in so per-query work is
    representative of serving traffic (leaf expansion multiplies the real
    work per query; the tracing cost stays per-query)."""
    sqls = []
    for thr in range(40, 136, 2):
        sqls.append(f"SELECT AVG(b) FROM t WHERE a > {thr * 2} GROUP BY g")
        sqls.append(f"SELECT COUNT(a) FROM t WHERE b > {thr} AND c < 25")
    return sqls


def _make_server(fw, trace_enabled: bool) -> AQPServer:
    srv = AQPServer(mode=None, trace_enabled=trace_enabled)
    srv.register("t", fw)
    return srv


def _chunk_ms(srv, chunk) -> float:
    t0 = time.perf_counter()
    srv.query_batch(chunk)
    return (time.perf_counter() - t0) / len(chunk) * 1e3


def _overhead_pct(fw, sqls, reps: int = 3) -> float:
    """Traced-vs-untraced overhead on the batched serving path.

    Shared CI boxes drift by +/- 20% at the 100ms timescale, so pass-level
    A/B medians cannot resolve a 5% effect. Instead each ~10ms chunk of
    the workload is timed back-to-back on an untraced and a traced server
    (order alternating chunk to chunk, so drift biases successive pairs in
    opposite directions) and the reported overhead is the median of the
    per-chunk traced/untraced ratios — drift cancels within a pair, and a
    real regression shifts every pair.
    """
    chunks = [sqls[lo:lo + 8] for lo in range(0, len(sqls), 8)]
    ratios = []
    for _ in range(reps):
        off_srv, on_srv = _make_server(fw, False), _make_server(fw, True)
        for i, chunk in enumerate(chunks):
            if i % 2 == 0:
                off = _chunk_ms(off_srv, chunk)
                on = _chunk_ms(on_srv, chunk)
            else:
                on = _chunk_ms(on_srv, chunk)
                off = _chunk_ms(off_srv, chunk)
            ratios.append(on / off)
        off_srv.close()
        on_srv.close()
    return (float(np.median(ratios)) - 1.0) * 100.0


def main() -> int:
    failures = []
    fw = _framework()
    sqls = _workload()

    # --- serve traced once: explain sanity + span export -------------------
    srv = AQPServer(mode=None, trace_enabled=True)
    srv.register("t", fw)
    t0 = time.perf_counter()
    res = srv.query(sqls[0])
    wall_ms = (time.perf_counter() - t0) * 1e3
    exp = res.explain
    if exp is None:
        failures.append("traced query returned no explain")
    else:
        stage_sum = sum(exp[k] for k in ("plan_ms", "admit_ms", "queue_ms",
                                         "assemble_ms", "execute_ms",
                                         "resolve_ms"))
        if abs(stage_sum - exp["total_ms"]) > 1e-6:
            failures.append(f"explain stages do not tile: {stage_sum} vs "
                            f"{exp['total_ms']}")
        if exp["total_ms"] > wall_ms:
            failures.append(f"explain total {exp['total_ms']:.3f} ms exceeds "
                            f"observed wall {wall_ms:.3f} ms")
    srv.query_batch(sqls[:16])
    events = srv.trace_events()
    srv.close()

    build_events = timeline_to_events(fw.synopsis.build_stats["timeline"])
    with tempfile.TemporaryDirectory() as tmp:
        for label, evs in (("serving", events), ("construction", build_events)):
            if not evs:
                failures.append(f"{label}: no trace events recorded")
                continue
            path = write_trace(os.path.join(tmp, f"{label}.json"), evs)
            with open(path) as f:
                parsed = json.load(f)
            problems = validate_trace_events(parsed)
            if problems:
                failures.append(f"{label}: invalid trace_event JSON: "
                                + "; ".join(problems[:5]))
            else:
                print(f"trace_smoke: {label} trace OK ({len(parsed)} events)")

    # --- traced vs untraced overhead ---------------------------------------
    warm = _make_server(fw, False)
    for lo in range(0, len(sqls), 16):            # compile/cache warm-up
        warm.query_batch(sqls[lo:lo + 16])
    warm.close()
    overhead_pct = _overhead_pct(fw, sqls)
    print(f"trace_smoke: traced-vs-untraced overhead {overhead_pct:+.1f}% "
          f"(median of paired chunk ratios, budget {MAX_OVERHEAD_PCT:.0f}%)")
    if overhead_pct >= MAX_OVERHEAD_PCT:
        failures.append(f"tracing overhead {overhead_pct:.1f}% >= "
                        f"{MAX_OVERHEAD_PCT:.1f}% budget")

    if failures:
        print("trace_smoke: FAIL", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("trace_smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
