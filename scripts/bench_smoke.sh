#!/usr/bin/env bash
# CI perf smoke: quick construction + serving benchmarks + JSON snapshots.
#
# Runs the construction suite (full-build comparison + the 2-D pair phase
# legacy-loop-vs-batched comparison with pairs/sec) and the serving suite
# (batched/streaming/GROUP BY throughput + latency percentiles) in --quick
# mode and snapshots the JSON artifacts to BENCH_construction.json /
# BENCH_serving.json at the repo root so the perf trajectory is tracked
# in-tree. Field reference: docs/benchmarks.md.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
    --only construction,serving --quick "$@"
cp benchmarks/results/construction.json BENCH_construction.json
cp benchmarks/results/serving.json BENCH_serving.json
echo "wrote BENCH_construction.json BENCH_serving.json"
