#!/usr/bin/env bash
# CI perf smoke: quick construction benchmark + JSON snapshot.
#
# Runs the construction suite (full-build comparison + the 2-D pair phase
# legacy-loop-vs-batched comparison with pairs/sec) in --quick mode and
# snapshots the JSON artifact to BENCH_construction.json at the repo root
# so the perf trajectory is tracked in-tree.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
    --only construction --quick "$@"
cp benchmarks/results/construction.json BENCH_construction.json
echo "wrote BENCH_construction.json"
