#!/usr/bin/env bash
# Fast tier-1 smoke lane: docs lint + the ROADMAP tier-1 command minus
# @slow tests (small-N stress variants stay in; the full-N stress suite
# runs behind --stress with a wall-clock budget).
#
#   scripts/tier1.sh               # -m "not slow and not stress", fail-fast
#   scripts/tier1.sh -k serving    # extra pytest args pass through
#   scripts/tier1.sh --stress      # full-N concurrency stress suite only,
#                                  # bounded by STRESS_BUDGET_S (default 600s)
#   scripts/tier1.sh --trace-smoke # observability smoke: tiny traced
#                                  # build+serve, trace_event schema
#                                  # validation, overhead budget (< 5%)
#   scripts/tier1.sh --plan-smoke  # planner smoke: zero parse_sql calls on
#                                  # the template-hit path (counter-based)
#                                  # + bit-for-bit hit-vs-cold plans
#   scripts/tier1.sh --gd-smoke    # GD pipeline smoke: compress ->
#                                  # build-from-compressed -> store ->
#                                  # cold-serve, decode-once + ratio > 1
#   scripts/tier1.sh --chaos       # fault-injection chaos smoke: seeded
#                                  # multi-site fault schedules vs an
#                                  # undisturbed control; every future
#                                  # resolves, bit-identical retries
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${1:-}" == "--stress" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        timeout "${STRESS_BUDGET_S:-600}" \
        python -m pytest -q -m "stress" "$@"
    exit $?
fi
if [[ "${1:-}" == "--trace-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        timeout "${TRACE_SMOKE_BUDGET_S:-300}" \
        python scripts/trace_smoke.py "$@"
    exit $?
fi
if [[ "${1:-}" == "--plan-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        timeout "${PLAN_SMOKE_BUDGET_S:-300}" \
        python scripts/plan_smoke.py "$@"
    exit $?
fi
if [[ "${1:-}" == "--gd-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        timeout "${GD_SMOKE_BUDGET_S:-300}" \
        python scripts/gd_smoke.py "$@"
    exit $?
fi
if [[ "${1:-}" == "--chaos" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        timeout "${CHAOS_BUDGET_S:-300}" \
        python scripts/chaos_smoke.py "$@"
    exit $?
fi
scripts/check_docs.sh
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow and not stress" "$@"
