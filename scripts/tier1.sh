#!/usr/bin/env bash
# Fast tier-1 smoke lane: docs lint + the ROADMAP tier-1 command minus
# @slow tests.
#
#   scripts/tier1.sh            # -m "not slow", fail-fast, quiet
#   scripts/tier1.sh -k serving # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
scripts/check_docs.sh
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
