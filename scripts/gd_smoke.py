"""Tier-1 GD pipeline smoke lane (``scripts/tier1.sh --gd-smoke``).

End-to-end check of the GD-native compressed pipeline (PR 8):

  1. compress a tiny redundant table with GreedyGD and assert the
     compression ratio is > 1 (bases/deviations split actually pays);
  2. build the synopsis **directly from the CompressedTable** — assert the
     build decoded only the N_s sampled rows (``rows_decoded`` stat) and
     is bit-identical to the raw build with ``seed_edges`` passed in;
  3. encode to a bit-packed blob, ``register_cold`` it on an ``AQPServer``
     and serve: the first query decodes exactly once, the second reuses
     the decoded engine (decode-once counter), and the epoch is stable
     across the decode;
  4. GD-native ``rebuild`` bumps the epoch, purges the result cache, and
     the rebuilt table still answers; cold telemetry (synopsis bytes,
     decode ms) lands in ``stats()``;
  5. ``demote`` drops the engine back to its blob at a *stable* epoch,
     the next query transparently re-decodes (decode-count increments)
     with bit-identical answers, and demote telemetry lands in
     ``stats()["cold"]``.

Writes nothing; exits non-zero on any failure.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import storage
from repro.core.build import build_pairwise_hist
from repro.core.types import BuildParams
from repro.gd.greedygd import GreedyGD
from repro.gd.preprocess import preprocess_table
from repro.serve.aqp import AQPServer


def _table(n=12_000):
    rng = np.random.default_rng(7)
    return {
        "a": rng.integers(0, 12, n).astype(float) * 500,   # few bases
        "b": np.round(rng.normal(800, 4, n)),              # narrow spread
        "c": rng.integers(0, 6, n).astype(float),
    }


def main() -> int:
    pp = preprocess_table(_table())
    ct = GreedyGD().compress(pp.data)
    ratio = ct.raw_size_bytes() / ct.size_bytes()
    if ratio <= 1.0:
        print(f"FAIL: compression ratio {ratio:.3f} <= 1")
        return 1
    print(f"compress: OK (ratio {ratio:.2f}x, "
          f"{ct.raw_size_bytes()} -> {ct.size_bytes()} bytes)")

    params = BuildParams(n_samples=5_000, seed=3)
    ph = build_pairwise_hist(ct, pp.columns, params)
    if not ph.build_stats.get("from_compressed"):
        print("FAIL: build did not take the compressed path")
        return 1
    decoded = ph.build_stats.get("rows_decoded")
    if decoded != 5_000 or decoded >= ct.n_rows:
        print(f"FAIL: expected 5000 sampled rows decoded, got {decoded} "
              f"(table has {ct.n_rows})")
        return 1
    raw = build_pairwise_hist(pp.data, pp.columns, params,
                              seed_edges=GreedyGD.seed_edges(ct))
    for h1, h2 in zip(ph.hists, raw.hists):
        if not (np.array_equal(h1.edges, h2.edges)
                and np.array_equal(h1.h, h2.h)):
            print("FAIL: compressed build differs from raw+seed_edges build")
            return 1
    print(f"gd-native build: OK ({decoded}/{ct.n_rows} rows decoded, "
          f"bit-identical to raw build)")

    blob = storage.encode(ph)
    srv = AQPServer(mode="numpy")
    srv.register_cold("t", blob, compressed=ct, params=params)
    cold = srv.catalog.resolve("t")
    e0 = srv.catalog.epoch("t")
    if cold.cold_info()["decoded"]:
        print("FAIL: registration decoded the blob eagerly")
        return 1
    sql = "SELECT COUNT(*) FROM t WHERE a > 2000"
    first = srv.query(sql)
    if cold.decode_count != 1 or srv.catalog.epoch("t") != e0:
        print(f"FAIL: first query: decode_count={cold.decode_count} "
              f"(want 1), epoch {e0} -> {srv.catalog.epoch('t')}")
        return 1
    srv.query("SELECT AVG(b) FROM t WHERE c < 3")
    if cold.decode_count != 1:
        print(f"FAIL: second query re-decoded (count={cold.decode_count})")
        return 1
    st = srv.stats()["tables"]["t"]["cold"]
    if st["synopsis_bytes"] != len(blob) or not st["decode_ms"]:
        print(f"FAIL: cold telemetry incomplete: {st}")
        return 1
    print(f"cold serve: OK (decode-once, {len(blob)} blob bytes, "
          f"{st['decode_ms']:.1f} ms decode, epoch stable)")

    cold.rebuild()
    if srv.catalog.epoch("t") <= e0:
        print(f"FAIL: rebuild did not bump the epoch ({e0} -> "
              f"{srv.catalog.epoch('t')})")
        return 1
    if len(srv.result_cache) != 0:
        print("FAIL: rebuild left stale result-cache entries")
        return 1
    again = srv.query(sql)
    if again.estimate is None or first.estimate is None:
        print("FAIL: no estimate before/after rebuild")
        return 1
    print(f"rebuild: OK (epoch {e0} -> {cold.epoch}, caches purged, "
          f"estimate {first.estimate:.0f} -> {again.estimate:.0f})")

    e1 = srv.catalog.epoch("t")
    dc = cold.decode_count
    if not srv.demote("t") or cold.engine is not None:
        print("FAIL: demote did not drop the decoded engine")
        return 1
    if srv.catalog.epoch("t") != e1:
        print(f"FAIL: demote moved the epoch ({e1} -> "
              f"{srv.catalog.epoch('t')})")
        return 1
    fresh = srv.query("SELECT COUNT(*) FROM t WHERE b < 810")
    if fresh.estimate is None or cold.decode_count != dc + 1:
        print(f"FAIL: post-demote query did not re-decode "
              f"(count={cold.decode_count}, want {dc + 1})")
        return 1
    redo = srv.query(sql)
    if redo.as_tuple()[:3] != again.as_tuple()[:3]:
        print(f"FAIL: post-demote answer drifted: "
              f"{again.as_tuple()[:3]} -> {redo.as_tuple()[:3]}")
        return 1
    snap = srv.stats()
    if snap["cold"]["demotes"] < 1 \
            or snap["tables"]["t"]["cold"]["demotes"] < 1:
        print(f"FAIL: demote telemetry missing: {snap.get('cold')}")
        return 1
    srv.close()
    print(f"demote: OK (re-decode {dc} -> {cold.decode_count}, epoch "
          f"stable at {e1}, answers bit-identical)")
    print("gd smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
