"""The end-to-end AQP framework of Fig. 2.

    raw table --preprocess--> integer domain --GreedyGD--> bases+deviations
                                   |                           |
                                   |                     (seed bin edges)
                                   v                           v
                            PairwiseHist  <--- BuildPairwiseHist(sample)
                                   |
        SQL --parse/encode--> QueryEngine --> (estimate, lower, upper)

Data lives compressed (CompressedTable); the synopsis answers queries without
touching it. ``append_rows`` supports incremental ingestion (compressed store
updated immediately; synopsis marked stale and rebuilt lazily) — the paper's
"more frequent updates" story.
"""
from __future__ import annotations

import itertools
import time
import types

import numpy as np

from repro.core.build import build_pairwise_hist
from repro.core.query import QueryEngine, QueryResult
from repro.core.types import BuildParams
from repro.core import storage as storagemod
from repro.gd.greedygd import GreedyGD
from repro.gd.preprocess import preprocess_table


class AQPFramework:
    # Process-global epoch sequence: epochs are unique across *all*
    # frameworks, so a serving cache entry tagged with one framework's epoch
    # can never validate against a different framework that replaced it
    # under the same catalog name (same-value collision is impossible).
    _epoch_seq = itertools.count(1)

    def __init__(self, params: BuildParams | None = None,
                 use_compression: bool = True, fastpath=None):
        self.params = params or BuildParams()
        self.use_compression = use_compression
        self.fastpath = fastpath
        self.gd = GreedyGD()
        self.compressed = None
        self.preprocessed = None
        self.synopsis = None
        self._raw_batches = []
        # Serving-layer integration: the queryable state is the ATOMICALLY
        # published (engine, epoch, timings) triple — one tuple assignment
        # whenever it changes (ingest / append_rows / rebuild), so a reader
        # snapshotting ``published`` can never observe an engine with the
        # wrong epoch (the serving scheduler's per-item epoch revalidation
        # and the plan-time epoch capture both rely on this). ``timings``
        # rides along as an immutable MappingProxyType: a server thread
        # snapshotting build telemetry mid-``rebuild()`` sees either the
        # whole old dict or the whole new one, never a half-built mutation.
        # Plan/result caches keyed on the epoch can never serve stale
        # answers; callbacks let a catalog purge eagerly.
        self._published: tuple = (None, 0, types.MappingProxyType({}))
        self._invalidate_cbs = []

    # ------------------------------------------------------- staleness hooks

    @property
    def engine(self):
        """The current QueryEngine, or None while stale (append_rows)."""
        return self._published[0]

    @property
    def epoch(self) -> int:
        """Staleness epoch of the currently published queryable state."""
        return self._published[1]

    @property
    def published(self) -> tuple:
        """Atomic (engine, epoch) snapshot — the pair was published in one
        assignment, so the engine is exactly the one built at that epoch."""
        return self._published[:2]

    @property
    def timings(self) -> "types.MappingProxyType":
        """Read-only build-timing telemetry published with the engine.

        Immutable by construction: ``ingest``/``rebuild`` assemble a fresh
        dict and publish it in the same tuple assignment as the engine, so
        concurrent readers never see partial updates and the keys always
        describe the *published* synopsis, not one mid-build.
        """
        return self._published[2]

    @property
    def is_stale(self) -> bool:
        return self.engine is None

    def on_invalidate(self, callback):
        """Register ``callback(framework)`` to fire on every epoch bump."""
        self._invalidate_cbs.append(callback)

    def off_invalidate(self, callback):
        """Detach a callback registered with ``on_invalidate`` (no-op if
        absent) — e.g. when a serving catalog replaces this framework."""
        try:
            self._invalidate_cbs.remove(callback)
        except ValueError:
            pass

    def _publish(self, engine, timings: dict | None = None):
        """Atomically publish ``(engine, fresh epoch, timings)`` and fire
        the invalidation callbacks (``engine=None`` marks the table stale;
        ``timings=None`` carries the previous telemetry forward)."""
        if timings is None:
            frozen = self._published[2]
        else:
            frozen = types.MappingProxyType(dict(timings))
        self._published = (engine, next(AQPFramework._epoch_seq), frozen)
        for cb in list(self._invalidate_cbs):
            cb(self)

    # -------------------------------------------------------------- ingest

    def ingest(self, table: dict) -> "AQPFramework":
        t0 = time.perf_counter()
        self.preprocessed = preprocess_table(table)
        t1 = time.perf_counter()
        if self.use_compression:
            self.compressed = self.gd.compress(self.preprocessed.data)
        t2 = time.perf_counter()
        # GD-native construction: build directly from the compressed store —
        # only the N_s sampled rows are decoded and the bases seed the 1-D
        # edges (bit-for-bit equal to the raw+seed_edges path).
        use_ct = self.use_compression and self.params.from_compressed
        build_input = self.compressed if use_ct else self.preprocessed.data
        seed_edges = (GreedyGD.seed_edges(self.compressed)
                      if self.use_compression and not use_ct else None)
        self.synopsis = build_pairwise_hist(
            build_input, self.preprocessed.columns, self.params,
            seed_edges=seed_edges)
        t3 = time.perf_counter()
        engine = QueryEngine(self.synopsis, fastpath=self.fastpath)
        # Pair-phase telemetry from the (batched) builder: rebuild() runs
        # through here too, so serving-cache invalidation pauses
        # (append_rows -> rebuild) are dominated by build_pairs_s.
        stats = self.synopsis.build_stats
        self._publish(engine, {
            "preprocess_s": t1 - t0, "compress_s": t2 - t1,
            "build_synopsis_s": t3 - t2,
            "build_pairs_s": stats.get("pair_phase_s", 0.0),
            "build_pair_mode": stats.get("mode", ""),
            "build_phase_s": dict(stats.get("phase_s", {})),
            "build_from_compressed": bool(stats.get("from_compressed")),
        })
        return self

    def ingest_compressed(self, compressed, columns) -> "AQPFramework":
        """Ingest an already-compressed table: build the synopsis straight
        from the ``CompressedTable`` (no raw matrix anywhere). ``columns``
        is the ``ColumnInfo`` list from pre-processing; this is the cold
        catalog's rebuild path."""
        t0 = time.perf_counter()
        self.compressed = compressed
        self.preprocessed = None
        self.synopsis = build_pairwise_hist(compressed, columns, self.params)
        t1 = time.perf_counter()
        engine = QueryEngine(self.synopsis, fastpath=self.fastpath)
        stats = self.synopsis.build_stats
        self._publish(engine, {
            "preprocess_s": 0.0, "compress_s": 0.0,
            "build_synopsis_s": t1 - t0,
            "build_pairs_s": stats.get("pair_phase_s", 0.0),
            "build_pair_mode": stats.get("mode", ""),
            "build_phase_s": dict(stats.get("phase_s", {})),
            "build_from_compressed": True,
        })
        return self

    def append_rows(self, table: dict):
        """Incremental ingestion: recompress the union (GD supports appends;
        dictionary growth forces re-coding here), mark synopsis stale."""
        self._raw_batches.append(table)
        self.synopsis = None
        self._publish(None)

    def _ensure_fresh(self):
        if self.engine is None:
            raise RuntimeError(
                "synopsis is stale after append_rows; call rebuild() first")

    def rebuild(self, base_table: dict):
        merged = dict(base_table)
        for batch in self._raw_batches:
            for k in merged:
                merged[k] = np.concatenate([np.asarray(merged[k]),
                                            np.asarray(batch[k])])
        self._raw_batches = []
        return self.ingest(merged)

    # -------------------------------------------------------------- queries

    def query(self, sql_text: str) -> QueryResult:
        self._ensure_fresh()
        return self.engine.query(sql_text)

    # -------------------------------------------------------------- reports

    def storage_report(self) -> dict:
        rep = {"synopsis": storagemod.synopsis_size_report(self.synopsis)}
        if self.compressed is not None:
            rep["compressed_data_bytes"] = self.compressed.size_bytes()
            rep["raw_data_bytes"] = self.compressed.raw_size_bytes()
            rep["compression_ratio"] = (self.compressed.raw_size_bytes()
                                        / max(self.compressed.size_bytes(), 1))
            rep["total_with_synopsis"] = (rep["compressed_data_bytes"]
                                          + rep["synopsis"]["total"])
            rep["total_storage_reduction"] = (rep["raw_data_bytes"]
                                              / max(rep["total_with_synopsis"], 1))
        return rep

    def size_bytes(self) -> int:
        return storagemod.synopsis_size_report(self.synopsis)["total"]
