"""Random query generation following the paper's evaluation protocol (§6):

  * aggregation in {COUNT, SUM, AVG, MIN, MAX, MEDIAN, VAR} on numeric cols;
  * 1–5 predicate conditions, AND/OR mixes, ops {<, <=, >, >=, =, !=};
  * equality predicates preferentially on categorical/low-cardinality cols;
  * minimum-selectivity rejection (10^-5 initial experiments, 10^-6 scaled).
"""
from __future__ import annotations

import numpy as np

from repro.aqp.exact import ExactEngine

AGGS_INITIAL = ("COUNT", "SUM", "AVG")
AGGS_FULL = ("COUNT", "SUM", "AVG", "MIN", "MAX", "MEDIAN", "VAR")


def _literal(rng, col):
    arr = np.asarray(col)
    if arr.dtype.kind in ("U", "S", "O"):
        vals = np.unique(arr.astype(str))
        return f"'{rng.choice(vals)}'", True
    x = arr.astype(np.float64)
    x = x[np.isfinite(x)]
    q = rng.uniform(0.02, 0.98)
    v = float(np.quantile(x, q))
    if np.allclose(x, np.round(x)):
        return str(int(round(v))), False
    return f"{v:.4f}", False


def generate_queries(table: dict, n_queries: int, seed: int = 0,
                     aggs=AGGS_FULL, max_preds: int = 5,
                     min_selectivity: float = 1e-5,
                     max_tries_factor: int = 30,
                     table_name: str = "t") -> list[str]:
    rng = np.random.default_rng(seed)
    exact = ExactEngine(table)
    names = list(table.keys())
    numeric = [c for c in names
               if np.asarray(table[c]).dtype.kind not in ("U", "S", "O")]
    out = []
    tries = 0
    while len(out) < n_queries and tries < n_queries * max_tries_factor:
        tries += 1
        func = rng.choice(aggs)
        agg_col = rng.choice(numeric)
        n_preds = int(rng.integers(1, max_preds + 1))
        conds = []
        for _ in range(n_preds):
            col = rng.choice(names)
            lit, is_cat = _literal(rng, table[col])
            if is_cat:
                op = rng.choice(["=", "!="], p=[0.8, 0.2])
            else:
                op = rng.choice(["<", "<=", ">", ">=", "=", "!="],
                                p=[0.24, 0.24, 0.24, 0.24, 0.02, 0.02])
            conds.append(f"{col} {op} {lit}")
        glue = [" AND " if rng.random() < 0.75 else " OR "
                for _ in range(len(conds) - 1)]
        where = conds[0]
        for g, c in zip(glue, conds[1:]):
            where += g + c
        sql = f"SELECT {func}({agg_col}) FROM {table_name} WHERE {where}"
        try:
            if exact.selectivity(sql) < min_selectivity:
                continue
            if exact.query(sql) is None:
                continue
        except (ValueError, KeyError):
            continue
        out.append(sql)
    return out


def relative_error(est, exact) -> float:
    """The paper's relative error metric (%); sMAPE-style guard at 0."""
    if est is None or exact is None:
        return 100.0
    if exact == 0:
        return 0.0 if abs(est) < 1e-9 else 100.0
    return abs(est - exact) / abs(exact) * 100.0
