"""Exact query engine over raw in-memory tables — the ground truth.

SQL-standard NULL semantics: comparisons with NULL are false; aggregates
ignore NULL; COUNT(col) counts non-null, COUNT(*) counts rows.
"""
from __future__ import annotations

import numpy as np

from repro.core import sql as sqlmod


class ExactEngine:
    def __init__(self, table: dict):
        self.table = {k: np.asarray(v) for k, v in table.items()}
        self.n = len(next(iter(self.table.values())))

    def _mask(self, node) -> np.ndarray:
        if node is None:
            return np.ones(self.n, bool)
        if isinstance(node, sqlmod.RawCond):
            col = self.table[node.col]
            if col.dtype.kind in ("U", "S", "O"):
                sval = str(node.value)
                eq = col.astype(str) == sval
                if node.op == "=":
                    return eq
                if node.op in ("!=", "<>"):
                    return ~eq
                raise ValueError(f"range op on categorical column {node.col}")
            x = col.astype(np.float64)
            v = float(node.value)
            with np.errstate(invalid="ignore"):
                out = {
                    "=": x == v, "!=": x != v, "<>": x != v,
                    "<": x < v, "<=": x <= v, ">": x > v, ">=": x >= v,
                }[node.op]
            return out & np.isfinite(x)  # NULL comparisons are false
        masks = [self._mask(ch) for ch in node.children]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if node.kind == "and" else (out | m)
        return out

    def query(self, sql_text: str):
        q = sqlmod.parse_sql(sql_text)
        mask = self._mask(q.where)
        if q.group_by is not None:
            gcol = self.table[q.group_by].astype(str)
            out = {}
            for val in np.unique(gcol[mask]):
                sub = mask & (gcol == val)
                r = self._agg(q.func, q.agg_col, sub)
                if r is not None and (q.func != "COUNT" or r > 0):
                    out[val] = r
            return out
        return self._agg(q.func, q.agg_col, mask)

    def _agg(self, func: str, col: str, mask: np.ndarray):
        if func == "COUNT":
            if col == "*":
                return float(mask.sum())
            x = self.table[col]
            if x.dtype.kind in ("U", "S", "O"):
                return float(mask.sum())
            return float((mask & np.isfinite(x.astype(np.float64))).sum())
        x = self.table[col].astype(np.float64)
        v = x[mask & np.isfinite(x)]
        if v.size == 0:
            return None
        return float({
            "SUM": v.sum(), "AVG": v.mean(), "MIN": v.min(), "MAX": v.max(),
            "MEDIAN": np.median(v), "VAR": v.var(),
        }[func])

    def selectivity(self, sql_text: str) -> float:
        q = sqlmod.parse_sql(sql_text)
        return float(self._mask(q.where).sum()) / self.n
