# End-to-end AQP framework (Fig. 2): ingestion -> GreedyGD -> PairwiseHist ->
# query execution; plus ground truth, baselines, datasets and query generation.
from repro.aqp.engine import AQPFramework  # noqa: F401
from repro.aqp.exact import ExactEngine  # noqa: F401
