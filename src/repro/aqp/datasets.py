"""Synthetic dataset suite modeled on the paper's 11 evaluation datasets
(Table 4) — offline stand-ins with matching schema *shape* and statistics:
mixed numeric/categorical, quantized sensor readings, strong pair
correlations, heavy skew, and missing values from asynchronous sources.

Also provides an IDEBench-style ``scale_up`` (§6: normalisation + Gaussian
perturbation resampling).
"""
from __future__ import annotations

import numpy as np

REGISTRY = {}


def dataset(name):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


@dataset("power")
def power(n: int = 500_000, seed: int = 0) -> dict:
    """Household electric power consumption (10 columns, quantized floats)."""
    rng = np.random.default_rng(seed)
    ts = np.arange(n, dtype=np.float64) * 60.0
    hour = (ts / 3600.0) % 24
    daily = 0.6 + 0.5 * np.exp(-((hour - 19) ** 2) / 8) + 0.2 * np.exp(-((hour - 7) ** 2) / 4)
    gap = np.round(np.abs(daily * rng.gamma(2.0, 0.6, n)), 3)
    grp = np.round(np.abs(rng.normal(0.12, 0.08, n)), 3)
    voltage = np.round(rng.normal(240.0, 3.2, n), 1)
    intensity = np.round(gap * 1000.0 / voltage / 0.95 + rng.normal(0, 0.2, n), 1)
    sub1 = np.round(np.clip(gap * rng.beta(2, 8, n) * 16, 0, None))
    sub2 = np.round(np.clip(gap * rng.beta(2, 6, n) * 13, 0, None))
    sub3 = np.round(np.clip(gap * rng.beta(4, 6, n) * 18, 0, None))
    day = np.floor(ts / 86400.0) % 31 + 1
    month = np.floor(ts / (86400.0 * 30)) % 12 + 1
    return {
        "ts": ts, "month": month, "day": day,
        "global_active_power": gap, "global_reactive_power": grp,
        "voltage": voltage, "global_intensity": intensity,
        "sub_metering_1": sub1, "sub_metering_2": sub2, "sub_metering_3": sub3,
    }


@dataset("flights")
def flights(n: int = 500_000, seed: int = 1) -> dict:
    """Flight delays & cancellations (mixed categorical/numeric, nulls)."""
    rng = np.random.default_rng(seed)
    airlines = np.array(["AA", "DL", "UA", "WN", "B6", "AS", "NK", "F9", "HA",
                         "VX", "OO", "EV", "MQ", "US"])
    airports = np.array([f"A{i:03d}" for i in range(120)])
    airline = airlines[rng.choice(len(airlines), n, p=_zipf_p(len(airlines), 1.3, rng))]
    origin = airports[rng.choice(len(airports), n, p=_zipf_p(len(airports), 1.2, rng))]
    dest = airports[rng.choice(len(airports), n, p=_zipf_p(len(airports), 1.2, rng))]
    month = rng.integers(1, 13, n).astype(float)
    dow = rng.integers(1, 8, n).astype(float)
    dist = np.round(rng.gamma(2.2, 380.0, n) + 69)
    air_time = np.round(dist / 7.7 + rng.normal(18, 9, n), 1)  # correlated pair (Fig. 7)
    dep_delay = np.round(rng.exponential(12.0, n) - 4.0)
    arr_delay = np.round(dep_delay + rng.normal(-2, 12, n))
    sched = np.round(rng.uniform(300, 1439, n))
    taxi_out = np.round(np.abs(rng.normal(16, 7, n)))
    cancelled = (rng.random(n) < 0.015).astype(float)
    # Cancelled flights have no airborne stats (missing values).
    for col in (air_time, arr_delay):
        col[cancelled == 1] = np.nan
    dep_delay[rng.random(n) < 0.01] = np.nan
    return {
        "airline": airline, "origin": origin, "dest": dest,
        "month": month, "day_of_week": dow, "sched_dep": sched,
        "dep_delay": dep_delay, "taxi_out": taxi_out, "distance": dist,
        "air_time": air_time, "arr_delay": arr_delay, "cancelled": cancelled,
    }


@dataset("iot_temp")
def iot_temp(n: int = 400_000, seed: int = 2) -> dict:
    """Temperature IoT on GCP-style: 5 columns, single source."""
    rng = np.random.default_rng(seed)
    ts = np.arange(n, dtype=np.float64) * 30.0
    device = np.array([f"dev{i}" for i in range(8)])[rng.integers(0, 8, n)]
    base = 21.0 + 4.0 * np.sin(ts / 86400.0 * 2 * np.pi)
    temp = np.round(base + rng.normal(0, 0.6, n), 1)
    humidity = np.round(np.clip(55 - (temp - 21) * 2.5 + rng.normal(0, 3, n), 5, 95), 1)
    battery = np.round(np.clip(100 - ts / ts.max() * 60 + rng.normal(0, 2, n), 0, 100))
    return {"ts": ts, "device": device, "temp": temp,
            "humidity": humidity, "battery": battery}


@dataset("aqua")
def aqua(n: int = 300_000, seed: int = 3) -> dict:
    """Aquaponics ponds: multi-source columns sharing a timestamp ->
    asynchronous sampling -> many nulls (like Aqua/Build in the paper)."""
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, 90 * 86400, n)).round()
    pond = np.array([f"pond{i}" for i in range(6)])[rng.integers(0, 6, n)]
    cols = {"ts": ts, "pond": pond}
    for k, (mean, sd, decimals, p_present) in enumerate([
            (7.1, 0.4, 2, 0.55), (26.0, 2.0, 1, 0.6), (5.2, 1.1, 2, 0.5),
            (180.0, 40.0, 0, 0.45), (0.45, 0.2, 2, 0.5), (3.1, 0.9, 1, 0.55),
            (12.0, 3.0, 1, 0.4), (650.0, 120.0, 0, 0.45), (1.8, 0.6, 2, 0.5),
            (95.0, 20.0, 0, 0.4), (0.08, 0.04, 3, 0.45)]):
        vals = np.round(np.abs(rng.normal(mean, sd, n)), decimals)
        vals[rng.random(n) > p_present] = np.nan  # asynchronous source
        cols[f"sensor_{k}"] = vals
    return cols


@dataset("taxi")
def taxi(n: int = 400_000, seed: int = 4) -> dict:
    """Chicago taxi trips: strongly correlated fare/miles/seconds + skew."""
    rng = np.random.default_rng(seed)
    miles = np.round(rng.gamma(1.4, 2.6, n), 1)
    seconds = np.round(miles * 160 + np.abs(rng.normal(250, 150, n)))
    fare = np.round(3.25 + miles * 2.25 + seconds * 0.005 + rng.normal(0, 1, n), 2)
    fare = np.clip(fare, 3.25, None)
    tips = np.round(np.where(rng.random(n) < 0.55, fare * rng.beta(2, 8, n), 0), 2)
    payment = np.array(["card", "cash", "mobile", "other"])[
        rng.choice(4, n, p=[0.55, 0.35, 0.08, 0.02])]
    company = np.array([f"co{i}" for i in range(16)])[
        rng.choice(16, n, p=_zipf_p(16, 1.5, rng))]
    pickup = rng.integers(1, 78, n).astype(float)
    dropoff = rng.integers(1, 78, n).astype(float)
    tolls = np.round(np.where(rng.random(n) < 0.03, rng.uniform(1, 8, n), 0), 2)
    tips[rng.random(n) < 0.02] = np.nan
    return {"trip_miles": miles, "trip_seconds": seconds, "fare": fare,
            "tips": tips, "tolls": tolls, "payment_type": payment,
            "company": company, "pickup_area": pickup, "dropoff_area": dropoff}


@dataset("gas")
def gas(n: int = 300_000, seed: int = 5) -> dict:
    """Home gas-sensor array: drifting baselines + correlated channels."""
    rng = np.random.default_rng(seed)
    ts = np.arange(n, dtype=np.float64)
    drift = np.cumsum(rng.normal(0, 0.01, n))
    cols = {"ts": ts}
    base = 12.0 + drift
    for k in range(8):
        gain = 1.0 + 0.15 * k
        cols[f"r{k}"] = np.round(base * gain + rng.normal(0, 0.4, n), 2)
    cols["temp"] = np.round(24 + 3 * np.sin(ts / 5000) + rng.normal(0, 0.3, n), 1)
    cols["humidity"] = np.round(48 - 2 * np.sin(ts / 5000) + rng.normal(0, 1, n), 1)
    cols["co_ppm"] = np.round(np.abs(rng.gamma(1.2, 2.0, n)), 1)
    return cols


def _zipf_p(k: int, a: float, rng) -> np.ndarray:
    p = 1.0 / np.arange(1, k + 1) ** a
    return p / p.sum()


def load(name: str, n: int | None = None, seed: int | None = None) -> dict:
    fn = REGISTRY[name]
    kwargs = {}
    if n is not None:
        kwargs["n"] = n
    if seed is not None:
        kwargs["seed"] = seed
    return fn(**kwargs)


def scale_up(table: dict, factor: int, seed: int = 0,
             noise_frac: float = 0.02) -> dict:
    """IDEBench-style scale-up: bootstrap resample + Gaussian perturbation of
    numeric columns (categoricals resampled as-is)."""
    rng = np.random.default_rng(seed)
    n = len(next(iter(table.values())))
    m = n * factor
    idx = rng.integers(0, n, m)
    out = {}
    for name, col in table.items():
        arr = np.asarray(col)[idx]
        if arr.dtype.kind == "f":
            finite = np.isfinite(arr)
            sd = np.nanstd(np.asarray(col, np.float64))
            decimals = _infer_decimals(np.asarray(col, np.float64))
            noise = rng.normal(0, max(sd, 1e-9) * noise_frac, m)
            arr = np.where(finite, np.round(arr + noise, decimals), arr)
        out[name] = arr
    return out


def _infer_decimals(col: np.ndarray, max_decimals: int = 6) -> int:
    finite = col[np.isfinite(col)][:10000]
    for p in range(max_decimals + 1):
        if np.all(np.abs(finite * 10**p - np.round(finite * 10**p)) < 1e-6):
            return p
    return max_decimals
