"""Comparison baselines.

DeepDB and DBEst++ (the paper's baselines) are unavailable offline; we
implement the two classical families they descend from, which bracket the
design space the paper argues against:

  * ``SamplingAQP``  — offline uniform-sample AQP (BlinkDB-family): evaluate
    the query exactly on an n-row sample, scale counts/sums by 1/rho, CLT
    bounds. Strong on accuracy per byte, weak on skew/outliers.
  * ``HistProductAQP`` — classical synopsis AQP: independent per-column
    equi-depth histograms, selectivity = product of marginal coverages
    (attribute-value independence) — what PairwiseHist's 2-D histograms fix.

Both expose the same .query(sql) -> (est, lo, hi) and .size_bytes() API as
the PairwiseHist engine, so benchmarks sweep engines uniformly.
"""
from __future__ import annotations

import numpy as np

from repro.aqp.exact import ExactEngine
from repro.core import sql as sqlmod

_Z98 = 2.3263478740408408


class SamplingAQP:
    def __init__(self, table: dict, n_sample: int = 100_000, seed: int = 0):
        self.n = len(next(iter(table.values())))
        rng = np.random.default_rng(seed)
        take = min(n_sample, self.n)
        idx = rng.choice(self.n, take, replace=False)
        self.sample = {k: np.asarray(v)[idx] for k, v in table.items()}
        self.rho = take / self.n
        self._exact = ExactEngine(self.sample)

    def size_bytes(self) -> int:
        total = 0
        for v in self.sample.values():
            arr = np.asarray(v)
            if arr.dtype.kind in ("U", "S", "O"):
                total += sum(len(str(x)) for x in arr[:1000]) * (len(arr) // 1000 + 1)
            else:
                total += arr.astype(np.float64).nbytes
        return total

    def query(self, sql_text: str):
        q = sqlmod.parse_sql(sql_text)
        mask = self._exact._mask(q.where)
        est = self._exact._agg(q.func, q.agg_col, mask)
        if est is None:
            return None, None, None
        n_match = float(mask.sum())
        if q.func in ("COUNT", "SUM"):
            est = est / self.rho
            # CLT bound on the match count (binomial, finite population).
            p = n_match / max(len(mask), 1)
            se = np.sqrt(max(p * (1 - p) * len(mask), 0.0)) / self.rho
            if q.func == "COUNT":
                return est, max(est - _Z98 * se, 0.0), est + _Z98 * se
            mean = est / max(n_match / self.rho, 1.0)
            return est, est - _Z98 * se * abs(mean), est + _Z98 * se * abs(mean)
        if q.func == "AVG":
            col = self.sample[q.agg_col].astype(np.float64)
            v = col[mask & np.isfinite(col)]
            se = v.std() / np.sqrt(max(v.size, 1))
            return est, est - _Z98 * se, est + _Z98 * se
        return est, est, est  # MIN/MAX/MEDIAN/VAR: sample value, no real bound


class HistProductAQP:
    """Per-column equi-depth histograms + independence assumption."""

    def __init__(self, table: dict, n_sample: int = 100_000, bins: int = 64,
                 seed: int = 0):
        self.n = len(next(iter(table.values())))
        rng = np.random.default_rng(seed)
        take = min(n_sample, self.n)
        idx = rng.choice(self.n, take, replace=False)
        self.rho = take / self.n
        self.bins = bins
        self.hists = {}
        self.cats = {}
        for name, col in table.items():
            arr = np.asarray(col)[idx]
            if arr.dtype.kind in ("U", "S", "O"):
                vals, counts = np.unique(arr.astype(str), return_counts=True)
                self.cats[name] = (vals, counts.astype(np.float64))
                continue
            x = arr.astype(np.float64)
            x = x[np.isfinite(x)]
            if x.size == 0:
                continue
            qs = np.quantile(x, np.linspace(0, 1, bins + 1))
            edges = np.unique(qs)
            h, _ = np.histogram(x, bins=edges)
            mids = 0.5 * (edges[:-1] + edges[1:])
            self.hists[name] = (edges, h.astype(np.float64), mids, x.size)

    def size_bytes(self) -> int:
        total = 0
        for edges, h, mids, _ in self.hists.values():
            total += edges.nbytes + h.nbytes
        for vals, counts in self.cats.values():
            total += sum(len(v) for v in vals) + counts.nbytes
        return total

    def _cond_fraction(self, cond: sqlmod.RawCond) -> float:
        """Marginal selectivity of one condition."""
        if cond.col in self.cats:
            vals, counts = self.cats[cond.col]
            total = counts.sum()
            match = counts[vals == str(cond.value)].sum()
            frac = match / max(total, 1.0)
            return frac if cond.op == "=" else 1.0 - frac
        if cond.col not in self.hists:
            return 0.0
        edges, h, mids, n = self.hists[cond.col]
        v = float(cond.value)
        total = h.sum()
        lo, hi = edges[:-1], edges[1:]
        width = np.maximum(hi - lo, 1e-300)
        if cond.op in ("<", "<="):
            frac_bin = np.clip((v - lo) / width, 0, 1)
        elif cond.op in (">", ">="):
            frac_bin = np.clip((hi - v) / width, 0, 1)
        else:
            inside = (lo <= v) & (v <= hi)
            frac_bin = np.where(inside, np.minimum(1.0 / np.maximum(h, 1), 1.0), 0.0)
            if cond.op in ("!=", "<>"):
                frac_bin = 1.0 - frac_bin
        return float((h * frac_bin).sum() / max(total, 1.0))

    def _selectivity(self, node) -> float:
        if node is None:
            return 1.0
        if isinstance(node, sqlmod.RawCond):
            return self._cond_fraction(node)
        fracs = [self._selectivity(ch) for ch in node.children]
        if node.kind == "and":
            out = 1.0
            for f in fracs:
                out *= f
            return out
        out = 1.0
        for f in fracs:
            out *= (1.0 - f)
        return 1.0 - out

    def _weighted_hist(self, col: str, node):
        """Weight the aggregation column's own histogram by its own
        conditions exactly; other columns contribute a scalar selectivity."""
        edges, h, mids, n = self.hists[col]
        w = h.astype(np.float64).copy()
        scalar = 1.0
        conds_self, others = [], []

        def walk(nd, own, oth):
            if nd is None:
                return
            if isinstance(nd, sqlmod.RawCond):
                (own if nd.col == col else oth).append(nd)
                return
            for ch in nd.children:
                walk(ch, own, oth)

        walk(node, conds_self, others)
        lo, hi = edges[:-1], edges[1:]
        width = np.maximum(hi - lo, 1e-300)
        for cond in conds_self:
            v = float(cond.value)
            if cond.op in ("<", "<="):
                w = w * np.clip((v - lo) / width, 0, 1)
            elif cond.op in (">", ">="):
                w = w * np.clip((hi - v) / width, 0, 1)
            elif cond.op == "=":
                w = w * np.where((lo <= v) & (v <= hi), 1.0 / np.maximum(h, 1), 0.0)
            else:
                w = w * (1 - np.where((lo <= v) & (v <= hi), 1.0 / np.maximum(h, 1), 0.0))
        for cond in others:
            scalar *= self._cond_fraction(cond)
        return w * scalar, mids

    def query(self, sql_text: str):
        q = sqlmod.parse_sql(sql_text)
        if q.func == "COUNT":
            sel = self._selectivity(q.where)
            est = sel * self.n
            return est, None, None
        if q.agg_col not in self.hists:
            return None, None, None
        w, mids = self._weighted_hist(q.agg_col, q.where)
        tot = w.sum()
        if tot <= 0:
            return None, None, None
        if q.func == "SUM":
            return float(w @ mids / self.rho), None, None
        if q.func == "AVG":
            return float(w @ mids / tot), None, None
        if q.func == "VAR":
            m = w @ mids / tot
            return float(w @ (mids**2) / tot - m**2), None, None
        nz = np.flatnonzero(w > 1e-9)
        edges = self.hists[q.agg_col][0]
        if q.func == "MIN":
            return float(edges[nz[0]]), None, None
        if q.func == "MAX":
            return float(edges[nz[-1] + 1]), None, None
        cum = np.cumsum(w)
        t = int(np.searchsorted(cum, 0.5 * tot))
        return float(mids[min(t, len(mids) - 1)]), None, None
