"""Fused JAX/Pallas query fast path (beyond-paper optimization, §Perf).

The paper's execution model runs ~3 small ops per predicate (mat-vec, fold,
divide) plus a combine — at sub-ms latencies the launch/dispatch overhead
dominates. This path stacks all AND-ed predicates of a query and executes
ONE fused kernel per bound variant (estimate / lower / upper).

Supported: AND trees of leaves (the dominant template in the paper's
workload). OR / nested trees return None -> engine falls back to the NumPy
reference path (repro.core.weightings), which is also the oracle in tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import coverage as covlib
from repro.core import weightings as wlib
from repro.kernels.weightings import fused_weightings

Z_98 = wlib.Z_98


def _flat_and_leaves(tree):
    """Tree -> list of Leaf/Consolidated if it is a pure AND tree, else None."""
    if isinstance(tree, (wlib.Leaf, wlib.Consolidated)):
        return [tree]
    if isinstance(tree, wlib.Node) and tree.kind == "and":
        out = []
        for ch in tree.children:
            sub = _flat_and_leaves(ch)
            if sub is None:
                return None
            out.extend(sub)
        return out
    return None


def _slice_beta(ph, leaf, h, u, vmin, vmax, mu):
    if isinstance(leaf, wlib.Consolidated):
        beta = covlib.coverage_intervals(leaf.intervals, h, u, vmin, vmax, mu)
    else:
        beta = covlib.coverage_single(leaf.op, leaf.value, h, u, vmin, vmax)
    blo, bhi = covlib.coverage_bounds(
        beta, h, u, ph.params.min_points, ph.chi2_table, ph.params.s1_max)
    return beta, blo, bhi


def _round_up(x: int, mult: int = 128) -> int:
    return ((x + mult - 1) // mult) * mult


def make_fastpath(use_pallas: bool = True):
    """Returns the engine hook: (ph, agg_col, tree, corrected) -> w-triple.

    The padded (H, fold) stacks depend only on (agg column, predicate
    columns), NOT on the query literals — they are device-resident constants
    of the synopsis. We cache them per column set (on TPU they'd simply stay
    in HBM/VMEM); per query only the tiny beta vectors are assembled.
    """
    stack_cache: dict = {}

    def get_stack(ph, agg_col, pred_cols):
        key = (id(ph), agg_col, pred_cols)
        if key in stack_cache:
            return stack_cache[key]
        hist = ph.hists[agg_col]
        k1 = int(hist.k)
        prs = [ph.pair(agg_col, j) for j in pred_cols]
        k2max = _round_up(max(max(p.H.shape) for p in prs))
        k1p = _round_up(k1)
        el = len(prs)
        hpad = np.zeros((el, k2max, k2max), np.float32)
        hxpad = np.zeros((el, k2max), np.float32)
        fpad = np.zeros((el, k1p, k2max), np.float32)
        for li, pr in enumerate(prs):
            hpad[li, :pr.H.shape[0], :pr.H.shape[1]] = pr.H
            # per-row denominator = 1-D mass inside the row (incl. j-NULLs)
            denom = np.zeros(int(pr.kx))
            np.add.at(denom, pr.fold_x, hist.h)
            hxpad[li, :pr.H.shape[0]] = denom
            fpad[li, np.arange(k1), np.asarray(pr.fold_x)] = 1.0
        import jax.numpy as jnp
        entry = (jnp.asarray(hpad), jnp.asarray(fpad), jnp.asarray(hxpad),
                 k1, k2max)
        stack_cache[key] = entry
        return entry

    def fastpath(ph, agg_col, tree, corrected):
        leaves = _flat_and_leaves(tree)
        if leaves is None:
            return None  # OR / nested: NumPy reference path
        hist = ph.hists[agg_col]
        k1 = int(hist.k)

        same_col = [[], [], []]   # product of (k1,) probs for j == agg_col
        pair_leaves = []
        for leaf in leaves:
            if leaf.col == agg_col:
                triple = _slice_beta(ph, leaf, hist.h, hist.u, hist.vmin,
                                     hist.vmax, ph.columns[leaf.col].mu)
                for idx in range(3):
                    same_col[idx].append(np.clip(triple[idx], 0.0, 1.0))
            else:
                pair_leaves.append(leaf)

        outs = []
        if pair_leaves:
            pred_cols = tuple(lf.col for lf in pair_leaves)
            hpad, fpad, hxpad, k1c, k2max = get_stack(ph, agg_col, pred_cols)
            el = len(pair_leaves)
            betas = [np.zeros((el, k2max), np.float32) for _ in range(3)]
            for li, leaf in enumerate(pair_leaves):
                pr = ph.pair(agg_col, leaf.col)
                triple = _slice_beta(ph, leaf, pr.hy, pr.uy, pr.vminy,
                                     pr.vmaxy, ph.columns[leaf.col].mu)
                for idx in range(3):
                    betas[idx][li, :len(triple[idx])] = triple[idx]
            for idx in range(3):
                prob1 = np.asarray(fused_weightings(
                    hpad, betas[idx], fpad, hxpad,
                    use_pallas=use_pallas))[:k1]
                w = np.asarray(hist.h, np.float64) * prob1
                for prob in same_col[idx]:
                    w = w * prob
                outs.append(np.asarray(w, np.float64))
        else:
            for idx in range(3):
                w = np.asarray(hist.h, np.float64).copy()
                for prob in same_col[idx]:
                    w = w * prob
                outs.append(w)
        w, wlo, whi = outs

        rho = ph.rho
        if rho < 1.0:  # Eq. 29 widening (same as the reference path)
            fpc = (ph.n_rows - ph.n_sampled) / max(ph.n_rows - 1, 1)
            h = np.asarray(hist.h, np.float64)
            blo = np.divide(wlo, h, out=np.zeros_like(wlo), where=h > 0)
            bhi = np.divide(whi, h, out=np.zeros_like(whi), where=h > 0)
            var_lo = blo * (1.0 - blo) * fpc
            var_hi = bhi * (1.0 - bhi) * fpc
            if corrected:
                var_lo, var_hi = var_lo * h, var_hi * h
            wlo = wlo - Z_98 * np.sqrt(np.maximum(var_lo, 0.0))
            whi = whi + Z_98 * np.sqrt(np.maximum(var_hi, 0.0))
        wlo = np.clip(wlo, 0.0, w)
        whi = np.clip(whi, w, np.asarray(hist.h, np.float64))
        return w, wlo, whi

    return fastpath
