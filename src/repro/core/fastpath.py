"""Fused JAX/Pallas query fast path (beyond-paper optimization, §Perf).

The paper's execution model runs ~3 small ops per predicate (mat-vec, fold,
divide) plus a combine — at sub-ms latencies the launch/dispatch overhead
dominates. This path stacks all AND-ed predicates of a query and executes
ONE fused kernel per bound variant (estimate / lower / upper).

``FastPath`` additionally exposes a *query-batched* entry (``batch``): a
group of queries sharing a plan shape (same agg column, same pair-predicate
column set) executes as ONE launch covering every query and all three bound
variants — the serving-layer analogue of the per-predicate fusion, used by
``repro.serve.aqp.scheduler.BatchScheduler``.

Supported: AND trees of leaves (the dominant template in the paper's
workload). OR / nested trees return None -> engine falls back to the NumPy
reference path (repro.core.weightings), which is also the oracle in tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import coverage as covlib
from repro.core import weightings as wlib
from repro.kernels.weightings import batched_weightings, fused_weightings

Z_98 = wlib.Z_98

_flat_and_leaves = wlib.flat_and_leaves  # back-compat alias


def _slice_beta(ph, leaf, h, u, vmin, vmax, mu):
    if isinstance(leaf, wlib.Consolidated):
        beta = covlib.coverage_intervals(leaf.intervals, h, u, vmin, vmax, mu)
    else:
        beta = covlib.coverage_single(leaf.op, leaf.value, h, u, vmin, vmax)
    blo, bhi = covlib.coverage_bounds(
        beta, h, u, ph.params.min_points, ph.chi2_table, ph.params.s1_max)
    return beta, blo, bhi


def _round_up(x: int, mult: int = 128) -> int:
    return ((x + mult - 1) // mult) * mult


def _widen_clip(w, wlo, whi, ph, h, corrected):
    """Eq. 29 sampling widening + monotone clipping (same as the reference
    path). Broadcasts over leading batch dimensions: w/wlo/whi are (..., K1),
    h is (K1,)."""
    rho = ph.rho
    if rho < 1.0:
        fpc = (ph.n_rows - ph.n_sampled) / max(ph.n_rows - 1, 1)
        blo = np.divide(wlo, h, out=np.zeros_like(wlo), where=h > 0)
        bhi = np.divide(whi, h, out=np.zeros_like(whi), where=h > 0)
        var_lo = blo * (1.0 - blo) * fpc
        var_hi = bhi * (1.0 - bhi) * fpc
        if corrected:
            var_lo, var_hi = var_lo * h, var_hi * h
        wlo = wlo - Z_98 * np.sqrt(np.maximum(var_lo, 0.0))
        whi = whi + Z_98 * np.sqrt(np.maximum(var_hi, 0.0))
    wlo = np.clip(wlo, 0.0, w)
    whi = np.clip(whi, w, h)
    return w, wlo, whi


class FastPath:
    """Engine hook: (ph, agg_col, tree, corrected) -> weightings triple.

    The padded (H, fold) stacks depend only on (agg column, predicate
    columns), NOT on the query literals — they are device-resident constants
    of the synopsis. We cache them per column set (on TPU they'd simply stay
    in HBM/VMEM); per query only the tiny beta vectors are assembled.
    """

    def __init__(self, use_pallas: bool = True):
        self.use_pallas = use_pallas

    # ----------------------------------------------------------- shared stacks

    def _get_stack(self, ph, agg_col, pred_cols):
        # The stack cache lives ON the synopsis object: its lifetime is
        # exactly the synopsis's (a rebuild produces a new PairwiseHist, so
        # stale stacks can never be served and the old device arrays are
        # garbage-collected with the old synopsis). Keying an external dict
        # on id(ph) would leak per rebuild and could alias a recycled id.
        cache = getattr(ph, "_fastpath_stacks", None)
        if cache is None:
            cache = {}
            ph._fastpath_stacks = cache
        key = (agg_col, pred_cols)
        if key in cache:
            return cache[key]
        hist = ph.hists[agg_col]
        k1 = int(hist.k)
        prs = [ph.pair(agg_col, j) for j in pred_cols]
        k2max = _round_up(max(max(p.H.shape) for p in prs))
        k1p = _round_up(k1)
        el = len(prs)
        hpad = np.zeros((el, k2max, k2max), np.float32)
        hxpad = np.zeros((el, k2max), np.float32)
        fpad = np.zeros((el, k1p, k2max), np.float32)
        for li, pr in enumerate(prs):
            hpad[li, :pr.H.shape[0], :pr.H.shape[1]] = pr.H
            # per-row denominator = 1-D mass inside the row (incl. j-NULLs)
            denom = np.zeros(int(pr.kx))
            np.add.at(denom, pr.fold_x, hist.h)
            hxpad[li, :pr.H.shape[0]] = denom
            fpad[li, np.arange(k1), np.asarray(pr.fold_x)] = 1.0
        import jax.numpy as jnp
        entry = (jnp.asarray(hpad), jnp.asarray(fpad), jnp.asarray(hxpad),
                 k1, k2max)
        cache[key] = entry
        return entry

    def _split_leaves(self, ph, agg_col, tree):
        """Pure-AND tree -> (same-col beta triples, pair leaves) or None."""
        leaves = wlib.flat_and_leaves(tree)
        if leaves is None:
            return None
        hist = ph.hists[agg_col]
        same_col = [[], [], []]   # per variant: (k1,) probs for j == agg_col
        pair_leaves = []
        for leaf in leaves:
            if leaf.col == agg_col:
                triple = _slice_beta(ph, leaf, hist.h, hist.u, hist.vmin,
                                     hist.vmax, ph.columns[leaf.col].mu)
                for idx in range(3):
                    same_col[idx].append(np.clip(triple[idx], 0.0, 1.0))
            else:
                pair_leaves.append(leaf)
        # Canonical (sorted-column) leaf order: the single and batched paths
        # then share one cached stack per column set regardless of the order
        # predicates appeared in the WHERE clause.
        pair_leaves.sort(key=lambda lf: lf.col)
        return same_col, pair_leaves

    def _pair_betas(self, ph, agg_col, pair_leaves, k2max):
        """(3, L, K2max) coverage matrix for one query's pair leaves."""
        el = len(pair_leaves)
        betas = np.zeros((3, el, k2max), np.float32)
        for li, leaf in enumerate(pair_leaves):
            pr = ph.pair(agg_col, leaf.col)
            triple = _slice_beta(ph, leaf, pr.hy, pr.uy, pr.vminy,
                                 pr.vmaxy, ph.columns[leaf.col].mu)
            for idx in range(3):
                betas[idx, li, :len(triple[idx])] = triple[idx]
        return betas

    def _pair_betas_batch(self, ph, agg_col, leaf_lists, k2max):
        """(B, 3, L, K2max) coverage stack for B same-shape queries.

        Vectorized per-leaf beta assembly: the B leaves on pair column
        ``li`` share the slice metadata (h, u, v-, v+), so simple-op leaves
        stack their literals into ONE broadcasted ``coverage_single`` +
        ``coverage_bounds`` evaluation per (column, operator) group —
        replacing the per-query-per-wave Python calls into ``_pair_betas``.
        Consolidated (interval-set) leaves keep the per-leaf path; they are
        the rarity in batched waves. Bit-for-bit equal to stacking
        ``_pair_betas`` per query (same elementwise arithmetic, broadcast
        over a leading batch axis).
        """
        nq = len(leaf_lists)
        el = len(leaf_lists[0])
        betas = np.zeros((nq, 3, el, k2max), np.float32)
        for li in range(el):
            leaves = [pls[li] for pls in leaf_lists]
            col = leaves[0].col
            pr = ph.pair(agg_col, col)
            h, u = pr.hy, pr.uy
            vmin, vmax = pr.vminy, pr.vmaxy
            k = len(np.asarray(h))
            mu = ph.columns[col].mu
            by_op: dict[str, list] = {}
            for qi, leaf in enumerate(leaves):
                if isinstance(leaf, wlib.Consolidated):
                    triple = _slice_beta(ph, leaf, h, u, vmin, vmax, mu)
                    for idx in range(3):
                        betas[qi, idx, li, :k] = triple[idx]
                else:
                    by_op.setdefault(leaf.op, []).append(qi)
            for op, qis in by_op.items():
                values = np.array([[leaves[qi].value] for qi in qis],
                                  float)                       # (Bg, 1)
                beta = covlib.coverage_single(op, values, h, u, vmin, vmax)
                blo, bhi = covlib.coverage_bounds(
                    beta, h, u, ph.params.min_points, ph.chi2_table,
                    ph.params.s1_max)
                rows = np.asarray(qis)
                for idx, arr in enumerate((beta, blo, bhi)):
                    betas[rows, idx, li, :k] = arr
        return betas

    # ------------------------------------------------------------ single query

    def __call__(self, ph, agg_col, tree, corrected):
        split = self._split_leaves(ph, agg_col, tree)
        if split is None:
            return None  # OR / nested: NumPy reference path
        same_col, pair_leaves = split
        hist = ph.hists[agg_col]
        h = np.asarray(hist.h, np.float64)

        outs = []
        if pair_leaves:
            pred_cols = tuple(lf.col for lf in pair_leaves)
            hpad, fpad, hxpad, k1c, k2max = self._get_stack(
                ph, agg_col, pred_cols)
            betas = self._pair_betas(ph, agg_col, pair_leaves, k2max)
            for idx in range(3):
                prob1 = np.asarray(fused_weightings(
                    hpad, betas[idx], fpad, hxpad,
                    use_pallas=self.use_pallas))[:k1c]
                w = h * prob1
                for prob in same_col[idx]:
                    w = w * prob
                outs.append(np.asarray(w, np.float64))
        else:
            for idx in range(3):
                w = h.copy()
                for prob in same_col[idx]:
                    w = w * prob
                outs.append(w)
        w, wlo, whi = outs
        return _widen_clip(w, wlo, whi, ph, h, corrected)

    # ------------------------------------------------------------- query batch

    def batch(self, ph, agg_col, trees, corrected):
        """One fused launch for B same-shape queries (x3 bound variants).

        Every tree must be a pure AND with an identical pair-predicate column
        *set* (same-column leaves are free to differ — they apply as
        elementwise products outside the kernel). Returns a list of
        (w, wlo, whi) triples aligned with ``trees``, or None if any tree is
        ineligible (caller falls back to per-query execution).
        """
        splits = []
        pair_cols = None
        for tree in trees:
            split = self._split_leaves(ph, agg_col, tree)
            if split is None:
                return None
            same_col, pair_leaves = split
            cols = tuple(lf.col for lf in pair_leaves)   # already sorted
            if len(set(cols)) != len(cols):
                return None  # duplicate pair col: un-consolidated shape
            if pair_cols is None:
                pair_cols = cols
            elif cols != pair_cols:
                return None
            splits.append((same_col, pair_leaves))

        hist = ph.hists[agg_col]
        h = np.asarray(hist.h, np.float64)
        nq = len(splits)

        if pair_cols:
            hpad, fpad, hxpad, k1c, k2max = self._get_stack(
                ph, agg_col, pair_cols)
            betas = self._pair_betas_batch(
                ph, agg_col, [pls for _, pls in splits], k2max)  # (B,3,L,K2)
            flat = betas.reshape(nq * 3, len(pair_cols), k2max)
            prob1 = np.asarray(batched_weightings(
                hpad, flat, fpad, hxpad,
                use_pallas=self.use_pallas))[:, :k1c]
            prob1 = prob1.reshape(nq, 3, k1c)               # (B, 3, K1)
        else:
            prob1 = np.ones((nq, 3, int(hist.k)))

        out = []
        for qi, (same_col, _) in enumerate(splits):
            triple = []
            for idx in range(3):
                w = h * np.asarray(prob1[qi, idx], np.float64)
                for prob in same_col[idx]:
                    w = w * prob
                triple.append(w)
            out.append(_widen_clip(*triple, ph, h, corrected))
        return out


def make_fastpath(use_pallas: bool = True) -> FastPath:
    """Returns the engine hook (kept for back-compat; now a FastPath)."""
    return FastPath(use_pallas=use_pallas)
