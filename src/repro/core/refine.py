"""Level-synchronous histogram refinement (TPU adaptation of Alg. 1 + 2).

The paper's ``RefineBin1D``/``RefineBin2D`` are data-dependent recursions. On
TPU we refine *every* bin of a histogram simultaneously per round inside a
``lax.while_loop`` over fixed-capacity, +inf-padded edge buffers:

  round:  (1) vectorized per-bin statistics (count, unique count, chi-squared
              over Terrell–Scott sub-bins) via a single ``searchsorted`` batch;
          (2) every bin failing the uniformity test inserts its midpoint;
          (3) edges <- sort(concat(edges, midpoints))[:capacity].

Because the paper splits at the *bin midpoint* (equal-width, §4.1), split
decisions in 1-D are independent across bins, so this BFS produces **exactly**
the same final bin set as the paper's depth-first recursion (verified against
a sequential NumPy oracle in tests). In 2-D, refinement order can matter
(row/column splits interact); the BFS is the deterministic, order-independent
variant of the same procedure.

All functions are jit-compatible with static capacities; 1-D refinement is
vmapped across columns. The 2-D path is *pair-batched*: pairs stack into
(P, N) tensors and one round refines every pair level-synchronously
(``_round_2d_batch``), with the per-round cell counts dispatching through
the batched hist2d kernel (``repro.kernels.hist2d.batched_hist2d``) and the
chi-squared sub-bin counts through the batched sub-bin kernel
(``repro.kernels.subbin`` via ``chi2.subbin_counts``) — Pallas one-hot
matmuls on TPU, dtype-preserving scatter/segment-sum oracles elsewhere.
Two schedulers drive that round:

  * ``refine_2d_batch`` — fixed chunk: ONE ``lax.while_loop`` runs until
    the slowest pair converges (converged pairs are at a fixed point —
    recomputing them yields no new splits);
  * ``refine_2d_compact`` — convergence-compacting: a fixed set of slots
    refines an arbitrarily long pending queue, draining each pair the
    round it converges and backfilling its slot, so deep-refining pairs
    never stall shallow ones (full occupancy until the queue runs dry).

Each pair is presorted once by (x, y) and (y, x) (``presort_pairs``), which
turns the former per-round ``lexsort`` in ``_slice_unique`` into cheap
run-boundary flag sums — counts are exact integers, so both batched
schedulers are bit-for-bit equal to the legacy per-pair ``refine_2d`` loop
(asserted in tests). ``refine_2d``/``pair_metadata`` remain as the
single-pair reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import chi2 as chi2lib
from repro.kernels.hist2d import batched_hist2d

_INF = jnp.inf


# ---------------------------------------------------------------------------
# Shared vectorized bin statistics (1-D)
# ---------------------------------------------------------------------------


def bin_stats_1d(xs, uprefix, edges, k):
    """Per-bin (count, unique, vmin, vmax, lo_idx, hi_idx) from sorted data.

    xs:      (N,) sorted ascending; invalid entries (+inf) sorted last.
    uprefix: (N+1,) uprefix[n] = number of distinct values among xs[:n].
    edges:   (K+1,) sorted, +inf padded.
    k:       () number of valid bins.
    """
    K = edges.shape[0] - 1
    n = xs.shape[0]
    t = jnp.arange(K)
    left = jnp.searchsorted(xs, edges, side="left")      # (K+1,)
    right = jnp.searchsorted(xs, edges, side="right")    # (K+1,)
    lo = left[:-1]
    # Standard histogram convention: all bins half-open, last valid bin closed.
    hi = jnp.where(t == k - 1, right[1:], left[1:])
    valid = t < k
    lo = jnp.where(valid, lo, n)
    hi = jnp.where(valid, jnp.maximum(hi, lo), lo)
    h = (hi - lo).astype(jnp.float64)
    u = (uprefix[hi] - uprefix[lo]).astype(jnp.float64)
    vmin = xs[jnp.clip(lo, 0, n - 1)]
    vmax = xs[jnp.clip(hi - 1, 0, n - 1)]
    # Empty bins keep their edges as extrema (RefineBin1D line 4).
    eL, eR = edges[:-1], edges[1:]
    empty = h == 0
    vmin = jnp.where(empty, eL, vmin)
    vmax = jnp.where(empty, eR, vmax)
    return h, u, vmin, vmax, lo, hi


def chi2_stat_1d(xs, edges, k, h, u, lo, hi, s_max: int, crit_table):
    """Vectorized IsUniform over all bins: returns (chi2, crit, s).

    Sub-bin boundary positions come from one batched searchsorted of the
    (K, s_max-1) sub-edge matrix into the sorted column.
    """
    K = edges.shape[0] - 1
    n = xs.shape[0]
    eL, eR = edges[:-1], edges[1:]
    s = chi2lib.num_subbins(u, s_max)                           # (K,) i32
    r = jnp.arange(1, s_max)                                    # (s_max-1,)
    frac = r[None, :] / jnp.maximum(s[:, None], 1)              # (K, s_max-1)
    width = jnp.where(jnp.isfinite(eR - eL), eR - eL, 0.0)
    sub_edges = eL[:, None] + width[:, None] * frac
    pos = jnp.searchsorted(xs, sub_edges.reshape(-1), side="left")
    pos = pos.reshape(K, s_max - 1)
    in_range = r[None, :] < s[:, None]
    pos = jnp.where(in_range, pos, hi[:, None])
    pos = jnp.clip(pos, lo[:, None], hi[:, None])
    bounds = jnp.concatenate([lo[:, None], pos, hi[:, None]], axis=1)
    hbar = jnp.diff(bounds, axis=1).astype(jnp.float64)         # (K, s_max)
    expect = h / jnp.maximum(s.astype(jnp.float64), 1.0)
    rr = jnp.arange(s_max)
    live = rr[None, :] < s[:, None]
    num = jnp.where(live, (hbar - expect[:, None]) ** 2, 0.0)
    stat = jnp.sum(num, axis=1) / jnp.maximum(expect, 1e-30)
    crit = crit_table[jnp.clip(s, 0, crit_table.shape[0] - 1)]
    return stat, crit, s


# ---------------------------------------------------------------------------
# 1-D refinement
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("s_max", "max_rounds"))
def refine_1d(xs, uprefix, init_edges, n_init, min_points, crit_table,
              s_max: int = 128, max_rounds: int = 64):
    """Refine one column's histogram. Returns (edges, k).

    xs:         (N,) sorted values, invalid rows = +inf at the end.
    init_edges: (K+1,) initial edges (+inf padded), K = capacity.
    n_init:     () number of valid initial bins.
    min_points: M.
    """
    K = init_edges.shape[0] - 1

    def cond(state):
        _, _, n_split, rounds = state
        return (n_split > 0) & (rounds < max_rounds)

    def body(state):
        edges, k, _, rounds = state
        h, u, _, _, lo, hi = bin_stats_1d(xs, uprefix, edges, k)
        stat, crit, _ = chi2_stat_1d(xs, edges, k, h, u, lo, hi, s_max, crit_table)
        t = jnp.arange(K)
        eL, eR = edges[:-1], edges[1:]
        z = 0.5 * (eL + eR)
        splittable = (z > eL) & (z < eR) & jnp.isfinite(z)
        split = (
            (t < k)
            & (h >= min_points)      # "fewer than M tuples" -> no split
            & (u > 1.0)              # single unique value -> no split
            & (stat > crit)          # IsUniform -> no split
            & splittable
        )
        # Capacity guard: keep at most (K - k) new edges (first-come by index).
        avail = K - k
        rank = jnp.cumsum(split.astype(jnp.int32)) - 1
        split = split & (rank < avail)
        n_split = jnp.sum(split, dtype=jnp.int32)
        new = jnp.where(split, z, _INF)
        edges = jnp.sort(jnp.concatenate([edges, new]))[: K + 1]
        return edges, (k + n_split).astype(jnp.int32), n_split, rounds + 1

    state = (init_edges, n_init.astype(jnp.int32), jnp.int32(1), jnp.int32(0))
    edges, k, _, _ = jax.lax.while_loop(cond, body, state)
    return edges, k


@functools.partial(jax.jit, static_argnames=("s_max",))
def metadata_1d(xs, uprefix, edges, k, min_points, crit_table, mu,
                s_max: int = 128):
    """Final per-bin metadata for a refined 1-D histogram.

    Returns (h, u, vmin, vmax, c, cminus, cplus) — Eq. 10 for the centre
    bounds, midpoint c = (v+ + v-)/2.
    """
    h, u, vmin, vmax, _, _ = bin_stats_1d(xs, uprefix, edges, k)
    c = 0.5 * (vmin + vmax)
    cminus, cplus = centre_bounds(h, u, vmin, vmax, min_points, crit_table, mu,
                                  s_max=s_max)
    return h, u, vmin, vmax, c, cminus, cplus


def centre_bounds(h, u, vmin, vmax, min_points, crit_table, mu, s_max: int):
    """Weighted-centre bounds (Theorem 1 / Eq. 10).

    Non-passing bins (h < M): c± = v± ∓ (u-1)u·mu / (2h).
    Passing bins:            c± = v- + (s±1)δ/2 ± (δ/6)·sqrt(3·chi2_a·(s²-1)/h).
    """
    s = chi2lib.num_subbins(u, s_max).astype(jnp.float64)
    delta = (vmax - vmin) / jnp.maximum(s, 1.0)
    crit = crit_table[jnp.clip(s.astype(jnp.int32), 0, crit_table.shape[0] - 1)]
    crit = jnp.where(jnp.isfinite(crit), crit, 0.0)  # s<2 => degenerate bin
    hsafe = jnp.maximum(h, 1.0)

    spread = (delta / 6.0) * jnp.sqrt(3.0 * crit * (s**2 - 1.0) / hsafe)
    c_lo_pass = vmin + (s - 1.0) * delta / 2.0 - spread
    c_hi_pass = vmin + (s + 1.0) * delta / 2.0 + spread

    shift = (u - 1.0) * u * mu / (2.0 * hsafe)
    c_lo_fail = vmin + shift
    c_hi_fail = vmax - shift

    fail = h < min_points
    cminus = jnp.where(fail, c_lo_fail, c_lo_pass)
    cplus = jnp.where(fail, c_hi_fail, c_hi_pass)

    mid = 0.5 * (vmin + vmax)
    degenerate = u <= 1.0
    cminus = jnp.where(degenerate, mid, cminus)
    cplus = jnp.where(degenerate, mid, cplus)
    cminus = jnp.clip(cminus, vmin, vmax)
    cplus = jnp.clip(cplus, cminus, vmax)
    return cminus, cplus


# ---------------------------------------------------------------------------
# 2-D refinement
# ---------------------------------------------------------------------------


def _bin_index(vals, edges, k):
    """Bin index per point under the half-open-except-last convention."""
    idx = jnp.searchsorted(edges, vals, side="right") - 1
    return jnp.clip(idx, 0, jnp.maximum(k - 1, 0))


def _slice_unique(sort_primary, sort_value, valid, num_segments):
    """Unique-value counts per segment via lexsort + first-occurrence flags."""
    order = jnp.lexsort((sort_value, sort_primary))
    seg = sort_primary[order]
    val = sort_value[order]
    ok = valid[order]
    new_seg = jnp.concatenate([jnp.array([True]), seg[1:] != seg[:-1]])
    new_val = jnp.concatenate([jnp.array([True]), val[1:] != val[:-1]])
    first = (new_seg | new_val) & ok
    return jax.ops.segment_sum(first.astype(jnp.float64), seg,
                               num_segments=num_segments)


def _cell_chi2(vals, lo, width, cell, h_cell, u_cell, valid, k2: int,
               s_max: int, crit_table):
    """Per-cell chi-squared uniformity statistic along one dimension.

    vals/lo/width: per-point value + its cell's interval in this dimension.
    cell:          per-point flattened cell id in [0, k2*k2).
    h_cell/u_cell: per-cell totals (k2*k2,).
    """
    ncell = k2 * k2
    s = chi2lib.num_subbins(u_cell, s_max)                       # (ncell,)
    s_pt = s[cell]
    frac = jnp.where(width > 0, (vals - lo) / width, 0.0)
    r = jnp.clip((frac * s_pt).astype(jnp.int32), 0, s_pt - 1)
    flat = jnp.where(valid, cell * s_max + r, ncell * s_max)
    hbar = jax.ops.segment_sum(jnp.ones_like(vals), flat,
                               num_segments=ncell * s_max + 1)[:-1]
    hbar = hbar.reshape(ncell, s_max)
    sf = jnp.maximum(s.astype(jnp.float64), 1.0)
    expect = h_cell / sf
    rr = jnp.arange(s_max)
    live = rr[None, :] < s[:, None]
    num = jnp.where(live, (hbar - expect[:, None]) ** 2, 0.0)
    stat = jnp.sum(num, axis=1) / jnp.maximum(expect, 1e-30)
    crit = crit_table[jnp.clip(s, 0, crit_table.shape[0] - 1)]
    return stat, crit


@functools.partial(jax.jit, static_argnames=("k2", "s_max", "max_rounds"))
def refine_2d(x, y, valid, ex0, ey0, kx0, ky0, min_points, crit_table,
              k2: int, s_max: int = 32, max_rounds: int = 16):
    """Refine a pair histogram. Returns (ex, ey, kx, ky).

    x, y:   (N,) point coordinates (pre-processed domain); `valid` masks rows
            where either column is null.
    ex0/ey0: (K2+1,) initial edges = the columns' final 1-D edges (padded).
    """
    ncell = k2 * k2

    def cond(state):
        _, _, _, _, n_split, rounds = state
        return (n_split > 0) & (rounds < max_rounds)

    def body(state):
        ex, ey, kx, ky, _, rounds = state
        bi = _bin_index(x, ex, kx)
        bj = _bin_index(y, ey, ky)
        cell = bi * k2 + bj
        cell_m = jnp.where(valid, cell, ncell)
        ones = jnp.where(valid, 1.0, 0.0)
        h_cell = jax.ops.segment_sum(ones, cell_m, num_segments=ncell + 1)[:-1]

        ux_cell = _slice_unique(cell_m, x, valid, ncell + 1)[:-1]
        uy_cell = _slice_unique(cell_m, y, valid, ncell + 1)[:-1]

        lox, wx = ex[bi], ex[bi + 1] - ex[bi]
        loy, wy = ey[bj], ey[bj + 1] - ey[bj]
        stat_x, crit_x = _cell_chi2(x, lox, wx, cell, h_cell, ux_cell, valid,
                                    k2, s_max, crit_table)
        stat_y, crit_y = _cell_chi2(y, loy, wy, cell, h_cell, uy_cell, valid,
                                    k2, s_max, crit_table)

        eligible = h_cell > min_points                      # Alg. 1 line 17
        fail_x = eligible & (ux_cell > 1.0) & (stat_x > crit_x)
        fail_y = eligible & (uy_cell > 1.0) & (stat_y > crit_y)
        # "split applied to the least uniform column": larger excess ratio.
        exc_x = jnp.where(fail_x, stat_x / jnp.maximum(crit_x, 1e-30), -1.0)
        exc_y = jnp.where(fail_y, stat_y / jnp.maximum(crit_y, 1e-30), -1.0)
        pick_x = fail_x & (~fail_y | (exc_x >= exc_y))
        pick_y = fail_y & ~pick_x

        # A split in cell (ti, tj) along x inserts the midpoint of row ti's
        # interval — applying to the whole row (Fig. 5). Reduce cell->row.
        ti = jnp.arange(ncell) // k2
        tj = jnp.arange(ncell) % k2
        want_x = jax.ops.segment_max(pick_x.astype(jnp.int32), ti,
                                     num_segments=k2).astype(bool)
        want_y = jax.ops.segment_max(pick_y.astype(jnp.int32), tj,
                                     num_segments=k2).astype(bool)

        tK = jnp.arange(k2)
        zx = 0.5 * (ex[:-1] + ex[1:])
        zy = 0.5 * (ey[:-1] + ey[1:])
        ok_x = want_x & (tK < kx) & (zx > ex[:-1]) & (zx < ex[1:])
        ok_y = want_y & (tK < ky) & (zy > ey[:-1]) & (zy < ey[1:])
        rank_x = jnp.cumsum(ok_x.astype(jnp.int32)) - 1
        rank_y = jnp.cumsum(ok_y.astype(jnp.int32)) - 1
        ok_x = ok_x & (rank_x < (k2 - kx))
        ok_y = ok_y & (rank_y < (k2 - ky))
        nx = jnp.sum(ok_x, dtype=jnp.int32)
        ny = jnp.sum(ok_y, dtype=jnp.int32)

        ex = jnp.sort(jnp.concatenate([ex, jnp.where(ok_x, zx, _INF)]))[: k2 + 1]
        ey = jnp.sort(jnp.concatenate([ey, jnp.where(ok_y, zy, _INF)]))[: k2 + 1]
        return (ex, ey, (kx + nx).astype(jnp.int32), (ky + ny).astype(jnp.int32),
                (nx + ny).astype(jnp.int32), rounds + 1)

    state = (ex0, ey0, kx0.astype(jnp.int32), ky0.astype(jnp.int32),
             jnp.int32(1), jnp.int32(0))
    ex, ey, kx, ky, _, _ = jax.lax.while_loop(cond, body, state)
    return ex, ey, kx, ky


@functools.partial(jax.jit, static_argnames=("k2",))
def pair_metadata(x, y, valid, ex, ey, kx, ky, k2: int):
    """Final pair-histogram metadata (counts + per-dim slice aggregates).

    Fold maps (1-D union bin -> pair row) are computed host-side in
    repro.core.build.fold_to_rows after the 1-D grids are union-refined.
    """
    ncell = k2 * k2
    bi = _bin_index(x, ex, kx)
    bj = _bin_index(y, ey, ky)
    cell = jnp.where(valid, bi * k2 + bj, ncell)
    ones = jnp.where(valid, 1.0, 0.0)
    H = jax.ops.segment_sum(ones, cell, num_segments=ncell + 1)[:-1]
    H = H.reshape(k2, k2)

    big = jnp.float64(jnp.finfo(jnp.float64).max)
    row = jnp.where(valid, bi, k2)
    col = jnp.where(valid, bj, k2)

    def slice_meta(seg, vals, edges, k):
        hh = jax.ops.segment_sum(ones, seg, num_segments=k2 + 1)[:-1]
        vmin = jax.ops.segment_min(jnp.where(valid, vals, big), seg,
                                   num_segments=k2 + 1)[:-1]
        vmax = jax.ops.segment_max(jnp.where(valid, vals, -big), seg,
                                   num_segments=k2 + 1)[:-1]
        uu = _slice_unique(seg, vals, valid, k2 + 1)[:-1]
        empty = hh == 0
        vmin = jnp.where(empty, edges[:-1], vmin)
        vmax = jnp.where(empty, edges[1:], vmax)
        return hh, uu, vmin, vmax

    hx, ux, vminx, vmaxx = slice_meta(row, x, ex, kx)
    hy, uy, vminy, vmaxy = slice_meta(col, y, ey, ky)
    return H, hx, ux, vminx, vmaxx, hy, uy, vminy, vmaxy


# ---------------------------------------------------------------------------
# Pair-batched 2-D refinement (all pairs of a chunk in one while_loop)
# ---------------------------------------------------------------------------


@jax.jit
def presort_pairs(x, y, valid):
    """Per-pair lexsorts, done once per chunk (not per refinement round).

    x/y/valid: (P, N). Invalid rows sort to the tail (+inf keys). Returns
    the points of every pair in (x, y) order and in (y, x) order plus
    run-start flags:

      xo1/yo1/vo1/new1: values, validity and x-run starts in (x, y) order;
      xo2/yo2/vo2/new2: values, validity and y-run starts in (y, x) order.

    Within an x-run (equal x => equal x-bin in any grid), points are sorted
    by y, so equal y-bins are contiguous — a point starts a new (x-value,
    y-bin) group iff it starts a run or its y-bin differs from its
    predecessor. Summing those flags per cell gives the exact distinct-x
    count per cell with no per-round sort (ditto distinct-y via order 2).

    ``build.build_pairs_batched`` computes the same arrays host-side with
    ``np.lexsort`` (numpy's sort is much faster than XLA:CPU's); this jitted
    version serves device-resident callers and tests.
    """
    key_x = jnp.where(valid, x, _INF)
    key_y = jnp.where(valid, y, _INF)

    def one(kx, ky):
        return jnp.lexsort((ky, kx)), jnp.lexsort((kx, ky))

    o1, o2 = jax.vmap(one)(key_x, key_y)

    def take(a, o):
        return jnp.take_along_axis(a, o, axis=1)

    xo1, yo1, vo1 = take(x, o1), take(y, o1), take(valid, o1)
    xo2, yo2, vo2 = take(x, o2), take(y, o2), take(valid, o2)
    first = jnp.ones((x.shape[0], 1), bool)
    new1 = jnp.concatenate([first, xo1[:, 1:] != xo1[:, :-1]], axis=1)
    new2 = jnp.concatenate([first, yo2[:, 1:] != yo2[:, :-1]], axis=1)
    return xo1, yo1, vo1, new1, xo2, yo2, vo2, new2


def _bin_index_b(vals, edges, k):
    """(P, N) values x (P, K+1) edges -> per-point bin indices, per pair."""
    idx = jax.vmap(
        lambda v, e: jnp.searchsorted(e, v, side="right"))(vals, edges) - 1
    return jnp.clip(idx, 0, jnp.maximum(k[:, None] - 1, 0))


def _unique_flags(new_run, other_bin, valid):
    """First-occurrence flags of each (run, other-dim bin) group (f64)."""
    prev = jnp.concatenate([other_bin[:, :1], other_bin[:, :-1]], axis=1)
    return ((new_run | (other_bin != prev)) & valid).astype(jnp.float64)


def _chi2_from_hbar_b(hbar, h_cell, s, s_max: int, crit_table):
    """Batched tail of ``_cell_chi2``: identical float ops on (P, ncell)."""
    sf = jnp.maximum(s.astype(jnp.float64), 1.0)
    expect = h_cell / sf
    rr = jnp.arange(s_max)
    live = rr[None, None, :] < s[:, :, None]
    num = jnp.where(live, (hbar - expect[:, :, None]) ** 2, 0.0)
    stat = jnp.sum(num, axis=2) / jnp.maximum(expect, 1e-30)
    crit = crit_table[jnp.clip(s, 0, crit_table.shape[0] - 1)]
    return stat, crit


def _round_2d_batch(xo1, yo1, vo1, new1, xo2, yo2, vo2, new2,
                    ex, ey, kx, ky, min_points, crit_table, *,
                    k2: int, s_max: int, use_pallas: bool,
                    interpret: bool | None):
    """ONE level-synchronous refinement round over P pairs.

    The shared inner step of ``refine_2d_batch`` (fixed chunk) and
    ``refine_2d_compact`` (drain/backfill active set): per-cell statistics
    via the batched hist2d + sub-bin kernels, split selection, capacity
    guard, edge insertion. Returns (ex, ey, kx, ky, n_split, capped_round)
    with per-pair split and guard-bound flags for this round. Exactly the
    ops of the legacy per-pair ``refine_2d`` body on each pair's lane, so
    any scheduler built on it stays bit-for-bit equal to the sequential
    path.
    """
    p = xo1.shape[0]
    ncell = k2 * k2
    bio1 = _bin_index_b(xo1, ex, kx)
    bjo1 = _bin_index_b(yo1, ey, ky)
    bio2 = _bin_index_b(xo2, ex, kx)
    bjo2 = _bin_index_b(yo2, ey, ky)
    cell1 = bio1 * k2 + bjo1
    cell2 = bio2 * k2 + bjo2

    ux_cell = batched_hist2d(
        bio1, bjo1, _unique_flags(new1, bjo1, vo1), k2, k2,
        use_pallas=use_pallas, interpret=interpret).reshape(p, ncell)
    uy_cell = batched_hist2d(
        bio2, bjo2, _unique_flags(new2, bio2, vo2), k2, k2,
        use_pallas=use_pallas, interpret=interpret).reshape(p, ncell)
    s_x = chi2lib.num_subbins(ux_cell, s_max)
    s_y = chi2lib.num_subbins(uy_cell, s_max)

    lox = jnp.take_along_axis(ex, bio1, axis=1)
    wx = jnp.take_along_axis(ex, bio1 + 1, axis=1) - lox
    loy = jnp.take_along_axis(ey, bjo2, axis=1)
    wy = jnp.take_along_axis(ey, bjo2 + 1, axis=1) - loy
    hbar_x = chi2lib.subbin_counts(xo1, lox, wx, cell1, s_x, vo1,
                                   ncell=ncell, s_max=s_max,
                                   use_pallas=use_pallas, interpret=interpret)
    hbar_y = chi2lib.subbin_counts(yo2, loy, wy, cell2, s_y, vo2,
                                   ncell=ncell, s_max=s_max,
                                   use_pallas=use_pallas, interpret=interpret)
    h_cell = jnp.sum(hbar_x, axis=2)
    stat_x, crit_x = _chi2_from_hbar_b(hbar_x, h_cell, s_x, s_max, crit_table)
    stat_y, crit_y = _chi2_from_hbar_b(hbar_y, h_cell, s_y, s_max, crit_table)

    eligible = h_cell > min_points
    fail_x = eligible & (ux_cell > 1.0) & (stat_x > crit_x)
    fail_y = eligible & (uy_cell > 1.0) & (stat_y > crit_y)
    exc_x = jnp.where(fail_x, stat_x / jnp.maximum(crit_x, 1e-30), -1.0)
    exc_y = jnp.where(fail_y, stat_y / jnp.maximum(crit_y, 1e-30), -1.0)
    pick_x = fail_x & (~fail_y | (exc_x >= exc_y))
    pick_y = fail_y & ~pick_x

    # cell (ti, tj) -> whole row/column wants a split (Fig. 5).
    want_x = pick_x.reshape(p, k2, k2).any(axis=2)
    want_y = pick_y.reshape(p, k2, k2).any(axis=1)

    tK = jnp.arange(k2)[None, :]
    zx = 0.5 * (ex[:, :-1] + ex[:, 1:])
    zy = 0.5 * (ey[:, :-1] + ey[:, 1:])
    ok_x = want_x & (tK < kx[:, None]) & (zx > ex[:, :-1]) & (zx < ex[:, 1:])
    ok_y = want_y & (tK < ky[:, None]) & (zy > ey[:, :-1]) & (zy < ey[:, 1:])
    nwx = jnp.sum(ok_x, axis=1, dtype=jnp.int32)   # wanted, pre-guard
    nwy = jnp.sum(ok_y, axis=1, dtype=jnp.int32)
    capped_round = (nwx > k2 - kx) | (nwy > k2 - ky)
    rank_x = jnp.cumsum(ok_x.astype(jnp.int32), axis=1) - 1
    rank_y = jnp.cumsum(ok_y.astype(jnp.int32), axis=1) - 1
    ok_x = ok_x & (rank_x < (k2 - kx)[:, None])
    ok_y = ok_y & (rank_y < (k2 - ky)[:, None])
    nx = jnp.sum(ok_x, axis=1, dtype=jnp.int32)
    ny = jnp.sum(ok_y, axis=1, dtype=jnp.int32)

    ex = jnp.sort(jnp.concatenate(
        [ex, jnp.where(ok_x, zx, _INF)], axis=1), axis=1)[:, : k2 + 1]
    ey = jnp.sort(jnp.concatenate(
        [ey, jnp.where(ok_y, zy, _INF)], axis=1), axis=1)[:, : k2 + 1]
    return (ex, ey, (kx + nx).astype(jnp.int32), (ky + ny).astype(jnp.int32),
            (nx + ny).astype(jnp.int32), capped_round)


@functools.partial(jax.jit, static_argnames=("k2", "s_max", "max_rounds",
                                             "use_pallas", "interpret"))
def refine_2d_batch(xo1, yo1, vo1, new1, xo2, yo2, vo2, new2,
                    ex0, ey0, kx0, ky0, min_points, crit_table, *,
                    k2: int, s_max: int = 32, max_rounds: int = 16,
                    use_pallas: bool = False, interpret: bool | None = None):
    """Refine P pair histograms in one level-synchronous while_loop.

    Inputs are ``presort_pairs`` outputs plus per-pair initial edges
    ``ex0``/``ey0`` (P, K2+1) and valid-bin counts ``kx0``/``ky0`` (P,).
    Returns (ex, ey, kx, ky, capped) with leading pair axis.

    Per-pair results are bit-for-bit identical to running ``refine_2d`` on
    each pair alone: a pair that stops splitting is at a deterministic fixed
    point, so the extra rounds it sits through while slower pairs converge
    are no-ops, and every per-cell statistic is an exact integer count or a
    float computed by the same ops on the same values.

    ``capped[p]`` is True iff pair p's K2-capacity guard ever dropped a
    wanted split. When False, the result is independent of ``k2`` (any
    capacity >= the final bin counts yields the same histogram), which is
    what lets construction refine at a small capacity first and escalate
    only saturated chunks (``build.build_pairs_batched``).
    """
    p = xo1.shape[0]

    def cond(state):
        _, _, _, _, n_split, _, rounds = state
        return jnp.any(n_split > 0) & (rounds < max_rounds)

    def body(state):
        ex, ey, kx, ky, _, capped, rounds = state
        ex, ey, kx, ky, n_split, capped_r = _round_2d_batch(
            xo1, yo1, vo1, new1, xo2, yo2, vo2, new2, ex, ey, kx, ky,
            min_points, crit_table, k2=k2, s_max=s_max,
            use_pallas=use_pallas, interpret=interpret)
        return ex, ey, kx, ky, n_split, capped | capped_r, rounds + 1

    state = (ex0, ey0, kx0.astype(jnp.int32), ky0.astype(jnp.int32),
             jnp.ones(p, jnp.int32), jnp.zeros(p, bool), jnp.int32(0))
    ex, ey, kx, ky, _, capped, _ = jax.lax.while_loop(cond, body, state)
    return ex, ey, kx, ky, capped


@functools.partial(jax.jit, static_argnames=("n_slots", "k2", "s_max",
                                             "max_rounds", "drain_capped",
                                             "use_pallas", "interpret"))
def refine_2d_compact(xo1, yo1, vo1, new1, xo2, yo2, vo2, new2,
                      ex0, ey0, kx0, ky0, rounds0, capped0, n_pending,
                      min_points, crit_table, occupancy_min, *,
                      n_slots: int, k2: int, s_max: int = 32,
                      max_rounds: int = 16, drain_capped: bool = False,
                      use_pallas: bool = False,
                      interpret: bool | None = None):
    """Convergence-compacting refinement: an S-slot active set over P pairs.

    The fixed-chunk ``refine_2d_batch`` runs until the *slowest* pair of
    its chunk converges — deep-refining (correlated) pairs lockstep-drag
    shallow ones. Here ``n_slots`` device-side slots refine one round per
    loop iteration; every iteration, slots whose pair converged this round
    (no splits, or ``max_rounds`` reached, or — when ``drain_capped`` —
    the capacity guard bound) **drain** into per-pair output buffers and
    **backfill** from the pending queue ``[next_ptr, n_pending)``, so the
    active set stays at full occupancy until the queue runs dry.

    Inputs are the presorted arrays of ALL P pending pairs plus per-pair
    start states (``ex0``/``ey0``/``kx0``/``ky0``/``rounds0``/``capped0``
    — fresh pairs have rounds 0, resumed pairs their partial state).
    ``n_pending`` (traced) is the real pair count; lanes beyond it are
    padding and are never fed. Because each pair's round trajectory is the
    deterministic ``_round_2d_batch`` fixed-point iteration, independent
    of slot assignment and of its slot neighbours, the drained results are
    **schedule-independent**: bit-for-bit equal to ``refine_2d`` on each
    pair alone, whatever the slot count, queue order or drain timing
    (asserted in tests/test_build_compact.py).

    ``drain_capped`` (static) drains a pair the moment its guard binds —
    used on non-final capacity rungs, where a capped result is discarded
    and the pair re-queued one rung up, so keeping it refining would only
    burn its slot. On the final rung it must be False (the capped result
    is the real, fully-refined K2-capped histogram).

    ``occupancy_min`` (traced, 0 disables): once the queue is empty and
    fewer than ``ceil(occupancy_min * n_slots)`` slots remain active, the
    loop exits early — after at least one round, so every launch makes
    progress — and returns the unconverged slots' partial states for the
    caller to re-bucket into a smaller launch (``build.build_pairs_compact``).

    Returns ``(out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds,
    out_done, slot_pair, slot_active, sex, sey, skx, sky, scapped, srounds,
    occ_hist, loop_rounds, active_rounds)`` — per-pair outputs (valid where
    ``out_done``), the live slot state for resumption, and occupancy
    telemetry (``active_rounds`` counts pair-rounds actually refined;
    ``loop_rounds * n_slots`` is the slot-rounds paid; ``occ_hist`` is an
    ``(n_slots + 1,)`` histogram of how many loop rounds ran with each
    possible active-slot count — the per-round occupancy distribution at
    fixed memory, feeding the build timeline).
    """
    P = xo1.shape[0]
    S = n_slots
    thr = jnp.ceil(occupancy_min * S).astype(jnp.int32)

    def fill(dst_mask, src_idx, cur):
        """Load per-pair start state into slots where ``dst_mask``."""
        idx = jnp.clip(src_idx, 0, P - 1)
        out = []
        for arr, val in cur:
            picked = arr[idx]
            m = dst_mask[:, None] if picked.ndim == 2 else dst_mask
            out.append(jnp.where(m, picked, val))
        return out

    slot_pair = jnp.minimum(jnp.arange(S, dtype=jnp.int32),
                            jnp.maximum(n_pending - 1, 0).astype(jnp.int32))
    active = jnp.arange(S) < n_pending
    sex = ex0[slot_pair]
    sey = ey0[slot_pair]
    skx = kx0[slot_pair].astype(jnp.int32)
    sky = ky0[slot_pair].astype(jnp.int32)
    scap = capped0[slot_pair]
    srnd = rounds0[slot_pair].astype(jnp.int32)
    out_ex = jnp.zeros_like(ex0)
    out_ey = jnp.zeros_like(ey0)
    out_kx = jnp.zeros(P, jnp.int32)
    out_ky = jnp.zeros(P, jnp.int32)
    out_capped = jnp.zeros(P, bool)
    out_rounds = jnp.zeros(P, jnp.int32)
    out_done = jnp.zeros(P, bool)
    state = (slot_pair, active, sex, sey, skx, sky, scap, srnd,
             jnp.minimum(jnp.int32(S), n_pending.astype(jnp.int32)),
             out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds,
             out_done, jnp.zeros(S + 1, jnp.int32), jnp.int32(0),
             jnp.int32(0))

    def cond(st):
        (_, active, _, _, _, _, _, _, next_ptr,
         _, _, _, _, _, _, _, _, loop_rounds, _) = st
        n_act = jnp.sum(active, dtype=jnp.int32)
        exhausted = next_ptr >= n_pending
        return jnp.any(active) & ((loop_rounds == 0)
                                  | ~(exhausted & (n_act < thr)))

    def body(st):
        (slot_pair, active, sex, sey, skx, sky, scap, srnd, next_ptr,
         out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds,
         out_done, occ_hist, loop_rounds, active_rounds) = st
        nex, ney, nkx, nky, n_split, cap_r = _round_2d_batch(
            xo1[slot_pair], yo1[slot_pair], vo1[slot_pair], new1[slot_pair],
            xo2[slot_pair], yo2[slot_pair], vo2[slot_pair], new2[slot_pair],
            sex, sey, skx, sky, min_points, crit_table, k2=k2, s_max=s_max,
            use_pallas=use_pallas, interpret=interpret)
        am = active
        sex = jnp.where(am[:, None], nex, sex)
        sey = jnp.where(am[:, None], ney, sey)
        skx = jnp.where(am, nkx, skx)
        sky = jnp.where(am, nky, sky)
        scap = scap | (cap_r & am)
        srnd = srnd + am.astype(jnp.int32)
        n_split = jnp.where(am, n_split, 0)

        conv = am & ((n_split == 0) | (srnd >= max_rounds))
        if drain_capped:
            conv = conv | (am & scap)

        # Drain: scatter converged slots into their pair's output lane
        # (index P for unconverged slots -> dropped).
        didx = jnp.where(conv, slot_pair, P)
        out_ex = out_ex.at[didx].set(sex, mode="drop")
        out_ey = out_ey.at[didx].set(sey, mode="drop")
        out_kx = out_kx.at[didx].set(skx, mode="drop")
        out_ky = out_ky.at[didx].set(sky, mode="drop")
        out_capped = out_capped.at[didx].set(scap, mode="drop")
        out_rounds = out_rounds.at[didx].set(srnd, mode="drop")
        out_done = out_done.at[didx].set(True, mode="drop")

        # Backfill: rank the drained slots and hand out pending pairs.
        offs = jnp.cumsum(conv.astype(jnp.int32)) - 1
        nidx = next_ptr + offs
        take = conv & (nidx < n_pending)
        slot_pair = jnp.where(take, nidx, slot_pair).astype(jnp.int32)
        active = jnp.where(conv, take, active)
        sex, sey, skx, sky, scap, srnd = fill(take, slot_pair, [
            (ex0, sex), (ey0, sey), (kx0.astype(jnp.int32), skx),
            (ky0.astype(jnp.int32), sky), (capped0, scap),
            (rounds0.astype(jnp.int32), srnd)])
        next_ptr = next_ptr + jnp.sum(take, dtype=jnp.int32)
        n_am = jnp.sum(am, dtype=jnp.int32)
        return (slot_pair, active, sex, sey, skx, sky, scap, srnd, next_ptr,
                out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds,
                out_done, occ_hist.at[n_am].add(1), loop_rounds + 1,
                active_rounds + n_am)

    (slot_pair, active, sex, sey, skx, sky, scap, srnd, _next_ptr,
     out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds, out_done,
     occ_hist, loop_rounds, active_rounds) = jax.lax.while_loop(
         cond, body, state)
    return (out_ex, out_ey, out_kx, out_ky, out_capped, out_rounds, out_done,
            slot_pair, active, sex, sey, skx, sky, scap, srnd,
            occ_hist, loop_rounds, active_rounds)


@functools.partial(jax.jit, static_argnames=("k2", "use_pallas", "interpret"))
def pair_metadata_batch(xo1, yo1, vo1, new1, xo2, yo2, vo2, new2,
                        ex, ey, kx, ky, *, k2: int,
                        use_pallas: bool = False,
                        interpret: bool | None = None):
    """Batched ``pair_metadata``: (P, ...) in, (P, ...) out, same values.

    The count matrix routes through the batched hist2d kernel; everything
    per-dimension comes from the presorted order *without scatters*: a
    row's points are a contiguous slice of the (x, y)-sorted array (bin
    index depends on x alone), so row extrema are the slice ends and
    distinct counts are prefix-sum differences of the run flags — exactly
    the values the legacy segment ops produce.
    """
    p, n = xo1.shape
    bio1 = _bin_index_b(xo1, ex, kx)
    bjo1 = _bin_index_b(yo1, ey, ky)
    ones1 = jnp.where(vo1, 1.0, 0.0)
    H = batched_hist2d(bio1, bjo1, ones1, k2, k2, use_pallas=use_pallas,
                       interpret=interpret)                    # (P, K2, K2)
    hx = H.sum(axis=2)
    hy = H.sum(axis=1)
    nv = jnp.sum(vo1, axis=1)                                  # (P,)

    def slice_meta(vals_sorted, valid_sorted, run_flags, edges, k):
        keyed = jnp.where(valid_sorted, vals_sorted, _INF)
        pos = jax.vmap(lambda kv, e: jnp.searchsorted(
            kv, e, side="left"))(keyed, edges)                 # (P, K2+1)
        t = jnp.arange(k2)[None, :]
        lo = pos[:, :-1]
        # Half-open bins except the last valid one (closed): its slice runs
        # to the end of the valid prefix.
        hi = jnp.where(t == k[:, None] - 1, nv[:, None], pos[:, 1:])
        hi = jnp.maximum(hi, lo)
        up = jnp.cumsum((run_flags & valid_sorted).astype(jnp.float64),
                        axis=1)
        up = jnp.concatenate([jnp.zeros((p, 1), jnp.float64), up], axis=1)
        uu = jnp.take_along_axis(up, hi, axis=1) - \
            jnp.take_along_axis(up, lo, axis=1)
        vmin = jnp.take_along_axis(vals_sorted,
                                   jnp.clip(lo, 0, n - 1), axis=1)
        vmax = jnp.take_along_axis(vals_sorted,
                                   jnp.clip(hi - 1, 0, n - 1), axis=1)
        return uu, vmin, vmax

    ux, vminx, vmaxx = slice_meta(xo1, vo1, new1, ex, kx)
    uy, vminy, vmaxy = slice_meta(yo2, vo2, new2, ey, ky)

    empty_x = hx == 0
    vminx = jnp.where(empty_x, ex[:, :-1], vminx)
    vmaxx = jnp.where(empty_x, ex[:, 1:], vmaxx)
    ux = jnp.where(empty_x, 0.0, ux)
    empty_y = hy == 0
    vminy = jnp.where(empty_y, ey[:, :-1], vminy)
    vmaxy = jnp.where(empty_y, ey[:, 1:], vmaxy)
    uy = jnp.where(empty_y, 0.0, uy)
    return H, hx, ux, vminx, vmaxx, hy, uy, vminy, vmaxy


@functools.partial(jax.jit, static_argnames=("k2", "s_max", "max_rounds",
                                             "use_pallas", "interpret"))
def build_pairs_device(xo1, yo1, vo1, new1, xo2, yo2, vo2, new2,
                       ex0, ey0, kx0, ky0, min_points,
                       crit_table, *, k2: int, s_max: int = 32,
                       max_rounds: int = 16, use_pallas: bool = False,
                       interpret: bool | None = None):
    """Batched refine + batched metadata as ONE compiled unit.

    Takes presorted chunk arrays (``presort_pairs`` layout — device- or
    host-produced). Everything for a chunk of P pairs runs in a single
    dispatch; the caller fetches all results in one grouped device->host
    transfer. Returns
    (ex, ey, kx, ky, capped, H, hx, ux, vminx, vmaxx, hy, uy, vminy, vmaxy).
    """
    pres = (xo1, yo1, vo1, new1, xo2, yo2, vo2, new2)
    ex, ey, kx, ky, capped = refine_2d_batch(
        *pres, ex0, ey0, kx0, ky0, min_points, crit_table, k2=k2,
        s_max=s_max, max_rounds=max_rounds, use_pallas=use_pallas,
        interpret=interpret)
    meta = pair_metadata_batch(*pres, ex, ey, kx, ky, k2=k2,
                               use_pallas=use_pallas, interpret=interpret)
    return (ex, ey, kx, ky, capped) + meta
