"""Chi-squared machinery for the uniformity hypothesis tests (§4.1).

The paper tests the null hypothesis "points are uniform within the bin" with a
chi-squared statistic over ``s = ceil((2u)^(1/3))`` sub-bins (Terrell–Scott,
Eq. 2–3) at significance ``alpha``.

Critical values chi2_alpha(df) are needed *inside* jitted refinement loops, so
we precompute a table indexed by ``s`` (df = s - 1). The quantile itself is
computed with a Wilson–Hilferty initial guess + bisection on the regularized
upper incomplete gamma (jax.scipy.special.gammaincc) — self-contained (no
scipy dependency at runtime; scipy is only used in tests as an oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.subbin import batched_subbin_hist


def chi2_sf(x, df):
    """Survival function of the chi-squared distribution: Pr(X > x)."""
    x = jnp.asarray(x, jnp.float64)
    df = jnp.asarray(df, jnp.float64)
    return jax.scipy.special.gammaincc(df / 2.0, x / 2.0)


def _wilson_hilferty(alpha, df):
    """Approximate upper quantile (starting point for bisection)."""
    # z_alpha via Acklam-lite rational approx is overkill; a crude normal
    # quantile suffices as a *bracket center* only.
    z = jnp.sqrt(2.0) * _erfinv(1.0 - 2.0 * alpha)
    term = 1.0 - 2.0 / (9.0 * df) + z * jnp.sqrt(2.0 / (9.0 * df))
    return df * term**3


def _erfinv(y):
    # jax provides erfinv directly.
    return jax.scipy.special.erfinv(y)


def chi2_isf(alpha: float, df, iters: int = 90):
    """Inverse survival function: x such that Pr(X > x) = alpha.

    Vectorized over ``df``. Bisection on [0, hi] where hi brackets the root.
    90 f64 bisection steps resolve to ~1 ulp of the bracket.
    """
    df = jnp.asarray(df, jnp.float64)
    alpha = jnp.float64(alpha)
    guess = _wilson_hilferty(alpha, jnp.maximum(df, 1.0))
    hi0 = jnp.maximum(4.0 * guess + 100.0, df + 200.0)
    lo0 = jnp.zeros_like(df)

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        # SF decreases in x: SF(mid) > alpha => root is to the right.
        go_right = chi2_sf(mid, df) > alpha
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    return 0.5 * (lo + hi)


# Largest crit table computed so far, per alpha. chi2_isf is element-wise
# over df (bisection per element, no cross-element coupling), so a longer
# table's prefix is bit-identical to a shorter table computed directly —
# which lets repeat callers (notably storage.decode, where the un-memoized
# fori_loop recompile used to dominate cold-start latency) slice instead of
# recompiling.
_CRIT_CACHE: dict = {}


def build_crit_table(alpha: float, s_max: int) -> np.ndarray:
    """Critical values indexed by the number of sub-bins ``s``.

    ``table[s] = chi2_isf(alpha, df=s-1)`` for s >= 2; entries for s < 2 are
    +inf (a bin with a single sub-bin can never fail the test — it also can
    never be split, matching RefineBin1D's u == 1 early-out).
    """
    if s_max < 2:
        raise ValueError("s_max must be >= 2")
    cached = _CRIT_CACHE.get(alpha)
    if cached is None or len(cached) < s_max + 1:
        s = np.arange(s_max + 1)
        table = np.full(s_max + 1, np.inf, dtype=np.float64)
        vals = np.asarray(chi2_isf(alpha, jnp.asarray(s[2:] - 1, jnp.float64)))
        table[2:] = vals
        table.setflags(write=False)
        _CRIT_CACHE[alpha] = cached = table
    return cached[:s_max + 1].copy()


def num_subbins(u, s_max: int):
    """Terrell–Scott sub-bin count (Eq. 2): s = ceil((2u)^(1/3)), clipped.

    Accepts float arrays (counts are carried as f64); guards u <= 0.
    """
    u = jnp.asarray(u, jnp.float64)
    s = jnp.ceil(jnp.cbrt(2.0 * jnp.maximum(u, 0.0)))
    return jnp.clip(s, 1.0, float(s_max)).astype(jnp.int32)


def subbin_counts(vals, lo, width, cell, s, valid, *, ncell: int, s_max: int,
                  use_pallas: bool = False, interpret: bool | None = None):
    """Kernel-backed per-cell sub-bin counts: (P, ncell, s_max) f64.

    Each valid point lands in sub-bin ``r = floor(s_cell * frac)`` of its
    cell, where ``frac`` is the point's fractional position in the cell's
    interval along the tested dimension. The counting itself dispatches
    through ``repro.kernels.subbin.batched_subbin_hist`` (Pallas one-hot
    matmuls on TPU, dtype-preserving ``segment_sum`` oracle elsewhere);
    counts are exact integers, so both backends agree bit-for-bit with the
    legacy in-loop scatter below 2^24 points.

    Every valid point lands in exactly one live sub-bin, so the last-axis
    sum reproduces the per-cell totals — callers need no separate h_cell
    scatter.

    vals/lo/width: (P, N) per-point value + its cell's interval.
    cell:          (P, N) flattened cell id in [0, ncell).
    s:             (P, ncell) per-cell sub-bin counts (``num_subbins``).
    valid:         (P, N) row mask (nulls / padding contribute weight 0).
    """
    s_pt = jnp.take_along_axis(s, cell, axis=1)
    frac = jnp.where(width > 0, (vals - lo) / width, 0.0)
    r = jnp.clip((frac * s_pt).astype(jnp.int32), 0, s_pt - 1)
    w = jnp.where(valid, 1.0, 0.0)
    return batched_subbin_hist(cell, r, w, ncell, s_max,
                               use_pallas=use_pallas, interpret=interpret)
