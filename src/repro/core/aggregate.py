"""Aggregation functions and their bounds (§5.4, Table 3).

Everything operates in the *pre-processed* domain; the engine de-preprocesses
results (repro.core.query). Inputs: weightings (w, wlo, whi) on the 1-D bins
of the aggregation column plus that histogram's metadata and rho = N_s/N.

Each function returns (estimate, lower, upper); empty results (no bin with
positive weight) return (nan, nan, nan) — SQL NULL.
"""
from __future__ import annotations

import numpy as np

_EPS = 1e-12


def _subbin_geometry(u, vmin, vmax, s_max):
    s = np.clip(np.ceil(np.cbrt(2.0 * np.maximum(np.asarray(u, float), 0.0))), 1, s_max)
    delta = (np.asarray(vmax, float) - np.asarray(vmin, float)) / s
    return s, delta


def agg_count(w, wlo, whi, rho):
    return (float(w.sum() / rho), float(wlo.sum() / rho), float(whi.sum() / rho))


def agg_sum(w, wlo, whi, c, cminus, cplus, rho):
    est = float(w @ c / rho)
    lo = float(wlo @ cminus / rho)
    hi = float(whi @ cplus / rho)
    return est, min(lo, est), max(hi, est)


def agg_avg(w, wlo, whi, c, cminus, cplus):
    tot = w.sum()
    if tot <= _EPS:
        return (np.nan,) * 3
    est = float(w @ c / tot)
    los, his = [], []
    for wb in (wlo, whi):
        n = wb.sum()
        if n > _EPS:
            los.append(wb @ cminus / n)
            his.append(wb @ cplus / n)
    lo = float(min(los)) if los else est
    hi = float(max(his)) if his else est
    return est, min(lo, est), max(hi, est)


def _first(mask):
    idx = np.flatnonzero(mask)
    return int(idx[0]) if idx.size else None


def _last(mask):
    idx = np.flatnonzero(mask)
    return int(idx[-1]) if idx.size else None


def agg_min(w, wlo, whi, hist, min_points, s_max, single_col: bool):
    """MIN per Table 3 (§5.4.4) with the single-column tightenings."""
    h, u, vmin, vmax = hist.h, hist.u, hist.vmin, hist.vmax
    s, delta = _subbin_geometry(u, vmin, vmax, s_max)

    t = _first(w > _EPS)
    if t is None:
        return (np.nan,) * 3
    if single_col and u[t] == 2 and w[t] < h[t] / 2.0:
        est = float(vmax[t])
    else:
        est = float(vmin[t])

    # Lower bound: first bin that *might* contain matches (Eq. 31).
    tl = _first(whi > _EPS)
    if tl is None:
        lo = est
    elif single_col and u[tl] == 2 and whi[tl] < h[tl] / 5.0:
        lo = float(vmax[tl])
    else:
        lo = float(vmin[tl])

    # Upper bound: first bin very likely to contain matches (Eq. 32).
    tu = _first(wlo > 0.5)
    if tu is None:
        tu = _last(whi > _EPS)  # conservative fallback
    if tu is None:
        hi = est
    elif single_col and u[tu] > 2 and h[tu] >= min_points:
        a = np.floor(s[tu] * wlo[tu] / max(h[tu], 1.0))
        hi = float(vmax[tu] - a * delta[tu])
    else:
        hi = float(vmax[tu])
    return est, min(lo, est), max(hi, est)


def agg_max(w, wlo, whi, hist, min_points, s_max, single_col: bool):
    """MAX — the mirror of MIN (§5.4.5)."""
    h, u, vmin, vmax = hist.h, hist.u, hist.vmin, hist.vmax
    s, delta = _subbin_geometry(u, vmin, vmax, s_max)

    t = _last(w > _EPS)
    if t is None:
        return (np.nan,) * 3
    if single_col and u[t] == 2 and w[t] < h[t] / 2.0:
        est = float(vmin[t])
    else:
        est = float(vmax[t])

    tu = _last(whi > _EPS)
    if tu is None:
        hi = est
    elif single_col and u[tu] == 2 and whi[tu] < h[tu] / 5.0:
        hi = float(vmin[tu])
    else:
        hi = float(vmax[tu])

    tl = _last(wlo > 0.5)
    if tl is None:
        tl = _first(whi > _EPS)
    if tl is None:
        lo = est
    elif single_col and u[tl] > 2 and h[tl] >= min_points:
        a = np.floor(s[tl] * wlo[tl] / max(h[tl], 1.0))
        lo = float(vmin[tl] + a * delta[tl])
    else:
        lo = float(vmin[tl])
    return est, min(lo, est), max(hi, est)


def _median_bin(wb):
    tot = wb.sum()
    if tot <= _EPS:
        return None
    cum = np.cumsum(wb)
    return int(np.searchsorted(cum, 0.5 * tot))


def agg_median(w, wlo, whi, hist):
    """MEDIAN per Eq. 34–37."""
    u, vmin, vmax = hist.u, hist.vmin, hist.vmax
    tot = w.sum()
    if tot <= _EPS:
        return (np.nan,) * 3
    cum = np.cumsum(w)
    t = int(np.searchsorted(cum, 0.5 * tot))
    t = min(t, len(w) - 1)
    prev = cum[t - 1] if t > 0 else 0.0
    f = (0.5 * tot - prev) / max(w[t], _EPS)
    if u[t] == 2:
        est = float(vmin[t] if f < 0.5 else vmax[t])
    else:
        est = float(vmin[t] + (vmax[t] - vmin[t]) * np.clip(f, 0.0, 1.0))

    ts = [x for x in (_median_bin(wlo), _median_bin(whi)) if x is not None]
    if ts:
        lo = float(vmin[min(ts)])
        hi = float(vmax[max(ts)])
    else:
        lo = hi = est
    return est, min(lo, est), max(hi, est)


def agg_var(w, wlo, whi, c, vmin, vmax):
    """VAR per §5.4.7 (Eq. 38–39)."""
    tot = w.sum()
    if tot <= _EPS:
        return (np.nan,) * 3
    avg = w @ c / tot
    est = float(w @ (c**2) / tot - avg**2)

    xi_lo = np.where(vmax < avg, vmax, np.where(vmin > avg, vmin, avg))
    xi_hi = np.where(np.abs(avg - vmin) > np.abs(vmax - avg), vmin, vmax)

    los, his = [], []
    for wb in (wlo, whi):
        n = wb.sum()
        if n <= _EPS:
            continue
        m_lo = wb @ xi_lo / n
        los.append(wb @ (xi_lo**2) / n - m_lo**2)
        m_hi = wb @ xi_hi / n
        his.append(wb @ (xi_hi**2) / n - m_hi**2)
    lo = float(min(los)) if los else est
    hi = float(max(his)) if his else est
    lo = max(lo, 0.0)
    return est, min(lo, est), max(hi, est)
