"""Core data structures for the PairwiseHist synopsis.

Runtime (in-memory) representation. The compact on-disk encoding lives in
``repro.core.storage``; ``c``/``c±`` (midpoints / weighted-centre bounds) are
re-derivable (§4.3) and are therefore *not* serialized, only cached here.

JAX-facing structs are NamedTuples (automatically pytrees) with fixed
capacities so construction can run under ``jit``/``vmap``/``lax.while_loop``.
Host-facing containers (``PairwiseHist``) hold trimmed NumPy arrays.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

# ---------------------------------------------------------------------------
# Build-time parameters (Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BuildParams:
    """Static construction parameters (Table 2 + capacity knobs).

    The paper's defaults (§6): ``m_frac = 0.01`` (M = 1% of N_s) and
    ``alpha = 0.001``.
    """

    n_samples: int = 100_000          # N_s
    m_frac: float = 0.01              # M = max(2, m_frac * N_s)
    alpha: float = 0.001              # hypothesis-test significance
    seed: int = 0                     # sampling seed
    # TPU-adaptation capacities (static shapes for lax control flow).
    k1_cap: int = 512                 # max 1-D bins per column
    k2_cap: int = 256                 # max 2-D bins per dimension
    s1_max: int = 128                 # max sub-bins, 1-D tests  (>= (2N_s)^(1/3))
    s2_max: int = 32                  # max sub-bins, 2-D tests
    max_rounds_1d: int = 64           # refinement rounds (== max recursion depth)
    max_rounds_2d: int = 16
    use_pallas: bool = False          # route 2-D binning through the Pallas kernel
    # Pair-batched construction (the 2-D hot path). ``pair_chunk`` bounds how
    # many pairs refine per launch (memory ~ pair_chunk * k2_cap^2 * s2_max);
    # launch sizes bucket to powers of two (pair_chunk rounds DOWN so the
    # memory bound is honoured) to bound jit recompiles.
    pair_batched: bool = True         # batched 2-D path vs legacy per-pair loop
    pair_chunk: int = 8               # max pairs per batched launch (pow-2)
    # Adaptive 2-D capacity: chunks refine at the smallest rung of the
    # doubling ladder k2_start, 2*k2_start, ..., k2_cap that fits their
    # initial grids, escalating only when the capacity guard binds (the
    # result is capacity-independent otherwise). Real pair grids are tens of
    # bins, so the k2_cap^2 * s2_max chi-squared workspace shrinks ~16x.
    k2_start: int = 64                # first rung of the capacity ladder
    # Convergence-compacting refinement (build_pairs_compact): pair_chunk
    # slots refine a device-resident pending queue, draining each pair the
    # round it converges and backfilling its slot, so deep (correlated)
    # pairs never lockstep-drag shallow ones. False falls back to the
    # fixed-chunk scheduler (the PR 2 path, kept as baseline/escape hatch).
    compact_drain: bool = True        # drain/backfill vs fixed-chunk lockstep
    # Early-exit threshold for a compacted launch's tail: once the pending
    # queue is empty and fewer than ceil(occupancy_min * slots) slots are
    # still active, the launch returns and the unconverged pairs re-bucket
    # into a smaller power-of-two launch. 0 disables (run the tail at full
    # slot width); results are schedule-independent either way.
    occupancy_min: float = 0.25       # min live-slot fraction before re-bucket
    # GD-native construction (knobs documented in docs/compression.md).
    # When ``build_pairwise_hist`` receives a CompressedTable it decodes only
    # the N_s sampled rows (never the full matrix); seed_from_bases seeds the
    # 1-D edges from the deduplicated bases. from_compressed lets the engine
    # route construction through the stored CompressedTable.
    from_compressed: bool = True      # engine builds from CompressedTable
    seed_from_bases: bool = True      # 1-D edges seeded from GD bases

    @property
    def min_points(self) -> int:
        """M — minimum points for a bin to be split."""
        return max(2, int(round(self.m_frac * self.n_samples)))


# ---------------------------------------------------------------------------
# JAX-facing fixed-capacity histogram structs
# ---------------------------------------------------------------------------


class Hist1D(NamedTuple):
    """One-dimensional histogram for one column (fixed capacity K).

    Valid bins are ``t in [0, k)``; bin ``t`` spans ``[edges[t], edges[t+1])``
    (last valid bin right-closed). Padding: ``edges[k+1:] = +inf``.
    """

    edges: np.ndarray   # (K+1,) f64, sorted, +inf padded
    k: np.ndarray       # ()    i32, number of valid bins
    h: np.ndarray       # (K,)  f64, bin counts
    u: np.ndarray       # (K,)  f64, unique-value counts
    vmin: np.ndarray    # (K,)  f64, per-bin minimum data value (v^-)
    vmax: np.ndarray    # (K,)  f64, per-bin maximum data value (v^+)
    c: np.ndarray       # (K,)  f64, midpoints (derived, cached)
    cminus: np.ndarray  # (K,)  f64, weighted-centre lower bound (Eq. 10)
    cplus: np.ndarray   # (K,)  f64, weighted-centre upper bound (Eq. 10)


class PairHist(NamedTuple):
    """Two-dimensional histogram for a column pair (i, j), i = x-dim, j = y-dim.

    ``H[tx, ty]`` counts points with x in x-bin tx, y in y-bin ty.
    Slice metadata aggregates over one dimension (everything the coverage and
    weightings math needs): e.g. ``hx[tx]`` is the row total,
    ``ux[tx]``/``vminx``/``vmaxx`` the unique count / extrema of x values in
    that row slice.

    ``fold_x[t]`` maps 1-D bin t of column i onto the pair x-row containing
    it (the 1-D grids are union-refined over all their pairs' edges at build
    time, so pair edges ⊆ 1-D edges and containment is exact). This realizes
    ``Pr(P_l | 1-D bin t) = [H^(ij) β^(j)]_{row(t)} / hx_{row(t)}`` — Eq. 27
    evaluated at the refined grid (the paper's Fig. 4 per-dimension 2-D
    metadata story).
    """

    ex: np.ndarray      # (K2+1,) f64 x-dim edges (+inf padded)
    ey: np.ndarray      # (K2+1,) f64 y-dim edges
    kx: np.ndarray      # () i32
    ky: np.ndarray      # () i32
    H: np.ndarray       # (K2, K2) f64 bin counts
    hx: np.ndarray      # (K2,) f64 row totals
    ux: np.ndarray      # (K2,) f64 unique x per row slice
    vminx: np.ndarray   # (K2,) f64
    vmaxx: np.ndarray   # (K2,) f64
    hy: np.ndarray      # (K2,) f64 column totals
    uy: np.ndarray      # (K2,) f64
    vminy: np.ndarray   # (K2,) f64
    vmaxy: np.ndarray   # (K2,) f64
    fold_x: np.ndarray  # (K2,) i32 x-row -> 1-D bin of column i
    fold_y: np.ndarray  # (K2,) i32 y-col -> 1-D bin of column j


# ---------------------------------------------------------------------------
# Host-side container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnInfo:
    """Per-column bookkeeping carried from GD pre-processing into queries."""

    name: str
    kind: str                 # "int" | "float" | "categorical"
    offset: float = 0.0       # subtracted minimum (pre-processed = raw*scale - offset)
    scale: float = 1.0        # float->int multiplier (10**p)
    categories: tuple = ()    # frequency-ranked category values (code -> value)
    n_null: int = 0           # null count (nulls are excluded from histograms)
    mu: float = 1.0           # minimum value spacing in pre-processed domain

    def encode(self, value):
        """Raw literal -> pre-processed domain."""
        if self.kind == "categorical":
            try:
                return float(self.categories.index(value))
            except ValueError:
                return float("nan")  # unseen literal: matches nothing
        # Clear float noise (10.22*100 -> 1022.0000000000001) but keep
        # off-grid literals (e.g. "> 18.65" with scale 10) intact.
        # np.round rather than builtin round so the scalar path and the
        # template batch-bind path (np.round over a literal matrix) share
        # one rounding algorithm elementwise — bit-for-bit by construction.
        return float(np.round(float(value) * self.scale - self.offset, 6))

    def decode(self, value: float):
        """Pre-processed domain -> raw domain (for result reporting)."""
        if self.kind == "categorical":
            idx = int(round(value))
            if 0 <= idx < len(self.categories):
                return self.categories[idx]
            return None
        return (value + self.offset) / self.scale


@dataclasses.dataclass
class PairwiseHist:
    """The complete synopsis: d 1-D histograms + d(d-1)/2 pair histograms."""

    params: BuildParams
    n_rows: int                         # N  (full dataset)
    n_sampled: int                      # N_s actually used
    columns: list                       # list[ColumnInfo]
    hists: list                         # list[Hist1D]   (numpy, trimmed to k)
    pairs: dict                         # {(i, j) i<j : PairHist} (numpy, trimmed)
    chi2_table: np.ndarray              # chi2 critical values, indexed by s
    # Construction telemetry (pair-phase wall time, mode, launch sizes);
    # in-memory only, not serialized.
    build_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def d(self) -> int:
        return len(self.columns)

    @property
    def rho(self) -> float:
        """Sampling ratio rho = N_s / N."""
        return self.n_sampled / max(1, self.n_rows)

    def col_index(self, name: str) -> int:
        for idx, col in enumerate(self.columns):
            if col.name == name:
                return idx
        raise KeyError(f"unknown column {name!r}")

    def pair(self, i: int, j: int) -> PairHist:
        """The pair histogram with x-dim = i, y-dim = j (transposing if needed)."""
        if i == j:
            raise ValueError("no pair histogram for identical columns")
        if (i, j) in self.pairs:
            return self.pairs[(i, j)]
        p = self.pairs[(j, i)]
        return PairHist(
            ex=p.ey, ey=p.ex, kx=p.ky, ky=p.kx, H=p.H.T,
            hx=p.hy, ux=p.uy, vminx=p.vminy, vmaxx=p.vmaxy,
            hy=p.hx, uy=p.ux, vminy=p.vminx, vmaxy=p.vmaxx,
            fold_x=p.fold_y, fold_y=p.fold_x,
        )

    def nbytes_runtime(self) -> int:
        """In-memory (runtime) footprint; the encoded size comes from storage.py."""
        total = 0
        for hist in self.hists:
            total += sum(np.asarray(a).nbytes for a in hist)
        for p in self.pairs.values():
            total += sum(np.asarray(a).nbytes for a in p)
        total += self.chi2_table.nbytes
        return total

    @property
    def nbytes(self) -> int:
        """Decoded-engine footprint estimator the cold-tier governor budgets
        against (``AQPServer(max_engine_bytes=...)``)."""
        return self.nbytes_runtime()
