"""Paper-faithful sequential construction (Algorithms 1 + 2) in NumPy.

This is the literal, recursive, depth-first implementation of
``BuildPairwiseHist`` / ``RefineBin1D`` / ``RefineBin2D`` as printed in the
paper. It serves two purposes:

  1. Test oracle: in 1-D, midpoint splits make refinement decisions
     independent across bins, so the level-synchronous TPU implementation in
     ``repro.core.refine`` must produce *identical* edge sets — asserted in
     tests/test_refine_equivalence.py.
  2. The "paper-faithful baseline" for the §Perf construction comparison in
     EXPERIMENTS.md (sequential recursion vs vectorized level-sync rounds).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import chi2 as chi2lib


def is_uniform(x: np.ndarray, e_lo: float, e_hi: float, n_unique: int,
               crit_table: np.ndarray, s_max: int) -> bool:
    """IsUniform: chi-squared test against within-bin uniformity (Eq. 2–3)."""
    s = int(np.clip(np.ceil(np.cbrt(2.0 * n_unique)), 1, s_max))
    if s < 2:
        return True
    h = x.size
    # Sub-bin counts over equal-width sub-intervals of [e_lo, e_hi).
    edges = e_lo + (e_hi - e_lo) * np.arange(1, s) / s
    idx = np.searchsorted(np.sort(x), edges, side="left")
    bounds = np.concatenate([[0], idx, [h]])
    hbar = np.diff(bounds)
    expect = h / s
    stat = float(np.sum((hbar - expect) ** 2) / expect)
    crit = crit_table[s] if s < len(crit_table) else crit_table[-1]
    return stat <= crit


def refine_bin_1d(x: np.ndarray, e_lo: float, e_hi: float, m_points: int,
                  crit_table: np.ndarray, s_max: int, depth: int = 0,
                  max_depth: int = 64):
    """RefineBin1D (Algorithm 2). Returns (upper_edges, vmin, vmax, u)."""
    uniq = np.unique(x)
    n_u = uniq.size
    if x.size == 0:
        return [e_hi], [e_lo], [e_hi], [0]
    if n_u == 1:
        return [e_hi], [uniq[0]], [uniq[0]], [1]
    if x.size < m_points or depth >= max_depth or \
            is_uniform(x, e_lo, e_hi, n_u, crit_table, s_max):
        return [e_hi], [uniq[0]], [uniq[-1]], [n_u]
    z = 0.5 * (e_lo + e_hi)          # equal-width split at the midpoint
    if not (e_lo < z < e_hi):
        return [e_hi], [uniq[0]], [uniq[-1]], [n_u]
    left = x[x < z]
    right = x[x >= z]
    e_l, v_l, vp_l, u_l = refine_bin_1d(left, e_lo, z, m_points, crit_table,
                                        s_max, depth + 1, max_depth)
    e_r, v_r, vp_r, u_r = refine_bin_1d(right, z, e_hi, m_points, crit_table,
                                        s_max, depth + 1, max_depth)
    return e_l + e_r, v_l + v_r, vp_l + vp_r, u_l + u_r


def build_1d_sequential(x: np.ndarray, init_edges: np.ndarray, m_points: int,
                        crit_table: np.ndarray, s_max: int = 128):
    """The 1-D section of BuildPairwiseHist (Algorithm 1, lines 3–12)."""
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x)]
    edges = [float(init_edges[0])]
    vmin, vmax, u = [], [], []
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))
    try:
        for t in range(len(init_edges) - 1):
            lo, hi = float(init_edges[t]), float(init_edges[t + 1])
            last = t == len(init_edges) - 2
            sel = (x >= lo) & ((x <= hi) if last else (x < hi))
            e_new, v_new, vp_new, u_new = refine_bin_1d(
                x[sel], lo, hi, m_points, crit_table, s_max)
            edges.extend(e_new)
            vmin.extend(v_new)
            vmax.extend(vp_new)
            u.extend(u_new)
    finally:
        sys.setrecursionlimit(old_limit)
    edges = np.asarray(edges)
    counts, _ = np.histogram(x, bins=edges)
    return (edges, counts.astype(np.float64), np.asarray(u, np.float64),
            np.asarray(vmin, np.float64), np.asarray(vmax, np.float64))


def refine_bin_2d(xy: np.ndarray, bx: tuple, by: tuple, m_points: int,
                  crit_table: np.ndarray, s_max: int, depth: int = 0,
                  max_depth: int = 16):
    """RefineBin2D: returns (new_x_edges, new_y_edges) discovered in this bin."""
    if xy.shape[0] <= m_points or depth >= max_depth:
        return [], []
    x, y = xy[:, 0], xy[:, 1]
    ux, uy = np.unique(x).size, np.unique(y).size
    ok_x = ux <= 1 or is_uniform(x, bx[0], bx[1], ux, crit_table, s_max)
    ok_y = uy <= 1 or is_uniform(y, by[0], by[1], uy, crit_table, s_max)
    if ok_x and ok_y:
        return [], []

    def excess(vals, lo, hi, n_u):
        s = int(np.clip(np.ceil(np.cbrt(2.0 * n_u)), 2, s_max))
        edges = lo + (hi - lo) * np.arange(1, s) / s
        idx = np.searchsorted(np.sort(vals), edges, side="left")
        hbar = np.diff(np.concatenate([[0], idx, [vals.size]]))
        expect = vals.size / s
        return float(np.sum((hbar - expect) ** 2) / expect) / crit_table[s]

    split_x = not ok_x and (ok_y or excess(x, *bx, ux) >= excess(y, *by, uy))
    if split_x:
        z = 0.5 * (bx[0] + bx[1])
        if not (bx[0] < z < bx[1]):
            return [], []
        ex_l, ey_l = refine_bin_2d(xy[x < z], (bx[0], z), by, m_points,
                                   crit_table, s_max, depth + 1, max_depth)
        ex_r, ey_r = refine_bin_2d(xy[x >= z], (z, bx[1]), by, m_points,
                                   crit_table, s_max, depth + 1, max_depth)
        return [z] + ex_l + ex_r, ey_l + ey_r
    z = 0.5 * (by[0] + by[1])
    if not (by[0] < z < by[1]):
        return [], []
    ex_l, ey_l = refine_bin_2d(xy[y < z], bx, (by[0], z), m_points,
                               crit_table, s_max, depth + 1, max_depth)
    ex_r, ey_r = refine_bin_2d(xy[y >= z], bx, (z, by[1]), m_points,
                               crit_table, s_max, depth + 1, max_depth)
    return ex_l + ex_r, [z] + ey_l + ey_r


def build_2d_sequential(x, y, ex0, ey0, m_points, crit_table, s_max: int = 32):
    """The 2-D section of BuildPairwiseHist (Algorithm 1, lines 14–26)."""
    pts = np.stack([x, y], 1)
    pts = pts[np.isfinite(pts).all(1)]
    ex, ey = list(ex0), list(ey0)
    H, _, _ = np.histogram2d(pts[:, 0], pts[:, 1], bins=[np.asarray(ex), np.asarray(ey)])
    new_x, new_y = [], []
    for ti in range(len(ex) - 1):
        for tj in range(len(ey) - 1):
            if H[ti, tj] <= m_points:
                continue
            last_x = ti == len(ex) - 2
            last_y = tj == len(ey) - 2
            sel_x = (pts[:, 0] >= ex[ti]) & ((pts[:, 0] <= ex[ti + 1]) if last_x
                                             else (pts[:, 0] < ex[ti + 1]))
            sel_y = (pts[:, 1] >= ey[tj]) & ((pts[:, 1] <= ey[tj + 1]) if last_y
                                             else (pts[:, 1] < ey[tj + 1]))
            cell = pts[sel_x & sel_y]
            zx, zy = refine_bin_2d(cell, (ex[ti], ex[ti + 1]),
                                   (ey[tj], ey[tj + 1]), m_points,
                                   crit_table, s_max)
            new_x.extend(zx)
            new_y.extend(zy)
    ex = np.unique(np.concatenate([ex, new_x]))
    ey = np.unique(np.concatenate([ey, new_y]))
    H, _, _ = np.histogram2d(pts[:, 0], pts[:, 1], bins=[ex, ey])
    return ex, ey, H


def crit_table_for(alpha: float, s_max: int) -> np.ndarray:
    return chi2lib.build_crit_table(alpha, s_max)
