"""BuildPairwiseHist (Algorithm 1), level-synchronous TPU adaptation.

Pipeline:
  1. downsample the (pre-processed, integer-domain) dataset to N_s rows;
  2. all columns at once: one ``np.sort(axis=0)`` + vectorized unique-prefix,
     then ``refine_1d`` (vmapped across all columns — one kernel refines
     every column's histogram);
  3. pair-batched 2-D refinement: the d(d-1)/2 pairs stack into (P, N_s)
     tensors (bucketed to powers of two so jit compiles a bounded set of
     shapes) and refine level-synchronously on device, with results
     arriving in grouped device->host transfers — no per-pair ``int(kx)`` /
     ``np.asarray`` round-trips. The default scheduler is
     **convergence-compacting** (``build_pairs_compact`` /
     ``refine.refine_2d_compact``): ``pair_chunk`` slots refine a
     device-resident pending queue, draining each pair the round it
     converges and backfilling its slot, so deep-refining (correlated)
     pairs never lockstep-drag shallow ones; per-column presorts are
     shared across all pairs (``_column_ranks``) and capacity-guard
     escalation re-queues only the capped pairs. The fixed-chunk
     scheduler (``build_pairs_batched``: one ``lax.while_loop`` per chunk
     of ``pair_chunk`` pairs, whole-chunk escalation) remains behind
     ``compact_drain=False``. Per-round bin counts dispatch through
     ``repro.kernels.hist2d.batched_hist2d`` and chi-squared sub-bin
     counts through ``repro.kernels.subbin`` (Pallas one-hot matmuls when
     ``params.use_pallas``; dtype-preserving jnp oracles otherwise). The
     legacy per-pair host loop survives as ``build_pairs_sequential``
     (oracle + benchmark baseline; bit-for-bit equal results, asserted in
     tests/test_build_batched.py and tests/test_build_compact.py).

Missing values (NaN) are excluded per-histogram: a row missing column i does
not contribute to hist(i) nor to any pair involving i — matching SQL
semantics (aggregates ignore NULL, comparisons with NULL are false).

``build_pairwise_hist`` never mutates its inputs: per-column null counts are
attached to *copies* of the caller's ``ColumnInfo`` objects (the synopsis
owns its own column list).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chi2 as chi2lib
from repro.core import refine
from repro.core.types import BuildParams, ColumnInfo, Hist1D, PairHist, PairwiseHist
from repro.gd.greedygd import CompressedTable, GreedyGD, decompress_rows
from repro.obs.timeline import BuildTimeline

def _prep_columns(sample: np.ndarray):
    """Sort all columns at once with NaN (missing) pushed to +inf at the tail.

    One ``np.sort(axis=0)`` over the (N, d) sample plus a vectorized
    unique-prefix replaces the former Python loop of d per-column sorts.
    Returns (xs_all (d, N), uprefix_all (d, N+1), n_valid (d,), vmin (d,),
    vmax (d,)).
    """
    x = np.asarray(sample, np.float64).copy()
    n, d = x.shape
    nan = np.isnan(x)
    x[nan] = np.inf
    xs = np.sort(x, axis=0)                       # (N, d)
    n_valid = (n - nan.sum(axis=0)).astype(np.int64)
    new = np.empty((n, d), bool)
    new[0] = True
    new[1:] = xs[1:] != xs[:-1]
    up = np.zeros((n + 1, d), np.int64)
    np.cumsum(new, axis=0, out=up[1:])
    has = n_valid > 0
    vmin = np.where(has, xs[0], 0.0)
    vmax = np.where(has, xs[np.maximum(n_valid - 1, 0), np.arange(d)], 0.0)
    return (np.ascontiguousarray(xs.T), np.ascontiguousarray(up.T),
            n_valid, vmin, vmax)


def fold_to_rows(edges_1d: np.ndarray, edges_pair: np.ndarray) -> np.ndarray:
    """Map each 1-D (union-grid) bin to the pair row containing it.

    Pair edges are a subset of the union grid, so containment is exact.
    """
    k1 = edges_1d.size - 1
    mids = 0.5 * (edges_1d[:-1] + edges_1d[1:])
    idx = np.searchsorted(edges_pair, mids, side="right") - 1
    return np.clip(idx, 0, max(edges_pair.size - 2, 0)).astype(np.int32)


def _init_edges(vmin: float, vmax: float, cap: int, n_take: int,
                seed_edges=None) -> tuple[np.ndarray, int]:
    """Initial bin edges: GD bases (downsampled to ceil(N_s/M)) or min/max."""
    if seed_edges is not None and len(seed_edges) > 2:
        e = np.unique(np.asarray(seed_edges, np.float64))
        e = e[(e > vmin) & (e < vmax)]
        if e.size > max(n_take - 2, 0):
            idx = np.linspace(0, e.size - 1, max(n_take - 2, 0)).round().astype(int)
            e = e[np.unique(idx)] if idx.size else e[:0]
        edges = np.concatenate([[vmin], e, [vmax]])
    else:
        edges = np.array([vmin, vmax], np.float64)
    edges = np.unique(edges)
    if edges.size == 1:  # constant column: single zero-width bin
        edges = np.array([edges[0], edges[0]], np.float64)
    edges = edges[: cap + 1]
    n_bins = edges.size - 1
    out = np.full(cap + 1, np.inf, np.float64)
    out[: edges.size] = edges
    return out, n_bins


def _pad_edges(e: np.ndarray, cap: int) -> np.ndarray:
    out = np.full(cap + 1, np.inf, np.float64)
    out[: min(e.size, cap + 1)] = e[: cap + 1]
    return out


def _pair_keys(d: int) -> list[tuple[int, int]]:
    """Pair keys (a, b), a < b, in the legacy loop's emission order."""
    return [(j, i) for i in range(1, d) for j in range(i)]


def _trim_pair(ex, ey, kx, ky, H, hx, ux, vminx, vmaxx, hy, uy, vminy,
               vmaxy) -> PairHist:
    """Trim one pair's fixed-capacity (host) arrays to its valid bins."""
    nkx, nky = int(kx), int(ky)
    return PairHist(
        ex=ex[: nkx + 1].copy(), ey=ey[: nky + 1].copy(),
        kx=np.int32(nkx), ky=np.int32(nky),
        H=H[:nkx, :nky].copy(),
        hx=hx[:nkx].copy(), ux=ux[:nkx].copy(),
        vminx=vminx[:nkx].copy(), vmaxx=vmaxx[:nkx].copy(),
        hy=hy[:nky].copy(), uy=uy[:nky].copy(),
        vminy=vminy[:nky].copy(), vmaxy=vmaxy[:nky].copy(),
        fold_x=np.zeros(0, np.int32), fold_y=np.zeros(0, np.int32),
    )


def build_pairs_sequential(sample: np.ndarray, hists: list, params,
                           crit2, m_pts: int) -> dict:
    """Legacy per-pair host loop (one compiled function, P sequential
    launches with a blocking device->host sync per pair).

    Kept as the bit-for-bit oracle for the batched path and as the
    benchmark baseline. Returns {(a, b): PairHist} without fold maps.
    """
    K2 = params.k2_cap
    sample_j = jnp.asarray(np.nan_to_num(sample, nan=0.0))
    nanmask = np.isnan(sample)
    raw_pairs = {}
    for a, b in _pair_keys(sample.shape[1]):
        valid = jnp.asarray(~(nanmask[:, a] | nanmask[:, b]))
        ex0 = jnp.asarray(_pad_edges(hists[a].edges, K2))
        ey0 = jnp.asarray(_pad_edges(hists[b].edges, K2))
        kx0 = jnp.int32(min(int(hists[a].k), K2))
        ky0 = jnp.int32(min(int(hists[b].k), K2))
        x = sample_j[:, a]
        y = sample_j[:, b]
        ex, ey, kx, ky = refine.refine_2d(
            x, y, valid, ex0, ey0, kx0, ky0, jnp.float64(m_pts), crit2,
            k2=K2, s_max=params.s2_max, max_rounds=params.max_rounds_2d)
        out = refine.pair_metadata(x, y, valid, ex, ey, kx, ky, k2=K2)
        raw_pairs[(a, b)] = _trim_pair(
            *(np.asarray(v) for v in (ex, ey, kx, ky) + tuple(out)))
    return raw_pairs


def _column_ranks(sample_nn: np.ndarray) -> np.ndarray:
    """Per-column dense ranks (d, N): ties share a rank, order preserved.

    One sort + one searchsorted *per column* — shared across every pair the
    column appears in. ``_presort_pairs_host`` composes two columns' ranks
    into a single int64 lexicographic key, so each pair pays one stable
    (radix) integer argsort instead of a two-key float ``np.lexsort``;
    before this, every column was re-lexsorted once per pair (d-1 times).
    """
    n, d = sample_nn.shape
    xs = np.sort(sample_nn, axis=0)
    ranks = np.empty((d, n), np.int64)
    for i in range(d):
        ranks[i] = np.searchsorted(xs[:, i], sample_nn[:, i], side="left")
    return ranks


def _presort_pairs_host(x, y, valid, rx=None, ry=None):
    """Host-side ``refine.presort_pairs`` (numpy's sort beats XLA:CPU's).

    Same layout and same (stable lexsort) semantics; done once per chunk —
    the per-round unique counts then need no sort at all.

    With ``rx``/``ry`` (per-pair rows of the shared ``_column_ranks``
    table) the two-key float lexsorts become single stable argsorts of the
    composite integer key ``rank_primary * (N+1) + rank_secondary``
    (invalid rows get the past-the-end sentinel ``(N+1)^2``, matching the
    +inf keys of the lexsort path). Ranks are order-isomorphic to values
    with identical ties and both sorts are stable, so the permutations —
    and therefore every output array — are identical to the lexsort path
    (asserted in tests/test_build_compact.py).
    """
    n_pairs, n = x.shape
    xo1 = np.empty_like(x)
    yo1 = np.empty_like(y)
    vo1 = np.empty_like(valid)
    xo2 = np.empty_like(x)
    yo2 = np.empty_like(y)
    vo2 = np.empty_like(valid)
    big = np.int64(n + 1) * np.int64(n + 1)
    for p in range(n_pairs):
        if rx is None:
            kx = np.where(valid[p], x[p], np.inf)
            ky = np.where(valid[p], y[p], np.inf)
            o1 = np.lexsort((ky, kx))
            o2 = np.lexsort((kx, ky))
        else:
            key1 = np.where(valid[p], rx[p] * np.int64(n + 1) + ry[p], big)
            key2 = np.where(valid[p], ry[p] * np.int64(n + 1) + rx[p], big)
            o1 = np.argsort(key1, kind="stable")
            o2 = np.argsort(key2, kind="stable")
        xo1[p], yo1[p], vo1[p] = x[p][o1], y[p][o1], valid[p][o1]
        xo2[p], yo2[p], vo2[p] = x[p][o2], y[p][o2], valid[p][o2]
    new1 = np.empty((n_pairs, n), bool)
    new1[:, 0] = True
    new1[:, 1:] = xo1[:, 1:] != xo1[:, :-1]
    new2 = np.empty((n_pairs, n), bool)
    new2[:, 0] = True
    new2[:, 1:] = yo2[:, 1:] != yo2[:, :-1]
    return xo1, yo1, vo1, new1, xo2, yo2, vo2, new2


def _pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1) — the chunk/slot bucketing rule
    (rounding DOWN honours the documented memory ceiling)."""
    return 1 << (max(1, n).bit_length() - 1)


def _pow2_ceil(n: int) -> int:
    """Smallest power of two >= n — the launch-size bucketing rule (tail
    launches pad up so jit sees a bounded set of shapes)."""
    return 1 << max(0, n - 1).bit_length()


def _cap_ladder(need: int, k2_cap: int, k2_start: int) -> list[int]:
    """Doubling capacity ladder: smallest rung fitting ``need`` up to k2_cap."""
    c = max(2, k2_start)
    while c < need:
        c *= 2
    c = min(c, k2_cap)
    ladder = [c]
    while c < k2_cap:
        c = min(c * 2, k2_cap)
        ladder.append(c)
    return ladder


def build_pairs_batched(sample: np.ndarray, hists: list, params,
                        crit2, m_pts: int, stats: dict | None = None,
                        timeline: BuildTimeline | None = None) -> dict:
    """Pair-batched 2-D construction: chunked (P, N) launches, one grouped
    device->host transfer per chunk. Returns {(a, b): PairHist} (no folds);
    records per-chunk (size, capacity) launches into ``stats`` and, when a
    ``timeline`` is passed, one ``batched_launch`` interval per launch.

    Each chunk refines at the smallest capacity rung that fits its initial
    grids; if any pair's capacity guard binds, the whole chunk re-runs one
    rung up (results are capacity-independent while the guard is slack, so
    this is exact — and saturation is the rare case by design).
    """
    K2 = params.k2_cap
    n_s, d = sample.shape
    keys = _pair_keys(d)
    sample_nn = np.nan_to_num(sample, nan=0.0)
    nanmask = np.isnan(sample)
    # Normalize the chunk cap to a power of two — rounding DOWN, so the
    # documented memory bound (~ pair_chunk * k2^2 * s2_max) is honoured;
    # the tail chunk buckets to the next power of two >= its size, so jit
    # sees at most log2(chunk) + 1 distinct batch shapes per capacity rung.
    chunk = _pow2_floor(int(params.pair_chunk))
    launches = []
    raw_pairs = {}
    for start in range(0, len(keys), chunk):
        part = keys[start:start + chunk]
        size = _pow2_ceil(len(part))
        x = np.zeros((size, n_s), np.float64)
        y = np.zeros((size, n_s), np.float64)
        valid = np.zeros((size, n_s), bool)
        kx0 = np.ones(size, np.int32)
        ky0 = np.ones(size, np.int32)
        for p, (a, b) in enumerate(part):
            x[p] = sample_nn[:, a]
            y[p] = sample_nn[:, b]
            valid[p] = ~(nanmask[:, a] | nanmask[:, b])
            kx0[p] = min(int(hists[a].k), K2)
            ky0[p] = min(int(hists[b].k), K2)
        pres_j = tuple(jnp.asarray(a) for a in
                       _presort_pairs_host(x, y, valid))
        need = int(max(kx0.max(), ky0.max()))
        for cap in _cap_ladder(need, K2, params.k2_start):
            t_launch = time.perf_counter()
            ex0 = np.full((size, cap + 1), np.inf, np.float64)
            ey0 = np.full((size, cap + 1), np.inf, np.float64)
            ex0[:, :2] = 0.0
            ey0[:, :2] = 0.0  # dummy lanes: one empty bin, no valid rows
            for p, (a, b) in enumerate(part):
                ex0[p] = _pad_edges(hists[a].edges, cap)
                ey0[p] = _pad_edges(hists[b].edges, cap)
            out = refine.build_pairs_device(
                *pres_j, jnp.asarray(ex0), jnp.asarray(ey0),
                jnp.asarray(kx0), jnp.asarray(ky0),
                jnp.float64(m_pts), crit2, k2=cap, s_max=params.s2_max,
                max_rounds=params.max_rounds_2d,
                use_pallas=params.use_pallas)
            host = jax.device_get(out)  # ONE grouped transfer for the chunk
            launches.append((size, cap))
            if timeline is not None:
                timeline.add("batched_launch", t_launch, time.perf_counter(),
                             cap=cap, size=size, pairs=len(part))
            capped = host[4]
            if cap >= K2 or not capped[: len(part)].any():
                break
        fields = host[:4] + host[5:]    # drop the capped flag
        for p, (a, b) in enumerate(part):
            raw_pairs[(a, b)] = _trim_pair(*(v[p] for v in fields))
    if stats is not None:
        stats["pair_launches"] = launches
    return raw_pairs


# Pending pairs held device-resident per compacted launch, in units of the
# slot count: the compaction horizon (a deep pair can only be overlapped by
# pairs inside its group) and the (group * N) presort-upload memory bound.
_COMPACT_QUEUE = 4


def build_pairs_compact(sample: np.ndarray, hists: list, params,
                        crit2, m_pts: int, stats: dict | None = None,
                        timeline: BuildTimeline | None = None) -> dict:
    """Convergence-compacting 2-D construction (the default batched path).

    Pairs feed through ``refine.refine_2d_compact`` in groups of up to
    ``_COMPACT_QUEUE`` chunks: ``pair_chunk`` slots refine while the rest
    of the group waits device-resident in the pending queue, so a slot
    whose pair converges is backfilled the same round instead of idling
    until the chunk's slowest pair finishes (the fixed-chunk
    ``build_pairs_batched`` failure mode on correlated columns). The
    capacity ladder escalates *per pair*: only pairs whose guard bound
    re-queue one rung up, where the fixed-chunk path re-runs whole chunks.
    Per-column presorts are shared (``_column_ranks``) and each group's
    metadata runs as one batched launch.

    Results are bit-for-bit equal to ``build_pairs_sequential``: every
    pair's refinement is the same deterministic fixed-point iteration
    whatever the slot count, queue order, drain timing or ``occupancy_min``
    re-bucketing (asserted in tests/test_build_compact.py). Returns
    {(a, b): PairHist} without fold maps; records launch shapes and
    occupancy telemetry into ``stats``. When a ``timeline`` is passed,
    every device relaunch becomes a ``compact_launch`` interval carrying
    its drained/escalated/resumed counters plus ``rung_escalation`` and
    ``occupancy_rebucket`` markers — the per-round schedule ledger as an
    event stream instead of summed scalars.
    """
    K2 = params.k2_cap
    n_s, d = sample.shape
    keys = _pair_keys(d)
    sample_nn = np.nan_to_num(sample, nan=0.0)
    nanmask = np.isnan(sample)
    ranks = _column_ranks(sample_nn)
    slots = _pow2_floor(int(params.pair_chunk))
    group_cap = slots * _COMPACT_QUEUE
    occupancy = float(params.occupancy_min)
    launches = []
    comp = {"loop_rounds": 0, "pair_rounds": 0, "slot_rounds": 0,
            "relaunches": 0, "escalated_pairs": 0, "occupancy_hist": {}}
    raw_pairs = {}

    for start in range(0, len(keys), group_cap):
        part = keys[start:start + group_cap]
        g = len(part)
        x = np.empty((g, n_s), np.float64)
        y = np.empty((g, n_s), np.float64)
        valid = np.empty((g, n_s), bool)
        rx = np.empty((g, n_s), np.int64)
        ry = np.empty((g, n_s), np.int64)
        kx0g = np.ones(g, np.int32)
        ky0g = np.ones(g, np.int32)
        for p, (a, b) in enumerate(part):
            x[p] = sample_nn[:, a]
            y[p] = sample_nn[:, b]
            valid[p] = ~(nanmask[:, a] | nanmask[:, b])
            rx[p], ry[p] = ranks[a], ranks[b]
            kx0g[p] = min(int(hists[a].k), K2)
            ky0g[p] = min(int(hists[b].k), K2)
        pres = _presort_pairs_host(x, y, valid, rx, ry)

        # Per-pair capacity rungs: each pair starts at the smallest ladder
        # rung that fits ITS initial grids (the fixed-chunk path levels a
        # whole chunk up to its widest pair), and capacity-guard escalation
        # re-queues only the capped pairs one rung up.
        ladder = _cap_ladder(2, K2, params.k2_start)
        queue: dict[int, list] = {}
        for gid in range(g):
            need = max(int(kx0g[gid]), int(ky0g[gid]))
            cap = next(c for c in ladder if c >= need or c == K2)
            queue.setdefault(cap, []).append(gid)
        final: dict[int, tuple] = {}  # gid -> (cap, ex, ey, kx, ky)
        for rung_i, cap in enumerate(ladder):
            pend = queue.pop(cap, [])
            if not pend:
                continue
            drain_capped = cap < K2
            # (gid, resume-state | None): fresh pairs start from their 1-D
            # grids; resumed pairs (occupancy_min re-buckets) continue their
            # partial refinement exactly where the previous launch left it.
            entries = [(gid, None) for gid in pend]
            first_launch = True
            while entries:
                size = _pow2_ceil(len(entries))
                s_eff = min(slots, size)
                idx = [gid for gid, _ in entries]
                idx += [idx[0]] * (size - len(idx))
                data = tuple(jnp.asarray(arr[idx]) for arr in pres)
                ex0 = np.full((size, cap + 1), np.inf, np.float64)
                ey0 = np.full((size, cap + 1), np.inf, np.float64)
                ex0[:, :2] = 0.0
                ey0[:, :2] = 0.0  # pad lanes: one empty bin, never fed
                kx0 = np.ones(size, np.int32)
                ky0 = np.ones(size, np.int32)
                rounds0 = np.zeros(size, np.int32)
                capped0 = np.zeros(size, bool)
                for p, (gid, st) in enumerate(entries):
                    a, b = part[gid]
                    if st is None:
                        ex0[p] = _pad_edges(hists[a].edges, cap)
                        ey0[p] = _pad_edges(hists[b].edges, cap)
                        kx0[p], ky0[p] = kx0g[gid], ky0g[gid]
                    else:
                        (ex0[p], ey0[p], kx0[p], ky0[p], rounds0[p],
                         capped0[p]) = st
                t_launch = time.perf_counter()
                out = refine.refine_2d_compact(
                    *data, jnp.asarray(ex0), jnp.asarray(ey0),
                    jnp.asarray(kx0), jnp.asarray(ky0),
                    jnp.asarray(rounds0), jnp.asarray(capped0),
                    jnp.int32(len(entries)), jnp.float64(m_pts), crit2,
                    jnp.float64(occupancy), n_slots=s_eff, k2=cap,
                    s_max=params.s2_max, max_rounds=params.max_rounds_2d,
                    drain_capped=drain_capped, use_pallas=params.use_pallas)
                host = jax.device_get(out)  # ONE grouped transfer
                (oex, oey, okx, oky, ocap, _ornd, odone, spair, sact,
                 sex, sey, skx, sky, scap, srnd, occ_hist, loop_rounds,
                 act_rounds) = host
                launches.append((s_eff, cap))
                comp["loop_rounds"] += int(loop_rounds)
                comp["pair_rounds"] += int(act_rounds)
                comp["slot_rounds"] += int(loop_rounds) * s_eff
                comp["relaunches"] += 0 if first_launch else 1
                for n_act, n_r in enumerate(occ_hist):
                    if n_r:
                        comp["occupancy_hist"][n_act] = \
                            comp["occupancy_hist"].get(n_act, 0) + int(n_r)
                escalated = 0
                for p, (gid, _) in enumerate(entries):
                    if not odone[p]:
                        continue  # still active in a slot: resumes below
                    if drain_capped and ocap[p]:
                        # Discard; re-queue one rung up (ladder[rung_i + 1]
                        # exists whenever drain_capped).
                        queue.setdefault(ladder[rung_i + 1], []).append(gid)
                        escalated += 1
                    else:
                        final[gid] = (cap, oex[p], oey[p], int(okx[p]),
                                      int(oky[p]))
                comp["escalated_pairs"] += escalated
                n_before = len(entries)
                entries = [
                    (entries[int(spair[s_i])][0],
                     (sex[s_i], sey[s_i], int(skx[s_i]), int(sky[s_i]),
                      int(srnd[s_i]), bool(scap[s_i])))
                    for s_i in range(s_eff) if sact[s_i]]
                if timeline is not None:
                    timeline.add(
                        "compact_launch", t_launch, time.perf_counter(),
                        cap=cap, slots=s_eff, pairs=n_before,
                        loop_rounds=int(loop_rounds),
                        pair_rounds=int(act_rounds),
                        drained=n_before - len(entries),
                        escalated=escalated, resumed=len(entries),
                        relaunch=not first_launch)
                    if escalated:
                        timeline.event("rung_escalation", from_cap=cap,
                                       to_cap=ladder[min(rung_i + 1,
                                                         len(ladder) - 1)],
                                       pairs=escalated)
                    if entries:
                        timeline.event("occupancy_rebucket",
                                       resumed=len(entries), cap=cap)
                first_launch = False

        # Metadata per rung (pairs that finished at the same capacity share
        # a bucketed launch; trim is capacity-independent).
        by_cap: dict[int, list] = {}
        for gid, (cap, *_rest) in final.items():
            by_cap.setdefault(cap, []).append(gid)
        for cap, gids in sorted(by_cap.items()):
            size = _pow2_ceil(len(gids))
            idx = gids + [gids[0]] * (size - len(gids))
            data = tuple(jnp.asarray(arr[idx]) for arr in pres)
            ex_m = np.full((size, cap + 1), np.inf, np.float64)
            ey_m = np.full((size, cap + 1), np.inf, np.float64)
            ex_m[:, :2] = 0.0
            ey_m[:, :2] = 0.0
            kx_m = np.ones(size, np.int32)
            ky_m = np.ones(size, np.int32)
            for p, gid in enumerate(gids):
                _c, fex, fey, fkx, fky = final[gid]
                ex_m[p, : fex.size] = fex
                ey_m[p, : fey.size] = fey
                kx_m[p], ky_m[p] = fkx, fky
            meta = refine.pair_metadata_batch(
                *data, jnp.asarray(ex_m), jnp.asarray(ey_m),
                jnp.asarray(kx_m), jnp.asarray(ky_m), k2=cap,
                use_pallas=params.use_pallas)
            meta_h = jax.device_get(meta)
            for p, gid in enumerate(gids):
                a, b = part[gid]
                raw_pairs[(a, b)] = _trim_pair(
                    ex_m[p], ey_m[p], kx_m[p], ky_m[p],
                    *(v[p] for v in meta_h))
    if stats is not None:
        stats["pair_launches"] = launches
        stats["compaction"] = comp
    return raw_pairs


def build_pairwise_hist(
    data: np.ndarray,
    columns: list[ColumnInfo],
    params: BuildParams | None = None,
    n_rows_full: int | None = None,
    seed_edges: list | None = None,
) -> PairwiseHist:
    """Construct the synopsis from a pre-processed (N, d) float64 matrix.

    ``data`` is in the *pre-processed* (GD) domain: non-negative integers as
    f64, NaN for missing — or a ``CompressedTable``, in which case only the
    N_s sampled rows are decoded (``decompress_rows``) and, with
    ``params.seed_from_bases``, the 1-D edges are seeded from the
    deduplicated bases (§3); the full raw matrix is never materialized.
    Because sampling draws row *indices* from ``params.seed`` and the decode
    is bit-exact, the compressed-input build is bit-for-bit identical to the
    raw build with ``GreedyGD.seed_edges`` passed in. ``seed_edges``
    (optional) are per-column initial edge candidates — typically
    reconstructed GreedyGD bases (§3). ``n_rows_full`` is N of the complete
    dataset when ``data`` is itself already a sample of something larger
    (IDEBench-style scale-up).

    The input ``columns`` list is left untouched; the returned synopsis
    carries copies with per-column null counts filled in.
    """
    params = params or BuildParams()
    ct = data if isinstance(data, CompressedTable) else None
    if ct is not None:
        n_input = ct.n_rows
        d = ct.d
        if seed_edges is None and params.seed_from_bases:
            seed_edges = GreedyGD.seed_edges(ct)
    else:
        data = np.asarray(data, np.float64)
        n_input = int(data.shape[0])
        d = data.shape[1]
    n_total = n_input if n_rows_full is None else int(n_rows_full)
    if len(columns) != d:
        raise ValueError("columns metadata must match data width")
    # The timeline is always-on: construction is host-orchestrated with a
    # handful of device launches, so recording costs a few dict appends
    # against seconds of build — not worth a knob.
    timeline = BuildTimeline()

    # --- 1. sample ---------------------------------------------------------
    with timeline.phase("sample", n_rows=n_input, d=d):
        n_s = min(params.n_samples, n_input)
        if n_s < n_input:
            rng = np.random.default_rng(params.seed)
            rows = rng.choice(n_input, size=n_s, replace=False)
        else:
            rows = None
        if ct is not None:
            sample = decompress_rows(ct, rows)
        else:
            sample = data if rows is None else data[rows]
        m_pts = max(2, int(round(params.m_frac * n_s)))
        n_take = max(2, math.ceil(n_s / m_pts))
        s_max = max(params.s1_max, params.s2_max)
        crit_np = chi2lib.build_crit_table(params.alpha, s_max)
        crit = jnp.asarray(crit_np)
        crit1 = crit[: params.s1_max + 1]
        crit2 = crit[: params.s2_max + 1]

    # --- 2. one-dimensional histograms (vmapped across columns) ------------
    K1 = params.k1_cap
    with timeline.phase("refine_1d", d=d):
        xs_all, up_all, nv_all, vmin_all, vmax_all = _prep_columns(sample)
        columns = [dataclasses.replace(c, n_null=int(n_s - nv_all[i]))
                   for i, c in enumerate(columns)]
        e0_all = np.empty((d, K1 + 1), np.float64)
        n0_all = np.empty((d,), np.int32)
        mu_all = np.array([c.mu for c in columns], np.float64)
        for i in range(d):
            seed = None if seed_edges is None else seed_edges[i]
            if columns[i].kind == "categorical" and \
                    0 < len(columns[i].categories) <= max(n_take, 4):
                # One bin per category: categorical codes with near-equal
                # frequencies look "uniform" to the chi-squared test and would
                # otherwise never split, destroying groupwise discrimination.
                # (GD-bases seeding achieves the same: each category is a
                # base.) Half-integer edges isolate every code incl. the
                # last two.
                seed = np.arange(len(columns[i].categories) - 1) + 0.5
            e0_all[i], n0_all[i] = _init_edges(vmin_all[i], vmax_all[i], K1,
                                               n_take, seed)

        refine_v = jax.vmap(
            lambda xs, up, e0, n0: refine.refine_1d(
                xs, up, e0, n0, jnp.float64(m_pts), crit1,
                s_max=params.s1_max, max_rounds=params.max_rounds_1d))
        edges_j, k_j = refine_v(jnp.asarray(xs_all), jnp.asarray(up_all),
                                jnp.asarray(e0_all), jnp.asarray(n0_all))

        meta_v = jax.vmap(
            lambda xs, up, e, k, mu: refine.metadata_1d(
                xs, up, e, k, jnp.float64(m_pts), crit1, mu,
                s_max=params.s1_max))
        h_j, u_j, vmin_j, vmax_j, c_j, cm_j, cp_j = meta_v(
            jnp.asarray(xs_all), jnp.asarray(up_all), edges_j, k_j,
            jnp.asarray(mu_all))

        edges_np = np.asarray(edges_j)
        k_np = np.asarray(k_j)
        hists: list[Hist1D] = []
        for i in range(d):
            k = int(k_np[i])
            hists.append(Hist1D(
                edges=edges_np[i, : k + 1].copy(),
                k=np.int32(k),
                h=np.asarray(h_j)[i, :k].copy(),
                u=np.asarray(u_j)[i, :k].copy(),
                vmin=np.asarray(vmin_j)[i, :k].copy(),
                vmax=np.asarray(vmax_j)[i, :k].copy(),
                c=np.asarray(c_j)[i, :k].copy(),
                cminus=np.asarray(cm_j)[i, :k].copy(),
                cplus=np.asarray(cp_j)[i, :k].copy(),
            ))

    # --- 3. pair histograms (batched across pairs) -------------------------
    t_pairs = time.perf_counter()
    build_stats: dict = {}
    with timeline.phase("pair_phase"):
        if params.pair_batched and params.compact_drain:
            mode = "compact"
            raw_pairs = build_pairs_compact(sample, hists, params, crit2,
                                            m_pts, stats=build_stats,
                                            timeline=timeline)
        elif params.pair_batched:
            mode = "batched"
            raw_pairs = build_pairs_batched(sample, hists, params, crit2,
                                            m_pts, stats=build_stats,
                                            timeline=timeline)
        else:
            mode = "sequential"
            raw_pairs = build_pairs_sequential(sample, hists, params, crit2,
                                               m_pts)
    build_stats.update({
        "mode": mode,
        "n_pairs": len(raw_pairs),
        "pair_phase_s": time.perf_counter() - t_pairs,
        "pair_chunk": params.pair_chunk,
        "from_compressed": ct is not None,
    })
    if ct is not None:
        build_stats["rows_decoded"] = int(n_s)

    # --- 4. refine 1-D grids to the union of their pairs' edge sets --------
    # Aggregation runs on the 1-D grid (Table 3); without this, a uniform
    # aggregation column would collapse to one bin and every conditional
    # AVG/SUM would see only the global midpoint. The union grid preserves
    # the 2-D refinement (this is what the paper's per-dimension 2-D bin
    # metadata, Fig. 4, buys). Fold maps: 1-D bin -> containing pair row.
    pairs: dict[tuple[int, int], PairHist] = {}
    t_regrid = time.perf_counter()
    for i in range(d):
        union = [hists[i].edges]
        for (a, b), pr in raw_pairs.items():
            if a == i:
                union.append(pr.ex)
            elif b == i:
                union.append(pr.ey)
        edges_u = np.unique(np.concatenate(union))
        edges_u = edges_u[np.isfinite(edges_u)]
        if edges_u.size > K1 + 1:  # capacity: thin uniformly, keep extremes
            idx = np.linspace(0, edges_u.size - 1, K1 + 1).round().astype(int)
            edges_u = edges_u[np.unique(idx)]
        e_pad = np.full(K1 + 1, np.inf)
        e_pad[: edges_u.size] = edges_u
        k_u = edges_u.size - 1
        h_u, u_u, vmin_u, vmax_u, c_u, cm_u, cp_u = refine.metadata_1d(
            jnp.asarray(xs_all[i]), jnp.asarray(up_all[i]),
            jnp.asarray(e_pad), jnp.int32(k_u), jnp.float64(m_pts), crit1,
            jnp.float64(mu_all[i]), s_max=params.s1_max)
        hists[i] = Hist1D(
            edges=edges_u.copy(), k=np.int32(k_u),
            h=np.asarray(h_u)[:k_u].copy(), u=np.asarray(u_u)[:k_u].copy(),
            vmin=np.asarray(vmin_u)[:k_u].copy(),
            vmax=np.asarray(vmax_u)[:k_u].copy(),
            c=np.asarray(c_u)[:k_u].copy(),
            cminus=np.asarray(cm_u)[:k_u].copy(),
            cplus=np.asarray(cp_u)[:k_u].copy())

    timeline.add("union_regrid", t_regrid, time.perf_counter(), d=d)

    with timeline.phase("folds", n_pairs=len(raw_pairs)):
        for (a, b), pr in raw_pairs.items():
            pairs[(a, b)] = pr._replace(
                fold_x=fold_to_rows(hists[a].edges, pr.ex),
                fold_y=fold_to_rows(hists[b].edges, pr.ey))

    build_stats["timeline"] = timeline.events
    build_stats["phase_s"] = timeline.summary()

    return PairwiseHist(
        params=params,
        n_rows=n_total,
        n_sampled=n_s,
        columns=columns,
        hists=hists,
        pairs=pairs,
        chi2_table=crit_np,
        build_stats=build_stats,
    )
