"""BuildPairwiseHist (Algorithm 1), level-synchronous TPU adaptation.

Pipeline:
  1. downsample the (pre-processed, integer-domain) dataset to N_s rows;
  2. all columns at once: one ``np.sort(axis=0)`` + vectorized unique-prefix,
     then ``refine_1d`` (vmapped across all columns — one kernel refines
     every column's histogram);
  3. pair-batched 2-D refinement: the d(d-1)/2 pairs stack into (P, N_s)
     tensors in chunks of ``BuildParams.pair_chunk`` (bucketed to powers of
     two so jit compiles a bounded set of shapes), ONE ``lax.while_loop``
     refines every pair of a chunk level-synchronously
     (``refine.build_pairs_device``), and each chunk's results arrive in a
     single grouped device->host transfer — no per-pair ``int(kx)`` /
     ``np.asarray`` round-trips. The per-round bin-index + cell-count inner
     loop dispatches through ``repro.kernels.hist2d.batched_hist2d``
     (Pallas one-hot matmuls when ``params.use_pallas``; dtype-preserving
     jnp oracle otherwise). The legacy per-pair host loop survives as
     ``build_pairs_sequential`` (oracle + benchmark baseline; bit-for-bit
     equal results, asserted in tests/test_build_batched.py).

Missing values (NaN) are excluded per-histogram: a row missing column i does
not contribute to hist(i) nor to any pair involving i — matching SQL
semantics (aggregates ignore NULL, comparisons with NULL are false).

``build_pairwise_hist`` never mutates its inputs: per-column null counts are
attached to *copies* of the caller's ``ColumnInfo`` objects (the synopsis
owns its own column list).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chi2 as chi2lib
from repro.core import refine
from repro.core.types import BuildParams, ColumnInfo, Hist1D, PairHist, PairwiseHist

def _prep_columns(sample: np.ndarray):
    """Sort all columns at once with NaN (missing) pushed to +inf at the tail.

    One ``np.sort(axis=0)`` over the (N, d) sample plus a vectorized
    unique-prefix replaces the former Python loop of d per-column sorts.
    Returns (xs_all (d, N), uprefix_all (d, N+1), n_valid (d,), vmin (d,),
    vmax (d,)).
    """
    x = np.asarray(sample, np.float64).copy()
    n, d = x.shape
    nan = np.isnan(x)
    x[nan] = np.inf
    xs = np.sort(x, axis=0)                       # (N, d)
    n_valid = (n - nan.sum(axis=0)).astype(np.int64)
    new = np.empty((n, d), bool)
    new[0] = True
    new[1:] = xs[1:] != xs[:-1]
    up = np.zeros((n + 1, d), np.int64)
    np.cumsum(new, axis=0, out=up[1:])
    has = n_valid > 0
    vmin = np.where(has, xs[0], 0.0)
    vmax = np.where(has, xs[np.maximum(n_valid - 1, 0), np.arange(d)], 0.0)
    return (np.ascontiguousarray(xs.T), np.ascontiguousarray(up.T),
            n_valid, vmin, vmax)


def fold_to_rows(edges_1d: np.ndarray, edges_pair: np.ndarray) -> np.ndarray:
    """Map each 1-D (union-grid) bin to the pair row containing it.

    Pair edges are a subset of the union grid, so containment is exact.
    """
    k1 = edges_1d.size - 1
    mids = 0.5 * (edges_1d[:-1] + edges_1d[1:])
    idx = np.searchsorted(edges_pair, mids, side="right") - 1
    return np.clip(idx, 0, max(edges_pair.size - 2, 0)).astype(np.int32)


def _init_edges(vmin: float, vmax: float, cap: int, n_take: int,
                seed_edges=None) -> tuple[np.ndarray, int]:
    """Initial bin edges: GD bases (downsampled to ceil(N_s/M)) or min/max."""
    if seed_edges is not None and len(seed_edges) > 2:
        e = np.unique(np.asarray(seed_edges, np.float64))
        e = e[(e > vmin) & (e < vmax)]
        if e.size > max(n_take - 2, 0):
            idx = np.linspace(0, e.size - 1, max(n_take - 2, 0)).round().astype(int)
            e = e[np.unique(idx)] if idx.size else e[:0]
        edges = np.concatenate([[vmin], e, [vmax]])
    else:
        edges = np.array([vmin, vmax], np.float64)
    edges = np.unique(edges)
    if edges.size == 1:  # constant column: single zero-width bin
        edges = np.array([edges[0], edges[0]], np.float64)
    edges = edges[: cap + 1]
    n_bins = edges.size - 1
    out = np.full(cap + 1, np.inf, np.float64)
    out[: edges.size] = edges
    return out, n_bins


def _pad_edges(e: np.ndarray, cap: int) -> np.ndarray:
    out = np.full(cap + 1, np.inf, np.float64)
    out[: min(e.size, cap + 1)] = e[: cap + 1]
    return out


def _pair_keys(d: int) -> list[tuple[int, int]]:
    """Pair keys (a, b), a < b, in the legacy loop's emission order."""
    return [(j, i) for i in range(1, d) for j in range(i)]


def _trim_pair(ex, ey, kx, ky, H, hx, ux, vminx, vmaxx, hy, uy, vminy,
               vmaxy) -> PairHist:
    """Trim one pair's fixed-capacity (host) arrays to its valid bins."""
    nkx, nky = int(kx), int(ky)
    return PairHist(
        ex=ex[: nkx + 1].copy(), ey=ey[: nky + 1].copy(),
        kx=np.int32(nkx), ky=np.int32(nky),
        H=H[:nkx, :nky].copy(),
        hx=hx[:nkx].copy(), ux=ux[:nkx].copy(),
        vminx=vminx[:nkx].copy(), vmaxx=vmaxx[:nkx].copy(),
        hy=hy[:nky].copy(), uy=uy[:nky].copy(),
        vminy=vminy[:nky].copy(), vmaxy=vmaxy[:nky].copy(),
        fold_x=np.zeros(0, np.int32), fold_y=np.zeros(0, np.int32),
    )


def build_pairs_sequential(sample: np.ndarray, hists: list, params,
                           crit2, m_pts: int) -> dict:
    """Legacy per-pair host loop (one compiled function, P sequential
    launches with a blocking device->host sync per pair).

    Kept as the bit-for-bit oracle for the batched path and as the
    benchmark baseline. Returns {(a, b): PairHist} without fold maps.
    """
    K2 = params.k2_cap
    sample_j = jnp.asarray(np.nan_to_num(sample, nan=0.0))
    nanmask = np.isnan(sample)
    raw_pairs = {}
    for a, b in _pair_keys(sample.shape[1]):
        valid = jnp.asarray(~(nanmask[:, a] | nanmask[:, b]))
        ex0 = jnp.asarray(_pad_edges(hists[a].edges, K2))
        ey0 = jnp.asarray(_pad_edges(hists[b].edges, K2))
        kx0 = jnp.int32(min(int(hists[a].k), K2))
        ky0 = jnp.int32(min(int(hists[b].k), K2))
        x = sample_j[:, a]
        y = sample_j[:, b]
        ex, ey, kx, ky = refine.refine_2d(
            x, y, valid, ex0, ey0, kx0, ky0, jnp.float64(m_pts), crit2,
            k2=K2, s_max=params.s2_max, max_rounds=params.max_rounds_2d)
        out = refine.pair_metadata(x, y, valid, ex, ey, kx, ky, k2=K2)
        raw_pairs[(a, b)] = _trim_pair(
            *(np.asarray(v) for v in (ex, ey, kx, ky) + tuple(out)))
    return raw_pairs


def _presort_pairs_host(x, y, valid):
    """Host-side ``refine.presort_pairs`` (numpy's sort beats XLA:CPU's).

    Same layout and same (stable lexsort) semantics; done once per chunk —
    the per-round unique counts then need no sort at all.
    """
    n_pairs, n = x.shape
    xo1 = np.empty_like(x)
    yo1 = np.empty_like(y)
    vo1 = np.empty_like(valid)
    xo2 = np.empty_like(x)
    yo2 = np.empty_like(y)
    vo2 = np.empty_like(valid)
    for p in range(n_pairs):
        kx = np.where(valid[p], x[p], np.inf)
        ky = np.where(valid[p], y[p], np.inf)
        o1 = np.lexsort((ky, kx))
        o2 = np.lexsort((kx, ky))
        xo1[p], yo1[p], vo1[p] = x[p][o1], y[p][o1], valid[p][o1]
        xo2[p], yo2[p], vo2[p] = x[p][o2], y[p][o2], valid[p][o2]
    new1 = np.empty((n_pairs, n), bool)
    new1[:, 0] = True
    new1[:, 1:] = xo1[:, 1:] != xo1[:, :-1]
    new2 = np.empty((n_pairs, n), bool)
    new2[:, 0] = True
    new2[:, 1:] = yo2[:, 1:] != yo2[:, :-1]
    return xo1, yo1, vo1, new1, xo2, yo2, vo2, new2


def _cap_ladder(need: int, k2_cap: int, k2_start: int) -> list[int]:
    """Doubling capacity ladder: smallest rung fitting ``need`` up to k2_cap."""
    c = max(2, k2_start)
    while c < need:
        c *= 2
    c = min(c, k2_cap)
    ladder = [c]
    while c < k2_cap:
        c = min(c * 2, k2_cap)
        ladder.append(c)
    return ladder


def build_pairs_batched(sample: np.ndarray, hists: list, params,
                        crit2, m_pts: int, stats: dict | None = None) -> dict:
    """Pair-batched 2-D construction: chunked (P, N) launches, one grouped
    device->host transfer per chunk. Returns {(a, b): PairHist} (no folds);
    records per-chunk (size, capacity) launches into ``stats``.

    Each chunk refines at the smallest capacity rung that fits its initial
    grids; if any pair's capacity guard binds, the whole chunk re-runs one
    rung up (results are capacity-independent while the guard is slack, so
    this is exact — and saturation is the rare case by design).
    """
    K2 = params.k2_cap
    n_s, d = sample.shape
    keys = _pair_keys(d)
    sample_nn = np.nan_to_num(sample, nan=0.0)
    nanmask = np.isnan(sample)
    # Normalize the chunk cap to a power of two — rounding DOWN, so the
    # documented memory bound (~ pair_chunk * k2^2 * s2_max) is honoured;
    # the tail chunk buckets to the next power of two >= its size, so jit
    # sees at most log2(chunk) + 1 distinct batch shapes per capacity rung.
    chunk = 1 << (max(1, int(params.pair_chunk)).bit_length() - 1)
    launches = []
    raw_pairs = {}
    for start in range(0, len(keys), chunk):
        part = keys[start:start + chunk]
        size = 1 << max(0, len(part) - 1).bit_length()
        x = np.zeros((size, n_s), np.float64)
        y = np.zeros((size, n_s), np.float64)
        valid = np.zeros((size, n_s), bool)
        kx0 = np.ones(size, np.int32)
        ky0 = np.ones(size, np.int32)
        for p, (a, b) in enumerate(part):
            x[p] = sample_nn[:, a]
            y[p] = sample_nn[:, b]
            valid[p] = ~(nanmask[:, a] | nanmask[:, b])
            kx0[p] = min(int(hists[a].k), K2)
            ky0[p] = min(int(hists[b].k), K2)
        pres_j = tuple(jnp.asarray(a) for a in
                       _presort_pairs_host(x, y, valid))
        need = int(max(kx0.max(), ky0.max()))
        for cap in _cap_ladder(need, K2, params.k2_start):
            ex0 = np.full((size, cap + 1), np.inf, np.float64)
            ey0 = np.full((size, cap + 1), np.inf, np.float64)
            ex0[:, :2] = 0.0
            ey0[:, :2] = 0.0  # dummy lanes: one empty bin, no valid rows
            for p, (a, b) in enumerate(part):
                ex0[p] = _pad_edges(hists[a].edges, cap)
                ey0[p] = _pad_edges(hists[b].edges, cap)
            out = refine.build_pairs_device(
                *pres_j, jnp.asarray(ex0), jnp.asarray(ey0),
                jnp.asarray(kx0), jnp.asarray(ky0),
                jnp.float64(m_pts), crit2, k2=cap, s_max=params.s2_max,
                max_rounds=params.max_rounds_2d,
                use_pallas=params.use_pallas)
            host = jax.device_get(out)  # ONE grouped transfer for the chunk
            launches.append((size, cap))
            capped = host[4]
            if cap >= K2 or not capped[: len(part)].any():
                break
        fields = host[:4] + host[5:]    # drop the capped flag
        for p, (a, b) in enumerate(part):
            raw_pairs[(a, b)] = _trim_pair(*(v[p] for v in fields))
    if stats is not None:
        stats["pair_launches"] = launches
    return raw_pairs


def build_pairwise_hist(
    data: np.ndarray,
    columns: list[ColumnInfo],
    params: BuildParams | None = None,
    n_rows_full: int | None = None,
    seed_edges: list | None = None,
) -> PairwiseHist:
    """Construct the synopsis from a pre-processed (N, d) float64 matrix.

    ``data`` is in the *pre-processed* (GD) domain: non-negative integers as
    f64, NaN for missing. ``seed_edges`` (optional) are per-column initial
    edge candidates — typically reconstructed GreedyGD bases (§3).
    ``n_rows_full`` is N of the complete dataset when ``data`` is itself
    already a sample of something larger (IDEBench-style scale-up).

    The input ``columns`` list is left untouched; the returned synopsis
    carries copies with per-column null counts filled in.
    """
    params = params or BuildParams()
    data = np.asarray(data, np.float64)
    n_total = int(data.shape[0]) if n_rows_full is None else int(n_rows_full)
    d = data.shape[1]
    if len(columns) != d:
        raise ValueError("columns metadata must match data width")

    # --- 1. sample ---------------------------------------------------------
    n_s = min(params.n_samples, data.shape[0])
    if n_s < data.shape[0]:
        rng = np.random.default_rng(params.seed)
        rows = rng.choice(data.shape[0], size=n_s, replace=False)
        sample = data[rows]
    else:
        sample = data
    m_pts = max(2, int(round(params.m_frac * n_s)))
    n_take = max(2, math.ceil(n_s / m_pts))
    s_max = max(params.s1_max, params.s2_max)
    crit_np = chi2lib.build_crit_table(params.alpha, s_max)
    crit = jnp.asarray(crit_np)
    crit1 = crit[: params.s1_max + 1]
    crit2 = crit[: params.s2_max + 1]

    # --- 2. one-dimensional histograms (vmapped across columns) ------------
    K1 = params.k1_cap
    xs_all, up_all, nv_all, vmin_all, vmax_all = _prep_columns(sample)
    columns = [dataclasses.replace(c, n_null=int(n_s - nv_all[i]))
               for i, c in enumerate(columns)]
    e0_all = np.empty((d, K1 + 1), np.float64)
    n0_all = np.empty((d,), np.int32)
    mu_all = np.array([c.mu for c in columns], np.float64)
    for i in range(d):
        seed = None if seed_edges is None else seed_edges[i]
        if columns[i].kind == "categorical" and \
                0 < len(columns[i].categories) <= max(n_take, 4):
            # One bin per category: categorical codes with near-equal
            # frequencies look "uniform" to the chi-squared test and would
            # otherwise never split, destroying groupwise discrimination.
            # (GD-bases seeding achieves the same: each category is a base.)
            # Half-integer edges isolate every code incl. the last two.
            seed = np.arange(len(columns[i].categories) - 1) + 0.5
        e0_all[i], n0_all[i] = _init_edges(vmin_all[i], vmax_all[i], K1,
                                           n_take, seed)

    refine_v = jax.vmap(
        lambda xs, up, e0, n0: refine.refine_1d(
            xs, up, e0, n0, jnp.float64(m_pts), crit1,
            s_max=params.s1_max, max_rounds=params.max_rounds_1d))
    edges_j, k_j = refine_v(jnp.asarray(xs_all), jnp.asarray(up_all),
                            jnp.asarray(e0_all), jnp.asarray(n0_all))

    meta_v = jax.vmap(
        lambda xs, up, e, k, mu: refine.metadata_1d(
            xs, up, e, k, jnp.float64(m_pts), crit1, mu,
            s_max=params.s1_max))
    h_j, u_j, vmin_j, vmax_j, c_j, cm_j, cp_j = meta_v(
        jnp.asarray(xs_all), jnp.asarray(up_all), edges_j, k_j,
        jnp.asarray(mu_all))

    edges_np = np.asarray(edges_j)
    k_np = np.asarray(k_j)
    hists: list[Hist1D] = []
    for i in range(d):
        k = int(k_np[i])
        hists.append(Hist1D(
            edges=edges_np[i, : k + 1].copy(),
            k=np.int32(k),
            h=np.asarray(h_j)[i, :k].copy(),
            u=np.asarray(u_j)[i, :k].copy(),
            vmin=np.asarray(vmin_j)[i, :k].copy(),
            vmax=np.asarray(vmax_j)[i, :k].copy(),
            c=np.asarray(c_j)[i, :k].copy(),
            cminus=np.asarray(cm_j)[i, :k].copy(),
            cplus=np.asarray(cp_j)[i, :k].copy(),
        ))

    # --- 3. pair histograms (batched across pairs) -------------------------
    t_pairs = time.perf_counter()
    build_stats: dict = {}
    if params.pair_batched:
        raw_pairs = build_pairs_batched(sample, hists, params, crit2, m_pts,
                                        stats=build_stats)
    else:
        raw_pairs = build_pairs_sequential(sample, hists, params, crit2,
                                           m_pts)
    build_stats.update({
        "mode": "batched" if params.pair_batched else "sequential",
        "n_pairs": len(raw_pairs),
        "pair_phase_s": time.perf_counter() - t_pairs,
        "pair_chunk": params.pair_chunk,
    })

    # --- 4. refine 1-D grids to the union of their pairs' edge sets --------
    # Aggregation runs on the 1-D grid (Table 3); without this, a uniform
    # aggregation column would collapse to one bin and every conditional
    # AVG/SUM would see only the global midpoint. The union grid preserves
    # the 2-D refinement (this is what the paper's per-dimension 2-D bin
    # metadata, Fig. 4, buys). Fold maps: 1-D bin -> containing pair row.
    pairs: dict[tuple[int, int], PairHist] = {}
    for i in range(d):
        union = [hists[i].edges]
        for (a, b), pr in raw_pairs.items():
            if a == i:
                union.append(pr.ex)
            elif b == i:
                union.append(pr.ey)
        edges_u = np.unique(np.concatenate(union))
        edges_u = edges_u[np.isfinite(edges_u)]
        if edges_u.size > K1 + 1:  # capacity: thin uniformly, keep extremes
            idx = np.linspace(0, edges_u.size - 1, K1 + 1).round().astype(int)
            edges_u = edges_u[np.unique(idx)]
        e_pad = np.full(K1 + 1, np.inf)
        e_pad[: edges_u.size] = edges_u
        k_u = edges_u.size - 1
        h_u, u_u, vmin_u, vmax_u, c_u, cm_u, cp_u = refine.metadata_1d(
            jnp.asarray(xs_all[i]), jnp.asarray(up_all[i]),
            jnp.asarray(e_pad), jnp.int32(k_u), jnp.float64(m_pts), crit1,
            jnp.float64(mu_all[i]), s_max=params.s1_max)
        hists[i] = Hist1D(
            edges=edges_u.copy(), k=np.int32(k_u),
            h=np.asarray(h_u)[:k_u].copy(), u=np.asarray(u_u)[:k_u].copy(),
            vmin=np.asarray(vmin_u)[:k_u].copy(),
            vmax=np.asarray(vmax_u)[:k_u].copy(),
            c=np.asarray(c_u)[:k_u].copy(),
            cminus=np.asarray(cm_u)[:k_u].copy(),
            cplus=np.asarray(cp_u)[:k_u].copy())

    for (a, b), pr in raw_pairs.items():
        pairs[(a, b)] = pr._replace(
            fold_x=fold_to_rows(hists[a].edges, pr.ex),
            fold_y=fold_to_rows(hists[b].edges, pr.ey))

    return PairwiseHist(
        params=params,
        n_rows=n_total,
        n_sampled=n_s,
        columns=columns,
        hists=hists,
        pairs=pairs,
        chi2_table=crit_np,
        build_stats=build_stats,
    )
