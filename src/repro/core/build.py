"""BuildPairwiseHist (Algorithm 1), level-synchronous TPU adaptation.

Pipeline:
  1. downsample the (pre-processed, integer-domain) dataset to N_s rows;
  2. per column: sort once, prefix-unique once, then `refine_1d` (vmapped
     across all columns — one kernel refines every column's histogram);
  3. per column pair: `refine_2d` + `pair_metadata` (host loop re-using one
     compiled function; all pairs share shapes).

Missing values (NaN) are excluded per-histogram: a row missing column i does
not contribute to hist(i) nor to any pair involving i — matching SQL
semantics (aggregates ignore NULL, comparisons with NULL are false).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chi2 as chi2lib
from repro.core import refine
from repro.core.types import BuildParams, ColumnInfo, Hist1D, PairHist, PairwiseHist


def _prep_column(col_vals: np.ndarray):
    """Sort one column with NaN (missing) pushed to +inf at the tail.

    Returns (sorted values, unique-prefix array, n_valid, vmin, vmax).
    """
    x = np.asarray(col_vals, np.float64).copy()
    nan = np.isnan(x)
    x[nan] = np.inf
    xs = np.sort(x)
    n_valid = int(x.size - nan.sum())
    new = np.empty(x.size, bool)
    new[0] = True
    new[1:] = xs[1:] != xs[:-1]
    uprefix = np.concatenate([[0], np.cumsum(new)]).astype(np.int64)
    if n_valid == 0:
        return xs, uprefix, 0, 0.0, 0.0
    return xs, uprefix, n_valid, float(xs[0]), float(xs[n_valid - 1])


def fold_to_rows(edges_1d: np.ndarray, edges_pair: np.ndarray) -> np.ndarray:
    """Map each 1-D (union-grid) bin to the pair row containing it.

    Pair edges are a subset of the union grid, so containment is exact.
    """
    k1 = edges_1d.size - 1
    mids = 0.5 * (edges_1d[:-1] + edges_1d[1:])
    idx = np.searchsorted(edges_pair, mids, side="right") - 1
    return np.clip(idx, 0, max(edges_pair.size - 2, 0)).astype(np.int32)


def _init_edges(vmin: float, vmax: float, cap: int, n_take: int,
                seed_edges=None) -> tuple[np.ndarray, int]:
    """Initial bin edges: GD bases (downsampled to ceil(N_s/M)) or min/max."""
    if seed_edges is not None and len(seed_edges) > 2:
        e = np.unique(np.asarray(seed_edges, np.float64))
        e = e[(e > vmin) & (e < vmax)]
        if e.size > max(n_take - 2, 0):
            idx = np.linspace(0, e.size - 1, max(n_take - 2, 0)).round().astype(int)
            e = e[np.unique(idx)] if idx.size else e[:0]
        edges = np.concatenate([[vmin], e, [vmax]])
    else:
        edges = np.array([vmin, vmax], np.float64)
    edges = np.unique(edges)
    if edges.size == 1:  # constant column: single zero-width bin
        edges = np.array([edges[0], edges[0]], np.float64)
    edges = edges[: cap + 1]
    n_bins = edges.size - 1
    out = np.full(cap + 1, np.inf, np.float64)
    out[: edges.size] = edges
    return out, n_bins


def build_pairwise_hist(
    data: np.ndarray,
    columns: list[ColumnInfo],
    params: BuildParams | None = None,
    n_rows_full: int | None = None,
    seed_edges: list | None = None,
) -> PairwiseHist:
    """Construct the synopsis from a pre-processed (N, d) float64 matrix.

    ``data`` is in the *pre-processed* (GD) domain: non-negative integers as
    f64, NaN for missing. ``seed_edges`` (optional) are per-column initial
    edge candidates — typically reconstructed GreedyGD bases (§3).
    ``n_rows_full`` is N of the complete dataset when ``data`` is itself
    already a sample of something larger (IDEBench-style scale-up).
    """
    params = params or BuildParams()
    data = np.asarray(data, np.float64)
    n_total = int(data.shape[0]) if n_rows_full is None else int(n_rows_full)
    d = data.shape[1]
    if len(columns) != d:
        raise ValueError("columns metadata must match data width")

    # --- 1. sample ---------------------------------------------------------
    n_s = min(params.n_samples, data.shape[0])
    if n_s < data.shape[0]:
        rng = np.random.default_rng(params.seed)
        rows = rng.choice(data.shape[0], size=n_s, replace=False)
        sample = data[rows]
    else:
        sample = data
    m_pts = max(2, int(round(params.m_frac * n_s)))
    n_take = max(2, math.ceil(n_s / m_pts))
    s_max = max(params.s1_max, params.s2_max)
    crit_np = chi2lib.build_crit_table(params.alpha, s_max)
    crit = jnp.asarray(crit_np)
    crit1 = crit[: params.s1_max + 1]
    crit2 = crit[: params.s2_max + 1]

    # --- 2. one-dimensional histograms (vmapped across columns) ------------
    K1 = params.k1_cap
    xs_all = np.empty((d, n_s), np.float64)
    up_all = np.empty((d, n_s + 1), np.int64)
    e0_all = np.empty((d, K1 + 1), np.float64)
    n0_all = np.empty((d,), np.int32)
    mu_all = np.array([c.mu for c in columns], np.float64)
    for i in range(d):
        xs, up, n_valid, vmin, vmax = _prep_column(sample[:, i])
        xs_all[i], up_all[i] = xs, up
        seed = None if seed_edges is None else seed_edges[i]
        if columns[i].kind == "categorical" and \
                0 < len(columns[i].categories) <= max(n_take, 4):
            # One bin per category: categorical codes with near-equal
            # frequencies look "uniform" to the chi-squared test and would
            # otherwise never split, destroying groupwise discrimination.
            # (GD-bases seeding achieves the same: each category is a base.)
            # Half-integer edges isolate every code incl. the last two.
            seed = np.arange(len(columns[i].categories) - 1) + 0.5
        e0_all[i], n0_all[i] = _init_edges(vmin, vmax, K1, n_take, seed)
        columns[i].n_null = n_s - n_valid

    refine_v = jax.vmap(
        lambda xs, up, e0, n0: refine.refine_1d(
            xs, up, e0, n0, jnp.float64(m_pts), crit1,
            s_max=params.s1_max, max_rounds=params.max_rounds_1d))
    edges_j, k_j = refine_v(jnp.asarray(xs_all), jnp.asarray(up_all),
                            jnp.asarray(e0_all), jnp.asarray(n0_all))

    meta_v = jax.vmap(
        lambda xs, up, e, k, mu: refine.metadata_1d(
            xs, up, e, k, jnp.float64(m_pts), crit1, mu,
            s_max=params.s1_max))
    h_j, u_j, vmin_j, vmax_j, c_j, cm_j, cp_j = meta_v(
        jnp.asarray(xs_all), jnp.asarray(up_all), edges_j, k_j,
        jnp.asarray(mu_all))

    edges_np = np.asarray(edges_j)
    k_np = np.asarray(k_j)
    hists: list[Hist1D] = []
    for i in range(d):
        k = int(k_np[i])
        hists.append(Hist1D(
            edges=edges_np[i, : k + 1].copy(),
            k=np.int32(k),
            h=np.asarray(h_j)[i, :k].copy(),
            u=np.asarray(u_j)[i, :k].copy(),
            vmin=np.asarray(vmin_j)[i, :k].copy(),
            vmax=np.asarray(vmax_j)[i, :k].copy(),
            c=np.asarray(c_j)[i, :k].copy(),
            cminus=np.asarray(cm_j)[i, :k].copy(),
            cplus=np.asarray(cp_j)[i, :k].copy(),
        ))

    # --- 3. pair histograms -------------------------------------------------
    K2 = params.k2_cap
    pairs: dict[tuple[int, int], PairHist] = {}
    sample_j = jnp.asarray(np.nan_to_num(sample, nan=0.0))
    nanmask = np.isnan(sample)

    def pad_edges(e: np.ndarray) -> np.ndarray:
        out = np.full(K2 + 1, np.inf, np.float64)
        out[: min(e.size, K2 + 1)] = e[: K2 + 1]
        return out

    raw_pairs = {}
    for i in range(d):
        for j in range(i):
            # pair key (j, i): x-dim = lower column index for determinism
            a, b = j, i
            valid = jnp.asarray(~(nanmask[:, a] | nanmask[:, b]))
            ex0 = jnp.asarray(pad_edges(hists[a].edges))
            ey0 = jnp.asarray(pad_edges(hists[b].edges))
            kx0 = jnp.int32(min(int(hists[a].k), K2))
            ky0 = jnp.int32(min(int(hists[b].k), K2))
            x = sample_j[:, a]
            y = sample_j[:, b]
            ex, ey, kx, ky = refine.refine_2d(
                x, y, valid, ex0, ey0, kx0, ky0, jnp.float64(m_pts), crit2,
                k2=K2, s_max=params.s2_max, max_rounds=params.max_rounds_2d)
            out = refine.pair_metadata(x, y, valid, ex, ey, kx, ky, k2=K2)
            H, hx, ux, vminx, vmaxx, hy, uy, vminy, vmaxy = out
            nkx, nky = int(kx), int(ky)
            raw_pairs[(a, b)] = PairHist(
                ex=np.asarray(ex)[: nkx + 1].copy(),
                ey=np.asarray(ey)[: nky + 1].copy(),
                kx=np.int32(nkx), ky=np.int32(nky),
                H=np.asarray(H)[:nkx, :nky].copy(),
                hx=np.asarray(hx)[:nkx].copy(), ux=np.asarray(ux)[:nkx].copy(),
                vminx=np.asarray(vminx)[:nkx].copy(),
                vmaxx=np.asarray(vmaxx)[:nkx].copy(),
                hy=np.asarray(hy)[:nky].copy(), uy=np.asarray(uy)[:nky].copy(),
                vminy=np.asarray(vminy)[:nky].copy(),
                vmaxy=np.asarray(vmaxy)[:nky].copy(),
                fold_x=np.zeros(0, np.int32), fold_y=np.zeros(0, np.int32),
            )

    # --- 4. refine 1-D grids to the union of their pairs' edge sets --------
    # Aggregation runs on the 1-D grid (Table 3); without this, a uniform
    # aggregation column would collapse to one bin and every conditional
    # AVG/SUM would see only the global midpoint. The union grid preserves
    # the 2-D refinement (this is what the paper's per-dimension 2-D bin
    # metadata, Fig. 4, buys). Fold maps: 1-D bin -> containing pair row.
    K1 = params.k1_cap
    for i in range(d):
        union = [hists[i].edges]
        for (a, b), pr in raw_pairs.items():
            if a == i:
                union.append(pr.ex)
            elif b == i:
                union.append(pr.ey)
        edges_u = np.unique(np.concatenate(union))
        edges_u = edges_u[np.isfinite(edges_u)]
        if edges_u.size > K1 + 1:  # capacity: thin uniformly, keep extremes
            idx = np.linspace(0, edges_u.size - 1, K1 + 1).round().astype(int)
            edges_u = edges_u[np.unique(idx)]
        e_pad = np.full(K1 + 1, np.inf)
        e_pad[: edges_u.size] = edges_u
        k_u = edges_u.size - 1
        h_u, u_u, vmin_u, vmax_u, c_u, cm_u, cp_u = refine.metadata_1d(
            jnp.asarray(xs_all[i]), jnp.asarray(up_all[i]),
            jnp.asarray(e_pad), jnp.int32(k_u), jnp.float64(m_pts), crit1,
            jnp.float64(mu_all[i]), s_max=params.s1_max)
        hists[i] = Hist1D(
            edges=edges_u.copy(), k=np.int32(k_u),
            h=np.asarray(h_u)[:k_u].copy(), u=np.asarray(u_u)[:k_u].copy(),
            vmin=np.asarray(vmin_u)[:k_u].copy(),
            vmax=np.asarray(vmax_u)[:k_u].copy(),
            c=np.asarray(c_u)[:k_u].copy(),
            cminus=np.asarray(cm_u)[:k_u].copy(),
            cplus=np.asarray(cp_u)[:k_u].copy())

    for (a, b), pr in raw_pairs.items():
        pairs[(a, b)] = pr._replace(
            fold_x=fold_to_rows(hists[a].edges, pr.ex),
            fold_y=fold_to_rows(hists[b].edges, pr.ey))

    return PairwiseHist(
        params=params,
        n_rows=n_total,
        n_sampled=n_s,
        columns=columns,
        hists=hists,
        pairs=pairs,
        chi2_table=crit_np,
    )
