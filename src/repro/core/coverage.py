"""Predicate coverage (§5.2): Eq. 14–16 estimates, Eq. 22–23 bounds.

Coverage beta_t = Pr(P | point in bin t), computed per bin of whichever bin
grid the predicate column uses for the query at hand (the 1-D histogram when
the predicate column *is* the aggregation column, a pair-histogram slice
otherwise — the slice carries the same metadata: h, u, v-, v+).

Functions here are NumPy (they are also the kernel oracle); the fused JAX
path lives in ``repro.core.fastpath``.

Consolidation ("delayed transformation", §5.2): groups of conditions on the
same column directly under one AND/OR are merged into an interval set in a
half-integer domain (integer data with spacing mu) *before* coverage, because
same-column conditions are maximally conditionally dependent (Eq. 28's
independence assumption would be badly violated).
"""
from __future__ import annotations

import math

import numpy as np

_RANGE_OPS = ("<", "<=", ">", ">=")


# ---------------------------------------------------------------------------
# Interval algebra for consolidation (half-open real intervals)
# ---------------------------------------------------------------------------


def cond_to_intervals(op: str, v: float, mu: float):
    """Condition -> list of closed intervals in the half-integer domain."""
    half = 0.5 * mu
    if op == "<":
        return [(-math.inf, v - half)]
    if op == "<=":
        return [(-math.inf, v + half)]
    if op == ">":
        return [(v + half, math.inf)]
    if op == ">=":
        return [(v - half, math.inf)]
    if op == "=":
        return [(v - half, v + half)]
    if op in ("!=", "<>"):
        return [(-math.inf, v - half), (v + half, math.inf)]
    raise ValueError(f"unknown operator {op!r}")


def union_intervals(sets):
    """Union of interval lists -> disjoint sorted list."""
    ivs = sorted(iv for s in sets for iv in s)
    out = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def intersect_intervals(sets):
    """Intersection of interval lists -> disjoint sorted list."""
    cur = sets[0]
    for s in sets[1:]:
        nxt = []
        for a_lo, a_hi in cur:
            for b_lo, b_hi in s:
                lo, hi = max(a_lo, b_lo), min(a_hi, b_hi)
                if lo <= hi:
                    nxt.append((lo, hi))
        cur = sorted(nxt)
    return cur


# ---------------------------------------------------------------------------
# Coverage estimates (Eq. 15 / 16)
# ---------------------------------------------------------------------------


def coverage_single(op, value, h, u, vmin, vmax):
    """Eq. 15 (equality / inequality) and Eq. 16 (range ops), vectorized.

    All bin arrays share shape (k,). Returns beta in [0, 1].
    """
    h = np.asarray(h, float)
    u = np.asarray(u, float)
    vmin = np.asarray(vmin, float)
    vmax = np.asarray(vmax, float)
    inside = (vmin <= value) & (value <= vmax)
    usafe = np.maximum(u, 1.0)
    if op == "=":
        return np.where(inside & (u > 0), 1.0 / usafe, 0.0)
    if op in ("!=", "<>"):
        return np.where(u > 0, 1.0 - np.where(inside, 1.0 / usafe, 0.0), 0.0)
    if op not in _RANGE_OPS:
        raise ValueError(f"unknown operator {op!r}")

    def sat(x):
        if op == "<":
            return x < value
        if op == "<=":
            return x <= value
        if op == ">":
            return x > value
        return x >= value

    lo_ok = sat(vmin)
    hi_ok = sat(vmax)
    width = np.maximum(vmax - vmin, 1e-300)
    if op in ("<", "<="):
        frac = (value - vmin) / width
    else:
        frac = (vmax - value) / width
    frac = np.clip(frac, 0.0, 1.0)
    beta = np.where(
        lo_ok & hi_ok, 1.0,
        np.where(
            ~lo_ok & ~hi_ok, 0.0,
            np.where(u == 2.0, 0.5, frac),
        ),
    )
    return np.where(h > 0, beta, np.where(lo_ok & hi_ok, 1.0, np.where(~lo_ok & ~hi_ok, 0.0, 0.5)))


def coverage_intervals(intervals, h, u, vmin, vmax, mu):
    """Coverage of a disjoint interval set (consolidated same-column group).

    Non-degenerate intervals contribute their overlap fraction of the bin's
    value span (the f_t(P) of Eq. 16); degenerate (single-value, width <= mu)
    intervals contribute 1/u (the Eq. 15 equality rule).
    """
    h = np.asarray(h, float)
    u = np.asarray(u, float)
    vmin = np.asarray(vmin, float)
    vmax = np.asarray(vmax, float)
    usafe = np.maximum(u, 1.0)
    width = np.maximum(vmax - vmin, 1e-300)
    beta = np.zeros_like(h)
    for lo, hi in intervals:
        if hi - lo <= mu * (1 + 1e-9):  # equality point
            v = 0.5 * (lo + hi)
            beta += np.where((vmin <= v) & (v <= vmax), 1.0 / usafe, 0.0)
            continue
        cov_lo = np.maximum(lo, vmin)
        cov_hi = np.minimum(hi, vmax)
        full = (lo <= vmin) & (vmax <= hi)
        none = (cov_hi < cov_lo)
        frac = np.clip((cov_hi - cov_lo) / width, 0.0, 1.0)
        beta += np.where(full, 1.0, np.where(none, 0.0, frac))
    return np.clip(np.where(u > 0, beta, 0.0), 0.0, 1.0)


# ---------------------------------------------------------------------------
# Coverage bounds (Theorem 2 -> Eq. 22 / 23)
# ---------------------------------------------------------------------------


def coverage_bounds(beta, h, u, min_points, crit_table, s_max: int):
    """Lower / upper coverage bounds per Eq. 22–23.

    beta in {0,1}: exact. h < M: [1/h, 1-1/h]. Otherwise the partial-count
    bounds from Theorem 2 with a = floor(beta*s), b = ceil(beta*s).
    """
    beta = np.asarray(beta, float)
    h = np.asarray(h, float)
    u = np.asarray(u, float)
    s = np.clip(np.ceil(np.cbrt(2.0 * np.maximum(u, 0.0))), 1, s_max)
    chi = crit_table[np.clip(s.astype(int), 0, len(crit_table) - 1)]
    chi = np.where(np.isfinite(chi), chi, 0.0)
    hsafe = np.maximum(h, 1.0)

    a = np.floor(beta * s)
    b = np.ceil(beta * s)
    with np.errstate(divide="ignore", invalid="ignore"):
        lo_pass = a / s - (a / s) * np.sqrt(chi * (s - a) / (hsafe * np.maximum(a, 1.0)))
        hi_pass = b / s + (b / s) * np.sqrt(chi * (s - b) / (hsafe * np.maximum(b, 1.0)))
    lo_pass = np.where(a > 0, lo_pass, 0.0)
    hi_pass = np.where(b > 0, hi_pass, 0.0)

    lo_fail = 1.0 / hsafe
    hi_fail = 1.0 - 1.0 / hsafe

    passing = h >= min_points
    lo = np.where(passing, lo_pass, lo_fail)
    hi = np.where(passing, hi_pass, hi_fail)

    exact = (beta <= 0.0) | (beta >= 1.0)
    lo = np.where(exact, beta, lo)
    hi = np.where(exact, beta, hi)
    empty = h <= 0
    lo = np.where(empty, beta, lo)
    hi = np.where(empty, beta, hi)
    lo = np.clip(np.minimum(lo, beta), 0.0, 1.0)
    hi = np.clip(np.maximum(hi, beta), 0.0, 1.0)
    return lo, hi
