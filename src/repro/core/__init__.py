# The paper's primary contribution: the PairwiseHist synopsis and its query
# engine, implemented as composable JAX modules (lax control flow, vmap over
# histograms, pjit-shardable construction).
#
# AQP operates on integer/float64 data domains (post-GD preprocessing values
# can exceed float32's 2^24 integer range), so x64 is enabled at import here.
# The LM stack (repro.models/train/serve/launch) never imports repro.core and
# always uses explicit dtypes, so this flag does not affect it.
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.types import (  # noqa: E402,F401
    Hist1D,
    PairHist,
    PairwiseHist,
    BuildParams,
)
from repro.core.build import build_pairwise_hist  # noqa: E402,F401

# QueryEngine / parse_sql are imported lazily to keep partial builds usable.
def __getattr__(name):  # noqa: D105
    if name == "QueryEngine":
        from repro.core.query import QueryEngine
        return QueryEngine
    if name == "parse_sql":
        from repro.core.sql import parse_sql
        return parse_sql
    raise AttributeError(name)
