"""Minimal SQL subset parser for the AQP query templates (§3, §5.1).

    SELECT F(col | *) FROM table [WHERE expr] [GROUP BY col] [;]

with F in {COUNT, SUM, AVG, MIN, MAX, MEDIAN, VAR}, expr a boolean tree of
``col OP literal`` conditions combined with AND/OR (AND binds tighter) and
parentheses; OP in {=, !=, <>, <, <=, >, >=}; literals are numbers or
single/double-quoted strings.

The parser is domain-agnostic: literals stay raw here; GreedyGD
pre-processing of literals (§5.1) happens in the engine planner where column
metadata lives.
"""
from __future__ import annotations

import dataclasses
import re

AGG_FUNCS = ("COUNT", "SUM", "AVG", "MIN", "MAX", "MEDIAN", "VAR")

_TOKEN_RE = re.compile(
    r"""
        (?P<num>-?\d+\.?\d*(?:[eE][+-]?\d+)?)
      | (?P<str>'[^']*'|"[^"]*")
      | (?P<op><=|>=|!=|<>|=|<|>)
      | (?P<punc>[(),;*])
      | (?P<word>[A-Za-z_][A-Za-z_0-9.]*)
    """,
    re.VERBOSE,
)

_WHITESPACE = " \t\n\r\f\v"


@dataclasses.dataclass
class RawCond:
    col: str
    op: str
    value: object  # float or str


@dataclasses.dataclass
class RawNode:
    kind: str          # "and" | "or"
    children: list


@dataclasses.dataclass
class ParsedQuery:
    func: str          # aggregation function
    agg_col: str       # column name or "*"
    table: str
    where: object      # RawCond | RawNode | None
    group_by: str | None


class SQLError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Literal-stripped shape of a query plus its extracted literal vector.

    ``shape`` is a canonical token string with every literal replaced by
    ``?``; two queries with equal shapes parse to structurally identical
    trees and differ only in the literal values, so a compiled
    ``PlanTemplate`` for one binds the other's literals bit-for-bit.
    ``literals`` holds the stripped values in token order, exactly as the
    parser would have produced them (numbers as float, strings unquoted).
    """

    shape: str
    literals: tuple


def fingerprint_sql(text: str) -> Fingerprint:
    """Tokenize ``text`` into a shape key + literal vector, without parsing.

    Canonicalization is deliberately conservative: whitespace is dropped by
    the tokenizer, a trailing ``;`` is ignored, and the two legal clause
    orders (``WHERE ... GROUP BY c`` vs ``GROUP BY c WHERE ...``) map to
    one shape. Word tokens are kept verbatim (no case folding) — case
    variants get separate templates rather than risking a collision with
    an identifier that shadows a keyword.
    """
    tokens = _tokenize(text)
    if tokens and tokens[-1] == ("punc", ";"):
        tokens = tokens[:-1]
    # Grammar fixes tokens 0..6 as: SELECT f ( col ) FROM table.  When a
    # GROUP BY clause precedes WHERE, swap them so both orders share a
    # shape.  (Malformed inputs just keep their literal token order — they
    # fail identically at parse time either way.)
    if (len(tokens) > 10
            and tokens[7][0] == "word" and tokens[7][1].upper() == "GROUP"
            and tokens[8][0] == "word" and tokens[8][1].upper() == "BY"
            and tokens[9][0] == "word"
            and tokens[10][0] == "word" and tokens[10][1].upper() == "WHERE"):
        tokens = tokens[:7] + tokens[10:] + tokens[7:10]
    parts, literals = [], []
    for kind, val in tokens:
        if kind in ("num", "str"):
            parts.append("?")
            literals.append(val)
        else:
            parts.append(str(val))
    return Fingerprint(" ".join(parts), tuple(literals))


_PARSE_CALLS = 0


def parse_calls() -> int:
    """Total ``parse_sql`` invocations (process-wide, monotonic).

    The ``--plan-smoke`` lane asserts this counter does not move across a
    template-hit burst — the zero-parse guarantee, checked by counting
    rather than timing.
    """
    return _PARSE_CALLS


def _tokenize(text: str):
    # Hot path: fingerprint_sql runs this per submitted query, so the loop
    # avoids per-token remainder slices and groupdict scans — whitespace is
    # skipped char-wise and the matched alternative read off ``lastgroup``
    # (every named group is top-level, so it is always the one that fired).
    tokens, pos, n = [], 0, len(text)
    append = tokens.append
    while pos < n:
        if text[pos] in _WHITESPACE:
            pos += 1
            continue
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise SQLError(f"cannot tokenize at: {text[pos:pos+25]!r}")
        pos = m.end()
        kind = m.lastgroup
        val = m.group(m.lastindex)
        if kind == "num":
            append(("num", float(val)))
        elif kind == "str":
            append(("str", val[1:-1]))
        else:
            append((kind, val))
    return tokens


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def peek(self):
        return self.toks[self.i] if self.i < len(self.toks) else (None, None)

    def next(self):
        tok = self.peek()
        self.i += 1
        return tok

    def expect_word(self, *words):
        kind, val = self.next()
        if kind != "word" or val.upper() not in words:
            raise SQLError(f"expected {'/'.join(words)}, got {val!r}")
        return val.upper()

    def expect_punc(self, ch):
        kind, val = self.next()
        if kind != "punc" or val != ch:
            raise SQLError(f"expected {ch!r}, got {val!r}")

    # expr := term (OR term)*
    def expr(self):
        children = [self.term()]
        while True:
            kind, val = self.peek()
            if kind == "word" and val.upper() == "OR":
                self.next()
                children.append(self.term())
            else:
                break
        return children[0] if len(children) == 1 else RawNode("or", children)

    # term := factor (AND factor)*
    def term(self):
        children = [self.factor()]
        while True:
            kind, val = self.peek()
            if kind == "word" and val.upper() == "AND":
                self.next()
                children.append(self.factor())
            else:
                break
        return children[0] if len(children) == 1 else RawNode("and", children)

    def factor(self):
        kind, val = self.peek()
        if kind == "punc" and val == "(":
            self.next()
            node = self.expr()
            self.expect_punc(")")
            return node
        if kind != "word":
            raise SQLError(f"expected column name, got {val!r}")
        self.next()
        col = val
        okind, op = self.next()
        if okind != "op":
            raise SQLError(f"expected operator after {col!r}, got {op!r}")
        vkind, lit = self.next()
        if vkind not in ("num", "str"):
            raise SQLError(f"expected literal, got {lit!r}")
        return RawCond(col, "!=" if op == "<>" else op, lit)


def parse_sql(text: str) -> ParsedQuery:
    global _PARSE_CALLS
    _PARSE_CALLS += 1
    p = _Parser(_tokenize(text))
    p.expect_word("SELECT")
    kind, func = p.next()
    if kind != "word" or func.upper() not in AGG_FUNCS:
        raise SQLError(f"expected aggregation function, got {func!r}")
    p.expect_punc("(")
    kind, col = p.next()
    if kind == "punc" and col == "*":
        agg_col = "*"
    elif kind == "word":
        agg_col = col
    else:
        raise SQLError(f"expected column or *, got {col!r}")
    p.expect_punc(")")
    p.expect_word("FROM")
    kind, table = p.next()
    if kind != "word":
        raise SQLError(f"expected table name, got {table!r}")

    where = None
    group_by = None
    while True:
        kind, val = p.peek()
        if kind is None or (kind == "punc" and val == ";"):
            break
        if kind == "word" and val.upper() == "WHERE":
            p.next()
            where = p.expr()
        elif kind == "word" and val.upper() == "GROUP":
            p.next()
            p.expect_word("BY")
            gkind, gcol = p.next()
            if gkind != "word":
                raise SQLError(f"expected GROUP BY column, got {gcol!r}")
            group_by = gcol
        else:
            raise SQLError(f"unexpected token {val!r}")
    if agg_col == "*" and func.upper() != "COUNT":
        raise SQLError(f"{func}(*) is only valid for COUNT")
    return ParsedQuery(func.upper(), agg_col, table, where, group_by)
