"""Query planning + execution engine (§5, Fig. 7).

Pipeline: parse (repro.core.sql) -> plan (encode literals into the GD
pre-processed domain, §5.1; consolidate same-column groups = "delayed
transformation", §5.2) -> weightings (§5.3) -> aggregate (§5.4) ->
de-preprocess results.

Value-domain aggregations (SUM/AVG/MIN/MAX/MEDIAN/VAR) run on the *decoded*
per-bin value metadata (affine inverse of pre-processing preserves ordering),
so Table 3's bound formulas apply directly in the raw domain.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import aggregate as agg
from repro.core import coverage as covlib
from repro.core import sql as sqlmod
from repro.core import weightings as wlib
from repro.core.types import PairwiseHist


@dataclasses.dataclass
class QueryResult:
    estimate: float | None
    lower: float | None
    upper: float | None
    groups: dict | None = None       # GROUP BY: value -> (est, lo, hi)
    latency_s: float = 0.0
    # Opt-in EXPLAIN breakdown (server-side tracing): per-stage ms tiling
    # the submit->resolve wall clock, plus cache/wave flags. None unless
    # the serving layer traced this query; cached results stay explain-free
    # (the breakdown describes ONE submission, not the shared value).
    explain: dict | None = None

    # Overridden by AdmissionRejected; lets clients branch on res.rejected
    # without an isinstance import.
    rejected = False
    # Overridden by QueryError / DeadlineExceeded (same pattern): failure
    # containment resolves futures with typed results, never hangs them.
    failed = False
    expired = False

    def as_tuple(self):
        return (self.estimate, self.lower, self.upper)


@dataclasses.dataclass
class AdmissionRejected(QueryResult):
    """Typed overload outcome: the serving layer declined to execute.

    Shares the ``QueryResult`` shape (``estimate``/``lower``/``upper`` are
    ``None``) so streaming clients that read fields never crash on an
    overload decision, and resolves the query's future as a *result*, not an
    exception — shedding is a policy outcome, not a failure. ``reason`` is
    ``"reject"`` (this query was turned away at a full queue) or
    ``"shed_oldest"`` (this query was evicted from the queue to admit a
    newer one); ``queue_depth`` is the depth observed at decision time.
    """

    estimate: float | None = None
    lower: float | None = None
    upper: float | None = None
    reason: str = "reject"
    queue_depth: int = 0

    rejected = True


@dataclasses.dataclass
class QueryError(QueryResult):
    """Typed execution-failure outcome (mirrors ``AdmissionRejected``).

    Resolves the query's future as a *result* rather than an exception so
    a wave-level crash, a poison query, or a quarantined statement can
    never hang or kill streaming clients that only read fields. ``kind``
    is ``"execution"`` (the wave raised while running this query; it was
    retried once before giving up) or ``"quarantined"`` (the statement was
    refused up front because it already failed execution twice).
    ``retries`` counts execution attempts consumed; ``error`` carries the
    underlying exception text.
    """

    estimate: float | None = None
    lower: float | None = None
    upper: float | None = None
    error: str = ""
    kind: str = "execution"
    retries: int = 0

    failed = True


@dataclasses.dataclass
class DeadlineExceeded(QueryResult):
    """Typed deadline outcome: the query expired before execution.

    A query submitted with ``deadline_ms`` whose deadline passes while it
    is still queued skips the fused launch entirely and resolves with this
    result at the start of the next wave. ``deadline_ms`` echoes the
    budget; ``elapsed_ms`` is submit-to-resolution wall clock.
    """

    estimate: float | None = None
    lower: float | None = None
    upper: float | None = None
    deadline_ms: float = 0.0
    elapsed_ms: float = 0.0

    expired = True


class PlanError(ValueError):
    pass


def tree_key(tree) -> str:
    """Deterministic serialization of an encoded predicate tree.

    Used as the canonical-identity component of plan/leaf cache keys: two
    trees with equal structure, columns, ops and encoded literals produce the
    same key regardless of the SQL text they were parsed from. ``None``
    (no WHERE) serializes to ``"T"``.
    """
    if tree is None:
        return "T"
    if isinstance(tree, wlib.Leaf):
        return f"L({tree.col},{tree.op},{tree.value!r})"
    if isinstance(tree, wlib.Consolidated):
        ivs = ",".join(f"[{lo!r},{hi!r}]" for lo, hi in tree.intervals)
        return f"C({tree.col},{ivs})"
    children = ";".join(tree_key(ch) for ch in tree.children)
    return f"N({tree.kind}:{children})"


@dataclasses.dataclass
class QueryPlan:
    """A planned query: encoded/consolidated predicate tree + resolved columns.

    Plans depend only on the SQL text and the synopsis metadata (column
    encodings, consolidation grids), not on the histogram counts, so they are
    reusable across executions and cacheable by the serving layer as long as
    the synopsis generation ("epoch") is unchanged.

    GROUP BY plans are expanded at planning time into per-category **leaf
    plans** (``leaf_plans``): leaf ``i`` is the same aggregation with the
    predicate ``group_col = code_i`` AND-ed onto the WHERE tree and
    ``group_by=None``. All leaves of a GROUP BY share one batch-execution
    plan shape, so the serving scheduler can run every leaf of every
    in-flight GROUP BY as part of one fused ``batched_weightings`` launch;
    ``group_values[i]`` is the decoded category value leaf ``i`` reports
    under.
    """

    func: str                 # aggregation function
    agg_col: int | None       # None for COUNT(*)
    tree: object              # Leaf | Consolidated | Node | None
    group_by: int | None
    table: str | None = None  # FROM clause (resolved by the serving catalog)
    exec_col: int | None = None  # column whose weightings drive execution
    # GROUP BY expansion (populated by plan_query for categorical group_by).
    leaf_plans: tuple = ()    # tuple[QueryPlan]: per-category leaf plans
    group_values: tuple = ()  # decoded category values aligned with leaf_plans
    # Memoized canonical_key (the serving layer calls it on every cache
    # lookup; the tree never mutates after planning, so stringify once).
    _ckey: str | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def canonical_key(self) -> str:
        """Text-independent identity of this plan's *semantics*.

        Two plans compare equal iff they run the same aggregation over the
        same encoded predicate tree — regardless of the SQL text they came
        from (clause order, whitespace, redundant parentheses). The serving
        layer keys per-leaf result-cache entries on this, so overlapping
        GROUP BY queries (and textual variants of one query) share entries.
        Memoized: the predicate tree is frozen after planning.
        """
        if self._ckey is None:
            self._ckey = (f"{self.table}|{self.func}|{self.agg_col}|"
                          f"{self.group_by}|{tree_key(self.tree)}")
        return self._ckey

    def and_leaves(self):
        """Leaves of a pure-AND tree, or None (OR / no WHERE)."""
        if self.tree is None:
            return None
        return wlib.flat_and_leaves(self.tree)

    def shape_key(self):
        """Batch-execution plan shape: (exec_col, sorted pair-predicate cols).

        Queries sharing a shape key can execute as one fused batched kernel
        launch (the padded H/fold stacks depend only on the column set).
        Returns None when this plan is not batchable: GROUP BY, no WHERE,
        OR/nested trees, or duplicate pair-column leaves.
        """
        if self.group_by is not None or self.exec_col is None:
            return None
        leaves = self.and_leaves()
        if leaves is None:
            return None
        pair_cols = set()
        for leaf in leaves:
            if leaf.col == self.exec_col:
                continue
            if leaf.col in pair_cols:   # un-consolidated duplicate: fall back
                return None
            pair_cols.add(leaf.col)
        return (self.exec_col, tuple(sorted(pair_cols)))


def assemble_groups(plan: QueryPlan, leaf_results: dict) -> QueryResult:
    """Per-leaf ``QueryResult``s -> one GROUP BY ``QueryResult``.

    ``leaf_results`` maps leaf index -> result. Matches the sequential
    ``_group_by`` contract exactly: a category appears in ``groups`` iff its
    estimate is non-null and positive. Shared by the engine's own leaf path
    and the serving layer (which supplies leaf results from the batched
    kernel launch and the per-leaf result cache).
    """
    groups = {}
    for i, value in enumerate(plan.group_values):
        res = leaf_results.get(i)
        if res is not None and res.estimate is not None and res.estimate > 0:
            groups[value] = res.as_tuple()
    return QueryResult(None, None, None, groups=groups)


# ---------------------------------------------------------------------------
# Plan templates (zero-parse fast path)
# ---------------------------------------------------------------------------
#
# A compiled recipe for one query *shape* (literal-stripped fingerprint).
# The key fact making this sound: the consolidated tree STRUCTURE is
# literal-independent — ``_consolidate`` merges leaves by column
# multiplicity and orders children (merged-by-first-occurrence, then
# non-leaf rest) without ever looking at a literal value.  Only Leaf
# values and Consolidated interval *contents* vary between two queries of
# the same shape, so a recipe tree with literal-slot indices can bind any
# literal vector of that shape into a plan bit-for-bit equal to the cold
# ``parse_sql`` -> ``plan_query`` path.

@dataclasses.dataclass
class _SlotLeaf:
    """Recipe for a ``Leaf``: encoded literal comes from slot ``slot``."""
    col: int
    op: str
    slot: int


@dataclasses.dataclass
class _SlotMerge:
    """Recipe for a ``Consolidated``: re-runs the same interval merge that
    ``_consolidate`` performed at compile, over the new slot values."""
    col: int
    kind: str                  # "and" | "or" of the merging parent node
    parts: list                # [(op, slot), ...] in leaf order
    mu: float


@dataclasses.dataclass
class _SlotNode:
    """Recipe for a ``Node``: children already recipe nodes, in order."""
    kind: str
    children: list


class PlanTemplate:
    """Compiled planner for one query shape: binds literals -> ``QueryPlan``.

    Compiled once per (shape, epoch) from a cold parse+plan; after that,
    ``bind`` produces plans without touching ``parse_sql``/``plan_query``.
    ``bind_batch`` encodes the literal vectors of a whole wave in one numpy
    pass (all-numeric shapes), then assembles the per-query trees.
    """

    def __init__(self, engine: "QueryEngine", parsed: sqlmod.ParsedQuery):
        ph = engine.ph
        self._engine = engine
        self.func = parsed.func
        self.table = parsed.table
        self.agg_col = (None if parsed.agg_col == "*"
                        else ph.col_index(parsed.agg_col))
        self.group_by = (None if parsed.group_by is None
                         else ph.col_index(parsed.group_by))
        self._slot_cols: list[int] = []       # slot -> column index
        slot_tree = self._compile_encode(parsed.where)
        self.recipe = self._compile_consolidate(slot_tree)
        self.n_slots = len(self._slot_cols)
        self._columns = [ph.columns[c] for c in self._slot_cols]
        # Vectorized-encode constants (numeric shapes only; categorical
        # slots need .index() per literal, so they take the scalar path).
        self.numeric_only = all(c.kind != "categorical" for c in self._columns)
        if self.numeric_only and self.n_slots:
            self._scales = np.array([c.scale for c in self._columns])
            self._offsets = np.array([c.offset for c in self._columns])
        # exec_col depends only on the column set -> compile-time constant.
        self.exec_col = self.agg_col
        if self.agg_col is None and self.recipe is not None:
            self.exec_col = min(self._recipe_cols(self.recipe, set()))
        # GROUP BY expansion constants: category leaves, values, and the
        # (invariant) per-leaf exec_col, computed once at compile.
        if self.group_by is not None:
            col = ph.columns[self.group_by]
            if col.kind != "categorical":
                raise PlanError(
                    f"GROUP BY requires a categorical column, got {col.name!r}")
            self.cat_leaves = tuple(
                wlib.Leaf(self.group_by, "=", float(code))
                for code in range(len(col.categories)))
            self.group_values = tuple(col.categories)
            self.leaf_exec_col = self.agg_col
            if self.agg_col is None:
                cols = (self._recipe_cols(self.recipe, set())
                        if self.recipe is not None else set())
                cols.add(self.group_by)
                self.leaf_exec_col = min(cols)

    # ------------------------------------------------------------- compile

    def _compile_encode(self, raw):
        """Mirror of ``_encode``: RawCond -> _SlotLeaf, slots in token order
        (the parser emits RawConds left-to-right, child order preserved)."""
        if raw is None:
            return None
        if isinstance(raw, sqlmod.RawCond):
            slot = len(self._slot_cols)
            self._slot_cols.append(self._engine.ph.col_index(raw.col))
            return _SlotLeaf(self._slot_cols[slot], raw.op, slot)
        return _SlotNode(raw.kind,
                         [self._compile_encode(ch) for ch in raw.children])

    def _compile_consolidate(self, node):
        """Mirror of ``_consolidate`` over slot nodes: same grouping, same
        child order, values replaced by slot references."""
        if node is None or isinstance(node, _SlotLeaf):
            return node
        children = [self._compile_consolidate(ch) for ch in node.children]
        by_col: dict[int, list] = {}
        rest = []
        for ch in children:
            if isinstance(ch, _SlotLeaf):
                by_col.setdefault(ch.col, []).append(ch)
            else:
                rest.append(ch)
        merged = []
        for col, leaves in by_col.items():
            if len(leaves) == 1:
                merged.append(leaves[0])
                continue
            merged.append(_SlotMerge(col, node.kind,
                                     [(lf.op, lf.slot) for lf in leaves],
                                     self._engine.ph.columns[col].mu))
        out = merged + rest
        if len(out) == 1:
            return out[0]
        return _SlotNode(node.kind, out)

    def _recipe_cols(self, node, acc):
        if isinstance(node, (_SlotLeaf, _SlotMerge)):
            acc.add(node.col)
            return acc
        for ch in node.children:
            self._recipe_cols(ch, acc)
        return acc

    # ---------------------------------------------------------------- bind

    def encode_literals(self, literals):
        """Scalar per-slot encode (same ``ColumnInfo.encode`` as cold path)."""
        if len(literals) != self.n_slots:
            raise PlanError(
                f"template expects {self.n_slots} literals, got {len(literals)}")
        return [c.encode(v) for c, v in zip(self._columns, literals)]

    def encode_batch(self, rows):
        """Encode a wave's literal vectors in one numpy pass.

        Returns an ``(n_rows, n_slots)`` float array, or ``None`` when this
        shape can't vectorize (categorical slots, string literals) — the
        caller falls back to per-row ``encode_literals``.  Elementwise
        identical to the scalar path: both funnel through ``np.round``.
        """
        if not self.numeric_only or not self.n_slots:
            return None
        try:
            lit = np.asarray(rows, dtype=float)
        except (TypeError, ValueError):
            return None
        if lit.ndim != 2 or lit.shape[1] != self.n_slots:
            return None
        return np.round(lit * self._scales - self._offsets, 6)

    def _bind_tree(self, node, enc):
        if node is None:
            return None
        if isinstance(node, _SlotLeaf):
            return wlib.Leaf(node.col, node.op, enc[node.slot])
        if isinstance(node, _SlotMerge):
            sets = [covlib.cond_to_intervals(op, enc[slot], node.mu)
                    for op, slot in node.parts]
            ivs = (covlib.intersect_intervals(sets) if node.kind == "and"
                   else covlib.union_intervals(sets))
            return wlib.Consolidated(node.col, ivs)
        return wlib.Node(node.kind,
                         [self._bind_tree(ch, enc) for ch in node.children])

    def _assemble(self, enc) -> QueryPlan:
        tree = self._bind_tree(self.recipe, enc)
        plan = QueryPlan(self.func, self.agg_col, tree, self.group_by,
                         self.table, self.exec_col)
        if self.group_by is not None:
            leaves = []
            for cleaf in self.cat_leaves:
                sub = cleaf if tree is None else \
                    wlib.Node("and", [cleaf, tree])
                leaves.append(QueryPlan(self.func, self.agg_col, sub, None,
                                        self.table, self.leaf_exec_col))
            plan.leaf_plans = tuple(leaves)
            plan.group_values = self.group_values
        return plan

    def bind(self, literals) -> QueryPlan:
        """One literal vector -> ``QueryPlan`` (no parse, no raw-tree walk)."""
        return self._assemble(self.encode_literals(literals))

    def bind_batch(self, rows) -> list:
        """Many literal vectors -> plans; encoding vectorized when possible."""
        for row in rows:
            if len(row) != self.n_slots:
                raise PlanError(
                    f"template expects {self.n_slots} literals, got {len(row)}")
        enc = self.encode_batch(rows)
        if enc is None:
            return [self._assemble(self.encode_literals(r)) for r in rows]
        # .tolist() drops back to Python floats so tree_key reprs (and
        # hence canonical/cache keys) match the scalar path exactly.
        return [self._assemble(row) for row in enc.tolist()]


class QueryEngine:
    """Executes the paper's query templates against a PairwiseHist synopsis."""

    def __init__(self, ph: PairwiseHist,
                 corrected_sampling_bounds: bool = False,
                 fastpath=None):
        self.ph = ph
        self.corrected = corrected_sampling_bounds
        # Optional fused JAX/Pallas weightings path (repro.core.fastpath).
        self.fastpath = fastpath

    # ------------------------------------------------------------------ API

    def query(self, sql_text: str) -> QueryResult:
        return self.execute_plan(self.plan_sql(sql_text))

    def plan_sql(self, sql_text: str) -> QueryPlan:
        return self.plan_query(sqlmod.parse_sql(sql_text))

    def plan_template(self, parsed: sqlmod.ParsedQuery) -> PlanTemplate:
        """Compile a reusable zero-parse planner for this query's shape.

        The template binds any literal vector of the same fingerprint shape
        (``sql.fingerprint_sql``) into a plan bit-for-bit equal to
        ``plan_query`` on the equivalent parse. Valid for this synopsis
        generation only — encode scales, category tables and consolidation
        grids are baked in at compile (the serving layer epoch-keys its
        template cache accordingly).
        """
        return PlanTemplate(self, parsed)

    def plan_query(self, q: sqlmod.ParsedQuery) -> QueryPlan:
        """Parsed query -> reusable QueryPlan (encode + consolidate).

        GROUP BY queries are additionally expanded into per-category leaf
        plans here (``QueryPlan.leaf_plans``), so downstream executors can
        treat each category as an ordinary single-result plan — in
        particular, batch all leaves through the fused kernel path.
        """
        tree = self._plan(q.where)
        agg_col = None if q.agg_col == "*" else self.ph.col_index(q.agg_col)
        gcol = None if q.group_by is None else self.ph.col_index(q.group_by)
        exec_col = agg_col
        if agg_col is None and tree is not None:   # COUNT(*) with WHERE
            exec_col = min(self._tree_cols(tree, set()))
        plan = QueryPlan(q.func, agg_col, tree, gcol, q.table, exec_col)
        if gcol is not None:
            plan.leaf_plans, plan.group_values = \
                self._expand_group_by(plan, gcol)
        return plan

    def _expand_group_by(self, plan: QueryPlan, gcol: int):
        """GROUP BY plan -> per-category leaf plans (planning-time expansion).

        Leaf trees are built exactly like the sequential ``_group_by`` loop
        (``Node("and", [Leaf(gcol, "=", code), tree])``), so executing a leaf
        plan is bit-for-bit identical to the unbatched per-category path.
        """
        col = self.ph.columns[gcol]
        if col.kind != "categorical":
            raise PlanError(
                f"GROUP BY requires a categorical column, got {col.name!r}")
        exec_col = plan.agg_col
        if exec_col is None:                       # COUNT(*): cheapest column
            # Every leaf tree is {gcol} AND-ed onto the same WHERE tree, so
            # the column set — and hence exec_col — is invariant across
            # categories: compute it once per plan, not once per leaf.
            exec_col = min(self._tree_cols(plan.tree, {gcol}))
        leaves, values = [], []
        for code, value in enumerate(col.categories):
            leaf = wlib.Leaf(gcol, "=", float(code))
            sub = leaf if plan.tree is None else \
                wlib.Node("and", [leaf, plan.tree])
            leaves.append(QueryPlan(plan.func, plan.agg_col, sub, None,
                                    plan.table, exec_col))
            values.append(value)
        return tuple(leaves), tuple(values)

    def execute_plan(self, plan: QueryPlan, weightings=None,
                     leaf_results=None) -> QueryResult:
        """Execute a plan; ``weightings`` optionally supplies a precomputed
        (w, wlo, whi) triple (e.g. from a fused batched kernel launch).

        GROUP BY plans execute their planning-time leaf expansion:
        ``leaf_results`` optionally supplies precomputed per-leaf
        ``QueryResult``s keyed by leaf index (e.g. from a batched serving
        launch or a per-leaf result cache); missing leaves execute here via
        the same ``_single`` path as the sequential oracle.
        """
        t0 = time.perf_counter()
        if plan.leaf_plans:
            result = self._assemble_groups(plan, leaf_results or {})
        elif plan.group_by is not None:    # unexpanded plan: sequential path
            result = self._group_by(plan.func, plan.agg_col, plan.tree,
                                    plan.group_by)
        else:
            result = self._single(plan.func, plan.agg_col, plan.tree,
                                  w_triple=weightings)
        result.latency_s = time.perf_counter() - t0
        return result

    def _assemble_groups(self, plan: QueryPlan, leaf_results) -> QueryResult:
        """Execute any missing GROUP BY leaves, then assemble the groups."""
        full = dict(leaf_results)
        for i, leaf in enumerate(plan.leaf_plans):
            if i not in full:
                full[i] = self._single(leaf.func, leaf.agg_col, leaf.tree)
        return assemble_groups(plan, full)

    def execute(self, func: str, agg_col: int | None, tree,
                group_by: int | None = None) -> QueryResult:
        t0 = time.perf_counter()
        if group_by is not None:
            result = self._group_by(func, agg_col, tree, group_by)
        else:
            result = self._single(func, agg_col, tree)
        result.latency_s = time.perf_counter() - t0
        return result

    # -------------------------------------------------------------- planning

    def _plan(self, raw):
        """RawCond/RawNode -> Leaf/Consolidated/Node with encoded literals."""
        if raw is None:
            return None
        node = self._encode(raw)
        return self._consolidate(node)

    def _encode(self, raw):
        if isinstance(raw, sqlmod.RawCond):
            col = self.ph.col_index(raw.col)
            value = self.ph.columns[col].encode(raw.value)
            return wlib.Leaf(col, raw.op, value)
        return wlib.Node(raw.kind, [self._encode(ch) for ch in raw.children])

    def _consolidate(self, node):
        """Delayed transformation: merge same-column leaves under one AND/OR."""
        if isinstance(node, wlib.Leaf):
            return node
        children = [self._consolidate(ch) for ch in node.children]
        by_col: dict[int, list] = {}
        rest = []
        for ch in children:
            if isinstance(ch, wlib.Leaf):
                by_col.setdefault(ch.col, []).append(ch)
            else:
                rest.append(ch)
        merged = []
        for col, leaves in by_col.items():
            if len(leaves) == 1:
                merged.append(leaves[0])
                continue
            mu = self.ph.columns[col].mu
            sets = [covlib.cond_to_intervals(lf.op, lf.value, mu)
                    for lf in leaves]
            ivs = (covlib.intersect_intervals(sets) if node.kind == "and"
                   else covlib.union_intervals(sets))
            merged.append(wlib.Consolidated(col, ivs))
        out = merged + rest
        if len(out) == 1:
            return out[0]
        return wlib.Node(node.kind, out)

    # ------------------------------------------------------------- execution

    def _tree_cols(self, tree, acc):
        if tree is None:
            return acc
        if isinstance(tree, (wlib.Leaf, wlib.Consolidated)):
            acc.add(tree.col)
            return acc
        for ch in tree.children:
            self._tree_cols(ch, acc)
        return acc

    def _agg_restriction(self, tree, col: int):
        """Necessary interval restriction the predicate imposes on `col`.

        Any matching row's value of `col` must lie in the returned disjoint
        interval set (pre-processed domain). Conditions on other columns are
        unrestrictive. Used to snap MIN/MAX estimates/bounds into the
        feasible region (sound; beyond-paper refinement, DESIGN §7).
        """
        full = [(-np.inf, np.inf)]
        if tree is None:
            return full
        mu = self.ph.columns[col].mu
        if isinstance(tree, wlib.Leaf):
            return covlib.cond_to_intervals(tree.op, tree.value, mu) \
                if tree.col == col else full
        if isinstance(tree, wlib.Consolidated):
            return tree.intervals if tree.col == col else full
        sets = [self._agg_restriction(ch, col) for ch in tree.children]
        if tree.kind == "and":
            return covlib.intersect_intervals(sets)
        return covlib.union_intervals(sets)

    @staticmethod
    def _snap_up(x: float, intervals, mu: float) -> float:
        """Smallest grid value >= x inside the interval set."""
        for lo, hi in intervals:
            cand = max(x, np.ceil((lo + 1e-12) / mu) * mu) if np.isfinite(lo) else x
            if cand <= hi:
                return cand
        return x

    @staticmethod
    def _snap_down(x: float, intervals, mu: float) -> float:
        """Largest grid value <= x inside the interval set."""
        for lo, hi in reversed(intervals):
            cand = min(x, np.floor((hi - 1e-12) / mu) * mu) if np.isfinite(hi) else x
            if cand >= lo:
                return cand
        return x

    def _weightings(self, agg_col, tree):
        if self.fastpath is not None and tree is not None:
            out = self.fastpath(self.ph, agg_col, tree, self.corrected)
            if out is not None:
                return out
        return wlib.weightings(self.ph, agg_col, tree,
                               corrected_sampling_bounds=self.corrected)

    def _single(self, func, agg_col, tree, w_triple=None) -> QueryResult:
        ph = self.ph
        if agg_col is None:  # COUNT(*)
            if tree is None:
                n = float(ph.n_rows)
                return QueryResult(n, n, n)
            agg_col = min(self._tree_cols(tree, set()))
        hist = ph.hists[agg_col]
        col = ph.columns[agg_col]
        w, wlo, whi = (w_triple if w_triple is not None
                       else self._weightings(agg_col, tree))
        rho = ph.rho

        if func == "COUNT":
            est, lo, hi = agg.agg_count(w, wlo, whi, rho)
            return QueryResult(est, lo, hi)

        if col.kind == "categorical" and func not in ("COUNT",):
            raise PlanError(f"{func} over categorical column {col.name!r}")

        # Decode bin value metadata into the raw domain (affine, increasing).
        dec = lambda a: (np.asarray(a, float) + col.offset) / col.scale  # noqa: E731
        c, cm, cp = dec(hist.c), dec(hist.cminus), dec(hist.cplus)
        vmin, vmax = dec(hist.vmin), dec(hist.vmax)
        hist_raw = hist._replace(vmin=vmin, vmax=vmax, c=c, cminus=cm, cplus=cp)

        pred_cols = self._tree_cols(tree, set())
        single_col = pred_cols.issubset({agg_col})

        if func == "SUM":
            est, lo, hi = agg.agg_sum(w, wlo, whi, c, cm, cp, rho)
        elif func == "AVG":
            est, lo, hi = agg.agg_avg(w, wlo, whi, c, cm, cp)
        elif func == "MIN":
            est, lo, hi = agg.agg_min(w, wlo, whi, hist_raw,
                                      ph.params.min_points,
                                      ph.params.s1_max, single_col)
            if not np.isnan(est):
                restrict = self._agg_restriction(tree, agg_col)
                enc = lambda x: x * col.scale - col.offset  # noqa: E731
                dec = lambda x: (x + col.offset) / col.scale  # noqa: E731
                est = dec(self._snap_up(enc(est), restrict, col.mu))
                lo = dec(self._snap_up(enc(lo), restrict, col.mu))
                hi = dec(self._snap_up(enc(hi), restrict, col.mu))
                lo, hi = min(lo, est), max(hi, est)
        elif func == "MAX":
            est, lo, hi = agg.agg_max(w, wlo, whi, hist_raw,
                                      ph.params.min_points,
                                      ph.params.s1_max, single_col)
            if not np.isnan(est):
                restrict = self._agg_restriction(tree, agg_col)
                enc = lambda x: x * col.scale - col.offset  # noqa: E731
                dec = lambda x: (x + col.offset) / col.scale  # noqa: E731
                est = dec(self._snap_down(enc(est), restrict, col.mu))
                lo = dec(self._snap_down(enc(lo), restrict, col.mu))
                hi = dec(self._snap_down(enc(hi), restrict, col.mu))
                lo, hi = min(lo, est), max(hi, est)
        elif func == "MEDIAN":
            est, lo, hi = agg.agg_median(w, wlo, whi, hist_raw)
        elif func == "VAR":
            est, lo, hi = agg.agg_var(w, wlo, whi, c, vmin, vmax)
        else:
            raise PlanError(f"unsupported aggregation {func!r}")
        if np.isnan(est):
            return QueryResult(None, None, None)
        return QueryResult(est, lo, hi)

    def _group_by(self, func, agg_col, tree, gcol) -> QueryResult:
        col = self.ph.columns[gcol]
        if col.kind != "categorical":
            raise PlanError(f"GROUP BY requires a categorical column, got {col.name!r}")
        groups = {}
        for code, value in enumerate(col.categories):
            leaf = wlib.Leaf(gcol, "=", float(code))
            sub = leaf if tree is None else wlib.Node("and", [leaf, tree])
            res = self._single(func, agg_col, sub)
            if res.estimate is not None and res.estimate > 0:
                groups[value] = res.as_tuple()
        return QueryResult(None, None, None, groups=groups)
