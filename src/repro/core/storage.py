"""Compact synopsis storage encoding (§4.3, Fig. 6, Eq. 11–13).

Re-derivable quantities (bin midpoints c, weighted-centre bounds c±, slice
totals h = H row/column sums, fold maps) are NOT stored. Counts matrices are
stored dense (ℓ_h bits per cell, Eq. 13) or sparse (Golomb–Rice-coded deltas
of non-zero flat indices + ℓ_h-bit counts), whichever is smaller, with a
1-bit flag per histogram — exactly the paper's scheme.

Values (edges / extrema) are integers in the pre-processed domain; edges
gain dyadic fractions from midpoint splits, so each edge array is encoded as
zig-zag varint numerators over a shared power-of-two denominator.

Everything is bit-level (BitWriter/BitReader below); decode reconstructs a
full runtime ``PairwiseHist`` (centre bounds recomputed via Eq. 10).
"""
from __future__ import annotations

import math
import struct

import numpy as np

from repro.core import chi2 as chi2lib
from repro.core.types import BuildParams, ColumnInfo, Hist1D, PairHist, PairwiseHist

_MAGIC = b"PWH1"


# ---------------------------------------------------------------------------
# Bit-level IO
# ---------------------------------------------------------------------------


class BitWriter:
    def __init__(self):
        self.buf = bytearray()
        self.acc = 0
        self.nbits = 0

    def write(self, value: int, nbits: int):
        if nbits == 0:
            return
        value &= (1 << nbits) - 1
        self.acc = (self.acc << nbits) | value
        self.nbits += nbits
        while self.nbits >= 8:
            self.nbits -= 8
            self.buf.append((self.acc >> self.nbits) & 0xFF)
        self.acc &= (1 << self.nbits) - 1

    def write_varint(self, value: int):
        """Unsigned bit-level LEB128 (7-bit chunks + continuation bit)."""
        v = int(value)
        if v < 0:
            raise ValueError("varint is unsigned")
        while True:
            chunk = v & 0x7F
            v >>= 7
            self.write(1 if v else 0, 1)
            self.write(chunk, 7)
            if not v:
                break

    def write_svarint(self, value: int):
        """Zig-zag signed varint."""
        v = int(value)
        self.write_varint((v << 1) ^ (v >> 63) if v >= 0 else ((-v) << 1) - 1)

    def write_rice(self, value: int, b: int):
        """Golomb–Rice with divisor 2**b: quotient unary + b-bit remainder."""
        q = int(value) >> b
        for _ in range(q):
            self.write(1, 1)
        self.write(0, 1)
        self.write(int(value) & ((1 << b) - 1), b)

    def write_f64(self, value: float):
        for byte in struct.pack("<d", float(value)):
            self.write(byte, 8)

    def getvalue(self) -> bytes:
        out = bytearray(self.buf)
        if self.nbits:
            out.append((self.acc << (8 - self.nbits)) & 0xFF)
        return bytes(out)


class BitReader:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0  # bit position

    def read(self, nbits: int) -> int:
        out = 0
        for _ in range(nbits):
            byte = self.data[self.pos >> 3]
            bit = (byte >> (7 - (self.pos & 7))) & 1
            out = (out << 1) | bit
            self.pos += 1
        return out

    def read_varint(self) -> int:
        shift, out = 0, 0
        while True:
            cont = self.read(1)
            chunk = self.read(7)
            out |= chunk << shift
            shift += 7
            if not cont:
                return out

    def read_svarint(self) -> int:
        z = self.read_varint()
        return (z >> 1) if (z & 1) == 0 else -((z + 1) >> 1)

    def read_rice(self, b: int) -> int:
        q = 0
        while self.read(1):
            q += 1
        return (q << b) | self.read(b)

    def read_f64(self) -> float:
        raw = bytes(self.read(8) for _ in range(8))
        return struct.unpack("<d", raw)[0]


# ---------------------------------------------------------------------------
# Edge / value array codecs
# ---------------------------------------------------------------------------


def _dyadic_exponent(arr: np.ndarray, cap: int = 40) -> int | None:
    """Smallest p such that arr * 2^p is integral (None if > cap)."""
    a = np.asarray(arr, np.float64)
    for p in range(cap + 1):
        scaled = a * (1 << p)
        if np.all(np.abs(scaled - np.round(scaled)) < 1e-6) and \
           np.all(np.abs(scaled) < 2**62):
            return p
    return None


def _encode_values(w: BitWriter, arr: np.ndarray):
    """Dyadic-rational array as (flag, p, varint deltas); f64 fallback."""
    arr = np.asarray(arr, np.float64)
    p = _dyadic_exponent(arr)
    if p is None:
        w.write(1, 1)
        for v in arr:
            w.write_f64(v)
        return
    w.write(0, 1)
    w.write_varint(p)
    ints = np.round(arr * (1 << p)).astype(np.int64)
    prev = 0
    for v in ints:
        w.write_svarint(int(v) - prev)
        prev = int(v)


def _decode_values(r: BitReader, n: int) -> np.ndarray:
    if r.read(1):
        return np.array([r.read_f64() for _ in range(n)], np.float64)
    p = r.read_varint()
    out = np.empty(n, np.int64)
    acc = 0
    for idx in range(n):
        acc += r.read_svarint()
        out[idx] = acc
    return out.astype(np.float64) / (1 << p)


def _bits_for(max_val: float) -> int:
    """ℓ_h per Eq. 13."""
    return max(1, int(math.ceil(math.log2(1.0 + max(0.0, float(max_val))))))


def _rice_param(mean: float) -> int:
    """Near-optimal Rice divisor exponent for geometric-ish deltas."""
    if mean <= 1.0:
        return 0
    return max(0, int(round(math.log2(mean))))


def _encode_counts(w: BitWriter, H: np.ndarray):
    """Dense (ℓ_h bits/cell) vs sparse (Rice deltas + ℓ_h counts): smaller wins."""
    flat = np.asarray(np.round(H), np.int64).reshape(-1)
    n = flat.size
    lh = _bits_for(flat.max() if n else 0)
    nz = np.flatnonzero(flat)
    theta = nz.size
    dense_bits = n * lh
    mean_delta = (n / max(theta, 1))
    b = _rice_param(mean_delta)
    deltas = np.diff(nz, prepend=-1) - 1  # gaps between non-zeros
    sparse_bits = 32 + theta * lh + int(sum(((int(d) >> b) + 1 + b) for d in deltas))
    w.write_varint(lh)
    if dense_bits <= sparse_bits:
        w.write(0, 1)  # I_h: dense
        for v in flat:
            w.write(int(v), lh)
    else:
        w.write(1, 1)  # I_h: sparse
        w.write_varint(theta)
        w.write_varint(b)
        for d in deltas:
            w.write_rice(int(d), b)
        for v in flat[nz]:
            w.write(int(v), lh)


def _decode_counts(r: BitReader, shape) -> np.ndarray:
    n = int(np.prod(shape))
    lh = r.read_varint()
    flat = np.zeros(n, np.int64)
    if r.read(1) == 0:
        for idx in range(n):
            flat[idx] = r.read(lh)
    else:
        theta = r.read_varint()
        b = r.read_varint()
        pos = -1
        idxs = []
        for _ in range(theta):
            pos += r.read_rice(b) + 1
            idxs.append(pos)
        for idx in idxs:
            flat[idx] = r.read(lh)
    return flat.astype(np.float64).reshape(shape)


# ---------------------------------------------------------------------------
# Histogram codecs
# ---------------------------------------------------------------------------


def _encode_dim(w: BitWriter, edges, u, vmin, vmax):
    k = len(u)
    w.write_varint(k)
    _encode_values(w, edges)
    _encode_values(w, vmin)
    _encode_values(w, vmax)
    for val in np.asarray(u, np.int64):
        w.write_varint(int(val))


def _decode_dim(r: BitReader):
    k = r.read_varint()
    edges = _decode_values(r, k + 1)
    vmin = _decode_values(r, k)
    vmax = _decode_values(r, k)
    u = np.array([r.read_varint() for _ in range(k)], np.float64)
    return edges, u, vmin, vmax


# ---------------------------------------------------------------------------
# Top level
# ---------------------------------------------------------------------------


def encode(ph: PairwiseHist) -> bytes:
    w = BitWriter()
    for byte in _MAGIC:
        w.write(byte, 8)
    w.write_varint(ph.n_rows)
    w.write_varint(ph.n_sampled)
    w.write_varint(ph.d)
    w.write_varint(ph.params.min_points)
    w.write_f64(ph.params.alpha)
    w.write_varint(ph.params.s1_max)
    w.write_varint(ph.params.s2_max)

    for col in ph.columns:
        kind_code = {"int": 0, "float": 1, "categorical": 2}[col.kind]
        w.write(kind_code, 2)
        w.write_f64(col.offset)
        w.write_f64(col.scale)
        w.write_f64(col.mu)
        w.write_varint(col.n_null)
        name = col.name.encode()
        w.write_varint(len(name))
        for byte in name:
            w.write(byte, 8)
        cats = "\x00".join(str(c) for c in col.categories).encode()
        w.write_varint(len(cats))
        for byte in cats:
            w.write(byte, 8)

    for hist in ph.hists:
        _encode_dim(w, hist.edges, hist.u, hist.vmin, hist.vmax)
        _encode_counts(w, hist.h)

    w.write_varint(len(ph.pairs))
    for (i, j), pr in sorted(ph.pairs.items()):
        w.write_varint(i)
        w.write_varint(j)
        _encode_dim(w, pr.ex, pr.ux, pr.vminx, pr.vmaxx)
        _encode_dim(w, pr.ey, pr.uy, pr.vminy, pr.vmaxy)
        _encode_counts(w, pr.H)
    return w.getvalue()


def _centre_bounds_np(h, u, vmin, vmax, min_points, crit_table, mu, s_max):
    """NumPy re-derivation of Eq. 10 (mirror of refine.centre_bounds)."""
    h = np.asarray(h, float)
    u = np.asarray(u, float)
    s = np.clip(np.ceil(np.cbrt(2.0 * np.maximum(u, 0.0))), 1, s_max)
    delta = (vmax - vmin) / np.maximum(s, 1.0)
    chi = crit_table[np.clip(s.astype(int), 0, len(crit_table) - 1)]
    chi = np.where(np.isfinite(chi), chi, 0.0)
    hsafe = np.maximum(h, 1.0)
    spread = (delta / 6.0) * np.sqrt(3.0 * chi * (s**2 - 1.0) / hsafe)
    c_lo_pass = vmin + (s - 1.0) * delta / 2.0 - spread
    c_hi_pass = vmin + (s + 1.0) * delta / 2.0 + spread
    shift = (u - 1.0) * u * mu / (2.0 * hsafe)
    fail = h < min_points
    cminus = np.where(fail, vmin + shift, c_lo_pass)
    cplus = np.where(fail, vmax - shift, c_hi_pass)
    mid = 0.5 * (vmin + vmax)
    degenerate = u <= 1.0
    cminus = np.where(degenerate, mid, cminus)
    cplus = np.where(degenerate, mid, cplus)
    cminus = np.clip(cminus, vmin, vmax)
    cplus = np.clip(cplus, cminus, vmax)
    return cminus, cplus


def decode(data: bytes) -> PairwiseHist:
    r = BitReader(data)
    magic = bytes(r.read(8) for _ in range(4))
    if magic != _MAGIC:
        raise ValueError("bad synopsis magic")
    n_rows = r.read_varint()
    n_sampled = r.read_varint()
    d = r.read_varint()
    min_points = r.read_varint()
    alpha = r.read_f64()
    s1_max = r.read_varint()
    s2_max = r.read_varint()
    params = BuildParams(n_samples=n_sampled, alpha=alpha,
                         m_frac=min_points / max(n_sampled, 1),
                         s1_max=s1_max, s2_max=s2_max)
    crit = chi2lib.build_crit_table(alpha, max(s1_max, s2_max))

    columns = []
    for _ in range(d):
        kind = ("int", "float", "categorical")[r.read(2)]
        offset = r.read_f64()
        scale = r.read_f64()
        mu = r.read_f64()
        n_null = r.read_varint()
        nlen = r.read_varint()
        name = bytes(r.read(8) for _ in range(nlen)).decode()
        clen = r.read_varint()
        raw = bytes(r.read(8) for _ in range(clen)).decode()
        cats = tuple(raw.split("\x00")) if raw else ()
        columns.append(ColumnInfo(name=name, kind=kind, offset=offset,
                                  scale=scale, categories=cats,
                                  n_null=n_null, mu=mu))

    hists = []
    for i in range(d):
        edges, u, vmin, vmax = _decode_dim(r)
        h = _decode_counts(r, (len(u),))
        c = 0.5 * (vmin + vmax)
        cm, cp = _centre_bounds_np(h, u, vmin, vmax, min_points, crit,
                                   columns[i].mu, s1_max)
        hists.append(Hist1D(edges=edges, k=np.int32(len(u)), h=h, u=u,
                            vmin=vmin, vmax=vmax, c=c, cminus=cm, cplus=cp))

    def fold_map(edges1, edges_pair):
        """1-D bin -> containing pair row (pair edges ⊆ 1-D edges)."""
        mids = 0.5 * (edges1[:-1] + edges1[1:])
        idx = np.searchsorted(edges_pair, mids, side="right") - 1
        return np.clip(idx, 0, max(edges_pair.size - 2, 0)).astype(np.int32)

    pairs = {}
    n_pairs = r.read_varint()
    for _ in range(n_pairs):
        i = r.read_varint()
        j = r.read_varint()
        ex, ux, vminx, vmaxx = _decode_dim(r)
        ey, uy, vminy, vmaxy = _decode_dim(r)
        H = _decode_counts(r, (len(ux), len(uy)))
        pairs[(i, j)] = PairHist(
            ex=ex, ey=ey, kx=np.int32(len(ux)), ky=np.int32(len(uy)), H=H,
            hx=H.sum(1), ux=ux, vminx=vminx, vmaxx=vmaxx,
            hy=H.sum(0), uy=uy, vminy=vminy, vmaxy=vmaxy,
            fold_x=fold_map(hists[i].edges, ex),
            fold_y=fold_map(hists[j].edges, ey),
        )

    return PairwiseHist(params=params, n_rows=n_rows, n_sampled=n_sampled,
                        columns=columns, hists=hists, pairs=pairs,
                        chi2_table=crit)


def blob_info(data: bytes) -> dict:
    """Cheap header peek: {bytes, n_rows, n_sampled, d} without full decode.

    Reads only the fixed-size preamble, so the cold catalog can report
    synopsis-bytes telemetry for registered blobs it has not decoded yet.
    """
    r = BitReader(data)
    magic = bytes(r.read(8) for _ in range(4))
    if magic != _MAGIC:
        raise ValueError("bad synopsis magic")
    return {
        "bytes": len(data),
        "n_rows": r.read_varint(),
        "n_sampled": r.read_varint(),
        "d": r.read_varint(),
    }


def eq12_bound(ph: PairwiseHist) -> int:
    """The paper's storage upper bound (Eq. 12), in bytes, for comparison."""
    d = ph.d

    def mbytes(col_idx):
        hist = ph.hists[col_idx]
        vmax = max(abs(float(hist.vmax.max() if len(hist.vmax) else 1)), 1.0)
        return max(1, int(math.ceil(math.log2(vmax + 2) / 8)))

    total = 29 + d + 4 * d * d
    for i in range(d):
        k_sum = 0
        for j in range(d):
            if i == j:
                continue
            pr = ph.pair(i, j)
            k_sum += int(pr.kx)
        k_i = int(ph.hists[i].k)
        total += (3 * mbytes(i) + 4) * (k_sum + k_i - (d - 1) * k_i + k_i)
    for (i, j), pr in ph.pairs.items():
        lh = _bits_for(pr.H.max() if pr.H.size else 0)
        total += math.ceil(int(pr.kx) * int(pr.ky) * lh / 8)
    return total


def synopsis_size_report(ph: PairwiseHist) -> dict:
    """Encoded size breakdown (bytes)."""
    blob = encode(ph)
    # Re-encode pieces for a rough breakdown.
    w = BitWriter()
    for hist in ph.hists:
        _encode_dim(w, hist.edges, hist.u, hist.vmin, hist.vmax)
        _encode_counts(w, hist.h)
    size_1d = len(w.getvalue())
    w = BitWriter()
    for pr in ph.pairs.values():
        _encode_dim(w, pr.ex, pr.ux, pr.vminx, pr.vmaxx)
        _encode_dim(w, pr.ey, pr.uy, pr.vminy, pr.vmaxy)
        _encode_counts(w, pr.H)
    size_2d = len(w.getvalue())
    return {
        "total": len(blob),
        "hists_1d": size_1d,
        "hists_2d": size_2d,
        "header_and_dicts": len(blob) - size_1d - size_2d,
        "eq12_bound": eq12_bound(ph),
    }
