"""Compact synopsis storage encoding (§4.3, Fig. 6, Eq. 11–13).

Re-derivable quantities (bin midpoints c, weighted-centre bounds c±, slice
totals h = H row/column sums, fold maps) are NOT stored. Counts matrices are
stored dense (ℓ_h bits per cell, Eq. 13) or sparse (Golomb–Rice-coded deltas
of non-zero flat indices + ℓ_h-bit counts), whichever is smaller, with a
1-bit flag per histogram — exactly the paper's scheme.

Values (edges / extrema) are integers in the pre-processed domain; edges
gain dyadic fractions from midpoint splits, so each edge array is encoded as
zig-zag varint numerators over a shared power-of-two denominator.

Everything is bit-level (BitWriter/BitReader below); decode reconstructs a
full runtime ``PairwiseHist`` (centre bounds recomputed via Eq. 10).
"""
from __future__ import annotations

import math
import struct
import zlib

import numpy as np

from repro.core import chi2 as chi2lib
from repro.core.types import BuildParams, ColumnInfo, Hist1D, PairHist, PairwiseHist

_MAGIC = b"PWH1"
_FRAME_MAGIC = b"PWF1"


class IntegrityError(ValueError):
    """Typed blob-integrity failure: corrupt, truncated, or mangled synopsis.

    Raised by ``decode``/``blob_info`` whenever the integrity frame fails
    verification (checksum mismatch, length mismatch, bad magic) or the
    payload bit-stream turns out to be structurally inconsistent mid-parse.
    Subclasses ``ValueError`` so pre-frame callers that caught ``ValueError``
    keep working. A corrupted blob always raises this — never returns wrong
    data, never hangs.
    """


def _crc32(payload: bytes) -> int:
    # zlib.crc32 (CRC-32/ISO-HDLC) runs in C and needs no new dependency;
    # CRC32C (Castagnoli) is a drop-in here if a native impl lands later.
    return zlib.crc32(payload) & 0xFFFFFFFF


def frame_blob(payload: bytes) -> bytes:
    """Wrap an encoded synopsis stream in the integrity frame.

    Layout: 4-byte frame magic, little-endian u32 payload length,
    little-endian u32 CRC-32 of the payload, then the payload itself.
    12 bytes of overhead per blob; verified by ``unframe_blob`` before any
    bit-level parsing touches the stream.
    """
    return _FRAME_MAGIC + struct.pack("<II", len(payload), _crc32(payload)) \
        + payload


def unframe_blob(data: bytes) -> bytes:
    """Verify and strip the integrity frame; returns the raw payload.

    Framed blobs are checked length-then-checksum and any mismatch raises
    ``IntegrityError``. Legacy unframed streams (leading with the payload
    magic ``PWH1``) pass through unchanged so pre-frame blobs stay
    readable — they simply do not get the checksum guarantee.
    """
    head = bytes(data[:4])
    if head == _FRAME_MAGIC:
        if len(data) < 12:
            raise IntegrityError("truncated synopsis frame header")
        n, crc = struct.unpack("<II", data[4:12])
        payload = bytes(data[12:])
        if len(payload) != n:
            raise IntegrityError(
                f"synopsis frame length mismatch: header says {n} payload "
                f"bytes, got {len(payload)}")
        if _crc32(payload) != crc:
            raise IntegrityError("synopsis frame checksum mismatch")
        return payload
    if head == _MAGIC:
        return bytes(data)
    raise IntegrityError("bad synopsis magic")


# ---------------------------------------------------------------------------
# Bit-level IO
# ---------------------------------------------------------------------------


class BitWriter:
    def __init__(self):
        self.buf = bytearray()
        self.acc = 0
        self.nbits = 0

    def write(self, value: int, nbits: int):
        if nbits == 0:
            return
        value &= (1 << nbits) - 1
        self.acc = (self.acc << nbits) | value
        self.nbits += nbits
        while self.nbits >= 8:
            self.nbits -= 8
            self.buf.append((self.acc >> self.nbits) & 0xFF)
        self.acc &= (1 << self.nbits) - 1

    def write_varint(self, value: int):
        """Unsigned bit-level LEB128 (7-bit chunks + continuation bit)."""
        v = int(value)
        if v < 0:
            raise ValueError("varint is unsigned")
        while True:
            chunk = v & 0x7F
            v >>= 7
            self.write(1 if v else 0, 1)
            self.write(chunk, 7)
            if not v:
                break

    def write_svarint(self, value: int):
        """Zig-zag signed varint (arbitrary-precision safe).

        Python ints are unbounded, so the classic C idiom
        ``(v << 1) ^ (v >> 63)`` silently corrupts ``|v| >= 2**63`` (the
        arithmetic shift is no longer a sign smear). The branchy zig-zag
        below is exact for every int and emits identical bits for the
        64-bit range the old encoding handled correctly.
        """
        v = int(value)
        self.write_varint(v << 1 if v >= 0 else ((-v) << 1) - 1)

    def write_run(self, values, nbits: int):
        """Write ``len(values)`` fields of ``nbits`` bits each — bit-for-bit
        the loop ``for v in values: write(v, nbits)``, but large runs pack
        through one vectorized ``np.packbits`` instead of the per-value
        accumulator (the dense-counts encode hot path)."""
        arr = np.asarray(values, np.int64).reshape(-1)
        n = arr.size
        if nbits == 0 or n == 0:
            return
        if n * nbits < 512 or nbits > 62:
            for v in arr:
                self.write(int(v), nbits)
            return
        arr = arr & ((np.int64(1) << nbits) - np.int64(1))
        bits = ((arr[:, None] >> np.arange(nbits - 1, -1, -1)) & 1) \
            .astype(np.uint8).reshape(-1)
        if self.nbits:      # prepend the pending sub-byte accumulator bits
            pend = np.array([(self.acc >> (self.nbits - 1 - i)) & 1
                             for i in range(self.nbits)], np.uint8)
            bits = np.concatenate([pend, bits])
        whole = (bits.size // 8) * 8
        self.buf.extend(np.packbits(bits[:whole]).tobytes())
        acc = 0
        for bit in bits[whole:]:
            acc = (acc << 1) | int(bit)
        self.acc = acc
        self.nbits = bits.size - whole

    def write_rice(self, value: int, b: int):
        """Golomb–Rice with divisor 2**b: quotient unary + b-bit remainder."""
        q = int(value) >> b
        for _ in range(q):
            self.write(1, 1)
        self.write(0, 1)
        self.write(int(value) & ((1 << b) - 1), b)

    def write_f64(self, value: float):
        for byte in struct.pack("<d", float(value)):
            self.write(byte, 8)

    def getvalue(self) -> bytes:
        out = bytearray(self.buf)
        if self.nbits:
            out.append((self.acc << (8 - self.nbits)) & 0xFF)
        return bytes(out)


class BitReader:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0  # bit position

    def read(self, nbits: int) -> int:
        out = 0
        for _ in range(nbits):
            byte = self.data[self.pos >> 3]
            bit = (byte >> (7 - (self.pos & 7))) & 1
            out = (out << 1) | bit
            self.pos += 1
        return out

    def read_varint(self) -> int:
        shift, out = 0, 0
        while True:
            cont = self.read(1)
            chunk = self.read(7)
            out |= chunk << shift
            shift += 7
            if not cont:
                return out

    def read_svarint(self) -> int:
        z = self.read_varint()
        return (z >> 1) if (z & 1) == 0 else -((z + 1) >> 1)

    def read_rice(self, b: int) -> int:
        q = 0
        while self.read(1):
            q += 1
        return (q << b) | self.read(b)

    def read_f64(self) -> float:
        raw = bytes(self.read(8) for _ in range(8))
        return struct.unpack("<d", raw)[0]

    # Bulk (run) reads. The base-class implementations are the plain loops —
    # the oracle the vectorized FastBitReader is asserted against bit for
    # bit; the decode paths below call only these run methods so both
    # readers share one traversal of the stream layout.

    def read_bytes(self, n: int) -> bytes:
        """``n`` bytes at the current (arbitrary) bit alignment."""
        return bytes(self.read(8) for _ in range(n))

    def read_uint_run(self, n: int, nbits: int) -> np.ndarray:
        """``n`` unsigned ``nbits``-bit fields -> int64 array."""
        return np.array([self.read(nbits) for _ in range(n)], np.int64)

    def read_varint_run(self, n: int) -> np.ndarray:
        """``n`` consecutive varints -> int64 array."""
        return np.array([self.read_varint() for _ in range(n)], np.int64)

    def read_svarint_run(self, n: int) -> np.ndarray:
        """``n`` consecutive zig-zag varints -> int64 array."""
        return np.array([self.read_svarint() for _ in range(n)], np.int64)

    def read_rice_run(self, n: int, b: int) -> np.ndarray:
        """``n`` consecutive Golomb-Rice values -> int64 array."""
        return np.array([self.read_rice(b) for _ in range(n)], np.int64)


class FastBitReader(BitReader):
    """Vectorized drop-in for ``BitReader`` (same stream, same results).

    Decoding cost on a cold-start blob is dominated by long homogeneous
    runs — dense ``l_h``-bit count blocks, non-zero value runs, Rice-coded
    delta runs, varint/svarint arrays. The base class walks those one *bit*
    at a time in Python; this subclass unpacks the whole blob into a bit
    array once (``np.unpackbits``, MSB-first — exactly the writer's order)
    and decodes each run with reshape/dot numpy passes:

      * fixed-width runs: an ``(n, nbits)`` gather @ a power-of-two vector;
      * varint runs: LEB128 chunks are a whole byte of stream each, so a
        run is chunk-aligned from its start — continuation bits land on a
        stride-8 slice, value boundaries fall out of ``flatnonzero``, and
        payload chunks fold with shifted ``np.add.reduceat``;
      * Rice runs: a vectorized unary scan — zero positions in a window,
        each value's terminator found by successor-pointer doubling
        (``searchsorted`` jump table), quotients from position gaps.

    Scalar reads use byte-sliced ``int.from_bytes`` instead of the per-bit
    loop. Runs that could overflow int64 (fields > 62 bits, varints past 9
    chunks) fall back to the exact scalar loop. Bit-for-bit equivalence
    with the oracle is asserted in tests/test_storage_vectorized.py.
    """

    def __init__(self, data: bytes):
        super().__init__(data)
        self._bits = np.unpackbits(np.frombuffer(data, np.uint8))

    # ------------------------------------------------------------- scalar IO

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        pos = self.pos
        end = pos + nbits
        last = (end + 7) >> 3
        if last > len(self.data):
            # A short slice would zero-pad and silently return wrong data
            # on truncated streams; fail like the oracle reader instead.
            raise IndexError("bit read overruns the synopsis stream")
        chunk = int.from_bytes(self.data[pos >> 3:last], "big")
        self.pos = end
        return (chunk >> ((-end) & 7)) & ((1 << nbits) - 1)

    def read_bytes(self, n: int) -> bytes:
        """``n`` bytes at the current (arbitrary) bit alignment."""
        if n == 0:
            return b""
        if (self.pos & 7) == 0:          # aligned: direct slice
            start = self.pos >> 3
            if start + n > len(self.data):
                raise IndexError("byte read overruns the synopsis stream")
            self.pos += 8 * n
            return bytes(self.data[start:start + n])
        return self.read_uint_run(n, 8).astype(np.uint8).tobytes()

    # --------------------------------------------------------------- run IO

    def read_uint_run(self, n: int, nbits: int) -> np.ndarray:
        """``n`` unsigned ``nbits``-bit fields -> int64 array (vectorized)."""
        if n == 0:
            return np.zeros(0, np.int64)
        if nbits == 0:
            return np.zeros(n, np.int64)
        if nbits > 62:                   # int64 headroom: exact scalar path
            return super().read_uint_run(n, nbits)
        pos = self.pos
        field = self._bits[pos:pos + n * nbits].astype(np.int64)
        field = field.reshape(n, nbits)
        weights = np.int64(1) << np.arange(nbits - 1, -1, -1, dtype=np.int64)
        self.pos = pos + n * nbits
        return field @ weights

    def read_varint_run(self, n: int) -> np.ndarray:
        """``n`` consecutive varints -> int64 array (vectorized)."""
        if n == 0:
            return np.zeros(0, np.int64)
        pos = self.pos
        bits = self._bits
        max_chunks = (bits.size - pos) >> 3
        cont = bits[pos:pos + 8 * max_chunks:8]
        ends = np.flatnonzero(cont == 0)
        if ends.size < n:
            raise ValueError("varint run overruns the stream")
        ends = ends[:n]
        starts = np.empty(n, np.int64)
        starts[0] = 0
        starts[1:] = ends[:-1] + 1
        if int((ends - starts).max()) + 1 > 9:
            # 9 chunks (9 * 7 = 63 payload bits) is exactly the int64 range;
            # a 10-chunk varint cannot land in the run's int64 array (the
            # scalar oracle overflows identically, just less legibly).
            raise OverflowError(
                "varint run value exceeds int64; run reads carry int64 arrays")
        total = int(ends[-1]) + 1
        payload = bits[pos:pos + 8 * total].astype(np.int64).reshape(total, 8)
        w7 = np.int64(1) << np.arange(6, -1, -1, dtype=np.int64)
        chunk_vals = payload[:, 1:] @ w7
        shifts = np.arange(total, dtype=np.int64) - np.repeat(
            starts, ends - starts + 1)
        self.pos = pos + 8 * total
        return np.add.reduceat(chunk_vals << (7 * shifts), starts)

    def read_svarint_run(self, n: int) -> np.ndarray:
        """``n`` consecutive zig-zag varints -> int64 array (vectorized)."""
        z = self.read_varint_run(n)
        # -(z >> 1) - 1 (not -((z + 1) >> 1)) so z = 2**63 - 1 cannot
        # overflow int64 before the negation.
        return np.where(z & 1, -(z >> 1) - 1, z >> 1)

    def read_rice_run(self, n: int, b: int) -> np.ndarray:
        """``n`` consecutive Golomb-Rice values -> int64 array.

        Vectorized unary scan: find the zero bits in a window, build a
        successor jump table (``searchsorted``: terminator -> next
        terminator ``1 + b`` bits later at the earliest), extract the chain
        of ``n`` terminators by pointer doubling, then quotients are
        position gaps and remainders a fixed-width gather. The window grows
        (rare: outlier quotients) until the chain fits.
        """
        if n == 0:
            return np.zeros(0, np.int64)
        pos = self.pos
        bits = self._bits
        window = max(1024, n * (b + 8))
        while True:
            zw = np.flatnonzero(bits[pos:pos + window] == 0)
            term = self._rice_chain(zw, n, b)
            if term is not None:
                break
            if pos + window >= bits.size:
                raise ValueError("rice run overruns the stream")
            window *= 4
        term = term + pos                   # absolute terminator positions
        prev_end = np.empty(n, np.int64)
        prev_end[0] = pos
        prev_end[1:] = term[:-1] + 1 + b
        q = term - prev_end
        if b:                               # remainders trail each terminator
            gather = term[:, None] + 1 + np.arange(b, dtype=np.int64)
            weights = np.int64(1) << np.arange(b - 1, -1, -1, dtype=np.int64)
            rem = bits[gather].astype(np.int64) @ weights
        else:
            rem = np.zeros(n, np.int64)
        self.pos = int(term[-1]) + 1 + b
        return (q << b) | rem

    @staticmethod
    def _rice_chain(zw: np.ndarray, n: int, b: int):
        """First ``n`` Rice terminators among window zeros ``zw`` (relative
        positions), or None if the window is too small. Successor-pointer
        doubling: O(log n) numpy passes instead of a per-value loop."""
        nz = zw.size
        if nz == 0:
            return None
        # succ[k]: index of the first zero >= zw[k] + 1 + b (the earliest
        # possible next terminator); nz = exhausted sentinel (maps to self).
        succ = np.empty(nz + 1, np.int64)
        succ[:nz] = np.searchsorted(zw, zw + 1 + b)
        succ[nz] = nz
        chain = np.empty(n, np.int64)
        chain[0] = 0                        # first zero in window terminates v0
        filled = 1
        jump = succ                         # jump == succ^filled
        while filled < n:
            take = min(filled, n - filled)
            chain[filled:filled + take] = jump[chain[:take]]
            filled += take
            if filled < n:
                jump = jump[jump]
        if int(chain[-1]) >= nz:            # ran off the window: grow it
            return None
        return zw[chain]


# ---------------------------------------------------------------------------
# Edge / value array codecs
# ---------------------------------------------------------------------------


def _dyadic_exponent(arr: np.ndarray, cap: int = 40) -> int | None:
    """Smallest p such that arr * 2^p is integral (None if > cap)."""
    a = np.asarray(arr, np.float64)
    for p in range(cap + 1):
        scaled = a * (1 << p)
        if np.all(np.abs(scaled - np.round(scaled)) < 1e-6) and \
           np.all(np.abs(scaled) < 2**62):
            return p
    return None


def _encode_values(w: BitWriter, arr: np.ndarray):
    """Dyadic-rational array as (flag, p, varint deltas); f64 fallback."""
    arr = np.asarray(arr, np.float64)
    p = _dyadic_exponent(arr)
    if p is None:
        w.write(1, 1)
        for v in arr:
            w.write_f64(v)
        return
    w.write(0, 1)
    w.write_varint(p)
    ints = np.round(arr * (1 << p)).astype(np.int64)
    prev = 0
    for v in ints:
        w.write_svarint(int(v) - prev)
        prev = int(v)


def _decode_values(r: BitReader, n: int) -> np.ndarray:
    if r.read(1):
        return np.array([r.read_f64() for _ in range(n)], np.float64)
    p = r.read_varint()
    out = np.cumsum(r.read_svarint_run(n))
    return out.astype(np.float64) / (1 << p)


def _bits_for(max_val: float) -> int:
    """ℓ_h per Eq. 13."""
    return max(1, int(math.ceil(math.log2(1.0 + max(0.0, float(max_val))))))


def _rice_param(mean: float) -> int:
    """Near-optimal Rice divisor exponent for geometric-ish deltas."""
    if mean <= 1.0:
        return 0
    return max(0, int(round(math.log2(mean))))


def _encode_counts(w: BitWriter, H: np.ndarray):
    """Dense (ℓ_h bits/cell) vs sparse (Rice deltas + ℓ_h counts): smaller wins."""
    flat = np.asarray(np.round(H), np.int64).reshape(-1)
    n = flat.size
    lh = _bits_for(flat.max() if n else 0)
    nz = np.flatnonzero(flat)
    theta = nz.size
    dense_bits = n * lh
    mean_delta = (n / max(theta, 1))
    b = _rice_param(mean_delta)
    deltas = np.diff(nz, prepend=-1) - 1  # gaps between non-zeros
    sparse_bits = 32 + theta * lh + int(((deltas >> b) + 1 + b).sum())
    w.write_varint(lh)
    if dense_bits <= sparse_bits:
        w.write(0, 1)  # I_h: dense
        w.write_run(flat, lh)
    else:
        w.write(1, 1)  # I_h: sparse
        w.write_varint(theta)
        w.write_varint(b)
        for d in deltas:
            w.write_rice(int(d), b)
        w.write_run(flat[nz], lh)


def _decode_counts(r: BitReader, shape) -> np.ndarray:
    n = int(np.prod(shape))
    lh = r.read_varint()
    if r.read(1) == 0:
        flat = r.read_uint_run(n, lh)
    else:
        theta = r.read_varint()
        b = r.read_varint()
        idxs = np.cumsum(r.read_rice_run(theta, b) + 1) - 1
        flat = np.zeros(n, np.int64)
        flat[idxs] = r.read_uint_run(theta, lh)
    return flat.astype(np.float64).reshape(shape)


# ---------------------------------------------------------------------------
# Histogram codecs
# ---------------------------------------------------------------------------


def _encode_dim(w: BitWriter, edges, u, vmin, vmax):
    k = len(u)
    w.write_varint(k)
    _encode_values(w, edges)
    _encode_values(w, vmin)
    _encode_values(w, vmax)
    for val in np.asarray(u, np.int64):
        w.write_varint(int(val))


def _decode_dim(r: BitReader):
    k = r.read_varint()
    edges = _decode_values(r, k + 1)
    vmin = _decode_values(r, k)
    vmax = _decode_values(r, k)
    u = r.read_varint_run(k).astype(np.float64)
    return edges, u, vmin, vmax


# ---------------------------------------------------------------------------
# Top level
# ---------------------------------------------------------------------------


def encode(ph: PairwiseHist, framed: bool = True) -> bytes:
    """Serialize ``ph`` to a synopsis blob.

    By default the bit-stream is wrapped in the CRC integrity frame
    (``frame_blob``); pass ``framed=False`` for the raw legacy stream.
    """
    payload = _encode_payload(ph)
    return frame_blob(payload) if framed else payload


def _encode_payload(ph: PairwiseHist) -> bytes:
    w = BitWriter()
    for byte in _MAGIC:
        w.write(byte, 8)
    w.write_varint(ph.n_rows)
    w.write_varint(ph.n_sampled)
    w.write_varint(ph.d)
    w.write_varint(ph.params.min_points)
    w.write_f64(ph.params.alpha)
    w.write_varint(ph.params.s1_max)
    w.write_varint(ph.params.s2_max)

    for col in ph.columns:
        kind_code = {"int": 0, "float": 1, "categorical": 2}[col.kind]
        w.write(kind_code, 2)
        w.write_f64(col.offset)
        w.write_f64(col.scale)
        w.write_f64(col.mu)
        w.write_varint(col.n_null)
        name = col.name.encode()
        w.write_varint(len(name))
        for byte in name:
            w.write(byte, 8)
        cats = "\x00".join(str(c) for c in col.categories).encode()
        w.write_varint(len(cats))
        for byte in cats:
            w.write(byte, 8)

    for hist in ph.hists:
        _encode_dim(w, hist.edges, hist.u, hist.vmin, hist.vmax)
        _encode_counts(w, hist.h)

    w.write_varint(len(ph.pairs))
    for (i, j), pr in sorted(ph.pairs.items()):
        w.write_varint(i)
        w.write_varint(j)
        _encode_dim(w, pr.ex, pr.ux, pr.vminx, pr.vmaxx)
        _encode_dim(w, pr.ey, pr.uy, pr.vminy, pr.vmaxy)
        _encode_counts(w, pr.H)
    return w.getvalue()


def _centre_bounds_np(h, u, vmin, vmax, min_points, crit_table, mu, s_max):
    """NumPy re-derivation of Eq. 10 (mirror of refine.centre_bounds)."""
    h = np.asarray(h, float)
    u = np.asarray(u, float)
    s = np.clip(np.ceil(np.cbrt(2.0 * np.maximum(u, 0.0))), 1, s_max)
    delta = (vmax - vmin) / np.maximum(s, 1.0)
    chi = crit_table[np.clip(s.astype(int), 0, len(crit_table) - 1)]
    chi = np.where(np.isfinite(chi), chi, 0.0)
    hsafe = np.maximum(h, 1.0)
    spread = (delta / 6.0) * np.sqrt(3.0 * chi * (s**2 - 1.0) / hsafe)
    c_lo_pass = vmin + (s - 1.0) * delta / 2.0 - spread
    c_hi_pass = vmin + (s + 1.0) * delta / 2.0 + spread
    shift = (u - 1.0) * u * mu / (2.0 * hsafe)
    fail = h < min_points
    cminus = np.where(fail, vmin + shift, c_lo_pass)
    cplus = np.where(fail, vmax - shift, c_hi_pass)
    mid = 0.5 * (vmin + vmax)
    degenerate = u <= 1.0
    cminus = np.where(degenerate, mid, cminus)
    cplus = np.where(degenerate, mid, cplus)
    cminus = np.clip(cminus, vmin, vmax)
    cplus = np.clip(cplus, cminus, vmax)
    return cminus, cplus


def decode(data: bytes, vectorized: bool = True) -> PairwiseHist:
    """Reconstruct the runtime ``PairwiseHist`` from an encoded blob.

    ``vectorized=True`` (default) decodes through ``FastBitReader`` —
    numpy bulk passes over the long homogeneous runs, >=10x faster on
    real synopses. ``vectorized=False`` walks the identical stream with
    the pure-Python ``BitReader`` oracle; the two are bit-for-bit equal
    (asserted in tests/test_storage_vectorized.py).

    The integrity frame (when present) is verified *before* any bit-level
    parsing, and structural parse failures are re-raised as
    ``IntegrityError`` — a corrupted blob raises a typed error rather than
    returning wrong data or hanging.
    """
    payload = unframe_blob(data)
    try:
        return _decode_payload(payload, vectorized)
    except IntegrityError:
        raise
    except (ValueError, IndexError, KeyError, OverflowError, MemoryError,
            UnicodeDecodeError, struct.error) as exc:
        raise IntegrityError(f"corrupt synopsis stream: {exc!r}") from exc


def _decode_payload(data: bytes, vectorized: bool) -> PairwiseHist:
    r = (FastBitReader if vectorized else BitReader)(data)
    magic = r.read_bytes(4)
    if magic != _MAGIC:
        raise IntegrityError("bad synopsis magic")
    n_rows = r.read_varint()
    n_sampled = r.read_varint()
    d = r.read_varint()
    min_points = r.read_varint()
    alpha = r.read_f64()
    s1_max = r.read_varint()
    s2_max = r.read_varint()
    params = BuildParams(n_samples=n_sampled, alpha=alpha,
                         m_frac=min_points / max(n_sampled, 1),
                         s1_max=s1_max, s2_max=s2_max)
    crit = chi2lib.build_crit_table(alpha, max(s1_max, s2_max))

    columns = []
    for _ in range(d):
        kind = ("int", "float", "categorical")[r.read(2)]
        offset = r.read_f64()
        scale = r.read_f64()
        mu = r.read_f64()
        n_null = r.read_varint()
        nlen = r.read_varint()
        name = r.read_bytes(nlen).decode()
        clen = r.read_varint()
        raw = r.read_bytes(clen).decode()
        cats = tuple(raw.split("\x00")) if raw else ()
        columns.append(ColumnInfo(name=name, kind=kind, offset=offset,
                                  scale=scale, categories=cats,
                                  n_null=n_null, mu=mu))

    hists = []
    for i in range(d):
        edges, u, vmin, vmax = _decode_dim(r)
        h = _decode_counts(r, (len(u),))
        c = 0.5 * (vmin + vmax)
        cm, cp = _centre_bounds_np(h, u, vmin, vmax, min_points, crit,
                                   columns[i].mu, s1_max)
        hists.append(Hist1D(edges=edges, k=np.int32(len(u)), h=h, u=u,
                            vmin=vmin, vmax=vmax, c=c, cminus=cm, cplus=cp))

    def fold_map(edges1, edges_pair):
        """1-D bin -> containing pair row (pair edges ⊆ 1-D edges)."""
        mids = 0.5 * (edges1[:-1] + edges1[1:])
        idx = np.searchsorted(edges_pair, mids, side="right") - 1
        return np.clip(idx, 0, max(edges_pair.size - 2, 0)).astype(np.int32)

    pairs = {}
    n_pairs = r.read_varint()
    for _ in range(n_pairs):
        i = r.read_varint()
        j = r.read_varint()
        ex, ux, vminx, vmaxx = _decode_dim(r)
        ey, uy, vminy, vmaxy = _decode_dim(r)
        H = _decode_counts(r, (len(ux), len(uy)))
        pairs[(i, j)] = PairHist(
            ex=ex, ey=ey, kx=np.int32(len(ux)), ky=np.int32(len(uy)), H=H,
            hx=H.sum(1), ux=ux, vminx=vminx, vmaxx=vmaxx,
            hy=H.sum(0), uy=uy, vminy=vminy, vmaxy=vmaxy,
            fold_x=fold_map(hists[i].edges, ex),
            fold_y=fold_map(hists[j].edges, ey),
        )

    return PairwiseHist(params=params, n_rows=n_rows, n_sampled=n_sampled,
                        columns=columns, hists=hists, pairs=pairs,
                        chi2_table=crit)


def blob_info(data: bytes) -> dict:
    """Cheap header peek: {bytes, n_rows, n_sampled, d} without full decode.

    Reads only the fixed-size preamble, so the cold catalog can report
    synopsis-bytes telemetry for registered blobs it has not decoded yet.
    Framed blobs are checksum-verified first; corruption raises
    ``IntegrityError``.
    """
    payload = unframe_blob(data)
    try:
        r = BitReader(payload)
        magic = r.read_bytes(4)
        if magic != _MAGIC:
            raise IntegrityError("bad synopsis magic")
        return {
            "bytes": len(data),
            "framed": bytes(data[:4]) == _FRAME_MAGIC,
            "n_rows": r.read_varint(),
            "n_sampled": r.read_varint(),
            "d": r.read_varint(),
        }
    except IntegrityError:
        raise
    except (ValueError, IndexError, OverflowError, struct.error) as exc:
        raise IntegrityError(f"corrupt synopsis header: {exc!r}") from exc


def eq12_bound(ph: PairwiseHist) -> int:
    """The paper's storage upper bound (Eq. 12), in bytes, for comparison."""
    d = ph.d

    def mbytes(col_idx):
        hist = ph.hists[col_idx]
        vmax = max(abs(float(hist.vmax.max() if len(hist.vmax) else 1)), 1.0)
        return max(1, int(math.ceil(math.log2(vmax + 2) / 8)))

    total = 29 + d + 4 * d * d
    for i in range(d):
        k_sum = 0
        for j in range(d):
            if i == j:
                continue
            pr = ph.pair(i, j)
            k_sum += int(pr.kx)
        k_i = int(ph.hists[i].k)
        total += (3 * mbytes(i) + 4) * (k_sum + k_i - (d - 1) * k_i + k_i)
    for (i, j), pr in ph.pairs.items():
        lh = _bits_for(pr.H.max() if pr.H.size else 0)
        total += math.ceil(int(pr.kx) * int(pr.ky) * lh / 8)
    return total


def synopsis_size_report(ph: PairwiseHist) -> dict:
    """Encoded size breakdown (bytes)."""
    blob = encode(ph)
    # Re-encode pieces for a rough breakdown.
    w = BitWriter()
    for hist in ph.hists:
        _encode_dim(w, hist.edges, hist.u, hist.vmin, hist.vmax)
        _encode_counts(w, hist.h)
    size_1d = len(w.getvalue())
    w = BitWriter()
    for pr in ph.pairs.values():
        _encode_dim(w, pr.ex, pr.ux, pr.vminx, pr.vmaxx)
        _encode_dim(w, pr.ey, pr.uy, pr.vminy, pr.vmaxy)
        _encode_counts(w, pr.H)
    size_2d = len(w.getvalue())
    return {
        "total": len(blob),
        "hists_1d": size_1d,
        "hists_2d": size_2d,
        "header_and_dicts": len(blob) - size_1d - size_2d,
        "eq12_bound": eq12_bound(ph),
    }
