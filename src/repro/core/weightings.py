"""Bin weightings (§5.3): Eq. 24–29.

Given aggregation column i and a predicate tree, weightings w^(i) estimate
how many points in each 1-D bin of column i satisfy the predicate:

    leaf on column j != i:  p = fold( H^(ij) @ beta^(j) ) / h^(i)     (Eq. 27)
    leaf on column j == i:  p = beta^(i)           (same-column: direct)
    AND:  p = prod_l p_l                                              (Eq. 25)
    OR:   p = 1 - prod_l (1 - p_l)                                    (Eq. 26)
    w = h^(i) * p                                                     (Eq. 24)

Bounds propagate through AND/OR monotonically (all p in [0,1]); Eq. 29 widens
them for sampling when rho < 1.

NumPy implementation (kernel oracle). The fused JAX/Pallas path is
``repro.core.fastpath`` / ``repro.kernels.weightings``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import coverage as covlib

Z_98 = 2.3263478740408408  # standard normal quantile for two-sided 98% CI


# ---------------------------------------------------------------------------
# Normalized predicate tree (planner output; see repro.core.query)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Leaf:
    """A single condition on one column."""

    col: int
    op: str
    value: float


@dataclasses.dataclass
class Consolidated:
    """A same-column group merged into a disjoint interval set (§5.2)."""

    col: int
    intervals: list


@dataclasses.dataclass
class Node:
    """AND / OR of children."""

    kind: str          # "and" | "or"
    children: list


def flat_and_leaves(tree):
    """Tree -> list of Leaf/Consolidated if it is a pure AND tree, else None.

    Pure AND trees are the batchable/fusable plan shape (repro.core.fastpath
    and the serving BatchScheduler); OR/nested trees evaluate via eval_tree.
    """
    if isinstance(tree, (Leaf, Consolidated)):
        return [tree]
    if isinstance(tree, Node) and tree.kind == "and":
        out = []
        for ch in tree.children:
            sub = flat_and_leaves(ch)
            if sub is None:
                return None
            out.extend(sub)
        return out
    return None


# ---------------------------------------------------------------------------
# Leaf probabilities
# ---------------------------------------------------------------------------


def _slice_beta(ph, leaf, h, u, vmin, vmax, mu):
    """Coverage + bounds of a Leaf/Consolidated on a given bin grid."""
    if isinstance(leaf, Consolidated):
        beta = covlib.coverage_intervals(leaf.intervals, h, u, vmin, vmax, mu)
    else:
        beta = covlib.coverage_single(leaf.op, leaf.value, h, u, vmin, vmax)
    blo, bhi = covlib.coverage_bounds(
        beta, h, u, ph.params.min_points, ph.chi2_table, ph.params.s1_max)
    return beta, blo, bhi


def leaf_prob(ph, agg_col: int, leaf):
    """Pr(P_l | bin t of 1-D hist agg_col) with bounds — Eq. 27 + fold."""
    j = leaf.col
    hist_i = ph.hists[agg_col]
    mu_j = ph.columns[j].mu
    if j == agg_col:
        beta = _slice_beta(ph, leaf, hist_i.h, hist_i.u, hist_i.vmin,
                           hist_i.vmax, mu_j)
        return beta  # (p, plo, phi) directly on the 1-D grid

    pr = ph.pair(agg_col, j)  # x-dim = agg_col, y-dim = j
    beta, blo, bhi = _slice_beta(ph, leaf, pr.hy, pr.uy, pr.vminy, pr.vmaxy,
                                 mu_j)
    # Denominator: the 1-D mass of each pair row — this *includes* rows
    # where column j is NULL (they fail the predicate; SQL semantics), which
    # hx excludes. Matches Eq. 27's h^(i) conditioning.
    denom = np.zeros(int(pr.kx))
    np.add.at(denom, pr.fold_x, hist_i.h)
    denom = np.maximum(denom, 1e-300)

    def fold(b):
        v = pr.H @ b                               # (kx,) matching mass
        p_row = np.clip(v / denom, 0.0, 1.0)       # Pr(P | pair x-row)
        return p_row[pr.fold_x]                    # gather onto the 1-D grid

    return fold(beta), fold(blo), fold(bhi)


# ---------------------------------------------------------------------------
# Tree evaluation
# ---------------------------------------------------------------------------


def eval_tree(ph, agg_col: int, node):
    """Returns (p, plo, phi), each (k_i,)."""
    if isinstance(node, (Leaf, Consolidated)):
        return leaf_prob(ph, agg_col, node)
    ps = [eval_tree(ph, agg_col, ch) for ch in node.children]
    if node.kind == "and":
        p = np.prod([x[0] for x in ps], axis=0)
        lo = np.prod([x[1] for x in ps], axis=0)
        hi = np.prod([x[2] for x in ps], axis=0)
    elif node.kind == "or":
        p = 1.0 - np.prod([1.0 - x[0] for x in ps], axis=0)
        lo = 1.0 - np.prod([1.0 - x[1] for x in ps], axis=0)
        hi = 1.0 - np.prod([1.0 - x[2] for x in ps], axis=0)
    else:
        raise ValueError(node.kind)
    return p, lo, hi


def weightings(ph, agg_col: int, tree, corrected_sampling_bounds: bool = False):
    """Full weightings vector + bounds for a query (Eq. 24–29).

    ``tree`` may be None (no WHERE clause): w = h, exact bounds.
    """
    hist = ph.hists[agg_col]
    h = hist.h
    if tree is None:
        return h.copy(), h.copy(), h.copy()
    p, plo, phi = eval_tree(ph, agg_col, tree)
    w = h * p
    wlo = h * plo
    whi = h * phi

    rho = ph.rho
    if rho < 1.0:
        # Eq. 29: widen by the two-sided 98% normal CI with finite-population
        # correction. Faithful mode uses the equation as printed; corrected
        # mode restores the binomial count-variance scale factor h_t.
        fpc = (ph.n_rows - ph.n_sampled) / max(ph.n_rows - 1, 1)
        blo = np.divide(wlo, h, out=np.zeros_like(wlo), where=h > 0)
        bhi = np.divide(whi, h, out=np.zeros_like(whi), where=h > 0)
        var_lo = blo * (1.0 - blo) * fpc
        var_hi = bhi * (1.0 - bhi) * fpc
        if corrected_sampling_bounds:
            var_lo = var_lo * h
            var_hi = var_hi * h
        wlo = wlo - Z_98 * np.sqrt(np.maximum(var_lo, 0.0))
        whi = whi + Z_98 * np.sqrt(np.maximum(var_hi, 0.0))

    wlo = np.clip(wlo, 0.0, w)
    whi = np.clip(whi, w, h)
    return w, wlo, whi
