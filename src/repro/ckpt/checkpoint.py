"""Fault-tolerant checkpointing.

Design points (per large-fleet practice):
  * atomic commits: write to ``step_XXXX.tmp/``, fsync, rename — a crash
    mid-save never corrupts the latest valid checkpoint;
  * integrity: every array file carries a sha256 in ``manifest.json``;
    restore verifies and *skips back* past corrupt/partial checkpoints;
  * keep-last-k garbage collection;
  * async save: the serialization happens on a worker thread off the train
    loop (double-buffered host copy first, so training can mutate on);
  * elastic restore: arrays are saved in *logical* (unsharded) form; restore
    re-shards onto whatever mesh is installed — resuming on a different
    device count (elastic scaling) is a first-class path, exercised in
    tests/test_ckpt.py with different XLA device counts;
  * multi-host note: on a real fleet each process would save only its
    addressable shards (same layout, per-process files); the single-process
    container exercises the full logic minus cross-host gather.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._worker: threading.Thread | None = None
        self._last_error: Exception | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save

    def save(self, step: int, state, blocking: bool = False):
        """Snapshot to host memory synchronously; serialize async."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree_util.tree_map(np.asarray, state)
        if self.async_save and not blocking:
            self._worker = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._worker.start()
        else:
            self._write(step, host_state)

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _write(self, step: int, host_state):
        try:
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "arrays": {}, "time": time.time()}
            for key, leaf in _tree_flatten_with_paths(host_state):
                arr = np.asarray(leaf)
                fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
                path = os.path.join(tmp, fname)
                with open(path, "wb") as fh:
                    np.save(fh, arr)
                    fh.flush()
                    os.fsync(fh.fileno())
                with open(path, "rb") as fh:
                    digest = hashlib.sha256(fh.read()).hexdigest()
                manifest["arrays"][key] = {
                    "file": fname, "sha256": digest,
                    "shape": list(arr.shape), "dtype": str(arr.dtype)}
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as fh:
                json.dump(manifest, fh)
                fh.flush()
                os.fsync(fh.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()
        except Exception as exc:  # surfaced on next wait()
            self._last_error = exc

    def _gc(self):
        steps = self.all_steps()
        for step in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{step:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _verify(self, path: str) -> dict | None:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            return None
        try:
            with open(mpath) as fh:
                manifest = json.load(fh)
            for key, info in manifest["arrays"].items():
                fpath = os.path.join(path, info["file"])
                with open(fpath, "rb") as fh:
                    if hashlib.sha256(fh.read()).hexdigest() != info["sha256"]:
                        return None
            return manifest
        except (OSError, ValueError, KeyError):
            return None

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Skips back past corrupt checkpoints. Returns
        (step, state) or (None, None) if nothing valid exists.

        ``shardings``: optional pytree (matching ``like``) of NamedShardings
        for elastic re-sharding onto the current mesh.
        """
        candidates = self.all_steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for cand in reversed(candidates):
            path = os.path.join(self.dir, f"step_{cand:010d}")
            manifest = self._verify(path)
            if manifest is None:
                continue  # corrupt/partial: skip back
            arrays = {}
            for key, info in manifest["arrays"].items():
                arrays[key] = np.load(os.path.join(path, info["file"]))
            flat_like = _tree_flatten_with_paths(like)
            if set(k for k, _ in flat_like) != set(arrays):
                continue  # structure mismatch (different model)
            leaves = [arrays[k] for k, _ in flat_like]
            treedef = jax.tree_util.tree_structure(like)
            state = jax.tree_util.tree_unflatten(treedef, leaves)
            if shardings is not None:
                state = jax.tree_util.tree_map(
                    lambda a, sh: jax.device_put(a, sh), state, shardings)
            return cand, state
        return None, None
