# Launch entry points: mesh construction, the multi-pod dry-run, training and
# serving drivers. NOTE: dryrun.py must be the process entry (it sets
# XLA_FLAGS before any jax import).
