import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). REPRO_DRYRUN_DEVICES overrides for debug meshes.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --multi-pod
    REPRO_DRYRUN_DEVICES=8 ... --debug-mesh                     # (2,2)/(2,2,2)

Results are cached as JSON under benchmarks/dryrun_results/ (one file per
cell); --force recomputes. EXPERIMENTS.md §Dry-run/§Roofline read these.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.sharding import rules as R  # noqa: E402
from repro.train.optimizer import Hyper  # noqa: E402
from repro.train.step import TrainState, make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "dryrun_results")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _result_bytes(segment: str) -> int:
    """Largest typed shape in the result segment (handles -start tuples)."""
    best = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire bytes per collective kind from the partitioned HLO.

    Result shapes in the partitioned module are per-device. Ring model:
      all-gather:    (g-1)/g x result          (result = gathered)
      all-reduce:    2 (g-1)/g x result
      reduce-scatter:(g-1)   x result          (result = scattered shard)
      all-to-all:    (g-1)/g x result
      collective-permute: 1 x result
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_seg, kind = m.group(1), m.group(2)
        size = _result_bytes(result_seg)
        g = _group_size(line)
        factor = {"all-gather": (g - 1) / g,
                  "all-reduce": 2 * (g - 1) / g,
                  "reduce-scatter": float(g - 1),
                  "all-to-all": (g - 1) / g,
                  "collective-permute": 1.0}[kind]
        rec = out.setdefault(kind, {"count": 0, "result_bytes": 0,
                                    "wire_bytes_per_device": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += size
        rec["wire_bytes_per_device"] += size * factor
    return out


def _shard_one(mesh, sds, axes):
    spec = R.logical_to_spec(axes, shape=sds.shape)
    return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                sharding=NamedSharding(mesh, spec))


def _shard_sds(mesh, sds_tree, axes_tree):
    """Attach divisibility-pruned NamedShardings to a ShapeDtypeStruct tree."""
    flat_sds, treedef = jax.tree_util.tree_flatten(sds_tree)
    flat_ax = treedef.flatten_up_to(axes_tree)
    out = [_shard_one(mesh, s, a) for s, a in zip(flat_sds, flat_ax)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _serve_dtype(sds_tree):
    """Serving params are bf16 (inference weights)."""
    def conv(sds):
        if jnp.issubdtype(sds.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(sds.shape, jnp.bfloat16)
        return sds
    return jax.tree_util.tree_map(conv, sds_tree)


# §Perf hillclimb variants: config overrides + train-step kwargs. A variant
# cost pass (--cost --variant NAME) produces {arch}__{shape}__single_pod_
# cost__{NAME}.json for before/after comparison against the baseline.
VARIANTS = {
    "cast_bf16": {"step_kwargs": {"cast_bf16": True}},
    "moe_sort": {"cfg": {"moe_impl": "sort"}},
    "ssm_mem": {"cfg": {"ssm_chunk": 128, "ssm_bf16_intra": True}},
    # residual stream sharded over SEQ instead of D (kills the per-matmul
    # f32 activation all-gathers; saved remat carries stay sharded)
    "seq_sp": {"rules": {"resid_seq": ("model",), "resid_embed": ()}},
    # bf16 RMSNorm with f32 accumulation: keeps the residual all-gathers in
    # bf16 (the f32 upcast otherwise gets hoisted before the gather)
    "bf16_norm": {"cfg": {"norm_upcast": False}},
    # replicate the residual at block ENTRY: one all-gather per layer at the
    # saved-carry boundary instead of per-matmul gathers from propagation
    "zero_r": {"rules": {"blk_in_embed": ()}},
    # zero_r + bf16 norm (the entry gather then carries a bf16 tensor)
    "zero_r_bf16": {"rules": {"blk_in_embed": ()},
                    "cfg": {"norm_upcast": False}},
    # save TP-matmul outputs under remat: backward stops re-running the
    # forward's boundary collectives (trades HBM for wire)
    "remat_dots": {"cfg": {"remat_policy": "dots"}},
    # deployable middle ground: save ONLY the named per-block projections
    # (the all-reduce-carrying tensors) — most of the wire win, bounded HBM
    "remat_names": {"cfg": {"remat_policy": "blk_out"}},
    "combo": {"step_kwargs": {"cast_bf16": True},
              "cfg": {"moe_impl": "sort", "ssm_chunk": 128,
                      "ssm_bf16_intra": True},
              "rules": {"resid_seq": ("model",), "resid_embed": ()}},
}


def arch_rules(cfg, model_size: int) -> dict:
    rules = dict(R.LOGICAL_RULES)
    heads_ok = cfg.heads_shardable and cfg.n_heads % model_size == 0
    kv_ok = cfg.n_kv > 0 and cfg.n_kv % model_size == 0
    rules["heads"] = ("model",) if heads_ok else ()
    # KV cache: shard heads when they divide the tensor axis; otherwise fall
    # back to sequence-sharded KV (distributed-softmax decode).
    rules["kv_heads"] = ("model",) if kv_ok else ()
    rules["kv_seq"] = () if kv_ok else ("model",)
    return rules


def lower_cell(arch: str, shape: str, multi_pod: bool, debug_mesh: bool,
               unrolled: bool = False, n_layers: int | None = None,
               variant: str | None = None):
    """Returns (lowered, meta) for one cell."""
    import dataclasses as _dc
    cfg = get_config(arch)
    step_kwargs = {}
    if variant:
        spec = VARIANTS[variant]
        if spec.get("cfg"):
            cfg = _dc.replace(cfg, **spec["cfg"])
        step_kwargs = dict(spec.get("step_kwargs", {}))
    if n_layers is not None:
        cfg = _dc.replace(cfg, n_layers=n_layers)
    if unrolled:
        cfg = _dc.replace(cfg, force_unroll=True)
    ok, why = S.shape_supported(cfg, shape)
    if not ok:
        return None, {"skipped": True, "reason": why}
    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    rules = arch_rules(cfg, model_size)
    if variant and VARIANTS[variant].get("rules"):
        rules.update(VARIANTS[variant]["rules"])
    R.set_mesh(mesh, rules)
    info = S.SHAPES[shape]
    key = jax.random.PRNGKey(0)

    if info["kind"] == "train":
        param_sds = jax.eval_shape(lambda: M.init_params(cfg, key))
        param_ax = M.param_logical_axes(cfg)
        opt_sds = {"mu": param_sds, "nu": param_sds}
        opt_ax = {"mu": param_ax, "nu": param_ax}
        state_sds = TrainState(
            params=_shard_sds(mesh, param_sds, param_ax),
            opt=_shard_sds(mesh, opt_sds, opt_ax),
            step=jax.ShapeDtypeStruct(
                (), jnp.int32,
                sharding=NamedSharding(mesh, R.logical_to_spec(()))))
        bspecs = S.batch_specs(cfg, info["batch"], info["seq"])
        batch_sds = {k: _shard_one(mesh, sds, ax)
                     for k, (sds, ax) in bspecs.items()}
        train_step = make_train_step(cfg, Hyper(), **step_kwargs)
        state_sh = jax.tree_util.tree_map(lambda s: s.sharding, state_sds)
        fn = jax.jit(train_step, donate_argnums=(0,),
                     out_shardings=(state_sh, None))
        lowered = fn.lower(state_sds, batch_sds)
    else:
        param_sds = _serve_dtype(jax.eval_shape(lambda: M.init_params(cfg, key)))
        param_sds = _shard_sds(mesh, param_sds, M.param_logical_axes(cfg))
        cache_sds, cache_ax = S.cache_specs(cfg, info["batch"], info["seq"])
        cache_sds = _shard_sds(mesh, cache_sds, cache_ax)
        if info["kind"] == "prefill":
            tok_sds, tok_ax = S.prompt_specs(cfg, info["batch"], info["seq"])
        else:
            tok_sds, tok_ax = S.token_specs(cfg, info["batch"])
        tok_sds = _shard_one(mesh, tok_sds, tok_ax)
        step_fn = M.prefill if info["kind"] == "prefill" else M.decode_step

        def serve_step(params, tok, cache):
            return step_fn(params, cfg, tok, cache)

        cache_sh = jax.tree_util.tree_map(lambda s: s.sharding, cache_sds)
        fn = jax.jit(serve_step, donate_argnums=(2,),
                     out_shardings=(None, cache_sh))
        lowered = fn.lower(param_sds, tok_sds, cache_sds)
    meta = {"mesh": tuple(mesh.devices.shape), "n_devices": mesh.devices.size}
    return lowered, meta


def run_cell(arch: str, shape: str, multi_pod: bool, debug_mesh: bool = False,
             keep_text: bool = False, unrolled: bool = False,
             n_layers: int | None = None, variant: str | None = None) -> dict:
    t0 = time.time()
    result = {"arch": arch, "shape": shape, "unrolled": unrolled,
              "n_layers": n_layers, "variant": variant,
              "mesh": "multi_pod" if multi_pod else "single_pod"}
    try:
        lowered, meta = lower_cell(arch, shape, multi_pod, debug_mesh,
                                   unrolled=unrolled, n_layers=n_layers,
                                   variant=variant)
        if lowered is None:
            result.update(meta)
            return result
        result.update(meta)
        result["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = time.time() - t1
        try:
            mem = compiled.memory_analysis()
            result["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as exc:  # CPU backend may not support it
            result["memory_analysis"] = {"error": str(exc)}
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            result["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and
                (k in ("flops", "bytes accessed", "optimal_seconds")
                 or k.startswith("bytes accessed"))}
        except Exception as exc:
            result["cost_analysis"] = {"error": str(exc)}
        hlo = compiled.as_text()
        result["collectives"] = parse_collectives(hlo)
        result["hlo_bytes"] = len(hlo)
        if keep_text:
            result["hlo_text"] = hlo
        result["ok"] = True
    except Exception as exc:
        result["ok"] = False
        result["error"] = f"{type(exc).__name__}: {exc}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["total_s"] = time.time() - t0
    return result


def cell_path(arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def _combine_costs(full: dict, u1: dict, u2: dict, n_super: int) -> dict:
    """true = full + (n_super - 1) * (U2 - U1), per metric.

    XLA counts while bodies once, so the full (scan) program already carries
    exactly ONE superblock's cost; two shallow *inlined* variants measure the
    marginal cost of one more superblock (flops, bytes, collectives).
    """
    out = {"method": "U1/U2 extrapolation", "n_super": n_super}
    scale = n_super - 1

    def delta(key):
        a = u2.get("cost_analysis", {}).get(key, 0.0)
        b = u1.get("cost_analysis", {}).get(key, 0.0)
        return max(a - b, 0.0)

    cost = {}
    for key in ("flops", "bytes accessed"):
        base = full.get("cost_analysis", {}).get(key, 0.0)
        cost[key] = base + scale * delta(key)
    out["cost_analysis"] = cost

    coll = {}
    kinds = set(full.get("collectives", {})) | set(u1.get("collectives", {})) \
        | set(u2.get("collectives", {}))
    for kind in kinds:
        f = full.get("collectives", {}).get(kind, {})
        a = u1.get("collectives", {}).get(kind, {})
        b = u2.get("collectives", {}).get(kind, {})
        dw = max(b.get("wire_bytes_per_device", 0.0)
                 - a.get("wire_bytes_per_device", 0.0), 0.0)
        dc = max(b.get("count", 0) - a.get("count", 0), 0)
        coll[kind] = {
            "count": f.get("count", 0) + scale * dc,
            "wire_bytes_per_device": (f.get("wire_bytes_per_device", 0.0)
                                      + scale * dw),
        }
    out["collectives"] = coll
    out["n_devices"] = full.get("n_devices")
    out["memory_analysis"] = full.get("memory_analysis")
    out["u1_compile_s"] = u1.get("compile_s")
    out["u2_compile_s"] = u2.get("compile_s")
    out["ok"] = full.get("ok", False) and u1.get("ok", False) \
        and u2.get("ok", False)
    for src, name in ((u1, "u1"), (u2, "u2")):
        if not src.get("ok"):
            out[f"{name}_error"] = src.get("error")
    return out


def run_cost_cell(arch: str, shape: str, debug_mesh: bool = False,
                  variant: str | None = None) -> dict:
    """Exact-cost record for one single-pod cell via U1/U2 extrapolation."""
    cfg = get_config(arch)
    ok, why = S.shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True, "reason": why}
    base = 1 if cfg.first_dense else 0
    pat = len(cfg.block_pattern)
    groups = cfg.layer_groups()
    n_super = max(rep for _, rep in groups)
    full_path = cell_path(arch, shape, "single_pod")
    if variant is None and os.path.exists(full_path):
        with open(full_path) as fh:
            full = json.load(fh)
    else:
        full = run_cell(arch, shape, False, debug_mesh=debug_mesh,
                        variant=variant)
    u1 = run_cell(arch, shape, False, debug_mesh=debug_mesh, unrolled=True,
                  n_layers=base + pat, variant=variant)
    u2 = run_cell(arch, shape, False, debug_mesh=debug_mesh, unrolled=True,
                  n_layers=base + 2 * pat, variant=variant)
    out = _combine_costs(full, u1, u2, n_super)
    out.update({"arch": arch, "shape": shape, "mesh": "single_pod",
                "variant": variant})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES),
                    help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2-pod mesh (default: both meshes)")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the single-pod mesh")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unrolled", action="store_true",
                    help="force-unroll layer scans for exact FLOP/collective "
                         "accounting (single-pod roofline pass)")
    ap.add_argument("--cost", action="store_true",
                    help="U1/U2 cost-extrapolation pass (single-pod): exact "
                         "FLOP/collective totals without unrolling the full "
                         "depth")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS),
                    help="apply a §Perf optimization variant (with --cost)")
    args = ap.parse_args()

    if args.cost:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(S.SHAPES)
        suffix = "single_pod_cost" + (f"__{args.variant}" if args.variant
                                      else "")
        n_fail = 0
        for arch in archs:
            for shape in shapes:
                path = cell_path(arch, shape, suffix)
                if os.path.exists(path) and not args.force:
                    with open(path) as fh:
                        prev = json.load(fh)
                    if prev.get("ok") or prev.get("skipped"):
                        print(f"[cached] cost {arch} {shape}")
                        continue
                res = run_cost_cell(arch, shape, debug_mesh=args.debug_mesh,
                                    variant=args.variant)
                with open(path, "w") as fh:
                    json.dump(res, fh, indent=1)
                if res.get("skipped"):
                    print(f"[skip]   cost {arch} {shape}")
                elif res.get("ok"):
                    fl = res["cost_analysis"]["flops"]
                    print(f"[ok]     cost {arch} {shape} flops/dev={fl:.3g}")
                else:
                    n_fail += 1
                    print(f"[FAIL]   cost {arch} {shape}: "
                          f"{res.get('u1_error') or res.get('u2_error')}")
        return 1 if n_fail else 0

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(S.SHAPES)
    meshes = []
    if not args.multi_pod:
        meshes.append(("single_pod", False))
    if not args.single_pod:
        meshes.append(("multi_pod", True))

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name, mp in meshes:
                suffix = mesh_name + ("_unrolled" if args.unrolled else "")
                path = cell_path(arch, shape, suffix)
                if os.path.exists(path) and not args.force:
                    with open(path) as fh:
                        prev = json.load(fh)
                    if prev.get("ok") or prev.get("skipped"):
                        print(f"[cached] {arch} {shape} {mesh_name}")
                        n_ok += prev.get("ok", False)
                        n_skip += prev.get("skipped", False)
                        continue
                res = run_cell(arch, shape, mp, debug_mesh=args.debug_mesh,
                               unrolled=args.unrolled)
                with open(path, "w") as fh:
                    json.dump(res, fh, indent=1)
                if res.get("skipped"):
                    n_skip += 1
                    print(f"[skip]   {arch} {shape} {mesh_name}: {res['reason'][:60]}")
                elif res.get("ok"):
                    n_ok += 1
                    fl = res.get("cost_analysis", {}).get("flops", 0)
                    print(f"[ok]     {arch} {shape} {mesh_name} "
                          f"compile={res['compile_s']:.1f}s flops={fl:.3g}")
                else:
                    n_fail += 1
                    print(f"[FAIL]   {arch} {shape} {mesh_name}: "
                          f"{res['error'][:200]}")
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
