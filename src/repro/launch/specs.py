"""Input specifications for every (architecture x shape) dry-run cell.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for all inputs of the lowered step, plus which
step function the cell lowers (train_step / prefill / decode_step).

Assigned shapes (LM family):
  train_4k     seq 4096,   global_batch 256   (training)
  prefill_32k  seq 32768,  global_batch 32    (inference prefill)
  decode_32k   cache 32768, global_batch 128  (inference decode, 1 token)
  long_500k    cache 524288, global_batch 1   (long-context decode;
               sub-quadratic archs only)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, cache_logical_axes, init_cache

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention architecture: 500k-token decode "
                       "requires sub-quadratic attention (DESIGN.md note)")
    return True, ""


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Training batch ShapeDtypeStructs (logical axes in .sharding slot)."""
    specs = {"labels": (jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                        ("batch", None))}
    if cfg.embed_inputs:
        specs["embeds"] = (jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                jnp.bfloat16),
                           ("batch", None, "embed"))
    else:
        specs["tokens"] = (jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                           ("batch", None))
    return specs


def token_specs(cfg: ModelConfig, batch: int) -> tuple:
    if cfg.embed_inputs:
        return (jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.bfloat16),
                ("batch", None, "embed"))
    return jax.ShapeDtypeStruct((batch,), jnp.int32), ("batch",)


def prompt_specs(cfg: ModelConfig, batch: int, seq: int) -> tuple:
    if cfg.embed_inputs:
        return (jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16),
                ("batch", None, "embed"))
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32), ("batch", None)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """(ShapeDtypeStruct tree, logical-axes tree) for the decode cache."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    axes = cache_logical_axes(cfg)
    axes["index"] = ()
    return shapes, axes
