"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --smoke                      # CPU-sized smoke run
    ... --mesh single|multi                      # on a real TPU fleet

On real hardware this process runs per-host under `jax.distributed` (the
mesh spans all hosts; each host feeds its data shard via
TokenPipeline(n_ranks=jax.process_count(), rank=jax.process_index())).
In this container it runs single-process; the multi-device path is proven
by the dry-run and the 8-device subprocess tests.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.sharding import rules as R
from repro.train.loop import train
from repro.train.optimizer import Hyper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--mesh", choices=("none", "single", "multi"),
                    default="none",
                    help="install the production mesh (TPU fleets)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        R.set_mesh(mesh)
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({mesh.devices.size} devices)")

    compressor = None
    if args.grad_compress:
        from repro.train.grad_compress import GDQuantizer
        compressor = GDQuantizer(bits=8)

    hyper = Hyper(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    state, hist = train(cfg, hyper, steps=args.steps, batch=args.batch,
                        seq=args.seq, ckpt_dir=args.ckpt_dir,
                        microbatches=args.microbatches,
                        compressor=compressor)
    print(f"done: step {int(state.step)}, "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}, "
          f"flagged steps: {hist['flagged_steps']}")


if __name__ == "__main__":
    main()
