"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run forces 512 host devices *before*
importing jax; smoke tests see the real single CPU device.

Topology (target: TPU v5e pods):
  single-pod: (data=16, model=16) = 256 chips; `model` is the ICI-contiguous
              inner axis (tensor-parallel collectives stay on-chip-neighbor).
  multi-pod:  (pod=2, data=16, model=16) = 512 chips; `pod` is the DCN axis —
              only data-parallel gradient reduction crosses it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=jax.devices()[: _prod(shape)])


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for 8-device subprocess tests: (2,2) or (2,2,2)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=jax.devices()[: _prod(shape)])


def _prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out
