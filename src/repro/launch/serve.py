"""Production serving driver (batched prefill+decode).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.generate(reqs)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"{tokens} tokens / {wall:.2f}s = {tokens/wall:.1f} tok/s; "
          f"stats {engine.last_stats}")


if __name__ == "__main__":
    main()
