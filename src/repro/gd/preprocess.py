"""GreedyGD pre-processing (§3 "Data Compression", Fig. 2).

Per-column, type-driven, and requiring no extra storage beyond tiny per-column
metadata (offset/scale/dictionary):

  * integers:      minimum-value subtraction;
  * floats:        fixed-point conversion (10.22 -> 1022) then min-subtraction;
  * categoricals:  frequency-ranked codes (most common -> 0, ...);
  * missing:       excluded via NaN; the null positions are carried in a
                   bitmap (storage) and as NaN in the working matrix.

Batch-friendly: ``preprocess_table`` accepts an iterable of column arrays; a
two-pass variant could stream batches, which we note rather than build (the
paper notes arbitrary batch sizes are possible, not a specific API).

Output values are non-negative integers stored as float64 (NaN = missing),
the domain PairwiseHist is built on, plus ``ColumnInfo`` used to encode query
literals (§5.1) and decode results.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ColumnInfo


class Preprocessed:
    """Pre-processed table: integer-domain matrix + per-column metadata."""

    def __init__(self, data: np.ndarray, columns: list):
        self.data = data          # (N, d) f64, NaN for missing
        self.columns = columns    # list[ColumnInfo]

    @property
    def n_rows(self) -> int:
        return self.data.shape[0]

    @property
    def d(self) -> int:
        return self.data.shape[1]


def _float_scale(x: np.ndarray, max_decimals: int = 6) -> float:
    """Smallest power of ten making every value integral (10.22 -> 1022)."""
    finite = x[np.isfinite(x)]
    for p in range(max_decimals + 1):
        scaled = finite * 10**p
        if np.all(np.abs(scaled - np.round(scaled)) < 1e-6):
            return float(10**p)
    return float(10**max_decimals)


def preprocess_column(values, name: str):
    """One column -> (f64 codes with NaN, ColumnInfo)."""
    arr = np.asarray(values)
    if arr.dtype.kind in ("U", "S", "O"):  # categorical
        str_vals = np.array(["\0NULL\0" if v is None or (isinstance(v, float)
                             and np.isnan(v)) else str(v) for v in arr])
        null = str_vals == "\0NULL\0"
        vals, counts = np.unique(str_vals[~null], return_counts=True)
        order = np.argsort(-counts, kind="stable")  # frequency-ranked
        ranked = vals[order]
        lut = {v: i for i, v in enumerate(ranked)}
        out = np.full(arr.shape, np.nan)
        out[~null] = [lut[v] for v in str_vals[~null]]
        info = ColumnInfo(name=name, kind="categorical",
                          categories=tuple(ranked.tolist()), mu=1.0)
        return out, info

    x = arr.astype(np.float64)
    null = ~np.isfinite(x)
    finite = x[~null]
    if finite.size == 0:
        return np.full(arr.shape, np.nan), ColumnInfo(name=name, kind="int")
    integral = np.all(np.abs(finite - np.round(finite)) < 1e-9)
    scale = 1.0 if integral else _float_scale(finite)
    kind = "int" if integral else "float"
    offset = float(np.min(finite) * scale)
    out = x * scale - offset
    out[null] = np.nan
    info = ColumnInfo(name=name, kind=kind, offset=offset, scale=scale, mu=1.0)
    return np.round(out), info


def preprocess_table(table: dict) -> Preprocessed:
    """{name: column array} -> Preprocessed (column order preserved)."""
    cols, mats = [], []
    for name, values in table.items():
        codes, info = preprocess_column(values, name)
        mats.append(codes)
        cols.append(info)
    return Preprocessed(np.stack(mats, axis=1), cols)
