# Generalized Deduplication compression substrate (GreedyGD, §3 + Fig. 2/3).
from repro.gd.preprocess import preprocess_table, Preprocessed  # noqa: F401
from repro.gd.greedygd import GreedyGD, CompressedTable  # noqa: F401
