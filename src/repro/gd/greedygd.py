"""GreedyGD: Generalized Deduplication with greedy base-bit selection (§3).

GD splits each row (chunk) into a *base* (most significant bits of every
column) and a *deviation* (the remaining bits). Bases are deduplicated —
compression wins when few distinct bases cover many rows (Fig. 3). GreedyGD
chooses *which* bits go to the base by greedily minimizing the modelled
compressed size:

    size = n_bases * sum(b_i)                       (deduplicated bases)
         + N * ceil(log2(n_bases))                  (base ids)
         + N * sum(w_i - b_i)                       (verbatim deviations)
         + null bitmap + dictionaries

starting from all bits in the base and repeatedly moving the nibble (4 bits,
GD's usual granularity) whose move reduces the modelled size the most.
Unique-base counts during the greedy search are estimated on a row subsample
(the search is a heuristic either way); the final split is exact.

The deduplicated bases double as seed bin edges for PairwiseHist (§3), which
is what makes construction on compressed data *faster*: the initial edges are
already shaped like the data.

Lossless: ``decompress()`` restores the pre-processed matrix bit-exactly
(including NaN positions via the null bitmap).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class CompressedTable:
    bases: np.ndarray          # (n_bases, d) uint64 — base bit patterns
    base_ids: np.ndarray       # (N,) uint32 — row -> base
    deviations: list           # per column: (N,) uint64 of low bits
    base_bits: np.ndarray      # (d,) — b_i
    total_bits: np.ndarray     # (d,) — w_i
    null_mask: np.ndarray      # (N, d) bool
    sentinels: np.ndarray      # (d,) — missing-value codes

    @property
    def n_rows(self) -> int:
        return self.base_ids.shape[0]

    @property
    def d(self) -> int:
        return self.bases.shape[1]

    def size_bits(self) -> dict:
        n, d = self.n_rows, self.d
        nb = self.bases.shape[0]
        id_bits = max(1, math.ceil(math.log2(max(nb, 2))))
        return {
            "bases": int(nb * self.base_bits.sum()),
            "ids": int(n * id_bits),
            "deviations": int(n * (self.total_bits - self.base_bits).sum()),
            "null_bitmap": int(n * d),
        }

    def size_bytes(self) -> int:
        return math.ceil(sum(self.size_bits().values()) / 8)

    def raw_size_bytes(self) -> int:
        """Typed-binary baseline: minimal whole-byte width per column."""
        n = self.n_rows
        return int(sum(n * max(1, math.ceil(w / 8)) for w in self.total_bits))


def decompress_rows(ct: CompressedTable, rows=None) -> np.ndarray:
    """Decode a row subset of a ``CompressedTable`` bit-exactly.

    ``rows`` is an index array (any order, duplicates allowed) or None for
    every row. Only the selected rows' base ids / deviations / null-bitmap
    slices are touched, so decoding an N_s-row construction sample costs
    O(N_s * d) regardless of the table's full height — this is what lets
    ``build_pairwise_hist`` consume a ``CompressedTable`` without ever
    materializing the full raw matrix.
    """
    shift = (ct.total_bits - ct.base_bits).astype(np.uint64)
    ids = ct.base_ids if rows is None else ct.base_ids[rows]
    base_rows = ct.bases[ids]
    out = np.empty((ids.shape[0], ct.d), np.float64)
    for i in range(ct.d):
        dev = ct.deviations[i] if rows is None else ct.deviations[i][rows]
        null = ct.null_mask[:, i] if rows is None else ct.null_mask[rows, i]
        codes = (base_rows[:, i] << shift[i]) | dev
        col = codes.astype(np.float64)
        col[null] = np.nan
        out[:, i] = col
    return out


class GreedyGD:
    """Compressor + decompressor + base extraction."""

    def __init__(self, nibble: int = 4, search_rows: int = 20000,
                 max_iters: int = 512, seed: int = 0):
        self.nibble = nibble
        self.search_rows = search_rows
        self.max_iters = max_iters
        self.seed = seed

    # ------------------------------------------------------------- internals

    @staticmethod
    def _encode_missing(data: np.ndarray):
        """NaN -> per-column sentinel code (max+1); returns ints + masks."""
        null = ~np.isfinite(data)
        codes = np.zeros(data.shape, np.uint64)
        sentinels = np.zeros(data.shape[1], np.uint64)
        for i in range(data.shape[1]):
            col = data[:, i]
            ok = ~null[:, i]
            mx = int(col[ok].max()) if ok.any() else 0
            sentinel = mx + 1
            sentinels[i] = sentinel
            vals = np.where(ok, col, float(sentinel))
            codes[:, i] = vals.astype(np.uint64)
        return codes, null, sentinels

    @staticmethod
    def _width(codes: np.ndarray) -> np.ndarray:
        mx = codes.max(axis=0).astype(np.uint64)
        return np.array([max(1, int(v).bit_length()) for v in mx], np.int64)

    @staticmethod
    def _n_unique_rows(masked: np.ndarray) -> int:
        view = np.ascontiguousarray(masked).view(
            np.dtype((np.void, masked.dtype.itemsize * masked.shape[1])))
        return np.unique(view).size

    def _model_bits(self, n_rows, widths, base_bits, nb) -> float:
        id_bits = max(1, math.ceil(math.log2(max(nb, 2))))
        return (nb * base_bits.sum() + n_rows * id_bits
                + n_rows * (widths - base_bits).sum())

    def plan(self, codes: np.ndarray) -> np.ndarray:
        """Greedy nibble search -> per-column base bit counts b_i.

        GreedyGD grows the base from *empty*: repeatedly move the MSB nibble
        of the column whose move most reduces the modelled size (deviations
        shrink by 4 bits/row; bases/ids grow with the deduplicated count).
        Stops at the first iteration with no improving move.
        """
        n, d = codes.shape
        widths = self._width(codes)
        rng = np.random.default_rng(self.seed)
        if n > self.search_rows:
            sub = codes[rng.choice(n, self.search_rows, replace=False)]
        else:
            sub = codes
        ns = sub.shape[0]
        base_bits = np.zeros(d, np.int64)

        def masked(bb):
            shift = (widths - bb).astype(np.uint64)
            return sub >> shift

        cur_cost = self._model_bits(ns, widths, base_bits, 1)
        for _ in range(self.max_iters):
            best = None
            for i in range(d):
                if base_bits[i] >= widths[i]:
                    continue
                cand = base_bits.copy()
                cand[i] = min(widths[i], cand[i] + self.nibble)
                nb = self._n_unique_rows(masked(cand))
                cost = self._model_bits(ns, widths, cand, nb)
                if cost < cur_cost and (best is None or cost < best[0]):
                    best = (cost, i, cand)
            if best is None:
                break
            cur_cost, _, base_bits = best
        return base_bits

    # ------------------------------------------------------------------- API

    def compress(self, data: np.ndarray) -> CompressedTable:
        """Pre-processed (N, d) f64 matrix (NaN = missing) -> CompressedTable."""
        codes, null, sentinels = self._encode_missing(np.asarray(data, np.float64))
        widths = self._width(codes)
        base_bits = self.plan(codes)
        shift = (widths - base_bits).astype(np.uint64)
        base_part = codes >> shift
        dev_mask = ((np.uint64(1) << shift) - np.uint64(1))
        deviations = [np.asarray(codes[:, i] & dev_mask[i])
                      for i in range(codes.shape[1])]
        view = np.ascontiguousarray(base_part).view(
            np.dtype((np.void, base_part.dtype.itemsize * base_part.shape[1])))
        _, first_idx, inverse = np.unique(view, return_index=True,
                                          return_inverse=True)
        bases = base_part[first_idx]
        return CompressedTable(
            bases=bases, base_ids=inverse.astype(np.uint32).reshape(-1),
            deviations=deviations, base_bits=base_bits, total_bits=widths,
            null_mask=null, sentinels=sentinels)

    def decompress(self, ct: CompressedTable) -> np.ndarray:
        """Bit-exact inverse of compress (NaN restored from the bitmap)."""
        return decompress_rows(ct, None)

    @staticmethod
    def decompress_rows(ct: CompressedTable, rows) -> np.ndarray:
        """Decode only ``rows`` (see module-level ``decompress_rows``)."""
        return decompress_rows(ct, rows)

    @staticmethod
    def seed_edges(ct: CompressedTable) -> list:
        """Per-column candidate bin edges from the deduplicated bases (§3).

        Each distinct base value of a column marks the lower boundary of the
        value range it covers: base << dev_bits.
        """
        shift = (ct.total_bits - ct.base_bits).astype(np.uint64)
        edges = []
        for i in range(ct.d):
            vals = np.unique(ct.bases[:, i])
            lo = (vals << shift[i]).astype(np.float64)
            edges.append(np.unique(lo))
        return edges
