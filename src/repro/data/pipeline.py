"""Deterministic, shardable synthetic token pipeline.

Properties a 1000-node fleet needs:
  * deterministic: batch(step) is a pure function of (seed, step) — restart
    or elastic re-shard never replays/skips data;
  * shardable: each data-parallel rank materializes only its slice
    (``host_slice``), so no rank ever holds the global batch;
  * checkpointable: state is just the step counter (stored by the ckpt
    manager alongside the model).

The synthetic stream is a Zipf-ish mixture with enough structure (bigram
template cycling) for loss curves to be meaningfully decreasing, which the
examples and convergence tests rely on.
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 n_ranks: int = 1, rank: int = 0):
        if batch % n_ranks:
            raise ValueError("global batch must divide across ranks")
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.n_ranks = n_ranks
        self.rank = rank
        self._templates = self._make_templates()

    def _make_templates(self):
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        n_templates = 64
        length = 48
        probs = 1.0 / np.arange(1, self.vocab + 1) ** 1.1
        probs /= probs.sum()
        return rng.choice(self.vocab, size=(n_templates, length), p=probs)

    def global_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        tpl_idx = rng.integers(0, len(self._templates), self.batch)
        for b in range(self.batch):
            tpl = self._templates[tpl_idx[b]]
            reps = int(np.ceil((self.seq + 1) / len(tpl)))
            row = np.tile(tpl, reps)[: self.seq + 1].copy()
            noise = rng.random(self.seq + 1) < 0.1
            row[noise] = rng.integers(0, self.vocab, noise.sum())
            toks[b] = row
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_slice(self, step: int) -> dict:
        """This rank's shard of the deterministic global batch."""
        full = self.global_batch(step)
        per = self.batch // self.n_ranks
        lo = self.rank * per
        return {k: v[lo: lo + per] for k, v in full.items()}

    def __iter__(self):
        step = 0
        while True:
            yield self.host_slice(step)
            step += 1
