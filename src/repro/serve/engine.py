"""Batched serving engine: prefill + decode with continuous-batching-lite.

Slots hold independent requests; finished slots are refilled from the queue
without stopping the decode loop (the decode step is a fixed-shape jit, so
refills swap cache contents via masked prefill of the new prompt into the
slot). Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig, decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0):
        if cfg.embed_inputs:
            raise ValueError("serve engine drives token models")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, c: prefill(p, cfg, t, c))
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c))

    def generate(self, requests: list[Request]) -> list[Request]:
        """Run all requests to completion with continuous slot refill."""
        queue = list(requests)
        active: list[Request | None] = [None] * self.slots
        t_start = time.perf_counter()
        stats = {"prefills": 0, "decode_steps": 0}

        while any(a is not None and not a.done for a in active) or queue:
            # Refill empty slots: batch the pending prompts together.
            for idx in range(self.slots):
                if active[idx] is None or active[idx].done:
                    active[idx] = queue.pop(0) if queue else None
            live = [r for r in active if r is not None and not r.done]
            if not live:
                break
            # (Re)prefill: pad prompts of the live set to one length.
            max_prompt = max(len(r.prompt) + len(r.out_tokens) for r in live)
            toks = np.zeros((self.slots, max_prompt), np.int32)
            for idx, req in enumerate(active):
                if req is None or req.done:
                    continue
                seqline = np.concatenate([req.prompt,
                                          np.asarray(req.out_tokens, np.int32)])
                toks[idx, -len(seqline):] = seqline  # left-pad
            cache = init_cache(self.cfg, self.slots, self.max_len)
            logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
            stats["prefills"] += 1

            # Decode until every live slot finishes (then refill loop re-runs).
            last = self._sample(logits[:, -1])
            for _ in range(max(r.max_new_tokens - len(r.out_tokens)
                               for r in live)):
                for idx, req in enumerate(active):
                    if req is None or req.done:
                        continue
                    tok = int(last[idx])
                    req.out_tokens.append(tok)
                    if (self.eos_id is not None and tok == self.eos_id) or \
                            len(req.out_tokens) >= req.max_new_tokens:
                        req.done = True
                if all(r is None or r.done for r in active):
                    break
                logits, cache = self._decode(self.params, last, cache)
                stats["decode_steps"] += 1
                last = self._sample(logits[:, 0])
        stats["wall_s"] = time.perf_counter() - t_start
        self.last_stats = stats
        return requests

    def _sample(self, logits):
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.asarray(greedy)
