"""Multi-table AQP serving subsystem: catalog + streaming admission +
batch scheduler + caches + telemetry.

Turns the single-table ``AQPFramework`` into a multi-tenant query server:
``AQPServer.submit`` enqueues without blocking and returns a
``QueryFuture``; a ``StreamingAdmission`` worker drains the queue into
plan-shape waves whose hot path is one fused kernel launch per group
(GROUP BY queries included, via planning-time leaf expansion). See
``docs/serving.md`` for the full reference.
"""
from repro.core.query import (AdmissionRejected,  # noqa: F401
                              DeadlineExceeded, QueryError)
from repro.serve.aqp import faults  # noqa: F401
from repro.serve.aqp.cache import LRUCache, normalize_sql  # noqa: F401
from repro.serve.aqp.catalog import (ColdTable,  # noqa: F401
                                     TableCatalog, TableQuarantinedError)
from repro.serve.aqp.metrics import (AdmissionMetrics,  # noqa: F401
                                     FaultMetrics, Metrics, TableMetrics)
from repro.serve.aqp.scheduler import (BatchScheduler,  # noqa: F401
                                       StreamingAdmission)
from repro.serve.aqp.server import AQPServer, QueryFuture  # noqa: F401
