# Multi-table AQP serving subsystem: catalog + batch scheduler + caches +
# telemetry. Turns the single-table AQPFramework into a multi-tenant query
# server whose hot path is one fused kernel launch per plan-shape group.
from repro.serve.aqp.cache import LRUCache, normalize_sql  # noqa: F401
from repro.serve.aqp.catalog import TableCatalog  # noqa: F401
from repro.serve.aqp.metrics import Metrics, TableMetrics  # noqa: F401
from repro.serve.aqp.scheduler import BatchScheduler  # noqa: F401
from repro.serve.aqp.server import AQPServer  # noqa: F401
