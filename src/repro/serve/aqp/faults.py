"""Deterministic fault injection for the serving stack.

Production code calls :func:`hook` at named *sites* (cold decode, blob
read, fused kernel launch, planner, wave execute, worker).  With no plan
installed the hook is a single global read plus an ``is None`` branch —
cheap enough to leave in the hot path permanently (the disabled cost is
measured by ``benchmarks/bench_serving.py`` and gated below 2% of p50).

Chaos tests install a seeded :class:`FaultPlan` that scripts *exact*
failure schedules: "fail the 3rd cold decode", "crash the worker on its
first wave", "fail 10% of kernel launches under seed 7".  Schedules are
deterministic — the same plan against the same call sequence injects the
same faults — so chaos runs are reproducible and bit-exact comparisons
against an undisturbed control server are meaningful.

Typical test usage::

    plan = FaultPlan(seed=7).fail("cold_decode", at=[0]).fail(
        "kernel_launch", rate=0.1)
    with installed(plan):
        ... drive the server ...
    assert plan.injected("cold_decode") == 1
"""
from __future__ import annotations

import contextlib
import random
import threading
import zlib
from typing import Callable, Iterable, Optional

# Canonical injection sites wired into the serving stack.  Hooks accept
# arbitrary site names (tests may add private sites), but these are the
# ones production code fires.
SITES = (
    "planner",        # cold-table planning (server._plan_cold)
    "wave_execute",   # top of a drained wave (server._execute_wave)
    "kernel_launch",  # fused batch launch (scheduler.BatchScheduler._run_group)
    "blob_read",      # cold blob fetch (catalog.ColdTable._decode)
    "cold_decode",    # synopsis decode (catalog.ColdTable._decode)
    "worker",         # admission worker heartbeat (scheduler._loop)
)


class InjectedFault(RuntimeError):
    """Raised by a fired fault rule; carries the site and call index."""

    def __init__(self, site: str, index: int, note: str = ""):
        self.site = site
        self.index = index
        msg = f"injected fault at {site}#{index}"
        if note:
            msg += f" ({note})"
        super().__init__(msg)


class _Rule:
    """One scheduled failure: matches call indices, then acts."""

    def __init__(self, site: str, seed: int, order: int,
                 at: Optional[Iterable[int]], first: int, every: int,
                 rate: float, exc: Optional[Callable[[str, int], Exception]],
                 action: Optional[Callable[[], None]], note: str):
        self.site = site
        self.at = frozenset(at) if at is not None else None
        self.first = first
        self.every = every
        self.rate = rate
        self.exc = exc
        self.action = action
        self.note = note
        # Per-rule deterministic stream: seed x site x registration order.
        self.rng = random.Random(
            (seed << 16) ^ zlib.crc32(site.encode()) ^ order)

    def matches(self, index: int) -> bool:
        if self.at is not None and index in self.at:
            return True
        if self.first and index < self.first:
            return True
        if self.every and (index + 1) % self.every == 0:
            return True
        if self.rate > 0.0 and self.rng.random() < self.rate:
            return True
        return False


class FaultPlan:
    """A seeded, scripted schedule of failures keyed by injection site.

    Rules are evaluated in registration order at every :func:`hook` call
    for their site; the first matching rule fires.  A rule either raises
    (``exc``, default :class:`InjectedFault`) or runs ``action`` (e.g. a
    ``time.sleep`` to inject latency) — an ``action`` that returns
    normally does not raise.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._lock = threading.Lock()
        self._rules: dict[str, list[_Rule]] = {}
        self._counts: dict[str, int] = {}
        self._injected: dict[str, int] = {}
        self._order = 0

    def fail(self, site: str, *, at: Optional[Iterable[int]] = None,
             first: int = 0, every: int = 0, rate: float = 0.0,
             exc: Optional[Callable[[str, int], Exception]] = None,
             action: Optional[Callable[[], None]] = None,
             note: str = "") -> "FaultPlan":
        """Register a failure rule for ``site``; returns ``self`` to chain.

        ``at`` fires on exact 0-based call indices; ``first`` fires on the
        first N calls; ``every`` fires on every k-th call; ``rate`` fires
        pseudo-randomly (deterministic under the plan seed).  ``exc`` is a
        factory ``(site, index) -> Exception``; ``action`` is called
        instead of raising when given (use it for latency injection).
        """
        with self._lock:
            rule = _Rule(site, self.seed, self._order, at, first, every,
                         rate, exc, action, note)
            self._order += 1
            self._rules.setdefault(site, []).append(rule)
        return self

    def fire(self, site: str) -> None:
        """Account one call at ``site`` and inject per the schedule."""
        with self._lock:
            index = self._counts.get(site, 0)
            self._counts[site] = index + 1
            hit = None
            for rule in self._rules.get(site, ()):
                if rule.matches(index):
                    hit = rule
                    break
            if hit is not None:
                self._injected[site] = self._injected.get(site, 0) + 1
        if hit is None:
            return
        if hit.action is not None:
            hit.action()
            return
        factory = hit.exc
        if factory is None:
            raise InjectedFault(site, index, hit.note)
        raise factory(site, index)

    def count(self, site: str) -> int:
        """Total hook calls observed at ``site`` so far."""
        with self._lock:
            return self._counts.get(site, 0)

    def injected(self, site: str) -> int:
        """Number of faults actually fired at ``site`` so far."""
        with self._lock:
            return self._injected.get(site, 0)

    def snapshot(self) -> dict:
        """Counts and injections per site, for assertions and reports."""
        with self._lock:
            return {"counts": dict(self._counts),
                    "injected": dict(self._injected)}


_ACTIVE: Optional[FaultPlan] = None


def hook(site: str) -> None:
    """Fire the active fault plan at ``site``; no-op when none installed."""
    plan = _ACTIVE
    if plan is not None:
        plan.fire(site)


def install(plan: FaultPlan) -> None:
    """Make ``plan`` the process-wide active fault plan."""
    global _ACTIVE
    _ACTIVE = plan


def clear() -> None:
    """Remove the active fault plan (hooks become no-ops again)."""
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultPlan]:
    """Return the currently installed plan, or ``None``."""
    return _ACTIVE


@contextlib.contextmanager
def installed(plan: FaultPlan):
    """Context manager: install ``plan``, restore the previous plan on exit."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev
