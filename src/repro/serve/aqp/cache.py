"""LRU plan / result caches for the AQP serving layer.

Entries are keyed on *normalized* SQL text and tagged with the owning
table's epoch (``AQPFramework.epoch``); a lookup whose stored epoch differs
from the table's current epoch is a miss — appended rows can never be
answered from a stale cached result. ``purge_table`` additionally evicts
eagerly (wired to ``AQPFramework.on_invalidate`` by the server) so stale
entries do not linger holding memory.

Thread safety: ``LRUCache`` is deliberately unsynchronized — the server's
lock split assigns each instance exactly one guarding lock (the plan cache
lives under ``AQPServer._plan_lock``, the result cache under
``AQPServer._state_lock``; see the locking section of
``repro.serve.aqp.server``), and every access goes through the owning
lock. Adding a lock here would double-pay on the hot path.
"""
from __future__ import annotations

import collections
import dataclasses
import re

import numpy as np

_QUOTED_RE = re.compile(r"('[^']*'|\"[^\"]*\")")


def normalize_sql(text: str) -> str:
    """Canonical cache key: collapse whitespace, drop a trailing semicolon.

    Quoted string literals are preserved verbatim (``'New  York'`` keeps its
    double space — the server parses the *normalized* text, so literal
    content must survive normalization); identifier/literal case is
    preserved too. Only insignificant layout outside quotes is collapsed,
    so ``SELECT COUNT(*)  FROM t ;`` and ``SELECT COUNT(*) FROM t`` share
    one cache slot.
    """
    parts = _QUOTED_RE.split(text.strip())
    parts[-1] = parts[-1].rstrip().rstrip(";")   # always outside quotes
    out = [part if i % 2 else " ".join(part.split())
           for i, part in enumerate(parts)]
    return " ".join(p for p in out if p)


def approx_nbytes(value, _depth: int = 0) -> int:
    """Rough in-memory footprint of a cached value, in bytes.

    Counts what dominates real result payloads — numpy arrays (``.nbytes``),
    strings, and the per-element overhead of containers / dataclasses —
    without a full ``gc`` traversal. It is an *estimate* feeding the cache's
    approximate byte budget, not an accounting tool; recursion is depth-
    bounded so a pathological self-referencing value cannot hang a put.
    """
    if _depth > 6 or value is None:
        return 8
    if isinstance(value, np.ndarray):
        return int(value.nbytes) + 96
    if isinstance(value, (bytes, str)):
        return len(value) + 49
    if isinstance(value, (int, float, bool, np.generic)):
        return 28
    if isinstance(value, dict):
        return 64 + sum(approx_nbytes(k, _depth + 1)
                        + approx_nbytes(v, _depth + 1)
                        for k, v in value.items())
    if isinstance(value, (list, tuple, set, frozenset)):
        return 56 + sum(approx_nbytes(v, _depth + 1) for v in value)
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return 56 + sum(
            approx_nbytes(getattr(value, f.name, None), _depth + 1)
            for f in dataclasses.fields(value))
    return 64


@dataclasses.dataclass
class CacheEntry:
    """One cached value tagged with its owning table + staleness epoch."""

    table: str
    epoch: int
    value: object
    nbytes: int = 0     # approx_nbytes(value), frozen at put time


class LRUCache:
    """LRU over normalized-SQL keys with epoch validation + stats.

    Bounded two ways: ``capacity`` (max entries) and — when ``max_bytes``
    is positive — an **approximate byte budget**: every put estimates the
    value's footprint (``approx_nbytes``) and evicts from the LRU end
    until the running total fits. An entry larger than the whole budget is
    rejected before insertion (the budget is a bound, not a best effort,
    and an oversized insert must not churn warm entries through the LRU
    end on its way out), which also means ``max_bytes > 0`` caches can
    reject a value outright.
    Byte-driven evictions are counted separately (``byte_evictions``) from
    capacity churn so telemetry shows which bound is binding.
    """

    def __init__(self, capacity: int = 1024, max_bytes: int = 0):
        self.capacity = int(capacity)
        self.max_bytes = int(max_bytes)
        self._data: collections.OrderedDict[str, CacheEntry] = \
            collections.OrderedDict()
        self._bytes = 0
        self.byte_evictions = 0
        self.hits = 0
        self.misses = 0
        self.table_hits: collections.Counter = collections.Counter()
        self.table_misses: collections.Counter = collections.Counter()

    def __len__(self) -> int:
        return len(self._data)

    @property
    def nbytes(self) -> int:
        """Approximate bytes currently held (sum of entry estimates)."""
        return self._bytes

    def get(self, key: str, epoch_of) -> CacheEntry | None:
        """Validated lookup. ``epoch_of(table) -> int`` supplies the current
        epoch; entries from older epochs are evicted silently. Miss
        accounting is the caller's job (one ``miss()`` per failed lookup,
        once the key's table is known) so a stale entry is not double
        counted."""
        entry = self._data.get(key)
        if entry is not None and entry.epoch == epoch_of(entry.table):
            self._data.move_to_end(key)
            self.hits += 1
            self.table_hits[entry.table] += 1
            return entry
        if entry is not None:   # stale epoch: evict; caller records the miss
            self._bytes -= entry.nbytes
            del self._data[key]
        return None

    def miss(self, table: str | None = None):
        """Record a miss (``table=None`` when the key's table is unknown)."""
        self.misses += 1
        if table is not None:
            self.table_misses[table] += 1

    def put(self, key: str, table: str, epoch: int, value):
        """Insert/refresh ``key`` (evicts LRU entries beyond capacity, then
        beyond the byte budget when ``max_bytes`` is set). A value larger
        than the whole budget is rejected up front — inserting it first
        would wipe every warm entry on its way through the LRU end — and
        drops the key's previous value (the caller meant to replace it)."""
        if self.capacity <= 0:
            return
        nb = approx_nbytes(value) if self.max_bytes > 0 else 0
        if self.max_bytes > 0 and nb > self.max_bytes:
            self.byte_evictions += 1
            old = self._data.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            return
        old = self._data.get(key)
        if old is not None:
            self._bytes -= old.nbytes
        self._data[key] = CacheEntry(table, epoch, value, nb)
        self._data.move_to_end(key)
        self._bytes += nb
        while len(self._data) > self.capacity:
            self._pop_lru()
        while self.max_bytes > 0 and self._bytes > self.max_bytes \
                and self._data:
            self._pop_lru(byte_evict=True)

    def _pop_lru(self, byte_evict: bool = False):
        _, entry = self._data.popitem(last=False)
        self._bytes -= entry.nbytes
        if byte_evict:
            self.byte_evictions += 1

    def purge_table(self, table: str):
        """Eagerly drop every entry belonging to ``table``."""
        dead = [k for k, e in self._data.items() if e.table == table]
        for k in dead:
            self._bytes -= self._data[k].nbytes
            del self._data[k]

    def clear(self):
        """Drop every entry (counters are preserved)."""
        self._data.clear()
        self._bytes = 0

    @property
    def hit_rate(self) -> float:
        """Lifetime hits / (hits + misses); 0.0 before any lookup."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        """Size/capacity/byte-budget/hit counters for telemetry snapshots."""
        return {"size": len(self._data), "capacity": self.capacity,
                "bytes": self._bytes, "max_bytes": self.max_bytes,
                "byte_evictions": self.byte_evictions,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate}
