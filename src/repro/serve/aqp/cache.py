"""LRU plan / result caches for the AQP serving layer.

Entries are keyed on *normalized* SQL text and tagged with the owning
table's epoch (``AQPFramework.epoch``); a lookup whose stored epoch differs
from the table's current epoch is a miss — appended rows can never be
answered from a stale cached result. ``purge_table`` additionally evicts
eagerly (wired to ``AQPFramework.on_invalidate`` by the server) so stale
entries do not linger holding memory.

Thread safety: ``LRUCache`` is deliberately unsynchronized — the server's
lock split assigns each instance exactly one guarding lock (the plan cache
lives under ``AQPServer._plan_lock``, the result cache under
``AQPServer._state_lock``; see the locking section of
``repro.serve.aqp.server``), and every access goes through the owning
lock. Adding a lock here would double-pay on the hot path.
"""
from __future__ import annotations

import collections
import dataclasses
import re

_QUOTED_RE = re.compile(r"('[^']*'|\"[^\"]*\")")


def normalize_sql(text: str) -> str:
    """Canonical cache key: collapse whitespace, drop a trailing semicolon.

    Quoted string literals are preserved verbatim (``'New  York'`` keeps its
    double space — the server parses the *normalized* text, so literal
    content must survive normalization); identifier/literal case is
    preserved too. Only insignificant layout outside quotes is collapsed,
    so ``SELECT COUNT(*)  FROM t ;`` and ``SELECT COUNT(*) FROM t`` share
    one cache slot.
    """
    parts = _QUOTED_RE.split(text.strip())
    parts[-1] = parts[-1].rstrip().rstrip(";")   # always outside quotes
    out = [part if i % 2 else " ".join(part.split())
           for i, part in enumerate(parts)]
    return " ".join(p for p in out if p)


@dataclasses.dataclass
class CacheEntry:
    """One cached value tagged with its owning table + staleness epoch."""

    table: str
    epoch: int
    value: object


class LRUCache:
    """Plain LRU over normalized-SQL keys with epoch validation + stats."""

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._data: collections.OrderedDict[str, CacheEntry] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.table_hits: collections.Counter = collections.Counter()
        self.table_misses: collections.Counter = collections.Counter()

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: str, epoch_of) -> CacheEntry | None:
        """Validated lookup. ``epoch_of(table) -> int`` supplies the current
        epoch; entries from older epochs are evicted silently. Miss
        accounting is the caller's job (one ``miss()`` per failed lookup,
        once the key's table is known) so a stale entry is not double
        counted."""
        entry = self._data.get(key)
        if entry is not None and entry.epoch == epoch_of(entry.table):
            self._data.move_to_end(key)
            self.hits += 1
            self.table_hits[entry.table] += 1
            return entry
        if entry is not None:   # stale epoch: evict; caller records the miss
            del self._data[key]
        return None

    def miss(self, table: str | None = None):
        """Record a miss (``table=None`` when the key's table is unknown)."""
        self.misses += 1
        if table is not None:
            self.table_misses[table] += 1

    def put(self, key: str, table: str, epoch: int, value):
        """Insert/refresh ``key`` (evicts LRU entries beyond capacity)."""
        if self.capacity <= 0:
            return
        self._data[key] = CacheEntry(table, epoch, value)
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def purge_table(self, table: str):
        """Eagerly drop every entry belonging to ``table``."""
        dead = [k for k, e in self._data.items() if e.table == table]
        for k in dead:
            del self._data[k]

    def clear(self):
        """Drop every entry (counters are preserved)."""
        self._data.clear()

    @property
    def hit_rate(self) -> float:
        """Lifetime hits / (hits + misses); 0.0 before any lookup."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        """Size/capacity/hit counters for telemetry snapshots."""
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate}
