"""Serving telemetry: latency/wait percentiles, throughput, admission stats.

Three layers:

  * ``TableMetrics`` — per-table query latencies (bounded reservoir with
    uniform replacement, so long-running servers report stable p50/p99
    without unbounded memory), batched/fallback/cache-hit counters, and
    GROUP BY leaf-expansion counters. Counters are exact: recording and
    snapshotting are serialized by a per-object lock, so concurrent
    submitter/worker threads can never lose an increment or snapshot a
    half-updated reservoir (asserted under contention in
    tests/test_obs.py).
  * ``AdmissionMetrics`` — server-wide streaming-admission stats: queue
    depth at drain time, per-query admission wait (submit -> drain), and
    drain causes (``full`` / ``flush`` / ``timeout``).
  * ``StageMetrics`` — trace-derived per-stage latency reservoirs (plan /
    queue / execute / ...): ``Metrics.record_explain`` feeds each traced
    query's EXPLAIN breakdown in, and the snapshot reports per-stage
    p50/p99 so aggregate dashboards see where wall-clock goes without
    reading raw traces.
  * ``Metrics`` — the container ``AQPServer`` owns; assembles the snapshot
    dict (see ``docs/serving.md`` for the field reference).
"""
from __future__ import annotations

import random
import threading
import time

import numpy as np


class _Reservoir:
    """Bounded uniform-replacement sample of a float stream."""

    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = int(capacity)
        self._rng = random.Random(seed)
        self._data: list[float] = []
        self.n_seen = 0

    def add(self, value: float):
        self.n_seen += 1
        if len(self._data) < self.capacity:
            self._data.append(value)
        else:
            idx = self._rng.randrange(self.n_seen)
            if idx < self.capacity:
                self._data[idx] = value

    def percentiles_ms(self, qs=(50, 99)) -> list:
        """Requested percentiles in milliseconds, or Nones when empty."""
        if not self._data:
            return [None] * len(qs)
        arr = np.asarray(self._data, float)
        return [float(np.percentile(arr, q) * 1e3) for q in qs]


class TableMetrics:
    """Per-table serving counters + latency reservoir.

    ``record``/``record_result_hit`` mirror the server's execution paths;
    ``record_group_expansion`` tracks GROUP BY queries whose per-category
    leaves went through the batched path (executed vs served from the
    per-leaf result cache).
    """

    def __init__(self, reservoir: int = 4096, seed: int = 0):
        self.reservoir = int(reservoir)
        self._lock = threading.Lock()
        self._lat = _Reservoir(self.reservoir, seed)
        self.n_queries = 0          # executed (cache misses)
        self.n_batched = 0          # executed via the fused batched kernel
        self.n_fallback = 0         # executed via the per-query path
        self.n_result_hits = 0      # served straight from the result cache
        self.n_group_queries = 0    # GROUP BY queries answered
        self.n_leaves_executed = 0  # GROUP BY leaves actually executed
        self.n_leaf_cache_hits = 0  # GROUP BY leaves served from cache
        self.n_cold_decodes = 0     # cold-tier blob -> engine decodes
        self.cold_synopsis_bytes = 0  # registered blob size (cold tables)
        self.cold_decode_ms = None  # latest cold-start decode latency
        self.n_demotes = 0          # governor engine -> blob demotions
        self.engine_resident_bytes = 0  # decoded-engine footprint right now
        self._t_first = None
        self._t_last = None
        # Last time this table served anything (executions, result-cache
        # hits, cold decodes) — the governor's idle clock. Separate from
        # _t_last so cache hits don't stretch the qps window.
        self._t_activity = None

    def record(self, latency_s: float, batched: bool):
        """One executed query: its latency share and whether it fused."""
        now = time.perf_counter()
        with self._lock:
            self._t_first = self._t_first if self._t_first is not None else now
            self._t_last = now
            self._t_activity = now
            self.n_queries += 1
            if batched:
                self.n_batched += 1
            else:
                self.n_fallback += 1
            self._lat.add(latency_s)

    def record_result_hit(self):
        """One query served from the result cache (no execution). Counts as
        table activity for the governor's idle clock — a cache-hit-hot
        table must not look idle and get demoted under it."""
        now = time.perf_counter()
        with self._lock:
            self._t_activity = now
            self.n_result_hits += 1

    def record_group_expansion(self, n_executed: int, n_cached: int):
        """One GROUP BY query: leaves executed vs served from cache."""
        with self._lock:
            self.n_group_queries += 1
            self.n_leaves_executed += int(n_executed)
            self.n_leaf_cache_hits += int(n_cached)

    def record_cold_register(self, n_bytes: int):
        """A cold (storage-tier) table registered under this name: its
        bit-packed synopsis blob size, reported before any decode."""
        with self._lock:
            self.cold_synopsis_bytes = int(n_bytes)

    def record_cold_decode(self, n_bytes: int, decode_s: float,
                           resident_bytes: int | None = None):
        """One lazy cold-start decode (blob -> engine) and its latency."""
        now = time.perf_counter()
        with self._lock:
            self._t_activity = now
            self.n_cold_decodes += 1
            self.cold_synopsis_bytes = int(n_bytes)
            self.cold_decode_ms = float(decode_s) * 1e3
            if resident_bytes is not None:
                self.engine_resident_bytes = int(resident_bytes)

    def record_demote(self):
        """One governor demotion (engine -> blob) for this table."""
        with self._lock:
            self.n_demotes += 1
            self.engine_resident_bytes = 0

    @property
    def last_activity(self) -> float | None:
        """``time.perf_counter()`` of this table's most recent serve
        activity (execution, result-cache hit, or cold decode); None if
        never queried. The governor orders demotion candidates by this."""
        with self._lock:
            return self._t_activity

    def snapshot(self) -> dict:
        """Point-in-time dict of counters + p50/p99/qps (None when empty)."""
        with self._lock:
            served = self.n_queries + self.n_result_hits
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None else 0.0)
            n_queries = self.n_queries
            p50, p99 = self._lat.percentiles_ms()
            snap = {
                "queries_served": served,
                "queries_executed": n_queries,
                "batched": self.n_batched,
                "fallback": self.n_fallback,
                "result_cache_hits": self.n_result_hits,
                "batched_fraction": (self.n_batched / n_queries
                                     if n_queries else 0.0),
                "p50_ms": p50,
                "p99_ms": p99,
                "group_by": {
                    "queries": self.n_group_queries,
                    "leaves_executed": self.n_leaves_executed,
                    "leaf_cache_hits": self.n_leaf_cache_hits,
                },
            }
            if self.n_cold_decodes or self.cold_synopsis_bytes:
                snap["cold"] = {
                    "decodes": self.n_cold_decodes,
                    "synopsis_bytes": self.cold_synopsis_bytes,
                    "decode_ms": self.cold_decode_ms,
                    "demotes": self.n_demotes,
                    "resident_bytes": self.engine_resident_bytes,
                }
        # qps window: once >= 1 query landed, span is clamped to a small
        # epsilon so a single query (span == 0 between first and last)
        # reports a finite rate instead of None.
        snap["qps"] = (n_queries / max(span, 1e-9)
                       if n_queries > 0 else None)
        return snap


class AdmissionMetrics:
    """Streaming-admission telemetry: queue depth, waits, drain causes,
    backpressure decisions (rejected / shed submissions)."""

    def __init__(self, reservoir: int = 4096):
        self._lock = threading.Lock()
        self._wait = _Reservoir(reservoir, seed=1)
        self.n_drains = 0
        self.n_submitted = 0
        self.max_depth = 0
        self._depth_sum = 0
        self.causes = {"full": 0, "flush": 0, "timeout": 0}
        self.n_rejected = 0         # new submissions turned away (reject)
        self.n_shed = 0             # queued submissions evicted (shed_oldest)
        self.queue_high_water = 0   # max depth observed at admit time
        self.n_stale_requeue = 0    # wave items re-enqueued on epoch races

    def record_submit(self):
        """One ``AQPServer.submit`` call (cache hits and dupes included)."""
        with self._lock:
            self.n_submitted += 1

    def record_shed(self, reason: str, depth: int):
        """One backpressure decision: a submission rejected at the door
        (``reason="reject"``) or evicted from the queue (``"shed_oldest"``).
        Counted per *submission*, not per attached future. ``depth`` (the
        queue depth observed at decision time) feeds the high-water mark,
        NOT ``max_depth`` (which stays drain-time-only as documented)."""
        with self._lock:
            if reason == "reject":
                self.n_rejected += 1
            else:
                self.n_shed += 1
            self.queue_high_water = max(self.queue_high_water, depth)

    def record_stale_requeue(self):
        """One submission re-enqueued because a rebuild raced its wave
        (the scheduler's per-item epoch re-validation refused to pair the
        old plan with the new synopsis)."""
        with self._lock:
            self.n_stale_requeue += 1

    def record_drain(self, stats):
        """One admission-loop drain (a ``scheduler.DrainStats``)."""
        with self._lock:
            self.n_drains += 1
            self.max_depth = max(self.max_depth, stats.depth)
            self._depth_sum += stats.depth
            self.causes[stats.cause] = self.causes.get(stats.cause, 0) + 1

    def record_wait(self, wait_s: float):
        """One submission's admission wait (submit -> drained into a wave)."""
        with self._lock:
            self._wait.add(wait_s)

    def snapshot(self) -> dict:
        """Point-in-time admission stats (see ``docs/serving.md``)."""
        with self._lock:
            p50, p99 = self._wait.percentiles_ms()
            return {
                "submitted": self.n_submitted,
                "drains": self.n_drains,
                "drain_causes": dict(self.causes),
                "max_queue_depth": self.max_depth,
                "mean_queue_depth": (self._depth_sum / self.n_drains
                                     if self.n_drains else 0.0),
                "wait_p50_ms": p50,
                "wait_p99_ms": p99,
                "rejected": self.n_rejected,
                "shed": self.n_shed,
                "queue_high_water": self.queue_high_water,
                "stale_requeues": self.n_stale_requeue,
            }


# The EXPLAIN stage keys StageMetrics aggregates (matches
# ``repro.obs.trace.QueryTrace.explain`` stage names). The two
# ``plan_*`` keys split the plan stage by planner path: a traced query's
# ``plan_ms`` additionally lands in ``plan_full`` (cold parse+plan) or
# ``plan_template_hit`` (zero-parse template bind / plan-cache hit)
# according to its ``plan_path`` label.
_STAGE_KEYS = ("plan", "admit", "queue", "assemble", "execute", "resolve",
               "plan_template_hit", "plan_full")


class StageMetrics:
    """Trace-derived per-stage latency reservoirs (seconds in, ms out)."""

    def __init__(self, reservoir: int = 4096):
        self._lock = threading.Lock()
        self._stages = {k: _Reservoir(reservoir, seed=2) for k in _STAGE_KEYS}
        self.n_explained = 0

    def record_explain(self, explain: dict):
        """Fold one query's EXPLAIN breakdown into the stage reservoirs."""
        with self._lock:
            self.n_explained += 1
            for key, res in self._stages.items():
                ms = explain.get(f"{key}_ms")
                if ms is not None:
                    res.add(ms / 1e3)
            path = explain.get("plan_path")
            plan_ms = explain.get("plan_ms")
            if path is not None and plan_ms is not None:
                split = "plan_full" if path == "full" else "plan_template_hit"
                self._stages[split].add(plan_ms / 1e3)

    def snapshot(self) -> dict:
        """Per-stage ``{"p50_ms", "p99_ms"}`` plus the explained count."""
        with self._lock:
            out = {"explained": self.n_explained}
            for key, res in self._stages.items():
                p50, p99 = res.percentiles_ms()
                out[key] = {"p50_ms": p50, "p99_ms": p99}
            return out


class ColdTierMetrics:
    """Server-wide cold-tier governor telemetry: decoded-engine resident
    bytes (current + high-water) and total demotions.

    ``record_resident`` is fed *post-enforcement* resident bytes by the
    governor, so with ``max_engine_bytes`` set the high-water mark is the
    proof the budget held — a transient decode-then-evict never lands in
    it."""

    def __init__(self):
        self._lock = threading.Lock()
        self.resident_bytes = 0
        self.resident_high_water = 0
        self.n_demotes = 0
        self.n_sweeps = 0

    def record_resident(self, n_bytes: int):
        """One governor sweep's post-enforcement resident-bytes total."""
        with self._lock:
            self.n_sweeps += 1
            self.resident_bytes = int(n_bytes)
            self.resident_high_water = max(self.resident_high_water,
                                           int(n_bytes))

    def record_demote(self, n: int = 1):
        """``n`` engines demoted back to their blobs."""
        with self._lock:
            self.n_demotes += int(n)

    def snapshot(self) -> dict:
        """Point-in-time cold-tier dict (see ``docs/compression.md``)."""
        with self._lock:
            return {
                "resident_bytes": self.resident_bytes,
                "resident_high_water": self.resident_high_water,
                "demotes": self.n_demotes,
                "sweeps": self.n_sweeps,
            }


class FaultMetrics:
    """Failure-containment counters (see ``docs/robustness.md``).

    Every contained failure increments exactly one primary counter:
    ``query_errors`` (futures resolved with a typed ``QueryError``),
    ``quarantined`` (quarantine events — a poison query or a cold table
    entering quarantine), ``deadline_expired`` (futures resolved with
    ``DeadlineExceeded``), ``decode_retries`` (cold decode attempts
    retried after a failure), plus supporting ``exec_retries`` (waves
    re-run after an execution failure) and ``worker_restarts`` is
    reported by the admission queue itself.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.n_query_errors = 0
        self.n_quarantined = 0
        self.n_deadline_expired = 0
        self.n_decode_retries = 0
        self.n_exec_retries = 0

    def record_query_error(self):
        """One future resolved with a typed ``QueryError`` result."""
        with self._lock:
            self.n_query_errors += 1

    def record_quarantined(self):
        """One quarantine event (query statement or cold table)."""
        with self._lock:
            self.n_quarantined += 1

    def record_deadline_expired(self):
        """One future resolved with a ``DeadlineExceeded`` result."""
        with self._lock:
            self.n_deadline_expired += 1

    def record_decode_retry(self):
        """One cold-decode attempt retried after a failure."""
        with self._lock:
            self.n_decode_retries += 1

    def record_exec_retry(self):
        """One submission re-enqueued after a wave execution failure."""
        with self._lock:
            self.n_exec_retries += 1

    def snapshot(self) -> dict:
        """Point-in-time fault-counter dict."""
        with self._lock:
            return {
                "query_errors": self.n_query_errors,
                "quarantined": self.n_quarantined,
                "deadline_expired": self.n_deadline_expired,
                "decode_retries": self.n_decode_retries,
                "exec_retries": self.n_exec_retries,
            }


class Metrics:
    """Per-table ``TableMetrics`` + admission stats + server-wide totals."""

    def __init__(self, reservoir: int = 4096):
        self.reservoir = reservoir
        self._lock = threading.Lock()
        self._tables: dict[str, TableMetrics] = {}
        self.admission = AdmissionMetrics(reservoir)
        self.stages = StageMetrics(reservoir)
        self.cold = ColdTierMetrics()
        self.faults = FaultMetrics()

    def table(self, name: str) -> TableMetrics:
        """The (lazily created) ``TableMetrics`` for ``name``."""
        tm = self._tables.get(name)
        if tm is None:
            with self._lock:
                tm = self._tables.setdefault(name, TableMetrics(self.reservoir))
        return tm

    def record_explain(self, explain: dict):
        """One traced query's stage breakdown -> stage-latency reservoirs."""
        self.stages.record_explain(explain)

    def snapshot(self, plan_cache=None, result_cache=None,
                 template_cache=None) -> dict:
        """Full telemetry snapshot: ``{"tables", "totals"}`` (see
        ``docs/serving.md`` for every field)."""
        with self._lock:
            tables = sorted(self._tables.items())
        out = {name: tm.snapshot() for name, tm in tables}
        totals = {
            "queries_served": sum(t["queries_served"] for t in out.values()),
            "queries_executed": sum(t["queries_executed"] for t in out.values()),
            "batched_fraction": (
                sum(t["batched"] for t in out.values())
                / max(sum(t["queries_executed"] for t in out.values()), 1)),
            "admission": self.admission.snapshot(),
            "stages": self.stages.snapshot(),
            "faults": self.faults.snapshot(),
        }
        if plan_cache is not None:
            totals["plan_cache"] = plan_cache.stats()
        if result_cache is not None:
            totals["result_cache"] = result_cache.stats()
        if template_cache is not None:
            totals["template_cache"] = template_cache.stats()
        return {"tables": out, "totals": totals}
