"""Per-table serving telemetry: latency percentiles, throughput, hit rates.

Latencies go into a bounded reservoir per table (uniform replacement after
``reservoir`` samples) so long-running servers report stable p50/p99 without
unbounded memory. Counters are exact.
"""
from __future__ import annotations

import random
import time

import numpy as np


class TableMetrics:
    def __init__(self, reservoir: int = 4096, seed: int = 0):
        self.reservoir = int(reservoir)
        self._rng = random.Random(seed)
        self._lat: list[float] = []
        self.n_queries = 0          # executed (cache misses)
        self.n_batched = 0          # executed via the fused batched kernel
        self.n_fallback = 0         # executed via the per-query path
        self.n_result_hits = 0      # served straight from the result cache
        self._t_first = None
        self._t_last = None

    def record(self, latency_s: float, batched: bool):
        now = time.perf_counter()
        self._t_first = self._t_first if self._t_first is not None else now
        self._t_last = now
        self.n_queries += 1
        if batched:
            self.n_batched += 1
        else:
            self.n_fallback += 1
        if len(self._lat) < self.reservoir:
            self._lat.append(latency_s)
        else:
            idx = self._rng.randrange(self.n_queries)
            if idx < self.reservoir:
                self._lat[idx] = latency_s

    def record_result_hit(self):
        self.n_result_hits += 1

    def snapshot(self) -> dict:
        lat = np.asarray(self._lat, float)
        served = self.n_queries + self.n_result_hits
        span = ((self._t_last - self._t_first)
                if self._t_first is not None else 0.0)
        return {
            "queries_served": served,
            "queries_executed": self.n_queries,
            "batched": self.n_batched,
            "fallback": self.n_fallback,
            "result_cache_hits": self.n_result_hits,
            "batched_fraction": (self.n_batched / self.n_queries
                                 if self.n_queries else 0.0),
            "p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else None,
            "p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else None,
            "qps": (self.n_queries / span if span > 0 else None),
        }


class Metrics:
    """Per-table TableMetrics plus server-wide aggregation."""

    def __init__(self, reservoir: int = 4096):
        self.reservoir = reservoir
        self._tables: dict[str, TableMetrics] = {}

    def table(self, name: str) -> TableMetrics:
        tm = self._tables.get(name)
        if tm is None:
            tm = self._tables[name] = TableMetrics(self.reservoir)
        return tm

    def snapshot(self, plan_cache=None, result_cache=None) -> dict:
        out = {name: tm.snapshot() for name, tm in sorted(self._tables.items())}
        totals = {
            "queries_served": sum(t["queries_served"] for t in out.values()),
            "queries_executed": sum(t["queries_executed"] for t in out.values()),
            "batched_fraction": (
                sum(t["batched"] for t in out.values())
                / max(sum(t["queries_executed"] for t in out.values()), 1)),
        }
        if plan_cache is not None:
            totals["plan_cache"] = plan_cache.stats()
        if result_cache is not None:
            totals["result_cache"] = result_cache.stats()
        return {"tables": out, "totals": totals}
