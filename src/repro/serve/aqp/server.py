"""AQPServer: multi-table AQP serving front-end with streaming admission.

Pipeline per submitted SQL string (``submit`` -> ``QueryFuture``):

    normalize -> plan cache -> result cache -> in-flight dedupe -> enqueue
       |            |              |                                  |
       |       (epoch-keyed   (epoch-keyed;                   StreamingAdmission
       |        QueryPlans)    GROUP BY adds                  drains plan-shape
       v                       per-leaf entries)              waves -> futures
    FROM <table> resolved via TableCatalog (PlanError if unknown)

``submit`` enqueues immediately and returns a future; the admission worker
drains the queue into execution waves under a ``max_wait_ms`` /
``max_batch`` policy and resolves futures as waves complete, without
blocking later arrivals. ``query_batch`` survives as a thin synchronous
wrapper: submit everything, flush, wait.

GROUP BY queries ride the batched fast path: plans arrive from
``core/query.py`` already expanded into per-category leaf plans, the server
executes every *uncached* leaf of every in-flight query through the
scheduler's fused ``batched_weightings`` launches, and reassembles per-group
results. Leaf results are cached under plan-canonical keys
(``QueryPlan.canonical_key``), so overlapping GROUP BYs — textual variants,
or re-issues after partial eviction — share entries.

Staleness: every ``AQPFramework`` bumps its epoch on ingest/append_rows;
cache entries are tagged with the epoch captured at *planning* time, so a
result computed before an ``append_rows`` that lands mid-flight is stored
under the old epoch and can never be served after the bump — and a query
against a stale (un-rebuilt) table fails with ``RuntimeError`` exactly like
the single-table ``AQPFramework.query``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time

from repro.core import sql as sqlmod
from repro.core.query import QueryPlan, QueryResult, assemble_groups
from repro.serve.aqp.cache import LRUCache, normalize_sql
from repro.serve.aqp.catalog import TableCatalog
from repro.serve.aqp.metrics import Metrics
from repro.serve.aqp.scheduler import BatchScheduler, StreamingAdmission


class QueryFuture(concurrent.futures.Future):
    """Handle for one submitted query; resolves to a ``QueryResult``.

    Standard ``concurrent.futures.Future`` API (``result(timeout)``,
    ``done()``, ``exception()``, ``add_done_callback``) plus the originating
    ``sql`` text for bookkeeping.
    """

    def __init__(self, sql: str = ""):
        super().__init__()
        self.sql = sql


@dataclasses.dataclass
class _Submission:
    """One enqueued (not yet executed) query and its attached futures."""

    norm: str
    table: str
    plan: QueryPlan
    epoch: int                       # table epoch captured at planning time
    t_submit: float
    futures: list                    # [QueryFuture]; index 0 is the primary
    missing: list | None = None      # GROUP BY: leaf indices still to execute
    cached_leaves: dict = dataclasses.field(default_factory=dict)


def _leaf_key(plan: QueryPlan) -> str:
    """Result-cache key for one GROUP BY leaf plan.

    Plan-canonical (text-independent), prefixed so it can never collide
    with a normalized-SQL whole-query key (SQL never starts with ``@``).
    """
    return "@leaf|" + plan.canonical_key()


class AQPServer:
    """Multi-table AQP serving front-end (catalog + admission + caches).

    Args:
        catalog: existing ``TableCatalog`` to serve from (default: new).
        mode: scheduler execution mode — ``"pallas"`` / ``"ref"`` /
            ``"numpy"`` / ``None`` (auto; see ``scheduler.BatchScheduler``).
        plan_cache_size / result_cache_size: LRU capacities (entries).
        max_group / min_group: fused-launch group bounds (scheduler knobs).
        max_wait_ms: admission policy — how long the oldest queued
            submission may wait before a partial wave fires.
        max_batch: admission policy — wave fires early once this many
            submissions are queued.
    """

    def __init__(self, catalog: TableCatalog | None = None,
                 mode: str | None = None,
                 plan_cache_size: int = 4096,
                 result_cache_size: int = 16384,
                 max_group: int = 256, min_group: int = 2,
                 max_wait_ms: float = 2.0, max_batch: int = 64):
        self.catalog = catalog or TableCatalog()
        self.scheduler = BatchScheduler(self.catalog, mode=mode,
                                        max_group=max_group,
                                        min_group=min_group)
        self.admission = StreamingAdmission(self._execute_wave,
                                            max_wait_ms=max_wait_ms,
                                            max_batch=max_batch)
        self.plan_cache = LRUCache(plan_cache_size)
        self.result_cache = LRUCache(result_cache_size)
        self.metrics = Metrics()
        self._wiring: dict[str, tuple] = {}   # name -> (framework, callback)
        # One lock guards caches, metrics and the in-flight dedupe map;
        # taken by the submitting thread, the admission worker, and
        # framework invalidation callbacks.
        self._lock = threading.RLock()
        self._inflight: dict[str, _Submission] = {}

    # ------------------------------------------------------------ registration

    def register(self, name: str, framework) -> "AQPServer":
        """Register a table; wires eager cache purging to its invalidation.
        Re-registering a name detaches the previous framework's wiring so a
        replaced table can no longer purge its successor's cache entries."""
        self.catalog.register(name, framework)
        self._wire(name, framework)
        return self

    def register_table(self, name: str, table: dict, **kwargs) -> "AQPServer":
        """Convenience: build + ingest a framework from a raw column dict
        (kwargs forward to ``TableCatalog.register_table``) and register it."""
        fw = self.catalog.register_table(name, table, **kwargs)
        self._wire(name, fw)
        return self

    def _wire(self, name: str, framework):
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
            self._purge(name)     # drop entries computed from the old table
        cb = lambda fw, name=name: self._purge(name)  # noqa: E731
        framework.on_invalidate(cb)
        self._wiring[name] = (framework, cb)

    def unregister(self, name: str):
        """Drop a table: detach its invalidation wiring and purge its
        cache entries."""
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
        self.catalog.unregister(name)
        self._purge(name)

    def close(self):
        """Shut down: drain+stop the admission worker, then detach every
        framework callback so a discarded server is not kept alive (and
        purged into) by long-lived frameworks."""
        self.admission.close()
        for name, (fw, cb) in list(self._wiring.items()):
            fw.off_invalidate(cb)
        self._wiring.clear()

    def _purge(self, name: str):
        with self._lock:
            self.plan_cache.purge_table(name)
            self.result_cache.purge_table(name)

    # ----------------------------------------------------------------- queries

    def submit(self, sql_text: str) -> QueryFuture:
        """Enqueue one query; returns immediately with a ``QueryFuture``.

        Planning (cached), result-cache lookup and in-flight deduplication
        happen inline on the calling thread — a cache hit resolves the
        future before ``submit`` returns, and planning errors (unknown
        table/column, stale synopsis) are set ON the future rather than
        raised, so streaming callers handle every outcome in one place.
        Uncached queries enter the admission queue and resolve when their
        wave completes.
        """
        fut = QueryFuture(sql_text)
        t_submit = time.perf_counter()
        norm = normalize_sql(sql_text)
        with self._lock:
            self.metrics.admission.record_submit()
            inflight = self._inflight.get(norm)
            if inflight is not None:          # identical query already queued
                inflight.futures.append(fut)
                return fut
            try:
                table, plan, epoch = self._plan_for(norm)
            except Exception as exc:          # PlanError / stale RuntimeError
                fut.set_exception(exc)
                return fut
            rentry = self.result_cache.get(norm, self.catalog.epoch)
            if rentry is not None:
                self.metrics.table(table).record_result_hit()
                fut.set_result(dataclasses.replace(rentry.value,
                                                   latency_s=0.0))
                return fut
            self.result_cache.miss(table)
            sub = _Submission(norm, table, plan, epoch, t_submit, [fut])
            if plan.leaf_plans:
                self._lookup_leaves(sub)
                if not sub.missing:           # every leaf served from cache
                    self._resolve_cached_group(sub)
                    return fut
            self._inflight[norm] = sub
        try:
            self.admission.submit(sub, t_submit)
        except Exception as exc:              # closed server: fail, don't leak
            with self._lock:
                self._inflight.pop(norm, None)
                futures = list(sub.futures)
            for f in futures:
                f.set_exception(exc)
        return fut

    def flush(self):
        """Ask the admission worker to drain the queue now (no-op if empty)."""
        self.admission.flush()

    def query(self, sql_text: str) -> QueryResult:
        """Synchronous single query (submit + flush + wait)."""
        return self.query_batch([sql_text])[0]

    def query_batch(self, sqls: list[str]) -> list[QueryResult]:
        """Synchronous wave: results align with ``sqls``.

        Thin wrapper over the streaming path: submits everything, flushes
        the admission queue (so a blocking caller never pays ``max_wait_ms``)
        and waits. Raises PlanError for unknown tables/columns and
        RuntimeError for stale tables — the serving contract matches
        ``AQPFramework.query``.
        """
        futures = [self.submit(sql) for sql in sqls]
        self.flush()
        return [fut.result() for fut in futures]

    # ------------------------------------------------------ submit-side helpers

    def _plan_for(self, norm: str):
        """Plan (via cache) -> (table, plan, epoch the plan is valid at).

        The epoch is captured BEFORE the engine fetch, so if a rebuild
        races the planning the plan is tagged with the older epoch and can
        only ever validate — in the caches and at wave execution — against
        the synopsis it was actually planned for.
        """
        entry = self.plan_cache.get(norm, self.catalog.epoch)
        if entry is not None:
            return entry.table, entry.value, entry.epoch
        parsed = sqlmod.parse_sql(norm)
        table = parsed.table
        self.plan_cache.miss(table if table in self.catalog else None)
        epoch = self.catalog.epoch(table)
        engine = self.catalog.engine(table)   # PlanError / RuntimeError here
        plan = engine.plan_query(parsed)
        self.plan_cache.put(norm, table, epoch, plan)
        return table, plan, epoch

    def _lookup_leaves(self, sub: _Submission):
        """Fill ``sub.cached_leaves`` / ``sub.missing`` from the result cache
        (one recorded miss per missing leaf, matching the per-leaf hits)."""
        sub.missing = []
        sub.cached_leaves = {}
        for i, leaf in enumerate(sub.plan.leaf_plans):
            entry = self.result_cache.get(_leaf_key(leaf), self.catalog.epoch)
            if entry is not None:
                sub.cached_leaves[i] = entry.value
            else:
                self.result_cache.miss(sub.table)
                sub.missing.append(i)

    def _replan(self, sub: _Submission):
        """The table changed while ``sub`` sat in the admission queue: its
        plan may encode literals against a synopsis that no longer exists.
        Re-plan against the current synopsis (plan cache was purged by the
        epoch bump) and refresh the per-leaf cache lookups; raises the
        usual PlanError/RuntimeError if the table is gone or stale."""
        sub.table, sub.plan, sub.epoch = self._plan_for(sub.norm)
        sub.missing = None
        if sub.plan.leaf_plans:
            self._lookup_leaves(sub)

    def _resolve_cached_group(self, sub: _Submission):
        """GROUP BY answered entirely from per-leaf cache entries."""
        result = assemble_groups(sub.plan, sub.cached_leaves)
        tm = self.metrics.table(sub.table)
        tm.record_result_hit()
        tm.record_group_expansion(0, len(sub.cached_leaves))
        self.result_cache.put(sub.norm, sub.table, sub.epoch, result)
        for fut in sub.futures:
            fut.set_result(dataclasses.replace(result, latency_s=0.0))

    # ------------------------------------------------------- admission worker

    def _execute_wave(self, batch: list, drain):
        """Execute one drained wave (admission-worker thread).

        Submissions whose table epoch moved while they sat in the queue
        (append_rows/rebuild landed mid-flight) are re-planned first — a
        plan encodes literals against one specific synopsis, so executing
        it against a rebuilt one would be silently wrong; if the table is
        stale (no rebuild yet) the re-plan raises and the futures resolve
        with that error. Then expands GROUP BY submissions into their
        uncached leaf plans, runs ALL work units (plain queries + leaves of
        every in-flight GROUP BY) through one ``BatchScheduler.execute``
        call — plan-shape grouping inside the scheduler fuses everything
        fusable — then reassembles, caches and resolves. A scheduler error
        isolates to per-item retry so one poisoned query cannot reject an
        entire wave's futures.
        """
        now = time.perf_counter()
        prefailed: dict[int, Exception] = {}
        with self._lock:
            self.metrics.admission.record_drain(drain)
            for sub in batch:
                self.metrics.admission.record_wait(now - sub.t_submit)
                if sub.epoch != self.catalog.epoch(sub.table):
                    try:
                        self._replan(sub)
                    except Exception as exc:
                        prefailed[id(sub)] = exc

        items, slots = [], []          # slots: (submission, leaf_idx | None)
        for sub in batch:
            if id(sub) in prefailed:
                continue
            if sub.plan.leaf_plans:
                for i in sub.missing:
                    items.append((sub.table, sub.plan.leaf_plans[i]))
                    slots.append((sub, i))
            else:
                items.append((sub.table, sub.plan))
                slots.append((sub, None))

        errors: dict[int, Exception] = {}
        try:
            scheduled = self.scheduler.execute(items)
        except Exception:
            scheduled = [None] * len(items)
            for k, item in enumerate(items):
                try:
                    scheduled[k] = self.scheduler.execute([item])[0]
                except Exception as exc:       # isolate the poisoned item
                    errors[k] = exc

        leaf_out: dict[int, dict] = {}         # id(sub) -> {leaf_idx: sr}
        failed = dict(prefailed)               # id(sub) -> first error
        direct: dict[int, object] = {}         # id(sub) -> ScheduledResult
        for k, (sub, leaf_idx) in enumerate(slots):
            if k in errors:
                failed.setdefault(id(sub), errors[k])
            elif leaf_idx is None:
                direct[id(sub)] = scheduled[k]
            else:
                leaf_out.setdefault(id(sub), {})[leaf_idx] = scheduled[k]

        with self._lock:
            for sub in batch:
                self._inflight.pop(sub.norm, None)
                err = failed.get(id(sub))
                if err is not None:
                    for fut in sub.futures:
                        fut.set_exception(err)
                elif sub.plan.leaf_plans:
                    self._finish_group(sub, leaf_out.get(id(sub), {}))
                else:
                    self._finish_single(sub, direct[id(sub)])

    def _finish_single(self, sub: _Submission, sr):
        self.result_cache.put(sub.norm, sub.table, sub.epoch, sr.result)
        self.metrics.table(sub.table).record(sr.latency_s, sr.batched)
        self._resolve(sub, sr.result)

    def _finish_group(self, sub: _Submission, executed: dict):
        """Cache executed leaves, merge with cached ones, assemble, resolve."""
        leaf_results = dict(sub.cached_leaves)
        latency = 0.0
        batched = False
        for i, sr in executed.items():
            self.result_cache.put(_leaf_key(sub.plan.leaf_plans[i]),
                                  sub.table, sub.epoch, sr.result)
            leaf_results[i] = sr.result
            latency += sr.latency_s
            batched = batched or sr.batched
        result = assemble_groups(sub.plan, leaf_results)
        result.latency_s = latency
        self.result_cache.put(sub.norm, sub.table, sub.epoch, result)
        tm = self.metrics.table(sub.table)
        tm.record(latency, batched)
        tm.record_group_expansion(len(executed), len(sub.cached_leaves))
        self._resolve(sub, result)

    def _resolve(self, sub: _Submission, result: QueryResult):
        """Primary future gets the real latency; in-flight duplicates are
        served (not executed) and count as result-cache hits."""
        sub.futures[0].set_result(result)
        for fut in sub.futures[1:]:
            self.metrics.table(sub.table).record_result_hit()
            fut.set_result(dataclasses.replace(result, latency_s=0.0))

    # ------------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Telemetry snapshot (tables + totals; see ``docs/serving.md``)."""
        with self._lock:
            snap = self.metrics.snapshot(self.plan_cache, self.result_cache)
        snap["totals"]["admission"]["queue_depth"] = self.admission.depth()
        return snap
