"""AQPServer: multi-table AQP serving front-end with streaming admission.

Pipeline per submitted SQL string (``submit`` -> ``QueryFuture``):

    normalize -> plan cache -> template cache -> result cache -> dedupe -> enqueue
       |            |              |                                          |
       |       (epoch-keyed   (epoch-keyed                           StreamingAdmission
       |        QueryPlans)    PlanTemplates:                        drains plan-shape
       v                       zero-parse shape hits)                waves -> futures
    FROM <table> resolved via TableCatalog (PlanError if unknown)

**Planner fast path** (zero-parse templating): when ``plan_templates`` is
on, a submission that misses the exact-text plan cache is fingerprinted
(``sql.fingerprint_sql`` — a tokenizer pass, no parse) into a
literal-stripped shape key + literal vector. A shape that hits the
epoch-keyed template cache skips ``parse_sql``/``plan_query`` entirely:
the submission carries ``(template, literals)`` with ``plan=None`` and the
admission worker binds every such submission of a wave in one
``PlanTemplate.bind_batch`` call per template — literal encoding for the
whole wave is a single numpy pass. Bound plans are bit-for-bit equal to
the cold path's (asserted by tests and the ``--plan-smoke`` lane). Cold
shapes plan as before and compile + cache their template as a side effect;
with ``planner_workers > 0`` that cold planning runs on a small planner
pool so the submit path never blocks on a parse.

``submit`` enqueues immediately and returns a future; the admission worker
drains the queue into execution waves under a ``max_wait_ms`` /
``max_batch`` policy and resolves futures as waves complete, without
blocking later arrivals. ``query_batch`` survives as a thin synchronous
wrapper: submit everything, flush, wait (with drain-and-retry when the
bounded queue rejects a submission — see ``retry_timeout_s``).

**Backpressure**: the admission queue is bounded by ``max_queue_depth``;
a full queue resolves the overflowing submission's futures with a typed
``AdmissionRejected`` *result* (never an exception raised in the worker)
according to ``shed_policy`` — see ``scheduler.StreamingAdmission``.

**Locking** (lock-split submit path): two locks replace the original
single server RLock so concurrent submitters no longer serialize against
each other or against wave resolution:

  * ``_plan_lock`` — read-mostly: guards the plan cache only. Planning
    itself (parse + literal encoding + GROUP BY leaf expansion, the
    expensive part of admission) runs with NO lock held; only the cache
    get/put bracket it.
  * ``_state_lock`` — short critical sections: result cache, metrics, and
    the in-flight dedupe map. Wave resolution snapshots futures under it
    but calls ``set_result``/``set_exception`` outside it, so done
    callbacks never run under (or deadlock against) a server lock.

The only nesting is ``_state_lock`` -> ``_plan_lock`` (re-plan inside a
wave); nothing acquires them in the reverse order. ``single_lock=True``
collapses both to one lock and plans inside it — the pre-split critical
section, kept as the contention baseline for ``benchmarks/bench_serving``.

GROUP BY queries ride the batched fast path: plans arrive from
``core/query.py`` already expanded into per-category leaf plans, the server
executes every *uncached* leaf of every in-flight query through the
scheduler's fused ``batched_weightings`` launches, and reassembles per-group
results. Leaf results are cached under plan-canonical keys
(``QueryPlan.canonical_key``), so overlapping GROUP BYs — textual variants,
or re-issues after partial eviction — share entries.

Staleness: every ``AQPFramework`` bumps its epoch on ingest/append_rows;
cache entries are tagged with the epoch captured at *planning* time, so a
result computed before an ``append_rows`` that lands mid-flight is stored
under the old epoch and can never be served after the bump — and a query
against a stale (un-rebuilt) table fails with ``RuntimeError`` exactly like
the single-table ``AQPFramework.query``.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time

from repro.core import sql as sqlmod
from repro.core.query import (AdmissionRejected, DeadlineExceeded, PlanError,
                              QueryError, QueryPlan, QueryResult,
                              assemble_groups)
from repro.obs.export import spans_to_events, trace_json, write_trace
from repro.obs.trace import QueryTrace, Tracer
from repro.serve.aqp.cache import LRUCache, normalize_sql
from repro.serve.aqp.catalog import (ColdTable, TableCatalog,
                                     TableQuarantinedError)
from repro.serve.aqp.metrics import Metrics
from repro.serve.aqp.scheduler import (BatchScheduler, PlannerPool,
                                       StreamingAdmission)

import repro.serve.aqp.faults as faults


class QueryFuture(concurrent.futures.Future):
    """Handle for one submitted query; resolves to a ``QueryResult``.

    Standard ``concurrent.futures.Future`` API (``result(timeout)``,
    ``done()``, ``exception()``, ``add_done_callback``) plus the originating
    ``sql`` text for bookkeeping. Overload decisions resolve it with an
    ``AdmissionRejected`` result (``result().rejected`` is True), never an
    exception.
    """

    def __init__(self, sql: str = ""):
        super().__init__()
        self.sql = sql


@dataclasses.dataclass
class _Submission:
    """One enqueued (not yet executed) query and its attached futures.

    ``plan`` may be None for a template-cache hit: the submission then
    carries ``(template, literals)`` and the admission worker binds the
    plan at wave time (one ``bind_batch`` per template per wave).
    """

    norm: str
    table: str
    plan: QueryPlan | None
    epoch: int                       # table epoch captured at planning time
    t_submit: float
    futures: list                    # [QueryFuture]; index 0 is the primary
    missing: list | None = None      # GROUP BY: leaf indices still to execute
    cached_leaves: dict = dataclasses.field(default_factory=dict)
    retries: int = 0                 # stale-epoch re-enqueues (bounded)
    trace: QueryTrace | None = None  # per-query trace (tracing enabled only)
    template: object = None          # PlanTemplate (deferred-bind hits only)
    literals: tuple | None = None    # fingerprint literal vector (ditto)
    deadline_at: float | None = None  # perf_counter deadline (deadline_ms)
    exec_failures: int = 0           # wave execution failures (bounded retry)
    requeued: bool = False           # True while re-admitted to the queue


def _leaf_key(plan: QueryPlan) -> str:
    """Result-cache key for one GROUP BY leaf plan.

    Plan-canonical (text-independent), prefixed so it can never collide
    with a normalized-SQL whole-query key (SQL never starts with ``@``).
    """
    return "@leaf|" + plan.canonical_key()


class AQPServer:
    """Multi-table AQP serving front-end (catalog + admission + caches).

    Args:
        catalog: existing ``TableCatalog`` to serve from (default: new).
        mode: scheduler execution mode — ``"pallas"`` / ``"ref"`` /
            ``"numpy"`` / ``None`` (auto; see ``scheduler.BatchScheduler``).
        plan_cache_size / result_cache_size: LRU capacities (entries).
        plan_templates: zero-parse planner fast path (default on) — see
            the module docstring; ``docs/serving.md`` has the architecture.
        template_cache_size: ``PlanTemplate`` LRU capacity (shapes).
        planner_workers: > 0 offloads *cold* planning to a
            ``scheduler.PlannerPool`` of that many workers, so the submit
            path never blocks on a parse (0 = plan inline, the default).
        max_result_bytes: approximate byte budget for the result cache
            (``<= 0`` = entries-only bounding); the LRU end evicts until
            the estimated footprint fits (``cache.LRUCache``).
        max_group / min_group: fused-launch group bounds (scheduler knobs).
        max_wait_ms: admission policy — how long the oldest queued
            submission may wait before a partial wave fires.
        max_batch: admission policy — wave fires early once this many
            submissions are queued.
        max_queue_depth: backpressure — bound on the admission queue
            (``<= 0`` = unbounded; default 1024).
        shed_policy: what a full queue does — ``"reject"`` (turn the new
            submission away), ``"shed_oldest"`` (evict the oldest queued
            submission to admit the new one) or ``"block"`` (pace the
            submitter until the worker drains space). Rejected/shed
            futures resolve with ``AdmissionRejected``.
        retry_timeout_s: ``query_batch``'s drain-and-retry budget when its
            submissions are rejected by the bounded queue.
        single_lock: compatibility/benchmark baseline — plan under the one
            big server lock (the pre-split critical section) instead of the
            lock-split submit path.
        trace_enabled: per-query tracing (``repro.obs``): every submission
            carries a ``QueryTrace`` through submit -> admission -> wave ->
            resolution, its result gains an ``explain`` stage breakdown,
            stage spans land in the server's span ring
            (``export_trace``/``trace_json``), stage-latency percentiles
            fold into ``stats()["totals"]["stages"]`` and queries slower
            than ``slow_query_ms`` enter the bounded slow-query log.
            Off by default: the disabled path adds no allocation and no
            clock reads beyond the pre-existing ``t_submit`` stamp.
        trace_buffer: span ring capacity (oldest spans overwritten).
        slow_query_ms: slow-query log threshold on a traced query's
            end-to-end latency (``explain()["total_ms"]``).
        max_engine_bytes / demote_idle_s: cold-tier memory governor —
            budget on decoded cold-table engines and idle-demotion window;
            see ``docs/compression.md`` for semantics and defaults.
    """

    # A submission whose table epoch keeps moving mid-wave re-enqueues at
    # most this many times before its futures fail (each retry implies a
    # full rebuild landed inside one wave — more than a couple in a row
    # means the table is being rebuilt faster than queries can run).
    MAX_STALE_RETRIES = 5

    # Bounded slow-query log: newest SLOW_LOG_CAP breakdowns whose total
    # latency crossed ``slow_query_ms`` (a window, like the span ring).
    SLOW_LOG_CAP = 256

    # A query whose wave raises this many times is quarantined: its futures
    # resolve with a typed QueryError and re-submissions of the same
    # normalized text are refused until the quarantine clears (a poison
    # query is contained, not retried forever).
    MAX_EXEC_FAILURES = 2

    # Bounded quarantine map (norm -> cause): oldest entries fall out so a
    # hostile workload cannot grow server state without bound.
    QUARANTINE_CAP = 1024

    def __init__(self, catalog: TableCatalog | None = None,
                 mode: str | None = None,
                 plan_cache_size: int = 4096,
                 result_cache_size: int = 16384,
                 plan_templates: bool = True,
                 template_cache_size: int = 512,
                 planner_workers: int = 0,
                 max_result_bytes: int = 0,
                 max_group: int = 256, min_group: int = 2,
                 max_wait_ms: float = 2.0, max_batch: int = 64,
                 max_queue_depth: int = 1024, shed_policy: str = "reject",
                 retry_timeout_s: float = 30.0, single_lock: bool = False,
                 trace_enabled: bool = False, trace_buffer: int = 65536,
                 slow_query_ms: float = 100.0,
                 max_engine_bytes: int = 0, demote_idle_s: float = 0.0):
        self.catalog = catalog or TableCatalog()
        self.max_engine_bytes = int(max_engine_bytes)
        self.demote_idle_s = float(demote_idle_s)
        self.tracer = Tracer(capacity=trace_buffer, enabled=trace_enabled)
        self.slow_query_ms = float(slow_query_ms)
        self._slow_log: collections.deque = collections.deque(
            maxlen=self.SLOW_LOG_CAP)
        self.scheduler = BatchScheduler(self.catalog, mode=mode,
                                        max_group=max_group,
                                        min_group=min_group,
                                        tracer=self.tracer)
        self.admission = StreamingAdmission(self._execute_wave,
                                            max_wait_ms=max_wait_ms,
                                            max_batch=max_batch,
                                            max_queue_depth=max_queue_depth,
                                            shed_policy=shed_policy,
                                            shed_cb=self._on_shed,
                                            tracer=self.tracer,
                                            idle_cb=self._govern_cold,
                                            error_cb=self._on_wave_error)
        self.plan_cache = LRUCache(plan_cache_size)
        self.result_cache = LRUCache(result_cache_size,
                                     max_bytes=max_result_bytes)
        # Zero-parse fast path: fingerprint-shape -> PlanTemplate, epoch-
        # keyed like the plan cache and guarded by the same _plan_lock.
        self.plan_templates = bool(plan_templates)
        self.template_cache = LRUCache(template_cache_size)
        self._planner = (PlannerPool(planner_workers)
                         if planner_workers > 0 else None)
        self.metrics = Metrics()
        self.retry_timeout_s = float(retry_timeout_s)
        self.single_lock = bool(single_lock)
        self._wiring: dict[str, tuple] = {}   # name -> (framework, callback)
        # Lock split (see module docstring): _state_lock guards result
        # cache + metrics + in-flight map; _plan_lock guards the plan cache.
        # Both RLocks: invalidation callbacks and the single_lock baseline
        # re-enter them. single_lock collapses the two into one.
        self._state_lock = threading.RLock()
        self._plan_lock = (self._state_lock if single_lock
                           else threading.RLock())
        self._inflight: dict[str, _Submission] = {}
        # norm -> (table, cause): statements refused after repeated
        # execution failure. Guarded by _state_lock; bounded; cleared by
        # clear_quarantine(), an epoch bump on the table (_purge), or
        # falling off the cap.
        self._quarantine: collections.OrderedDict = collections.OrderedDict()

    # ------------------------------------------------------------ registration

    def register(self, name: str, framework) -> "AQPServer":
        """Register a table; wires eager cache purging to its invalidation.
        Re-registering a name detaches the previous framework's wiring so a
        replaced table can no longer purge its successor's cache entries."""
        self.catalog.register(name, framework)
        self._wire(name, framework)
        return self

    def register_table(self, name: str, table: dict, **kwargs) -> "AQPServer":
        """Convenience: build + ingest a framework from a raw column dict
        (kwargs forward to ``TableCatalog.register_table``) and register it."""
        fw = self.catalog.register_table(name, table, **kwargs)
        self._wire(name, fw)
        return self

    def register_cold(self, name: str, blob: bytes, compressed=None,
                      params=None, fastpath=None, decode_retries: int = 2,
                      decode_backoff_s: float = 0.01,
                      breaker_reset_s: float = 0.0) -> "AQPServer":
        """Register a cold (storage-tier) table: a bit-packed synopsis blob
        that decodes lazily on the first query against it. The decode
        latency and blob size land in this table's metrics (``stats()``
        ``"cold"`` section); ``compressed`` (a ``CompressedTable``) enables
        GD-native ``rebuild`` on the returned catalog entry.

        The blob is validated (integrity frame + magic, inside
        ``ColdTable``) *before* any telemetry is recorded, so a rejected
        registration leaves no phantom metrics entry behind. The retry /
        backoff / breaker knobs configure decode resilience (retries, then
        quarantine with a typed error — see ``docs/robustness.md``); fault
        events land in ``stats()["totals"]["faults"]`` and on the trace
        ring's "faults" lane."""
        cold = self.catalog.register_cold(
            name, blob, compressed=compressed, params=params,
            fastpath=fastpath,
            decode_cb=lambda n, s, name=name: self._on_cold_decode(name, n, s),
            decode_retries=decode_retries, decode_backoff_s=decode_backoff_s,
            breaker_reset_s=breaker_reset_s,
            fault_cb=lambda ev, n, exc, name=name:
                self._on_cold_fault(name, ev, n, exc))
        self.metrics.table(name).record_cold_register(len(blob))
        self._wire(name, cold)
        return self

    def _on_cold_fault(self, name: str, event: str, n: int, exc):
        """ColdTable fault callback: decode retries and quarantine events
        into the fault counters and the trace ring's "faults" lane."""
        if event == "decode_retry":
            self.metrics.faults.record_decode_retry()
        else:                              # "quarantine"
            self.metrics.faults.record_quarantined()
        if self.tracer.enabled:
            self.tracer.instant(event, track="faults",
                                attrs={"table": name, "attempt": n,
                                       "error": repr(exc)})

    def _wire(self, name: str, framework):
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
            self._purge(name)     # drop entries computed from the old table
        cb = lambda fw, name=name: self._purge(name)  # noqa: E731
        framework.on_invalidate(cb)
        self._wiring[name] = (framework, cb)

    # ------------------------------------------------------- cold-tier governor

    def _on_cold_decode(self, name: str, n_bytes: int, decode_s: float):
        """ColdTable decode callback: per-table telemetry, then immediate
        budget enforcement (a decode is exactly when resident bytes grow,
        so waiting for the next between-waves sweep could overshoot)."""
        try:
            cold = self.catalog.resolve(name)
            resident = getattr(cold, "resident_bytes", None)
        except PlanError:       # unregistered mid-decode
            resident = None
        self.metrics.table(name).record_cold_decode(
            n_bytes, decode_s, resident_bytes=resident)
        if self.max_engine_bytes > 0:
            self._govern_cold(idle=False)

    def _govern_cold(self, idle: bool = True):
        """The cold-tier memory governor: one sweep over the catalog's
        ``ColdTable`` entries.

        Two policies, both LRU-ordered by ``TableMetrics.last_activity``:
        idle demotion (``demote_idle_s > 0``: engines untouched for that
        long drop back to their blobs; only on between-waves sweeps, where
        ``idle=True``) and budget enforcement (``max_engine_bytes > 0``:
        least-recently-active engines demote until the decoded-resident
        total fits). Demotion is epoch-stable, so no cache purge and no
        invalidation callbacks — an in-flight wave holding a demoted
        engine's reference finishes safely and the next query re-decodes.
        Post-enforcement resident bytes land in the server-wide high-water
        telemetry (``stats()["cold"]``)."""
        budget = self.max_engine_bytes
        idle_s = self.demote_idle_s
        if budget <= 0 and idle_s <= 0:
            return
        resident = [(n, t) for n, t in self.catalog.cold_tables()
                    if t.engine is not None]

        def last_activity(name):
            la = self.metrics.table(name).last_activity
            return la if la is not None else 0.0

        demoted = 0
        if idle and idle_s > 0:
            now = time.perf_counter()
            for name, t in resident:
                if now - last_activity(name) >= idle_s and t.demote():
                    self.metrics.table(name).record_demote()
                    demoted += 1
        if budget > 0:
            live = sorted(((n, t) for n, t in resident if t.engine is not None),
                          key=lambda nt: last_activity(nt[0]))
            total = sum(t.resident_bytes for _, t in live)
            for name, t in live:
                if total <= budget:
                    break
                n_bytes = t.resident_bytes
                if t.demote():
                    self.metrics.table(name).record_demote()
                    demoted += 1
                    total -= n_bytes
        if demoted:
            self.metrics.cold.record_demote(demoted)
        self.metrics.cold.record_resident(
            sum(t.resident_bytes for _, t in self.catalog.cold_tables()))

    def demote(self, name: str) -> bool:
        """Explicitly demote one cold table's decoded engine back to its
        blob (same epoch-stable semantics as the governor — caches stay
        valid, the next query re-decodes). Returns True if an engine was
        resident and demoted; False for unknown, non-cold, or already-cold
        tables."""
        try:
            t = self.catalog.resolve(name)
        except PlanError:
            return False
        if not isinstance(t, ColdTable) or not t.demote():
            return False
        self.metrics.table(name).record_demote()
        self.metrics.cold.record_demote()
        self.metrics.cold.record_resident(
            sum(ct.resident_bytes for _, ct in self.catalog.cold_tables()))
        return True

    def unregister(self, name: str):
        """Drop a table: detach its invalidation wiring and purge its
        cache entries."""
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
        self.catalog.unregister(name)
        self._purge(name)

    def close(self):
        """Shut down: join the planner pool (pending cold plans enqueue or
        fail their futures), drain+stop the admission worker, then detach
        every framework callback so a discarded server is not kept alive
        (and purged into) by long-lived frameworks."""
        if self._planner is not None:
            self._planner.close()
        self.admission.close()
        for name, (fw, cb) in list(self._wiring.items()):
            fw.off_invalidate(cb)
        self._wiring.clear()

    def _purge(self, name: str):
        # Sequential (never nested) acquisition: purging needs no atomicity
        # across the two caches — each entry validates its epoch anyway.
        with self._plan_lock:
            self.plan_cache.purge_table(name)
            self.template_cache.purge_table(name)
        with self._state_lock:
            self.result_cache.purge_table(name)
            # An epoch bump (rebuild / re-register) gives quarantined
            # statements against this table a fresh chance.
            for norm in [n for n, (t, _) in self._quarantine.items()
                         if t == name]:
                del self._quarantine[norm]

    # ----------------------------------------------------------------- queries

    def submit(self, sql_text: str,
               deadline_ms: float | None = None) -> QueryFuture:
        """Enqueue one query; returns immediately with a ``QueryFuture``.

        Planning (cached), result-cache lookup and in-flight deduplication
        happen inline on the calling thread — a cache hit resolves the
        future before ``submit`` returns, and planning errors (unknown
        table/column, stale synopsis) are set ON the future rather than
        raised, so streaming callers handle every outcome in one place.
        A full admission queue resolves the future with a typed
        ``AdmissionRejected`` result per ``shed_policy``; otherwise the
        query enters the queue and resolves when its wave completes.

        ``deadline_ms`` attaches a per-query deadline: the drain policy
        fires a wave early rather than let the deadline expire in the
        queue, and a query whose deadline has passed by the time its wave
        starts skips execution and resolves with a typed
        ``DeadlineExceeded`` result. Deadline-carrying submissions skip
        in-flight deduplication (each deadline is its own contract); they
        still hit the result cache. A statement quarantined after
        repeated execution failures resolves immediately with a typed
        ``QueryError`` (``kind="quarantined"``).

        On the lock-split path the expensive planning step runs with no
        server lock held; only the dedupe check / admission bookkeeping
        take the short state lock.
        """
        fut = QueryFuture(sql_text)
        t_submit = time.perf_counter()
        norm = normalize_sql(sql_text)
        deadline_at = (t_submit + deadline_ms / 1e3
                       if deadline_ms is not None else None)
        # Per-query trace only when tracing: the disabled path pays no
        # allocation beyond the future itself.
        trace = QueryTrace(t_submit) if self.tracer.enabled else None
        sub = None
        with self._state_lock:
            self.metrics.admission.record_submit()
            quarantined = self._quarantine.get(norm)
            if quarantined is not None:
                self.metrics.faults.record_query_error()
            else:
                inflight = (self._inflight.get(norm)
                            if deadline_at is None else None)
                if inflight is not None:      # identical query already queued
                    inflight.futures.append(fut)
                    return fut
                if self.single_lock:          # legacy: plan under the lock
                    sub = self._plan_admit(fut, norm, t_submit, trace,
                                           deadline_at)
        if quarantined is not None:
            fut.set_result(QueryError(
                error=quarantined[1], kind="quarantined",
                retries=self.MAX_EXEC_FAILURES))
            return fut
        if not self.single_lock:
            sub = self._plan_admit(fut, norm, t_submit, trace, deadline_at)
        if sub is not None:
            self._enqueue(sub)
        return fut

    def flush(self):
        """Ask the admission worker to drain the queue now (no-op if empty)."""
        self.admission.flush()

    def query(self, sql_text: str) -> QueryResult:
        """Synchronous single query (submit + flush + wait, with the same
        drain-and-retry as ``query_batch`` if the queue is full)."""
        return self.query_batch([sql_text])[0]

    def query_batch(self, sqls: list[str],
                    retry_timeout_s: float | None = None
                    ) -> list[QueryResult]:
        """Synchronous wave: results align with ``sqls``.

        Thin wrapper over the streaming path: submits everything, flushes
        the admission queue (so a blocking caller never pays ``max_wait_ms``)
        and waits. Raises PlanError for unknown tables/columns and
        RuntimeError for stale tables — the serving contract matches
        ``AQPFramework.query``.

        A submission rejected by the bounded admission queue (``"reject"``
        or ``"shed_oldest"`` shed policy under load) is **drained and
        retried**: the queue is flushed and the query re-submitted until it
        is answered or ``retry_timeout_s`` (default: the server's
        ``retry_timeout_s``) elapses, at which point ``TimeoutError`` is
        raised. A synchronous caller therefore never sees an
        ``AdmissionRejected`` result — that outcome is for streaming
        clients that chose to observe overload.
        """
        budget = (self.retry_timeout_s if retry_timeout_s is None
                  else float(retry_timeout_s))
        deadline = time.monotonic() + budget
        futures = [self.submit(sql) for sql in sqls]
        self.flush()
        out = []
        for i, fut in enumerate(futures):
            while True:
                res = fut.result()            # plan/stale errors raise here
                if not getattr(res, "rejected", False):
                    break
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"query_batch: admission queue still full after "
                        f"{budget:.1f}s of drain-and-retry "
                        f"(last outcome: {res.reason}, queue depth "
                        f"{res.queue_depth})")
                self.flush()                  # drain, then retry
                time.sleep(0.001)
                fut = self.submit(sqls[i])
                self.flush()
            out.append(res)
        return out

    # ------------------------------------------------------ submit-side helpers

    def _plan_admit(self, fut: QueryFuture, norm: str, t_submit: float,
                    trace: QueryTrace | None = None,
                    deadline_at: float | None = None) -> _Submission | None:
        """Plan ``norm`` (fast path first), then admit it.

        Resolution order: exact-text plan cache -> template cache (zero
        parse; the plan bind is deferred to the wave) -> cold planning —
        inline, or on the planner pool when ``planner_workers > 0`` (the
        pool job admits AND enqueues; this call then returns None with the
        future pending). Returns the ``_Submission`` the caller should
        enqueue, or None when the future was settled inline / handed off.
        """
        fast = self._plan_fast(norm)
        if fast is not None:
            return self._admit(fut, norm, t_submit, trace, deadline_at,
                               *fast)
        if self._planner is not None:
            self._planner.submit(self._plan_async, fut, norm, t_submit,
                                 trace, deadline_at)
            return None
        return self._plan_cold_admit(fut, norm, t_submit, trace, deadline_at)

    def _plan_fast(self, norm: str):
        """Lock-cheap planner fast path: exact-text plan-cache hit, else
        template-cache hit on the literal-stripped fingerprint shape (no
        ``parse_sql`` on either). Returns admit args or None (plan cold).
        """
        with self._plan_lock:
            entry = self.plan_cache.get(norm, self.catalog.epoch)
        if entry is not None:
            return (entry.table, entry.value, entry.epoch, "plan_cache",
                    None, None)
        if not self.plan_templates:
            return None
        try:
            fp = sqlmod.fingerprint_sql(norm)
        except sqlmod.SQLError:
            return None          # untokenizable: let the cold parse raise
        with self._plan_lock:
            tentry = self.template_cache.get(fp.shape, self.catalog.epoch)
            if tentry is None:
                self.template_cache.miss(None)
        if tentry is not None and tentry.value.n_slots == len(fp.literals):
            return (tentry.table, None, tentry.epoch, "template",
                    tentry.value, fp.literals)
        return None

    def _plan_cold_admit(self, fut: QueryFuture, norm: str, t_submit: float,
                         trace: QueryTrace | None,
                         deadline_at: float | None = None
                         ) -> _Submission | None:
        """Cold-plan ``norm`` (parse + plan + template compile), then admit."""
        try:
            table, plan, epoch = self._plan_cold(norm)
        except Exception as exc:          # PlanError / stale RuntimeError
            fut.set_exception(exc)
            return None
        return self._admit(fut, norm, t_submit, trace, deadline_at, table,
                           plan, epoch, "full", None, None)

    def _plan_async(self, fut: QueryFuture, norm: str, t_submit: float,
                    trace: QueryTrace | None,
                    deadline_at: float | None = None):
        """Planner-pool job: cold-plan, admit, enqueue (worker thread)."""
        sub = self._plan_cold_admit(fut, norm, t_submit, trace, deadline_at)
        if sub is not None:
            self._enqueue(sub)

    def _admit(self, fut: QueryFuture, norm: str, t_submit: float,
               trace: QueryTrace | None, deadline_at: float | None,
               table: str, plan: QueryPlan | None, epoch: int, path: str,
               template, literals) -> _Submission | None:
        """Admit a planned (or template-deferred) query under a short
        state-lock section.

        Returns the ``_Submission`` the caller should enqueue, or None when
        the future was settled inline (result-cache hit, fully-cached
        GROUP BY) or attached to a submission another thread planned
        concurrently. Future resolution happens after the lock is released.
        """
        if trace is not None:
            trace.t_planned = time.perf_counter()
            trace.plan_cache_hit = path == "plan_cache"
            trace.plan_path = path
        hit = None
        with self._state_lock:
            inflight = (self._inflight.get(norm)
                        if deadline_at is None else None)
            if inflight is not None:      # planned concurrently: attach
                inflight.futures.append(fut)
                return None
            rentry = self.result_cache.get(norm, self.catalog.epoch)
            if rentry is not None:
                self.metrics.table(table).record_result_hit()
                hit = rentry.value
            else:
                self.result_cache.miss(table)
                sub = _Submission(norm, table, plan, epoch, t_submit, [fut],
                                  trace=trace, template=template,
                                  literals=literals, deadline_at=deadline_at)
                if plan is not None and plan.leaf_plans:
                    self._lookup_leaves(sub)
                    if not sub.missing:   # every leaf served from cache
                        hit = self._finish_cached_group(sub)
                if hit is None and deadline_at is None:
                    # Deadline-carrying submissions are never dedupe
                    # targets: each deadline is its own contract.
                    self._inflight[norm] = sub
        if hit is not None:
            if trace is not None:
                trace.result_cache_hit = True
                trace.t_resolved = time.perf_counter()
                exp = self._trace_done(trace, norm)
                fut.set_result(dataclasses.replace(hit, latency_s=0.0,
                                                   explain=exp))
            else:
                fut.set_result(dataclasses.replace(hit, latency_s=0.0))
            return None
        if trace is not None:
            trace.t_admitted = time.perf_counter()
        return sub

    def _enqueue(self, sub: _Submission, requeue: bool = False):
        """Hand an admitted submission to the streaming-admission queue.
        Backpressure rejection is handled by ``_on_shed`` (wired as the
        admission's shed callback); a closed server fails the futures.
        ``requeue=True`` re-admits a wave item from the worker thread
        itself, bypassing backpressure (``StreamingAdmission.requeue`` —
        blocking or shedding there would deadlock or drop an
        already-admitted query)."""
        try:
            if requeue:
                # Marks the submission as queue-owned again: a wave-level
                # error callback skips requeued items (the next wave, not
                # the supervisor, owns their resolution).
                sub.requeued = True
                self.admission.requeue(sub, sub.t_submit)
            else:
                self.admission.submit(sub, sub.t_submit)
        except Exception as exc:          # closed server: fail, don't leak
            with self._state_lock:
                if self._inflight.get(sub.norm) is sub:
                    del self._inflight[sub.norm]
                futures = list(sub.futures)
            for f in futures:
                f.set_exception(exc)

    def _on_shed(self, sub: _Submission, reason: str, depth: int):
        """Backpressure decision (runs on the deciding submitter's thread,
        no admission lock held): detach the submission from the in-flight
        dedupe map and resolve every attached future with a typed
        ``AdmissionRejected`` result — overload is an answer, not a worker
        exception."""
        with self._state_lock:
            if self._inflight.get(sub.norm) is sub:
                del self._inflight[sub.norm]
            futures = list(sub.futures)
            self.metrics.admission.record_shed(reason, depth)
        if sub.trace is not None:
            sub.trace.rejected = True
            sub.trace.t_resolved = time.perf_counter()
            self.tracer.instant("shed", track="admission",
                                attrs={"reason": reason, "depth": depth,
                                       "qid": sub.trace.qid})
            sub.trace.emit_spans(self.tracer, sub.norm)
        for fut in futures:
            fut.set_result(AdmissionRejected(reason=reason,
                                             queue_depth=depth))

    def _plan_cold(self, norm: str):
        """Cold planning: parse + plan -> (table, plan, epoch). Compiles and
        caches the shape's ``PlanTemplate`` as a side effect, so the next
        query of this shape skips the parse entirely.

        Engine and epoch come from one atomic ``catalog.snapshot``, so the
        plan is tagged with exactly the epoch of the synopsis its literals
        were encoded against — a rebuild racing the planning can never
        produce a plan that validates (in the caches or at wave execution)
        against a synopsis it was not planned for.

        Only the cache get/puts take ``_plan_lock``; the planning work
        itself (parse + encode + GROUP BY leaf expansion + template
        compile) runs unlocked, so concurrent submitters planning
        *different* queries overlap. Two threads planning the *same* query
        race benignly: both plans are identical and the puts are
        idempotent.
        """
        faults.hook("planner")
        parsed = sqlmod.parse_sql(norm)
        table = parsed.table
        with self._plan_lock:
            self.plan_cache.miss(table if table in self.catalog else None)
        engine, epoch = self.catalog.snapshot(table)  # PlanError/RuntimeError
        plan = engine.plan_query(parsed)
        template = fp = None
        if self.plan_templates:
            try:
                template = engine.plan_template(parsed)
                fp = sqlmod.fingerprint_sql(norm)
            except Exception:
                template = None   # shape not templatable: plan cold next time
        with self._plan_lock:
            self.plan_cache.put(norm, table, epoch, plan)
            if template is not None and template.n_slots == len(fp.literals):
                self.template_cache.put(fp.shape, table, epoch, template)
        return table, plan, epoch

    def _lookup_leaves(self, sub: _Submission):
        """Fill ``sub.cached_leaves`` / ``sub.missing`` from the result cache
        (one recorded miss per missing leaf, matching the per-leaf hits).
        Caller holds ``_state_lock``."""
        sub.missing = []
        sub.cached_leaves = {}
        for i, leaf in enumerate(sub.plan.leaf_plans):
            entry = self.result_cache.get(_leaf_key(leaf), self.catalog.epoch)
            if entry is not None:
                sub.cached_leaves[i] = entry.value
            else:
                self.result_cache.miss(sub.table)
                sub.missing.append(i)

    def _replan(self, sub: _Submission):
        """The table changed while ``sub`` sat in the admission queue: its
        plan may encode literals against a synopsis that no longer exists.
        Re-plan against the current synopsis (plan + template caches were
        purged by the epoch bump — always the cold path, which recompiles
        the shape's template) and refresh the per-leaf cache lookups;
        raises the usual PlanError/RuntimeError if the table is gone or
        stale."""
        sub.table, sub.plan, sub.epoch = self._plan_cold(sub.norm)
        sub.template = sub.literals = None   # concrete plan supersedes
        sub.missing = None
        if sub.plan.leaf_plans:
            with self._state_lock:
                self._lookup_leaves(sub)

    def _finish_cached_group(self, sub: _Submission,
                             result: QueryResult | None = None) -> QueryResult:
        """GROUP BY answered entirely from per-leaf cache entries (state
        lock held); returns the assembled result for the caller to set.
        ``result`` carries a pre-assembled answer from the wave path (a
        deferred template bind learns its leaves are all cached only after
        binding) so assembly is never repeated under the lock."""
        if result is None:
            result = assemble_groups(sub.plan, sub.cached_leaves)
        tm = self.metrics.table(sub.table)
        tm.record_result_hit()
        tm.record_group_expansion(0, len(sub.cached_leaves))
        self.result_cache.put(sub.norm, sub.table, sub.epoch, result)
        return result

    def _trace_done(self, trace: QueryTrace, label: str) -> dict:
        """Finalize a resolved query's trace: assemble the EXPLAIN
        breakdown, emit its stage spans, fold the stage latencies into the
        metrics reservoirs and (past ``slow_query_ms``) append to the
        bounded slow-query log. Returns the explain dict for attachment to
        the outgoing result. No server lock held (metrics self-lock)."""
        exp = trace.explain()
        trace.emit_spans(self.tracer, label)
        self.metrics.record_explain(exp)
        if exp["total_ms"] >= self.slow_query_ms:
            entry = dict(exp)
            entry["sql"] = label
            self._slow_log.append(entry)
        return exp

    # ------------------------------------------------------- admission worker

    def _execute_wave(self, batch: list, drain):
        """Execute one drained wave (admission-worker thread).

        Submissions whose table epoch moved while they sat in the queue
        (append_rows/rebuild landed mid-flight) are re-planned first — a
        plan encodes literals against one specific synopsis, so executing
        it against a rebuilt one would be silently wrong; if the table is
        stale (no rebuild yet) the re-plan raises and the futures resolve
        with that error. Then expands GROUP BY submissions into their
        uncached leaf plans, runs ALL work units (plain queries + leaves of
        every in-flight GROUP BY) through one ``BatchScheduler.execute``
        call — plan-shape grouping inside the scheduler fuses everything
        fusable — then reassembles, caches and resolves. A scheduler error
        isolates to per-item retry so one poisoned query cannot reject an
        entire wave's futures.

        Locking: metrics and cache puts take the short state lock; the
        re-plan, the scheduler execution and the future resolution all run
        outside it, so submitters are never blocked behind a wave.
        """
        # Drained items are worker-owned now; clearing the requeue flag
        # FIRST means a wave-level crash (including the injected
        # wave_execute fault below) routes every un-requeued item through
        # the supervisor exactly once.
        for sub in batch:
            sub.requeued = False
        faults.hook("wave_execute")
        now = time.perf_counter()
        with self._state_lock:
            self.metrics.admission.record_drain(drain)
            for sub in batch:
                self.metrics.admission.record_wait(now - sub.t_submit)
        for sub in batch:
            if sub.trace is not None:
                sub.trace.t_drained = now
                sub.trace.drain_cause = drain.cause
                sub.trace.wave_size = drain.size
        # Per-query deadlines: a submission whose deadline passed while it
        # sat in the queue skips the fused launch entirely and resolves
        # with a typed DeadlineExceeded result.
        expired = [sub for sub in batch
                   if sub.deadline_at is not None and now >= sub.deadline_at]
        if expired:
            gone = {id(s) for s in expired}
            batch = [sub for sub in batch if id(sub) not in gone]
            self._resolve_expired(expired)
        prefailed: dict[int, Exception] = {}
        for sub in batch:
            if sub.epoch != self.catalog.epoch(sub.table):
                try:
                    self._replan(sub)
                except Exception as exc:
                    prefailed[id(sub)] = exc

        # Deferred template binds: every template-hit submission of the
        # wave still carries (template, literals). Group them by template
        # and bind each group in ONE bind_batch call — the wave's literal
        # encoding collapses into a single numpy pass per shape. A bad
        # literal isolates to its own submission (per-sub scalar bind on
        # group failure), never poisoning the rest of the group.
        by_template: dict[int, list] = {}
        for sub in batch:
            if id(sub) not in prefailed and sub.plan is None:
                by_template.setdefault(id(sub.template), []).append(sub)
        bound_groups = []
        for subs in by_template.values():
            template = subs[0].template
            try:
                plans = template.bind_batch([s.literals for s in subs])
            except Exception:
                plans = None
            if plans is None:          # isolate: per-sub scalar bind
                for s in subs:
                    try:
                        s.plan = template.bind(s.literals)
                    except Exception as exc:
                        prefailed[id(s)] = exc
            else:
                for s, p in zip(subs, plans):
                    s.plan = p
            for s in subs:
                if id(s) not in prefailed:
                    if s.plan.leaf_plans:
                        bound_groups.append(s)
                    with self._plan_lock:   # exact-text repeats skip the bind
                        self.plan_cache.put(s.norm, s.table, s.epoch, s.plan)
        if bound_groups:
            # GROUP BY leaf-cache lookups were deferred along with the bind.
            with self._state_lock:
                for s in bound_groups:
                    self._lookup_leaves(s)

        items, slots = [], []          # slots: (submission, leaf_idx | None)
        for sub in batch:
            if id(sub) in prefailed:
                continue
            # Items carry the plan's epoch so the scheduler re-validates it
            # per item at execution time (engines are fetched there; see
            # BatchScheduler.execute). A rebuild landing after the pre-check
            # above then surfaces as stale=True instead of silently pairing
            # this plan with the new synopsis.
            if sub.plan.leaf_plans:
                for i in sub.missing:
                    items.append((sub.table, sub.plan.leaf_plans[i],
                                  sub.epoch))
                    slots.append((sub, i))
            else:
                items.append((sub.table, sub.plan, sub.epoch))
                slots.append((sub, None))

        errors: dict[int, Exception] = {}
        t_exec0 = time.perf_counter()
        try:
            scheduled = self.scheduler.execute(items)
        except Exception:
            scheduled = [None] * len(items)
            for k, item in enumerate(items):
                try:
                    scheduled[k] = self.scheduler.execute([item])[0]
                except Exception as exc:       # isolate the poisoned item
                    errors[k] = exc
        t_exec1 = time.perf_counter()

        leaf_out: dict[int, dict] = {}         # id(sub) -> {leaf_idx: sr}
        failed = dict(prefailed)               # id(sub) -> first error
        exec_failed: set[int] = set()          # failed during EXECUTION:
        direct: dict[int, object] = {}         # retry/quarantine, not raise
        stale: set[int] = set()                # id(sub) -> re-enqueue
        for k, (sub, leaf_idx) in enumerate(slots):
            if k in errors:
                if id(sub) not in failed:
                    failed[id(sub)] = errors[k]
                    exec_failed.add(id(sub))
            elif scheduled[k] is not None and scheduled[k].stale:
                # A rebuild raced this item inside the wave: the scheduler
                # refused to pair the old plan with the new synopsis. The
                # whole submission re-enqueues (next wave's epoch pre-check
                # re-plans it); partial leaf results are discarded.
                stale.add(id(sub))
            elif leaf_idx is None:
                direct[id(sub)] = scheduled[k]
            else:
                leaf_out.setdefault(id(sub), {})[leaf_idx] = scheduled[k]
        for sub in batch:
            if id(sub) in stale and id(sub) not in failed:
                if sub.retries >= self.MAX_STALE_RETRIES:
                    failed[id(sub)] = RuntimeError(
                        f"table {sub.table!r}: epoch kept moving mid-wave "
                        f"after {sub.retries} re-plans; giving up")
                    stale.discard(id(sub))

        # Caching + metrics under the state lock — taken PER SUBMISSION, not
        # across the batch, so a submitter's short critical section can
        # interleave with a long wave's bookkeeping. Future resolution
        # happens outside the lock (done callbacks must never run under a
        # server lock). Popping the in-flight entry under the lock freezes
        # the futures list: any duplicate attached before the pop is
        # resolved here, any submit after it plans afresh. Pure group
        # assembly runs unlocked too.
        for sub in batch:
            tr = sub.trace
            if id(sub) in stale:
                # Keep the in-flight entry (dupes still attach) and send the
                # submission back through admission — bypassing backpressure
                # (we ARE the worker; see _enqueue) — so the next wave's
                # epoch pre-check re-plans it against the rebuilt synopsis.
                sub.retries += 1
                with self._state_lock:
                    self.metrics.admission.record_stale_requeue()
                if self.tracer.enabled:
                    self.tracer.instant(
                        "requeue", track="worker",
                        attrs={"table": sub.table, "retries": sub.retries})
                self._enqueue(sub, requeue=True)
                continue
            err = failed.get(id(sub))
            if err is not None and id(sub) in exec_failed:
                # Execution failures are a containment outcome, not a
                # raise: retry once (requeue), then quarantine with a
                # typed QueryError. Plan/bind errors above keep their
                # exception semantics.
                self._resolve_exec_failure(sub, err)
                continue
            result = None
            batched = False
            if err is None and sub.plan.leaf_plans:
                executed = leaf_out.get(id(sub), {})
                leaf_results = dict(sub.cached_leaves)
                leaf_results.update({i: sr.result
                                     for i, sr in executed.items()})
                result = assemble_groups(sub.plan, leaf_results)
                result.latency_s = sum(sr.latency_s
                                       for sr in executed.values())
                batched = any(sr.batched for sr in executed.values())
            with self._state_lock:
                # Conditional pop: deadline-carrying submissions never
                # register in the dedupe map, so an unconditional pop could
                # detach a different submission sharing the text.
                if self._inflight.get(sub.norm) is sub:
                    del self._inflight[sub.norm]
                futures = list(sub.futures)
                if err is None:
                    if sub.plan.leaf_plans and not executed \
                            and not sub.missing:
                        # Deferred-bind GROUP BY whose leaves were ALL in
                        # the cache: account as a result hit, exactly like
                        # the submit-time fully-cached fast path (a plan
                        # known at submit never reaches the wave in this
                        # state — it resolves there instead).
                        result = self._finish_cached_group(sub, result)
                    elif sub.plan.leaf_plans:
                        self._finish_group(sub, executed, result)
                    else:
                        sr = direct[id(sub)]
                        result = self._finish_single(sub, sr)
                        batched = sr.batched
                    for _ in futures[1:]:      # served dupes = result hits
                        self.metrics.table(sub.table).record_result_hit()
            if err is not None:
                if tr is not None:             # spans still tell the story
                    tr.t_exec0, tr.t_exec1 = t_exec0, t_exec1
                    tr.t_resolved = time.perf_counter()
                    tr.emit_spans(self.tracer, sub.norm)
                for fut in futures:
                    fut.set_exception(err)
            else:
                # Primary future gets the real latency (and, when traced,
                # its own explain-carrying copy — the cached result object
                # stays explain-free, a breakdown describes ONE submission);
                # in-flight duplicates are served copies.
                if tr is not None:
                    tr.t_exec0, tr.t_exec1 = t_exec0, t_exec1
                    tr.kernel_share_s = result.latency_s
                    tr.batched = batched
                    tr.retries = sub.retries
                    tr.t_resolved = time.perf_counter()
                    exp = self._trace_done(tr, sub.norm)
                    futures[0].set_result(
                        dataclasses.replace(result, explain=exp))
                else:
                    futures[0].set_result(result)
                for fut in futures[1:]:
                    fut.set_result(dataclasses.replace(result, latency_s=0.0))

    def _resolve_expired(self, subs: list):
        """Resolve deadline-expired submissions with typed
        ``DeadlineExceeded`` results (admission-worker thread, outside any
        server lock at resolution time)."""
        now = time.perf_counter()
        for sub in subs:
            with self._state_lock:
                if self._inflight.get(sub.norm) is sub:
                    del self._inflight[sub.norm]
                futures = list(sub.futures)
                self.metrics.faults.record_deadline_expired()
            deadline_ms = (sub.deadline_at - sub.t_submit) * 1e3
            elapsed_ms = (now - sub.t_submit) * 1e3
            if self.tracer.enabled:
                self.tracer.instant(
                    "deadline_expired", track="faults",
                    attrs={"deadline_ms": deadline_ms,
                           "elapsed_ms": elapsed_ms})
            if sub.trace is not None:
                sub.trace.t_resolved = now
                sub.trace.emit_spans(self.tracer, sub.norm)
            res = DeadlineExceeded(deadline_ms=deadline_ms,
                                   elapsed_ms=elapsed_ms)
            for fut in futures:
                if not fut.done():
                    fut.set_result(res)

    def _resolve_exec_failure(self, sub: _Submission, exc: Exception):
        """Contain one submission's wave-execution failure.

        First failure: re-enqueue for one more attempt (the retry rides
        the normal wave path, so a transient fault — an injected kernel
        error, a recovered cold table — answers correctly on the retry).
        At ``MAX_EXEC_FAILURES`` the statement quarantines: its futures
        resolve with a typed ``QueryError`` and re-submissions are refused
        until the quarantine clears. A ``TableQuarantinedError`` (the cold
        table's circuit breaker is open) skips the retry — it would only
        fail fast against the same open breaker — and quarantines the
        statement immediately. Never raises, never hangs a future.
        """
        sub.exec_failures += 1
        if isinstance(exc, TableQuarantinedError):
            sub.exec_failures = self.MAX_EXEC_FAILURES
        if sub.exec_failures < self.MAX_EXEC_FAILURES:
            with self._state_lock:
                self.metrics.faults.record_exec_retry()
            if self.tracer.enabled:
                self.tracer.instant(
                    "exec_retry", track="faults",
                    attrs={"table": sub.table, "error": repr(exc)})
            self._enqueue(sub, requeue=True)
            return
        with self._state_lock:
            if self._inflight.get(sub.norm) is sub:
                del self._inflight[sub.norm]
            futures = list(sub.futures)
            self._quarantine[sub.norm] = (sub.table, repr(exc))
            while len(self._quarantine) > self.QUARANTINE_CAP:
                self._quarantine.popitem(last=False)
            self.metrics.faults.record_quarantined()
            self.metrics.faults.record_query_error()
        if self.tracer.enabled:
            self.tracer.instant(
                "quarantine", track="faults",
                attrs={"table": sub.table, "error": repr(exc)})
        if sub.trace is not None:
            sub.trace.t_resolved = time.perf_counter()
            sub.trace.emit_spans(self.tracer, sub.norm)
        kind = ("quarantined" if isinstance(exc, TableQuarantinedError)
                else "execution")
        res = QueryError(error=repr(exc), kind=kind,
                         retries=sub.exec_failures)
        for fut in futures:
            if not fut.done():
                fut.set_result(res)

    def _on_wave_error(self, batch: list, exc: Exception):
        """Supervision callback: ``_execute_wave`` raised for a whole wave.

        Runs on the (surviving) admission worker. Every submission that is
        neither already resolved nor already re-admitted to the queue goes
        through the same retry-then-quarantine containment as an isolated
        execution failure, so a wave-level crash resolves every future
        with a typed result instead of stranding them.
        """
        for sub in batch:
            if sub.requeued:
                continue              # queue-owned again; next wave handles
            futures = list(sub.futures)
            if futures and all(f.done() for f in futures):
                continue              # already resolved (cache/expired path)
            self._resolve_exec_failure(sub, exc)

    # -------------------------------------------------------------- quarantine

    def quarantined(self) -> dict:
        """Snapshot of quarantined statements: normalized SQL ->
        ``{"table", "error"}``."""
        with self._state_lock:
            return {norm: {"table": t, "error": e}
                    for norm, (t, e) in self._quarantine.items()}

    def clear_quarantine(self, norm: str | None = None):
        """Lift the quarantine for one normalized statement (or all with
        ``None``) so re-submissions execute again."""
        with self._state_lock:
            if norm is None:
                self._quarantine.clear()
            else:
                self._quarantine.pop(normalize_sql(norm), None)

    def _finish_single(self, sub: _Submission, sr) -> QueryResult:
        """Cache + account one executed plain query (state lock held)."""
        self.result_cache.put(sub.norm, sub.table, sub.epoch, sr.result)
        self.metrics.table(sub.table).record(sr.latency_s, sr.batched)
        return sr.result

    def _finish_group(self, sub: _Submission, executed: dict,
                      result: QueryResult):
        """Cache executed leaves + the pre-assembled group result, account
        (state lock held; the assembly itself ran unlocked)."""
        batched = False
        for i, sr in executed.items():
            self.result_cache.put(_leaf_key(sub.plan.leaf_plans[i]),
                                  sub.table, sub.epoch, sr.result)
            batched = batched or sr.batched
        self.result_cache.put(sub.norm, sub.table, sub.epoch, result)
        tm = self.metrics.table(sub.table)
        tm.record(result.latency_s, batched)
        tm.record_group_expansion(len(executed), len(sub.cached_leaves))

    # ------------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Telemetry snapshot (tables + totals; see ``docs/serving.md``).
        Takes each lock separately (never nested): counters across the two
        caches may be mutually a submit apart, which telemetry tolerates."""
        with self._plan_lock:
            plan_stats = self.plan_cache.stats()
            tmpl_stats = self.template_cache.stats()
        with self._state_lock:
            snap = self.metrics.snapshot(None, self.result_cache)
        snap["totals"]["plan_cache"] = plan_stats
        snap["totals"]["template_cache"] = tmpl_stats
        adm = snap["totals"]["admission"]
        adm["queue_depth"] = self.admission.depth()
        # The admission object tracks depth after every admit; the metrics
        # side only sees shed-time observations — report the max of both.
        adm["queue_high_water"] = max(adm["queue_high_water"],
                                      self.admission.high_water)
        flt = snap["totals"]["faults"]
        flt["worker_restarts"] = self.admission.restarts
        with self._state_lock:
            flt["quarantine_size"] = len(self._quarantine)
        snap["tracing"] = {
            "enabled": self.tracer.enabled,
            "spans_recorded": self.tracer.n_recorded,
            "spans_dropped": self.tracer.n_dropped,
            "buffer_capacity": self.tracer.capacity,
            "slow_queries": len(self._slow_log),
            "slow_query_ms": self.slow_query_ms,
        }
        cold_tables = self.catalog.cold_tables()
        if cold_tables:
            gov = self.metrics.cold.snapshot()
            snap["cold"] = {
                "tables": len(cold_tables),
                # Live decoded-engine footprint; the high-water mark is
                # governor-recorded *post-enforcement* (the budget proof).
                "resident_bytes": sum(t.resident_bytes
                                      for _, t in cold_tables),
                "resident_high_water": gov["resident_high_water"],
                "demotes": gov["demotes"],
                "sweeps": gov["sweeps"],
                "max_engine_bytes": self.max_engine_bytes,
                "demote_idle_s": self.demote_idle_s,
            }
        return snap

    # ----------------------------------------------------------------- tracing

    def trace_events(self) -> list[dict]:
        """The span ring as Chrome/Perfetto ``trace_event`` dicts (one lane
        per query plus admission/worker lanes)."""
        return spans_to_events(self.tracer.spans())

    def trace_json(self) -> str:
        """The span ring serialized as trace_event JSON (paste into
        https://ui.perfetto.dev or chrome://tracing)."""
        return trace_json(self.trace_events())

    def export_trace(self, path) -> str:
        """Write the trace_event JSON artifact to ``path``; returns it."""
        return write_trace(path, self.trace_events())

    def slow_queries(self) -> list[dict]:
        """The bounded slow-query log, oldest first: explain breakdowns
        (plus ``sql``) of traced queries slower than ``slow_query_ms``."""
        return list(self._slow_log)
