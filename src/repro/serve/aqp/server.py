"""AQPServer: multi-table AQP serving front-end.

Pipeline per wave of SQL strings (``query_batch``):

    normalize -> plan cache -> result cache -> dedupe -> BatchScheduler
       |            |              |                        |
       |       (epoch-keyed   (epoch-keyed             one fused launch
       |        QueryPlans)    QueryResults)           per plan shape
       v
    FROM <table> resolved via TableCatalog (PlanError if unknown)

Staleness: every ``AQPFramework`` bumps its epoch on ingest/append_rows;
cache entries are tagged with the epoch they were computed at, so appended
rows can never be answered from a stale cache — a query against a stale
(un-rebuilt) table raises ``RuntimeError`` exactly like the single-table
``AQPFramework.query``.
"""
from __future__ import annotations

import dataclasses

from repro.core import sql as sqlmod
from repro.core.query import QueryResult
from repro.serve.aqp.cache import LRUCache, normalize_sql
from repro.serve.aqp.catalog import TableCatalog
from repro.serve.aqp.metrics import Metrics
from repro.serve.aqp.scheduler import BatchScheduler


class AQPServer:
    def __init__(self, catalog: TableCatalog | None = None,
                 mode: str | None = None,
                 plan_cache_size: int = 4096,
                 result_cache_size: int = 16384,
                 max_group: int = 256, min_group: int = 2):
        self.catalog = catalog or TableCatalog()
        self.scheduler = BatchScheduler(self.catalog, mode=mode,
                                        max_group=max_group,
                                        min_group=min_group)
        self.plan_cache = LRUCache(plan_cache_size)
        self.result_cache = LRUCache(result_cache_size)
        self.metrics = Metrics()
        self._wiring: dict[str, tuple] = {}   # name -> (framework, callback)

    # ------------------------------------------------------------ registration

    def register(self, name: str, framework) -> "AQPServer":
        """Register a table; wires eager cache purging to its invalidation.
        Re-registering a name detaches the previous framework's wiring so a
        replaced table can no longer purge its successor's cache entries."""
        self.catalog.register(name, framework)
        self._wire(name, framework)
        return self

    def register_table(self, name: str, table: dict, **kwargs) -> "AQPServer":
        fw = self.catalog.register_table(name, table, **kwargs)
        self._wire(name, fw)
        return self

    def _wire(self, name: str, framework):
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
            self._purge(name)     # drop entries computed from the old table
        cb = lambda fw, name=name: self._purge(name)  # noqa: E731
        framework.on_invalidate(cb)
        self._wiring[name] = (framework, cb)

    def unregister(self, name: str):
        """Drop a table: detach its invalidation wiring and purge its
        cache entries."""
        old = self._wiring.pop(name, None)
        if old is not None:
            old[0].off_invalidate(old[1])
        self.catalog.unregister(name)
        self._purge(name)

    def close(self):
        """Detach every framework callback so a discarded server is not
        kept alive (and purged into) by long-lived frameworks."""
        for name, (fw, cb) in list(self._wiring.items()):
            fw.off_invalidate(cb)
        self._wiring.clear()

    def _purge(self, name: str):
        self.plan_cache.purge_table(name)
        self.result_cache.purge_table(name)

    # ----------------------------------------------------------------- queries

    def query(self, sql_text: str) -> QueryResult:
        return self.query_batch([sql_text])[0]

    def query_batch(self, sqls: list[str]) -> list[QueryResult]:
        """Answer a wave of queries; results align with ``sqls``.

        Raises PlanError for unknown tables/columns and RuntimeError for
        stale tables (the whole wave aborts — the serving contract matches
        ``AQPFramework.query``).
        """
        results: list[QueryResult | None] = [None] * len(sqls)
        pending: dict[str, list[int]] = {}       # norm -> indices to fill
        pending_items: dict[str, tuple] = {}     # norm -> (table, plan)
        epoch_of = self.catalog.epoch

        for i, sql in enumerate(sqls):
            norm = normalize_sql(sql)
            if norm in pending:                  # duplicate within the wave
                pending[norm].append(i)
                continue
            table, plan = self._plan_for(norm)
            rentry = self.result_cache.get(norm, epoch_of)
            if rentry is not None:
                results[i] = dataclasses.replace(rentry.value, latency_s=0.0)
                self.metrics.table(table).record_result_hit()
                continue
            self.result_cache.miss(table)
            pending[norm] = [i]
            pending_items[norm] = (table, plan)

        if pending:
            norms = list(pending)
            scheduled = self.scheduler.execute(
                [pending_items[n] for n in norms])
            for norm, sr in zip(norms, scheduled):
                table, _plan = pending_items[norm]
                self.result_cache.put(norm, table, epoch_of(table), sr.result)
                self.metrics.table(table).record(sr.latency_s, sr.batched)
                idxs = pending[norm]
                results[idxs[0]] = sr.result
                for j in idxs[1:]:   # in-wave duplicates: served, not executed
                    results[j] = dataclasses.replace(sr.result, latency_s=0.0)
                    self.metrics.table(table).record_result_hit()
        return results  # type: ignore[return-value]

    def _plan_for(self, norm: str):
        entry = self.plan_cache.get(norm, self.catalog.epoch)
        if entry is not None:
            return entry.table, entry.value
        parsed = sqlmod.parse_sql(norm)
        table = parsed.table
        self.plan_cache.miss(table if table in self.catalog else None)
        engine = self.catalog.engine(table)   # PlanError / RuntimeError here
        plan = engine.plan_query(parsed)
        self.plan_cache.put(norm, table, self.catalog.epoch(table), plan)
        return table, plan

    # ------------------------------------------------------------------- stats

    def stats(self) -> dict:
        return self.metrics.snapshot(self.plan_cache, self.result_cache)
