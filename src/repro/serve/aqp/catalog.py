"""Table catalog: named multi-table registry for the AQP server.

``core/sql.py`` has always parsed ``FROM <table>`` but nothing resolved the
name — the single-table engines just ignored it. The catalog closes that
gap: queries against unregistered tables raise ``PlanError`` with the list
of known tables, and each registered ``AQPFramework`` reports its staleness
epoch for cache invalidation.
"""
from __future__ import annotations

from repro.aqp.engine import AQPFramework
from repro.core.query import PlanError
from repro.core.types import BuildParams


class TableCatalog:
    """name -> AQPFramework registry with staleness-epoch bookkeeping."""

    def __init__(self):
        self._tables: dict[str, AQPFramework] = {}

    # ------------------------------------------------------------ registration

    def register(self, name: str, framework: AQPFramework) -> AQPFramework:
        """Register an (already ingested or to-be-ingested) framework."""
        self._tables[name] = framework
        return framework

    def register_table(self, name: str, table: dict,
                       params: BuildParams | None = None,
                       use_compression: bool = True,
                       fastpath=None) -> AQPFramework:
        """Convenience: build + ingest a framework from a raw column dict."""
        fw = AQPFramework(params=params, use_compression=use_compression,
                          fastpath=fastpath)
        fw.ingest(table)
        return self.register(name, fw)

    def unregister(self, name: str):
        """Drop ``name`` from the registry (no-op if absent)."""
        self._tables.pop(name, None)

    # -------------------------------------------------------------- resolution

    def __contains__(self, name: str) -> bool:
        return name in self._tables

    def __len__(self) -> int:
        return len(self._tables)

    def tables(self) -> list[str]:
        """Sorted registered table names."""
        return sorted(self._tables)

    def resolve(self, name: str) -> AQPFramework:
        """The framework registered under ``name``; PlanError if unknown."""
        try:
            return self._tables[name]
        except KeyError:
            raise PlanError(
                f"unknown table {name!r}; registered tables: "
                f"{self.tables()}") from None

    def engine(self, name: str):
        """Fresh QueryEngine for ``name``; raises RuntimeError if the
        synopsis is stale (append_rows without rebuild)."""
        return self.snapshot(name)[0]

    def snapshot(self, name: str) -> tuple:
        """Atomic ``(engine, epoch)`` for ``name`` — the framework publishes
        the pair in one assignment, so the returned engine is exactly the
        one built at the returned epoch (no engine/epoch tearing even when
        a rebuild races the read). Raises PlanError for unknown tables and
        RuntimeError for stale ones, like ``engine``."""
        fw = self.resolve(name)
        engine, epoch = fw.published
        if engine is None:
            raise RuntimeError(
                f"table {name!r}: synopsis is stale after append_rows; "
                "call rebuild() first")
        return engine, epoch

    def epoch(self, name: str) -> int:
        """Current staleness epoch of a table (cache-key component).
        Unknown tables report -1 so stale cache entries for dropped tables
        can never validate."""
        fw = self._tables.get(name)
        return fw.epoch if fw is not None else -1
