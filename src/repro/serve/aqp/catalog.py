"""Table catalog: named multi-table registry for the AQP server.

``core/sql.py`` has always parsed ``FROM <table>`` but nothing resolved the
name — the single-table engines just ignored it. The catalog closes that
gap: queries against unregistered tables raise ``PlanError`` with the list
of known tables, and each registered ``AQPFramework`` reports its staleness
epoch for cache invalidation.

**Cold tier** (``register_cold`` / ``ColdTable``): a table can register as
a bit-packed ``storage.py`` synopsis blob (plus, optionally, its
``CompressedTable``) instead of a live framework. The blob decodes lazily
on the first ``snapshot``/``published`` access — concurrent first queries
block on one decode and all observe the same atomic ``(engine, epoch)``
pair, exactly the ``append_rows``/``rebuild`` publication semantics — so
thousands of registered tables cost blob bytes, not runtime synopses,
until queried. ``epoch`` never triggers a decode (it is on the submit-path
cache-validation hot path).
"""
from __future__ import annotations

import threading
import time
import types

from repro.aqp.engine import AQPFramework
from repro.core import storage as storagemod
from repro.core.build import build_pairwise_hist
from repro.core.query import PlanError, QueryEngine
from repro.core.types import BuildParams

import repro.serve.aqp.faults as faults


class TableQuarantinedError(RuntimeError):
    """The cold table's blob repeatedly failed to decode and is quarantined.

    Raised (typed, fast — no decode re-attempt while the circuit breaker
    is open) by every access that needs the engine. Recover by fixing the
    blob and re-registering the table, by ``reset_faults()``, or
    automatically after ``breaker_reset_s`` elapses (half-open retry).
    Queriers see this as a failed future, never a hang.
    """


class ColdTable:
    """A storage-tier table: bit-packed synopsis blob, decoded lazily.

    Duck-types the slice of ``AQPFramework`` the catalog and server use
    (``published`` / ``epoch`` / ``engine`` / ``on_invalidate`` /
    ``off_invalidate``). The epoch is allocated from the same process-global
    sequence at registration and is *stable across the first decode* —
    decoding changes representation, not table state — so epoch-keyed
    plan/result caches populated after the decode stay valid. ``rebuild``
    (GD-native, from the attached ``CompressedTable``) re-encodes the blob
    and publishes at a fresh epoch, firing the invalidation callbacks like
    a live framework's rebuild.

    ``demote`` reverses the decode: the engine drops back to its blob at
    the *same* epoch (again a representation change, not a state change —
    epoch-keyed cache entries stay valid), and the next query transparently
    re-decodes. In-flight waves holding the pre-demote engine reference
    finish safely; the tuple swap never mutates an engine in place.

    ``decode_cb(n_bytes, decode_s)`` (optional) fires once per decode,
    *outside* the publication lock — the server wires it to per-table
    cold-start telemetry and the memory governor, which may demote other
    tables (taking their locks) from inside the callback.
    """

    BACKOFF_CAP_S = 1.0

    def __init__(self, blob: bytes, compressed=None,
                 params: BuildParams | None = None, fastpath=None,
                 decode_cb=None, decode_retries: int = 2,
                 decode_backoff_s: float = 0.01,
                 breaker_reset_s: float = 0.0, fault_cb=None):
        storagemod.blob_info(blob)   # verify frame checksum + magic up front
        self.blob = bytes(blob)
        self.compressed = compressed
        self.params = params
        self.fastpath = fastpath
        self.decode_cb = decode_cb
        # Resilience policy: a failed decode is retried decode_retries
        # times with capped exponential backoff (decode_backoff_s base);
        # when every attempt fails the table quarantines — the circuit
        # breaker makes subsequent accesses raise TableQuarantinedError
        # immediately instead of hammering the broken blob. breaker_reset_s
        # > 0 allows a half-open re-attempt after that long.
        self.decode_retries = max(int(decode_retries), 0)
        self.decode_backoff_s = max(float(decode_backoff_s), 0.0)
        self.breaker_reset_s = float(breaker_reset_s)
        # fault_cb(event, n, exc) with event in {"decode_retry",
        # "quarantine"}: the server wires fault telemetry (counters +
        # trace instants) here. Runs under the table lock; must not take
        # table locks itself.
        self.fault_cb = fault_cb
        self.decode_count = 0
        self.demote_count = 0
        self.decode_failures = 0
        self._fault: Exception | None = None
        self._fault_t = 0.0
        self._lock = threading.Lock()
        # Rebuilds serialize on their own lock so a slow older build can
        # never overwrite a newer publication (epochs are claimed before
        # building, and the publish refuses to go backwards).
        self._rebuild_lock = threading.Lock()
        self._invalidate_cbs = []
        self._engine_nbytes = 0
        # Same atomic-tuple publication as AQPFramework: (engine, epoch,
        # timings) swaps in one assignment; engine None = not yet decoded.
        self._published: tuple = (None, next(AQPFramework._epoch_seq),
                                  types.MappingProxyType({}))
        # Epoch the current self.blob encodes; when a rebuild bumps the
        # epoch the blob is re-encoded in step, so demote only needs to
        # re-encode if the two ever diverge.
        self._blob_epoch = self._published[1]

    # -------------------------------------------------------- framework duck

    @property
    def engine(self):
        """The decoded QueryEngine, or None while still cold (no decode)."""
        return self._published[0]

    @property
    def epoch(self) -> int:
        """Staleness epoch; never triggers a decode (submit-path safe)."""
        return self._published[1]

    @property
    def published(self) -> tuple:
        """Atomic ``(engine, epoch)``; decodes the blob on first access."""
        pub = self._published
        if pub[0] is None:
            pub = self._decode()
        return pub[:2]

    @property
    def timings(self) -> "types.MappingProxyType":
        """Read-only telemetry published with the engine (decode/build)."""
        return self._published[2]

    def on_invalidate(self, callback):
        """Register ``callback(table)`` to fire on every epoch bump."""
        self._invalidate_cbs.append(callback)

    def off_invalidate(self, callback):
        """Detach a callback registered with ``on_invalidate`` (no-op if
        absent)."""
        try:
            self._invalidate_cbs.remove(callback)
        except ValueError:
            pass

    # ------------------------------------------------------------- lifecycle

    def _check_breaker(self):
        """Raise fast while quarantined; allow a half-open retry after
        ``breaker_reset_s`` (caller holds the lock)."""
        if self._fault is None:
            return
        if self.breaker_reset_s > 0 and \
                time.perf_counter() - self._fault_t >= self.breaker_reset_s:
            return                    # half-open: permit a fresh attempt
        raise TableQuarantinedError(
            f"cold table quarantined (circuit open): {self._fault!r}")

    def _decode(self) -> tuple:
        """Decode the blob under the lock (double-checked): concurrent first
        readers block here and then all see the same published tuple.

        Returns the locally published tuple (not a re-read of
        ``_published``) so a demote racing in right after the decode cannot
        hand the caller a cold ``(None, epoch)`` — the in-flight query keeps
        the engine it decoded.

        Decode failures retry with capped exponential backoff; when every
        attempt fails the table quarantines (``TableQuarantinedError``,
        typed and immediate for queriers — never a hang) and the circuit
        breaker short-circuits further attempts until reset."""
        with self._lock:
            pub = self._published
            if pub[0] is not None:
                return pub
            self._check_breaker()
            ph = None
            last: Exception | None = None
            attempts = self.decode_retries + 1
            for attempt in range(attempts):
                if attempt:
                    time.sleep(min(
                        self.decode_backoff_s * (2 ** (attempt - 1)),
                        self.BACKOFF_CAP_S))
                    if self.fault_cb is not None:
                        self.fault_cb("decode_retry", attempt, last)
                t0 = time.perf_counter()
                try:
                    faults.hook("blob_read")
                    blob = self.blob
                    faults.hook("cold_decode")
                    ph = storagemod.decode(blob)
                    break
                except Exception as exc:
                    last = exc
                    self.decode_failures += 1
            if ph is None:
                self._fault = last
                self._fault_t = time.perf_counter()
                if self.fault_cb is not None:
                    self.fault_cb("quarantine", attempts, last)
                raise TableQuarantinedError(
                    f"cold table blob failed to decode after {attempts} "
                    f"attempts (re-register or reset_faults() to recover): "
                    f"{last!r}") from last
            self._fault = None
            engine = QueryEngine(ph, fastpath=self.fastpath)
            decode_s = time.perf_counter() - t0
            self.decode_count += 1
            self._engine_nbytes = ph.nbytes
            published = (engine, pub[1], types.MappingProxyType({
                "cold_decode_s": decode_s,
                "synopsis_bytes": len(self.blob),
            }))
            self._published = published
        # Outside the lock: the server's callback runs the memory governor,
        # which may demote tables (taking their _lock) — firing it under
        # our own (non-reentrant) lock would deadlock on self-demotion.
        if self.decode_cb is not None:
            self.decode_cb(len(self.blob), decode_s)
        return published

    def demote(self) -> bool:
        """Drop the decoded engine back to the blob (the governor's evict).

        Publishes ``(None, epoch)`` at the *unchanged* epoch — demote is a
        representation change, so plan/result caches keyed on the epoch stay
        valid and no invalidation callbacks fire. If the engine was rebuilt
        since the blob was last encoded, the fresh synopsis is re-encoded
        first so no state is lost. Returns True if an engine was resident
        (demoted), False if the table was already cold (no-op)."""
        with self._lock:
            pub = self._published
            engine = pub[0]
            if engine is None:
                return False
            if self._blob_epoch != pub[1]:
                self.blob = storagemod.encode(engine.ph)
                self._blob_epoch = pub[1]
            self.demote_count += 1
            self._engine_nbytes = 0
            self._published = (None, pub[1], types.MappingProxyType({
                "demoted": True,
                "synopsis_bytes": len(self.blob),
            }))
        return True

    @property
    def resident_bytes(self) -> int:
        """Decoded-engine footprint right now (0 while cold/demoted)."""
        return self._engine_nbytes if self._published[0] is not None else 0

    @property
    def quarantined(self) -> bool:
        """True while the decode circuit breaker is open."""
        return self._fault is not None

    def reset_faults(self):
        """Close the circuit breaker so the next access re-attempts the
        decode (operator override; re-registering the table also works)."""
        with self._lock:
            self._fault = None

    def rebuild(self, params: BuildParams | None = None) -> "ColdTable":
        """Rebuild the synopsis GD-natively from the attached
        ``CompressedTable``, re-encode the blob and publish at a fresh
        epoch (fires the invalidation callbacks — caches purge exactly as
        for a live framework's rebuild).

        Concurrent rebuilds serialize on ``_rebuild_lock`` and each claims
        its epoch *before* building, so publications land in epoch order;
        the publish additionally refuses to overwrite a higher epoch, so a
        stale build can never clobber a newer one (last-write-wins bug)."""
        if self.compressed is None:
            raise RuntimeError(
                "cold table has no CompressedTable attached; cannot rebuild")
        with self._rebuild_lock:
            epoch_new = next(AQPFramework._epoch_seq)
            engine_old = self.published[0]  # decode if needed: columns live
            columns = engine_old.ph.columns  # in the synopsis
            build_params = params or self.params or engine_old.ph.params
            t0 = time.perf_counter()
            ph = build_pairwise_hist(self.compressed, columns, build_params)
            blob = storagemod.encode(ph)
            engine = QueryEngine(ph, fastpath=self.fastpath)
            build_s = time.perf_counter() - t0
            with self._lock:
                if self._published[1] > epoch_new:
                    return self             # a newer publication already won
                self.blob = blob
                self.params = build_params
                self._blob_epoch = epoch_new
                self._engine_nbytes = ph.nbytes
                self._published = (engine, epoch_new,
                                   types.MappingProxyType({
                                       "build_synopsis_s": build_s,
                                       "synopsis_bytes": len(blob),
                                       "build_from_compressed": True,
                                   }))
        for cb in list(self._invalidate_cbs):
            cb(self)
        return self

    def cold_info(self) -> dict:
        """Header peek + decode state: {bytes, n_rows, n_sampled, d,
        decoded, decode_count, demote_count, resident_bytes} without
        forcing a decode."""
        info = storagemod.blob_info(self.blob)
        info["decoded"] = self._published[0] is not None
        info["decode_count"] = self.decode_count
        info["demote_count"] = self.demote_count
        info["resident_bytes"] = self.resident_bytes
        info["quarantined"] = self.quarantined
        info["decode_failures"] = self.decode_failures
        return info


class TableCatalog:
    """name -> AQPFramework registry with staleness-epoch bookkeeping.

    All registry access goes through ``_reglock``: ``register``/
    ``unregister`` racing submit-path ``resolve``/``epoch``/``tables()``
    used to mutate the plain dict mid-``sorted()`` (``RuntimeError:
    dictionary changed size during iteration``) or tear a registration.
    The lock only guards the dict, never a decode or build, so it is
    never held across anything slow.
    """

    def __init__(self):
        self._tables: dict[str, AQPFramework] = {}
        self._reglock = threading.Lock()

    # ------------------------------------------------------------ registration

    def register(self, name: str, framework: AQPFramework) -> AQPFramework:
        """Register an (already ingested or to-be-ingested) framework."""
        with self._reglock:
            self._tables[name] = framework
        return framework

    def register_table(self, name: str, table: dict,
                       params: BuildParams | None = None,
                       use_compression: bool = True,
                       fastpath=None) -> AQPFramework:
        """Convenience: build + ingest a framework from a raw column dict."""
        fw = AQPFramework(params=params, use_compression=use_compression,
                          fastpath=fastpath)
        fw.ingest(table)
        return self.register(name, fw)

    def register_cold(self, name: str, blob: bytes, compressed=None,
                      params: BuildParams | None = None, fastpath=None,
                      decode_cb=None, decode_retries: int = 2,
                      decode_backoff_s: float = 0.01,
                      breaker_reset_s: float = 0.0,
                      fault_cb=None) -> ColdTable:
        """Register a storage-tier table: a bit-packed synopsis blob (plus
        optionally its ``CompressedTable`` for GD-native rebuilds) that
        decodes lazily on first query — see ``ColdTable``. The retry /
        backoff / breaker knobs and ``fault_cb`` configure decode
        resilience (see ``docs/robustness.md``)."""
        cold = ColdTable(blob, compressed=compressed, params=params,
                         fastpath=fastpath, decode_cb=decode_cb,
                         decode_retries=decode_retries,
                         decode_backoff_s=decode_backoff_s,
                         breaker_reset_s=breaker_reset_s, fault_cb=fault_cb)
        with self._reglock:
            self._tables[name] = cold
        return cold

    def unregister(self, name: str):
        """Drop ``name`` from the registry (no-op if absent)."""
        with self._reglock:
            self._tables.pop(name, None)

    # -------------------------------------------------------------- resolution

    def __contains__(self, name: str) -> bool:
        with self._reglock:
            return name in self._tables

    def __len__(self) -> int:
        with self._reglock:
            return len(self._tables)

    def tables(self) -> list[str]:
        """Sorted registered table names."""
        with self._reglock:
            return sorted(self._tables)

    def cold_tables(self) -> list:
        """Point-in-time ``[(name, ColdTable)]`` snapshot — the governor's
        sweep list (live frameworks are not demotable and are excluded)."""
        with self._reglock:
            return [(name, t) for name, t in self._tables.items()
                    if isinstance(t, ColdTable)]

    def resolve(self, name: str) -> AQPFramework:
        """The framework registered under ``name``; PlanError if unknown."""
        with self._reglock:
            fw = self._tables.get(name)
        if fw is None:
            raise PlanError(
                f"unknown table {name!r}; registered tables: "
                f"{self.tables()}")
        return fw

    def engine(self, name: str):
        """Fresh QueryEngine for ``name``; raises RuntimeError if the
        synopsis is stale (append_rows without rebuild)."""
        return self.snapshot(name)[0]

    def snapshot(self, name: str) -> tuple:
        """Atomic ``(engine, epoch)`` for ``name`` — the framework publishes
        the pair in one assignment, so the returned engine is exactly the
        one built at the returned epoch (no engine/epoch tearing even when
        a rebuild races the read). Raises PlanError for unknown tables and
        RuntimeError for stale ones, like ``engine``."""
        fw = self.resolve(name)
        engine, epoch = fw.published
        if engine is None:
            raise RuntimeError(
                f"table {name!r}: synopsis is stale after append_rows; "
                "call rebuild() first")
        return engine, epoch

    def epoch(self, name: str) -> int:
        """Current staleness epoch of a table (cache-key component).
        Unknown tables report -1 so stale cache entries for dropped tables
        can never validate."""
        with self._reglock:
            fw = self._tables.get(name)
        return fw.epoch if fw is not None else -1
