"""Batch scheduler + streaming admission for the AQP serving layer.

At sub-ms per-query latency the serving bottleneck is dispatch, not math
(the same observation that motivates ``core/fastpath``'s per-predicate
fusion, one level up). ``BatchScheduler`` takes a set of in-flight planned
queries and groups them by **plan shape** ``(table, exec column,
pair-predicate column set)``; each group shares its padded (H, fold, hx)
stacks and executes as ONE query-batched kernel launch covering every query
and all three bound variants (``FastPath.batch`` ->
``kernels.weightings.batched_weightings``). Per-query work shrinks to beta
assembly + the final scalar aggregation.

``StreamingAdmission`` feeds it continuously: submissions enqueue without
blocking and a worker thread drains the queue into waves under a
``max_wait_ms`` / ``max_batch`` policy, so the batched launches fill up
from *traffic*, not from whoever happened to call ``query_batch`` with a
big list. GROUP BY queries arrive from the server already expanded into
per-category leaf plans (``QueryPlan.leaf_plans``) — every leaf of every
in-flight GROUP BY shares one plan shape and rides the same fused launch.

Queries outside the batchable shape (OR trees, no WHERE) fall back to the
per-table engine's own path — which is also the oracle the batched path is
tested against.

Execution modes:
  * ``"pallas"`` — batched Pallas kernel (TPU; interpret elsewhere)
  * ``"ref"``    — batched jitted-jnp oracle of the same kernel (f32)
  * ``"numpy"``  — no fused launch; per-query reference execution,
                   bit-identical to ``QueryEngine.query`` (grouping,
                   dedup and caching still apply)
  * ``None``     — auto: "pallas" on TPU, "numpy" elsewhere. On CPU the
                   per-launch JAX dispatch the fused kernel amortizes on
                   TPU *is* the overhead, so fusing small groups loses to
                   NumPy (same reasoning as bench_kernels.py: Pallas off-TPU
                   is for correctness, not speed).
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time

from repro.core.fastpath import FastPath
from repro.core.query import QueryPlan, QueryResult

import repro.serve.aqp.faults as faults


@dataclasses.dataclass
class ScheduledResult:
    """Outcome of one scheduled (planned) query.

    Attributes:
        result: the ``QueryResult`` (estimate/bounds or groups dict);
            None when ``stale``.
        batched: True iff this query executed inside a fused batched launch.
        latency_s: per-query wall share (group wall time / group size).
        stale: the item's table epoch moved between planning and execution
            (a rebuild landed mid-wave), so the plan was NOT executed — its
            literal encodings belong to a synopsis that no longer exists.
            The caller must re-plan and retry (``AQPServer`` re-enqueues).
    """

    result: QueryResult | None
    batched: bool           # executed via the fused batched launch
    latency_s: float        # per-query wall share (group wall / group size)
    stale: bool = False     # epoch moved mid-wave: not executed, re-plan


@dataclasses.dataclass
class DrainStats:
    """One admission-loop drain: why it fired and what it took.

    Attributes:
        cause: ``"full"`` (queue reached ``max_batch``), ``"flush"``
            (explicit flush / synchronous wrapper), ``"timeout"``
            (``max_wait_ms`` elapsed with a partial group), or
            ``"deadline"`` (a queued item's per-query deadline is at risk,
            so the wave stops filling and fires early).
        size: number of submissions drained into this wave.
        depth: queue depth observed at drain time (``size`` plus whatever
            stayed behind because of ``max_batch``).
        waited_s: age of the oldest drained submission (enqueue -> drain).
    """

    cause: str
    size: int
    depth: int
    waited_s: float


SHED_POLICIES = ("reject", "shed_oldest", "block")


class PlannerPool:
    """Optional planner offload: cold planning runs off the submit thread.

    A thin, swappable wrapper over a thread pool. On today's GIL-bound
    CPython a thread pool mostly buys submit-path *latency* (the submitter
    returns a pending future instead of planning inline); the interface —
    ``submit(fn, *args) -> future``, ``close()`` — is deliberately the
    executor protocol so a free-threaded or subprocess executor can drop
    in without touching the server (``AQPServer(planner_workers=N)``).
    """

    def __init__(self, workers: int):
        if workers <= 0:
            raise ValueError("PlannerPool needs workers >= 1")
        self.workers = int(workers)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="aqp-planner")

    def submit(self, fn, *args) -> concurrent.futures.Future:
        """Schedule ``fn(*args)`` on a planner worker; returns its future."""
        return self._pool.submit(fn, *args)

    def close(self):
        """Stop accepting work and join the workers (pending plans finish)."""
        self._pool.shutdown(wait=True)


class StreamingAdmission:
    """Continuous admission: a bounded queue drained into waves by a worker.

    ``submit`` enqueues and returns immediately — the online-aggregation
    serving model, replacing the synchronous wave-per-call scheduler. A
    single daemon worker drains the queue into execution waves under a
    latency/batch-size policy:

      * a wave fires as soon as ``max_batch`` submissions are queued, or
      * when the oldest queued submission has waited ``max_wait_ms``, or
      * immediately on ``flush()`` (used by the synchronous ``query_batch``
        wrapper so a blocking caller never pays the admission wait).

    **Backpressure** (overload safety): the queue is bounded by
    ``max_queue_depth`` (``<= 0`` = unbounded). When a submit finds the
    queue full, ``shed_policy`` decides:

      * ``"reject"`` — the *new* item is turned away (``submit`` returns
        False after invoking ``shed_cb(item, "reject", depth)``);
      * ``"shed_oldest"`` — the *oldest* queued item is evicted
        (``shed_cb(old, "shed_oldest", depth)``) and the new one admitted;
      * ``"block"`` — ``submit`` blocks until the worker drains space (the
        producer is paced to the consumer; raises if closed while waiting).

    ``shed_cb`` runs on the submitting thread with no admission lock held,
    so it may take the server's locks and resolve futures. An item is
    handed to exactly one of ``execute_cb`` (as part of one wave) or
    ``shed_cb`` — never both, never twice — which is the exactly-once
    foundation the serving layer's future-resolution contract builds on.
    ``high_water`` records the maximum depth ever observed right after an
    admit (the enforced bound is therefore visible, not just configured).

    The worker executes each wave via ``execute_cb(batch, stats)`` (supplied
    by ``AQPServer``) and keeps draining, so completed waves resolve their
    futures without blocking later arrivals. ``flush()`` on an empty queue
    is a no-op (the flag is cleared while idle, never banked).

    The worker thread starts lazily on first submit and is a daemon;
    ``close()`` stops and joins it (pending submissions are drained first so
    no future is abandoned).
    """

    def __init__(self, execute_cb, max_wait_ms: float = 2.0,
                 max_batch: int = 64, max_queue_depth: int = 0,
                 shed_policy: str = "reject", shed_cb=None, tracer=None,
                 idle_cb=None, error_cb=None):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             f"expected one of {SHED_POLICIES}")
        self.execute_cb = execute_cb
        # Supervision hook: when execute_cb raises, the worker survives and
        # hands the wave to error_cb(batch, exc) so the server can resolve
        # every future with a typed result (never a hang, never a dead
        # loop). error_cb itself is guarded — a raising error handler
        # cannot kill the worker either.
        self.error_cb = error_cb
        # Optional between-waves hook on the worker thread (the server wires
        # the cold-tier memory governor here): runs after each wave's
        # execute_cb returns, never concurrently with one, and exceptions
        # are swallowed so housekeeping can't kill the drain loop.
        self.idle_cb = idle_cb
        # Optional repro.obs.trace.Tracer: each drain emits an instant on
        # the "admission" lane (cause/size/depth/oldest-wait).
        self.tracer = tracer
        self.max_wait_ms = float(max_wait_ms)
        self.max_batch = int(max_batch)
        self.max_queue_depth = int(max_queue_depth)
        self.shed_policy = shed_policy
        self.shed_cb = shed_cb or (lambda item, reason, depth: None)
        self.high_water = 0
        # Watchdog: number of times a dead worker thread was replaced (a
        # BaseException escaped the wave guard, e.g. an injected worker
        # crash). Un-executed wave items are restored to the queue front
        # before the restart, preserving the exactly-once contract.
        self.restarts = 0
        self._q: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._flush = False
        self._stop = False
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- public

    def submit(self, item, t_submit: float | None = None) -> bool:
        """Enqueue ``item`` and wake the admission worker.

        Returns True if the item was admitted, False if the bounded queue
        rejected it (``shed_policy="reject"``; ``shed_cb`` has then already
        been invoked with the item). Under ``"shed_oldest"`` the call always
        admits but may evict the queue's oldest item; under ``"block"`` it
        waits for space (non-blocking otherwise).
        """
        t = time.perf_counter() if t_submit is None else t_submit
        shed = None
        with self._cv:
            if self._stop:
                raise RuntimeError("admission queue is closed")
            self._ensure_worker()
            bound = self.max_queue_depth
            if bound > 0 and len(self._q) >= bound:
                if self.shed_policy == "block":
                    while len(self._q) >= bound and not self._stop:
                        self._cv.wait()
                    if self._stop:
                        raise RuntimeError("admission queue is closed")
                elif self.shed_policy == "reject":
                    shed, reason = item, "reject"
                else:                         # shed_oldest: evict to admit
                    shed, reason = self._q.popleft()[1], "shed_oldest"
                depth = len(self._q)
            if shed is not item:
                self._q.append((t, item))
                self.high_water = max(self.high_water, len(self._q))
                self._cv.notify_all()
        if shed is not None:
            self.shed_cb(shed, reason, depth)
        return shed is not item

    def requeue(self, item, t_submit: float):
        """Re-admit an item that was already admitted once (wave retry).

        Skips the backpressure bound entirely: the caller is the admission
        worker itself (re-enqueueing a wave item whose table epoch moved),
        so ``"block"`` would deadlock on the condition the worker alone
        drains, and ``"reject"``/``"shed_oldest"`` would shed an already-
        admitted query. The queue may briefly exceed ``max_queue_depth`` by
        the handful of retried items; they re-enter at the FRONT (oldest
        first — they keep their original submit time, so the wave deadline
        policy treats them as the longest-waiting work).
        """
        with self._cv:
            if self._stop:
                raise RuntimeError("admission queue is closed")
            self._ensure_worker()
            self._q.appendleft((t_submit, item))
            self.high_water = max(self.high_water, len(self._q))
            self._cv.notify_all()

    def flush(self):
        """Drain the current queue immediately (no-op when empty)."""
        with self._cv:
            if self._q:
                self._ensure_worker()
                self._flush = True
                self._cv.notify_all()

    def depth(self) -> int:
        """Current queue depth (submitted, not yet drained into a wave)."""
        with self._cv:
            return len(self._q)

    def close(self):
        """Stop the worker after draining anything still queued."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            thread = self._thread
        if thread is not None:
            thread.join()
            self._thread = None

    # ----------------------------------------------------------------- worker

    def _ensure_worker(self):
        """Start the worker lazily; restart it if it died (watchdog).

        Caller holds ``self._cv``. A replacement after a hard death (a
        ``BaseException`` that escaped the wave guard) counts in
        ``restarts``; ``_loop`` restores un-executed items to the queue
        front before dying, so nothing is lost across the restart.
        """
        if self._thread is not None and not self._thread.is_alive():
            self._thread = None
            self.restarts += 1
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="aqp-admission", daemon=True)
            self._thread.start()

    def _queue_deadline(self):
        """Earliest per-item ``deadline_at`` among queued items, or None."""
        qdl = None
        for _, item in self._q:
            dl = getattr(item, "deadline_at", None)
            if dl is not None and (qdl is None or dl < qdl):
                qdl = dl
        return qdl

    def _collect(self):
        """Block until a wave is due; returns (pairs, DrainStats) or None.

        ``pairs`` keeps the ``(t_submit, item)`` tuples so a crashing
        worker can restore un-executed items to the queue front with their
        original submit times intact.
        """
        with self._cv:
            while not self._q:
                self._flush = False         # flush on empty queue: no-op
                if self._stop:
                    return None
                self._cv.wait()
            # Admission policy: the wave fires on whichever of max_batch /
            # flush / oldest-waited-max_wait_ms trips first — or early,
            # with cause "deadline", when a queued item's per-query
            # deadline would expire before the normal wave fire time (the
            # drain stops adding to a wave whose oldest deadline is at
            # risk).
            margin = self.max_wait_ms / 1e3
            deadline = self._q[0][0] + margin
            cause = "timeout"
            while True:
                if len(self._q) >= self.max_batch:
                    cause = "full"
                    break
                if self._flush or self._stop:
                    cause = "flush"
                    break
                wake = deadline
                at_risk = False
                qdl = self._queue_deadline()
                if qdl is not None and qdl - margin < wake:
                    wake = qdl - margin
                    at_risk = True
                remaining = wake - time.perf_counter()
                if remaining <= 0:
                    if at_risk:
                        cause = "deadline"
                    break
                self._cv.wait(remaining)
            self._flush = False
            depth = len(self._q)
            take = min(depth, self.max_batch)
            now = time.perf_counter()
            waited = now - self._q[0][0]
            pairs = [self._q.popleft() for _ in range(take)]
            self._cv.notify_all()   # wake producers blocked on a full queue
        stats = DrainStats(cause, take, depth, waited)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(
                "drain", track="admission",
                attrs={"cause": cause, "size": take, "depth": depth,
                       "oldest_wait_ms": waited * 1e3})
        return pairs, stats

    def _loop(self):
        while True:
            wave = self._collect()
            if wave is None:
                return
            pairs, stats = wave
            try:
                faults.hook("worker")
            except Exception:
                # Simulated worker death before the wave ran: nothing was
                # executed, so the whole wave re-enters the queue and the
                # replacement worker drains it. Exit quietly — the crash is
                # already accounted for in ``restarts``.
                self._revive(pairs)
                return
            except BaseException:
                self._revive(pairs)
                raise
            batch = [item for _, item in pairs]
            try:
                self.execute_cb(batch, stats)
            except Exception as exc:
                # Supervision: a raising wave must not kill the drain loop
                # or strand its futures. The server's error_cb resolves
                # them with typed QueryError results (or retries).
                if self.error_cb is not None:
                    try:
                        self.error_cb(batch, exc)
                    except Exception:
                        pass
            except BaseException:
                # Hard death (interpreter shutdown, injected worker crash
                # mid-wave): the wave may be partially executed, so it is
                # NOT restored — already-resolved futures stay resolved,
                # and the watchdog replaces the worker for queued items.
                self._revive(())
                raise
            if self.idle_cb is not None:
                try:
                    self.idle_cb()
                except Exception:
                    pass

    def _revive(self, pairs):
        """Restore un-executed wave items and spawn a replacement worker.

        Called on the dying worker thread itself. ``pairs`` (possibly
        empty) re-enter at the queue FRONT in their original order with
        original submit times — they were handed to neither ``execute_cb``
        nor ``shed_cb``, so exactly-once is preserved across the restart.
        """
        with self._cv:
            self._q.extendleft(reversed(pairs))
            self.high_water = max(self.high_water, len(self._q))
            if not self._stop:
                self.restarts += 1
                self._thread = threading.Thread(
                    target=self._loop, name="aqp-admission", daemon=True)
                self._thread.start()
            self._cv.notify_all()


class BatchScheduler:
    """Groups planned queries by plan shape and fuses kernel launches.

    Args:
        catalog: ``TableCatalog`` resolving table names to engines.
        mode: ``"pallas"`` / ``"ref"`` / ``"numpy"`` / ``None`` (auto) —
            see the module docstring for the semantics of each.
        max_group: hard cap on queries per fused launch (group splits).
        min_group: groups smaller than this skip the fused launch (a batch
            of one gains nothing from the kernel but still pays dispatch).
        tracer: optional ``repro.obs.trace.Tracer``. When enabled, every
            fused launch records a ``kernel`` span on the "worker" lane —
            fenced with ``jax.block_until_ready`` so the interval is wall
            time, not dispatch time — and (``tracer.annotate_jax``) opens a
            matching ``jax.profiler.TraceAnnotation`` so the span lines up
            inside a captured JAX profiler trace.
    """

    def __init__(self, catalog, mode: str | None = None,
                 max_group: int = 256, min_group: int = 2, tracer=None):
        if mode is None:
            import jax
            mode = "pallas" if jax.default_backend() == "tpu" else "numpy"
        if mode not in ("pallas", "ref", "numpy"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        self.catalog = catalog
        self.mode = mode
        self.max_group = int(max_group)
        # Groups below min_group skip the fused launch: a batch of one gains
        # nothing from the kernel but still pays its dispatch.
        self.min_group = int(min_group)
        self.tracer = tracer
        self.fastpath = (None if mode == "numpy"
                         else FastPath(use_pallas=(mode == "pallas")))

    # ----------------------------------------------------------------- public

    def execute(self, items: list[tuple]) -> list[ScheduledResult]:
        """Execute a wave of planned queries; returns results aligned with
        ``items``. Grouping is transparent: results are identical (numpy
        mode) / fp-close (kernel modes) to per-query execution.

        Items are ``(table, plan)`` or ``(table, plan, epoch)``. With an
        epoch, the item's table epoch is **re-validated here, per item**,
        against an atomic ``catalog.snapshot`` — engines are fetched at
        execution time, so a rebuild landing after the server's wave-start
        epoch check would otherwise pair this old plan with the new
        synopsis (silently wrong literal encodings). The framework
        publishes ``(engine, epoch)`` in one assignment, so a snapshot
        whose epoch matches the plan's guarantees the engine is exactly
        the synopsis the plan was encoded against (no tearing); executing
        that engine stays correct even if a rebuild lands mid-execution —
        the result is consistent at the plan's epoch and is cached under
        it. A mismatched snapshot returns ``stale=True`` for that item
        (nothing executes) and the caller re-plans."""
        out: list[ScheduledResult | None] = [None] * len(items)
        groups: dict[tuple, list[int]] = {}
        for idx, item in enumerate(items):
            table, plan = item[0], item[1]
            shape = plan.shape_key() if self.fastpath is not None else None
            if shape is None:
                self._run_single(items, idx, out)
            else:
                groups.setdefault((table,) + shape, []).append(idx)

        for (table, exec_col, _cols), idxs in groups.items():
            if len(idxs) < self.min_group:
                for idx in idxs:
                    self._run_single(items, idx, out)
                continue
            for lo in range(0, len(idxs), self.max_group):
                self._run_group(items, table, exec_col,
                                idxs[lo:lo + self.max_group], out)
        return out  # type: ignore[return-value]

    # ---------------------------------------------------------------- helpers

    @staticmethod
    def _item_epoch(item):
        """The epoch an item's plan was made at, or None (no validation)."""
        return item[2] if len(item) > 2 else None

    def _stale_result(self) -> ScheduledResult:
        """A per-item 'epoch moved mid-wave' outcome (plan not executed)."""
        return ScheduledResult(None, False, 0.0, stale=True)

    def _tracing(self) -> bool:
        return self.tracer is not None and self.tracer.enabled

    def _run_single(self, items, idx, out, span: bool = True):
        item = items[idx]
        table, plan, epoch = item[0], item[1], self._item_epoch(item)
        engine, cur = self.catalog.snapshot(table)
        if epoch is not None and cur != epoch:
            out[idx] = self._stale_result()
            return
        t0 = time.perf_counter()
        res = engine.execute_plan(plan)
        t1 = time.perf_counter()
        if span and self._tracing():
            self.tracer.add("single_exec", t0, t1, track="worker",
                            attrs={"table": table})
        out[idx] = ScheduledResult(res, False, t1 - t0)

    def _run_group(self, items, table, exec_col, idxs, out):
        engine, cur = self.catalog.snapshot(table)
        live = []
        for idx in idxs:
            epoch = self._item_epoch(items[idx])
            if epoch is not None and cur != epoch:
                out[idx] = self._stale_result()
            else:
                live.append(idx)
        if not live:
            return
        ph = engine.ph
        tracing = self._tracing()
        t0 = time.perf_counter()
        triples = None
        if len(live) > 0 and self.fastpath is not None:
            faults.hook("kernel_launch")
            trees = [items[idx][1].tree for idx in live]
            if tracing and self.tracer.annotate_jax:
                import jax.profiler
                with jax.profiler.TraceAnnotation(
                        f"aqp.fused:{table}.{exec_col}"):
                    triples = self.fastpath.batch(ph, exec_col, trees,
                                                  engine.corrected)
            else:
                triples = self.fastpath.batch(ph, exec_col, trees,
                                              engine.corrected)
            if tracing and triples is not None:
                # Fence the fused launch so the kernel span is honest wall
                # time; the per-query aggregation below would otherwise
                # absorb the async dispatch.
                import jax
                jax.block_until_ready(triples)
                self.tracer.add("kernel", t0, time.perf_counter(),
                                track="worker",
                                attrs={"table": table, "col": exec_col,
                                       "queries": len(live)})
        if triples is None:       # ineligible after all: per-query fallback
            # One group_exec span for the whole loop, not one per item:
            # GROUP BY leaves land here ~10 at a time and per-leaf spans
            # were the single largest traced-path cost (ring churn included)
            # for zero extra information — the leaves are interchangeable.
            for idx in live:
                self._run_single(items, idx, out, span=False)
            if tracing:
                self.tracer.add("group_exec", t0, time.perf_counter(),
                                track="worker",
                                attrs={"table": table, "col": exec_col,
                                       "queries": len(live)})
            return
        for triple, idx in zip(triples, live):
            res = engine.execute_plan(items[idx][1], weightings=triple)
            out[idx] = ScheduledResult(res, True, 0.0)
        t1 = time.perf_counter()
        if tracing:
            self.tracer.add("wave_group", t0, t1, track="worker",
                            attrs={"table": table, "col": exec_col,
                                   "queries": len(live)})
        share = (t1 - t0) / len(live)
        for idx in live:
            out[idx].latency_s = share
            out[idx].result.latency_s = share
