"""Batch scheduler: group in-flight queries by plan shape, fuse launches.

At sub-ms per-query latency the serving bottleneck is dispatch, not math
(the same observation that motivates ``core/fastpath``'s per-predicate
fusion, one level up). The scheduler takes a set of in-flight planned
queries and groups them by **plan shape** ``(table, exec column,
pair-predicate column set)``; each group shares its padded (H, fold, hx)
stacks and executes as ONE query-batched kernel launch covering every query
and all three bound variants (``FastPath.batch`` ->
``kernels.weightings.batched_weightings``). Per-query work shrinks to beta
assembly + the final scalar aggregation.

Queries outside the batchable shape (OR trees, GROUP BY, no WHERE) fall
back to the per-table engine's own path — which is also the oracle the
batched path is tested against.

Execution modes:
  * ``"pallas"`` — batched Pallas kernel (TPU; interpret elsewhere)
  * ``"ref"``    — batched jitted-jnp oracle of the same kernel (f32)
  * ``"numpy"``  — no fused launch; per-query reference execution,
                   bit-identical to ``QueryEngine.query`` (grouping,
                   dedup and caching still apply)
  * ``None``     — auto: "pallas" on TPU, "numpy" elsewhere. On CPU the
                   per-launch JAX dispatch the fused kernel amortizes on
                   TPU *is* the overhead, so fusing small groups loses to
                   NumPy (same reasoning as bench_kernels.py: Pallas off-TPU
                   is for correctness, not speed).
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.fastpath import FastPath
from repro.core.query import QueryPlan, QueryResult


@dataclasses.dataclass
class ScheduledResult:
    result: QueryResult
    batched: bool           # executed via the fused batched launch
    latency_s: float        # per-query wall share (group wall / group size)


class BatchScheduler:
    def __init__(self, catalog, mode: str | None = None,
                 max_group: int = 256, min_group: int = 2):
        if mode is None:
            import jax
            mode = "pallas" if jax.default_backend() == "tpu" else "numpy"
        if mode not in ("pallas", "ref", "numpy"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        self.catalog = catalog
        self.mode = mode
        self.max_group = int(max_group)
        # Groups below min_group skip the fused launch: a batch of one gains
        # nothing from the kernel but still pays its dispatch.
        self.min_group = int(min_group)
        self.fastpath = (None if mode == "numpy"
                         else FastPath(use_pallas=(mode == "pallas")))

    # ----------------------------------------------------------------- public

    def execute(self, items: list[tuple[str, QueryPlan]]
                ) -> list[ScheduledResult]:
        """Execute a wave of planned queries; returns results aligned with
        ``items``. Grouping is transparent: results are identical (numpy
        mode) / fp-close (kernel modes) to per-query execution."""
        out: list[ScheduledResult | None] = [None] * len(items)
        groups: dict[tuple, list[int]] = {}
        for idx, (table, plan) in enumerate(items):
            shape = plan.shape_key() if self.fastpath is not None else None
            if shape is None:
                self._run_single(items, idx, out)
            else:
                groups.setdefault((table,) + shape, []).append(idx)

        for (table, exec_col, _cols), idxs in groups.items():
            if len(idxs) < self.min_group:
                for idx in idxs:
                    self._run_single(items, idx, out)
                continue
            for lo in range(0, len(idxs), self.max_group):
                self._run_group(items, table, exec_col,
                                idxs[lo:lo + self.max_group], out)
        return out  # type: ignore[return-value]

    # ---------------------------------------------------------------- helpers

    def _run_single(self, items, idx, out):
        table, plan = items[idx]
        engine = self.catalog.engine(table)
        t0 = time.perf_counter()
        res = engine.execute_plan(plan)
        out[idx] = ScheduledResult(res, False, time.perf_counter() - t0)

    def _run_group(self, items, table, exec_col, idxs, out):
        engine = self.catalog.engine(table)
        ph = engine.ph
        t0 = time.perf_counter()
        triples = None
        if len(idxs) > 0 and self.fastpath is not None:
            trees = [items[idx][1].tree for idx in idxs]
            triples = self.fastpath.batch(ph, exec_col, trees,
                                          engine.corrected)
        if triples is None:       # ineligible after all: per-query fallback
            for idx in idxs:
                self._run_single(items, idx, out)
            return
        for triple, idx in zip(triples, idxs):
            res = engine.execute_plan(items[idx][1], weightings=triple)
            out[idx] = ScheduledResult(res, True, 0.0)
        share = (time.perf_counter() - t0) / len(idxs)
        for idx in idxs:
            out[idx].latency_s = share
            out[idx].result.latency_s = share
