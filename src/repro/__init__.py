"""repro — PairwiseHist AQP framework on JAX, with a multi-arch LM substrate.

Layout:
  repro.core      — the paper's contribution (PairwiseHist synopsis + queries)
  repro.gd        — GreedyGD compression substrate
  repro.aqp       — end-to-end AQP engine, datasets, baselines, exact engine
  repro.kernels   — Pallas TPU kernels (hist2d, fused weightings) + refs
  repro.models    — 10 assigned LM architectures
  repro.sharding  — logical-axis sharding rules
  repro.train     — optimizer, train step, telemetry, grad compression
  repro.serve     — prefill/decode serving
  repro.ckpt      — fault-tolerant checkpointing
  repro.data      — data pipelines
  repro.configs   — architecture configs
  repro.launch    — mesh / dryrun / train / serve entry points

NOTE: importing `repro` has no JAX side effects (no x64 flag, no device init).
`repro.core` enables x64 at import (AQP needs int64/float64 domains); the LM
stack never imports `repro.core` and uses explicit dtypes throughout.
"""

__version__ = "1.0.0"
