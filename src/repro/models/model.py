"""Model assembly: config, parameter init, train forward, prefill, decode.

Layers are grouped into *superblocks* (one repetition of ``block_pattern``)
stacked along a leading axis and applied with ``lax.scan`` + ``jax.checkpoint``
— HLO stays compact for 80-layer models and activations are rematerialized in
the backward pass. Pattern remainders (e.g. recurrentgemma's 38 = 12x(rec,
rec, attn) + (rec, rec)) form a second, smaller stack.

Embeddings are tied (logits = x @ embed.T, vocab-sharded).
``embed_inputs=True`` (VLM/audio stubs) takes pre-computed frontend
embeddings instead of token ids, per the assignment brief.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import layers as L
from repro.models.common import rms_norm, softcap, trunc_normal
from repro.sharding import constrain

BLOCK_KINDS = ("attn", "attn_local", "attn_global", "moe", "moe_local",
               "ssm", "rec")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    block_pattern: tuple = ("attn",)
    first_dense: bool = False          # deepseek: layer 0 is dense
    # attention options
    qk_norm: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    window: int | None = None          # local-attention window
    rope_theta: float = 10000.0
    attn_chunk: int = 1024
    heads_shardable: bool = True       # n_heads % tensor-parallel == 0
    mlp_act: str = "silu"              # "silu" (SwiGLU) | "gelu" (GeGLU)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"           # "einsum" (baseline) | "sort" (§Perf)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_bf16_intra: bool = False       # bf16 intra-chunk SSD tensors (§Perf)
    # RG-LRU
    rnn_width: int = 0
    rnn_conv: int = 4
    # modality
    embed_inputs: bool = False         # frontend stub feeds (B,S,D) embeds
    sub_quadratic: bool = False        # can run long_500k decode
    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    # "nothing": full remat; "dots": save every no-batch-dim matmul output
    # (§Perf remat_dots — big wire/compute win, big HBM cost); "blk_out":
    # save only the named per-block output projections — the deployable
    # middle ground (§Perf remat_names).
    remat_policy: str = "nothing"
    norm_upcast: bool = True           # False: bf16 RMSNorm (§Perf bf16_norm)
    # Cost-analysis mode: XLA counts while-loop bodies ONCE regardless of
    # trip count, so the dry-run lowers an unrolled variant for exact
    # FLOP/collective accounting (scan variant stays the memory/compile
    # deliverable). Never set outside the dry-run.
    force_unroll: bool = False

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_groups(self):
        """[(pattern_tuple, n_repeats)] covering all n_layers."""
        n = self.n_layers - (1 if self.first_dense else 0)
        pat = self.block_pattern
        groups = []
        if self.first_dense:
            groups.append((("attn",), 1))
        n_super, rem = divmod(n, len(pat))
        if n_super:
            groups.append((pat, n_super))
        if rem:
            groups.append((pat[:rem], 1))
        return groups


# ---------------------------------------------------------------------------
# Parameter init / logical axes
# ---------------------------------------------------------------------------

_BLOCK_INIT = {
    "attn": lambda k, cfg: {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                            "attn": L.init_attention(k, cfg),
                            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                            "mlp": L.init_mlp(jax.random.fold_in(k, 1), cfg)},
    "moe": lambda k, cfg: {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                           "attn": L.init_attention(k, cfg),
                           "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                           "moe": L.init_moe(jax.random.fold_in(k, 1), cfg)},
    "ssm": lambda k, cfg: {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                           "ssm": L.init_ssm(k, cfg)},
    "rec": lambda k, cfg: {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                           "rec": L.init_rglru(k, cfg),
                           "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                           "mlp": L.init_mlp(jax.random.fold_in(k, 1), cfg)},
}
for alias, base in (("attn_local", "attn"), ("attn_global", "attn"),
                    ("moe_local", "moe")):
    _BLOCK_INIT[alias] = _BLOCK_INIT[base]


def _block_axes(kind: str, cfg) -> dict:
    heads_ax = "heads" if cfg.heads_shardable else None
    attn_ax = L.attention_axes(cfg)
    if kind.startswith("attn") or kind.startswith("moe"):
        out = {"ln1": (None,), "ln2": (None,),
               "attn": attn_ax}
        if kind.startswith("moe"):
            out["moe"] = L.moe_axes(cfg)
        else:
            out["mlp"] = L.mlp_axes()
        return out
    if kind == "ssm":
        return {"ln1": (None,), "ssm": L.ssm_axes()}
    if kind == "rec":
        return {"ln1": (None,), "rec": L.rglru_axes(),
                "ln2": (None,), "mlp": L.mlp_axes()}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    params = {
        "embed": trunc_normal(keys[0], (cfg.vocab, cfg.d_model),
                              1.0 / math.sqrt(cfg.d_model)),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        "groups": [],
    }
    for gi, (pat, n_rep) in enumerate(cfg.layer_groups()):
        gkey = jax.random.fold_in(keys[1], gi)

        def one_super(k):
            return {f"{pi}_{kind}": _BLOCK_INIT[kind](jax.random.fold_in(k, pi), cfg)
                    for pi, kind in enumerate(pat)}

        stacked = jax.vmap(one_super)(jax.random.split(gkey, n_rep))
        params["groups"].append(stacked)
    return params


def param_logical_axes(cfg: ModelConfig):
    """Same tree structure as init_params, leaves = logical axis tuples
    (stacked layer groups get a leading None for the repeat axis)."""
    axes = {"embed": ("vocab", "fsdp"), "ln_f": (None,), "groups": []}
    for pat, _ in cfg.layer_groups():
        g = {f"{pi}_{kind}": _block_axes(kind, cfg)
             for pi, kind in enumerate(pat)}
        g = jax.tree_util.tree_map(lambda ax: (None,) + tuple(ax), g,
                                   is_leaf=lambda x: isinstance(x, tuple))
        axes["groups"].append(g)
    return axes


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_block(kind: str, p, x, cfg, cache=None, cache_index=None):
    """Pre-norm residual block. Returns (x, new_cache).

    Entry constraint: the saved inter-block residual is D-sharded
    ("resid_embed"), but "blk_in_embed" controls what GSPMD propagates
    *inside* the block — baseline keeps D-sharding (per-matmul gathers);
    the §Perf zero_r variant replicates at entry (ONE gather per layer).
    """
    x = constrain(x, "batch", None, "blk_in_embed")
    new_cache = cache
    if kind.startswith("attn") or kind.startswith("moe"):
        local = kind.endswith("local")
        h = rms_norm(x, p["ln1"], upcast=cfg.norm_upcast)
        attn_out, new_cache = L.attention_apply(
            p["attn"], h, cfg, local=local, cache=cache,
            cache_index=cache_index)
        attn_out = checkpoint_name(attn_out, "attn_out")
        x = x + attn_out
        h = rms_norm(x, p["ln2"], upcast=cfg.norm_upcast)
        if kind.startswith("moe"):
            ffn = L.moe_apply(p["moe"], h, cfg)
        else:
            ffn = L.mlp_apply(p["mlp"], h, cfg)
        x = x + checkpoint_name(ffn, "ffn_out")
    elif kind == "ssm":
        h = rms_norm(x, p["ln1"], upcast=cfg.norm_upcast)
        state = None if cache is None else cache["state"]
        conv = None if cache is None else cache["conv"]
        out, (new_state, new_conv) = L.ssm_apply(p["ssm"], h, cfg, state, conv)
        x = x + out
        if cache is not None:
            new_cache = {"state": new_state, "conv": new_conv}
    elif kind == "rec":
        h = rms_norm(x, p["ln1"], upcast=cfg.norm_upcast)
        state = None if cache is None else cache["state"]
        conv = None if cache is None else cache["conv"]
        out, (new_state, new_conv) = L.rglru_apply(p["rec"], h, cfg, state, conv)
        x = x + out
        h = rms_norm(x, p["ln2"], upcast=cfg.norm_upcast)
        x = x + L.mlp_apply(p["mlp"], h, cfg)
        if cache is not None:
            new_cache = {"state": new_state, "conv": new_conv}
    else:
        raise ValueError(kind)
    return constrain(x, "batch", "resid_seq", "resid_embed"), new_cache


def _superblock(pat, sp, x, cfg, caches=None, cache_index=None):
    new_caches = {} if caches is not None else None
    for pi, kind in enumerate(pat):
        key = f"{pi}_{kind}"
        cache = None if caches is None else caches.get(key)
        x, nc = _apply_block(kind, sp[key], x, cfg, cache, cache_index)
        if caches is not None:
            new_caches[key] = nc
    return x, new_caches


def embed_tokens(params, cfg, tokens_or_embeds):
    if cfg.embed_inputs:
        x = tokens_or_embeds.astype(cfg.act_dtype)
    else:
        x = params["embed"].astype(cfg.act_dtype)[tokens_or_embeds]
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.act_dtype)
    return constrain(x, "batch", "resid_seq", "resid_embed")


def forward(params, cfg: ModelConfig, tokens_or_embeds):
    """Training/scoring forward -> logits (B, S, V) (vocab-sharded)."""
    x = embed_tokens(params, cfg, tokens_or_embeds)
    for (pat, n_rep), stacked in zip(cfg.layer_groups(), params["groups"]):

        def body(carry, sp):
            out, _ = _superblock(pat, sp, carry, cfg)
            return out, None

        if cfg.remat:
            if cfg.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            elif cfg.remat_policy == "blk_out":
                policy = jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "ffn_out")
            else:
                policy = jax.checkpoint_policies.nothing_saveable
            body = jax.checkpoint(body, policy=policy)
        if n_rep == 1:
            sp0 = jax.tree_util.tree_map(lambda a: a[0], stacked)
            x, _ = body(x, sp0)
        elif cfg.force_unroll:
            for rep in range(n_rep):
                sp_i = jax.tree_util.tree_map(lambda a: a[rep], stacked)
                x, _ = body(x, sp_i)
        else:
            x, _ = jax.lax.scan(body, x, stacked)
    x = rms_norm(x, params["ln_f"], upcast=cfg.norm_upcast)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "batch", None, "vocab")


def loss_fn(params, cfg: ModelConfig, batch):
    """Mean next-token cross-entropy (f32 logsumexp over sharded vocab)."""
    inputs = batch["embeds"] if cfg.embed_inputs else batch["tokens"]
    logits = forward(params, cfg, inputs).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = jnp.asarray(nll.size, jnp.float32)
    return nll.sum() / denom


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _block_cache(kind, cfg, batch, max_len, dtype):
    if kind.startswith("attn") or kind.startswith("moe"):
        return L.attention_cache(cfg, batch, max_len, dtype,
                                 local=kind.endswith("local"))
    if kind == "ssm":
        return L.ssm_cache(cfg, batch, dtype)
    if kind == "rec":
        return L.rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _block_cache_axes(kind):
    if kind.startswith("attn") or kind.startswith("moe"):
        return L.attention_cache_axes()
    if kind == "ssm":
        return L.ssm_cache_axes()
    return L.rglru_cache_axes()


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked caches mirroring the layer-group structure + position."""
    dtype = cfg.act_dtype
    groups = []
    for pat, n_rep in cfg.layer_groups():
        def one(_):
            return {f"{pi}_{kind}": _block_cache(kind, cfg, batch, max_len, dtype)
                    for pi, kind in enumerate(pat)}
        stacked = jax.vmap(one)(jnp.arange(n_rep))
        groups.append(stacked)
    return {"groups": groups, "index": jnp.zeros((), jnp.int32)}


def cache_logical_axes(cfg: ModelConfig):
    axes = {"groups": [], "index": ()}
    for pat, _ in cfg.layer_groups():
        g = {f"{pi}_{kind}": _block_cache_axes(kind)
             for pi, kind in enumerate(pat)}
        g = jax.tree_util.tree_map(lambda ax: (None,) + tuple(ax), g,
                                   is_leaf=lambda x: isinstance(x, tuple))
        axes["groups"].append(g)
    return axes


def _step(params, cfg, x, cache, seq_len: int):
    """Shared prefill/decode walker over the stacked caches."""
    index = cache["index"]
    new_groups = []
    for (pat, n_rep), stacked_p, stacked_c in zip(
            cfg.layer_groups(), params["groups"], cache["groups"]):

        def body(carry, inp):
            sp, sc = inp
            out, nc = _superblock(pat, sp, carry, cfg, sc, index)
            return out, nc

        if n_rep == 1:
            sp0 = jax.tree_util.tree_map(lambda a: a[0], stacked_p)
            sc0 = jax.tree_util.tree_map(lambda a: a[0], stacked_c)
            x, nc = body(x, (sp0, sc0))
            nc = jax.tree_util.tree_map(lambda a: a[None], nc)
        elif cfg.force_unroll:
            ncs = []
            for rep in range(n_rep):
                sp_i = jax.tree_util.tree_map(lambda a: a[rep], stacked_p)
                sc_i = jax.tree_util.tree_map(lambda a: a[rep], stacked_c)
                x, nc_i = body(x, (sp_i, sc_i))
                ncs.append(nc_i)
            nc = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ncs)
        else:
            x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_groups.append(nc)
    x = rms_norm(x, params["ln_f"], upcast=cfg.norm_upcast)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    new_cache = {"groups": new_groups, "index": index + seq_len}
    return constrain(logits, "batch", None, "vocab"), new_cache


def prefill(params, cfg: ModelConfig, tokens_or_embeds, cache):
    """Process a prompt batch, filling the cache. Returns (logits, cache)."""
    x = embed_tokens(params, cfg, tokens_or_embeds)
    return _step(params, cfg, x, cache, x.shape[1])


def decode_step(params, cfg: ModelConfig, token_or_embed, cache):
    """One token per sequence: (B,) ids or (B,1,D) embeds."""
    if not cfg.embed_inputs and token_or_embed.ndim == 1:
        token_or_embed = token_or_embed[:, None]
    x = embed_tokens(params, cfg, token_or_embed)
    return _step(params, cfg, x, cache, 1)
