"""Layer library for the 10 assigned architectures.

Pure-functional: every layer is (init_fn, apply_fn) over plain dict pytrees.
Attention is *blockwise/chunked* (never materializes (S, S) scores): scores
live per query-chunk in f32, which keeps the 32k-prefill and 4k-train
memory footprints inside HBM under remat-over-layers. Pallas-TPU flash
kernels can replace the chunked path on real hardware; the chunked XLA path
is what the CPU dry-run lowers (see DESIGN.md §3).

Decode paths (single query token) update caches functionally.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, make_rope, rms_norm, softcap, trunc_normal
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# Attention (GQA/MQA, optional qk-norm / soft-capping / local window)
# ---------------------------------------------------------------------------


def init_attention(key, cfg):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": trunc_normal(ks[0], (d, h, dh), std),
        "wk": trunc_normal(ks[1], (d, hkv, dh), std),
        "wv": trunc_normal(ks[2], (d, hkv, dh), std),
        "wo": trunc_normal(ks[3], (h, dh, d), 1.0 / math.sqrt(h * dh)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def attention_axes(cfg):
    return {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
        **({"q_norm": (None,), "k_norm": (None,)} if cfg.qk_norm else {}),
    }


_NEG_POS = -(2**30)


def _chunked_attention(q, k, v, *, q_positions, kv_positions, window, cap,
                       chunk):
    """Blockwise causal attention with explicit absolute positions.

    q: (B, Sq, Hkv, G, dh); k/v: (B, Skv, Hkv, dh).
    q_positions: (Sq,) int32; kv_positions: (Skv,) int32 (ring caches carry
    stale slots with very negative positions -> masked automatically).
    Returns (B, Sq, Hkv, G, dh). Scores are per-chunk f32 (never (S, S)).
    """
    b, sq, hkv, g, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, sq)
    if sq % chunk != 0:  # ragged (smoke-test) sizes: single chunk
        chunk = sq
    n_chunks = max(sq // chunk, 1)
    qs = jnp.moveaxis(q.reshape(b, n_chunks, chunk, hkv, g, dh), 1, 0)
    qp = q_positions.reshape(n_chunks, chunk)

    def one_chunk(carry, inp):
        qc, q_pos = inp
        s = jnp.einsum("bchgd,bshd->bhgcs", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if cap is not None:
            s = softcap(s, cap)
        causal = (kv_positions[None, :] <= q_pos[:, None]) \
            & (kv_positions[None, :] >= 0)  # unwritten ring slots are < 0
        if window is not None:
            causal &= kv_positions[None, :] > (q_pos[:, None] - window)
        s = jnp.where(causal[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgcs,bshd->bchgd", p, v.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(one_chunk, None, (qs, qp))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, dh)


def attention_apply(p, x, cfg, *, local: bool, cache=None, cache_index=None):
    """Full-sequence path when cache is None; else cached prefill/decode.

    cache: dict(k/v=(B, S_eff, Hkv, dh), pos=(S_eff,) i32). Local-attention
    caches are ring buffers of size window; writes go to index % S_eff and
    masking relies on the stored absolute positions. Returns (out, cache').
    """
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    g = h // hkv
    window = cfg.window if local else None

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    positions = jnp.arange(s, dtype=jnp.int32)
    if cache_index is not None:
        positions = positions + cache_index
    cos, sin = make_rope(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    qg = q.reshape(b, s, hkv, g, dh)

    if cache is None:
        out = _chunked_attention(qg, k, v, q_positions=positions,
                                 kv_positions=positions, window=window,
                                 cap=cfg.attn_softcap, chunk=cfg.attn_chunk)
        new_cache = None
    elif s > 1:
        # Prefill (from an empty cache): attend within the prompt itself;
        # the cache receives the tail needed for future decode steps.
        out = _chunked_attention(qg, k, v, q_positions=positions,
                                 kv_positions=positions, window=window,
                                 cap=cfg.attn_softcap, chunk=cfg.attn_chunk)
        eff = cache["k"].shape[1]
        take = min(s, eff)
        # Ring invariant: position p lives in slot p % eff, so later decode
        # writes (at index % eff) overwrite the right slots.
        shift = (s - take) % eff
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], jnp.roll(k[:, -take:], shift, axis=1), 0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], jnp.roll(v[:, -take:], shift, axis=1), 0, 1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.roll(positions[-take:], shift), 0, 0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        # Single-token decode: ring write at index % eff, mask by positions.
        eff = cache["k"].shape[1]
        slot = jax.lax.rem(cache_index, jnp.int32(eff))
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"],
                                                   positions, slot, 0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = _chunked_attention(qg, ck, cv, q_positions=positions,
                                 kv_positions=cpos, window=window,
                                 cap=cfg.attn_softcap, chunk=cfg.attn_chunk)
    out = out.reshape(b, s, h, dh)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(out, "batch", "resid_seq", "resid_embed"), new_cache


def attention_cache(cfg, batch: int, max_len: int, dtype, local: bool = False):
    eff = max_len
    if local and cfg.window:
        eff = min(max_len, cfg.window)
    shape = (batch, eff, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((eff,), _NEG_POS, jnp.int32)}


def attention_cache_axes():
    # "kv_seq" is the fallback shard axis when kv heads don't divide the
    # tensor axis (the dry-run rules enable exactly one of kv_heads/kv_seq).
    return {"k": ("batch", "kv_seq", "kv_heads", None),
            "v": ("batch", "kv_seq", "kv_heads", None),
            "pos": (None,)}


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": trunc_normal(ks[0], (d, f), 1.0 / math.sqrt(d)),
        "w3": trunc_normal(ks[1], (d, f), 1.0 / math.sqrt(d)),
        "w2": trunc_normal(ks[2], (f, d), 1.0 / math.sqrt(f)),
    }


def mlp_axes():
    return {"w1": ("fsdp", "tensor"), "w3": ("fsdp", "tensor"),
            "w2": ("tensor", "fsdp")}


def mlp_apply(p, x, cfg):
    act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
    hcur = act(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    hcur = constrain(hcur, "batch", None, "tensor")
    return hcur @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based einsum dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": trunc_normal(ks[0], (d, e), 1.0 / math.sqrt(d)),
        "w1": trunc_normal(ks[1], (e, d, f), 1.0 / math.sqrt(d)),
        "w3": trunc_normal(ks[2], (e, d, f), 1.0 / math.sqrt(d)),
        "w2": trunc_normal(ks[3], (e, f, d), 1.0 / math.sqrt(f)),
    }
    if cfg.n_shared > 0:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff_expert * cfg.n_shared)
    return p


def moe_axes(cfg):
    ax = {
        "router": ("fsdp", None),
        "w1": ("expert", "fsdp", None),
        "w3": ("expert", "fsdp", None),
        "w2": ("expert", None, "fsdp"),
    }
    if cfg.n_shared > 0:
        ax["shared"] = mlp_axes()
    return ax


def moe_apply(p, x, cfg):
    """Top-k MoE FFN. Two dispatch implementations (cfg.moe_impl):

    "einsum" (baseline, Switch/Mesh-TF style): one-hot dispatch/combine
    einsums — simple and MXU-dense but burns O(S*E*C*d) FLOPs and bytes on
    the dispatch masks (visible as a depressed useful-FLOP ratio in the
    roofline table).

    "sort" (optimized): argsort tokens by expert id, place into (E, C)
    buffers with gathers, combine with a scatter-add — dispatch cost drops
    from matmul-sized to gather-sized (EXPERIMENTS.md §Perf).
    """
    if cfg.moe_impl == "sort":
        return _moe_apply_sort(p, x, cfg)
    return _moe_apply_einsum(p, x, cfg)


def _moe_router(p, x, cfg):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(s * k / e * cfg.capacity_factor))
    cap = min(max(cap, 4), s)
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e, cap


def _moe_ffn(p, xin, cfg):
    """xin: (..., E, C, D) -> (..., E, C, D)."""
    act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
    hcur = act(jnp.einsum("becd,edf->becf", xin, p["w1"].astype(xin.dtype)))
    hcur = hcur * jnp.einsum("becd,edf->becf", xin, p["w3"].astype(xin.dtype))
    return jnp.einsum("becf,efd->becd", hcur, p["w2"].astype(xin.dtype))


def _moe_apply_sort(p, x, cfg):
    """Sort-based dispatch: gathers/scatter-adds instead of one-hot matmuls."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    top_p, top_e, cap = _moe_router(p, x, cfg)

    def per_row(xr, top_pr, top_er):
        # xr: (S, D); top_er/top_pr: (S, k)
        flat_e = top_er.reshape(-1)                       # (S*k,)
        flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
        flat_gate = top_pr.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
        # position of each entry within its expert's buffer
        pos = jnp.arange(s * k, dtype=jnp.int32) - jnp.searchsorted(
            se, se, side="left").astype(jnp.int32)
        keep = pos < cap
        dest = jnp.where(keep, se * cap + pos, e * cap)   # overflow slot
        buf = jnp.zeros((e * cap + 1, d), x.dtype)
        buf = buf.at[dest].set(xr[st] * keep[:, None].astype(x.dtype))
        xin = buf[:-1].reshape(e, cap, d)
        yout = _moe_ffn(p, xin[None], cfg)[0]             # (E, C, D)
        ybuf = jnp.concatenate(
            [yout.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)])
        contrib = ybuf[dest] * (sg[:, None].astype(x.dtype)
                                * keep[:, None].astype(x.dtype))
        out = jnp.zeros((s, d), x.dtype).at[st].add(contrib)
        return out

    out = jax.vmap(per_row)(x, top_p, top_e)
    if cfg.n_shared > 0:
        out = out + mlp_apply(p["shared"], x, cfg)
    return constrain(out, "batch", "resid_seq", "resid_embed")


def _moe_apply_einsum(p, x, cfg):
    """Capacity-based top-k routing with einsum dispatch/combine.

    Tokens grouped by batch row (group = one sequence): capacity
    C = ceil(S * k / E * capacity_factor). Dropped tokens fall through the
    residual (standard Switch behaviour).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    top_p, top_e, cap = _moe_router(p, x, cfg)

    # Position of each (token, choice) in its expert's buffer.
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)       # (B,S,k,E)
    comb = (onehot * top_p[..., None]).sum(2)                  # (B,S,E)
    mask = onehot.sum(2)                                       # (B,S,E) 0/1
    pos = jnp.cumsum(mask, axis=1) - 1.0                       # (B,S,E)
    keep = (pos < cap) & (mask > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
    disp = pos_oh * keep[..., None].astype(x.dtype)            # (B,S,E,C)

    xin = jnp.einsum("bsec,bsd->becd", disp, x)                # (B,E,C,D)
    xin = constrain(xin, "batch", "expert", None, None)
    eout = _moe_ffn(p, xin, cfg)
    eout = constrain(eout, "batch", "expert", None, None)
    out = jnp.einsum("becd,bsec->bsd", eout,
                     disp * comb.astype(x.dtype)[..., None])
    if cfg.n_shared > 0:
        out = out + mlp_apply(p["shared"], x, cfg)
    return constrain(out, "batch", "resid_seq", "resid_embed")


def moe_aux_loss(p, x, cfg):
    """Load-balance auxiliary loss (Switch-style)."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD — state-space duality, chunked)
# ---------------------------------------------------------------------------


def init_ssm(key, cfg):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = din + 2 * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": trunc_normal(ks[0], (d, 2 * din + 2 * n + nh),
                                1.0 / math.sqrt(d)),
        "conv_w": trunc_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.2),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": trunc_normal(ks[2], (din, d), 1.0 / math.sqrt(din)),
    }


def ssm_axes():
    return {"in_proj": ("fsdp", "tensor"), "conv_w": (None, "tensor"),
            "A_log": (None,), "dt_bias": (None,), "D": (None,),
            "out_proj": ("tensor", "fsdp")}


def _causal_conv(x, w, carry=None):
    """Depthwise causal conv along seq. x: (B,S,C), w: (W,C).

    carry: (B, W-1, C) previous context (decode); returns (y, new_carry).
    """
    width = w.shape[0]
    if carry is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(width))
    new_carry = xp[:, -(width - 1):]
    return y, new_carry


def ssm_apply(p, x, cfg, state=None, conv_carry=None):
    """Chunked SSD forward. state: (B, nh, hd, N) for decode.

    Returns (y, (new_state, new_conv_carry)).
    """
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    nh = din // hd
    n = cfg.ssm_state

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * n]
    dt = zxbcdt[..., -nh:]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_carry)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :din].reshape(b, s, nh, hd)
    xs = constrain(xs, "batch", None, "tensor", None)
    bmat = xbc[..., din:din + n]                       # (B,S,N) single group
    cmat = xbc[..., din + n:]                          # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])   # (B,S,nh)
    a = -jnp.exp(p["A_log"])[None, None]               # (1,1,nh)
    da = dt * a                                        # (B,S,nh) negative

    if state is not None and s == 1:  # single-step decode
        xs1 = xs[:, 0]                                 # (B,nh,hd)
        dt1 = dt[:, 0]
        da1 = jnp.exp(da[:, 0])                        # (B,nh)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, bmat[:, 0].astype(jnp.float32),
                         xs1.astype(jnp.float32))
        new_state = state * da1[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new_state, cmat[:, 0].astype(jnp.float32))
        y = y + p["D"][None, :, None] * xs1.astype(jnp.float32)
        y = y.reshape(b, 1, din).astype(x.dtype)
        y = y * jax.nn.silu(z)
        return y @ p["out_proj"].astype(x.dtype), (new_state, new_conv)

    q = min(cfg.ssm_chunk, s)
    if s % q != 0:  # ragged (smoke-test) sizes: single chunk
        q = s
    nc = s // q
    # The intra-chunk tensors (lmat/gmat: B,nc,q,q[,nh]) dominate the SSD
    # layer's HBM traffic; bf16 mode halves it with f32 accumulation in the
    # einsums (EXPERIMENTS.md §Perf, mamba2 hillclimb).
    intra_dt = jnp.bfloat16 if cfg.ssm_bf16_intra else jnp.float32
    xs_c = xs.reshape(b, nc, q, nh, hd)
    b_c = bmat.reshape(b, nc, q, n).astype(intra_dt)
    c_c = cmat.reshape(b, nc, q, n).astype(intra_dt)
    dt_c = dt.reshape(b, nc, q, nh)
    da_c = da.reshape(b, nc, q, nh)
    acum = jnp.cumsum(da_c, axis=2)                    # (B,nc,q,nh) f32

    # Intra-chunk (quadratic within chunk): L[i,j] = exp(acum_i - acum_j) i>=j.
    # Mask *before* exp: the upper triangle has positive diffs whose exp
    # overflows and poisons the backward pass through where().
    diff = acum[:, :, :, None] - acum[:, :, None, :, :]  # (B,nc,q,q,nh)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.exp(jnp.where(tri[None, None, ..., None], diff, -1e30))
    lmat = lmat.astype(intra_dt)
    # scores g[i,j] = C_i . B_j
    gmat = jnp.einsum("bcin,bcjn->bcij", c_c, b_c,
                      preferred_element_type=intra_dt)
    y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp", gmat, lmat,
                        dt_c.astype(intra_dt), xs_c.astype(intra_dt),
                        preferred_element_type=jnp.float32)

    # Chunk-final states + inter-chunk recurrence.
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)  # (B,nc,q,nh)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjh,bcjhp->bchpn", b_c,
                             decay_to_end, dt_c, xs_c.astype(jnp.float32))
    chunk_decay = jnp.exp(acum[:, :, -1, :])           # (B,nc,nh)

    def scan_states(h_prev, inp):
        st, dec = inp
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, nh, hd, n), jnp.float32) if state is None else state
    last, h_prevs = jax.lax.scan(
        scan_states,
        init,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (B,nc,nh,hd,n)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", c_c, h_prevs,
                       jnp.exp(acum))
    y = (y_diag + y_off).reshape(b, s, nh, hd)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", None, "tensor")
    return y @ p["out_proj"].astype(x.dtype), (last, new_conv)


def ssm_cache(cfg, batch: int, dtype):
    din = cfg.ssm_expand * cfg.d_model
    nh = din // cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           din + 2 * cfg.ssm_state), dtype),
    }


def ssm_cache_axes():
    return {"state": ("batch", "tensor", None, None),
            "conv": ("batch", None, "tensor")}


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "in_x": trunc_normal(ks[0], (d, w), 1.0 / math.sqrt(d)),
        "in_gate": trunc_normal(ks[1], (d, w), 1.0 / math.sqrt(d)),
        "conv_w": trunc_normal(ks[2], (cfg.rnn_conv, w), 0.2),
        "w_input_gate": trunc_normal(ks[3], (w, w), 1.0 / math.sqrt(w)),
        "w_rec_gate": trunc_normal(ks[4], (w, w), 1.0 / math.sqrt(w)),
        "lam": 8.0 * jnp.ones((w,), jnp.float32),  # Λ parameter
        "out_proj": trunc_normal(ks[5], (w, d), 1.0 / math.sqrt(w)),
    }


def rglru_axes():
    return {"in_x": ("fsdp", "tensor"), "in_gate": ("fsdp", "tensor"),
            "conv_w": (None, "tensor"), "w_input_gate": (None, "tensor"),
            "w_rec_gate": (None, "tensor"), "lam": ("tensor",),
            "out_proj": ("tensor", "fsdp")}


_RG_C = 8.0


def rglru_apply(p, x, cfg, state=None, conv_carry=None):
    """Griffin recurrent block: proj -> causal conv -> RG-LRU -> gated out.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Λ) * r_t).
    """
    xb = x @ p["in_x"].astype(x.dtype)
    gate = x @ p["in_gate"].astype(x.dtype)
    xb, new_conv = _causal_conv(xb, p["conv_w"], conv_carry)
    xb = constrain(xb, "batch", None, "tensor")

    r = jax.nn.sigmoid((xb @ p["w_rec_gate"].astype(xb.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["w_input_gate"].astype(xb.dtype)).astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb.astype(jnp.float32))

    if state is not None and x.shape[1] == 1:  # decode: single step
        h = a[:, 0] * state + gated[:, 0]
        y = h[:, None]
        new_state = h
    else:
        # associative scan over the linear recurrence h_t = a_t h_{t-1} + b_t
        if state is not None:  # chain from a carried state
            gated = gated.at[:, 0].add(a[:, 0] * state)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_s, b_s = jax.lax.associative_scan(combine, (a, gated), axis=1)
        y = b_s
        new_state = b_s[:, -1]
    y = y.astype(x.dtype) * jax.nn.gelu(gate)
    y = constrain(y, "batch", None, "tensor")
    return y @ p["out_proj"].astype(x.dtype), (new_state, new_conv)


def rglru_cache(cfg, batch: int, dtype):
    return {
        "state": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rnn_conv - 1, cfg.rnn_width), dtype),
    }


def rglru_cache_axes():
    return {"state": ("batch", "tensor"), "conv": ("batch", None, "tensor")}
