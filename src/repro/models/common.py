"""Shared model components: norms, RoPE, initializers, dtype policy.

All functions take explicit dtypes — the LM stack must behave identically
whether or not x64 is globally enabled (repro.core enables it; the dry-run
does not import repro.core).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps: float = 1e-6, upcast: bool = True):
    """RMSNorm. upcast=True (default): f32 math on the full tensor — safest,
    but under GSPMD the f32 convert gets hoisted before the residual-stream
    all-gather, doubling its wire bytes. upcast=False keeps the tensor bf16
    and only accumulates the variance in f32 (§Perf 'bf16_norm' variant)."""
    dtype = x.dtype
    if upcast:
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
        return out.astype(dtype)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(dtype)
    return x * inv * (1.0 + scale.astype(jnp.float32)).astype(dtype)


def make_rope(positions, head_dim: int, theta: float = 10000.0):
    """Rotary embedding tables for given positions: (..., head_dim/2) each."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch/heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def trunc_normal(key, shape, std: float, dtype=jnp.float32):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def param_count(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))
