"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L, d_model 1536, 24 heads (MHA kv=24, head_dim 64), d_ff 6144, vocab 2048
(one EnCodec codebook head; the 4-codebook delay-pattern frontend is a STUB:
``input_specs`` supplies pre-computed frame embeddings per the assignment).
24 heads do not divide 16 -> attention shards on batch only.
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", vocab=2048, d_model=1536, n_layers=48,
        n_heads=24, n_kv=24, head_dim=64, d_ff=6144,
        embed_inputs=True, heads_shardable=False, attn_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", vocab=256, d_model=96, n_layers=2,
        n_heads=6, n_kv=6, head_dim=16, d_ff=288,
        embed_inputs=True, heads_shardable=False, attn_chunk=32,
    )
