"""DBRX-132B [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), vocab 100352,
fine-grained MoE: 16 experts, top-4, expert d_ff 10752.
16 experts shard exactly onto the 16-way tensor axis (1 expert/device).
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", vocab=100352, d_model=6144, n_layers=40,
        n_heads=48, n_kv=8, head_dim=128,
        block_pattern=("moe",), n_experts=16, top_k=4, d_ff_expert=10752,
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", vocab=512, d_model=96, n_layers=2,
        n_heads=4, n_kv=2, head_dim=24,
        block_pattern=("moe",), n_experts=4, top_k=2, d_ff_expert=128,
        attn_chunk=64,
    )
