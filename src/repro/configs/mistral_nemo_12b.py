"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model 5120, 32 heads (GQA kv=8), explicit head_dim 128 (not 5120/32),
d_ff 14336, vocab 131072, 128k-context rope theta 1e6.
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", vocab=131072, d_model=5120, n_layers=40,
        n_heads=32, n_kv=8, head_dim=128, d_ff=14336,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", vocab=512, d_model=128, n_layers=2,
        n_heads=4, n_kv=2, head_dim=32, d_ff=384, rope_theta=1_000_000.0,
        attn_chunk=64,
    )
