"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L, d_model 4096, pattern 2 recurrent (RG-LRU, width 4096) : 1 local
attention (window 2048, MQA kv=1, head_dim 256), d_ff 12288, vocab 256000,
GeGLU. Fixed-size state + ring local cache -> runs long_500k decode.
38 = 12 x (rec, rec, attn_local) + (rec, rec).
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", vocab=256000, d_model=4096, n_layers=38,
        n_heads=16, n_kv=1, head_dim=256, d_ff=12288,
        block_pattern=("rec", "rec", "attn_local"),
        window=2048, rnn_width=4096, rnn_conv=4,
        mlp_act="gelu", sub_quadratic=True, attn_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", vocab=512, d_model=96, n_layers=5,
        n_heads=4, n_kv=1, head_dim=24, d_ff=288,
        block_pattern=("rec", "rec", "attn_local"),
        window=32, rnn_width=96, rnn_conv=4,
        mlp_act="gelu", sub_quadratic=True, attn_chunk=32,
    )
