"""DeepSeekMoE-16B [arXiv:2401.06066; hf].

28L, d_model 2048, 16 heads (kv=16 i.e. MHA, head_dim 128), vocab 102400.
Fine-grained MoE: 64 routed experts top-6 + 2 shared experts, expert d_ff
1408; layer 0 is a dense FFN (d_ff 10944).
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", vocab=102400, d_model=2048, n_layers=28,
        n_heads=16, n_kv=16, head_dim=128, d_ff=10944,
        block_pattern=("moe",), first_dense=True,
        n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", vocab=512, d_model=96, n_layers=3,
        n_heads=4, n_kv=4, head_dim=24, d_ff=256,
        block_pattern=("moe",), first_dense=True,
        n_experts=8, top_k=2, n_shared=1, d_ff_expert=64,
        attn_chunk=64,
    )
