"""InternVL2-76B [arXiv:2404.16821] — Llama-3-70B-class language backbone.

80L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 28672,
vocab 128256. The InternViT vision frontend is a STUB per the assignment:
``input_specs`` supplies pre-computed patch embeddings (B, S, d_model).
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", vocab=128256, d_model=8192, n_layers=80,
        n_heads=64, n_kv=8, head_dim=128, d_ff=28672,
        rope_theta=500_000.0, embed_inputs=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke", vocab=512, d_model=128, n_layers=2,
        n_heads=4, n_kv=2, head_dim=32, d_ff=384, embed_inputs=True,
        attn_chunk=64,
    )
