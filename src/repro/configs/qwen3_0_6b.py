"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B family].

28L, d_model 1024, 16 heads (GQA kv=8, explicit head_dim 128), d_ff 3072,
vocab 151936, per-head q/k RMSNorm (qk_norm).
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", vocab=151936, d_model=1024, n_layers=28,
        n_heads=16, n_kv=8, head_dim=128, d_ff=3072,
        qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-smoke", vocab=512, d_model=96, n_layers=2,
        n_heads=4, n_kv=2, head_dim=24, d_ff=288, qk_norm=True,
        attn_chunk=64,
    )
