"""Architecture registry: one module per assigned architecture.

Each module provides ``config()`` (the exact public configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCHS = (
    "minitron_4b",
    "mistral_nemo_12b",
    "gemma2_2b",
    "qwen3_0_6b",
    "dbrx_132b",
    "deepseek_moe_16b",
    "internvl2_76b",
    "mamba2_1_3b",
    "recurrentgemma_9b",
    "musicgen_medium",
)

_ALIAS = {name.replace("_", "-"): name for name in ARCHS}
_ALIAS.update({"qwen3-0.6b": "qwen3_0_6b", "mamba2-1.3b": "mamba2_1_3b"})


def canonical(name: str) -> str:
    key = name.replace("-", "_").replace(".", "_")
    if key in ARCHS:
        return key
    if name in _ALIAS:
        return _ALIAS[name]
    raise KeyError(f"unknown architecture {name!r}; known: {list(ARCHS)}")


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config() if smoke else mod.config()
