"""Gemma-2 2B [arXiv:2408.00118; hf].

26L, d_model 2304, 8 heads (GQA kv=4, explicit head_dim 256), d_ff 9216,
vocab 256000. Alternating local (window 4096) / global attention, attention
softcap 50, final-logit softcap 30, GeGLU MLP. 8 heads < 16-way tensor axis
-> attention shards on batch only.
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", vocab=256000, d_model=2304, n_layers=26,
        n_heads=8, n_kv=4, head_dim=256, d_ff=9216,
        block_pattern=("attn_local", "attn_global"),
        window=4096, attn_softcap=50.0, logit_softcap=30.0,
        mlp_act="gelu", heads_shardable=False, attn_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", vocab=512, d_model=96, n_layers=4,
        n_heads=4, n_kv=2, head_dim=24, d_ff=288,
        block_pattern=("attn_local", "attn_global"),
        window=32, attn_softcap=50.0, logit_softcap=30.0,
        mlp_act="gelu", heads_shardable=False, attn_chunk=32,
    )
