"""Minitron-4B — width/depth-pruned Nemotron [arXiv:2407.14679; hf].

32L, d_model 3072, 24 heads (GQA kv=8, head_dim 128), d_ff 9216, vocab 256000.
24 heads do not divide the 16-way tensor axis -> attention activations shard
on batch only (heads_shardable=False); MLP/vocab dims still shard 16-way.
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", vocab=256000, d_model=3072, n_layers=32,
        n_heads=24, n_kv=8, head_dim=128, d_ff=9216,
        rope_theta=10000.0, heads_shardable=False, attn_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", vocab=512, d_model=96, n_layers=2,
        n_heads=6, n_kv=2, head_dim=16, d_ff=288,
        heads_shardable=False, attn_chunk=64,
    )
