"""Mamba2-1.3B [arXiv:2405.21060] — SSD (state-space duality), attention-free.

48L, d_model 2048 (d_inner 4096 = 2x expand, 64 heads of head_dim 64,
d_state 128, conv width 4), vocab 50280. Constant-size recurrent state ->
runs the long_500k decode shape.
"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", vocab=50280, d_model=2048, n_layers=48,
        block_pattern=("ssm",), ssm_state=128, ssm_head_dim=64,
        ssm_expand=2, ssm_conv=4, ssm_chunk=256, sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke", vocab=512, d_model=64, n_layers=2,
        block_pattern=("ssm",), ssm_state=16, ssm_head_dim=16,
        ssm_expand=2, ssm_conv=4, ssm_chunk=32, sub_quadratic=True,
    )
