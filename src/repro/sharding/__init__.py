from repro.sharding.rules import (  # noqa: F401
    LOGICAL_RULES,
    MESH_AXES,
    constrain,
    logical_to_spec,
    set_mesh,
    get_mesh,
    param_sharding,
)
