"""Logical-axis sharding rules (MaxText-style) for the LM substrate.

Physical mesh axes:
  * ``pod``   — cross-pod data parallelism (DCN axis; multi-pod mesh only)
  * ``data``  — in-pod data parallel + ZeRO/FSDP weight sharding
  * ``model`` — tensor parallel (heads / d_ff / vocab / experts) and the
                residual-stream d_model shard between layers (Megatron-SP
                flavored: XLA inserts the boundary all-gathers)

Logical axes used by the model code:

  batch      -> (pod, data)      activations' leading dim
  embed      -> model            residual-stream d_model (activation only)
  fsdp       -> data             weight dim sharded ZeRO-style
  tensor     -> model            weight head/ff/vocab/expert dims
  kv_heads   -> model            KV-cache head dim (padded if not divisible)
  none       -> replicated

The mesh is installed per-process via ``set_mesh``; with no mesh installed
every constraint is a no-op, so smoke tests on 1 CPU device run unchanged.
"""
from __future__ import annotations

import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MESH_AXES = ("pod", "data", "model")

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "embed": ("model",),
    "fsdp": ("data",),
    "tensor": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "kv_seq": (),           # enabled instead of kv_heads when heads < mesh
    "expert": ("model",),
    "vocab": ("model",),
    # Residual-stream (B, S, D) sharding between blocks. Baseline shards D
    # ("Megatron-SP over d_model"); the §Perf seq_sp variant shards S
    # instead, which removes the per-matmul f32 activation all-gathers
    # (see EXPERIMENTS.md §Perf hillclimb 1).
    "resid_seq": (),
    "resid_embed": ("model",),
    "blk_in_embed": ("model",),   # zero_r variant: () = replicate in-block
    None: (),
}

_state = threading.local()


def set_mesh(mesh: Mesh | None, rules: dict | None = None):
    _state.mesh = mesh
    _state.rules = dict(LOGICAL_RULES if rules is None else rules)


def get_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def _rules() -> dict:
    return getattr(_state, "rules", LOGICAL_RULES)


def logical_to_spec(logical_axes, shape=None) -> P:
    """Tuple of logical axis names (or None) -> PartitionSpec filtered to the
    axes that exist on the installed mesh.

    When ``shape`` is given, any dim not evenly divisible by its mesh-axis
    product is left unsharded (explicit input shardings must divide; this is
    also how non-16-divisible head counts fall back to replication).
    """
    mesh = get_mesh()
    mesh_axis_names = set(mesh.axis_names) if mesh is not None else set()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    rules = _rules()
    spec = []
    for d, ax in enumerate(logical_axes):
        phys = [a for a in rules.get(ax, ()) if a in mesh_axis_names]
        if shape is not None and phys:
            n = 1
            for a in phys:
                n *= sizes[a]
            if shape[d] % n != 0:
                phys = []
        if not phys:
            spec.append(None)
        elif len(phys) == 1:
            spec.append(phys[0])
        else:
            spec.append(tuple(phys))
    return P(*spec)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(logical_axes, shape=None) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape=shape))
