"""Build-phase timeline: phases + per-launch compaction events.

Construction is single-threaded host orchestration around device launches,
so the recorder here is simpler than the serving ``Tracer``: an append-only
list of dict events. ``build_pairwise_hist`` opens one ``phase(...)`` per
pipeline stage (sample, 1-D refine, pair phase, union regrid, folds) and
``build_pairs_compact`` appends one ``compact_launch`` event per device
relaunch carrying the drained/escalated/occupancy counters PR 5's ledger
already tracks — making compaction behavior visible on a Perfetto track
instead of a single ``pair_phase_s`` scalar.

Events are plain dicts (JSON-ready, survive a trip through
``build_stats``): ``{"name", "t0", "t1", "kind": "phase"|"event", ...attrs}``
with perf_counter seconds.
"""
from __future__ import annotations

import time
from contextlib import contextmanager


class BuildTimeline:
    """Append-only event recorder for one synopsis construction."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events: list[dict] = []
        self.t_start = time.perf_counter()

    @contextmanager
    def phase(self, name: str, **attrs):
        """Time a pipeline stage; the caller is responsible for fencing
        device work (``jax.block_until_ready``) inside the block so the
        interval is honest wall-clock, not dispatch time."""
        if not self.enabled:
            yield self
            return
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            ev = {"name": name, "t0": t0, "t1": time.perf_counter(),
                  "kind": "phase"}
            ev.update(attrs)
            self.events.append(ev)

    def add(self, name: str, t0: float, t1: float, **attrs):
        """Record an interval from captured timestamps."""
        if not self.enabled:
            return
        ev = {"name": name, "t0": t0, "t1": t1, "kind": "phase"}
        ev.update(attrs)
        self.events.append(ev)

    def event(self, name: str, **attrs):
        """Record an instantaneous marker (e.g. a rung escalation)."""
        if not self.enabled:
            return
        now = time.perf_counter()
        ev = {"name": name, "t0": now, "t1": now, "kind": "event"}
        ev.update(attrs)
        self.events.append(ev)

    def summary(self) -> dict:
        """Total seconds per phase name (events contribute zero)."""
        out: dict[str, float] = {}
        for ev in self.events:
            if ev["kind"] == "phase":
                out[ev["name"]] = out.get(ev["name"], 0.0) \
                    + (ev["t1"] - ev["t0"])
        return out
