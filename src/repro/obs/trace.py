"""Span tracing: a monotonic-clock, lock-free ring-buffer collector.

Design constraints (serving hot path):

  * **near-zero cost when disabled** — ``Tracer.span`` on a disabled tracer
    returns one shared no-op context manager (no allocation, no clock
    read); call sites that would build attribute dicts guard on
    ``tracer.enabled`` first.
  * **lock-free when enabled** — committing a span claims a slot from an
    ``itertools.count`` (atomic under CPython) and writes one list item;
    there is no lock to contend on and a recording thread can never block
    a submitter. The buffer is a fixed-capacity ring: once full, the
    oldest spans are overwritten (``n_dropped`` counts them) — tracing is
    a window, not an unbounded log.
  * **monotonic clock** — all timestamps are ``time.perf_counter()``
    seconds; exporters rebase to the first event.

``QueryTrace`` is the per-query companion: one slotted object riding a
serving submission that stamps the stage-boundary timestamps
(submit/plan/admit/drain/execute/resolve) across threads and assembles the
EXPLAIN breakdown — the stages *tile* the submit->resolve interval, so the
breakdown accounts for the full client-observed wall clock.
"""
from __future__ import annotations

import itertools
import time


class Span:
    """One recorded interval (or instant, when ``t1 == t0``).

    ``track`` is a free-form lane name (``"q42"`` for a query's own lane,
    ``"worker"`` / ``"submit-<tid>"`` for thread lanes); the exporter maps
    each distinct track to a Perfetto thread row. ``attrs`` become the
    event's ``args``.
    """

    __slots__ = ("seq", "name", "cat", "t0", "t1", "track", "attrs")

    def __init__(self, name: str, cat: str, t0: float, t1: float,
                 track: str, attrs: dict | None):
        self.seq = -1
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t1
        self.track = track
        self.attrs = attrs

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, cat={self.cat!r}, track={self.track!r},"
                f" dur={(self.t1 - self.t0) * 1e3:.3f}ms)")


class _NoopSpan:
    """Shared do-nothing context manager returned by disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _LiveSpan:
    """Context manager that stamps perf_counter on enter/exit and commits."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_attrs", "_t0")

    def __init__(self, tracer, name, cat, track, attrs):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.add(self._name, self._t0, time.perf_counter(),
                         cat=self._cat, track=self._track,
                         attrs=self._attrs)
        return False


class Tracer:
    """Lock-free ring-buffer span collector.

    Args:
        capacity: ring size in spans (oldest overwritten beyond it).
        enabled: when False every recording call is a no-op; flip
            ``enabled`` at runtime to start/stop collection.
        annotate_jax: when True, instrumented kernel launches additionally
            open a ``jax.profiler.TraceAnnotation`` so spans line up with
            a captured JAX profiler trace (off by default — it is only
            useful under an active profiler session).
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 annotate_jax: bool = False):
        self.capacity = max(int(capacity), 1)
        self.enabled = bool(enabled)
        self.annotate_jax = bool(annotate_jax)
        self._buf: list = [None] * self.capacity
        self._seq = itertools.count()
        self._n = 0   # spans ever committed (monotonic; benign read races)

    # -------------------------------------------------------------- recording

    def span(self, name: str, cat: str = "serve", track: str = "main",
             attrs: dict | None = None):
        """Context manager timing a block; no-op when disabled."""
        if not self.enabled:
            return NOOP_SPAN
        return _LiveSpan(self, name, cat, track, attrs)

    def add(self, name: str, t0: float, t1: float, cat: str = "serve",
            track: str = "main", attrs: dict | None = None):
        """Record a span retroactively from already-captured timestamps
        (how cross-thread intervals like queue-wait are recorded)."""
        if not self.enabled:
            return
        span = Span(name, cat, t0, t1, track, attrs)
        i = next(self._seq)            # atomic slot claim (CPython)
        span.seq = i
        self._buf[i % self.capacity] = span
        self._n = i + 1

    def instant(self, name: str, cat: str = "serve", track: str = "main",
                attrs: dict | None = None):
        """Record a zero-duration event (shed / requeue / drain markers)."""
        if not self.enabled:
            return
        now = time.perf_counter()
        self.add(name, now, now, cat=cat, track=track, attrs=attrs)

    # -------------------------------------------------------------- inspection

    @property
    def n_recorded(self) -> int:
        """Total spans ever committed (including overwritten ones)."""
        return self._n

    @property
    def n_dropped(self) -> int:
        """Spans overwritten by ring wraparound."""
        return max(0, self._n - self.capacity)

    def spans(self) -> list:
        """The retained window, oldest first (at most ``capacity`` spans)."""
        live = [s for s in self._buf if s is not None]
        live.sort(key=lambda s: s.seq)
        return live

    def clear(self):
        """Drop every retained span (counters reset too)."""
        self._buf = [None] * self.capacity
        self._seq = itertools.count()
        self._n = 0


# ---------------------------------------------------------------------------
# Per-query trace
# ---------------------------------------------------------------------------

_QID = itertools.count(1)

# Stage-boundary timestamp chain. Each stage's duration is the gap from the
# previous *present* boundary, so the stages tile t_submit -> t_resolved
# exactly — missing boundaries (e.g. a result-cache hit never queues)
# contribute zero width instead of holes.
_STAGES = (("plan", "t_planned"), ("admit", "t_admitted"),
           ("queue", "t_drained"), ("assemble", "t_exec0"),
           ("execute", "t_exec1"), ("resolve", "t_resolved"))


class QueryTrace:
    """Stage-boundary timestamps + flags for one submitted query.

    Stamped across threads (submit/plan on the submitter, drain/execute/
    resolve on the admission worker); each field is written once per
    attempt by exactly one thread, and the EXPLAIN breakdown is assembled
    only at resolution time, after every stamp has happened.
    """

    __slots__ = ("qid", "t_submit", "t_planned", "t_admitted", "t_drained",
                 "t_exec0", "t_exec1", "t_resolved", "plan_cache_hit",
                 "result_cache_hit", "plan_path", "drain_cause", "wave_size",
                 "kernel_share_s", "batched", "retries", "rejected")

    def __init__(self, t_submit: float | None = None):
        self.qid = next(_QID)
        self.t_submit = (time.perf_counter() if t_submit is None
                         else t_submit)
        self.t_planned = None
        self.t_admitted = None
        self.t_drained = None
        self.t_exec0 = None
        self.t_exec1 = None
        self.t_resolved = None
        self.plan_cache_hit = False
        self.result_cache_hit = False
        # Which planner path produced the plan: "full" (cold parse+plan),
        # "template" (zero-parse template bind), "plan_cache" (exact-text
        # plan-cache hit), or None (never planned, e.g. result-cache hit).
        self.plan_path = None
        self.drain_cause = None
        self.wave_size = 0
        self.kernel_share_s = 0.0
        self.batched = False
        self.retries = 0
        self.rejected = False

    @property
    def track(self) -> str:
        """This query's export lane (one Perfetto row per query)."""
        return f"q{self.qid}"

    def explain(self) -> dict:
        """The EXPLAIN breakdown: per-stage milliseconds + flags.

        ``plan/admit/queue/assemble/execute/resolve`` tile the full
        submit -> resolve interval (``total_ms``); ``kernel_share_ms`` is
        this query's amortized share of its fused wave/kernel launch time
        (informational — already contained inside ``execute_ms``).
        """
        out = {"qid": self.qid}
        prev = self.t_submit
        total = 0.0
        for stage, field in _STAGES:
            t = getattr(self, field)
            if t is None or t < prev:
                t = prev
            out[f"{stage}_ms"] = (t - prev) * 1e3
            total += t - prev
            prev = t
        out["total_ms"] = total * 1e3
        out["kernel_share_ms"] = self.kernel_share_s * 1e3
        out["plan_cache_hit"] = self.plan_cache_hit
        out["result_cache_hit"] = self.result_cache_hit
        out["plan_path"] = self.plan_path
        out["batched"] = self.batched
        out["wave_size"] = self.wave_size
        out["drain_cause"] = self.drain_cause
        out["stale_retries"] = self.retries
        out["rejected"] = self.rejected
        return out

    def emit_spans(self, tracer: Tracer, label: str = ""):
        """Write this query's stage spans onto its own export lane."""
        if not tracer.enabled:
            return
        track = self.track
        attrs = {"qid": self.qid}
        if label:
            attrs["sql"] = label
        if self.plan_path is not None:
            attrs["plan_path"] = self.plan_path
        prev = self.t_submit
        for stage, field in _STAGES:
            t = getattr(self, field)
            if t is None or t < prev:
                continue
            if t > prev:
                tracer.add(stage, prev, t, cat="query", track=track,
                           attrs=attrs if stage == "plan" else None)
            prev = t
