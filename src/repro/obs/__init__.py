"""Observability: spans, per-query traces, build timelines, Perfetto export.

The serving stack (``repro.serve.aqp``) threads a per-query ``QueryTrace``
through submit -> admission -> wave -> resolution and records spans into a
lock-free ring-buffer ``Tracer``; the construction stack records a
``BuildTimeline`` of phases and per-launch compaction events into
``PairwiseHist.build_stats``. Both sides export to Chrome/Perfetto
``trace_event`` JSON via ``repro.obs.export`` (open the artifact at
https://ui.perfetto.dev). Reference: docs/observability.md.
"""
from repro.obs.export import (spans_to_events, timeline_to_events,  # noqa: F401
                              trace_json, validate_trace_events, write_trace)
from repro.obs.timeline import BuildTimeline  # noqa: F401
from repro.obs.trace import NOOP_SPAN, QueryTrace, Span, Tracer  # noqa: F401
