"""Chrome/Perfetto ``trace_event`` JSON export + schema validation.

Emits the JSON-array flavor of the Trace Event Format: ``"X"`` (complete)
events with microsecond ``ts``/``dur``, ``"i"`` instants, and ``"M"``
metadata events naming the tracks. Everything lands under a single
``pid``; each distinct span track (a query lane, the admission worker, a
submitter thread, a build phase lane) gets its own ``tid`` so Perfetto
renders one row per track. Load artifacts at https://ui.perfetto.dev or
chrome://tracing.
"""
from __future__ import annotations

import json
import os

_PID = 1


def _track_tids(names):
    """Stable track-name -> tid mapping plus the naming metadata events.

    Tracks are numbered in first-appearance order; query lanes (``q<n>``)
    sort after service lanes so the per-query swimlanes group together at
    the bottom of the view.
    """
    service = [n for n in names if not (n.startswith("q") and n[1:].isdigit())]
    queries = [n for n in names if n.startswith("q") and n[1:].isdigit()]
    queries.sort(key=lambda n: int(n[1:]))
    tids = {}
    meta = []
    for i, name in enumerate(service + queries):
        tids[name] = i
        meta.append({"name": "thread_name", "ph": "M", "pid": _PID,
                     "tid": i, "args": {"name": name}})
    return tids, meta


def spans_to_events(spans, t0: float | None = None) -> list[dict]:
    """Convert ``Tracer`` spans to trace_event dicts (ts rebased to t0)."""
    spans = list(spans)
    if not spans:
        return []
    if t0 is None:
        t0 = min(s.t0 for s in spans)
    seen = []
    for s in spans:
        if s.track not in seen:
            seen.append(s.track)
    tids, events = _track_tids(seen)
    for s in spans:
        ev = {"name": s.name, "cat": s.cat, "pid": _PID,
              "tid": tids[s.track], "ts": (s.t0 - t0) * 1e6}
        if s.t1 > s.t0:
            ev["ph"] = "X"
            ev["dur"] = (s.t1 - s.t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"           # thread-scoped instant
        if s.attrs:
            ev["args"] = dict(s.attrs)
        events.append(ev)
    return events


def timeline_to_events(timeline, t0: float | None = None) -> list[dict]:
    """Convert a ``BuildTimeline`` (or its raw ``events`` list) to
    trace_event dicts. Phases go on a ``build`` track, instantaneous
    markers and per-launch events on a ``compact`` track."""
    raw = timeline if isinstance(timeline, list) else timeline.events
    if not raw:
        return []
    if t0 is None:
        t0 = min(ev["t0"] for ev in raw)
    tids, events = _track_tids(["build", "compact"])
    for ev in raw:
        track = "build" if ev["kind"] == "phase" else "compact"
        args = {k: v for k, v in ev.items()
                if k not in ("name", "t0", "t1", "kind")}
        out = {"name": ev["name"], "cat": "build", "pid": _PID,
               "tid": tids[track], "ts": (ev["t0"] - t0) * 1e6}
        if ev["t1"] > ev["t0"]:
            out["ph"] = "X"
            out["dur"] = (ev["t1"] - ev["t0"]) * 1e6
        else:
            out["ph"] = "i"
            out["s"] = "t"
        if args:
            out["args"] = args
        events.append(out)
    return events


def trace_json(events: list[dict]) -> str:
    """Serialize events as the JSON-array trace format Perfetto accepts."""
    return json.dumps(events, separators=(",", ":"), default=str)


def write_trace(path, events: list[dict]) -> str:
    """Write events to ``path`` (parent dirs created); returns the path."""
    parent = os.path.dirname(str(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        f.write(trace_json(events))
    return str(path)


def validate_trace_events(events) -> list[str]:
    """Schema-check a parsed event list; returns problems ([] = valid).

    Checks the invariants Perfetto's importer actually relies on: a JSON
    array of objects, required keys per phase type, numeric non-negative
    ``ts``/``dur``, and ``M`` metadata naming each referenced tid.
    """
    problems = []
    if not isinstance(events, list):
        return ["top level is not a JSON array"]
    named_tids = set()
    used_tids = set()
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if "name" not in ev or not isinstance(ev["name"], str):
            problems.append(f"{where}: missing/invalid name")
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            problems.append(f"{where}: pid/tid must be ints")
            continue
        if ph == "M":
            named_tids.add(ev["tid"])
            continue
        used_tids.add(ev["tid"])
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: ts must be a non-negative number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event needs non-negative dur")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be an object")
    for tid in sorted(used_tids - named_tids):
        problems.append(f"tid {tid} has events but no thread_name metadata")
    return problems
