from repro.train.optimizer import adamw_init, adamw_update, Hyper  # noqa: F401
from repro.train.step import make_train_step, TrainState  # noqa: F401
