"""Pure-JAX AdamW with linear-warmup cosine decay and global-norm clipping.

Optimizer state is a pytree mirroring params (f32 moments), so the params'
logical sharding axes apply verbatim to mu/nu — ZeRO-style sharded optimizer
state for free under pjit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Hyper:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(hyper: Hyper, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(hyper.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - hyper.warmup_steps)
                    / jnp.maximum(hyper.total_steps - hyper.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return hyper.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, opt, step, hyper: Hyper):
    """Returns (new_params, new_opt, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, hyper.clip_norm)
    lr = schedule(hyper, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - hyper.b1 ** t
    bc2 = 1.0 - hyper.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = hyper.b1 * mu + (1.0 - hyper.b1) * g
        nu = hyper.b2 * nu + (1.0 - hyper.b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        p32 = p.astype(jnp.float32)
        step_val = mhat / (jnp.sqrt(vhat) + hyper.eps)
        if p.ndim >= 2:  # decay matrices only (norms/embeddings-1d exempt)
            step_val = step_val + hyper.weight_decay * p32
        return (p32 - lr * step_val).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt["mu"])
    flat_nu = treedef.flatten_up_to(opt["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu}, {"grad_norm": gnorm, "lr": lr}
