"""GD-inspired gradient compression with error feedback.

The paper's substrate (Generalized Deduplication: split values into a coarse
*base* + a *deviation*) re-applied to gradients: each step the gradient is
split into a quantized base grid (what the optimizer consumes) and a
deviation that enters an error-feedback accumulator, reappearing on later
steps (convergence-safe, cf. EF-SGD; verified by
tests/test_train.py::test_grad_compression_error_feedback_converges). Two
codecs:

  * ``GDQuantizer``  — per-tensor scale + int8 base grid (the "base bits"),
    error feedback carries the deviation;
  * ``TopKCompressor`` — classical sparsification baseline.

Scope note (honest): under single-program pjit the DP reduction is inserted
by GSPMD *after* dequantization, so this layer is the algorithmic half
(quantization + error feedback). Realizing the 4x wire reduction requires
moving the psum into the quantized domain with an explicit shard_map
reduction (or a custom collective) — a per-axis restructuring we document
as the deployment step rather than fake with a constraint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class GDQuantizer:
    """int8 base / error-feedback deviation gradient codec."""

    def __init__(self, bits: int = 8):
        if bits not in (4, 8):
            raise ValueError("bits must be 4 or 8")
        self.bits = bits
        self.levels = 2 ** (bits - 1) - 1

    def init(self, params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, err):
        """Returns (decompressed grads as seen by optimizer, new error)."""
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / self.levels
            base = jnp.clip(jnp.round(g32 / scale), -self.levels, self.levels)
            base = base.astype(jnp.int8)
            deq = base.astype(jnp.float32) * scale  # "base" part, transmitted
            new_e = g32 - deq                       # "deviation": kept local
            return deq, new_e

        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_e = td.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))


class TopKCompressor:
    """Keep the top-k fraction of entries per tensor; error-feedback rest."""

    def __init__(self, frac: float = 0.1):
        self.frac = frac

    def init(self, params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, err):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            flat = jnp.abs(g32).reshape(-1)
            k = max(1, int(flat.size * self.frac))
            thresh = jax.lax.top_k(flat, k)[0][-1]
            kept = jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)
            return kept, g32 - kept

        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_e = td.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))


def make_compressing_hook(codec, err_state_holder: dict):
    """Adapter for make_train_step(compressor=...): stateless-in-jit via an
    error-feedback tree threaded through TrainState-external storage is NOT
    jit-safe, so the hook signature takes/returns explicit state instead.

    Used by repro.train.loop which carries the error tree alongside
    TrainState.
    """
    def hook(grads, state):
        err = err_state_holder["err"]
        new_grads, new_err = codec.compress(grads, err)
        err_state_holder["err"] = new_err
        return new_grads, state
    return hook
