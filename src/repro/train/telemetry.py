"""Training-telemetry AQP: the paper's technique applied to the LM framework.

A 1000-node training fleet emits billions of telemetry rows (per-step loss,
grad-norm, step-time, per-host straggler timings). PairwiseHist gives sub-ms
approximate queries over that stream without a database — the paper's
Edge-analytics story applied to cluster health:

    tel = TelemetryStore()
    tel.record(step=i, loss=..., grad_norm=..., step_time=..., host=h)
    tel.build()                    # compressed store + synopsis
    tel.query("SELECT AVG(step_time) FROM t WHERE step > 1000")
    tel.query("SELECT MAX(step_time) FROM t WHERE host = 'host7'")  # stragglers
"""
from __future__ import annotations

import numpy as np


class TelemetryStore:
    def __init__(self, params=None):
        self._rows = []
        self._params = params
        self._framework = None

    def record(self, **fields):
        self._rows.append(fields)
        self._framework = None  # synopsis is stale

    def extend(self, rows: list):
        self._rows.extend(rows)
        self._framework = None

    def _table(self) -> dict:
        keys = sorted({k for row in self._rows for k in row})
        out = {}
        for k in keys:
            vals = [row.get(k) for row in self._rows]
            if all(isinstance(v, (int, float)) or v is None for v in vals):
                out[k] = np.array([np.nan if v is None else float(v)
                                   for v in vals])
            else:
                out[k] = np.array([str(v) for v in vals])
        return out

    def build(self):
        from repro.aqp.engine import AQPFramework
        from repro.core.types import BuildParams
        if not self._rows:
            raise ValueError("no telemetry recorded")
        params = self._params or BuildParams(
            n_samples=min(len(self._rows), 100_000))
        self._framework = AQPFramework(params).ingest(self._table())
        return self

    def query(self, sql: str):
        if self._framework is None:
            self.build()
        return self._framework.query(sql)

    def straggler_report(self, factor: float = 1.5) -> dict:
        """Hosts whose AVG(step_time) exceeds ``factor`` x the global median
        step time — the hot-spare trigger heuristic used by the train loop.
        All statistics come from the synopsis (sub-ms, no table scan)."""
        table = self._table()
        if "step_time" not in table or "host" not in table:
            return {}
        med = self.query("SELECT MEDIAN(step_time) FROM t")
        if med.estimate is None:
            return {}
        thresh = factor * med.estimate
        out = {}
        for host in np.unique(table["host"]):
            res = self.query(
                f"SELECT AVG(step_time) FROM t WHERE host = '{host}'")
            if res.estimate is not None and res.estimate > thresh:
                out[str(host)] = (res.estimate, thresh)
        return out
