"""Train step: loss -> grads -> AdamW, with optional microbatch accumulation
and optional gradient compression (repro.train.grad_compress).

Microbatch accumulation runs as a ``lax.scan`` over microbatches so XLA can
overlap the reduce-scatter of microbatch k's grads with microbatch k+1's
compute (a standard compute/comm-overlap trick at pod scale).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, loss_fn
from repro.train.optimizer import Hyper, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: dict
    step: jnp.ndarray


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    from repro.models.model import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, hyper: Hyper, microbatches: int = 1,
                    compressor=None, cast_bf16: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    cast_bf16: cast f32 master weights to bf16 *before* the layer stack (one
    tree-wide convert per step, outside the scan). Under ZeRO-3/FSDP this
    forces the per-layer weight all-gathers to move bf16 instead of f32 —
    halving the dominant collective volume (EXPERIMENTS.md §Perf).
    """

    def cast(params):
        return jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

    def grads_of(params, batch):
        if cast_bf16:
            return jax.value_and_grad(
                lambda p, b: loss_fn(cast(p), cfg, b))(params, batch)
        return jax.value_and_grad(loss_fn)(params, cfg, batch)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            loss, grads = grads_of(state.params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), zero),
                                            micro)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        if compressor is not None:
            grads, state = compressor(grads, state)
        params, opt, metrics = adamw_update(state.params, grads, state.opt,
                                            state.step, hyper)
        metrics["loss"] = loss
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        return new_state, metrics

    return train_step
