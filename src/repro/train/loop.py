"""Fault-tolerant training loop.

Features exercised by tests/examples (designed for 1000+-node fleets,
demonstrated single-host):

  * periodic + SIGTERM-triggered atomic checkpoints (preemption safety);
  * deterministic resume: data pipeline is a function of step, params/opt
    restore bit-exactly -> the loss trajectory after resume equals the
    uninterrupted run (tests/test_train_loop.py asserts this);
  * straggler watchdog: per-step wall times stream into the PairwiseHist
    telemetry store; steps above 1.5x the trailing p99 are flagged (on a
    real fleet this triggers hot-spare swap — here it logs);
  * failure injection (``fail_at_step``) for crash/restart testing;
  * optional GD-inspired gradient compression with error feedback.
"""
from __future__ import annotations

import signal
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models.model import ModelConfig
from repro.train.optimizer import Hyper
from repro.train.step import TrainState, init_train_state, make_train_step


class InjectedFailure(RuntimeError):
    pass


def train(cfg: ModelConfig, hyper: Hyper, *, steps: int, batch: int, seq: int,
          ckpt_dir: str, ckpt_every: int = 50, seed: int = 0,
          fail_at_step: int | None = None, compressor=None,
          microbatches: int = 1, log_every: int = 10,
          watchdog_factor: float = 1.5, telemetry=None, verbose: bool = True):
    """Run (or resume) training. Returns (final TrainState, history dict)."""
    pipeline = TokenPipeline(cfg.vocab, batch, seq, seed=seed)
    mgr = CheckpointManager(ckpt_dir)

    err_holder = {"err": None}
    hook = None
    if compressor is not None:
        def hook(grads, state):
            new_grads, new_err = compressor.compress(grads, err_holder["err"])
            err_holder["err"] = new_err
            return new_grads, state

    step_fn = jax.jit(make_train_step(cfg, hyper, microbatches=microbatches,
                                      compressor=hook))

    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    if compressor is not None:
        err_holder["err"] = compressor.init(state.params)
    start, restored = mgr.restore(state)
    if restored is not None:
        state = restored
        if verbose:
            print(f"[loop] resumed from step {start}")
    start_step = int(state.step)

    stop = {"now": False}

    def on_sigterm(signum, frame):
        stop["now"] = True

    old_handler = signal.signal(signal.SIGTERM, on_sigterm)
    history = {"loss": [], "step_time": [], "flagged_steps": []}
    times: list[float] = []
    try:
        for step in range(start_step, steps):
            if fail_at_step is not None and step == fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch_arrays = pipeline.host_slice(step)
            state, metrics = step_fn(state, batch_arrays)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            history["loss"].append(loss)
            history["step_time"].append(dt)
            if telemetry is not None:
                telemetry.record(step=step, loss=loss,
                                 grad_norm=float(metrics["grad_norm"]),
                                 step_time=dt, host="host0")
            # straggler watchdog on the trailing window
            if len(times) >= 20:
                p99 = float(np.quantile(times[-200:], 0.99))
                if dt > watchdog_factor * p99:
                    history["flagged_steps"].append(step)
                    if verbose:
                        print(f"[watchdog] step {step} took {dt:.3f}s "
                              f"(> {watchdog_factor:.1f} x p99 {p99:.3f}s) — "
                              "hot-spare swap would trigger here")
            if verbose and step % log_every == 0:
                print(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if (step + 1) % ckpt_every == 0 or stop["now"]:
                mgr.save(int(state.step), state)
            if stop["now"]:
                if verbose:
                    print("[loop] SIGTERM: checkpointed and exiting")
                break
    except InjectedFailure:
        raise
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        mgr.wait()
    mgr.save(int(state.step), state, blocking=True)
    return state, history
